# cxlmem build/verify entry points.
#
# `make ci` is the PR gate: release build, tests (including the
# golden-parity suite), a quick hot-path benchmark pass with schema
# validation of BENCH_hotpath.json + BENCH_metrics.json, the scenario
# engine checks, the result-cache smoke, the two-process shard smoke,
# the layered-store seal/compact smoke, the metrics-registry smoke, the
# chaos/fault-isolation smoke, the shared epoch-trace store smoke, the
# million-page scale smoke, the serve-daemon smoke, and a formatting
# check. Mirrors .github/workflows/ci.yml.

.PHONY: ci build test bench-smoke bench bench-check fmt-check exp-all scenario-check cache-smoke shard-smoke store-smoke metrics-smoke chaos-smoke trace-smoke scale-smoke serve-smoke

ci: build test bench-check scenario-check cache-smoke shard-smoke store-smoke metrics-smoke chaos-smoke trace-smoke scale-smoke serve-smoke fmt-check

build:
	cargo build --release

test:
	cargo test -q

# Quick benchmark pass: verifies the suite runs and reports the
# reference-vs-optimized trajectory without the full sampling budget.
bench-smoke:
	cargo bench --bench hotpath -- --smoke

# Full benchmark pass; `cxlmem bench` additionally writes BENCH_hotpath.json.
bench:
	cargo bench --bench hotpath

# Benchmark gate: quick suite run through the CLI (writes
# BENCH_hotpath.json plus a BENCH_metrics.json registry sidecar), then
# schema validation of both (cxlmem-bench-v1, cxlmem-metrics-v1).
bench-check: build
	./target/release/cxlmem bench --quick --out BENCH_hotpath.json --metrics BENCH_metrics.json
	./target/release/cxlmem bench --validate BENCH_hotpath.json
	./target/release/cxlmem stats --validate BENCH_metrics.json

fmt-check:
	cargo fmt --check

# Scenario engine gate: every bundled spec validates, a single scenario
# runs end-to-end, and a small seeded fleet expands + evaluates.
# (--no-cache: this gate measures the evaluation path, not the cache.)
scenario-check: build
	./target/release/cxlmem scenario validate examples/scenarios/*.json
	./target/release/cxlmem scenario run examples/scenarios/table1.json --no-cache --out /tmp/scenario_smoke.jsonl
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 8 --out /tmp/fleet8.jsonl
	./target/release/cxlmem scenario run /tmp/fleet8.jsonl --jobs 2 --no-cache --out /tmp/fleet8_results.jsonl

# Result-cache gate: a re-run of the same scenario must be served from
# the cache (the CLI reports `cached: true`) and emit byte-identical
# JSONL to the cold run.
cache-smoke: build
	rm -rf /tmp/cxlmem-cache-smoke
	./target/release/cxlmem scenario run examples/scenarios/table1.json --cache-dir /tmp/cxlmem-cache-smoke --out /tmp/cache_run1.jsonl
	./target/release/cxlmem scenario run examples/scenarios/table1.json --cache-dir /tmp/cxlmem-cache-smoke --out /tmp/cache_run2.jsonl 2>&1 | grep -q "cached: true"
	cmp /tmp/cache_run1.jsonl /tmp/cache_run2.jsonl
	rm -rf /tmp/cxlmem-cache-smoke

# Cross-process shard gate: a small fleet split across two concurrent
# --shard processes sharing one cache dir must (a) merge loss-free — the
# sorted union of the two shard outputs equals a single-process run —
# (b) make the coordinator re-run pure cache hits with byte-identical
# JSONL, and (c) feed `scenario report` a best-policy summary.
shard-smoke: build
	rm -rf /tmp/cxlmem-shard-smoke && mkdir -p /tmp/cxlmem-shard-smoke
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 6 --seed 5 --out /tmp/cxlmem-shard-smoke/fleet.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-shard-smoke/fleet.jsonl --shard 1/2 --jobs 2 --cache-dir /tmp/cxlmem-shard-smoke/cache --out /tmp/cxlmem-shard-smoke/s1.jsonl & pid=$$!; \
	./target/release/cxlmem scenario run /tmp/cxlmem-shard-smoke/fleet.jsonl --shard 2/2 --jobs 2 --cache-dir /tmp/cxlmem-shard-smoke/cache --out /tmp/cxlmem-shard-smoke/s2.jsonl || exit 1; \
	wait $$pid
	./target/release/cxlmem scenario run /tmp/cxlmem-shard-smoke/fleet.jsonl --no-cache --jobs 2 --out /tmp/cxlmem-shard-smoke/single.jsonl
	sort /tmp/cxlmem-shard-smoke/s1.jsonl /tmp/cxlmem-shard-smoke/s2.jsonl > /tmp/cxlmem-shard-smoke/merged_sorted.jsonl
	sort /tmp/cxlmem-shard-smoke/single.jsonl | cmp - /tmp/cxlmem-shard-smoke/merged_sorted.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-shard-smoke/fleet.jsonl --cache-dir /tmp/cxlmem-shard-smoke/cache --out /tmp/cxlmem-shard-smoke/coord.jsonl 2>&1 | grep -q "cached: true"
	cmp /tmp/cxlmem-shard-smoke/coord.jsonl /tmp/cxlmem-shard-smoke/single.jsonl
	./target/release/cxlmem scenario report /tmp/cxlmem-shard-smoke/coord.jsonl | grep -q "best policy per device profile"
	./target/release/cxlmem scenario report /tmp/cxlmem-shard-smoke/cache | grep -q "best policy per device profile"
	rm -rf /tmp/cxlmem-shard-smoke

# Layered-store gate: two concurrent seal-only (`--compact-every 0`)
# shard runs share one cache dir without ever taking the store lock on
# the write path — they must leave sealed seg-*.jsonl segments and no
# results.jsonl; `scenario report` summarizes the merged segment view
# directly; one `scenario compact` pass then folds everything into
# results.jsonl, after which the coordinator re-run is pure cache hits
# with JSONL byte-identical to an uncached run.
store-smoke: build
	rm -rf /tmp/cxlmem-store-smoke && mkdir -p /tmp/cxlmem-store-smoke
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 6 --seed 11 --out /tmp/cxlmem-store-smoke/fleet.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-store-smoke/fleet.jsonl --shard 1/2 --jobs 2 --compact-every 0 --cache-dir /tmp/cxlmem-store-smoke/cache --out /tmp/cxlmem-store-smoke/s1.jsonl & pid=$$!; \
	./target/release/cxlmem scenario run /tmp/cxlmem-store-smoke/fleet.jsonl --shard 2/2 --jobs 2 --compact-every 0 --cache-dir /tmp/cxlmem-store-smoke/cache --out /tmp/cxlmem-store-smoke/s2.jsonl || exit 1; \
	wait $$pid
	ls /tmp/cxlmem-store-smoke/cache/seg-*.jsonl > /dev/null
	test ! -f /tmp/cxlmem-store-smoke/cache/results.jsonl
	./target/release/cxlmem scenario report /tmp/cxlmem-store-smoke/cache | grep -q "best policy per device profile"
	./target/release/cxlmem scenario compact /tmp/cxlmem-store-smoke/cache | grep -q "compacted"
	! ls /tmp/cxlmem-store-smoke/cache/seg-*.jsonl 2> /dev/null
	test -f /tmp/cxlmem-store-smoke/cache/results.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-store-smoke/fleet.jsonl --cache-dir /tmp/cxlmem-store-smoke/cache --out /tmp/cxlmem-store-smoke/coord.jsonl 2>&1 | grep -q "cached: true"
	./target/release/cxlmem scenario run /tmp/cxlmem-store-smoke/fleet.jsonl --no-cache --jobs 2 --out /tmp/cxlmem-store-smoke/single.jsonl
	cmp /tmp/cxlmem-store-smoke/coord.jsonl /tmp/cxlmem-store-smoke/single.jsonl
	rm -rf /tmp/cxlmem-store-smoke

# Metrics gate: the in-process consistency check (cold/warm fleet run
# against one cache store; registry deltas must agree with the cache
# handle's own counters), then the CLI path — a fleet run writes a
# sidecar that `cxlmem stats` validates and renders, `--metrics -`
# lands the snapshot on stderr, the warm re-run's JSONL is
# byte-identical, and `scenario report --metrics` folds the sidecar
# into the fleet summary.
metrics-smoke: build
	./target/release/cxlmem metrics-smoke
	rm -rf /tmp/cxlmem-metrics-smoke && mkdir -p /tmp/cxlmem-metrics-smoke
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 4 --seed 5 --out /tmp/cxlmem-metrics-smoke/fleet.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-metrics-smoke/fleet.jsonl --jobs 2 --cache-dir /tmp/cxlmem-metrics-smoke/cache --metrics /tmp/cxlmem-metrics-smoke/m1.json --out /tmp/cxlmem-metrics-smoke/r1.jsonl
	./target/release/cxlmem stats --validate /tmp/cxlmem-metrics-smoke/m1.json
	./target/release/cxlmem stats /tmp/cxlmem-metrics-smoke/m1.json | grep -q "runtime metrics"
	./target/release/cxlmem scenario run /tmp/cxlmem-metrics-smoke/fleet.jsonl --jobs 2 --cache-dir /tmp/cxlmem-metrics-smoke/cache --metrics - --out /tmp/cxlmem-metrics-smoke/r2.jsonl 2>&1 | grep -q "cxlmem-metrics-v1"
	cmp /tmp/cxlmem-metrics-smoke/r1.jsonl /tmp/cxlmem-metrics-smoke/r2.jsonl
	./target/release/cxlmem scenario report /tmp/cxlmem-metrics-smoke/r1.jsonl --metrics /tmp/cxlmem-metrics-smoke/m1.json | grep -q "runtime metrics"
	rm -rf /tmp/cxlmem-metrics-smoke

# Chaos gate: the in-process check first — a fleet under a seeded fault
# plan must isolate the injected panic into exactly the planned
# cxlmem-result-error-v1 document, retry the transient IO faults to
# success, and (error documents are never cached) heal on a re-run to
# JSONL byte-identical to a never-faulted run. Then the CLI path: an
# --inject-faults run exits 0 with the error document embedded,
# `scenario report --expect` reconciles the coverage, and a clean
# re-run over the same cache heals byte-identically. Finally the serve
# stage: an injected admission panic in the daemon must answer exactly
# that one request with an error document while the daemon keeps
# serving, and a re-submit (the panic rule consumed) heals cleanly.
chaos-smoke: build
	./target/release/cxlmem chaos-smoke
	rm -rf /tmp/cxlmem-chaos-cli && mkdir -p /tmp/cxlmem-chaos-cli
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 6 --seed 9 --out /tmp/cxlmem-chaos-cli/fleet.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-chaos-cli/fleet.jsonl --jobs 2 --cache-dir /tmp/cxlmem-chaos-cli/cache --inject-faults "scenario.eval/fleet-002=panic:1" --out /tmp/cxlmem-chaos-cli/faulted.jsonl
	grep -q "cxlmem-result-error-v1" /tmp/cxlmem-chaos-cli/faulted.jsonl
	./target/release/cxlmem scenario report /tmp/cxlmem-chaos-cli/faulted.jsonl --expect /tmp/cxlmem-chaos-cli/fleet.jsonl | grep -q "error documents by kind"
	./target/release/cxlmem scenario run /tmp/cxlmem-chaos-cli/fleet.jsonl --jobs 2 --cache-dir /tmp/cxlmem-chaos-cli/cache --out /tmp/cxlmem-chaos-cli/healed.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-chaos-cli/fleet.jsonl --jobs 2 --no-cache --out /tmp/cxlmem-chaos-cli/clean.jsonl
	cmp /tmp/cxlmem-chaos-cli/healed.jsonl /tmp/cxlmem-chaos-cli/clean.jsonl
	rm -rf /tmp/cxlmem-chaos-cli
	rm -rf /tmp/cxlmem-chaos-serve && mkdir -p /tmp/cxlmem-chaos-serve
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 4 --seed 9 --out /tmp/cxlmem-chaos-serve/fleet.jsonl
	./target/release/cxlmem scenario serve /tmp/cxlmem-chaos-serve/cache --socket /tmp/cxlmem-chaos-serve/serve.sock --jobs 2 --inject-faults "serve.admit/fleet-002=panic:1" & pid=$$!; \
	for i in $$(seq 1 100); do test -S /tmp/cxlmem-chaos-serve/serve.sock && break; sleep 0.1; done; \
	./target/release/cxlmem scenario submit /tmp/cxlmem-chaos-serve/fleet.jsonl --socket /tmp/cxlmem-chaos-serve/serve.sock --out /tmp/cxlmem-chaos-serve/faulted.jsonl || exit 1; \
	./target/release/cxlmem scenario submit /tmp/cxlmem-chaos-serve/fleet.jsonl --socket /tmp/cxlmem-chaos-serve/serve.sock --out /tmp/cxlmem-chaos-serve/healed.jsonl || exit 1; \
	./target/release/cxlmem scenario submit --shutdown --socket /tmp/cxlmem-chaos-serve/serve.sock > /dev/null || exit 1; \
	wait $$pid
	grep -c "cxlmem-result-error-v1" /tmp/cxlmem-chaos-serve/faulted.jsonl | grep -qx 1
	! grep -q "cxlmem-result-error-v1" /tmp/cxlmem-chaos-serve/healed.jsonl
	rm -rf /tmp/cxlmem-chaos-serve

# Shared epoch-trace store gate: fig16 twice in one process must emit
# byte-identical reports from a single trace generation per app
# (counter via TraceStore::stats; the second run is pure Arc replays).
trace-smoke: build
	./target/release/cxlmem trace-smoke

# Serve-daemon gate: a fleet submitted to the long-lived daemon must
# answer byte-identically to a batch `scenario run` of the same specs —
# cold (the daemon evaluates) and warm (pure resident-store hits) —
# the `stats` verb must report live counters over the same socket, and
# `--shutdown` must drain cleanly (exit 0 via wait).
serve-smoke: build
	rm -rf /tmp/cxlmem-serve-smoke && mkdir -p /tmp/cxlmem-serve-smoke
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 6 --seed 17 --out /tmp/cxlmem-serve-smoke/fleet.jsonl
	./target/release/cxlmem scenario run /tmp/cxlmem-serve-smoke/fleet.jsonl --jobs 2 --no-cache --out /tmp/cxlmem-serve-smoke/batch.jsonl
	./target/release/cxlmem scenario serve /tmp/cxlmem-serve-smoke/cache --socket /tmp/cxlmem-serve-smoke/serve.sock --jobs 2 & pid=$$!; \
	for i in $$(seq 1 100); do test -S /tmp/cxlmem-serve-smoke/serve.sock && break; sleep 0.1; done; \
	./target/release/cxlmem scenario submit /tmp/cxlmem-serve-smoke/fleet.jsonl --socket /tmp/cxlmem-serve-smoke/serve.sock --out /tmp/cxlmem-serve-smoke/cold.jsonl || exit 1; \
	./target/release/cxlmem scenario submit /tmp/cxlmem-serve-smoke/fleet.jsonl --socket /tmp/cxlmem-serve-smoke/serve.sock --out /tmp/cxlmem-serve-smoke/warm.jsonl || exit 1; \
	./target/release/cxlmem scenario submit --stats --socket /tmp/cxlmem-serve-smoke/serve.sock | grep -q "cxlmem-serve-stats-v1" || exit 1; \
	./target/release/cxlmem scenario submit --shutdown --socket /tmp/cxlmem-serve-smoke/serve.sock > /dev/null || exit 1; \
	wait $$pid
	cmp /tmp/cxlmem-serve-smoke/cold.jsonl /tmp/cxlmem-serve-smoke/batch.jsonl
	cmp /tmp/cxlmem-serve-smoke/warm.jsonl /tmp/cxlmem-serve-smoke/batch.jsonl
	rm -rf /tmp/cxlmem-serve-smoke

# Million-page scale gate: one 1M-page fig16 cell must be bit-identical
# across chunked-vs-sequential epoch passes and delta-vs-dense trace
# replay, with peak RSS under a bound a dense per-cell materialization
# would break at production scale.
scale-smoke: build
	./target/release/cxlmem scale-smoke

# Regenerate every paper figure/table, in parallel.
exp-all: build
	./target/release/cxlmem exp all
