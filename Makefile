# cxlmem build/verify entry points.
#
# `make ci` is the PR gate: release build, tests (including the
# golden-parity suite), a smoke run of the hot-path benchmarks, and a
# formatting check. Mirrors .github/workflows/ci.yml.

.PHONY: ci build test bench-smoke bench fmt-check exp-all

ci: build test bench-smoke fmt-check

build:
	cargo build --release

test:
	cargo test -q

# Quick benchmark pass: verifies the suite runs and reports the
# reference-vs-optimized trajectory without the full sampling budget.
bench-smoke:
	cargo bench --bench hotpath -- --smoke

# Full benchmark pass; `cxlmem bench` additionally writes BENCH_hotpath.json.
bench:
	cargo bench --bench hotpath

fmt-check:
	cargo fmt --check

# Regenerate every paper figure/table, in parallel.
exp-all: build
	./target/release/cxlmem exp all
