# cxlmem build/verify entry points.
#
# `make ci` is the PR gate: release build, tests (including the
# golden-parity suite), a smoke run of the hot-path benchmarks, and a
# formatting check. Mirrors .github/workflows/ci.yml.

.PHONY: ci build test bench-smoke bench fmt-check exp-all scenario-check

ci: build test bench-smoke scenario-check fmt-check

build:
	cargo build --release

test:
	cargo test -q

# Quick benchmark pass: verifies the suite runs and reports the
# reference-vs-optimized trajectory without the full sampling budget.
bench-smoke:
	cargo bench --bench hotpath -- --smoke

# Full benchmark pass; `cxlmem bench` additionally writes BENCH_hotpath.json.
bench:
	cargo bench --bench hotpath

fmt-check:
	cargo fmt --check

# Scenario engine gate: every bundled spec validates, a single scenario
# runs end-to-end, and a small seeded fleet expands + evaluates.
scenario-check: build
	./target/release/cxlmem scenario validate examples/scenarios/*.json
	./target/release/cxlmem scenario run examples/scenarios/table1.json --out /tmp/scenario_smoke.jsonl
	./target/release/cxlmem scenario expand examples/scenarios/fleet.json --count 8 --out /tmp/fleet8.jsonl
	./target/release/cxlmem scenario run /tmp/fleet8.jsonl --jobs 2 --out /tmp/fleet8_results.jsonl

# Regenerate every paper figure/table, in parallel.
exp-all: build
	./target/release/cxlmem exp all
