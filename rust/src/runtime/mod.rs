//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust hot path. Python never runs at request time — `make artifacts`
//! produces `artifacts/*.hlo.txt` once, this module does the rest.
//!
//! Pattern (from /opt/xla-example): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `executable.execute`.

pub mod artifacts;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

pub use artifacts::{ArtifactSpec, Dtype, InputSpec, Manifest, ModelMeta};

/// A typed input value for an artifact call.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with shape/dtype validation against the manifest spec.
    /// Returns the flattened f32 outputs (loss scalars come back as
    /// single-element vectors).
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{}' expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            ));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, spec)) in args.iter().zip(&self.spec.inputs).enumerate() {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match (arg, spec.dtype) {
                (Arg::F32(v), Dtype::F32) => {
                    if v.len() != spec.elements() {
                        return Err(anyhow!(
                            "input {i} of '{}': {} elements, expected {}",
                            self.spec.name,
                            v.len(),
                            spec.elements()
                        ));
                    }
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                (Arg::I32(v), Dtype::I32) => {
                    if v.len() != spec.elements() {
                        return Err(anyhow!(
                            "input {i} of '{}': {} elements, expected {}",
                            self.spec.name,
                            v.len(),
                            spec.elements()
                        ));
                    }
                    xla::Literal::vec1(v).reshape(&dims)?
                }
                _ => {
                    return Err(anyhow!(
                        "input {i} of '{}': dtype mismatch",
                        self.spec.name
                    ))
                }
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let items = result.to_tuple()?;
        if items.len() != self.spec.outputs {
            return Err(anyhow!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.spec.name,
                items.len(),
                self.spec.outputs
            ));
        }
        items
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

/// PJRT client + compiled executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Load the manifest from `dir` and create the CPU PJRT client.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            manifest,
            client,
            cache: HashMap::new(),
        })
    }

    /// Discover artifacts via $CXLMEM_ARTIFACTS / ./artifacts.
    pub fn discover() -> Result<Self> {
        let dir = std::env::var("CXLMEM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(Path::new(&dir))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.artifact(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("loading HLO text {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(spec.name.clone(), Executable { spec, exe });
        }
        Ok(&self.cache[name])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn adam_artifact_matches_scalar_reference() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(Path::new("artifacts")).unwrap();
        let exe = rt.load("adam").unwrap();
        let n = exe.spec.inputs[0].elements();
        let p = vec![1.0f32; n];
        let g = vec![0.5f32; n];
        let m = vec![0.0f32; n];
        let v = vec![0.0f32; n];
        let step = [1.0f32];
        let out = exe
            .run(&[
                Arg::F32(&p),
                Arg::F32(&g),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::F32(&step),
            ])
            .unwrap();
        assert_eq!(out.len(), 3);
        // Scalar ADAM at step 1: m̂ = g, v̂ = g², p' = p - lr·g/(|g|+eps)
        let expect_p = 1.0 - 1e-3 * 0.5 / (0.5 + 1e-8);
        assert!((out[0][0] - expect_p).abs() < 1e-5, "{}", out[0][0]);
        let expect_m = 0.1 * 0.5;
        assert!((out[1][0] - expect_m).abs() < 1e-6);
    }

    #[test]
    fn run_rejects_wrong_arity_and_shape() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(Path::new("artifacts")).unwrap();
        let exe = rt.load("adam").unwrap();
        assert!(exe.run(&[]).is_err());
        let tiny = [0.0f32; 3];
        let step = [1.0f32];
        assert!(exe
            .run(&[
                Arg::F32(&tiny),
                Arg::F32(&tiny),
                Arg::F32(&tiny),
                Arg::F32(&tiny),
                Arg::F32(&step),
            ])
            .is_err());
    }

    #[test]
    fn decode_attn_artifact_uniform_values() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(Path::new("artifacts")).unwrap();
        let exe = rt.load("decode_attn").unwrap();
        let q_n = exe.spec.inputs[0].elements();
        let kv_n = exe.spec.inputs[1].elements();
        // V = all ones → attention output must be exactly 1 everywhere.
        let q = vec![0.3f32; q_n];
        let k = vec![0.1f32; kv_n];
        let v = vec![1.0f32; kv_n];
        let out = exe
            .run(&[Arg::F32(&q), Arg::F32(&k), Arg::F32(&v)])
            .unwrap();
        assert_eq!(out.len(), 1);
        for &x in out[0].iter().take(16) {
            assert!((x - 1.0).abs() < 1e-5, "{x}");
        }
    }
}
