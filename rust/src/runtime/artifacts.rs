//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (shapes, dtypes, model hyperparameters).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Input dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One input spec of an artifact.
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
    pub outputs: usize,
}

/// Model hyperparameters baked into `train_step`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub params: usize,
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        Self::parse(dir, &text)
    }

    /// Locate the artifacts directory: $CXLMEM_ARTIFACTS or ./artifacts.
    pub fn discover() -> Result<Self> {
        let dir = std::env::var("CXLMEM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let m = j.get("model").ok_or_else(|| anyhow!("manifest: no model"))?;
        let get = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("manifest: model.{k} missing"))
        };
        let model = ModelMeta {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            layers: get("layers")?,
            heads: get("heads")?,
            seq: get("seq")?,
            batch: get("batch")?,
            params: get("params")?,
        };
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest: no artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact name"))?
                .to_string();
            let file = dir.join(
                a.get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("artifact file"))?,
            );
            let mut inputs = Vec::new();
            for i in a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("artifact inputs"))?
            {
                let shape: Vec<usize> = i
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("input shape"))?
                    .iter()
                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                    .collect();
                let dtype = match i.get("dtype").and_then(|v| v.as_str()) {
                    Some("f32") => Dtype::F32,
                    Some("i32") => Dtype::I32,
                    other => return Err(anyhow!("unsupported dtype {other:?}")),
                };
                inputs.push(InputSpec { shape, dtype });
            }
            let outputs = a.get("outputs").and_then(|v| v.as_u64()).unwrap_or(1) as usize;
            artifacts.push(ArtifactSpec {
                name,
                file,
                inputs,
                outputs,
            });
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            model,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "model": {"vocab": 4096, "d_model": 256, "layers": 4, "heads": 8,
                  "seq": 128, "batch": 4, "params": 4196608},
        "artifacts": [
            {"name": "adam", "file": "adam.hlo.txt", "outputs": 3,
             "inputs": [{"shape": [1048576], "dtype": "f32"},
                        {"shape": [1048576], "dtype": "f32"},
                        {"shape": [1048576], "dtype": "f32"},
                        {"shape": [1048576], "dtype": "f32"},
                        {"shape": [1], "dtype": "f32"}]}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.model.params, 4196608);
        let a = m.artifact("adam").unwrap();
        assert_eq!(a.inputs.len(), 5);
        assert_eq!(a.inputs[0].elements(), 1048576);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.outputs, 3);
        assert!(a.file.ends_with("adam.hlo.txt"));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f8\"");
        assert!(Manifest::parse(Path::new("/tmp/a"), &bad).is_err());
    }
}
