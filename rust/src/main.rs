//! cxlmem CLI — leader entrypoint.
//!
//! ```text
//! cxlmem exp <id|all> [--csv|--json] [--out FILE]   regenerate a paper figure/table
//! cxlmem train [--steps N] [--seed S]               E2E training through the PJRT artifact
//! cxlmem serve [--requests N]                       FlexGen-style serving demo
//! cxlmem info                                       platform + artifact status
//! ```

use anyhow::Result;

use cxlmem::report::Format;
use cxlmem::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "train" => cxlmem::exp::drivers::train(&args),
        "serve" => cxlmem::exp::drivers::serve(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let fmt = if args.flag("json") {
        Format::Json
    } else if args.flag("csv") {
        Format::Csv
    } else {
        Format::Text
    };
    let ids: Vec<&str> = if id == "all" {
        cxlmem::exp::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let report = cxlmem::exp::run(id)?;
        if let Some(path) = args.get("out") {
            report.save(std::path::Path::new(path), fmt)?;
            println!("wrote {path}");
        } else {
            report.print(fmt);
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    match cxlmem::runtime::Runtime::discover() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!(
                "artifacts: {} in {} (model: {} params, vocab {}, d_model {}, {} layers)",
                rt.manifest.artifacts.len(),
                rt.manifest.dir.display(),
                rt.manifest.model.params,
                rt.manifest.model.vocab,
                rt.manifest.model.d_model,
                rt.manifest.model.layers,
            );
        }
        Err(e) => println!("artifacts not built ({e}); run `make artifacts`"),
    }
    println!("systems: A, B, C (see `cxlmem exp table1`)");
    Ok(())
}

fn print_help() {
    println!(
        "cxlmem — 'Exploring and Evaluating Real-world CXL' reproduction\n\
         \n\
         USAGE:\n\
         \x20 cxlmem exp <id|all> [--csv|--json] [--out FILE]\n\
         \x20 cxlmem train [--steps N] [--seed S] [--log-every K]\n\
         \x20 cxlmem serve [--requests N]\n\
         \x20 cxlmem info\n\
         \n\
         experiment ids: {}",
        cxlmem::exp::ALL.join(", ")
    );
}
