//! cxlmem CLI — leader entrypoint.
//!
//! ```text
//! cxlmem exp <id|all> [--csv|--json] [--out FILE] [--jobs N]  regenerate a paper figure/table
//! cxlmem bench [--smoke] [--jobs N] [--out FILE]              hot-path benchmarks → BENCH_hotpath.json
//! cxlmem train [--steps N] [--seed S]                         E2E training through the PJRT artifact
//! cxlmem serve [--requests N]                                 FlexGen-style serving demo
//! cxlmem info                                                 platform + artifact status
//! ```

use anyhow::Result;

use cxlmem::report::Format;
use cxlmem::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "bench" => cmd_bench(&args),
        "train" => cxlmem::exp::drivers::train(&args),
        "serve" => cxlmem::exp::drivers::serve(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let fmt = if args.flag("json") {
        Format::Json
    } else if args.flag("csv") {
        Format::Csv
    } else {
        Format::Text
    };
    // `exp all` fans the 19 experiments out over --jobs threads (default:
    // all cores); a single experiment instead uses --jobs for its inner
    // sweeps (default: 1, fully deterministic timing either way — the
    // tables are identical to a sequential run).
    if id == "all" {
        let jobs = args.get_usize("jobs", cxlmem::perf::default_jobs());
        let reports = cxlmem::exp::run_all(cxlmem::exp::ALL, jobs)?;
        if let Some(path) = args.get("out") {
            let body: Vec<String> = reports.iter().map(|(_, r)| r.render(fmt)).collect();
            // Text/CSV concatenate; JSON documents must be wrapped in an
            // array to stay parseable as one file.
            let doc = if fmt == Format::Json {
                format!("[{}]", body.join(","))
            } else {
                body.join("\n")
            };
            std::fs::write(path, doc)?;
            println!("wrote {path}");
        } else {
            for (_, report) in &reports {
                report.print(fmt);
            }
        }
        return Ok(());
    }
    let jobs = args.get_usize("jobs", 1);
    cxlmem::perf::set_jobs(jobs);
    let report = cxlmem::exp::run(id)?;
    if let Some(path) = args.get("out") {
        report.save(std::path::Path::new(path), fmt)?;
        println!("wrote {path}");
    } else {
        report.print(fmt);
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let opts = cxlmem::bench::BenchOpts {
        smoke: args.flag("smoke"),
        jobs: args.get_usize("jobs", cxlmem::perf::default_jobs()),
    };
    let report = cxlmem::bench::run_suite(&opts);
    print!("{}", report.summary());
    let out = args.get_or("out", "BENCH_hotpath.json");
    report.save(std::path::Path::new(out))?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    match cxlmem::runtime::Runtime::discover() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!(
                "artifacts: {} in {} (model: {} params, vocab {}, d_model {}, {} layers)",
                rt.manifest.artifacts.len(),
                rt.manifest.dir.display(),
                rt.manifest.model.params,
                rt.manifest.model.vocab,
                rt.manifest.model.d_model,
                rt.manifest.model.layers,
            );
        }
        Err(e) => println!("artifacts not built ({e}); run `make artifacts`"),
    }
    println!("systems: A, B, C (see `cxlmem exp table1`)");
    Ok(())
}

fn print_help() {
    println!(
        "cxlmem — 'Exploring and Evaluating Real-world CXL' reproduction\n\
         \n\
         USAGE:\n\
         \x20 cxlmem exp <id|all> [--csv|--json] [--out FILE] [--jobs N]\n\
         \x20 cxlmem bench [--smoke] [--jobs N] [--out FILE]\n\
         \x20 cxlmem train [--steps N] [--seed S] [--log-every K]\n\
         \x20 cxlmem serve [--requests N]\n\
         \x20 cxlmem info\n\
         \n\
         experiment ids: {}",
        cxlmem::exp::ALL.join(", ")
    );
}
