//! cxlmem CLI — leader entrypoint.
//!
//! ```text
//! cxlmem exp <id|all> [--csv|--json] [--out FILE] [--jobs N]  regenerate a paper figure/table
//! cxlmem scenario validate <files…>                           parse + validate scenario specs
//! cxlmem scenario expand <file> [--seed S] [--count N]        expand sweeps/fleets to spec JSONL
//! cxlmem scenario run <files…|-> [--jobs N] [--out FILE]      batch-evaluate → result JSONL
//!                    [--shard K/N] [--no-cache] [--cache-dir DIR]  (result cache on by default)
//!                    [--compact-every N]                      (store compaction cadence; 0 = seal only)
//!                    [--fail-fast] [--retries N] [--deadline-secs S] [--inject-faults PLAN]
//! cxlmem scenario bench [--count N] [--jobs N] [--cache]      fleet throughput probe
//! cxlmem scenario report <results.jsonl|cache dir>            fleet summaries from result JSONL
//!                    [--metrics FILE]                         (fold in metrics sidecars)
//!                    [--expect FILE] [--shards N]             (reconcile shard coverage)
//! cxlmem scenario compact <cache dir>                         fold sealed segments into results.jsonl
//! cxlmem scenario serve <cache dir> [--socket PATH]           long-lived eval daemon on a Unix socket
//!                    [--jobs N] [--queue N] [--compact-every N]  (JSONL requests; warm caches resident)
//!                    [--retries N] [--deadline-secs S]
//! cxlmem scenario submit <files…|-> --socket PATH             send specs to a running daemon
//!                    [--stats] [--shutdown] [--out FILE]      (or query/stop it)
//! cxlmem bench [--smoke|--quick] [--jobs N] [--out FILE]      hot-path benchmarks → BENCH_hotpath.json
//! cxlmem bench --validate FILE                                schema-check a BENCH_hotpath.json
//! cxlmem stats [FILE|-] [--json]                              render a cxlmem-metrics-v1 snapshot
//! cxlmem stats --validate FILE                                schema-check a metrics sidecar
//! cxlmem metrics-smoke [--count N] [--jobs N]                 metrics/cache consistency gate (make metrics-smoke)
//! cxlmem chaos-smoke [--count N] [--jobs N]                   fault-isolation gate (make chaos-smoke)
//! cxlmem trace-smoke                                          shared epoch-trace store gate (make trace-smoke)
//! cxlmem scale-smoke [--pages N] [--epochs N] [--jobs N]      million-page parity + peak-RSS gate (make scale-smoke)
//!                    [--rss-mb MB]
//! cxlmem train [--steps N] [--seed S]                         E2E training through the PJRT artifact
//! cxlmem serve [--requests N]                                 FlexGen-style serving demo
//! cxlmem info                                                 platform + artifact status
//! ```
//!
//! `exp`, `scenario run|bench`, `bench` and the smokes all accept
//! `--metrics FILE` (`-` for stderr) to write a `cxlmem-metrics-v1`
//! registry snapshot when the command finishes — see README "Metrics".

use anyhow::Result;

use cxlmem::report::Format;
use cxlmem::util::cli::Args;
use cxlmem::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "scenario" => cmd_scenario(&args),
        "bench" => cmd_bench(&args),
        "stats" => cmd_stats(&args),
        "metrics-smoke" => cmd_metrics_smoke(&args),
        "chaos-smoke" => cmd_chaos_smoke(&args),
        "trace-smoke" => cmd_trace_smoke(&args),
        "scale-smoke" => cmd_scale_smoke(&args),
        "train" => cxlmem::exp::drivers::train(&args),
        "serve" => cxlmem::exp::drivers::serve(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

/// `--metrics FILE` handling shared by every long-running verb: resolve
/// the requested sidecar destination up front (so a malformed flag
/// fails before the run, not after), then write a registry snapshot
/// when the command finishes. `-` sends the snapshot to stderr so it
/// never mixes with JSONL on stdout.
fn metrics_out(args: &Args) -> Result<Option<String>> {
    // A bare `--metrics` (FILE forgotten, or eaten by a following flag)
    // must error, not silently drop the sidecar.
    if args.flag("metrics") {
        anyhow::bail!("--metrics requires a FILE argument ('-' for stderr)");
    }
    Ok(args.get("metrics").map(String::from))
}

fn emit_metrics(dest: Option<&String>) -> Result<()> {
    let Some(path) = dest else { return Ok(()) };
    let snap = cxlmem::util::metrics::snapshot();
    if path == "-" {
        eprintln!("{snap}");
    } else {
        std::fs::write(path, format!("{snap}\n"))?;
        eprintln!("wrote metrics sidecar {path}");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let metrics = metrics_out(args)?;
    let id = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let fmt = if args.flag("json") {
        Format::Json
    } else if args.flag("csv") {
        Format::Csv
    } else {
        Format::Text
    };
    // `exp all` fans the 19 experiments out over --jobs threads (default:
    // all cores); a single experiment instead uses --jobs for its inner
    // sweeps (default: 1, fully deterministic timing either way — the
    // tables are identical to a sequential run).
    if id == "all" {
        let jobs = args.get_usize("jobs", cxlmem::perf::default_jobs());
        let reports = cxlmem::exp::run_all(cxlmem::exp::ALL, jobs)?;
        if let Some(path) = args.get("out") {
            // Text/CSV concatenate; JSON documents are wrapped in a
            // `Json::Arr` so the file serializes through the same
            // util::json writer as every other emitter.
            let doc = if fmt == Format::Json {
                Json::Arr(reports.iter().map(|(_, r)| r.to_json()).collect()).to_string()
            } else {
                let body: Vec<String> = reports.iter().map(|(_, r)| r.render(fmt)).collect();
                body.join("\n")
            };
            std::fs::write(path, doc)?;
            println!("wrote {path}");
        } else {
            for (_, report) in &reports {
                report.print(fmt);
            }
        }
        return emit_metrics(metrics.as_ref());
    }
    let jobs = args.get_usize("jobs", 1);
    cxlmem::perf::set_jobs(jobs);
    let report = cxlmem::exp::run(id)?;
    if let Some(path) = args.get("out") {
        report.save(std::path::Path::new(path), fmt)?;
        println!("wrote {path}");
    } else {
        report.print(fmt);
    }
    emit_metrics(metrics.as_ref())
}

fn cmd_scenario(args: &Args) -> Result<()> {
    use anyhow::{anyhow, bail, Context};
    use cxlmem::scenario;
    use cxlmem::util::json::to_jsonl;

    let verb = args.positional.get(1).map(|s| s.as_str()).unwrap_or("help");
    let files = &args.positional[args.positional.len().min(2)..];
    match verb {
        "validate" => {
            if files.is_empty() {
                bail!("usage: cxlmem scenario validate <files...>");
            }
            for file in files {
                let text = std::fs::read_to_string(file)
                    .with_context(|| format!("reading {file}"))?;
                let docs = scenario::docs_of(&text).map_err(|e| anyhow!("{file}: {e}"))?;
                for doc in &docs {
                    if scenario::is_template(doc) {
                        // Templates validate through a sample expansion
                        // (fleet size capped; sweeps expand fully).
                        let count = doc.get("fleet").map(|_| 4);
                        let n = scenario::expand(doc, None, count)
                            .map_err(|e| anyhow!("{file}: {e}"))?
                            .len();
                        println!("{file}: ok — template (validated {n}-scenario expansion)");
                    } else {
                        let spec = scenario::ScenarioSpec::parse(doc)
                            .map_err(|e| anyhow!("{file}: {e}"))?;
                        println!(
                            "{file}: ok — '{}' ({}, {} system{})",
                            spec.name,
                            spec.kind_label(),
                            spec.systems.len(),
                            if spec.systems.len() == 1 { "" } else { "s" }
                        );
                    }
                }
            }
            Ok(())
        }
        "expand" => {
            let file = files
                .first()
                .ok_or_else(|| anyhow!("usage: cxlmem scenario expand <file> [--seed S] [--count N] [--out FILE]"))?;
            let text = std::fs::read_to_string(file)
                .with_context(|| format!("reading {file}"))?;
            let doc = Json::parse(&text).map_err(|e| anyhow!("{file}: {e}"))?;
            // Malformed override values must error, not silently fall
            // back to the template's embedded seed/count.
            let seed = args
                .get("seed")
                .map(|s| s.parse().map_err(|_| anyhow!("--seed '{s}' is not an integer")))
                .transpose()?;
            let count = args
                .get("count")
                .map(|s| s.parse().map_err(|_| anyhow!("--count '{s}' is not an integer")))
                .transpose()?;
            let expanded = scenario::expand(&doc, seed, count)?;
            let out = to_jsonl(expanded);
            write_or_print(args, &out)
        }
        "run" => {
            if files.is_empty() {
                bail!(
                    "usage: cxlmem scenario run <files...|-> [--jobs N] [--out FILE] \
                     [--shard K/N] [--no-cache] [--cache-dir DIR] [--metrics FILE] \
                     [--fail-fast] [--retries N] [--deadline-secs S] [--inject-faults PLAN]"
                );
            }
            let metrics = metrics_out(args)?;
            let opts = supervise_opts(args)?;
            install_faults(args)?;
            let mut specs = Vec::new();
            for file in files {
                let text = if file == "-" {
                    let mut buf = String::new();
                    std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
                    buf
                } else {
                    std::fs::read_to_string(file).with_context(|| format!("reading {file}"))?
                };
                specs.extend(scenario::parse_docs(&text).map_err(|e| anyhow!("{file}: {e}"))?);
            }
            let specs = apply_shard(args, specs)?;
            let jobs = args.get_usize("jobs", cxlmem::perf::default_jobs());
            let mut cache = open_scenario_cache(args, true)?;
            let results = scenario::run_batch_supervised(&specs, jobs, cache.as_mut(), &opts)?;
            let errors = results
                .iter()
                .filter(|r| scenario::supervise::is_error_doc(&r.doc))
                .count();
            match &cache {
                Some(c) => eprintln!(
                    "ran {} scenario(s) on {jobs} job(s) (cache: {} hit(s), {} miss(es), \
                     cached: {})",
                    results.len(),
                    c.hits(),
                    c.misses(),
                    c.misses() == 0 && c.hits() > 0
                ),
                None => eprintln!("ran {} scenario(s) on {jobs} job(s)", results.len()),
            }
            if errors > 0 {
                eprintln!(
                    "{errors} scenario(s) failed — {} document(s) embedded in the output \
                     JSONL (see `scenario report`)",
                    scenario::ERROR_SCHEMA
                );
            }
            let out = to_jsonl(results.into_iter().map(|r| r.doc));
            write_or_print(args, &out)?;
            emit_metrics(metrics.as_ref())
        }
        "bench" => {
            // Throughput probe: expand a default fleet and time the batch.
            let metrics = metrics_out(args)?;
            let count = args.get_usize("count", 64);
            let seed = args.get_u64("seed", 42);
            let jobs = args.get_usize("jobs", cxlmem::perf::default_jobs());
            let doc = cxlmem::util::json::Json::parse(&format!(
                r#"{{"name": "bench-fleet", "fleet": {{"count": {count}, "seed": {seed}}}}}"#
            ))
            .map_err(|e| anyhow!("internal fleet template: {e}"))?;
            let expanded = scenario::expand(&doc, None, None)?;
            let specs: Vec<_> = expanded
                .iter()
                .map(scenario::ScenarioSpec::parse)
                .collect::<Result<_>>()?;
            let specs = apply_shard(args, specs)?;
            // The probe is uncached by default — it measures evaluation
            // throughput; pass --cache/--cache-dir to measure warm-cache
            // serving instead.
            let mut cache = open_scenario_cache(args, false)?;
            let t0 = std::time::Instant::now();
            let results = scenario::run_batch_cached(&specs, jobs, cache.as_mut())?;
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "scenario bench: {} scenarios, jobs={jobs}, {wall:.2} s wall, {:.1} scenarios/s",
                results.len(),
                results.len() as f64 / wall.max(1e-9)
            );
            if let Some(c) = &cache {
                println!("cache: {} hit(s), {} miss(es)", c.hits(), c.misses());
            }
            if args.get("out").is_some() {
                let out = to_jsonl(results.into_iter().map(|r| r.doc));
                write_or_print(args, &out)?;
            }
            emit_metrics(metrics.as_ref())
        }
        "report" => {
            let file = files.first().ok_or_else(|| {
                anyhow!(
                    "usage: cxlmem scenario report <results.jsonl|cache dir|-> \
                     [--csv|--json] [--out FILE] [--metrics FILE] [--expect FILE] [--shards N]"
                )
            })?;
            let mut text = if file == "-" {
                let mut buf = String::new();
                std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
                buf
            } else {
                // A cache directory is accepted directly: summarize the
                // merged view of its layered store (base file plus any
                // sealed segments not yet compacted), so seal-only
                // shards report completely without a compaction pass.
                let path = std::path::PathBuf::from(file);
                if path.is_dir() {
                    cxlmem::scenario::cache::merged_store_text(&path)
                        .map_err(|e| anyhow!("{file}: {e}"))?
                } else {
                    std::fs::read_to_string(&path)
                        .with_context(|| format!("reading {}", path.display()))?
                }
            };
            // `--metrics FILE` folds a run's metrics sidecar into the
            // summary: collect_docs routes lines by schema, so the
            // sidecar text simply concatenates onto the result JSONL.
            if args.flag("metrics") {
                bail!("--metrics requires a FILE argument (a metrics sidecar)");
            }
            if let Some(side) = args.get("metrics") {
                let extra = std::fs::read_to_string(side)
                    .with_context(|| format!("reading metrics sidecar {side}"))?;
                if !text.ends_with('\n') && !text.is_empty() {
                    text.push('\n');
                }
                text.push_str(&extra);
            }
            // `--expect FILE [--shards N]` reconciles expected-vs-present
            // coverage: the expanded spec list (or its template) names
            // what every index-modulo shard owed; the report classifies
            // each name as present, errored, or missing.
            if args.flag("expect") {
                bail!("--expect requires a FILE argument (expanded spec JSONL or a template)");
            }
            let expected = match args.get("expect") {
                Some(f) => {
                    if args.flag("shards") {
                        bail!("--shards requires an N argument (how many --shard K/N processes)");
                    }
                    let etext = std::fs::read_to_string(f)
                        .with_context(|| format!("reading expected specs {f}"))?;
                    let shards = args.get_usize("shards", 1);
                    Some(
                        scenario::report::expectation_from_text(&etext, shards)
                            .map_err(|e| anyhow!("{f}: {e}"))?,
                    )
                }
                None if args.get("shards").is_some() || args.flag("shards") => {
                    bail!("--shards only makes sense together with --expect FILE")
                }
                None => None,
            };
            let report = scenario::report::summarize_text_with(&text, expected.as_ref())
                .map_err(|e| anyhow!("{file}: {e}"))?;
            let fmt = if args.flag("json") {
                Format::Json
            } else if args.flag("csv") {
                Format::Csv
            } else {
                Format::Text
            };
            if let Some(path) = args.get("out") {
                report.save(std::path::Path::new(path), fmt)?;
                println!("wrote {path}");
            } else {
                report.print(fmt);
            }
            Ok(())
        }
        "compact" => {
            // Fold every sealed `seg-*.jsonl` segment into the durable
            // store file. Routine maintenance for seal-only shards
            // (`--compact-every 0`): N processes seal concurrently
            // without ever contending on the store lock, then one
            // `compact` pass consolidates the directory.
            let file = files.first().ok_or_else(|| {
                anyhow!("usage: cxlmem scenario compact <cache dir> [--metrics FILE]")
            })?;
            let metrics = metrics_out(args)?;
            let dir = std::path::Path::new(file);
            if !dir.is_dir() {
                bail!("{file}: not a cache directory");
            }
            let mut cache = scenario::ResultCache::open(dir)?;
            let stats = cache.compact().map_err(|e| anyhow!("{file}: {e}"))?;
            println!(
                "compacted {file}: {} segment(s) folded, {} key(s) in {}{}",
                stats.segments,
                stats.keys,
                cxlmem::scenario::cache::STORE_FILE,
                if stats.rewrote { "" } else { " (store already consolidated)" }
            );
            emit_metrics(metrics.as_ref())
        }
        "serve" => {
            // The long-lived daemon: open the cache once, keep the trace
            // store resident, answer spec JSONL over a Unix socket. See
            // scenario::serve for the architecture.
            let file = files.first().ok_or_else(|| {
                anyhow!(
                    "usage: cxlmem scenario serve <cache dir> [--socket PATH] [--jobs N] \
                     [--queue N] [--compact-every N] [--retries N] [--deadline-secs S] \
                     [--metrics FILE] [--inject-faults PLAN]"
                )
            })?;
            let metrics = metrics_out(args)?;
            install_faults(args)?;
            let dir = std::path::PathBuf::from(file);
            let mut cache = scenario::ResultCache::open(&dir)?;
            if args.flag("compact-every") {
                bail!("--compact-every requires an N argument (0 = seal only, 1 = every flush)");
            }
            if let Some(n) = args.get("compact-every") {
                let n: u64 = n
                    .parse()
                    .map_err(|_| anyhow!("--compact-every wants an integer, got '{n}'"))?;
                cache.set_compact_every(n);
            }
            if args.flag("socket") {
                bail!("--socket requires a PATH argument");
            }
            if args.flag("queue") {
                bail!("--queue requires an N argument (admission bound)");
            }
            let socket = args
                .get("socket")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|| dir.join("serve.sock"));
            let mut opts = scenario::serve::ServeOpts::new(socket);
            opts.workers = args.get_usize("jobs", cxlmem::perf::default_jobs());
            opts.queue_cap = args.get_usize("queue", scenario::serve::DEFAULT_QUEUE_CAP);
            opts.supervise = supervise_opts(args)?;
            eprintln!(
                "serving {} on {} ({} worker(s), queue {})",
                dir.display(),
                opts.socket.display(),
                opts.workers,
                opts.queue_cap
            );
            scenario::serve::run_serve(cache, &opts)?;
            eprintln!("serve: drained and stopped");
            emit_metrics(metrics.as_ref())
        }
        "submit" => {
            // The line client: one connection, one response line per
            // request line, in request order. `--stats`/`--shutdown`
            // send the corresponding verb instead of spec documents.
            if args.flag("socket") {
                bail!("--socket requires a PATH argument");
            }
            let Some(socket) = args.get("socket") else {
                bail!(
                    "usage: cxlmem scenario submit <files...|-> --socket PATH \
                     [--out FILE] [--stats] [--shutdown]"
                );
            };
            let socket = std::path::PathBuf::from(socket);
            let verb_line = if args.flag("stats") {
                Some(r#"{"verb": "stats"}"#.to_string())
            } else if args.flag("shutdown") {
                Some(r#"{"verb": "shutdown"}"#.to_string())
            } else {
                None
            };
            let lines = match verb_line {
                Some(line) => vec![line],
                None => {
                    if files.is_empty() {
                        bail!(
                            "usage: cxlmem scenario submit <files...|-> --socket PATH \
                             [--out FILE] [--stats] [--shutdown]"
                        );
                    }
                    let mut lines = Vec::new();
                    for file in files {
                        let text = if file == "-" {
                            let mut buf = String::new();
                            std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
                            buf
                        } else {
                            std::fs::read_to_string(file)
                                .with_context(|| format!("reading {file}"))?
                        };
                        for doc in
                            scenario::docs_of(&text).map_err(|e| anyhow!("{file}: {e}"))?
                        {
                            lines.push(doc.to_string());
                        }
                    }
                    lines
                }
            };
            let responses = scenario::serve::request_lines(&socket, &lines)?;
            let mut out = responses.join("\n");
            if !out.is_empty() {
                out.push('\n');
            }
            write_or_print(args, &out)
        }
        _ => {
            println!(
                "cxlmem scenario — declarative scenario engine\n\
                 \n\
                 USAGE:\n\
                 \x20 cxlmem scenario validate <files...>\n\
                 \x20 cxlmem scenario expand <file> [--seed S] [--count N] [--out FILE]\n\
                 \x20 cxlmem scenario run <files...|-> [--jobs N] [--out FILE]\n\
                 \x20\x20\x20\x20 [--shard K/N] [--no-cache] [--cache-dir DIR] [--compact-every N]\n\
                 \x20\x20\x20\x20 [--metrics FILE] [--fail-fast] [--retries N] [--deadline-secs S]\n\
                 \x20\x20\x20\x20 [--inject-faults PLAN]\n\
                 \x20 cxlmem scenario bench [--count N] [--seed S] [--jobs N] [--out FILE] [--cache]\n\
                 \x20\x20\x20\x20 [--shard K/N] [--metrics FILE]\n\
                 \x20 cxlmem scenario report <results.jsonl|cache dir|-> [--csv|--json] [--out FILE]\n\
                 \x20\x20\x20\x20 [--metrics FILE] [--expect FILE] [--shards N]\n\
                 \x20 cxlmem scenario compact <cache dir> [--metrics FILE]\n\
                 \x20 cxlmem scenario serve <cache dir> [--socket PATH] [--jobs N] [--queue N]\n\
                 \x20\x20\x20\x20 [--compact-every N] [--retries N] [--deadline-secs S]\n\
                 \x20\x20\x20\x20 [--metrics FILE] [--inject-faults PLAN]\n\
                 \x20 cxlmem scenario submit <files...|-> --socket PATH [--out FILE]\n\
                 \x20\x20\x20\x20 [--stats] [--shutdown]\n\
                 \n\
                 `run` serves repeated specs from the content-addressed result cache\n\
                 (default {}; key = canonical spec hash — see README 'Result cache').\n\
                 `bench` measures evaluation throughput and is uncached unless asked.\n\
                 `--shard K/N` runs the K-th of N index-modulo slices of the expanded\n\
                 list: point N processes at one --cache-dir and they rendezvous in the\n\
                 shared store; re-running the full list is then pure cache hits.\n\
                 `--compact-every N` tunes the layered store's compaction cadence:\n\
                 1 (default) folds sealed segments into results.jsonl after every\n\
                 flush, 0 seals only (run `scenario compact` later), and N>1 folds in\n\
                 the background every Nth flush. `compact` consolidates a seal-only\n\
                 directory in one pass.\n\
                 `run` is supervised by default: a panicking or erroring spec becomes a\n\
                 cxlmem-result-error-v1 document in the output instead of aborting the\n\
                 fleet, transient IO failures retry (--retries, default 2) with seeded\n\
                 jittered backoff, --deadline-secs marks overruns timed out, and\n\
                 --fail-fast restores the historical first-failure abort. Error\n\
                 documents are never cached: re-running retries exactly the failed\n\
                 slots. --inject-faults arms the deterministic chaos layer (see README\n\
                 'Fault tolerance & chaos testing'; env spelling CXLMEM_FAULTS).\n\
                 `report` aggregates result JSONL (or a cache dir) into fleet summaries:\n\
                 best policy per device profile, win matrix, quantiles, OLI gains, and\n\
                 error documents by kind and shard; `--expect FILE [--shards N]`\n\
                 reconciles expected-vs-present coverage per shard.\n\
                 `serve` keeps a fleet evaluator resident: specs go in as JSONL over a\n\
                 Unix domain socket (default <cache dir>/serve.sock) and come back as\n\
                 the same result/error documents `run` emits, byte-identical, with warm\n\
                 caches and the trace store amortized across requests. A bounded\n\
                 admission queue (--queue, default 256) answers overload with queue-full\n\
                 error documents; a {{\"verb\": \"stats\"}} line returns live counters and\n\
                 {{\"verb\": \"shutdown\"}} drains and stops. `submit` is the line client.\n\
                 `run`/`bench` accept `--metrics FILE` ('-' for stderr) to capture a\n\
                 cxlmem-metrics-v1 registry snapshot; `report --metrics FILE` folds\n\
                 sidecars into the summary (hit rates, queue depth, eval quantiles).\n\
                 \n\
                 Bundled scenarios: examples/scenarios/*.json (one per experiment id,\n\
                 plus fleet.json). See README 'Scenario files' for the schema.",
                cxlmem::scenario::cache::DEFAULT_DIR
            );
            Ok(())
        }
    }
}

/// `--shard K/N` handling shared by `scenario run` and `scenario bench`:
/// keep only this process's index-modulo slice of the expanded spec
/// list (see `scenario::shard` for the pinned scheme), reporting the
/// split on stderr so fleet drivers can log it.
fn apply_shard(
    args: &Args,
    specs: Vec<cxlmem::scenario::ScenarioSpec>,
) -> Result<Vec<cxlmem::scenario::ScenarioSpec>> {
    // A bare `--shard` (K/N forgotten, or eaten by a following flag)
    // must error, not silently run the whole fleet on every process.
    if args.flag("shard") {
        anyhow::bail!("--shard requires a K/N argument (e.g. --shard 1/2)");
    }
    let Some(spec) = args.get("shard") else {
        return Ok(specs);
    };
    let shard = cxlmem::scenario::Shard::parse(spec)?;
    let total = specs.len();
    let kept = shard.filter(specs);
    eprintln!("shard {shard}: {} of {total} scenario(s)", kept.len());
    Ok(kept)
}

/// `--fail-fast` / `--retries N` / `--deadline-secs S` handling for
/// `scenario run`: build the batch supervision policy (see
/// `scenario::supervise`). The `--shard K/N` label, when present, is
/// echoed into error documents so `scenario report` can count errors
/// per shard.
fn supervise_opts(args: &Args) -> Result<cxlmem::scenario::SuperviseOpts> {
    use anyhow::{anyhow, bail};
    let mut opts = if args.flag("fail-fast") {
        cxlmem::scenario::SuperviseOpts::fail_fast()
    } else {
        cxlmem::scenario::SuperviseOpts::default()
    };
    // Bare `--retries` / `--deadline-secs` (value forgotten, or eaten
    // by a following flag) must error, not silently keep the defaults.
    if args.flag("retries") {
        bail!("--retries requires a COUNT argument");
    }
    if let Some(r) = args.get("retries") {
        opts.retries = r.parse().map_err(|_| anyhow!("--retries '{r}' is not an integer"))?;
    }
    if args.flag("deadline-secs") {
        bail!("--deadline-secs requires a SECONDS argument");
    }
    if let Some(d) = args.get("deadline-secs") {
        let secs: f64 = d
            .parse()
            .map_err(|_| anyhow!("--deadline-secs '{d}' is not a number"))?;
        if !secs.is_finite() || secs <= 0.0 {
            bail!("--deadline-secs wants a positive number of seconds (got '{d}')");
        }
        opts.deadline = Some(std::time::Duration::from_secs_f64(secs));
    }
    opts.shard = args.get("shard").map(String::from);
    Ok(opts)
}

/// `--inject-faults PLAN`: arm the deterministic chaos layer for this
/// process (see `util::fault` for the `point[/KEY]=KIND[:N];…` syntax;
/// `CXLMEM_FAULTS` is the environment spelling of the same plan).
fn install_faults(args: &Args) -> Result<()> {
    use cxlmem::util::fault;
    if args.flag("inject-faults") {
        anyhow::bail!("--inject-faults requires a PLAN argument (point[/KEY]=KIND[:N];...)");
    }
    if let Some(plan) = args.get("inject-faults") {
        fault::install(fault::FaultPlan::parse(plan)?);
        eprintln!("fault injection armed: {plan}");
    }
    Ok(())
}

/// `--cache` / `--no-cache` / `--cache-dir DIR` handling shared by
/// `scenario run` (cached by default) and `scenario bench` (uncached by
/// default — it is a throughput probe). `--no-cache` wins over the
/// enabling forms.
fn open_scenario_cache(
    args: &Args,
    default_on: bool,
) -> Result<Option<cxlmem::scenario::ResultCache>> {
    use anyhow::bail;
    // The tiny CLI parser turns `--cache FILE` into an option and
    // swallows FILE from the positional list — on a file-list command
    // that silently drops a scenario file. Reject the valued forms
    // outright instead of guessing.
    for flag in ["cache", "no-cache"] {
        if let Some(v) = args.get(flag) {
            bail!(
                "--{flag} takes no value (got '{v}', which would be dropped from the \
                 file list) — put the flag after the files or before another --option"
            );
        }
    }
    let dir = args.get("cache-dir");
    let on = !args.flag("no-cache") && (args.flag("cache") || dir.is_some() || default_on);
    if !on {
        return Ok(None);
    }
    let dir = std::path::Path::new(dir.unwrap_or(cxlmem::scenario::cache::DEFAULT_DIR));
    let mut cache = cxlmem::scenario::ResultCache::open(dir)?;
    if args.flag("compact-every") {
        bail!("--compact-every requires an N argument (0 = seal only, 1 = every flush)");
    }
    if let Some(n) = args.get("compact-every") {
        let n: u64 = n
            .parse()
            .map_err(|_| anyhow::anyhow!("--compact-every wants an integer, got '{n}'"))?;
        cache.set_compact_every(n);
    }
    Ok(Some(cache))
}

/// Write to `--out FILE` when given, else print to stdout.
fn write_or_print(args: &Args, body: &str) -> Result<()> {
    if let Some(path) = args.get("out") {
        std::fs::write(path, body)?;
        println!("wrote {path}");
    } else {
        print!("{body}");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    use anyhow::{anyhow, bail, Context};
    // `--validate FILE`: schema-check an existing BENCH_hotpath.json
    // instead of running the suite (the `make bench-check` gate). A bare
    // `--validate` (file forgotten, or eaten by a following flag) must
    // error, not silently fall through to a full suite run.
    if args.flag("validate") {
        bail!("--validate requires a FILE argument (a written BENCH_hotpath.json)");
    }
    if let Some(path) = args.get("validate") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        // A bench artifact is normally one JSON document; supervised
        // pipelines may append cxlmem-result-error-v1 lines to the same
        // file, so fall back to JSONL and schema-check every line
        // against its own schema.
        let docs = match Json::parse(&text) {
            Ok(doc) => vec![doc],
            Err(_) => {
                cxlmem::util::json::parse_jsonl(&text).map_err(|e| anyhow!("{path}: {e}"))?
            }
        };
        let (mut benches, mut errors) = (0usize, 0usize);
        for doc in &docs {
            if cxlmem::scenario::supervise::is_error_doc(doc) {
                cxlmem::scenario::validate_error_doc(doc).map_err(|e| anyhow!("{path}: {e}"))?;
                errors += 1;
            } else {
                cxlmem::bench::validate_report_doc(doc).map_err(|e| anyhow!("{path}: {e}"))?;
                benches += 1;
            }
        }
        if benches == 0 {
            bail!("{path}: no bench report found (schema cxlmem-bench-v1)");
        }
        println!(
            "{path}: ok (schema cxlmem-bench-v1{})",
            if errors == 0 {
                String::new()
            } else {
                format!(" + {errors} error document(s), schema {}", cxlmem::scenario::ERROR_SCHEMA)
            }
        );
        return Ok(());
    }
    let metrics = metrics_out(args)?;
    let opts = cxlmem::bench::BenchOpts {
        // --quick is an alias for --smoke (the `make bench-check` spelling).
        smoke: args.flag("smoke") || args.flag("quick"),
        jobs: args.get_usize("jobs", cxlmem::perf::default_jobs()),
    };
    let report = cxlmem::bench::run_suite(&opts);
    print!("{}", report.summary());
    let out = args.get_or("out", "BENCH_hotpath.json");
    report.save(std::path::Path::new(out))?;
    println!("wrote {out}");
    emit_metrics(metrics.as_ref())
}

/// `cxlmem stats` — the metrics surface. With no FILE, snapshot this
/// process's registry (useful under `--json` for scripting; most
/// counters are zero in a fresh process — the `--metrics` sidecar flags
/// on the long-running verbs are the real capture points). With FILE
/// (or `-` for stdin), validate and render a written sidecar. With
/// `--validate FILE`, schema-check only (the `make metrics-smoke`
/// spelling).
fn cmd_stats(args: &Args) -> Result<()> {
    use anyhow::{anyhow, bail, Context};
    use cxlmem::util::metrics;

    // A bare `--validate` (file forgotten, or eaten by a following
    // flag) must error, not silently fall through to a live snapshot.
    if args.flag("validate") {
        bail!("--validate requires a FILE argument (a written metrics sidecar)");
    }
    // Supervised runs may interleave cxlmem-result-error-v1 documents
    // with the snapshots; route by schema and validate each line
    // against its own schema. Returns `(metrics_docs, error_docs)`.
    let read_docs = |path: &str| -> Result<(Vec<Json>, Vec<Json>)> {
        let text = if path == "-" {
            let mut buf = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut buf)?;
            buf
        } else {
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?
        };
        // A sidecar holds one snapshot per line (shard runs append).
        let docs = cxlmem::util::json::parse_jsonl(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        if docs.is_empty() {
            bail!("{path}: no metrics snapshots found");
        }
        let (mut mdocs, mut edocs) = (Vec::new(), Vec::new());
        for doc in docs {
            if cxlmem::scenario::supervise::is_error_doc(&doc) {
                cxlmem::scenario::validate_error_doc(&doc).map_err(|e| anyhow!("{path}: {e}"))?;
                edocs.push(doc);
            } else {
                metrics::validate_metrics_doc(&doc).map_err(|e| anyhow!("{path}: {e}"))?;
                mdocs.push(doc);
            }
        }
        Ok((mdocs, edocs))
    };
    if let Some(path) = args.get("validate") {
        let (mdocs, edocs) = read_docs(path)?;
        println!(
            "{path}: ok ({} snapshot(s), schema {}{})",
            mdocs.len(),
            metrics::METRICS_SCHEMA,
            if edocs.is_empty() {
                String::new()
            } else {
                format!(
                    "; {} error document(s), schema {}",
                    edocs.len(),
                    cxlmem::scenario::ERROR_SCHEMA
                )
            }
        );
        return Ok(());
    }
    match args.positional.get(1).map(|s| s.as_str()) {
        None => {
            // Live snapshot of this process's registry.
            println!("{}", metrics::snapshot());
        }
        Some(path) => {
            let (mdocs, edocs) = read_docs(path)?;
            if args.flag("json") {
                for doc in mdocs.iter().chain(&edocs) {
                    println!("{doc}");
                }
            } else {
                // Render through the same fold `scenario report` uses,
                // so N sharded sidecars aggregate identically here and
                // any embedded error documents get their tables.
                let collected = cxlmem::scenario::report::Collected {
                    results: Vec::new(),
                    metrics: mdocs,
                    errors: edocs,
                    skipped: 0,
                };
                let report = cxlmem::scenario::report::summarize_collected(&collected, None);
                report.print(Format::Text);
            }
        }
    }
    Ok(())
}

/// The `make metrics-smoke` gate: a small fleet run twice against one
/// cache store must (a) emit byte-identical result JSONL, (b) serve the
/// warm run purely from cache, and (c) keep the metrics registry
/// consistent with the per-instance cache counters — the registry's
/// `scenario.cache.hits` delta across the warm run must equal the
/// cache handle's own hit count, and `scenario.batch.evaluated` must
/// not move when everything hits.
fn cmd_metrics_smoke(args: &Args) -> Result<()> {
    use anyhow::{anyhow, bail};
    use cxlmem::scenario;
    use cxlmem::util::json::to_jsonl;
    use cxlmem::util::metrics;

    if !metrics::global().enabled() {
        bail!("metrics-smoke needs the registry enabled (unset CXLMEM_METRICS)");
    }
    let metrics_dest = metrics_out(args)?;
    let count = args.get_usize("count", 6);
    let jobs = args.get_usize("jobs", 2);
    let doc = Json::parse(&format!(
        r#"{{"name": "metrics-fleet", "fleet": {{"count": {count}, "seed": 11}}}}"#
    ))
    .map_err(|e| anyhow!("internal fleet template: {e}"))?;
    let expanded = scenario::expand(&doc, None, None)?;
    let specs: Vec<_> = expanded
        .iter()
        .map(scenario::ScenarioSpec::parse)
        .collect::<Result<_>>()?;

    let dir = std::env::temp_dir().join(format!("cxlmem-metrics-smoke-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // Cold run: everything misses and evaluates.
    let mut cold = scenario::ResultCache::open(&dir)?;
    let r1 = scenario::run_batch_cached(&specs, jobs, Some(&mut cold))?;
    let snap_cold = metrics::snapshot();
    metrics::validate_metrics_doc(&snap_cold).map_err(|e| anyhow!("cold snapshot invalid: {e}"))?;
    if cold.misses() == 0 {
        bail!("cold run reported no cache misses — the store was not fresh");
    }
    let hits_cold = metrics::counter("scenario.cache.hits").get();
    let evaluated_cold = metrics::counter("scenario.batch.evaluated").get();

    // Warm run: a fresh handle on the same store must be pure hits.
    let mut warm = scenario::ResultCache::open(&dir)?;
    let r2 = scenario::run_batch_cached(&specs, jobs, Some(&mut warm))?;
    let snap_warm = metrics::snapshot();
    metrics::validate_metrics_doc(&snap_warm).map_err(|e| anyhow!("warm snapshot invalid: {e}"))?;
    let _ = std::fs::remove_dir_all(&dir);

    let a = to_jsonl(r1.into_iter().map(|r| r.doc));
    let b = to_jsonl(r2.into_iter().map(|r| r.doc));
    if a != b {
        bail!("warm re-run JSONL differs from the cold run");
    }
    if warm.misses() != 0 || warm.hits() == 0 {
        bail!(
            "warm run was not pure cache hits ({} hit(s), {} miss(es))",
            warm.hits(),
            warm.misses()
        );
    }
    let hit_delta = metrics::counter("scenario.cache.hits").get() - hits_cold;
    if hit_delta != warm.hits() {
        bail!(
            "registry cache-hit delta {hit_delta} != warm cache handle's {} hit(s)",
            warm.hits()
        );
    }
    if metrics::counter("scenario.batch.evaluated").get() != evaluated_cold {
        bail!("warm run evaluated scenarios despite a fully warm cache");
    }
    let n_policy = snap_warm
        .get("histograms")
        .and_then(|h| h.as_obj())
        .map(|m| m.keys().filter(|k| k.starts_with("eval.policy.")).count())
        .unwrap_or(0);
    if n_policy == 0 {
        bail!("no per-policy eval-time histograms were recorded");
    }
    println!(
        "metrics-smoke: ok — {} scenario(s); warm re-run byte-identical, {} cache hit(s) \
         (registry delta agrees), {} per-policy eval histogram(s); snapshots validate ({})",
        specs.len(),
        warm.hits(),
        n_policy,
        metrics::METRICS_SCHEMA
    );
    emit_metrics(metrics_dest.as_ref())
}

/// The `make chaos-smoke` gate. Stage 1 drills the storage layer: a
/// trace generation killed mid-fill (`trace.generate` panic) must leave
/// the trace store usable for the retry, and the traffic solver must
/// absorb injected memo-path latency (`solver.memo` delay) without a
/// degenerate answer. Stage 2 runs a small fleet under a seeded fault
/// plan (one eval panic, transient eval-IO errors, a flush IO error,
/// lock contention) which must (a) exit 0 with the batch supervised —
/// the panic isolated into exactly the error document the plan names
/// while the transient faults retry to success — and (b) heal on a
/// clean re-run: error documents are never cached, so re-running the
/// same fleet over the same store evaluates just the failed slot and
/// emits JSONL byte-identical to a never-faulted run in a fresh store.
fn cmd_chaos_smoke(args: &Args) -> Result<()> {
    use anyhow::{anyhow, bail};
    use cxlmem::scenario::{self, SuperviseOpts};
    use cxlmem::util::fault;
    use cxlmem::util::json::to_jsonl;

    let metrics_dest = metrics_out(args)?;

    // Stage 1 — storage-layer drills, before the fleet: a trace
    // generation killed mid-fill must leave the store usable for the
    // retry, and the solver's memoized path must absorb injected
    // latency without changing results. These points are armed in a
    // dedicated plan and cleared before stage 2 so the fleet's exact
    // fired-counter assertions below stay untouched (a delay rule in
    // particular fires on *every* hit).
    {
        use cxlmem::memsim::{topology, Pattern, Stream};
        use cxlmem::workloads::tiering_apps::pagerank;
        use cxlmem::workloads::trace::TraceStore;

        let app = pagerank();
        fault::install(fault::FaultPlan::parse(&format!(
            "trace.generate/{}=panic:1;solver.memo=delay:1",
            app.name
        ))?);
        // A private store keeps the drill out of the process-global one.
        let store = TraceStore::with_budget(64 << 20);
        let killed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            store.get(&app, 4, 99)
        }));
        if killed.is_ok() {
            fault::clear();
            bail!("trace.generate panic rule did not fire");
        }
        if fault::fired("trace.generate") != 1 {
            fault::clear();
            bail!("trace.generate fired {} time(s), want 1", fault::fired("trace.generate"));
        }
        // The poisoned-lock recovery in TraceStore makes the retry
        // generate cleanly in the same store.
        let trace = store.get(&app, 4, 99);
        if trace.bytes() == 0 {
            fault::clear();
            bail!("post-crash trace generation returned an empty trace");
        }
        let sol = topology::system_a().solve_traffic(&[Stream {
            socket: 0,
            node_weights: vec![(0, 1.0)],
            pattern: Pattern::Random,
            threads: 8.0,
            delay_ns: 0.0,
        }]);
        let delayed = fault::fired("solver.memo");
        fault::clear();
        if delayed == 0 {
            bail!("solver.memo delay rule never fired");
        }
        if !sol.streams[0].bw_gbs.is_finite() || sol.streams[0].bw_gbs <= 0.0 {
            bail!("solver under injected memo latency returned a degenerate solution");
        }
    }

    // Stage 2 — the supervised fleet under the eval/flush/lock plan.
    let count = args.get_usize("count", 8).max(3);
    let jobs = args.get_usize("jobs", 2);
    let doc = Json::parse(&format!(
        r#"{{"name": "chaos-fleet", "fleet": {{"count": {count}, "seed": 23}}}}"#
    ))
    .map_err(|e| anyhow!("internal fleet template: {e}"))?;
    let expanded = scenario::expand(&doc, None, None)?;
    let specs: Vec<_> = expanded
        .iter()
        .map(scenario::ScenarioSpec::parse)
        .collect::<Result<_>>()?;
    // Fleet names are zero-padded, so a name is never a substring of a
    // sibling's and the /KEY filters below hit exactly one spec each.
    let panic_victim = specs[1].name.clone();
    let io_victim = specs[count - 1].name.clone();

    let base = std::env::temp_dir();
    let dir_faulted = base.join(format!("cxlmem-chaos-smoke-{}", std::process::id()));
    let dir_clean = base.join(format!("cxlmem-chaos-smoke-ref-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir_faulted);
    let _ = std::fs::remove_dir_all(&dir_clean);

    let opts = SuperviseOpts {
        backoff_ms: 1,
        shard: Some("1/1".to_string()),
        ..SuperviseOpts::default()
    };
    // One panic (isolated), two transient eval-IO errors (retried to
    // success under the default 2 retries), one flush IO error (the
    // cache's own bounded retry), and 1 ms of lock contention.
    let plan = format!(
        "scenario.eval/{panic_victim}=panic:1;scenario.eval.io/{io_victim}=io:2;\
         cache.flush.io=io:1;lock.acquire=delay:1"
    );
    fault::install(fault::FaultPlan::parse(&plan)?);
    let mut cache = scenario::ResultCache::open(&dir_faulted)?;
    let faulted = scenario::run_batch_supervised(&specs, jobs, Some(&mut cache), &opts)?;
    let eval_panics = fault::fired("scenario.eval");
    let eval_io = fault::fired("scenario.eval.io");
    let flush_io = fault::fired("cache.flush.io");
    fault::clear();

    if faulted.len() != specs.len() {
        bail!(
            "supervised run returned {} slot(s) for {} spec(s)",
            faulted.len(),
            specs.len()
        );
    }
    let error_docs: Vec<_> = faulted
        .iter()
        .filter(|r| scenario::supervise::is_error_doc(&r.doc))
        .collect();
    match error_docs.as_slice() {
        [only] => {
            scenario::validate_error_doc(&only.doc)?;
            let name = only.doc.get("scenario").and_then(Json::as_str).unwrap_or("");
            let kind = only.doc.get("error").and_then(Json::as_str).unwrap_or("");
            if name != panic_victim || kind != "panic" {
                bail!(
                    "error document names '{name}' ({kind}); the plan faulted \
                     '{panic_victim}' (panic)"
                );
            }
            let msg = only.doc.get("message").and_then(Json::as_str).unwrap_or("");
            if !msg.contains(fault::INJECTED) {
                bail!("error document message lost the injected-fault marker: {msg}");
            }
        }
        other => bail!(
            "expected exactly 1 error document (the injected panic), found {}",
            other.len()
        ),
    }
    if eval_panics != 1 || eval_io != 2 || flush_io != 1 {
        bail!(
            "fault plan misfired: eval panics {eval_panics}, eval io {eval_io}, \
             flush io {flush_io} (want 1/2/1)"
        );
    }

    // Heal: the error document was never cached, so the same fleet over
    // the same store re-evaluates only the panicked slot...
    let mut healed_cache = scenario::ResultCache::open(&dir_faulted)?;
    let healed = scenario::run_batch_supervised(&specs, jobs, Some(&mut healed_cache), &opts)?;
    if healed_cache.misses() != 1 || healed_cache.hits() as usize != specs.len() - 1 {
        bail!(
            "healing run expected {} hit(s) + 1 miss, saw {} hit(s), {} miss(es)",
            specs.len() - 1,
            healed_cache.hits(),
            healed_cache.misses()
        );
    }
    // ...and must agree byte for byte with a never-faulted run in a
    // fresh store.
    let mut ref_cache = scenario::ResultCache::open(&dir_clean)?;
    let reference = scenario::run_batch_supervised(&specs, jobs, Some(&mut ref_cache), &opts)?;
    let healed_jsonl = to_jsonl(healed.into_iter().map(|r| r.doc));
    let reference_jsonl = to_jsonl(reference.into_iter().map(|r| r.doc));
    let _ = std::fs::remove_dir_all(&dir_faulted);
    let _ = std::fs::remove_dir_all(&dir_clean);
    if healed_jsonl != reference_jsonl {
        bail!("healed re-run JSONL differs from the never-faulted run");
    }
    if healed_jsonl.contains(scenario::ERROR_SCHEMA) {
        bail!("healed re-run still contains error documents");
    }
    println!(
        "chaos-smoke: ok — trace crash + solver delay drills survived; {} scenario(s); \
         1 panic isolated into a {} document ({panic_victim}), {eval_io} transient \
         eval-IO fault(s) and {flush_io} flush fault(s) retried; healed re-run \
         byte-identical to the never-faulted run",
        specs.len(),
        scenario::ERROR_SCHEMA
    );
    emit_metrics(metrics_dest.as_ref())
}

/// The `make trace-smoke` gate: fig16 twice in one process must emit
/// byte-identical reports while the shared epoch-trace store generates
/// each app's trace exactly once (the second run is pure `Arc` replays).
fn cmd_trace_smoke(args: &Args) -> Result<()> {
    use anyhow::bail;
    let metrics = metrics_out(args)?;
    let store = cxlmem::workloads::trace::global();
    store.clear();
    cxlmem::perf::set_jobs(cxlmem::perf::default_jobs());
    let apps = cxlmem::workloads::tiering_apps::all_apps().len() as u64;
    let first = cxlmem::exp::run("fig16")?.render(Format::Text);
    let after_first = store.stats();
    let second = cxlmem::exp::run("fig16")?.render(Format::Text);
    let stats = store.stats();
    if first != second {
        bail!("fig16 reports differ between two in-process runs");
    }
    if after_first.generated != apps {
        bail!(
            "expected one trace generation per app ({apps}) after run 1, saw {}",
            after_first.generated
        );
    }
    if stats.generated != after_first.generated {
        bail!(
            "second run regenerated traces ({} -> {})",
            after_first.generated,
            stats.generated
        );
    }
    if stats.requests < 2 * after_first.requests || stats.requests < 2 * apps {
        bail!(
            "second run did not request the store (requests {} -> {})",
            after_first.requests,
            stats.requests
        );
    }
    println!(
        "trace-smoke: ok — byte-identical fig16 reports; {} trace generation(s) served {} \
         request(s), {} bytes held in {} entr(ies)",
        stats.generated,
        stats.requests,
        stats.bytes,
        stats.entries
    );
    emit_metrics(metrics.as_ref())
}

/// The `make scale-smoke` gate: one million-page fig16-style cell must
/// produce bit-identical results across (a) the chunked-parallel epoch
/// passes vs the sequential seed path and (b) delta-encoded trace
/// replay vs a dense materialized trace — while peak RSS stays under
/// `--rss-mb` (a guard against accidental per-cell dense
/// materialization or quadratic scratch at scale).
fn cmd_scale_smoke(args: &Args) -> Result<()> {
    use anyhow::bail;
    use cxlmem::memsim::{topology, MemKind, Pattern};
    use cxlmem::tiering::{self, initial_state, SimConfig, Tpp};
    use cxlmem::workloads::tiering_apps::pagerank;
    use cxlmem::workloads::trace::EpochTrace;

    let metrics = metrics_out(args)?;
    let pages = args.get_usize("pages", 1 << 20);
    let epochs = args.get_usize("epochs", 5);
    let rss_mb = args.get_usize("rss-mb", 1024);
    let jobs = args.get_usize("jobs", cxlmem::perf::default_jobs()).max(2);
    let seed = 7;

    // PageRank with a small drift: every epoch boundary is a real —
    // but sparse — delta, so the snapshot is certainly delta-encoded
    // and the replay exercises the patch path, not a trivial constant.
    let mut app = pagerank();
    app.pages = pages;
    app.drift = 0.05;

    let trace = EpochTrace::generate(&app, epochs, seed);
    if !trace.is_delta() {
        bail!("expected a delta-encoded trace at {pages} pages (got the dense fallback)");
    }
    let dense = EpochTrace::generate_dense(&app, epochs, seed);

    let sys = topology::system_a();
    let socket = 0;
    let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(socket, MemKind::Cxl).unwrap();
    let fast_cap = pages * 2 / 5;
    let cfg = SimConfig {
        socket,
        threads: 8,
        compute_ns_per_byte: app.compute_ns_per_access / 64.0,
        epochs,
        seed,
    };

    // One first-touch TPP cell, run three ways; every way must agree
    // bit-for-bit on stats, times, and the final page placement.
    let run_cell = |tr: &EpochTrace, jobs: usize| {
        let mut state = initial_state(pages, ld, cxl, fast_cap, false);
        let mut policy = Tpp::default();
        let run = cxlmem::perf::with_jobs(jobs, || {
            tiering::simulate_trace(&sys, &cfg, &mut state, &mut policy, tr, |_| {
                (Pattern::Random, 0.55)
            })
        });
        let placement: Vec<_> = (0..pages).map(|p| state.node_of(p)).collect();
        (run, state.fast_used(), placement)
    };
    let t0 = std::time::Instant::now();
    let (run_par, used_par, place_par) = run_cell(&trace, jobs);
    let par_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (run_seq, used_seq, place_seq) = run_cell(&trace, 1);
    let seq_s = t0.elapsed().as_secs_f64();
    let (run_dense, used_dense, place_dense) = run_cell(&dense, jobs);

    for (label, run, used, place) in [
        ("sequential delta replay (jobs=1)", &run_seq, used_seq, &place_seq),
        ("dense-trace replay", &run_dense, used_dense, &place_dense),
    ] {
        if run.stats != run_par.stats
            || run.app_s.to_bits() != run_par.app_s.to_bits()
            || run.overhead_s.to_bits() != run_par.overhead_s.to_bits()
        {
            bail!("scale-smoke: {label} diverged from the chunked delta replay (stats/times)");
        }
        if used != used_par || place != &place_par {
            bail!("scale-smoke: {label} diverged from the chunked delta replay (final placement)");
        }
    }
    println!(
        "scale-smoke: ok — {pages} pages x {epochs} epochs, TPP first-touch; chunked \
         (jobs={jobs}, {par_s:.2} s) == sequential ({seq_s:.2} s) == dense replay; \
         delta snapshot {} KB vs {} KB dense",
        trace.bytes() >> 10,
        dense.bytes() >> 10
    );
    match peak_rss_mb() {
        Some(mb) if mb > rss_mb => bail!("scale-smoke: peak RSS {mb} MB exceeds --rss-mb {rss_mb}"),
        Some(mb) => println!("scale-smoke: peak RSS {mb} MB (bound {rss_mb} MB)"),
        None => println!("scale-smoke: VmHWM unreadable on this platform; skipping the RSS gate"),
    }
    emit_metrics(metrics.as_ref())
}

/// Peak resident set size in MB from `/proc/self/status` (Linux only).
fn peak_rss_mb() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: usize = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024)
}

fn cmd_info() -> Result<()> {
    match cxlmem::runtime::Runtime::discover() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!(
                "artifacts: {} in {} (model: {} params, vocab {}, d_model {}, {} layers)",
                rt.manifest.artifacts.len(),
                rt.manifest.dir.display(),
                rt.manifest.model.params,
                rt.manifest.model.vocab,
                rt.manifest.model.d_model,
                rt.manifest.model.layers,
            );
        }
        Err(e) => println!("artifacts not built ({e}); run `make artifacts`"),
    }
    println!("systems: A, B, C (see `cxlmem exp table1`)");
    println!(
        "verbs: exp, scenario (validate|expand|run|bench|report|compact|serve|submit), \
         bench, stats, metrics-smoke, chaos-smoke, trace-smoke, scale-smoke, train, \
         serve, info"
    );
    println!(
        "fault injection: {} (`--inject-faults PLAN` on scenario run; see README \
         'Fault tolerance & chaos testing')",
        if cxlmem::util::fault::active() {
            "armed via CXLMEM_FAULTS"
        } else {
            "disarmed"
        }
    );
    println!(
        "metrics: registry {} (schema {}; `cxlmem stats`, `--metrics FILE` sidecars)",
        if cxlmem::util::metrics::global().enabled() {
            "enabled"
        } else {
            "disabled via CXLMEM_METRICS"
        },
        cxlmem::util::metrics::METRICS_SCHEMA
    );
    Ok(())
}

fn print_help() {
    println!(
        "cxlmem — 'Exploring and Evaluating Real-world CXL' reproduction\n\
         \n\
         USAGE:\n\
         \x20 cxlmem exp <id|all> [--csv|--json] [--out FILE] [--jobs N] [--metrics FILE]\n\
         \x20 cxlmem scenario validate|expand|run|bench|report|compact|serve|submit ...\n\
         \x20\x20\x20\x20 (see `cxlmem scenario help`)\n\
         \x20 cxlmem bench [--smoke|--quick] [--jobs N] [--out FILE] [--validate FILE]\n\
         \x20 cxlmem stats [FILE|-] [--json] [--validate FILE]\n\
         \x20 cxlmem metrics-smoke [--count N] [--jobs N]\n\
         \x20 cxlmem chaos-smoke [--count N] [--jobs N]\n\
         \x20 cxlmem trace-smoke [--metrics FILE]\n\
         \x20 cxlmem scale-smoke [--pages N] [--epochs N] [--jobs N] [--rss-mb MB]\n\
         \x20 cxlmem train [--steps N] [--seed S] [--log-every K]\n\
         \x20 cxlmem serve [--requests N]\n\
         \x20 cxlmem info\n\
         \n\
         `exp`, `scenario run|bench`, `bench` and the smokes accept --metrics FILE\n\
         ('-' for stderr) to write a cxlmem-metrics-v1 snapshot (see README 'Metrics').\n\
         \n\
         experiment ids: {}",
        cxlmem::exp::ALL.join(", ")
    );
}
