//! Memory device models.
//!
//! A `MemDevice` is one attach point of physical memory: a local DDR5 pool,
//! the remote socket's DDR5 pool (reached over the inter-socket link), a
//! CXL type-3 expansion card (reached over PCIe 5.0 + CXL controller), or
//! an NVMe SSD (FlexGen's coldest tier).
//!
//! The paper's systems A/B/C (Table I) are three calibrations of these
//! models; see `memsim::topology`. Parameters are *measured-behaviour*
//! parameters (idle latency, achievable peak bandwidth), not datasheet
//! numbers — Table I datasheet values are kept separately for reporting.

/// Access pattern, as driven by Intel MLC: dependent pointer-chasing
/// ("random") vs hardware-prefetchable streaming ("sequential").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pattern {
    Sequential,
    Random,
}

/// Kind of memory attach point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemKind {
    /// DDR channels on the socket running the workload.
    Ldram,
    /// DDR channels on the other socket (one NUMA hop: xGMI / UPI).
    Rdram,
    /// CXL 1.1 type-3 expansion card (PCIe 5.0 + CXL controller + HA).
    Cxl,
    /// NVMe SSD exposed via mmap (FlexGen's lowest tier).
    Nvme,
}

impl MemKind {
    pub fn label(&self) -> &'static str {
        match self {
            MemKind::Ldram => "LDRAM",
            MemKind::Rdram => "RDRAM",
            MemKind::Cxl => "CXL",
            MemKind::Nvme => "NVMe",
        }
    }

    /// True for byte-addressable load/store tiers.
    pub fn is_dram_like(&self) -> bool {
        !matches!(self, MemKind::Nvme)
    }
}

/// Idle (unloaded) latency, split by access pattern. Nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct IdleLatency {
    pub seq_ns: f64,
    pub rand_ns: f64,
}

impl IdleLatency {
    pub fn get(&self, p: Pattern) -> f64 {
        match p {
            Pattern::Sequential => self.seq_ns,
            Pattern::Random => self.rand_ns,
        }
    }
}

/// One memory device (= one NUMA node's backing store).
#[derive(Clone, Debug)]
pub struct MemDevice {
    pub kind: MemKind,
    /// Unloaded access latency from the *near* socket.
    pub idle: IdleLatency,
    /// Achievable peak bandwidth (GB/s) — the measured plateau of Fig 3,
    /// not the datasheet number.
    pub peak_bw_gbs: f64,
    /// Datasheet max bandwidth (GB/s) for Table I reporting.
    pub spec_bw_gbs: f64,
    /// Capacity in bytes.
    pub capacity: u64,
    /// Memory-controller queueing factor `Q` in
    /// `lat(ρ) = idle + min(Q·ρ/(1−ρ), queue_cap_ns)`; larger = sharper
    /// latency knee.
    pub queue_ns: f64,
    /// Upper bound on the queueing delay (ns): finite MC/device queues
    /// exert backpressure instead of growing without bound, so loaded
    /// latency plateaus (Fig 4's right edge) rather than diverging.
    pub queue_cap_ns: f64,
    /// Per-thread streaming (sequential) bandwidth against this device
    /// from the near socket, GB/s. Streaming cores are *issue-rate*-bound
    /// (HW prefetchers hide latency), so this is a rate, not an MLP count;
    /// it fixes each tier's saturation thread count: `sat ≈ peak / rate`.
    pub stream_rate_gbs: f64,
    /// Per-thread outstanding cache lines for *dependent/random* access
    /// (MSHR-bound); random throughput is `mlp_rand · 64B / latency`.
    pub mlp_rand: f64,
    /// Device-side access optimization factor for *concentrated* random
    /// access streams (<1.0 = faster). Models the CXL controller/HA
    /// caching the paper invokes for HPC-observation 3 (CG on CXL).
    pub concentrated_rand_factor: f64,
}

/// Cache line size used throughout (bytes).
pub const LINE: f64 = 64.0;
/// Utilization cap: queues are modeled as stable up to this occupancy.
pub const RHO_MAX: f64 = 0.98;

impl MemDevice {
    /// Loaded latency at utilization `rho` (0..1) for the given pattern,
    /// before any topology hop adders. The queueing term is capped by
    /// `queue_cap_ns` (finite queues + backpressure).
    pub fn latency_at(&self, p: Pattern, rho: f64) -> f64 {
        let rho = rho.clamp(0.0, RHO_MAX);
        let q = (self.queue_ns * rho / (1.0 - rho)).min(self.queue_cap_ns);
        self.idle.get(p) + q
    }

    /// Single-thread unloaded bandwidth (GB/s). Sequential: the issue
    /// rate. Random: `mlp · 64B / idle latency` (bytes/ns == GB/s).
    pub fn thread_bw(&self, p: Pattern) -> f64 {
        match p {
            Pattern::Sequential => self.stream_rate_gbs,
            Pattern::Random => self.mlp_rand * LINE / self.idle.get(p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> MemDevice {
        MemDevice {
            kind: MemKind::Cxl,
            idle: IdleLatency {
                seq_ns: 250.0,
                rand_ns: 380.0,
            },
            peak_bw_gbs: 22.0,
            spec_bw_gbs: 38.4,
            capacity: 128 << 30,
            queue_ns: 60.0,
            queue_cap_ns: 300.0,
            stream_rate_gbs: 5.6,
            mlp_rand: 10.0,
            concentrated_rand_factor: 0.8,
        }
    }

    #[test]
    fn latency_monotone_in_load() {
        let d = dev();
        let l0 = d.latency_at(Pattern::Sequential, 0.0);
        let l5 = d.latency_at(Pattern::Sequential, 0.5);
        let l9 = d.latency_at(Pattern::Sequential, 0.9);
        assert_eq!(l0, 250.0);
        assert!(l0 < l5 && l5 < l9);
    }

    #[test]
    fn latency_capped_at_rho_max() {
        let d = dev();
        let a = d.latency_at(Pattern::Random, 0.999);
        let b = d.latency_at(Pattern::Random, 2.0);
        assert_eq!(a, b); // both clamp to RHO_MAX
        assert!(a.is_finite());
        // queue term is bounded by queue_cap_ns
        assert!(a <= d.idle.rand_ns + d.queue_cap_ns);
    }

    #[test]
    fn random_slower_than_sequential_idle() {
        let d = dev();
        assert!(d.idle.get(Pattern::Random) > d.idle.get(Pattern::Sequential));
    }

    #[test]
    fn thread_bw_sane() {
        let d = dev();
        assert_eq!(d.thread_bw(Pattern::Sequential), 5.6);
        // 10 lines * 64B / 380ns = 1.684 GB/s
        assert!((d.thread_bw(Pattern::Random) - 10.0 * 64.0 / 380.0).abs() < 1e-9);
    }

    #[test]
    fn kind_labels() {
        assert_eq!(MemKind::Cxl.label(), "CXL");
        assert!(MemKind::Ldram.is_dram_like());
        assert!(!MemKind::Nvme.is_dram_like());
    }
}
