//! Memory-system simulator: the substrate standing in for the paper's
//! three CXL testbeds (Table I).
//!
//! Structure:
//! - [`device`] — per-device latency/bandwidth/queueing models
//! - [`link`]   — interconnect hops (xGMI/UPI/PCIe) and data paths
//! - [`system`] — NUMA topology + the closed-loop traffic solver
//! - [`topology`] — calibrated presets for systems A, B, C

pub mod device;
pub mod link;
pub mod system;
pub mod topology;

pub use device::{IdleLatency, MemDevice, MemKind, Pattern, LINE};
pub use link::{Link, Path};
pub use system::{Node, NodeId, Stream, StreamResult, System, TrafficSolution};
