//! System model: sockets, NUMA nodes, interconnects, and the closed-loop
//! traffic solver that turns "n threads accessing these nodes with this
//! pattern" into achieved bandwidth + observed latency.
//!
//! The solver is the analytical heart of the reproduction: every figure in
//! §III (Figs 2–4), the HPC engine (§V) and the LLM transfer model (§IV)
//! are built on `solve_traffic`.

use super::device::{MemDevice, MemKind, Pattern, LINE, RHO_MAX};
use super::link::{Link, Path};

/// Index of a NUMA node within a `System`.
pub type NodeId = usize;

/// One NUMA node: a memory device attached at some socket.
#[derive(Clone, Debug)]
pub struct Node {
    pub device: MemDevice,
    /// Socket the device is attached to (LDRAM/RDRAM: their socket;
    /// CXL: socket holding the card's PCIe root port).
    pub socket: usize,
}

/// A whole evaluation platform (one of the paper's systems A/B/C).
#[derive(Clone, Debug)]
pub struct System {
    pub name: String,
    pub description: String,
    pub sockets: usize,
    pub cores_per_socket: usize,
    /// NUMA nodes; by convention node `s` is socket `s`'s DDR pool and
    /// CXL/NVMe nodes follow.
    pub nodes: Vec<Node>,
    /// Inter-socket fabric (xGMI / UPI).
    pub fabric: Link,
    /// PCIe link between CPU root port and the CXL card.
    pub cxl_link: Link,
    /// PCIe link to the GPU, if the platform has one (system A's A10).
    pub gpu_link: Option<Link>,
}

/// One traffic stream presented to the solver.
#[derive(Clone, Debug)]
pub struct Stream {
    /// Socket whose cores issue the accesses.
    pub socket: usize,
    /// Distribution of accesses over nodes; weights must sum to ~1.
    pub node_weights: Vec<(NodeId, f64)>,
    pub pattern: Pattern,
    /// Number of threads driving this stream.
    pub threads: f64,
    /// Additional per-access injection delay (ns) — MLC's load knob;
    /// 0 = as fast as possible.
    pub delay_ns: f64,
}

/// Solver output for one stream.
#[derive(Clone, Debug)]
pub struct StreamResult {
    /// Achieved bandwidth (GB/s).
    pub bw_gbs: f64,
    /// Average observed access latency (ns), including queueing and hops.
    pub latency_ns: f64,
}

/// Solver output.
#[derive(Clone, Debug)]
pub struct TrafficSolution {
    pub streams: Vec<StreamResult>,
    /// Per-node utilization (0..1) and per-node achieved bandwidth.
    pub node_rho: Vec<f64>,
    pub node_bw_gbs: Vec<f64>,
}

impl System {
    /// Nodes of a given kind visible from `socket` (e.g. "the LDRAM node").
    pub fn node_of(&self, socket: usize, kind: MemKind) -> Option<NodeId> {
        match kind {
            MemKind::Ldram => self
                .nodes
                .iter()
                .position(|n| n.device.kind == MemKind::Ldram && n.socket == socket),
            MemKind::Rdram => self
                .nodes
                .iter()
                .position(|n| n.device.kind == MemKind::Ldram && n.socket != socket),
            other => self.nodes.iter().position(|n| n.device.kind == other),
        }
    }

    /// Kind of `node` as seen from `socket` (the other socket's DDR pool
    /// is RDRAM from here).
    pub fn kind_from(&self, socket: usize, node: NodeId) -> MemKind {
        let n = &self.nodes[node];
        match n.device.kind {
            MemKind::Ldram if n.socket != socket => MemKind::Rdram,
            k => k,
        }
    }

    /// Interconnect path from a core on `socket` to `node`.
    /// DDR on same socket: direct. DDR on other socket: fabric.
    /// CXL: fabric first if the card hangs off the other socket.
    /// (The CXL PCIe+controller latency itself is part of the device's
    /// calibrated idle latency, since Fig 2 measures it from the near
    /// socket.)
    pub fn path(&self, socket: usize, node: NodeId) -> Path {
        let n = &self.nodes[node];
        let mut p = Path::direct();
        if n.socket != socket {
            p = p.then(self.fabric);
        }
        p
    }

    /// Unloaded latency from `socket` to `node` (Fig 2's quantity).
    pub fn idle_latency(&self, socket: usize, node: NodeId, pattern: Pattern) -> f64 {
        self.nodes[node].device.idle.get(pattern) + self.path(socket, node).latency_ns()
    }

    /// Peak bandwidth reachable from `socket` to `node`: device plateau
    /// clamped by any interconnect on the path.
    pub fn eff_peak_bw(&self, socket: usize, node: NodeId) -> f64 {
        self.nodes[node]
            .device
            .peak_bw_gbs
            .min(self.path(socket, node).bw_gbs())
    }

    /// Closed-loop fixed point: each stream's threads keep `mlp` lines
    /// outstanding; achieved per-stream bandwidth, per-node queueing
    /// latency, and per-node capacity are mutually consistent.
    ///
    /// Per iteration:
    /// 1. *demand*  D_s = threads_s · mlp_s · LINE / (delay_s + lat_s)
    /// 2. node demand D_i = Σ_s D_s · w_si ; ρ_i = D_i / cap_i
    /// 3. saturated nodes (ρ_i > RHO_MAX) throttle every stream that
    ///    touches them proportionally (backpressure), so served node
    ///    bandwidth never exceeds RHO_MAX · cap_i *inside* the loop —
    ///    which keeps the solution monotone in thread count.
    /// 4. lat_s from ρ via each device's bounded-queue latency model.
    pub fn solve_traffic(&self, streams: &[Stream]) -> TrafficSolution {
        let nn = self.nodes.len();
        let caps: Vec<f64> = (0..nn).map(|i| self.node_cap(i, streams)).collect();
        let mut rho = vec![0.0f64; nn];
        let mut stream_bw = vec![0.0f64; streams.len()];
        let mut lat_out = vec![0.0f64; streams.len()];
        let mut node_bw = vec![0.0f64; nn];

        for iter in 0..400 {
            // 1. unthrottled demand under current utilization estimate
            let mut demand: Vec<f64> = Vec::with_capacity(streams.len());
            for (si, s) in streams.iter().enumerate() {
                let lat = self.stream_latency(s, &rho);
                lat_out[si] = lat;
                demand.push(self.stream_offered(s, lat));
            }
            // 2. node demand
            let mut d_i = vec![0.0f64; nn];
            for (s, &d) in streams.iter().zip(demand.iter()) {
                for &(node, w) in &s.node_weights {
                    d_i[node] += d * w;
                }
            }
            // 3. backpressure throttle: a stream runs at the rate of its
            //    most-congested node.
            let mut served: Vec<f64> = demand.clone();
            for (si, s) in streams.iter().enumerate() {
                let mut scale: f64 = 1.0;
                for &(node, w) in &s.node_weights {
                    if w > 0.0 && d_i[node] > caps[node] * RHO_MAX && d_i[node] > 0.0 {
                        scale = scale.min(caps[node] * RHO_MAX / d_i[node]);
                    }
                }
                served[si] = demand[si] * scale;
            }
            // served node bandwidth + new utilization estimate
            let mut b_i = vec![0.0f64; nn];
            for (s, &b) in streams.iter().zip(served.iter()) {
                for &(node, w) in &s.node_weights {
                    b_i[node] += b * w;
                }
            }
            // Utilization for the *latency* model uses demand (queues fill
            // when demand exceeds service), clamped into [0, 1].
            let mut max_delta = 0.0f64;
            for i in 0..nn {
                let target = if caps[i] > 0.0 {
                    (d_i[i] / caps[i]).min(1.0)
                } else {
                    0.0
                };
                let new = 0.35 * target + 0.65 * rho[i]; // damped update
                max_delta = max_delta.max((new - rho[i]).abs());
                rho[i] = new;
            }
            stream_bw = served;
            node_bw = b_i;
            if max_delta < 1e-7 && iter > 10 {
                break;
            }
        }

        TrafficSolution {
            streams: streams
                .iter()
                .enumerate()
                .map(|(si, _)| StreamResult {
                    bw_gbs: stream_bw[si],
                    latency_ns: lat_out[si],
                })
                .collect(),
            node_rho: rho,
            node_bw_gbs: node_bw,
        }
    }

    /// Effective node bandwidth cap given the sockets driving traffic at
    /// it (interconnect clamp uses the weakest path among participants —
    /// conservative and adequate for the paper's single-socket runs).
    fn node_cap(&self, node: NodeId, streams: &[Stream]) -> f64 {
        let mut cap = self.nodes[node].device.peak_bw_gbs;
        for s in streams {
            if s.node_weights.iter().any(|&(n, w)| n == node && w > 0.0) {
                cap = cap.min(self.path(s.socket, node).bw_gbs());
            }
        }
        cap
    }

    /// Average access latency for a stream under node utilizations `rho`.
    fn stream_latency(&self, s: &Stream, rho: &[f64]) -> f64 {
        let concentrated = s
            .node_weights
            .iter()
            .filter(|&&(_, w)| w > 1e-9)
            .count()
            <= 1;
        let mut lat = 0.0;
        for &(node, w) in &s.node_weights {
            if w <= 0.0 {
                continue;
            }
            let dev = &self.nodes[node].device;
            let mut l = dev.latency_at(s.pattern, rho[node]);
            // HPC observation 3: a *concentrated* random stream on one
            // node benefits from row-buffer locality / device caching;
            // spreading the same stream across nodes forfeits it.
            if s.pattern == Pattern::Random && concentrated {
                l *= dev.concentrated_rand_factor;
            }
            lat += w * (l + self.path(s.socket, node).latency_ns());
        }
        lat
    }

    /// Offered (unthrottled) bandwidth of a stream given its observed
    /// access latency.
    ///
    /// Sequential streams are issue-rate-bound: each thread sustains the
    /// device's `stream_rate_gbs` (degraded by fabric hops), independent
    /// of latency — HW prefetchers hide it. Injection delay (MLC's load
    /// knob) stretches the per-line cycle.
    ///
    /// Random streams are latency-bound: `mlp_rand` outstanding lines per
    /// thread against the observed latency.
    fn stream_offered(&self, s: &Stream, lat: f64) -> f64 {
        match s.pattern {
            Pattern::Sequential => {
                // Average per-line issue time across the node mix.
                let mut t_line = s.delay_ns;
                for &(node, w) in &s.node_weights {
                    if w <= 0.0 {
                        continue;
                    }
                    let dev = &self.nodes[node].device;
                    let hop = self.path(s.socket, node).latency_ns();
                    // Fabric hops lower the effective issue rate in
                    // proportion to the lengthened round trip.
                    let rate = dev.stream_rate_gbs * dev.idle.seq_ns / (dev.idle.seq_ns + hop);
                    t_line += w * LINE / rate;
                }
                s.threads * LINE / t_line
            }
            Pattern::Random => {
                let mut mlp = 0.0;
                for &(node, w) in &s.node_weights {
                    mlp += w * self.nodes[node].device.mlp_rand;
                }
                s.threads * mlp * LINE / (s.delay_ns + lat)
            }
        }
    }

    /// Convenience: single stream of `threads` threads from `socket`
    /// hammering one node. Returns (bandwidth GB/s, latency ns).
    pub fn drive(
        &self,
        socket: usize,
        node: NodeId,
        pattern: Pattern,
        threads: f64,
        delay_ns: f64,
    ) -> (f64, f64) {
        let sol = self.solve_traffic(&[Stream {
            socket,
            node_weights: vec![(node, 1.0)],
            pattern,
            threads,
            delay_ns,
        }]);
        (sol.streams[0].bw_gbs, sol.streams[0].latency_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::{system_a, system_b, system_c};

    #[test]
    fn node_lookup_roles() {
        let sys = system_a();
        let l0 = sys.node_of(0, MemKind::Ldram).unwrap();
        let r0 = sys.node_of(0, MemKind::Rdram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        assert_ne!(l0, r0);
        // From socket 1 the roles swap.
        assert_eq!(sys.node_of(1, MemKind::Ldram).unwrap(), r0);
        assert_eq!(sys.node_of(1, MemKind::Rdram).unwrap(), l0);
        assert_eq!(sys.kind_from(0, cxl), MemKind::Cxl);
        assert_eq!(sys.kind_from(0, r0), MemKind::Rdram);
    }

    #[test]
    fn idle_latency_ordering_ldram_rdram_cxl() {
        // Fig 2: LDRAM < RDRAM < CXL on every system, both patterns.
        for sys in [system_a(), system_b(), system_c()] {
            for p in [Pattern::Sequential, Pattern::Random] {
                let s = 0;
                let l = sys.idle_latency(s, sys.node_of(s, MemKind::Ldram).unwrap(), p);
                let r = sys.idle_latency(s, sys.node_of(s, MemKind::Rdram).unwrap(), p);
                let c = sys.idle_latency(s, sys.node_of(s, MemKind::Cxl).unwrap(), p);
                assert!(l < r && r < c, "{} {:?}: {l} {r} {c}", sys.name, p);
            }
        }
    }

    #[test]
    fn cxl_like_two_hop_numa() {
        // §III: CXL latency ≈ two hops of NUMA distance.
        let sys = system_a();
        let s = 1; // socket the CXL card hangs off
        let p = Pattern::Sequential;
        let l = sys.idle_latency(s, sys.node_of(s, MemKind::Ldram).unwrap(), p);
        let r = sys.idle_latency(s, sys.node_of(s, MemKind::Rdram).unwrap(), p);
        let c = sys.idle_latency(s, sys.node_of(s, MemKind::Cxl).unwrap(), p);
        let hop = r - l;
        let hops = (c - l) / hop;
        assert!(
            (1.5..=3.0).contains(&hops),
            "CXL distance should be ~2 NUMA hops, got {hops:.2}"
        );
    }

    #[test]
    fn bandwidth_saturates_with_threads() {
        let sys = system_b();
        let s = 0;
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        let (bw4, _) = sys.drive(s, cxl, Pattern::Sequential, 4.0, 0.0);
        let (bw8, _) = sys.drive(s, cxl, Pattern::Sequential, 8.0, 0.0);
        let (bw32, _) = sys.drive(s, cxl, Pattern::Sequential, 32.0, 0.0);
        assert!(bw8 <= sys.nodes[cxl].device.peak_bw_gbs * 1.01);
        // CXL saturates early: 8→32 threads gains <10%.
        assert!(bw32 < bw8 * 1.10, "bw8={bw8} bw32={bw32}");
        assert!(bw4 < bw8 * 1.05 || bw8 > 0.8 * sys.nodes[cxl].device.peak_bw_gbs);
    }

    #[test]
    fn ldram_scales_further_than_cxl() {
        let sys = system_b();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        // Thread count where the node first reaches 95% of its plateau.
        let sat = |node| {
            let peak = (1..=52)
                .map(|t| sys.drive(s, node, Pattern::Sequential, t as f64, 0.0).0)
                .fold(0.0f64, f64::max);
            (1..=52)
                .find(|&t| {
                    sys.drive(s, node, Pattern::Sequential, t as f64, 0.0).0 >= 0.95 * peak
                })
                .unwrap_or(52)
        };
        let sat_cxl = sat(cxl);
        let sat_ld = sat(ld);
        assert!(
            sat_cxl <= 8 && sat_ld >= 2 * sat_cxl,
            "sat_cxl={sat_cxl} sat_ld={sat_ld}"
        );
    }

    #[test]
    fn loaded_latency_grows_with_injection() {
        let sys = system_c();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let (_bw_hi, lat_hi) = sys.drive(s, ld, Pattern::Sequential, 32.0, 0.0);
        let (_bw_lo, lat_lo) = sys.drive(s, ld, Pattern::Sequential, 32.0, 80_000.0);
        assert!(lat_hi > 1.5 * lat_lo, "lat_hi={lat_hi} lat_lo={lat_lo}");
        // At 80µs injection delay latency is near idle.
        let idle = sys.idle_latency(s, ld, Pattern::Sequential);
        assert!((lat_lo - idle).abs() / idle < 0.15);
    }

    #[test]
    fn under_load_dram_latency_approaches_cxl() {
        // §III "performance under load": near peak bandwidth, LDRAM and
        // RDRAM latencies reach the CXL-under-load band.
        let sys = system_c();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        let (_, lat_ld_loaded) = sys.drive(s, ld, Pattern::Sequential, 64.0, 0.0);
        let lat_cxl_idle = sys.idle_latency(s, cxl, Pattern::Sequential);
        assert!(
            lat_ld_loaded > lat_cxl_idle,
            "loaded LDRAM {lat_ld_loaded} should exceed idle CXL {lat_cxl_idle}"
        );
    }

    #[test]
    fn interleave_bottlenecked_by_slowest_node() {
        // A 50/50 LDRAM+CXL interleaved stream cannot exceed 2× the CXL
        // plateau no matter how many threads drive it.
        let sys = system_a();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        let sol = sys.solve_traffic(&[Stream {
            socket: s,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            pattern: Pattern::Sequential,
            threads: 32.0,
            delay_ns: 0.0,
        }]);
        let cxl_peak = sys.nodes[cxl].device.peak_bw_gbs;
        assert!(sol.streams[0].bw_gbs <= 2.0 * cxl_peak * 1.02);
        assert!(sol.node_rho[cxl] > 0.9);
    }

    #[test]
    fn two_streams_share_a_node() {
        let sys = system_b();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let mk = |threads: f64| Stream {
            socket: 0,
            node_weights: vec![(ld, 1.0)],
            pattern: Pattern::Sequential,
            threads,
            delay_ns: 0.0,
        };
        let alone = sys.solve_traffic(&[mk(26.0)]).streams[0].bw_gbs;
        let shared = sys.solve_traffic(&[mk(26.0), mk(26.0)]);
        let each = shared.streams[0].bw_gbs;
        // Sharing halves per-stream bandwidth near saturation (±25%).
        assert!(each < alone, "each={each} alone={alone}");
        let total = shared.streams[0].bw_gbs + shared.streams[1].bw_gbs;
        assert!(total <= sys.nodes[ld].device.peak_bw_gbs * 1.02);
    }
}
