//! System model: sockets, NUMA nodes, interconnects, and the closed-loop
//! traffic solver that turns "n threads accessing these nodes with this
//! pattern" into achieved bandwidth + observed latency.
//!
//! The solver is the analytical heart of the reproduction: every figure in
//! §III (Figs 2–4), the HPC engine (§V) and the LLM transfer model (§IV)
//! are built on `solve_traffic`.
//!
//! Two implementations coexist:
//!
//! - [`System::solve_traffic`] — the production path: loop-invariant
//!   per-(stream, node) quantities (hop latencies, issue rates, caps,
//!   concentrated flags) are hoisted into a reusable thread-local
//!   [`SolverScratch`], the damped fixed-point iteration adapts its step
//!   size and exits on a residual test, and solutions are memoized on a
//!   *quantized* (system, stream-set) descriptor so sweeps that re-pose
//!   the same scenario — exactly, or within float noise of it (Fig 3/4
//!   grids, saturation searches, FlexGen policy search, scenario fleets)
//!   — reuse them.
//! - [`System::solve_traffic_reference`] — the seed's fixed-damping loop,
//!   kept verbatim as the golden-parity oracle and the `cxlmem bench`
//!   baseline. [`crate::perf::with_reference`] routes `solve_traffic`
//!   here for before/after measurements.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::OnceLock;

use crate::util::metrics;

use super::device::{MemDevice, MemKind, Pattern, LINE, RHO_MAX};
use super::link::{Link, Path};

/// Index of a NUMA node within a `System`.
pub type NodeId = usize;

/// One NUMA node: a memory device attached at some socket.
#[derive(Clone, Debug)]
pub struct Node {
    pub device: MemDevice,
    /// Socket the device is attached to (LDRAM/RDRAM: their socket;
    /// CXL: socket holding the card's PCIe root port).
    pub socket: usize,
}

/// A whole evaluation platform (one of the paper's systems A/B/C).
#[derive(Clone, Debug)]
pub struct System {
    pub name: String,
    pub description: String,
    pub sockets: usize,
    pub cores_per_socket: usize,
    /// NUMA nodes; by convention node `s` is socket `s`'s DDR pool and
    /// CXL/NVMe nodes follow.
    pub nodes: Vec<Node>,
    /// Inter-socket fabric (xGMI / UPI).
    pub fabric: Link,
    /// PCIe link between CPU root port and the CXL card.
    pub cxl_link: Link,
    /// PCIe link to the GPU, if the platform has one (system A's A10).
    pub gpu_link: Option<Link>,
}

/// One traffic stream presented to the solver.
#[derive(Clone, Debug)]
pub struct Stream {
    /// Socket whose cores issue the accesses.
    pub socket: usize,
    /// Distribution of accesses over nodes; weights must sum to ~1.
    pub node_weights: Vec<(NodeId, f64)>,
    pub pattern: Pattern,
    /// Number of threads driving this stream.
    pub threads: f64,
    /// Additional per-access injection delay (ns) — MLC's load knob;
    /// 0 = as fast as possible.
    pub delay_ns: f64,
}

/// Solver output for one stream.
#[derive(Clone, Debug)]
pub struct StreamResult {
    /// Achieved bandwidth (GB/s).
    pub bw_gbs: f64,
    /// Average observed access latency (ns), including queueing and hops.
    pub latency_ns: f64,
}

/// Solver output.
#[derive(Clone, Debug)]
pub struct TrafficSolution {
    pub streams: Vec<StreamResult>,
    /// Per-node utilization (0..1) and per-node achieved bandwidth.
    pub node_rho: Vec<f64>,
    pub node_bw_gbs: Vec<f64>,
}

/// One precomputed (stream, node) interaction: everything about the pair
/// that does not change across solver iterations.
#[derive(Clone, Copy, Debug, Default)]
struct Touch {
    node: usize,
    /// Access weight (only weights > 0 are materialized).
    w: f64,
    /// Multiplier on the node's queueing delay in the stream's latency:
    /// `w * concentrated_rand_factor` for concentrated random streams,
    /// plain `w` otherwise.
    lat_coeff: f64,
    /// Constant latency contribution: `lat_coeff * idle + w * hop`.
    lat_base: f64,
    /// Node queue model parameters, copied out of the device.
    queue_ns: f64,
    queue_cap_ns: f64,
}

/// Per-stream hoisted issue model.
#[derive(Clone, Copy, Debug)]
enum IssueModel {
    /// Sequential streams are issue-rate-bound: offered bandwidth is a
    /// constant, independent of latency.
    Seq { demand: f64 },
    /// Random streams are latency-bound: `coeff / (delay + lat)`.
    Rand { coeff: f64, delay: f64 },
}

/// Reusable solver workspace: one per thread, allocation-free after the
/// first solve of a given size.
#[derive(Default)]
pub struct SolverScratch {
    touches: Vec<Touch>,
    /// Offsets into `touches`, one per stream plus a final sentinel.
    touch_start: Vec<usize>,
    issue: Vec<IssueModel>,
    caps: Vec<f64>,
    cap_rho: Vec<f64>,
    rho: Vec<f64>,
    d_i: Vec<f64>,
    b_i: Vec<f64>,
    target: Vec<f64>,
    demand: Vec<f64>,
    served: Vec<f64>,
    lat_out: Vec<f64>,
}

/// Memoization key: *quantized* stream descriptors plus a fingerprint of
/// the system calibration. Quantized admission coalesces near-identical
/// descriptors — sweeps that re-pose the same scenario with float noise
/// (a weight computed as `c/total` vs. its closed form, a thread count
/// through one extra rounding) hit the entry of the first solve instead
/// of missing on a one-ulp difference. The grains below keep the
/// representative's solution within ~1e-8 relative of an exact solve,
/// far inside the golden-parity print tolerance, while real sweep steps
/// (integer threads, percent-level weights) land in distinct buckets.
#[derive(Clone, PartialEq, Eq, Hash)]
struct MemoStream {
    socket: usize,
    sequential: bool,
    threads_q: u64,
    delay_q: u64,
    weights: Vec<(usize, u64)>,
}

/// Absolute admission grain for thread counts (≤ ~1e-8 relative at the
/// paper's 1–64 thread range).
const MEMO_THREADS_GRAIN: f64 = 1e-6;
/// Absolute admission grain for injection delay (ns).
const MEMO_DELAY_GRAIN: f64 = 1e-6;
/// Absolute admission grain for node weights (weights live in [0, 1]).
const MEMO_WEIGHT_GRAIN: f64 = 1e-9;

/// Bucket a non-negative descriptor value for memo admission. Values the
/// grain cannot represent (non-finite, astronomically large) fall back to
/// the exact bit pattern, which can only split buckets, never merge them.
#[inline]
fn memo_quantize(x: f64, grain: f64) -> u64 {
    let q = (x / grain).round();
    if q.is_finite() && q.abs() < 9.0e18 {
        (q as i64) as u64
    } else {
        x.to_bits()
    }
}

/// Snap a descriptor value to its bucket representative. Paired with
/// [`memo_quantize`]: every member of a bucket snaps to the same value,
/// so the solution cached for (and computed from) a bucket is a pure
/// function of the bucket — results never depend on which member was
/// solved first, keeping batch output byte-identical at any `--jobs`.
#[inline]
fn memo_snap(x: f64, grain: f64) -> f64 {
    let q = (x / grain).round();
    if q.is_finite() && q.abs() < 9.0e18 {
        q * grain
    } else {
        x
    }
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct MemoKey {
    fingerprint: u64,
    streams: Vec<MemoStream>,
}

/// Bound on cached solutions per thread before the cache is reset.
const MEMO_CAP: usize = 8192;

thread_local! {
    static SCRATCH: RefCell<SolverScratch> = RefCell::new(SolverScratch::default());
    static MEMO: RefCell<HashMap<MemoKey, TrafficSolution>> = RefCell::new(HashMap::new());
}

/// Registry handles for the memo-cache counters, resolved once per
/// process. Only the memoized path (never the reference or
/// memo-disabled branches of [`System::solve_traffic`]) touches these.
struct MemoMetrics {
    hits: &'static metrics::Counter,
    misses: &'static metrics::Counter,
    admissions: &'static metrics::Counter,
}

fn memo_metrics() -> &'static MemoMetrics {
    static M: OnceLock<MemoMetrics> = OnceLock::new();
    M.get_or_init(|| MemoMetrics {
        hits: metrics::counter("solver.memo.hits"),
        misses: metrics::counter("solver.memo.misses"),
        admissions: metrics::counter("solver.memo.admissions"),
    })
}

#[inline]
fn fnv1a(h: &mut u64, x: u64) {
    for b in x.to_le_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

impl System {
    /// Nodes of a given kind visible from `socket` (e.g. "the LDRAM node").
    pub fn node_of(&self, socket: usize, kind: MemKind) -> Option<NodeId> {
        match kind {
            MemKind::Ldram => self
                .nodes
                .iter()
                .position(|n| n.device.kind == MemKind::Ldram && n.socket == socket),
            MemKind::Rdram => self
                .nodes
                .iter()
                .position(|n| n.device.kind == MemKind::Ldram && n.socket != socket),
            other => self.nodes.iter().position(|n| n.device.kind == other),
        }
    }

    /// Kind of `node` as seen from `socket` (the other socket's DDR pool
    /// is RDRAM from here).
    pub fn kind_from(&self, socket: usize, node: NodeId) -> MemKind {
        let n = &self.nodes[node];
        match n.device.kind {
            MemKind::Ldram if n.socket != socket => MemKind::Rdram,
            k => k,
        }
    }

    /// Interconnect path from a core on `socket` to `node`.
    /// DDR on same socket: direct. DDR on other socket: fabric.
    /// CXL: fabric first if the card hangs off the other socket.
    /// (The CXL PCIe+controller latency itself is part of the device's
    /// calibrated idle latency, since Fig 2 measures it from the near
    /// socket.)
    pub fn path(&self, socket: usize, node: NodeId) -> Path {
        let n = &self.nodes[node];
        let mut p = Path::direct();
        if n.socket != socket {
            p = p.then(self.fabric);
        }
        p
    }

    /// Hop latency of [`System::path`] without materializing the path
    /// (the solver's per-iteration paths are all 0-or-1 fabric hops).
    #[inline]
    fn hop_ns(&self, socket: usize, node: NodeId) -> f64 {
        if self.nodes[node].socket != socket {
            self.fabric.hop_ns
        } else {
            0.0
        }
    }

    /// Bandwidth clamp of [`System::path`] without materializing it.
    #[inline]
    fn hop_bw_gbs(&self, socket: usize, node: NodeId) -> f64 {
        if self.nodes[node].socket != socket {
            self.fabric.bw_gbs
        } else {
            f64::INFINITY
        }
    }

    /// Unloaded latency from `socket` to `node` (Fig 2's quantity).
    pub fn idle_latency(&self, socket: usize, node: NodeId, pattern: Pattern) -> f64 {
        self.nodes[node].device.idle.get(pattern) + self.path(socket, node).latency_ns()
    }

    /// Peak bandwidth reachable from `socket` to `node`: device plateau
    /// clamped by any interconnect on the path.
    pub fn eff_peak_bw(&self, socket: usize, node: NodeId) -> f64 {
        self.nodes[node]
            .device
            .peak_bw_gbs
            .min(self.path(socket, node).bw_gbs())
    }

    /// Closed-loop fixed point: each stream's threads keep `mlp` lines
    /// outstanding; achieved per-stream bandwidth, per-node queueing
    /// latency, and per-node capacity are mutually consistent.
    ///
    /// Per iteration:
    /// 1. *demand*  D_s = threads_s · mlp_s · LINE / (delay_s + lat_s)
    /// 2. node demand D_i = Σ_s D_s · w_si ; ρ_i = D_i / cap_i
    /// 3. saturated nodes (ρ_i > RHO_MAX) throttle every stream that
    ///    touches them proportionally (backpressure), so served node
    ///    bandwidth never exceeds RHO_MAX · cap_i *inside* the loop —
    ///    which keeps the solution monotone in thread count.
    /// 4. lat_s from ρ via each device's bounded-queue latency model.
    ///
    /// This entry point dispatches to the adaptive, workspace-backed,
    /// memoized implementation; under [`crate::perf::with_reference`] it
    /// runs the seed's fixed-damping loop instead.
    pub fn solve_traffic(&self, streams: &[Stream]) -> TrafficSolution {
        if crate::perf::reference_enabled() {
            return self.solve_traffic_reference(streams);
        }
        if !crate::perf::memo_enabled() {
            return SCRATCH.with(|s| self.solve_adaptive(streams, &mut s.borrow_mut()));
        }
        let m = memo_metrics();
        // Chaos hook (`solver.memo`, fixed key): a `delay` rule
        // simulates a slow memoized solve path end to end (probe, snap,
        // adaptive solve, admission) without touching its results.
        crate::util::fault::point("solver.memo", "solve_traffic");
        let key = self.memo_key(streams);
        if let Some(hit) = MEMO.with(|c| c.borrow().get(&key).cloned()) {
            m.hits.inc();
            return hit;
        }
        m.misses.inc();
        // Solve the bucket *representative*, not the exact input: any
        // member of a quantized bucket then computes (and caches) the
        // identical solution, independent of solve order or sharding.
        let snapped = Self::snap_streams(streams);
        let sol = SCRATCH.with(|s| self.solve_adaptive(&snapped, &mut s.borrow_mut()));
        MEMO.with(|c| {
            let mut cache = c.borrow_mut();
            if cache.len() >= MEMO_CAP {
                cache.clear();
            }
            cache.insert(key, sol.clone());
        });
        m.admissions.inc();
        sol
    }

    /// The bucket-representative descriptors for [`System::solve_traffic`]'s
    /// memoized path (see [`memo_snap`]).
    fn snap_streams(streams: &[Stream]) -> Vec<Stream> {
        streams
            .iter()
            .map(|s| Stream {
                socket: s.socket,
                node_weights: s
                    .node_weights
                    .iter()
                    .map(|&(n, w)| (n, memo_snap(w, MEMO_WEIGHT_GRAIN)))
                    .collect(),
                pattern: s.pattern,
                threads: memo_snap(s.threads, MEMO_THREADS_GRAIN),
                delay_ns: memo_snap(s.delay_ns, MEMO_DELAY_GRAIN),
            })
            .collect()
    }

    /// The seed's solver, kept verbatim: fixed 0.35 damping, damped-delta
    /// exit at 1e-7 after 10 iterations, 400-iteration cap, per-iteration
    /// allocation. Serves as the `cxlmem bench` baseline and the loose
    /// end of the golden-parity comparison.
    pub fn solve_traffic_reference(&self, streams: &[Stream]) -> TrafficSolution {
        self.solve_reference_inner(streams, 1e-7, 10, 400)
    }

    /// The reference iteration run to a much tighter exit (damped delta
    /// 1e-12), leaving it within ~1e-11 of the true fixed point — the
    /// strict oracle the golden-parity tests compare the adaptive solver
    /// against.
    pub fn solve_traffic_converged_reference(&self, streams: &[Stream]) -> TrafficSolution {
        self.solve_reference_inner(streams, 1e-12, 10, 4000)
    }

    fn solve_reference_inner(
        &self,
        streams: &[Stream],
        exit_delta: f64,
        min_iters: usize,
        max_iters: usize,
    ) -> TrafficSolution {
        let nn = self.nodes.len();
        let caps: Vec<f64> = (0..nn).map(|i| self.node_cap(i, streams)).collect();
        let mut rho = vec![0.0f64; nn];
        let mut stream_bw = vec![0.0f64; streams.len()];
        let mut lat_out = vec![0.0f64; streams.len()];
        let mut node_bw = vec![0.0f64; nn];

        for iter in 0..max_iters {
            // 1. unthrottled demand under current utilization estimate
            let mut demand: Vec<f64> = Vec::with_capacity(streams.len());
            for (si, s) in streams.iter().enumerate() {
                let lat = self.stream_latency(s, &rho);
                lat_out[si] = lat;
                demand.push(self.stream_offered(s, lat));
            }
            // 2. node demand
            let mut d_i = vec![0.0f64; nn];
            for (s, &d) in streams.iter().zip(demand.iter()) {
                for &(node, w) in &s.node_weights {
                    d_i[node] += d * w;
                }
            }
            // 3. backpressure throttle: a stream runs at the rate of its
            //    most-congested node.
            let mut served: Vec<f64> = demand.clone();
            for (si, s) in streams.iter().enumerate() {
                let mut scale: f64 = 1.0;
                for &(node, w) in &s.node_weights {
                    if w > 0.0 && d_i[node] > caps[node] * RHO_MAX && d_i[node] > 0.0 {
                        scale = scale.min(caps[node] * RHO_MAX / d_i[node]);
                    }
                }
                served[si] = demand[si] * scale;
            }
            // served node bandwidth + new utilization estimate
            let mut b_i = vec![0.0f64; nn];
            for (s, &b) in streams.iter().zip(served.iter()) {
                for &(node, w) in &s.node_weights {
                    b_i[node] += b * w;
                }
            }
            // Utilization for the *latency* model uses demand (queues fill
            // when demand exceeds service), clamped into [0, 1].
            let mut max_delta = 0.0f64;
            for i in 0..nn {
                let target = if caps[i] > 0.0 {
                    (d_i[i] / caps[i]).min(1.0)
                } else {
                    0.0
                };
                let new = 0.35 * target + 0.65 * rho[i]; // damped update
                max_delta = max_delta.max((new - rho[i]).abs());
                rho[i] = new;
            }
            stream_bw = served;
            node_bw = b_i;
            if max_delta < exit_delta && iter > min_iters {
                break;
            }
        }

        TrafficSolution {
            streams: streams
                .iter()
                .enumerate()
                .map(|(si, _)| StreamResult {
                    bw_gbs: stream_bw[si],
                    latency_ns: lat_out[si],
                })
                .collect(),
            node_rho: rho,
            node_bw_gbs: node_bw,
        }
    }

    /// Hoist every loop-invariant (stream, node) quantity into `ws`.
    fn prepare_workspace(&self, streams: &[Stream], ws: &mut SolverScratch) {
        let nn = self.nodes.len();
        ws.touches.clear();
        ws.touch_start.clear();
        ws.issue.clear();

        ws.caps.clear();
        ws.caps.extend(self.nodes.iter().map(|n| n.device.peak_bw_gbs));
        for s in streams {
            for &(node, w) in &s.node_weights {
                if w > 0.0 {
                    let clamp = self.hop_bw_gbs(s.socket, node);
                    if clamp < ws.caps[node] {
                        ws.caps[node] = clamp;
                    }
                }
            }
        }
        ws.cap_rho.clear();
        ws.cap_rho.extend(ws.caps.iter().map(|&c| c * RHO_MAX));

        for s in streams {
            ws.touch_start.push(ws.touches.len());
            let concentrated = s
                .node_weights
                .iter()
                .filter(|&&(_, w)| w > 1e-9)
                .count()
                <= 1;
            for &(node, w) in &s.node_weights {
                if w <= 0.0 {
                    continue;
                }
                let dev = &self.nodes[node].device;
                let hop = self.hop_ns(s.socket, node);
                // HPC observation 3: a *concentrated* random stream on one
                // node benefits from row-buffer locality / device caching.
                let factor = if s.pattern == Pattern::Random && concentrated {
                    dev.concentrated_rand_factor
                } else {
                    1.0
                };
                let lat_coeff = w * factor;
                ws.touches.push(Touch {
                    node,
                    w,
                    lat_coeff,
                    lat_base: lat_coeff * dev.idle.get(s.pattern) + w * hop,
                    queue_ns: dev.queue_ns,
                    queue_cap_ns: dev.queue_cap_ns,
                });
            }
            ws.issue.push(match s.pattern {
                Pattern::Sequential => {
                    // Average per-line issue time across the node mix —
                    // latency-independent, so the offered bandwidth is a
                    // per-call constant.
                    let mut t_line = s.delay_ns;
                    for &(node, w) in &s.node_weights {
                        if w <= 0.0 {
                            continue;
                        }
                        let dev = &self.nodes[node].device;
                        let hop = self.hop_ns(s.socket, node);
                        let rate =
                            dev.stream_rate_gbs * dev.idle.seq_ns / (dev.idle.seq_ns + hop);
                        t_line += w * LINE / rate;
                    }
                    IssueModel::Seq {
                        demand: s.threads * LINE / t_line,
                    }
                }
                Pattern::Random => {
                    let mut mlp = 0.0;
                    for &(node, w) in &s.node_weights {
                        mlp += w * self.nodes[node].device.mlp_rand;
                    }
                    IssueModel::Rand {
                        coeff: s.threads * mlp * LINE,
                        delay: s.delay_ns,
                    }
                }
            });
        }
        ws.touch_start.push(ws.touches.len());

        ws.rho.clear();
        ws.rho.resize(nn, 0.0);
        ws.d_i.clear();
        ws.d_i.resize(nn, 0.0);
        ws.b_i.clear();
        ws.b_i.resize(nn, 0.0);
        ws.target.clear();
        ws.target.resize(nn, 0.0);
        ws.demand.clear();
        ws.demand.resize(streams.len(), 0.0);
        ws.served.clear();
        ws.served.resize(streams.len(), 0.0);
        ws.lat_out.clear();
        ws.lat_out.resize(streams.len(), 0.0);
    }

    /// The production fixed-point iteration: same update map as the
    /// reference, but allocation-free, with hoisted invariants, an
    /// adaptive damping factor, and a residual-based convergence exit
    /// (max |target − ρ| < 1e-10) that leaves the answer strictly closer
    /// to the fixed point than the reference's exit does.
    fn solve_adaptive(&self, streams: &[Stream], ws: &mut SolverScratch) -> TrafficSolution {
        let nn = self.nodes.len();
        self.prepare_workspace(streams, ws);

        let mut alpha = 0.35f64;
        let mut prev_residual = f64::INFINITY;
        for iter in 0..600 {
            // 1. per-stream latency + offered demand under current rho
            for si in 0..streams.len() {
                let mut lat = 0.0;
                for t in &ws.touches[ws.touch_start[si]..ws.touch_start[si + 1]] {
                    let rho = ws.rho[t.node].clamp(0.0, RHO_MAX);
                    let q = (t.queue_ns * rho / (1.0 - rho)).min(t.queue_cap_ns);
                    lat += t.lat_base + t.lat_coeff * q;
                }
                ws.lat_out[si] = lat;
                ws.demand[si] = match ws.issue[si] {
                    IssueModel::Seq { demand } => demand,
                    IssueModel::Rand { coeff, delay } => coeff / (delay + lat),
                };
            }
            // 2. node demand
            for d in ws.d_i.iter_mut() {
                *d = 0.0;
            }
            for si in 0..streams.len() {
                let d = ws.demand[si];
                for t in &ws.touches[ws.touch_start[si]..ws.touch_start[si + 1]] {
                    ws.d_i[t.node] += d * t.w;
                }
            }
            // 3. backpressure throttle
            for si in 0..streams.len() {
                let mut scale: f64 = 1.0;
                for t in &ws.touches[ws.touch_start[si]..ws.touch_start[si + 1]] {
                    let d_node = ws.d_i[t.node];
                    if d_node > ws.cap_rho[t.node] && d_node > 0.0 {
                        scale = scale.min(ws.cap_rho[t.node] / d_node);
                    }
                }
                ws.served[si] = ws.demand[si] * scale;
            }
            for b in ws.b_i.iter_mut() {
                *b = 0.0;
            }
            for si in 0..streams.len() {
                let b = ws.served[si];
                for t in &ws.touches[ws.touch_start[si]..ws.touch_start[si + 1]] {
                    ws.b_i[t.node] += b * t.w;
                }
            }
            // 4. residual + adaptively damped update
            let mut residual = 0.0f64;
            for i in 0..nn {
                let target = if ws.caps[i] > 0.0 {
                    (ws.d_i[i] / ws.caps[i]).min(1.0)
                } else {
                    0.0
                };
                ws.target[i] = target;
                residual = residual.max((target - ws.rho[i]).abs());
            }
            for i in 0..nn {
                ws.rho[i] += alpha * (ws.target[i] - ws.rho[i]);
            }
            if residual < 1e-10 && iter >= 6 {
                break;
            }
            // Monotone progress → lengthen the step; oscillation → back off.
            if residual < prev_residual * 0.999 {
                alpha = (alpha * 1.3).min(0.9);
            } else {
                alpha = (alpha * 0.5).max(0.2);
            }
            prev_residual = residual;
        }

        TrafficSolution {
            streams: (0..streams.len())
                .map(|si| StreamResult {
                    bw_gbs: ws.served[si],
                    latency_ns: ws.lat_out[si],
                })
                .collect(),
            node_rho: ws.rho.clone(),
            node_bw_gbs: ws.b_i.clone(),
        }
    }

    /// FNV-1a fingerprint of every calibration parameter the solver reads,
    /// so memoized solutions never leak across differently-calibrated
    /// systems that share a name.
    fn solver_fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in self.name.as_bytes() {
            fnv1a(&mut h, *b as u64);
        }
        fnv1a(&mut h, self.sockets as u64);
        fnv1a(&mut h, self.nodes.len() as u64);
        for n in &self.nodes {
            fnv1a(&mut h, n.socket as u64);
            fnv1a(&mut h, n.device.kind.label().len() as u64);
            fnv1a(&mut h, n.device.idle.seq_ns.to_bits());
            fnv1a(&mut h, n.device.idle.rand_ns.to_bits());
            fnv1a(&mut h, n.device.peak_bw_gbs.to_bits());
            fnv1a(&mut h, n.device.queue_ns.to_bits());
            fnv1a(&mut h, n.device.queue_cap_ns.to_bits());
            fnv1a(&mut h, n.device.stream_rate_gbs.to_bits());
            fnv1a(&mut h, n.device.mlp_rand.to_bits());
            fnv1a(&mut h, n.device.concentrated_rand_factor.to_bits());
        }
        fnv1a(&mut h, self.fabric.hop_ns.to_bits());
        fnv1a(&mut h, self.fabric.bw_gbs.to_bits());
        h
    }

    fn memo_key(&self, streams: &[Stream]) -> MemoKey {
        MemoKey {
            fingerprint: self.solver_fingerprint(),
            streams: streams
                .iter()
                .map(|s| MemoStream {
                    socket: s.socket,
                    sequential: s.pattern == Pattern::Sequential,
                    threads_q: memo_quantize(s.threads, MEMO_THREADS_GRAIN),
                    delay_q: memo_quantize(s.delay_ns, MEMO_DELAY_GRAIN),
                    weights: s
                        .node_weights
                        .iter()
                        .map(|&(n, w)| (n, memo_quantize(w, MEMO_WEIGHT_GRAIN)))
                        .collect(),
                })
                .collect(),
        }
    }

    /// Drop this thread's memoized solutions (benchmark hygiene).
    pub fn clear_solver_cache() {
        MEMO.with(|c| c.borrow_mut().clear());
    }

    /// Effective node bandwidth cap given the sockets driving traffic at
    /// it (interconnect clamp uses the weakest path among participants —
    /// conservative and adequate for the paper's single-socket runs).
    fn node_cap(&self, node: NodeId, streams: &[Stream]) -> f64 {
        let mut cap = self.nodes[node].device.peak_bw_gbs;
        for s in streams {
            if s.node_weights.iter().any(|&(n, w)| n == node && w > 0.0) {
                cap = cap.min(self.path(s.socket, node).bw_gbs());
            }
        }
        cap
    }

    /// Average access latency for a stream under node utilizations `rho`.
    fn stream_latency(&self, s: &Stream, rho: &[f64]) -> f64 {
        let concentrated = s
            .node_weights
            .iter()
            .filter(|&&(_, w)| w > 1e-9)
            .count()
            <= 1;
        let mut lat = 0.0;
        for &(node, w) in &s.node_weights {
            if w <= 0.0 {
                continue;
            }
            let dev = &self.nodes[node].device;
            let mut l = dev.latency_at(s.pattern, rho[node]);
            // HPC observation 3: a *concentrated* random stream on one
            // node benefits from row-buffer locality / device caching;
            // spreading the same stream across nodes forfeits it.
            if s.pattern == Pattern::Random && concentrated {
                l *= dev.concentrated_rand_factor;
            }
            lat += w * (l + self.path(s.socket, node).latency_ns());
        }
        lat
    }

    /// Offered (unthrottled) bandwidth of a stream given its observed
    /// access latency.
    ///
    /// Sequential streams are issue-rate-bound: each thread sustains the
    /// device's `stream_rate_gbs` (degraded by fabric hops), independent
    /// of latency — HW prefetchers hide it. Injection delay (MLC's load
    /// knob) stretches the per-line cycle.
    ///
    /// Random streams are latency-bound: `mlp_rand` outstanding lines per
    /// thread against the observed latency.
    fn stream_offered(&self, s: &Stream, lat: f64) -> f64 {
        match s.pattern {
            Pattern::Sequential => {
                // Average per-line issue time across the node mix.
                let mut t_line = s.delay_ns;
                for &(node, w) in &s.node_weights {
                    if w <= 0.0 {
                        continue;
                    }
                    let dev = &self.nodes[node].device;
                    let hop = self.path(s.socket, node).latency_ns();
                    // Fabric hops lower the effective issue rate in
                    // proportion to the lengthened round trip.
                    let rate = dev.stream_rate_gbs * dev.idle.seq_ns / (dev.idle.seq_ns + hop);
                    t_line += w * LINE / rate;
                }
                s.threads * LINE / t_line
            }
            Pattern::Random => {
                let mut mlp = 0.0;
                for &(node, w) in &s.node_weights {
                    mlp += w * self.nodes[node].device.mlp_rand;
                }
                s.threads * mlp * LINE / (s.delay_ns + lat)
            }
        }
    }

    /// Convenience: single stream of `threads` threads from `socket`
    /// hammering one node. Returns (bandwidth GB/s, latency ns).
    pub fn drive(
        &self,
        socket: usize,
        node: NodeId,
        pattern: Pattern,
        threads: f64,
        delay_ns: f64,
    ) -> (f64, f64) {
        let sol = self.solve_traffic(&[Stream {
            socket,
            node_weights: vec![(node, 1.0)],
            pattern,
            threads,
            delay_ns,
        }]);
        (sol.streams[0].bw_gbs, sol.streams[0].latency_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::{system_a, system_b, system_c};

    #[test]
    fn node_lookup_roles() {
        let sys = system_a();
        let l0 = sys.node_of(0, MemKind::Ldram).unwrap();
        let r0 = sys.node_of(0, MemKind::Rdram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        assert_ne!(l0, r0);
        // From socket 1 the roles swap.
        assert_eq!(sys.node_of(1, MemKind::Ldram).unwrap(), r0);
        assert_eq!(sys.node_of(1, MemKind::Rdram).unwrap(), l0);
        assert_eq!(sys.kind_from(0, cxl), MemKind::Cxl);
        assert_eq!(sys.kind_from(0, r0), MemKind::Rdram);
    }

    #[test]
    fn idle_latency_ordering_ldram_rdram_cxl() {
        // Fig 2: LDRAM < RDRAM < CXL on every system, both patterns.
        for sys in [system_a(), system_b(), system_c()] {
            for p in [Pattern::Sequential, Pattern::Random] {
                let s = 0;
                let l = sys.idle_latency(s, sys.node_of(s, MemKind::Ldram).unwrap(), p);
                let r = sys.idle_latency(s, sys.node_of(s, MemKind::Rdram).unwrap(), p);
                let c = sys.idle_latency(s, sys.node_of(s, MemKind::Cxl).unwrap(), p);
                assert!(l < r && r < c, "{} {:?}: {l} {r} {c}", sys.name, p);
            }
        }
    }

    #[test]
    fn cxl_like_two_hop_numa() {
        // §III: CXL latency ≈ two hops of NUMA distance.
        let sys = system_a();
        let s = 1; // socket the CXL card hangs off
        let p = Pattern::Sequential;
        let l = sys.idle_latency(s, sys.node_of(s, MemKind::Ldram).unwrap(), p);
        let r = sys.idle_latency(s, sys.node_of(s, MemKind::Rdram).unwrap(), p);
        let c = sys.idle_latency(s, sys.node_of(s, MemKind::Cxl).unwrap(), p);
        let hop = r - l;
        let hops = (c - l) / hop;
        assert!(
            (1.5..=3.0).contains(&hops),
            "CXL distance should be ~2 NUMA hops, got {hops:.2}"
        );
    }

    #[test]
    fn bandwidth_saturates_with_threads() {
        let sys = system_b();
        let s = 0;
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        let (bw4, _) = sys.drive(s, cxl, Pattern::Sequential, 4.0, 0.0);
        let (bw8, _) = sys.drive(s, cxl, Pattern::Sequential, 8.0, 0.0);
        let (bw32, _) = sys.drive(s, cxl, Pattern::Sequential, 32.0, 0.0);
        assert!(bw8 <= sys.nodes[cxl].device.peak_bw_gbs * 1.01);
        // CXL saturates early: 8→32 threads gains <10%.
        assert!(bw32 < bw8 * 1.10, "bw8={bw8} bw32={bw32}");
        assert!(bw4 < bw8 * 1.05 || bw8 > 0.8 * sys.nodes[cxl].device.peak_bw_gbs);
    }

    #[test]
    fn ldram_scales_further_than_cxl() {
        let sys = system_b();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        // Thread count where the node first reaches 95% of its plateau.
        let sat = |node| {
            let peak = (1..=52)
                .map(|t| sys.drive(s, node, Pattern::Sequential, t as f64, 0.0).0)
                .fold(0.0f64, f64::max);
            (1..=52)
                .find(|&t| {
                    sys.drive(s, node, Pattern::Sequential, t as f64, 0.0).0 >= 0.95 * peak
                })
                .unwrap_or(52)
        };
        let sat_cxl = sat(cxl);
        let sat_ld = sat(ld);
        assert!(
            sat_cxl <= 8 && sat_ld >= 2 * sat_cxl,
            "sat_cxl={sat_cxl} sat_ld={sat_ld}"
        );
    }

    #[test]
    fn loaded_latency_grows_with_injection() {
        let sys = system_c();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let (_bw_hi, lat_hi) = sys.drive(s, ld, Pattern::Sequential, 32.0, 0.0);
        let (_bw_lo, lat_lo) = sys.drive(s, ld, Pattern::Sequential, 32.0, 80_000.0);
        assert!(lat_hi > 1.5 * lat_lo, "lat_hi={lat_hi} lat_lo={lat_lo}");
        // At 80µs injection delay latency is near idle.
        let idle = sys.idle_latency(s, ld, Pattern::Sequential);
        assert!((lat_lo - idle).abs() / idle < 0.15);
    }

    #[test]
    fn under_load_dram_latency_approaches_cxl() {
        // §III "performance under load": near peak bandwidth, LDRAM and
        // RDRAM latencies reach the CXL-under-load band.
        let sys = system_c();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        let (_, lat_ld_loaded) = sys.drive(s, ld, Pattern::Sequential, 64.0, 0.0);
        let lat_cxl_idle = sys.idle_latency(s, cxl, Pattern::Sequential);
        assert!(
            lat_ld_loaded > lat_cxl_idle,
            "loaded LDRAM {lat_ld_loaded} should exceed idle CXL {lat_cxl_idle}"
        );
    }

    #[test]
    fn interleave_bottlenecked_by_slowest_node() {
        // A 50/50 LDRAM+CXL interleaved stream cannot exceed 2× the CXL
        // plateau no matter how many threads drive it.
        let sys = system_a();
        let s = 0;
        let ld = sys.node_of(s, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(s, MemKind::Cxl).unwrap();
        let sol = sys.solve_traffic(&[Stream {
            socket: s,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            pattern: Pattern::Sequential,
            threads: 32.0,
            delay_ns: 0.0,
        }]);
        let cxl_peak = sys.nodes[cxl].device.peak_bw_gbs;
        assert!(sol.streams[0].bw_gbs <= 2.0 * cxl_peak * 1.02);
        assert!(sol.node_rho[cxl] > 0.9);
    }

    #[test]
    fn two_streams_share_a_node() {
        let sys = system_b();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let mk = |threads: f64| Stream {
            socket: 0,
            node_weights: vec![(ld, 1.0)],
            pattern: Pattern::Sequential,
            threads,
            delay_ns: 0.0,
        };
        let alone = sys.solve_traffic(&[mk(26.0)]).streams[0].bw_gbs;
        let shared = sys.solve_traffic(&[mk(26.0), mk(26.0)]);
        let each = shared.streams[0].bw_gbs;
        // Sharing halves per-stream bandwidth near saturation (±25%).
        assert!(each < alone, "each={each} alone={alone}");
        let total = shared.streams[0].bw_gbs + shared.streams[1].bw_gbs;
        assert!(total <= sys.nodes[ld].device.peak_bw_gbs * 1.02);
    }

    // ---- optimized-solver specific tests ----

    fn rel_close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(a.abs()).max(1e-12)
    }

    fn assert_solutions_close(a: &TrafficSolution, b: &TrafficSolution, tol: f64) {
        assert_eq!(a.streams.len(), b.streams.len());
        for (x, y) in a.streams.iter().zip(&b.streams) {
            assert!(
                rel_close(x.bw_gbs, y.bw_gbs, tol),
                "bw {} vs {}",
                x.bw_gbs,
                y.bw_gbs
            );
            assert!(
                rel_close(x.latency_ns, y.latency_ns, tol),
                "lat {} vs {}",
                x.latency_ns,
                y.latency_ns
            );
        }
        for (x, y) in a.node_bw_gbs.iter().zip(&b.node_bw_gbs) {
            assert!(rel_close(*x, *y, tol), "node bw {x} vs {y}");
        }
    }

    /// The two ISSUE-named convergence scenarios: the adaptive solver must
    /// land on the same fixed point as the damped reference loop.
    #[test]
    fn adaptive_matches_reference_on_named_scenarios() {
        // Scenario 1: two_streams_share_a_node (system B).
        let sys = system_b();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let mk = |threads: f64| Stream {
            socket: 0,
            node_weights: vec![(ld, 1.0)],
            pattern: Pattern::Sequential,
            threads,
            delay_ns: 0.0,
        };
        let streams = [mk(26.0), mk(26.0)];
        let opt = sys.solve_traffic(&streams);
        let oracle = sys.solve_traffic_converged_reference(&streams);
        assert_solutions_close(&opt, &oracle, 1e-7);
        let loose = sys.solve_traffic_reference(&streams);
        assert_solutions_close(&opt, &loose, 1e-5);

        // Scenario 2: interleave_bottlenecked_by_slowest_node (system A).
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let streams = [Stream {
            socket: 0,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            pattern: Pattern::Sequential,
            threads: 32.0,
            delay_ns: 0.0,
        }];
        let opt = sys.solve_traffic(&streams);
        let oracle = sys.solve_traffic_converged_reference(&streams);
        assert_solutions_close(&opt, &oracle, 1e-7);
        let loose = sys.solve_traffic_reference(&streams);
        assert_solutions_close(&opt, &loose, 1e-5);
    }

    #[test]
    fn adaptive_matches_reference_across_grid() {
        // A broad grid over systems × tiers × patterns × loads.
        for sys in [system_a(), system_b(), system_c()] {
            for kind in [MemKind::Ldram, MemKind::Rdram, MemKind::Cxl] {
                let node = sys.node_of(0, kind).unwrap();
                for pattern in [Pattern::Sequential, Pattern::Random] {
                    for threads in [1.0, 4.0, 16.0, 48.0] {
                        for delay in [0.0, 300.0, 20_000.0] {
                            let streams = [Stream {
                                socket: 0,
                                node_weights: vec![(node, 1.0)],
                                pattern,
                                threads,
                                delay_ns: delay,
                            }];
                            let opt = crate::perf::without_memo(|| sys.solve_traffic(&streams));
                            let oracle = sys.solve_traffic_converged_reference(&streams);
                            assert_solutions_close(&opt, &oracle, 1e-7);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn memoized_solution_is_identical_to_cold() {
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let streams = [Stream {
            socket: 0,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            pattern: Pattern::Random,
            threads: 24.0,
            delay_ns: 0.0,
        }];
        System::clear_solver_cache();
        let cold = sys.solve_traffic(&streams);
        let warm = sys.solve_traffic(&streams);
        assert_eq!(cold.streams[0].bw_gbs.to_bits(), warm.streams[0].bw_gbs.to_bits());
        assert_eq!(
            cold.streams[0].latency_ns.to_bits(),
            warm.streams[0].latency_ns.to_bits()
        );
    }

    #[test]
    fn quantized_admission_coalesces_near_identical_descriptors() {
        // Two descriptors a float-noise apart must share one memo entry
        // (bit-identical results), and the shared answer must still sit
        // within golden-parity tolerance of the strict oracle for *both*.
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mk = |w: f64, threads: f64| {
            [Stream {
                socket: 0,
                node_weights: vec![(ld, w), (cxl, 1.0 - w)],
                pattern: Pattern::Sequential,
                threads,
                delay_ns: 0.0,
            }]
        };
        System::clear_solver_cache();
        let exact = sys.solve_traffic(&mk(0.5, 32.0));
        let noisy_streams = mk(0.5 + 1e-12, 32.0 * (1.0 + 1e-13));
        let noisy = sys.solve_traffic(&noisy_streams);
        assert_eq!(
            exact.streams[0].bw_gbs.to_bits(),
            noisy.streams[0].bw_gbs.to_bits(),
            "near-identical descriptors must hit the same memo entry"
        );
        // Golden-parity guard: the coalesced answer is within 1e-6
        // relative of the noisy descriptor's own converged solution.
        let oracle = sys.solve_traffic_converged_reference(&noisy_streams);
        for (a, b) in noisy.streams.iter().zip(&oracle.streams) {
            assert!(rel_close(a.bw_gbs, b.bw_gbs, 1e-6), "{} vs {}", a.bw_gbs, b.bw_gbs);
            assert!(
                rel_close(a.latency_ns, b.latency_ns, 1e-6),
                "{} vs {}",
                a.latency_ns,
                b.latency_ns
            );
        }
        // Genuinely different descriptors stay in distinct buckets.
        let other = sys.solve_traffic(&mk(0.6, 32.0));
        assert!(
            (other.streams[0].bw_gbs - exact.streams[0].bw_gbs).abs() > 1e-3,
            "distinct scenarios must not collide: {} vs {}",
            other.streams[0].bw_gbs,
            exact.streams[0].bw_gbs
        );
        // Solve ORDER inside a bucket must not matter: the cached answer
        // is computed from the bucket representative, so noisy-first and
        // exact-first runs produce the same bits (batch `--jobs`
        // invariance relies on this).
        System::clear_solver_cache();
        let noisy_first = sys.solve_traffic(&noisy_streams);
        assert_eq!(
            noisy_first.streams[0].bw_gbs.to_bits(),
            exact.streams[0].bw_gbs.to_bits(),
            "bucket solution must not depend on which member is solved first"
        );
    }

    #[test]
    fn memo_key_distinguishes_calibrations() {
        // Same stream on two systems must not collide.
        let a = system_a();
        let b = system_b();
        let ld_a = a.node_of(0, MemKind::Ldram).unwrap();
        let ld_b = b.node_of(0, MemKind::Ldram).unwrap();
        System::clear_solver_cache();
        let (bw_a, _) = a.drive(0, ld_a, Pattern::Sequential, 32.0, 0.0);
        let (bw_b, _) = b.drive(0, ld_b, Pattern::Sequential, 32.0, 0.0);
        assert!((bw_a - bw_b).abs() > 1.0, "distinct systems: {bw_a} vs {bw_b}");
    }

    #[test]
    fn reference_mode_dispatches_seed_loop() {
        let sys = system_c();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let streams = [Stream {
            socket: 0,
            node_weights: vec![(ld, 1.0)],
            pattern: Pattern::Sequential,
            threads: 32.0,
            delay_ns: 0.0,
        }];
        let via_mode = crate::perf::with_reference(|| sys.solve_traffic(&streams));
        let direct = sys.solve_traffic_reference(&streams);
        assert_eq!(
            via_mode.streams[0].bw_gbs.to_bits(),
            direct.streams[0].bw_gbs.to_bits()
        );
    }
}
