//! The paper's three evaluation platforms (Table I), as calibrated model
//! instances.
//!
//! Calibration sources, per system:
//! - Idle latencies: Fig 2 (e.g. CXL A adds ~153 ns over LDRAM sequential,
//!   CXL B adds ~211 ns; CXL ≈ a two-hop NUMA node).
//! - Peak bandwidths: Fig 3 plateaus and §III text (CXL A = 17.1% of
//!   RDRAM A, CXL B = 46.4% of RDRAM B, CXL C close to RDRAM C;
//!   intro: CXL peak spans 9.8%–80.3% of LDRAM peak across vendors).
//! - Queueing knees: Fig 4 (loaded LDRAM/RDRAM latencies on C reach
//!   ~543/600 ns, i.e. the CXL band, near peak bandwidth).
//! - Saturation thread counts: Fig 3 (CXL saturates by ~4–8 threads;
//!   LDRAM/RDRAM at ~28/20 on system B).
//!
//! Spec numbers (DDR5 channel counts, GT/s, GB capacities) come straight
//! from Table I and are reported by `exp table1`.

use super::device::{IdleLatency, MemDevice, MemKind};
use super::link::Link;
use super::system::{Node, System};

const GB: u64 = 1 << 30;

fn ddr(idle_seq: f64, idle_rand: f64, peak: f64, spec: f64, rate: f64, cap_gb: u64) -> MemDevice {
    MemDevice {
        kind: MemKind::Ldram,
        idle: IdleLatency {
            seq_ns: idle_seq,
            rand_ns: idle_rand,
        },
        peak_bw_gbs: peak,
        spec_bw_gbs: spec,
        capacity: cap_gb * GB,
        queue_ns: 9.0,
        queue_cap_ns: 430.0,
        stream_rate_gbs: rate,
        mlp_rand: 12.0,
        concentrated_rand_factor: 0.88,
    }
}

fn cxl(idle_seq: f64, idle_rand: f64, peak: f64, spec: f64, rate: f64, cap_gb: u64) -> MemDevice {
    MemDevice {
        kind: MemKind::Cxl,
        idle: IdleLatency {
            seq_ns: idle_seq,
            rand_ns: idle_rand,
        },
        peak_bw_gbs: peak,
        spec_bw_gbs: spec,
        capacity: cap_gb * GB,
        queue_ns: 6.0,
        queue_cap_ns: 230.0,
        stream_rate_gbs: rate,
        mlp_rand: 10.0,
        // HPC observation 3: the CXL controller optimizes concentrated
        // random streams (row-buffer locality / device-side caching).
        concentrated_rand_factor: 0.55,
    }
}

/// NVMe SSD tier (system A's FlexGen runs). Modeled as a very-high-latency,
/// low-bandwidth "device"; reads go through the page cache via mmap.
fn nvme(cap_gb: u64) -> MemDevice {
    MemDevice {
        kind: MemKind::Nvme,
        idle: IdleLatency {
            seq_ns: 25_000.0,
            rand_ns: 80_000.0,
        },
        peak_bw_gbs: 4.0,
        spec_bw_gbs: 7.0,
        capacity: cap_gb * GB,
        queue_ns: 15_000.0,
        queue_cap_ns: 400_000.0,
        stream_rate_gbs: 1.5,
        mlp_rand: 32.0,
        concentrated_rand_factor: 1.0,
    }
}

/// Names of the shipped device calibrations, in a stable order: the DDR
/// pools and the three vendor CXL cards of Table I, plus the NVMe tier.
pub const DEVICE_PRESETS: &[&str] = &[
    "ddr-a", "ddr-b", "ddr-c", "cxl-a", "cxl-b", "cxl-c", "nvme",
];

/// Look a calibrated device profile up by preset name. These are the
/// exact calibrations the systems below are assembled from, exposed so
/// scenario specs can splice one vendor's card into another topology
/// (e.g. "system A with CXL B's dual... card") as data, not code.
pub fn device_preset(name: &str) -> Option<MemDevice> {
    Some(match name {
        "ddr-a" => ddr(98.0, 112.0, 230.0, 460.8, 8.2, 768),
        "ddr-b" => ddr(112.0, 127.0, 260.0, 307.2, 9.3, 1024),
        "ddr-c" => ddr(110.0, 125.0, 110.0, 307.2, 9.0, 512),
        // Fig 2: +153 ns over LDRAM (seq); rand ≈ 2.1× LDRAM (§V).
        "cxl-a" => cxl(251.0, 235.0, 22.5, 38.4, 7.4, 128),
        // Fig 2: +211 ns over LDRAM (seq). 46.4% of RDRAM peak.
        "cxl-b" => cxl(323.0, 310.0, 51.0, 64.0, 7.9, 64),
        // Dual-channel card: bandwidth close to RDRAM (Fig 3),
        // loaded latency band 400–550 ns (Fig 4c).
        "cxl-c" => cxl(295.0, 280.0, 80.0, 96.8, 7.8, 128),
        "nvme" => nvme(128),
        _ => return None,
    })
}

fn preset(name: &str) -> MemDevice {
    device_preset(name).expect("unknown built-in device preset")
}

/// System A — 2× AMD EPYC 9354 (Genoa, 32c), 12× DDR5-4800 per socket,
/// CXL A: single-channel DDR5-4800 128 GB card on socket 1, PCIe 5.0 x16.
/// NVIDIA A10 (24 GB) on PCIe 4.0 hangs off socket 1 as well.
pub fn system_a() -> System {
    System {
        name: "A".into(),
        description: "2x AMD EPYC 9354 (Genoa) + CXL A (1ch DDR5-4800, 128GB) + A10 GPU".into(),
        sockets: 2,
        cores_per_socket: 32,
        nodes: vec![
            Node {
                device: preset("ddr-a"),
                socket: 0,
            },
            Node {
                device: preset("ddr-a"),
                socket: 1,
            },
            Node {
                device: preset("cxl-a"),
                socket: 1,
            },
            Node {
                device: preset("nvme"),
                socket: 1,
            },
        ],
        fabric: Link::xgmi(),
        cxl_link: Link::pcie5_x16(),
        gpu_link: Some(Link::pcie4_x16()),
    }
}

/// System B — 2× Intel Xeon Platinum 8470 (SPR, 52c), 8× DDR5-4800 per
/// socket, CXL B: single-channel DDR5-8000 64 GB card on socket 1.
pub fn system_b() -> System {
    System {
        name: "B".into(),
        description: "2x Intel Xeon Platinum 8470 (SPR) + CXL B (1ch DDR5-8000, 64GB)".into(),
        sockets: 2,
        cores_per_socket: 52,
        nodes: vec![
            Node {
                device: preset("ddr-b"),
                socket: 0,
            },
            Node {
                device: preset("ddr-b"),
                socket: 1,
            },
            Node {
                device: preset("cxl-b"),
                socket: 1,
            },
        ],
        fabric: Link::upi(),
        cxl_link: Link::pcie5_x16(),
        gpu_link: None,
    }
}

/// System C — 2× Intel Xeon Gold 6438V (SPR, 32c), 8× DDR5-4800 per
/// socket, CXL C: dual-channel DDR5-6200 128 GB card on socket 0.
pub fn system_c() -> System {
    System {
        name: "C".into(),
        description: "2x Intel Xeon Gold 6438V+ (SPR) + CXL C (2ch DDR5-6200, 128GB)".into(),
        sockets: 2,
        cores_per_socket: 32,
        nodes: vec![
            Node {
                device: preset("ddr-c"),
                socket: 0,
            },
            Node {
                device: preset("ddr-c"),
                socket: 1,
            },
            Node {
                device: preset("cxl-c"),
                socket: 0,
            },
        ],
        fabric: Link::upi(),
        cxl_link: Link::pcie5_x16(),
        gpu_link: None,
    }
}

/// All three systems, for sweeps.
pub fn all_systems() -> Vec<System> {
    vec![system_a(), system_b(), system_c()]
}

/// Look a system up by its paper letter.
pub fn by_name(name: &str) -> Option<System> {
    match name.to_ascii_uppercase().as_str() {
        "A" => Some(system_a()),
        "B" => Some(system_b()),
        "C" => Some(system_c()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::device::Pattern;

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("a").unwrap().name, "A");
        assert_eq!(by_name("B").unwrap().name, "B");
        assert!(by_name("X").is_none());
    }

    #[test]
    fn device_presets_resolve_and_match_systems() {
        for name in DEVICE_PRESETS {
            assert!(device_preset(name).is_some(), "{name}");
        }
        assert!(device_preset("cxl-x").is_none());
        // The preset is the exact calibration the system carries.
        let a = system_a();
        let card = device_preset("cxl-a").unwrap();
        let node = a.node_of(0, MemKind::Cxl).unwrap();
        assert_eq!(a.nodes[node].device.peak_bw_gbs, card.peak_bw_gbs);
        assert_eq!(a.nodes[node].device.idle.seq_ns, card.idle.seq_ns);
        let c = system_c();
        let card_c = device_preset("cxl-c").unwrap();
        let node_c = c.node_of(0, MemKind::Cxl).unwrap();
        assert_eq!(c.nodes[node_c].device.capacity, card_c.capacity);
    }

    #[test]
    fn cxl_latency_adders_match_fig2() {
        // CXL A ≈ +153 ns over LDRAM, CXL B ≈ +211 ns (sequential).
        let a = system_a();
        let add_a = a.idle_latency(1, a.node_of(1, MemKind::Cxl).unwrap(), Pattern::Sequential)
            - a.idle_latency(1, a.node_of(1, MemKind::Ldram).unwrap(), Pattern::Sequential);
        assert!((add_a - 153.0).abs() < 10.0, "A adder {add_a}");
        let b = system_b();
        let add_b = b.idle_latency(1, b.node_of(1, MemKind::Cxl).unwrap(), Pattern::Sequential)
            - b.idle_latency(1, b.node_of(1, MemKind::Ldram).unwrap(), Pattern::Sequential);
        assert!((add_b - 211.0).abs() < 10.0, "B adder {add_b}");
    }

    #[test]
    fn cxl_to_rdram_bw_ratios_match_text() {
        // §III: CXL/RDRAM peak bandwidth = 17.1% (A) and 46.4% (B);
        // on C the two are close.
        let a = system_a();
        let ra = a.nodes[a.node_of(0, MemKind::Cxl).unwrap()].device.peak_bw_gbs
            / a.eff_peak_bw(0, a.node_of(0, MemKind::Rdram).unwrap());
        assert!((ra - 0.171).abs() < 0.02, "A ratio {ra}");
        let b = system_b();
        let rb = b.nodes[b.node_of(0, MemKind::Cxl).unwrap()].device.peak_bw_gbs
            / b.eff_peak_bw(0, b.node_of(0, MemKind::Rdram).unwrap());
        assert!((rb - 0.464).abs() < 0.05, "B ratio {rb}");
        let c = system_c();
        let rc = c.nodes[c.node_of(0, MemKind::Cxl).unwrap()].device.peak_bw_gbs
            / c.eff_peak_bw(0, c.node_of(0, MemKind::Rdram).unwrap());
        assert!(rc > 0.7, "C ratio {rc} should be close to RDRAM");
    }

    #[test]
    fn capacities_match_table1() {
        let a = system_a();
        assert_eq!(a.nodes[0].device.capacity, 768 << 30);
        assert_eq!(
            a.nodes[a.node_of(0, MemKind::Cxl).unwrap()].device.capacity,
            128 << 30
        );
        let b = system_b();
        assert_eq!(
            b.nodes[b.node_of(0, MemKind::Cxl).unwrap()].device.capacity,
            64 << 30
        );
    }

    #[test]
    fn only_system_a_has_gpu() {
        assert!(system_a().gpu_link.is_some());
        assert!(system_b().gpu_link.is_none());
        assert!(system_c().gpu_link.is_none());
    }

    #[test]
    fn cxl_attach_socket_matches_paper() {
        // A and B: CXL on socket 1; C: socket 0.
        let a = system_a();
        assert_eq!(a.nodes[a.node_of(0, MemKind::Cxl).unwrap()].socket, 1);
        let c = system_c();
        assert_eq!(c.nodes[c.node_of(0, MemKind::Cxl).unwrap()].socket, 0);
    }
}
