//! Interconnect link models: inter-socket fabric (xGMI / UPI) and PCIe.
//!
//! A link adds a fixed hop latency and clamps bandwidth. Data paths are
//! chains of links ending at a `MemDevice`; the paper's key LLM finding
//! (Fig 5/6) is exactly a path-composition effect: under CXL 1.1 the GPU
//! reaches CXL memory via `GPU –PCIe– CPU –PCIe– CXL`, so the GPU-visible
//! bandwidth is min over both PCIe hops and the latency is the sum.

/// A point-to-point interconnect hop.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// One-way latency added per access (ns).
    pub hop_ns: f64,
    /// Peak payload bandwidth (GB/s).
    pub bw_gbs: f64,
}

impl Link {
    pub fn new(hop_ns: f64, bw_gbs: f64) -> Self {
        Self { hop_ns, bw_gbs }
    }

    /// Inter-socket fabric: AMD xGMI (Genoa) — measured effective numbers.
    pub fn xgmi() -> Self {
        Link::new(80.0, 130.0)
    }

    /// Inter-socket fabric: Intel UPI (SPR).
    pub fn upi() -> Self {
        Link::new(75.0, 110.0)
    }

    /// PCIe 5.0 x16: 32 GT/s · 16 lanes ≈ 63 GB/s raw, ~55 GB/s payload.
    pub fn pcie5_x16() -> Self {
        Link::new(110.0, 55.0)
    }

    /// PCIe 4.0 x16 (the A10 GPU in the paper's system A): 32 GB/s raw,
    /// ~26 GB/s achievable with cudaMemcpy over pinned buffers.
    pub fn pcie4_x16() -> Self {
        Link::new(140.0, 26.0)
    }
}

/// A data path: an ordered chain of links. Bandwidth is the min across
/// hops; latency is the sum of hop latencies.
#[derive(Clone, Debug, Default)]
pub struct Path {
    pub links: Vec<Link>,
}

impl Path {
    pub fn new(links: Vec<Link>) -> Self {
        Self { links }
    }

    pub fn direct() -> Self {
        Self { links: Vec::new() }
    }

    pub fn latency_ns(&self) -> f64 {
        self.links.iter().map(|l| l.hop_ns).sum()
    }

    pub fn bw_gbs(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.bw_gbs)
            .fold(f64::INFINITY, f64::min)
    }

    pub fn then(mut self, link: Link) -> Self {
        self.links.push(link);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_composes_latency_and_bottleneck_bw() {
        // GPU -PCIe4- CPU -PCIe5- CXL: min bandwidth is the GPU link,
        // latency is the sum — the Fig 5/6 mechanism.
        let p = Path::direct().then(Link::pcie4_x16()).then(Link::pcie5_x16());
        assert_eq!(p.latency_ns(), 140.0 + 110.0);
        assert_eq!(p.bw_gbs(), 26.0);
    }

    #[test]
    fn empty_path_is_free() {
        let p = Path::direct();
        assert_eq!(p.latency_ns(), 0.0);
        assert_eq!(p.bw_gbs(), f64::INFINITY);
    }

    #[test]
    fn fabric_links_are_distinct() {
        assert!(Link::xgmi().bw_gbs > Link::upi().bw_gbs);
        assert!(Link::pcie4_x16().bw_gbs < Link::pcie5_x16().bw_gbs);
    }
}
