//! Per-thread performance-mode context shared by the optimized hot paths.
//!
//! Three knobs, all thread-local so parallel experiment workers stay
//! independent:
//!
//! - **reference mode**: when enabled, [`crate::memsim::System::solve_traffic`]
//!   and the tiering epoch loop dispatch to their seed-semantics reference
//!   implementations (fixed damping, O(pages) recomputation, per-call
//!   allocation). Used by the golden-parity tests and by `cxlmem bench` to
//!   record the before/after trajectory in the same run.
//! - **memoization**: lets benchmarks measure the solver cold (cache off)
//!   vs warm (cache on, the default).
//! - **jobs**: inner-sweep parallelism consulted by [`crate::util::par`].
//!   Defaults to 1 so library calls stay single-threaded unless the CLI
//!   (or an outer runner) raises it.
//!
//! [`crate::util::par::par_map`] propagates a snapshot of this context
//! into its worker threads (with `jobs` forced to 1 inside workers to
//! avoid oversubscription).

use std::cell::Cell;

thread_local! {
    static REFERENCE: Cell<bool> = Cell::new(false);
    static MEMO: Cell<bool> = Cell::new(true);
    static JOBS: Cell<usize> = Cell::new(1);
}

/// Snapshot of the context, for propagation into worker threads.
#[derive(Clone, Copy, Debug)]
pub struct Snapshot {
    pub reference: bool,
    pub memo: bool,
}

/// True when hot paths must run their seed-semantics reference versions.
pub fn reference_enabled() -> bool {
    REFERENCE.with(|c| c.get())
}

/// True when the solver may consult/fill its memoization cache.
pub fn memo_enabled() -> bool {
    MEMO.with(|c| c.get())
}

/// Inner-sweep parallelism for the current thread (≥ 1).
pub fn current_jobs() -> usize {
    JOBS.with(|c| c.get()).max(1)
}

/// Set inner-sweep parallelism for the current thread.
pub fn set_jobs(jobs: usize) {
    JOBS.with(|c| c.set(jobs.max(1)));
}

/// A sensible default for `--jobs`: the machine's available parallelism.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Capture the current thread's context.
pub fn snapshot() -> Snapshot {
    Snapshot {
        reference: reference_enabled(),
        memo: memo_enabled(),
    }
}

/// Apply a snapshot on the current thread (worker-side; jobs stays 1).
pub fn apply(snap: Snapshot) {
    REFERENCE.with(|c| c.set(snap.reference));
    MEMO.with(|c| c.set(snap.memo));
}

struct Restore {
    reference: bool,
    memo: bool,
}

impl Drop for Restore {
    fn drop(&mut self) {
        REFERENCE.with(|c| c.set(self.reference));
        MEMO.with(|c| c.set(self.memo));
    }
}

/// Run `f` with reference mode enabled (restored on exit, even on panic).
pub fn with_reference<R>(f: impl FnOnce() -> R) -> R {
    let _restore = Restore {
        reference: REFERENCE.with(|c| c.replace(true)),
        memo: MEMO.with(|c| c.get()),
    };
    f()
}

/// Run `f` with the solver memo cache disabled (restored on exit).
pub fn without_memo<R>(f: impl FnOnce() -> R) -> R {
    let _restore = Restore {
        reference: REFERENCE.with(|c| c.get()),
        memo: MEMO.with(|c| c.replace(false)),
    };
    f()
}

/// Run `f` with inner-sweep parallelism set to `jobs`, restored on exit
/// *including panic unwinds* — callers that temporarily hand a whole
/// `--jobs` budget to one evaluation (the batch runner's single-miss
/// inline path) must not leave the session clamped when it panics.
pub fn with_jobs<R>(jobs: usize, f: impl FnOnce() -> R) -> R {
    struct RestoreJobs(usize);
    impl Drop for RestoreJobs {
        fn drop(&mut self) {
            JOBS.with(|c| c.set(self.0));
        }
    }
    let _restore = RestoreJobs(JOBS.with(|c| c.replace(jobs.max(1))));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        assert!(!reference_enabled());
        assert!(memo_enabled());
        assert!(current_jobs() >= 1);
        assert!(default_jobs() >= 1);
    }

    #[test]
    fn guards_nest_and_restore() {
        assert!(!reference_enabled());
        with_reference(|| {
            assert!(reference_enabled());
            without_memo(|| {
                assert!(reference_enabled());
                assert!(!memo_enabled());
            });
            assert!(memo_enabled());
        });
        assert!(!reference_enabled());
    }

    #[test]
    fn snapshot_roundtrip() {
        let snap = with_reference(snapshot);
        assert!(snap.reference);
        // apply + manual restore
        apply(snap);
        assert!(reference_enabled());
        apply(Snapshot {
            reference: false,
            memo: true,
        });
        assert!(!reference_enabled());
    }

    #[test]
    fn jobs_set_get() {
        set_jobs(0);
        assert_eq!(current_jobs(), 1);
        set_jobs(4);
        assert_eq!(current_jobs(), 4);
        set_jobs(1);
    }

    #[test]
    fn with_jobs_restores_even_on_panic() {
        set_jobs(3);
        with_jobs(8, || assert_eq!(current_jobs(), 8));
        assert_eq!(current_jobs(), 3);
        let unwound = std::panic::catch_unwind(|| {
            with_jobs(16, || panic!("evaluation blew up"));
        });
        assert!(unwound.is_err());
        assert_eq!(current_jobs(), 3, "panic must not leave jobs clamped");
        set_jobs(1);
    }
}
