//! Experiment registry: one driver per paper table/figure.
//!
//! `cxlmem exp <id>` regenerates the corresponding artifact as a text
//! table (or CSV/JSON via `--csv` / `--json`). `cxlmem exp all` runs the
//! whole suite. See DESIGN.md §4 for the experiment index.

pub mod basic;
pub mod drivers;
pub mod hpc;
pub mod llm;
pub mod tiering_exp;

use anyhow::{anyhow, Result};

use crate::report::Report;
use crate::util::par::par_map;

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "fig2", "fig3", "fig4", "assign", "fig5", "fig6", "fig8", "fig9", "fig11",
    "table2", "fig12", "table3", "fig13", "fig14", "fig15a", "fig15b", "fig16", "fig17",
];

/// Run one experiment by id.
pub fn run(id: &str) -> Result<Report> {
    Ok(match id {
        "table1" => basic::table1(),
        "fig2" => basic::fig2(),
        "fig3" => basic::fig3(),
        "fig4" => basic::fig4(),
        "assign" => basic::assign(),
        "fig5" => llm::fig5(),
        "fig6" => llm::fig6(),
        "fig8" => llm::fig8(),
        "fig9" => llm::fig9(),
        "fig11" => llm::fig11(),
        "table2" => llm::table2(),
        "fig12" => llm::fig12(),
        "table3" => hpc::table3(),
        "fig13" => hpc::fig13(),
        "fig14" => hpc::fig14(),
        "fig15a" => hpc::fig15a(),
        "fig15b" => hpc::fig15b(),
        "fig16" => tiering_exp::fig16(),
        "fig17" => tiering_exp::fig17(),
        other => return Err(anyhow!("unknown experiment '{other}'; try one of {ALL:?}")),
    })
}

/// Run a set of experiments concurrently on up to `jobs` OS threads
/// (scoped; no work survives the call). Reports come back in input
/// order. Experiment drivers only share thread-local state (solver
/// scratch + memo cache), so each worker is fully independent; every
/// table is identical to a sequential run. Worker-internal sweeps run
/// with inner parallelism pinned to 1 — outer × inner oversubscription
/// never happens.
pub fn run_all(ids: &[&str], jobs: usize) -> Result<Vec<(String, Report)>> {
    let results = par_map(ids, jobs, |&id| (id.to_string(), run(id)));
    results
        .into_iter()
        .map(|(id, r)| r.map(|rep| (id, rep)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_ids() {
        for id in ALL {
            let r = run(id).unwrap();
            assert!(!r.tables.is_empty(), "{id} produced no tables");
            assert!(
                r.tables.iter().all(|t| !t.rows.is_empty()),
                "{id} has an empty table"
            );
        }
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99").is_err());
    }

    #[test]
    fn run_all_parallel_matches_sequential() {
        // A cheap subset: parallel execution must reproduce the exact
        // tables a sequential run produces, in input order.
        let ids = ["table1", "fig2", "fig6"];
        let par = run_all(&ids, 3).unwrap();
        for (id, report) in &par {
            let seq = run(id).unwrap();
            assert_eq!(report.tables.len(), seq.tables.len(), "{id}");
            for (a, b) in report.tables.iter().zip(&seq.tables) {
                assert_eq!(a.title, b.title);
                assert_eq!(a.rows, b.rows, "{id}");
            }
        }
        assert_eq!(
            par.iter().map(|(id, _)| id.as_str()).collect::<Vec<_>>(),
            ids.to_vec()
        );
    }

    #[test]
    fn run_all_surfaces_errors() {
        assert!(run_all(&["table1", "fig99"], 2).is_err());
    }
}
