//! Experiment drivers for §VI: Figs 16–17 (page migration × placement).
//!
//! The policy×placement grids are embarrassingly parallel — every cell
//! builds its own page state and policy — so both drivers flatten
//! their grid into a cell list and fan it out over
//! [`crate::util::par::par_map_auto`]. Results are reassembled in the
//! sequential order, so tables are byte-identical at any `--jobs`.
//!
//! Trace sharing: all cells of one app observe the *same* epoch stream,
//! so fig16 fetches one immutable `Arc<EpochTrace>` snapshot per app
//! from the process-global [`crate::workloads::trace`] store (generated
//! at most once per process — fleet scenarios with the same key reuse
//! it too; million-page snapshots come back delta-encoded, so they fit
//! the store budget dense traces would blow) and every cell replays it
//! through [`tiering::simulate_trace`], which materializes epochs via a
//! per-cell [`crate::workloads::trace::TraceCursor`]; fig17 shares one
//! constant-histogram trace per workload the same way. Under
//! [`crate::perf::with_reference`] each cell instead seeds its own
//! generator and regenerates the stream per epoch — the seed-semantics
//! baseline `cxlmem bench` records as `exp/fig16(shared trace)`.

use std::sync::Arc;

use crate::mem::oli;
use crate::memsim::{topology, MemKind, Pattern, System};
use crate::report::Report;
use crate::tiering::{
    self, initial_state, AutoNuma, NoBalance, PageState, SimConfig, Tiering08, TieringPolicy, Tpp,
};
use crate::util::par::par_map_auto;
use crate::util::table::{f1, Table};
use crate::workloads::npb::all_hpc_workloads;
use crate::workloads::tiering_apps::{all_apps, AppModel, TraceGen};
use crate::workloads::trace::{self, EpochTrace};

const EPOCHS: usize = 10;

/// Names of the §VI tiering policies, grid order.
pub const POLICY_NAMES: &[&str] = &["NoBalance", "AutoNUMA", "Tiering-0.8", "TPP"];

fn fresh_policies() -> Vec<Box<dyn TieringPolicy>> {
    vec![
        Box::new(NoBalance),
        Box::new(AutoNuma::default()),
        Box::new(Tiering08::default()),
        Box::new(Tpp::default()),
    ]
}

fn policy_by_index(i: usize) -> Box<dyn TieringPolicy> {
    fresh_policies()
        .into_iter()
        .nth(i)
        .expect("policy index out of range")
}

#[allow(clippy::too_many_arguments)]
fn app_sim(
    sys: &System,
    app: &AppModel,
    trace: Option<&Arc<EpochTrace>>,
    interleave: bool,
    policy: &mut dyn TieringPolicy,
    seed: u64,
    epochs: usize,
    threads: usize,
    fast_cap: usize,
) -> tiering::TieringRun {
    let socket = 0;
    let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(socket, MemKind::Cxl).unwrap();
    let mut state = initial_state(app.pages, ld, cxl, fast_cap, interleave);
    let cfg = SimConfig {
        socket,
        threads,
        compute_ns_per_byte: app.compute_ns_per_access / 64.0,
        epochs,
        seed,
    };
    let dep = 0.55;
    let mut run = match trace {
        // Optimized path: replay the app's shared immutable snapshot.
        Some(tr) if !crate::perf::reference_enabled() => tiering::simulate_trace(
            sys,
            &cfg,
            &mut state,
            policy,
            tr,
            move |_| (Pattern::Random, dep),
        ),
        // Reference (and store-less) path: seed semantics — this cell
        // regenerates its own epoch stream.
        _ => {
            let mut gen = TraceGen::new(app.clone(), seed);
            tiering::simulate(
                sys,
                &cfg,
                &mut state,
                policy,
                |_, buf| {
                    gen.epoch_counts_into(buf);
                    gen.drift();
                },
                move |_| (Pattern::Random, dep),
            )
        }
    };
    run.placement = if interleave { "interleave" } else { "first-touch" }.into();
    run
}

/// Fig 16: execution time for BTree/PageRank/Graph500/Silo under
/// {NoBalance, AutoNUMA, Tiering-0.8, TPP} × {first touch, interleave},
/// plus the PMO hint-fault/migration counters.
pub fn fig16() -> Report {
    // §VI-A: LDRAM limited to 50 GB (~25k 2MB regions) of a 130 GB WSS.
    fig16_with(&topology::system_a(), &all_apps(), EPOCHS, 7, 64, 50)
}

/// Fig 16 on an arbitrary system / app set / epoch budget / seed /
/// thread count / fast-tier capacity (GB). The app × placement × policy
/// grid runs in parallel over the configured `--jobs`.
pub fn fig16_with(
    sys: &System,
    apps: &[AppModel],
    epochs: usize,
    seed: u64,
    threads: usize,
    fast_gb: u64,
) -> Report {
    let fast_cap = ((fast_gb << 30) / crate::mem::PAGE_BYTES) as usize;
    let mut t = Table::new(
        "Fig 16 — tiering x placement (seconds; lower is better)",
        &["app", "policy", "placement", "time s", "hint faults", "migrated 4K pages"],
    );
    // One immutable snapshot per app, generated at most once per
    // process: every policy×placement cell below — and any fleet
    // sibling with the same (app, pages, epochs, drift, seed) key —
    // replays a pointer-equal Arc instead of regenerating the stream.
    // Reference mode skips the store so its cells stay seed-pure.
    let traces: Option<Vec<Arc<EpochTrace>>> = if crate::perf::reference_enabled() {
        None
    } else {
        let shared = apps
            .iter()
            .map(|a| trace::global().get(a, epochs, seed))
            .collect();
        Some(shared)
    };
    // Flatten the grid in row order; every cell is independent.
    let mut cells: Vec<(usize, bool, usize)> = Vec::new();
    for ai in 0..apps.len() {
        for interleave in [false, true] {
            for pi in 0..POLICY_NAMES.len() {
                cells.push((ai, interleave, pi));
            }
        }
    }
    let rows = par_map_auto(&cells, |&(ai, interleave, pi)| {
        let mut pol = policy_by_index(pi);
        let run = app_sim(
            sys,
            &apps[ai],
            traces.as_ref().map(|t| &t[ai]),
            interleave,
            pol.as_mut(),
            seed,
            epochs,
            threads,
            fast_cap,
        );
        vec![
            apps[ai].name.into(),
            run.policy.clone(),
            run.placement.clone(),
            f1(run.total_s),
            run.stats.hint_faults.to_string(),
            run.stats.migrated_pages.to_string(),
        ]
    });
    for row in rows {
        t.row(row);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 17: tiering × {first touch, uniform interleave, OLI} for the HPC
/// workloads (§VI-B; 32 threads, socket 1).
pub fn fig17() -> Report {
    fig17_with(&topology::system_a(), 1, 32, EPOCHS, 11)
}

/// Fig 17 on an arbitrary system / socket / thread count / epoch budget /
/// seed. The placement × policy grid of each workload runs in parallel
/// over the configured `--jobs`.
pub fn fig17_with(
    sys: &System,
    socket: usize,
    threads: usize,
    epochs: usize,
    seed: u64,
) -> Report {
    let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(socket, MemKind::Cxl).unwrap();
    let mut t = Table::new(
        "Fig 17 — tiering x placement for HPC (seconds; lower is better)",
        &["wl", "placement", "NoBalance", "AutoNUMA", "Tiering-0.8", "TPP"],
    );
    const PLACEMENTS: [&str; 3] = ["first-touch", "uniform", "OLI"];
    for wl in all_hpc_workloads() {
        // §VI-B capacities: 40 GB (FT), 100 GB (MG), 50 GB otherwise.
        let cap_gb: u64 = match wl.name {
            "FT" => 40,
            "MG" => 100,
            _ => 50,
        };
        let fast_cap = ((cap_gb << 30) / crate::mem::PAGE_BYTES) as usize;
        let pages_per_obj: Vec<usize> = wl
            .objects
            .iter()
            .map(|o| (o.spec.bytes / crate::mem::PAGE_BYTES) as usize)
            .collect();
        let total_pages: usize = pages_per_obj.iter().sum();
        let plan = oli::plan(sys, socket, &wl.specs(), &[MemKind::Ldram, MemKind::Cxl]);
        // per-epoch counts: uniform scan of each object scaled by its
        // traffic (accesses in cache lines / page).
        let counts: Vec<u32> = wl
            .objects
            .iter()
            .zip(&pages_per_obj)
            .flat_map(|(o, &n)| {
                let per_page =
                    (o.traffic_bytes() / 64.0 / n.max(1) as f64 / epochs as f64) as u32;
                std::iter::repeat(per_page).take(n)
            })
            .collect();
        let patterns: Vec<(Pattern, f64)> = wl
            .objects
            .iter()
            .map(|o| (o.pattern, o.spec.dep_frac))
            .collect();
        // Every cell of this workload replays the same constant
        // histogram; share it as one immutable trace snapshot instead
        // of copying it into each cell's epoch buffer.
        let shared = Arc::new(EpochTrace::constant(counts.clone(), epochs));
        // Flatten the 3 × 4 grid; every cell builds its own page state
        // and policy, so the cells are fully independent.
        let mut cells: Vec<(usize, usize)> = Vec::new();
        for li in 0..PLACEMENTS.len() {
            for pi in 0..POLICY_NAMES.len() {
                cells.push((li, pi));
            }
        }
        let times: Vec<String> = par_map_auto(&cells, |&(li, pi)| {
            let placement = PLACEMENTS[li];
            let mut pol = policy_by_index(pi);
            let mut state = match placement {
                "first-touch" => initial_state(total_pages, ld, cxl, fast_cap, false),
                "uniform" => initial_state(total_pages, ld, cxl, fast_cap, true),
                _ => oli_state(&plan, &pages_per_obj, ld, cxl, fast_cap),
            };
            // object ids per page
            let mut obj_of = Vec::with_capacity(total_pages);
            for (oi, &n) in pages_per_obj.iter().enumerate() {
                obj_of.extend(std::iter::repeat(oi as u32).take(n));
            }
            state.set_objects(obj_of);
            let cfg = SimConfig {
                socket,
                threads,
                compute_ns_per_byte: wl.compute_ns_per_byte,
                epochs,
                seed,
            };
            let patterns = &patterns;
            let run = if crate::perf::reference_enabled() {
                // Seed semantics: copy the histogram into the cell's
                // own epoch buffer every epoch.
                tiering::simulate(
                    sys,
                    &cfg,
                    &mut state,
                    pol.as_mut(),
                    |_, buf| {
                        buf.clear();
                        buf.extend_from_slice(&counts);
                    },
                    move |oi| patterns[oi as usize],
                )
            } else {
                tiering::simulate_trace(
                    sys,
                    &cfg,
                    &mut state,
                    pol.as_mut(),
                    &shared,
                    move |oi| patterns[oi as usize],
                )
            };
            f1(run.total_s)
        });
        for (li, placement) in PLACEMENTS.iter().enumerate() {
            let mut row = vec![wl.name.to_string(), (*placement).into()];
            row.extend(times[li * POLICY_NAMES.len()..(li + 1) * POLICY_NAMES.len()].to_vec());
            t.row(row);
        }
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Build the OLI page state: interleaved objects alternate LDRAM/CXL and
/// are unmigratable; preferred objects fill LDRAM first (migratable).
fn oli_state(
    plan: &oli::OliPlan,
    pages_per_obj: &[usize],
    ld: usize,
    cxl: usize,
    fast_cap: usize,
) -> PageState {
    let total: usize = pages_per_obj.iter().sum();
    let mut node = Vec::with_capacity(total);
    let mut migratable = Vec::with_capacity(total);
    let mut fast_used = 0usize;
    for (oi, &n) in pages_per_obj.iter().enumerate() {
        let interleaved = plan.assignments[oi].2;
        for p in 0..n {
            if interleaved {
                let target = if p % 2 == 0 && fast_used < fast_cap { ld } else { cxl };
                if target == ld {
                    fast_used += 1;
                }
                node.push(target);
                migratable.push(false);
            } else {
                let target = if fast_used < fast_cap { ld } else { cxl };
                if target == ld {
                    fast_used += 1;
                }
                node.push(target);
                migratable.push(true);
            }
        }
    }
    PageState::new(node, migratable, vec![0; total], ld, fast_cap, cxl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(t: &Table, app: &str, pol: &str, place: &str) -> f64 {
        t.rows
            .iter()
            .find(|r| r[0] == app && r[1] == pol && r[2] == place)
            .unwrap()[3]
            .parse()
            .unwrap()
    }

    #[test]
    fn fig16_pagerank_first_touch_no_migration_wins() {
        // PMO 1: PageRank's small stable hot set favors plain first touch.
        let r = fig16();
        let t = &r.tables[0];
        let ft_nb = get(t, "PageRank", "NoBalance", "first-touch");
        for pol in ["NoBalance", "AutoNUMA", "Tiering-0.8", "TPP"] {
            let inter = get(t, "PageRank", pol, "interleave");
            assert!(ft_nb < inter, "{pol}: {ft_nb} vs {inter}");
        }
    }

    #[test]
    fn fig16_btree_insensitive() {
        // PMO 1: BTree varies little across solutions.
        let r = fig16();
        let t = &r.tables[0];
        let vals: Vec<f64> = t
            .rows
            .iter()
            .filter(|r| r[0] == "BTree")
            .map(|r| r[3].parse().unwrap())
            .collect();
        let max = vals.iter().cloned().fold(0.0f64, f64::max);
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((max - min) / min < 0.25, "{vals:?}");
    }

    #[test]
    fn fig16_interleave_suppresses_hint_faults() {
        // PMO 3.
        let r = fig16();
        let t = &r.tables[0];
        for row in &t.rows {
            if row[2] == "interleave" {
                assert_eq!(row[4], "0", "{row:?}");
            }
        }
    }

    #[test]
    fn fig16_repeat_run_is_byte_identical_via_shared_store() {
        // Two in-process grid runs hit the same process-global trace
        // snapshots (the second is pure store hits) and must emit
        // byte-identical tables — the `make trace-smoke` invariant.
        let mut apps = all_apps();
        for a in &mut apps {
            a.pages = 2_000;
        }
        let sys = topology::system_a();
        let a = fig16_with(&sys, &apps, 3, 123, 64, 2);
        let b = fig16_with(&sys, &apps, 3, 123, 64, 2);
        assert_eq!(a.tables[0].rows, b.tables[0].rows);
    }

    #[test]
    fn fig16_tiering08_fewer_faults_than_tpp() {
        // PMO 2 (paper: 59× fewer).
        let r = fig16();
        let t = &r.tables[0];
        for app in ["BTree", "PageRank", "Graph500", "Silo"] {
            let t08: f64 = t
                .rows
                .iter()
                .find(|r| r[0] == app && r[1] == "Tiering-0.8" && r[2] == "first-touch")
                .unwrap()[4]
                .parse()
                .unwrap();
            let tpp: f64 = t
                .rows
                .iter()
                .find(|r| r[0] == app && r[1] == "TPP" && r[2] == "first-touch")
                .unwrap()[4]
                .parse()
                .unwrap();
            assert!(tpp > 8.0 * t08.max(1.0), "{app}: tpp {tpp} vs t08 {t08}");
        }
    }
}
