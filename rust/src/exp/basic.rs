//! Experiment drivers for §III: Table I and Figs 2–4 + the §III thread
//! assignment study.

use crate::memsim::{topology, MemKind, Pattern, System};
use crate::probes::{self, mlc};
use crate::report::Report;
use crate::util::table::{f1, Table};

const TIERS: [MemKind; 3] = [MemKind::Ldram, MemKind::Rdram, MemKind::Cxl];

/// Default Fig 3 thread-count rows (truncated per system's core count).
pub const FIG3_THREAD_ROWS: &[usize] = &[1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32, 40, 48, 52];

/// Table I: the three systems.
pub fn table1() -> Report {
    table1_with(&topology::all_systems())
}

/// Table I over an arbitrary system list (scenario entry point).
pub fn table1_with(systems: &[System]) -> Report {
    let mut t = Table::new(
        "Table I — three systems with CXL devices",
        &["Sys", "Description", "DDR spec GB/s", "CXL spec GB/s", "CXL cap"],
    );
    for sys in systems {
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        t.row(vec![
            sys.name.clone(),
            sys.description.clone(),
            f1(sys.nodes[0].device.spec_bw_gbs),
            f1(sys.nodes[cxl].device.spec_bw_gbs),
            format!("{} GB", sys.nodes[cxl].device.capacity >> 30),
        ]);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 2: idle load latency, random + sequential, per system and tier.
pub fn fig2() -> Report {
    fig2_with(&topology::all_systems(), 5000, 42)
}

/// Fig 2 over arbitrary systems / sample budget / base seed (the random
/// pattern uses `seed + 1`, matching the paper harness defaults 42/43).
pub fn fig2_with(systems: &[System], samples: usize, seed: u64) -> Report {
    let mut r = Report::new();
    let mut t = Table::new(
        "Fig 2 — load latency (ns) for random/sequential access",
        &["Sys", "Tier", "sequential", "random"],
    );
    for sys in systems {
        // Measure from the socket nearest the CXL card (paper's setup).
        let socket = sys.nodes[sys.node_of(0, MemKind::Cxl).unwrap()].socket;
        for kind in TIERS {
            let node = sys.node_of(socket, kind).unwrap();
            let seq = mlc::idle_latency(sys, socket, node, Pattern::Sequential, samples, seed);
            let rnd = mlc::idle_latency(sys, socket, node, Pattern::Random, samples, seed + 1);
            t.row(vec![
                sys.name.clone(),
                kind.label().into(),
                f1(seq),
                f1(rnd),
            ]);
        }
    }
    r.add(t);
    r
}

/// Fig 3: bandwidth scaling vs thread count, per system.
pub fn fig3() -> Report {
    fig3_with(&topology::all_systems(), FIG3_THREAD_ROWS)
}

/// Fig 3 over arbitrary systems and thread-count rows.
pub fn fig3_with(systems: &[System], rows: &[usize]) -> Report {
    let mut r = Report::new();
    for sys in systems {
        let socket = 0;
        let max_t = sys.cores_per_socket;
        let mut t = Table::new(
            &format!("Fig 3 — bandwidth (GB/s) vs threads, system {}", sys.name),
            &["threads", "LDRAM", "RDRAM", "CXL"],
        );
        // Independent per-tier scans: fan out when --jobs allows.
        let sweeps: Vec<Vec<mlc::BwPoint>> =
            crate::util::par::par_map_auto(&TIERS[..], |&k| {
                let node = sys.node_of(socket, k).unwrap();
                mlc::bw_scaling_sweep(sys, socket, node, Pattern::Sequential, max_t)
            });
        // Skip (not stop at) rows beyond this system's core count — the
        // row list is scenario data now and need not be sorted.
        for &ti in rows {
            if ti == 0 || ti > max_t {
                continue;
            }
            t.row(vec![
                ti.to_string(),
                f1(sweeps[0][ti - 1].bw_gbs),
                f1(sweeps[1][ti - 1].bw_gbs),
                f1(sweeps[2][ti - 1].bw_gbs),
            ]);
        }
        // Saturation summary row (the paper's headline observation).
        let sat: Vec<String> = sweeps
            .iter()
            .map(|s| format!("sat@{}", mlc::saturation_threads(s, 0.95)))
            .collect();
        t.row(vec!["(95% sat)".into(), sat[0].clone(), sat[1].clone(), sat[2].clone()]);
        r.add(t);
    }
    r
}

/// Fig 4: latency/bandwidth under varying injected load.
pub fn fig4() -> Report {
    fig4_with(&topology::all_systems(), 32)
}

/// Fig 4 over arbitrary systems / driving thread count (MLC delay grid).
pub fn fig4_with(systems: &[System], threads: usize) -> Report {
    let mut r = Report::new();
    for sys in systems {
        let socket = 0;
        let mut t = Table::new(
            &format!(
                "Fig 4 — loaded latency, system {} ({threads} threads, delay sweep)",
                sys.name
            ),
            &[
                "delay ns", "LDRAM ns", "LDRAM GB/s", "RDRAM ns", "RDRAM GB/s", "CXL ns",
                "CXL GB/s",
            ],
        );
        let grid = mlc::mlc_delay_grid();
        let sweeps: Vec<Vec<mlc::LoadPoint>> =
            crate::util::par::par_map_auto(&TIERS[..], |&k| {
                let node = sys.node_of(socket, k).unwrap();
                mlc::loaded_latency_sweep(sys, socket, node, Pattern::Sequential, threads, &grid)
            });
        for i in 0..grid.len() {
            t.row(vec![
                format!("{:.0}", sweeps[0][i].delay_ns),
                f1(sweeps[0][i].latency_ns),
                f1(sweeps[0][i].bw_gbs),
                f1(sweeps[1][i].latency_ns),
                f1(sweeps[1][i].bw_gbs),
                f1(sweeps[2][i].latency_ns),
                f1(sweeps[2][i].bw_gbs),
            ]);
        }
        r.add(t);
    }
    r
}

/// §III thread-assignment study (system B: 6/23/23 → ~420 GB/s).
pub fn assign() -> Report {
    assign_with(&topology::system_b(), 0)
}

/// The thread-assignment study on an arbitrary system/socket.
pub fn assign_with(sys: &System, socket: usize) -> Report {
    let best = probes::best_assignment(sys, socket, sys.cores_per_socket);
    let mut t = Table::new(
        &format!("§III — bandwidth-aware thread assignment (system {})", sys.name),
        &["assignment", "LDRAM t", "RDRAM t", "CXL t", "total GB/s"],
    );
    let names: Vec<MemKind> = best
        .split
        .iter()
        .map(|&(n, _)| sys.kind_from(socket, n))
        .collect();
    let get = |k: MemKind| -> usize {
        best.split
            .iter()
            .zip(&names)
            .find(|&(_, &kk)| kk == k)
            .map(|(&(_, t), _)| t)
            .unwrap_or(0)
    };
    t.row(vec![
        "bandwidth-aware (searched)".into(),
        get(MemKind::Ldram).to_string(),
        get(MemKind::Rdram).to_string(),
        get(MemKind::Cxl).to_string(),
        f1(best.total_bw_gbs),
    ]);
    // Baselines: all threads on LDRAM; uniform split.
    let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
    let rd = sys.node_of(socket, MemKind::Rdram).unwrap();
    let cxl = sys.node_of(socket, MemKind::Cxl).unwrap();
    let n = sys.cores_per_socket;
    let all_ld = mlc::combined_bw(&sys, socket, &[(ld, n)]);
    t.row(vec![
        "all threads LDRAM".into(),
        n.to_string(),
        "0".into(),
        "0".into(),
        f1(all_ld),
    ]);
    let third = n / 3;
    let uni = mlc::combined_bw(&sys, socket, &[(ld, third), (rd, third), (cxl, third)]);
    t.row(vec![
        "uniform thirds".into(),
        third.to_string(),
        third.to_string(),
        third.to_string(),
        f1(uni),
    ]);
    let mut r = Report::new();
    r.add(t);
    r
}

/// Convenience used by tests: the systems the drivers run on.
pub fn systems() -> Vec<System> {
    topology::all_systems()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_ordering_holds_in_report() {
        let r = fig2();
        let t = &r.tables[0];
        // For each system: CXL > RDRAM > LDRAM in both columns.
        for chunk in t.rows.chunks(3) {
            let seq: Vec<f64> = chunk.iter().map(|r| r[2].parse().unwrap()).collect();
            assert!(seq[0] < seq[1] && seq[1] < seq[2], "{seq:?}");
        }
    }

    #[test]
    fn fig3_has_saturation_row() {
        let r = fig3();
        for t in &r.tables {
            assert!(t.rows.last().unwrap()[1].starts_with("sat@"));
        }
    }

    #[test]
    fn assign_beats_baselines() {
        let r = assign();
        let t = &r.tables[0];
        let best: f64 = t.rows[0][4].parse().unwrap();
        let all_ld: f64 = t.rows[1][4].parse().unwrap();
        let uniform: f64 = t.rows[2][4].parse().unwrap();
        assert!(best > all_ld && best >= uniform);
    }

    #[test]
    fn table1_lists_three_systems() {
        assert_eq!(table1().tables[0].rows.len(), 3);
    }
}
