//! Experiment drivers for §III: Table I and Figs 2–4 + the §III thread
//! assignment study.

use crate::memsim::{topology, MemKind, Pattern, System};
use crate::probes::{self, mlc};
use crate::report::Report;
use crate::util::table::{f1, Table};

const TIERS: [MemKind; 3] = [MemKind::Ldram, MemKind::Rdram, MemKind::Cxl];

/// Table I: the three systems.
pub fn table1() -> Report {
    let mut t = Table::new(
        "Table I — three systems with CXL devices",
        &["Sys", "Description", "DDR spec GB/s", "CXL spec GB/s", "CXL cap"],
    );
    for sys in topology::all_systems() {
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        t.row(vec![
            sys.name.clone(),
            sys.description.clone(),
            f1(sys.nodes[0].device.spec_bw_gbs),
            f1(sys.nodes[cxl].device.spec_bw_gbs),
            format!("{} GB", sys.nodes[cxl].device.capacity >> 30),
        ]);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 2: idle load latency, random + sequential, per system and tier.
pub fn fig2() -> Report {
    let mut r = Report::new();
    let mut t = Table::new(
        "Fig 2 — load latency (ns) for random/sequential access",
        &["Sys", "Tier", "sequential", "random"],
    );
    for sys in topology::all_systems() {
        // Measure from the socket nearest the CXL card (paper's setup).
        let socket = sys.nodes[sys.node_of(0, MemKind::Cxl).unwrap()].socket;
        for kind in TIERS {
            let node = sys.node_of(socket, kind).unwrap();
            let seq = mlc::idle_latency(&sys, socket, node, Pattern::Sequential, 5000, 42);
            let rnd = mlc::idle_latency(&sys, socket, node, Pattern::Random, 5000, 43);
            t.row(vec![
                sys.name.clone(),
                kind.label().into(),
                f1(seq),
                f1(rnd),
            ]);
        }
    }
    r.add(t);
    r
}

/// Fig 3: bandwidth scaling vs thread count, per system.
pub fn fig3() -> Report {
    let mut r = Report::new();
    for sys in topology::all_systems() {
        let socket = 0;
        let max_t = sys.cores_per_socket;
        let mut t = Table::new(
            &format!("Fig 3 — bandwidth (GB/s) vs threads, system {}", sys.name),
            &["threads", "LDRAM", "RDRAM", "CXL"],
        );
        // Independent per-tier scans: fan out when --jobs allows.
        let sweeps: Vec<Vec<mlc::BwPoint>> =
            crate::util::par::par_map_auto(&TIERS[..], |&k| {
                let node = sys.node_of(socket, k).unwrap();
                mlc::bw_scaling_sweep(&sys, socket, node, Pattern::Sequential, max_t)
            });
        for ti in [1, 2, 4, 6, 8, 12, 16, 20, 24, 28, 32, 40, 48, 52] {
            if ti > max_t {
                break;
            }
            t.row(vec![
                ti.to_string(),
                f1(sweeps[0][ti - 1].bw_gbs),
                f1(sweeps[1][ti - 1].bw_gbs),
                f1(sweeps[2][ti - 1].bw_gbs),
            ]);
        }
        // Saturation summary row (the paper's headline observation).
        let sat: Vec<String> = sweeps
            .iter()
            .map(|s| format!("sat@{}", mlc::saturation_threads(s, 0.95)))
            .collect();
        t.row(vec!["(95% sat)".into(), sat[0].clone(), sat[1].clone(), sat[2].clone()]);
        r.add(t);
    }
    r
}

/// Fig 4: latency/bandwidth under varying injected load.
pub fn fig4() -> Report {
    let mut r = Report::new();
    for sys in topology::all_systems() {
        let socket = 0;
        let mut t = Table::new(
            &format!(
                "Fig 4 — loaded latency, system {} (32 threads, delay sweep)",
                sys.name
            ),
            &[
                "delay ns", "LDRAM ns", "LDRAM GB/s", "RDRAM ns", "RDRAM GB/s", "CXL ns",
                "CXL GB/s",
            ],
        );
        let grid = mlc::mlc_delay_grid();
        let sweeps: Vec<Vec<mlc::LoadPoint>> =
            crate::util::par::par_map_auto(&TIERS[..], |&k| {
                let node = sys.node_of(socket, k).unwrap();
                mlc::loaded_latency_sweep(&sys, socket, node, Pattern::Sequential, 32, &grid)
            });
        for i in 0..grid.len() {
            t.row(vec![
                format!("{:.0}", sweeps[0][i].delay_ns),
                f1(sweeps[0][i].latency_ns),
                f1(sweeps[0][i].bw_gbs),
                f1(sweeps[1][i].latency_ns),
                f1(sweeps[1][i].bw_gbs),
                f1(sweeps[2][i].latency_ns),
                f1(sweeps[2][i].bw_gbs),
            ]);
        }
        r.add(t);
    }
    r
}

/// §III thread-assignment study (system B: 6/23/23 → ~420 GB/s).
pub fn assign() -> Report {
    let sys = topology::system_b();
    let socket = 0;
    let best = probes::best_assignment(&sys, socket, sys.cores_per_socket);
    let mut t = Table::new(
        "§III — bandwidth-aware thread assignment (system B)",
        &["assignment", "LDRAM t", "RDRAM t", "CXL t", "total GB/s"],
    );
    let names: Vec<MemKind> = best
        .split
        .iter()
        .map(|&(n, _)| sys.kind_from(socket, n))
        .collect();
    let get = |k: MemKind| -> usize {
        best.split
            .iter()
            .zip(&names)
            .find(|&(_, &kk)| kk == k)
            .map(|(&(_, t), _)| t)
            .unwrap_or(0)
    };
    t.row(vec![
        "bandwidth-aware (searched)".into(),
        get(MemKind::Ldram).to_string(),
        get(MemKind::Rdram).to_string(),
        get(MemKind::Cxl).to_string(),
        f1(best.total_bw_gbs),
    ]);
    // Baselines: all threads on LDRAM; uniform split.
    let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
    let rd = sys.node_of(socket, MemKind::Rdram).unwrap();
    let cxl = sys.node_of(socket, MemKind::Cxl).unwrap();
    let n = sys.cores_per_socket;
    let all_ld = mlc::combined_bw(&sys, socket, &[(ld, n)]);
    t.row(vec![
        "all threads LDRAM".into(),
        n.to_string(),
        "0".into(),
        "0".into(),
        f1(all_ld),
    ]);
    let third = n / 3;
    let uni = mlc::combined_bw(&sys, socket, &[(ld, third), (rd, third), (cxl, third)]);
    t.row(vec![
        "uniform thirds".into(),
        third.to_string(),
        third.to_string(),
        third.to_string(),
        f1(uni),
    ]);
    let mut r = Report::new();
    r.add(t);
    r
}

/// Convenience used by tests: the systems the drivers run on.
pub fn systems() -> Vec<System> {
    topology::all_systems()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_ordering_holds_in_report() {
        let r = fig2();
        let t = &r.tables[0];
        // For each system: CXL > RDRAM > LDRAM in both columns.
        for chunk in t.rows.chunks(3) {
            let seq: Vec<f64> = chunk.iter().map(|r| r[2].parse().unwrap()).collect();
            assert!(seq[0] < seq[1] && seq[1] < seq[2], "{seq:?}");
        }
    }

    #[test]
    fn fig3_has_saturation_row() {
        let r = fig3();
        for t in &r.tables {
            assert!(t.rows.last().unwrap()[1].starts_with("sat@"));
        }
    }

    #[test]
    fn assign_beats_baselines() {
        let r = assign();
        let t = &r.tables[0];
        let best: f64 = t.rows[0][4].parse().unwrap();
        let all_ld: f64 = t.rows[1][4].parse().unwrap();
        let uniform: f64 = t.rows[2][4].parse().unwrap();
        assert!(best > all_ld && best >= uniform);
    }

    #[test]
    fn table1_lists_three_systems() {
        assert_eq!(table1().tables[0].rows.len(), 3);
    }
}
