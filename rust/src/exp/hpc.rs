//! Experiment drivers for §V: Table III, Figs 13, 14, 15a/b.

use anyhow::Result;

use crate::mem::{self, oli, PhysMem, Policy};
use crate::memsim::{topology, MemKind, System};
use crate::report::Report;
use crate::util::table::{f2, Table};
use crate::workloads::npb::{all_hpc_workloads, by_name};
use crate::workloads::HpcWorkload;

/// Table III: HPC workload inventory + the OLI-selected objects.
pub fn table3() -> Report {
    table3_with(&all_hpc_workloads())
}

/// Table III over an arbitrary workload list.
pub fn table3_with(workloads: &[HpcWorkload]) -> Report {
    let mut t = Table::new(
        "Table III — HPC workloads",
        &["wl", "type", "input", "footprint GB", "BW-hungry objects (OLI-selected)"],
    );
    for wl in workloads {
        let sel = oli::select_bw_hungry(&wl.specs());
        let picked: Vec<String> = wl
            .objects
            .iter()
            .zip(&sel)
            .filter(|&(_, &s)| s)
            .map(|(o, _)| format!("{}({:.1}G)", o.spec.name, o.spec.bytes as f64 / 1e9))
            .collect();
        t.row(vec![
            wl.name.into(),
            wl.dwarf.into(),
            wl.input.into(),
            format!("{:.0}", wl.footprint_bytes() as f64 / 1e9),
            picked.join(", "),
        ]);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// The interleave policies of Fig 13.
fn fig13_policies(sys: &System, socket: usize) -> Vec<(String, Policy)> {
    let pols = vec![
        mem::policy::ldram_preferred(sys, socket),
        Policy::Preferred(sys.node_of(socket, MemKind::Rdram).unwrap()),
        mem::policy::cxl_preferred(sys, socket),
        mem::policy::interleave_kinds(sys, socket, &[MemKind::Ldram, MemKind::Cxl]),
        mem::policy::interleave_kinds(sys, socket, &[MemKind::Rdram, MemKind::Cxl]),
        mem::policy::interleave_all(sys, socket),
    ];
    pols.into_iter()
        .map(|p| (p.label(sys, socket), p))
        .collect()
}

fn run_policy(
    sys: &System,
    wl: &HpcWorkload,
    socket: usize,
    threads: usize,
    policy: &Policy,
) -> Result<f64> {
    let mut phys = PhysMem::of_system(sys);
    Ok(wl.run_uniform(sys, socket, threads, &mut phys, policy)?.total_s)
}

/// Fig 13: HPC performance under the interleaving policy family
/// (normalized to LDRAM preferred; lower is better).
pub fn fig13() -> Report {
    // paper: benchmarks run on CPU 0
    fig13_with(&topology::system_a(), 0, 32, &all_hpc_workloads())
}

/// Fig 13 on an arbitrary system / socket / thread count / workload set.
pub fn fig13_with(
    sys: &System,
    socket: usize,
    threads: usize,
    workloads: &[HpcWorkload],
) -> Report {
    let pols = fig13_policies(sys, socket);
    let mut headers = vec!["wl".to_string()];
    headers.extend(pols.iter().map(|(n, _)| n.clone()));
    let mut t = Table::new(
        "Fig 13 — normalized time under interleaving policies (LDRAM preferred = 1.0)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for wl in workloads {
        let base = run_policy(sys, wl, socket, threads, &pols[0].1).unwrap();
        let mut row = vec![wl.name.to_string()];
        for (_, p) in &pols {
            let v = run_policy(sys, wl, socket, threads, p).unwrap();
            row.push(f2(v / base));
        }
        t.row(row);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Default Fig 14 thread-count grid.
pub const FIG14_THREADS: &[usize] = &[4, 8, 12, 16, 20, 24, 28, 32];

/// Fig 14: CG and MG thread-scaling under CXL-preferred / RDRAM-only /
/// interleave-all, normalized to LDRAM-only at each thread count.
/// Run on socket 1 (the CXL-attached socket, as in §V-B's setup).
pub fn fig14() -> Report {
    fig14_with(&topology::system_a(), 1, &["CG", "MG"], FIG14_THREADS)
}

/// Fig 14 on an arbitrary system / socket / workload names / thread grid.
pub fn fig14_with(
    sys: &System,
    socket: usize,
    names: &[&str],
    thread_grid: &[usize],
) -> Report {
    let mut r = Report::new();
    for name in names {
        let wl = by_name(name).unwrap();
        let mut t = Table::new(
            &format!("Fig 14 — {name} scalability (time normalized to LDRAM only)"),
            &["threads", "LDRAM only", "RDRAM only", "CXL preferred", "interleave all"],
        );
        let ld = Policy::Membind(vec![sys.node_of(socket, MemKind::Ldram).unwrap()]);
        let rd = Policy::Membind(vec![sys.node_of(socket, MemKind::Rdram).unwrap()]);
        let cxl = mem::policy::cxl_preferred(sys, socket);
        let all = mem::policy::interleave_all(sys, socket);
        for &threads in thread_grid {
            let base = run_policy(sys, &wl, socket, threads, &ld).unwrap();
            let mut row = vec![threads.to_string(), f2(1.0)];
            for p in [&rd, &cxl, &all] {
                row.push(f2(run_policy(sys, &wl, socket, threads, p).unwrap() / base));
            }
            t.row(row);
        }
        r.add(t);
    }
    r
}

/// Fig 15 core: per-workload speedup (vs LDRAM preferred) for uniform
/// interleave and OLI under an LDRAM capacity limit.
fn fig15(ldram_gb: u64, title: &str) -> Report {
    fig15_with(&topology::system_a(), 0, 32, ldram_gb, 32, title)
}

/// Fig 15 on an arbitrary system / socket / thread count / capacity
/// limits. `rdram_residue_gb` is the emergency-overflow headroom the
/// paper's GRUB-limited systems keep (MG's 210 GB does not fit 64+128 GB
/// otherwise).
pub fn fig15_with(
    sys: &System,
    socket: usize,
    threads: usize,
    ldram_gb: u64,
    rdram_residue_gb: u64,
    title: &str,
) -> Report {
    let mut t = Table::new(
        title,
        &["wl", "LDRAM preferred", "uniform interleave", "OLI", "OLI LDRAM saved"],
    );
    for wl in all_hpc_workloads() {
        // §V-B setup: "run the workload on CPU 0 using both LDRAM (memory
        // node 0) and CXL memory" — RDRAM is excluded from the test.
        let limit = |phys: &mut PhysMem| {
            let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
            let rd = sys.node_of(socket, MemKind::Rdram).unwrap();
            phys.limit_node(ld, ldram_gb << 30);
            phys.limit_node(rd, rdram_residue_gb << 30);
        };
        // LDRAM preferred baseline
        let mut phys = PhysMem::of_system(sys);
        limit(&mut phys);
        let base = wl
            .run_uniform(sys, socket, threads, &mut phys, &mem::policy::ldram_preferred(sys, socket))
            .unwrap()
            .total_s;
        // Uniform interleave LDRAM+CXL
        let mut phys = PhysMem::of_system(sys);
        limit(&mut phys);
        let uni = wl
            .run_uniform(
                sys,
                socket,
                threads,
                &mut phys,
                &mem::policy::interleave_kinds(sys, socket, &[MemKind::Ldram, MemKind::Cxl]),
            )
            .unwrap()
            .total_s;
        // OLI
        let plan = oli::plan(sys, socket, &wl.specs(), &[MemKind::Ldram, MemKind::Cxl]);
        let mut phys = PhysMem::of_system(sys);
        limit(&mut phys);
        let oli_t = wl
            .run_with(sys, socket, threads, &mut phys, &|i, _| {
                plan.assignments[i].1.clone()
            })
            .unwrap()
            .total_s;
        let (oli_ld, base_ld) = oli::ldram_demand(&wl.specs(), &plan);
        t.row(vec![
            wl.name.into(),
            f2(1.0),
            f2(base / uni), // speedup vs LDRAM preferred
            f2(base / oli_t),
            format!("{:.0}%", 100.0 * (1.0 - oli_ld as f64 / base_ld as f64)),
        ]);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 15(a): sufficient LDRAM (128 GB).
pub fn fig15a() -> Report {
    fig15(
        128,
        "Fig 15a — speedup vs LDRAM preferred, sufficient LDRAM (128 GB)",
    )
}

/// Fig 15(b): insufficient LDRAM (64 GB).
pub fn fig15b() -> Report {
    fig15(
        64,
        "Fig 15b — speedup vs LDRAM preferred, insufficient LDRAM (64 GB)",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, row: usize, c: usize) -> f64 {
        t.rows[row][c].parse().unwrap()
    }

    #[test]
    fn fig13_rdram_cxl_close_to_ldram_cxl() {
        // HPC observation 1: ≤ ~9.2% gap between the two CXL interleaves.
        let r = fig13();
        let t = &r.tables[0];
        for row in 0..t.rows.len() {
            let ldcxl = col(t, row, 4);
            let rdcxl = col(t, row, 5);
            let gap = (rdcxl - ldcxl).abs() / ldcxl;
            assert!(gap < 0.15, "{}: {gap}", t.rows[row][0]);
        }
    }

    #[test]
    fn fig14_mg_interleave_all_beats_cxl_preferred() {
        // HPC observation 2.
        let r = fig14();
        let mg = &r.tables[1];
        let last = mg.rows.len() - 1; // 32 threads
        assert!(col(mg, last, 3) > col(mg, last, 4) * 1.10);
    }

    #[test]
    fn fig14_cg_cxl_preferred_wins_at_low_threads() {
        // HPC observation 3.
        let r = fig14();
        let cg = &r.tables[0];
        // At low thread counts CXL-preferred ≤ RDRAM-only time
        // (paper: 4–20 threads; our crossover lands at ~8–12).
        for row in 0..2 {
            assert!(
                col(cg, row, 3) <= col(cg, row, 2) * 1.02,
                "row {row}: cxl {} vs rdram {}",
                col(cg, row, 3),
                col(cg, row, 2)
            );
        }
    }

    #[test]
    fn fig15a_oli_close_to_ldram_preferred_and_beats_uniform() {
        let r = fig15a();
        let t = &r.tables[0];
        let mut oli_speeds = Vec::new();
        let mut uni_speeds = Vec::new();
        for row in 0..t.rows.len() {
            if t.rows[row][0] == "XSBench" {
                continue; // paper: the exception
            }
            uni_speeds.push(col(t, row, 2));
            oli_speeds.push(col(t, row, 3));
        }
        let oli_avg: f64 = oli_speeds.iter().sum::<f64>() / oli_speeds.len() as f64;
        let uni_avg: f64 = uni_speeds.iter().sum::<f64>() / uni_speeds.len() as f64;
        assert!(oli_avg > 0.9, "OLI ≈ LDRAM preferred, got {oli_avg}");
        // Paper: +65% over uniform on average; our gap is smaller because
        // several workloads are compute-bound at full LDRAM (see
        // EXPERIMENTS.md F15 notes) but the ordering must hold.
        assert!(oli_avg > uni_avg * 1.08, "OLI {oli_avg} vs uniform {uni_avg}");
    }

    #[test]
    fn fig15b_oli_wins_with_insufficient_ldram() {
        let r = fig15b();
        let t = &r.tables[0];
        let mut oli_speeds = Vec::new();
        for row in 0..t.rows.len() {
            if t.rows[row][0] == "XSBench" {
                continue;
            }
            oli_speeds.push(col(t, row, 3));
        }
        let avg: f64 = oli_speeds.iter().sum::<f64>() / oli_speeds.len() as f64;
        // Paper: 1.42× over LDRAM-preferred. Our engine keeps several
        // workloads compute-bound under the 64 GB limit, so the win is
        // concentrated in the latency-sensitive ones (CG) — assert the
        // ordering + near-parity floor and document the delta.
        assert!(avg > 0.9, "OLI vs LDRAM preferred avg: {avg}");
        let r2 = fig15b();
        let t2 = &r2.tables[0];
        for row in 0..t2.rows.len() {
            let uni = col(t2, row, 2);
            let oli = col(t2, row, 3);
            assert!(oli >= uni - 1e-9, "OLI must never lose to uniform");
        }
    }

    #[test]
    fn table3_footprints() {
        let r = table3();
        assert_eq!(r.tables[0].rows.len(), 7);
    }
}
