//! Experiment drivers for §IV: Figs 5, 6, 8, 9, 11, 12 and Table II.

use crate::gpu::Gpu;
use crate::llm::flexgen::{self, InferCfg};
use crate::llm::model_cfg::{bert, gpt2, llama_65b, opt_66b, ModelCfg};
use crate::llm::zero_offload::{self, TrainCfg};
use crate::memsim::{topology, MemKind, NodeId, System};
use crate::report::Report;
use crate::util::table::{f1, f2, Table};

const GB: f64 = 1e9;

fn sys_a() -> (System, Gpu) {
    (topology::system_a(), Gpu::a10())
}

/// A named CPU memory hierarchy handed to the FlexGen policy search —
/// Fig 11/12 and Table II parameterize over lists of these, and scenario
/// files supply them as data.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    pub name: String,
    /// (tier kind, capacity bytes) in preference order.
    pub tiers: Vec<(MemKind, f64)>,
}

impl Hierarchy {
    pub fn new(name: &str, tiers: &[(MemKind, f64)]) -> Self {
        Self {
            name: name.to_string(),
            tiers: tiers.to_vec(),
        }
    }
}

/// The paper's equal-capacity (324 GB) hierarchies of Fig 11.
pub fn hierarchies_324() -> Vec<Hierarchy> {
    configs_324()
        .into_iter()
        .map(|(n, t)| Hierarchy::new(n, &t))
        .collect()
}

/// The paper's capacity ladder of Table II / Fig 12.
pub fn hierarchies_ladder() -> Vec<Hierarchy> {
    capacity_ladder()
        .into_iter()
        .map(|(n, t)| Hierarchy::new(n, &t))
        .collect()
}

/// Inference model lookup for scenario specs.
pub fn infer_model(name: &str) -> Option<ModelCfg> {
    match name {
        "llama-65b" => Some(llama_65b()),
        "opt-66b" => Some(opt_66b()),
        _ => None,
    }
}

/// The paper's default inference model pair.
pub fn default_infer_models() -> Vec<ModelCfg> {
    vec![llama_65b(), opt_66b()]
}

/// The four CPU-side placements of Fig 8 (from the GPU's socket 1 the
/// "local" DDR is node 1's pool; we keep the paper's socket-0 naming).
fn placements(sys: &System) -> Vec<(&'static str, Vec<(NodeId, f64)>)> {
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let rd = sys.node_of(0, MemKind::Rdram).unwrap();
    let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
    vec![
        ("LDRAM only", vec![(ld, 1.0)]),
        ("LDRAM+CXL", vec![(ld, 0.5), (cxl, 0.5)]),
        ("LDRAM+RDRAM", vec![(ld, 0.5), (rd, 0.5)]),
        (
            "interleave all",
            vec![(ld, 1.0 / 3.0), (rd, 1.0 / 3.0), (cxl, 1.0 / 3.0)],
        ),
    ]
}

/// Default Fig 5 transfer block sizes (log2 bytes).
pub const FIG5_BLOCKS_LOG2: &[usize] = &[7, 12, 16, 20, 24, 28, 30, 32];

/// Fig 5: GPU↔CPU copy bandwidth vs block size × memory policy.
pub fn fig5() -> Report {
    let (sys, gpu) = sys_a();
    fig5_with(&sys, &gpu, FIG5_BLOCKS_LOG2)
}

/// Fig 5 on an arbitrary system / block-size grid.
pub fn fig5_with(sys: &System, gpu: &Gpu, blocks_log2: &[usize]) -> Report {
    let mut t = Table::new(
        "Fig 5 — GPU<->CPU transfer bandwidth (GB/s) vs block size",
        &["block", "LDRAM", "LDRAM+CXL", "LDRAM+RDRAM", "interleave all", "CXL only"],
    );
    let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
    let mut pols = placements(sys);
    pols.push(("CXL only", vec![(cxl, 1.0)]));
    for &exp in blocks_log2 {
        let bytes = (1u64 << exp) as f64;
        let mut row = vec![if exp < 20 {
            format!("{} B", 1u64 << exp)
        } else if exp < 30 {
            format!("{} MB", 1u64 << (exp - 20))
        } else {
            format!("{} GB", 1u64 << (exp - 30))
        }];
        for (_, p) in &pols {
            row.push(f2(gpu.observed_bw(&sys, p, bytes)));
        }
        t.row(row);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 6: 64-byte transfer latency GPU↔each memory node.
pub fn fig6() -> Report {
    let (sys, gpu) = sys_a();
    fig6_with(&sys, &gpu)
}

/// Fig 6 on an arbitrary system.
pub fn fig6_with(sys: &System, gpu: &Gpu) -> Report {
    let mut t = Table::new(
        "Fig 6 — 64B GPU<->CPU transfer latency (ns)",
        &["target memory", "latency ns", "delta vs LDRAM"],
    );
    let ld = sys.node_of(1, MemKind::Ldram).unwrap();
    let base = gpu.transfer_latency_ns(&sys, ld);
    for kind in [MemKind::Ldram, MemKind::Rdram, MemKind::Cxl] {
        let node = sys.node_of(1, kind).unwrap();
        let lat = gpu.transfer_latency_ns(&sys, node);
        t.row(vec![kind.label().into(), f1(lat), f1(lat - base)]);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

fn train_models() -> Vec<(ModelCfg, usize)> {
    let gpu = Gpu::a10();
    let mut out = Vec::new();
    for m in [bert("110M"), bert("340M"), bert("4B")] {
        let bs = zero_offload::max_batch(&gpu, &m, 512);
        out.push((m, bs));
    }
    for m in [gpt2("4B"), gpt2("6B"), gpt2("8B")] {
        let bs = zero_offload::max_batch(&gpu, &m, 1024);
        out.push((m, bs));
    }
    out
}

/// Fig 8: ZeRO-Offload training throughput × policy × model size.
pub fn fig8() -> Report {
    let (sys, gpu) = sys_a();
    fig8_with(&sys, &gpu)
}

/// Fig 8 on an arbitrary system (e.g. one with a swapped CXL card).
pub fn fig8_with(sys: &System, gpu: &Gpu) -> Report {
    let mut t = Table::new(
        "Fig 8 — ZeRO-Offload samples/s (bs=max batch @ model)",
        &["model", "bs", "LDRAM only", "LDRAM+CXL", "LDRAM+RDRAM", "interleave all"],
    );
    for (model, bs) in train_models() {
        let cfg = TrainCfg {
            model: model.clone(),
            batch: bs,
            seq: if model.name.starts_with("BERT") { 512 } else { 1024 },
            threads: 32,
        };
        let mut row = vec![model.name.clone(), bs.to_string()];
        for (_, p) in placements(&sys) {
            row.push(f2(zero_offload::throughput(&sys, &gpu, &cfg, &p)));
        }
        t.row(row);
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 9: optimizer + exposed-data-movement breakdown (% of step).
pub fn fig9() -> Report {
    let (sys, gpu) = sys_a();
    fig9_with(&sys, &gpu)
}

/// Fig 9 on an arbitrary system.
pub fn fig9_with(sys: &System, gpu: &Gpu) -> Report {
    let mut t = Table::new(
        "Fig 9 — step breakdown (optimizer% / data-move% of total)",
        &["model", "policy", "optimizer s", "opt %", "data-move s", "dm %"],
    );
    for (model, bs) in train_models() {
        let cfg = TrainCfg {
            model: model.clone(),
            batch: bs,
            seq: if model.name.starts_with("BERT") { 512 } else { 1024 },
            threads: 32,
        };
        for (name, p) in placements(&sys) {
            let b = zero_offload::step(&sys, &gpu, &cfg, &p);
            t.row(vec![
                format!("bs={}@{}", bs, model.name),
                name.into(),
                f2(b.optimizer_s),
                format!("{:.0}%", 100.0 * b.optimizer_share()),
                f2(b.data_move_exposed_s),
                format!("{:.1}%", 100.0 * b.data_move_share()),
            ]);
        }
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// The Fig 11 equal-capacity (324 GB) configurations.
fn configs_324() -> Vec<(&'static str, Vec<(MemKind, f64)>)> {
    vec![
        (
            "LDRAM+CXL",
            vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)],
        ),
        (
            "LDRAM+RDRAM",
            vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Rdram, 128.0 * GB)],
        ),
        (
            "LDRAM+NVMe",
            vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Nvme, 128.0 * GB)],
        ),
    ]
}

/// Fig 11: FlexGen throughput across 324 GB memory systems.
pub fn fig11() -> Report {
    let (sys, gpu) = sys_a();
    fig11_with(&sys, &gpu, &default_infer_models(), &hierarchies_324())
}

/// Fig 11 over arbitrary models and memory hierarchies.
pub fn fig11_with(
    sys: &System,
    gpu: &Gpu,
    models: &[ModelCfg],
    hierarchies: &[Hierarchy],
) -> Report {
    let mut t = Table::new(
        "Fig 11 — LLM inference throughput, 324 GB configs (tok/s)",
        &["model", "config", "batch", "prefill", "decode", "total"],
    );
    for model in models {
        let cfg = InferCfg::paper(model.clone());
        for h in hierarchies {
            let tiers = flexgen::tiers_of(sys, &h.tiers);
            let pol = flexgen::search_policy(gpu, &cfg, &tiers);
            let th = flexgen::throughput(sys, gpu, &cfg, &pol);
            t.row(vec![
                cfg.model.name.clone(),
                h.name.clone(),
                pol.batch.to_string(),
                f1(th.prefill_tok_s),
                f2(th.decode_tok_s),
                f2(th.total_tok_s),
            ]);
        }
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// The Fig 12 / Table II capacity ladder.
fn capacity_ladder() -> Vec<(&'static str, Vec<(MemKind, f64)>)> {
    vec![
        ("LDRAM only (196GB)", vec![(MemKind::Ldram, 196.0 * GB)]),
        (
            "LDRAM+CXL (324GB)",
            vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)],
        ),
        (
            "LDRAM+RDRAM (392GB)",
            vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Rdram, 196.0 * GB)],
        ),
        (
            "interleave all (520GB)",
            vec![
                (MemKind::Ldram, 196.0 * GB),
                (MemKind::Rdram, 196.0 * GB),
                (MemKind::Cxl, 128.0 * GB),
            ],
        ),
    ]
}

/// Table II: offload-policy search results.
pub fn table2() -> Report {
    let (sys, gpu) = sys_a();
    table2_with(&sys, &gpu, &default_infer_models(), &hierarchies_ladder())
}

/// Table II over arbitrary models and memory hierarchies.
pub fn table2_with(
    sys: &System,
    gpu: &Gpu,
    models: &[ModelCfg],
    hierarchies: &[Hierarchy],
) -> Report {
    let mut t = Table::new(
        "Table II — FlexGen offload policy per memory hierarchy",
        &["LLM", "hierarchy", "BS", "c on GPU", "c on CPU", "footprint"],
    );
    for model in models {
        let cfg = InferCfg::paper(model.clone());
        for h in hierarchies {
            let tiers = flexgen::tiers_of(sys, &h.tiers);
            let pol = flexgen::search_policy(gpu, &cfg, &tiers);
            t.row(vec![
                cfg.model.name.clone(),
                h.name.clone(),
                pol.batch.to_string(),
                format!("{:.0}%", 100.0 * pol.kv_gpu_frac),
                format!("{:.0}%", 100.0 * (1.0 - pol.kv_gpu_frac)),
                format!("{:.0} GB", pol.footprint / GB),
            ]);
        }
    }
    let mut r = Report::new();
    r.add(t);
    r
}

/// Fig 12: throughput vs memory capacity (batch-size scaling).
pub fn fig12() -> Report {
    let (sys, gpu) = sys_a();
    fig12_with(&sys, &gpu, &default_infer_models(), &hierarchies_ladder())
}

/// Fig 12 over arbitrary models and hierarchies; the first hierarchy is
/// the normalization baseline.
pub fn fig12_with(
    sys: &System,
    gpu: &Gpu,
    models: &[ModelCfg],
    hierarchies: &[Hierarchy],
) -> Report {
    let mut t = Table::new(
        "Fig 12 — inference throughput vs capacity (tok/s)",
        &["model", "config", "batch", "prefill", "decode", "total", "vs LDRAM only"],
    );
    for model in models {
        let cfg = InferCfg::paper(model.clone());
        let mut base_total = 0.0;
        for (i, h) in hierarchies.iter().enumerate() {
            let tiers = flexgen::tiers_of(sys, &h.tiers);
            let pol = flexgen::search_policy(gpu, &cfg, &tiers);
            let th = flexgen::throughput(sys, gpu, &cfg, &pol);
            if i == 0 {
                base_total = th.total_tok_s;
            }
            t.row(vec![
                cfg.model.name.clone(),
                h.name.clone(),
                pol.batch.to_string(),
                f1(th.prefill_tok_s),
                f2(th.decode_tok_s),
                f2(th.total_tok_s),
                format!("{:+.0}%", 100.0 * (th.total_tok_s / base_total - 1.0)),
            ]);
        }
    }
    let mut r = Report::new();
    r.add(t);
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_policies_within_3pct_at_4gb() {
        let r = fig5();
        let last = r.tables[0].rows.iter().rev().nth(0).unwrap();
        let bws: Vec<f64> = last[1..5].iter().map(|c| c.parse().unwrap()).collect();
        let max = bws.iter().cloned().fold(0.0f64, f64::max);
        let min = bws.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((max - min) / max < 0.03, "{bws:?}");
    }

    #[test]
    fn fig6_cxl_has_largest_delta() {
        let r = fig6();
        let rows = &r.tables[0].rows;
        let deltas: Vec<f64> = rows.iter().map(|r| r[2].parse().unwrap()).collect();
        assert!(deltas[2] > deltas[1] && deltas[1] > deltas[0]);
        assert!(deltas[2] > 100.0);
    }

    #[test]
    fn fig8_cxl_never_best() {
        // LLM training observation 1.
        let r = fig8();
        for row in &r.tables[0].rows {
            let ld: f64 = row[2].parse().unwrap();
            let ldcxl: f64 = row[3].parse().unwrap();
            assert!(ldcxl <= ld * 1.02, "{row:?}");
        }
    }

    #[test]
    fn table2_batches_scale_with_capacity() {
        let r = table2();
        for model_rows in r.tables[0].rows.chunks(4) {
            let bs: Vec<usize> = model_rows.iter().map(|r| r[2].parse().unwrap()).collect();
            assert!(bs[0] < bs[2] && bs[2] <= bs[3], "{bs:?}");
        }
    }

    #[test]
    fn fig12_relative_column_positive() {
        let r = fig12();
        for row in r.tables[0].rows.iter().skip(1) {
            if row[1].contains("LDRAM only") {
                continue;
            }
            assert!(row[6].starts_with('+'), "{row:?}");
        }
    }
}
