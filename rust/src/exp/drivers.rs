//! End-to-end drivers: `cxlmem train` (ZeRO-Offload-coordinated training
//! through the real PJRT `train_step` artifact) and `cxlmem serve`
//! (FlexGen-style batched serving with the real decode-attention kernel).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::gpu::Gpu;
use crate::llm::batcher::{Batcher, Request};
use crate::llm::flexgen::{self, InferCfg};
use crate::llm::model_cfg::llama_65b;
use crate::memsim::{topology, MemKind};
use crate::runtime::{Arg, Runtime};
use crate::util::cli::Args;
use crate::util::rng::Rng;

/// Markov-chain synthetic corpus: each token has 4 likely successors,
/// so a trained model can reach ≈ ln(4) ≈ 1.39 nats; an untrained one
/// sits at ≈ ln(vocab).
pub struct Corpus {
    vocab: usize,
    successors: Vec<[u32; 4]>,
    rng: Rng,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let successors = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                    rng.below(vocab as u64) as u32,
                ]
            })
            .collect();
        Self {
            vocab,
            successors,
            rng,
        }
    }

    /// Sample a [batch, seq_plus_one] token block.
    pub fn batch(&mut self, batch: usize, seq_plus_one: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus_one);
        for _ in 0..batch {
            let mut tok = self.rng.below(self.vocab as u64) as u32;
            for _ in 0..seq_plus_one {
                out.push(tok as i32);
                // 90% chain transition, 10% noise.
                tok = if self.rng.chance(0.9) {
                    self.successors[tok as usize][self.rng.index(4)]
                } else {
                    self.rng.below(self.vocab as u64) as u32
                };
            }
        }
        out
    }
}

/// `cxlmem train`: run N steps of the AOT `train_step` artifact, logging
/// the loss curve, with ZeRO-Offload-style memory accounting against the
/// simulated system A.
pub fn train(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 300);
    let seed = args.get_u64("seed", 42);
    let log_every = args.get_usize("log-every", 10);

    let mut rt = Runtime::discover()
        .map_err(|e| anyhow!("artifacts missing ({e}); run `make artifacts`"))?;
    let meta = rt.manifest.model.clone();
    println!(
        "model: {} params, vocab {}, d_model {}, layers {}, batch {}, seq {}",
        meta.params, meta.vocab, meta.d_model, meta.layers, meta.batch, meta.seq
    );

    // Parameter init: normal(0, 0.02); ln scales live at the tail of the
    // flat vector but ones-init vs normal-init only changes early steps.
    let mut rng = Rng::seeded(seed);
    let mut params: Vec<f32> = (0..meta.params)
        .map(|_| 0.02 * rng.normal() as f32)
        .collect();
    let mut m = vec![0.0f32; meta.params];
    let mut v = vec![0.0f32; meta.params];
    let mut corpus = Corpus::new(meta.vocab, seed ^ 0xC0FFEE);

    // ZeRO-Offload memory accounting on simulated system A.
    let sys = topology::system_a();
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let placement = vec![(ld, 1.0)];
    let gpu = Gpu::a10();

    let exe = rt.load("train_step")?;
    let t0 = Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    let mut sim_xfer_s = 0.0f64;
    for step in 1..=steps {
        let tokens = corpus.batch(meta.batch, meta.seq + 1);
        let step_f = [step as f32];
        let out = exe.run(&[
            Arg::F32(&params),
            Arg::F32(&m),
            Arg::F32(&v),
            Arg::I32(&tokens),
            Arg::F32(&step_f),
        ])?;
        last_loss = out[0][0];
        params = out[1].clone();
        m = out[2].clone();
        v = out[3].clone();
        first_loss.get_or_insert(last_loss);
        // Simulated tensor-offload traffic: grads down + params up.
        sim_xfer_s += gpu.transfer_time_s(&sys, &placement, 2.0 * meta.params as f64) * 2.0;
        if step % log_every == 0 || step == 1 {
            println!(
                "step {step:>4}  loss {last_loss:.4}  ({:.2} s elapsed)",
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\ntrained {steps} steps in {wall:.1} s ({:.2} s/step); loss {:.4} -> {:.4}",
        wall / steps as f64,
        first_loss.unwrap_or(0.0),
        last_loss
    );
    println!(
        "simulated ZeRO-Offload transfer time (system A, LDRAM): {sim_xfer_s:.2} s for {steps} steps"
    );
    if last_loss >= first_loss.unwrap_or(f32::MAX) {
        return Err(anyhow!("loss did not decrease — training is broken"));
    }
    Ok(())
}

/// `cxlmem serve`: batched FlexGen-style serving; each decode step runs
/// the real Pallas decode-attention artifact, throughput/latency follow
/// the simulated offloading cost model.
pub fn serve(args: &Args) -> Result<()> {
    let n_requests = args.get_usize("requests", 24);
    let mut rt = Runtime::discover()
        .map_err(|e| anyhow!("artifacts missing ({e}); run `make artifacts`"))?;
    let exe = rt.load("decode_attn")?;
    let q_n = exe.spec.inputs[0].elements();
    let kv_n = exe.spec.inputs[1].elements();

    let sys = topology::system_a();
    let gpu = Gpu::a10();
    let cfg = InferCfg::paper(llama_65b());
    let tiers = flexgen::tiers_of(
        &sys,
        &[(MemKind::Ldram, 196e9), (MemKind::Cxl, 128e9)],
    );
    let pol = flexgen::search_policy(&gpu, &cfg, &tiers);
    let th = flexgen::throughput(&sys, &gpu, &cfg, &pol);
    println!(
        "offload policy: batch {}, {:.0}% of KV on GPU, decode {:.2} tok/s (simulated)",
        pol.batch,
        100.0 * pol.kv_gpu_frac,
        th.decode_tok_s
    );

    let mut rng = Rng::seeded(7);
    let mut batcher = Batcher::new(pol.batch);
    for i in 0..n_requests {
        batcher.submit(Request {
            id: i as u64,
            arrival_s: i as f64 * 0.2,
            prompt_len: cfg.prompt,
            gen_len: cfg.gen,
        });
    }

    let q: Vec<f32> = (0..q_n).map(|_| rng.normal() as f32 * 0.1).collect();
    let k: Vec<f32> = (0..kv_n).map(|_| rng.normal() as f32 * 0.1).collect();
    let v: Vec<f32> = (0..kv_n).map(|_| rng.normal() as f32 * 0.1).collect();

    let t0 = Instant::now();
    let mut kernel_calls = 0u64;
    while batcher.pending() > 0 {
        let batch = batcher.next_batch();
        if batch.is_empty() {
            continue;
        }
        // One real decode-attention kernel call stands in for the
        // per-step CPU attention of this batch.
        let out = exe.run(&[Arg::F32(&q), Arg::F32(&k), Arg::F32(&v)])?;
        assert!(out[0].iter().all(|x| x.is_finite()));
        kernel_calls += 1;
        // Simulated batch time: prefill + full decode for this batch.
        let batch_time = cfg.gen as f64 * batch.len() as f64 / th.decode_tok_s.max(1e-9)
            + cfg.prompt as f64 * batch.len() as f64 / th.prefill_tok_s.max(1e-9);
        batcher.complete(batch, batch_time);
    }
    let (mean_lat, p95, tput) = batcher.metrics();
    println!(
        "served {n_requests} requests; simulated mean latency {mean_lat:.1} s, p95 {p95:.1} s, throughput {tput:.2} tok/s"
    );
    println!(
        "real decode-attention kernel calls: {kernel_calls} ({:.1} ms wall)",
        t0.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}
