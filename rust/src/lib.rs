//! # cxlmem — Exploring and Evaluating Real-world CXL, reproduced
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *"Exploring and Evaluating Real-world CXL: Use Cases and System
//! Adoption"* (IPDPS 2025). The physical CXL testbeds are replaced by a
//! calibrated memory-system simulator ([`memsim`]); the LLM compute that
//! the paper offloads to the CPU runs for real through AOT-compiled
//! JAX/Pallas artifacts ([`runtime`]).
//!
//! Layer map:
//! - L3 (this crate): memory simulator, page placement policies, the
//!   paper's object-level interleaving, memory-tiering engines, HPC
//!   workload models, the ZeRO-Offload / FlexGen coordinators, and the
//!   experiment drivers that regenerate every figure and table.
//! - L2 (`python/compile/model.py`): JAX transformer fwd/bwd/train-step.
//! - L1 (`python/compile/kernels/`): Pallas kernels (fused ADAM, decode
//!   attention, tiled matmul), lowered with `interpret=True`.

pub mod bench;
pub mod engine;
pub mod exp;
pub mod gpu;
pub mod llm;
pub mod mem;
pub mod memsim;
pub mod perf;
pub mod probes;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod testkit;
pub mod tiering;
pub mod util;
pub mod workloads;
