//! The three tiering policies the paper evaluates (§VI) + No-Balance.
//!
//! All three consume NUMA hint faults; they differ in scan aggressiveness
//! and promotion criteria — exactly the axes the paper identifies:
//!
//! | policy      | scan                   | promotion criterion          |
//! |-------------|------------------------|------------------------------|
//! | AutoNUMA    | steady fraction        | any faulted slow page        |
//! | Tiering-0.8 | lazy (adaptive)        | re-fault hotness ≥ adaptive  |
//! |             |                        | threshold + traffic throttle |
//! | TPP         | aggressive, slow tier  | faulted + on active LRU      |

use super::stats::VmStats;
use super::PageState;

/// What a policy wants scanned this epoch.
#[derive(Clone, Copy, Debug)]
pub struct ScanRequest {
    /// Fraction of candidate pages to mark for hint faults.
    pub frac: f64,
    /// Restrict scanning to slow-tier pages (TPP-style).
    pub slow_tier_only: bool,
}

/// A page-migration policy driven by hint faults.
pub trait TieringPolicy {
    fn name(&self) -> &'static str;

    /// How much to scan this epoch.
    fn scan_request(&self, state: &PageState, stats: &VmStats) -> ScanRequest;

    /// Process this epoch's faults; perform promotions/demotions on
    /// `state`; return the number of 2 MB regions moved.
    fn epoch(
        &mut self,
        state: &mut PageState,
        counts: &[u32],
        faults: &[usize],
        stats: &mut VmStats,
    ) -> u64;
}

/// Static placement: no balancing, no migration (the paper's "No
/// Balance" baseline).
#[derive(Default)]
pub struct NoBalance;

impl TieringPolicy for NoBalance {
    fn name(&self) -> &'static str {
        "NoBalance"
    }

    fn scan_request(&self, _state: &PageState, _stats: &VmStats) -> ScanRequest {
        ScanRequest {
            frac: 0.0,
            slow_tier_only: false,
        }
    }

    fn epoch(
        &mut self,
        _state: &mut PageState,
        _counts: &[u32],
        _faults: &[usize],
        _stats: &mut VmStats,
    ) -> u64 {
        0
    }
}

/// AutoNUMA (`numa_balancing = 1`): steady scanning; every faulted page
/// that lives on the slow tier is promoted toward the accessing node.
pub struct AutoNuma {
    pub scan_frac: f64,
    /// Kernel migration rate limit (regions per epoch) — AutoNUMA
    /// throttles via `numa_balancing_rate_limit_mbps`.
    pub migrate_cap: usize,
}

impl Default for AutoNuma {
    fn default() -> Self {
        Self {
            scan_frac: 0.22,
            migrate_cap: 1200,
        }
    }
}

impl TieringPolicy for AutoNuma {
    fn name(&self) -> &'static str {
        "AutoNUMA"
    }

    fn scan_request(&self, _state: &PageState, _stats: &VmStats) -> ScanRequest {
        ScanRequest {
            frac: self.scan_frac,
            slow_tier_only: false,
        }
    }

    fn epoch(
        &mut self,
        state: &mut PageState,
        _counts: &[u32],
        faults: &[usize],
        stats: &mut VmStats,
    ) -> u64 {
        let mut cands: Vec<usize> = faults
            .iter()
            .copied()
            .filter(|&p| !state.on_fast(p))
            .collect();
        cands.truncate(self.migrate_cap);
        let (promoted, demoted) = state.promote_batch(&cands);
        stats.promoted_regions += promoted;
        stats.demoted_regions += demoted;
        promoted + demoted
    }
}

/// Tiering-0.8 (Linux AutoNUMA tiering patch, `numa_balancing = 2`):
/// lazy scanning (much fewer hint faults), hotness from re-fault
/// interval (approximated by the page's access count vs an adaptive
/// threshold), and promotion-rate throttling that adapts the threshold.
pub struct Tiering08 {
    pub scan_frac: f64,
    /// Current promotion hotness threshold (accesses/epoch).
    pub threshold: f64,
    /// Target promotions per epoch (migration-traffic budget).
    pub promote_budget: u64,
}

impl Default for Tiering08 {
    fn default() -> Self {
        Self {
            scan_frac: 0.02, // PMO 2: ~59× fewer hint faults than TPP
            threshold: 8.0,
            promote_budget: 600,
        }
    }
}

impl TieringPolicy for Tiering08 {
    fn name(&self) -> &'static str {
        "Tiering-0.8"
    }

    fn scan_request(&self, _state: &PageState, _stats: &VmStats) -> ScanRequest {
        ScanRequest {
            frac: self.scan_frac,
            slow_tier_only: false,
        }
    }

    fn epoch(
        &mut self,
        state: &mut PageState,
        counts: &[u32],
        faults: &[usize],
        stats: &mut VmStats,
    ) -> u64 {
        // Candidates: faulted slow pages whose hotness clears the
        // threshold ("re-faulted recently enough").
        let mut cands: Vec<usize> = faults
            .iter()
            .copied()
            .filter(|&p| !state.on_fast(p) && counts[p] as f64 >= self.threshold)
            .collect();
        let n_cands = cands.len();
        // Hottest first; respect the promotion budget. The key
        // `(Reverse(count), page)` is unique, so selecting the top-k
        // with `select_nth_unstable` then ordering just those k is
        // O(n + k log k) and picks exactly the set (and order) the
        // previous stable full sort produced.
        let budget = self.promote_budget as usize;
        if cands.len() > budget {
            stats.throttled += (cands.len() - budget) as u64;
            if budget == 0 {
                cands.clear();
            } else {
                cands.select_nth_unstable_by_key(budget - 1, |&p| {
                    (std::cmp::Reverse(counts[p]), p)
                });
                cands.truncate(budget);
            }
        }
        cands.sort_unstable_by_key(|&p| (std::cmp::Reverse(counts[p]), p));
        let (promoted, demoted) = state.promote_batch(&cands);
        stats.promoted_regions += promoted;
        stats.demoted_regions += demoted;
        let moved = promoted + demoted;
        // Adaptive threshold: promote rate above budget → raise the bar;
        // far below → lower it (down to 1 access).
        let promoted_f = n_cands.min(self.promote_budget as usize) as f64;
        if n_cands as u64 > self.promote_budget {
            self.threshold *= 1.5;
        } else if promoted_f < 0.25 * self.promote_budget as f64 {
            self.threshold = (self.threshold * 0.7).max(1.0);
        }
        moved
    }
}

/// TPP: aggressive slow-tier scanning; promote every faulted slow page
/// that sits on the (approximated) active LRU — i.e. was accessed in the
/// previous epoch too. High hint-fault volume is TPP's documented cost.
pub struct Tpp {
    pub scan_frac: f64,
    /// Demotion-watermark-driven migration budget (regions per epoch).
    pub migrate_cap: usize,
}

impl Default for Tpp {
    fn default() -> Self {
        Self {
            scan_frac: 1.0,
            migrate_cap: 2500,
        }
    }
}

impl TieringPolicy for Tpp {
    fn name(&self) -> &'static str {
        "TPP"
    }

    fn scan_request(&self, _state: &PageState, _stats: &VmStats) -> ScanRequest {
        ScanRequest {
            frac: self.scan_frac,
            slow_tier_only: true,
        }
    }

    fn epoch(
        &mut self,
        state: &mut PageState,
        _counts: &[u32],
        faults: &[usize],
        stats: &mut VmStats,
    ) -> u64 {
        // Active-LRU check: accessed last epoch as well.
        let mut cands: Vec<usize> = faults
            .iter()
            .copied()
            .filter(|&p| !state.on_fast(p) && state.last_counts[p] > 0)
            .collect();
        cands.truncate(self.migrate_cap);
        let (promoted, demoted) = state.promote_batch(&cands);
        stats.promoted_regions += promoted;
        stats.demoted_regions += demoted;
        promoted + demoted
    }
}

/// All evaluated policies, paper order, fresh instances.
pub fn all_policies() -> Vec<Box<dyn TieringPolicy>> {
    vec![
        Box::new(NoBalance),
        Box::new(AutoNuma::default()),
        Box::new(Tiering08::default()),
        Box::new(Tpp::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiering::initial_state;

    fn state() -> PageState {
        let mut s = initial_state(1000, 0, 2, 300, false);
        s.last_counts = vec![1; 1000];
        s
    }

    #[test]
    fn nobalance_never_moves() {
        let mut s = state();
        let mut st = VmStats::default();
        let faults: Vec<usize> = (300..400).collect();
        let moved = NoBalance.epoch(&mut s, &vec![10; 1000], &faults, &mut st);
        assert_eq!(moved, 0);
        assert_eq!(st, VmStats::default());
    }

    #[test]
    fn autonuma_promotes_faulted_slow_pages() {
        let mut s = state();
        let mut st = VmStats::default();
        let faults = vec![500, 600];
        let moved = AutoNuma::default().epoch(&mut s, &vec![1; 1000], &faults, &mut st);
        assert!(moved >= 2);
        assert_eq!(s.node_of(500), s.fast_node);
        assert_eq!(s.node_of(600), s.fast_node);
        assert_eq!(st.promoted_regions, 2);
    }

    #[test]
    fn tiering08_threshold_filters_cold_pages() {
        let mut s = state();
        let mut st = VmStats::default();
        let mut counts = vec![1u32; 1000]; // all below threshold 8
        counts[700] = 50; // one hot page
        let mut pol = Tiering08::default();
        let moved = pol.epoch(&mut s, &counts, &[500, 700], &mut st);
        assert_eq!(st.promoted_regions, 1);
        assert_eq!(s.node_of(700), s.fast_node);
        assert_ne!(s.node_of(500), s.fast_node);
        assert!(moved >= 1);
    }

    #[test]
    fn tiering08_throttles_and_adapts() {
        let mut s = initial_state(5000, 0, 2, 1000, false);
        s.last_counts = vec![1; 5000];
        let mut st = VmStats::default();
        let counts = vec![100u32; 5000];
        let faults: Vec<usize> = (2000..5000).collect(); // 3000 hot candidates
        let mut pol = Tiering08 {
            promote_budget: 100,
            ..Default::default()
        };
        let t0 = pol.threshold;
        pol.epoch(&mut s, &counts, &faults, &mut st);
        assert_eq!(st.promoted_regions, 100);
        assert!(st.throttled > 0);
        assert!(pol.threshold > t0, "threshold must rise under pressure");
    }

    #[test]
    fn tpp_requires_lru_presence() {
        let mut s = state();
        s.last_counts = vec![0; 1000]; // nothing on active LRU
        s.last_counts[800] = 5;
        let mut st = VmStats::default();
        let moved = Tpp::default().epoch(&mut s, &vec![10; 1000], &[700, 800], &mut st);
        assert_eq!(st.promoted_regions, 1);
        assert!(moved >= 1);
        assert_eq!(s.node_of(800), s.fast_node);
        assert_ne!(s.node_of(700), s.fast_node);
    }

    #[test]
    fn scan_aggressiveness_ordering() {
        // PMO 2 mechanism: t08 scans ≪ autonuma ≤ tpp.
        let s = state();
        let st = VmStats::default();
        let t08 = Tiering08::default().scan_request(&s, &st).frac;
        let an = AutoNuma::default().scan_request(&s, &st).frac;
        let tpp = Tpp::default().scan_request(&s, &st).frac;
        assert!(t08 < an && an <= tpp);
    }
}
