//! Memory tiering based on page migration (§VI).
//!
//! An epoch-based page-granular simulator: each epoch the application
//! produces per-page access counts (from `workloads::tiering_apps` traces
//! or from HPC object traffic); the tiering policy samples accesses
//! through NUMA *hint faults* and promotes/demotes pages between the
//! fast tier (LDRAM) and the slow tier (CXL); epoch execution time comes
//! from the same engine cost model as §V plus fault/migration overheads.
//!
//! The paper's key mechanisms are modeled faithfully:
//! - hint faults only fire on *migratable* VMAs — pages under an explicit
//!   interleave policy never fault (PMO 3: interleaving + migration do
//!   not compose);
//! - Tiering-0.8 samples lazily and throttles promotion with an adaptive
//!   threshold (59× fewer faults than TPP, PMO 2);
//! - TPP scans the slow tier aggressively and promotes on LRU presence;
//! - AutoNUMA promotes any faulted slow page.
//!
//! Hot-path structure: the per-epoch work is O(Δ) in the number of
//! migrations plus a single O(pages) pass per epoch to ingest the new
//! access histogram —
//! - the page placement state is structure-of-arrays: one packed `u32`
//!   column carries each page's node id plus a "pinned" bit (set for
//!   unmigratable pages), so the victim scan in [`PageState::promote_batch`]
//!   and the candidate filter in [`sample_hint_faults`] stream a single
//!   narrow column linearly instead of two pointer-width ones;
//! - `fast_used` is an incrementally-maintained counter (was an O(pages)
//!   recount per promotion batch);
//! - per-(object, node) traffic aggregates are built once per epoch and
//!   updated on each migration (was a full O(pages) rebuild inside
//!   [`epoch_app_time`]);
//! - victim selection uses `select_nth_unstable` (was a full sort);
//! - hint-fault sampling uses geometric skip sampling (one RNG draw per
//!   *fault* instead of one per candidate page);
//! - [`simulate_trace`] replays a shared immutable
//!   [`crate::workloads::trace::EpochTrace`] snapshot through a
//!   [`crate::workloads::trace::TraceCursor`] (delta-encoded snapshots
//!   materialize into the cursor's single reusable buffer; dense ones
//!   are handed out as direct slices), eliminating the per-epoch
//!   histogram copy the producer path pays (and, through the trace
//!   store, the per-cell regeneration an entire grid pays);
//! - at [`PAR_MIN_PAGES`] pages and above — the million-page regime —
//!   the remaining O(pages) epoch passes run *chunked* over
//!   [`crate::util::par`] when the caller configured `--jobs > 1`: the
//!   [`PageState::promote_batch`] victim scan keeps each chunk's
//!   `need`-smallest candidates via `select_nth_unstable` and
//!   rank-merges the per-chunk winners (the global k-smallest set under
//!   the strict total order `(last_counts, page)` is unique, so the
//!   merged result is bit-identical to the sequential scan); the
//!   [`sample_hint_faults`] candidate filter collects candidates per
//!   chunk and then jump-selects over the concatenated list with the
//!   same geometric-skip draws the streaming walk makes (identical RNG
//!   consumption ⇒ identical fault sets); and
//!   [`PageState::set_epoch_counts`] accumulates per-chunk integer
//!   aggregates summed at the end (u64 adds — order-free). Below the
//!   threshold everything stays sequential, so 65k-page paper runs
//!   don't pay thread fan-out; inside a `par_map` grid cell worker
//!   `jobs` is pinned to 1, so grids never nest parallelism.
//!
//! Under [`crate::perf::with_reference`] the seed's O(pages)
//! implementations run instead; they make identical decisions (see the
//! golden-parity tests), so the mode only changes cost, not results.
//! One deliberate semantic change relative to the seed: both modes share
//! the geometric-skip sampler, whose RNG *realization* differs from the
//! seed's per-page Bernoulli draws (the fault distribution is identical,
//! but individual fault sets — and hence fig16/fig17 cell values — are
//! a different draw from the same process).

pub mod policies;
pub mod stats;

use crate::engine::{self, ObjectTraffic, RunConfig};
use crate::memsim::{NodeId, Pattern, System};
use crate::util::metrics;
use crate::util::par::{chunk_ranges, par_map};
use crate::util::rng::Rng;
use crate::workloads::trace::EpochTrace;

pub use policies::{AutoNuma, NoBalance, Tiering08, TieringPolicy, Tpp};
pub use stats::VmStats;

/// Cost of one hint fault (ns): trap + PTE walk + bookkeeping.
pub const HINT_FAULT_NS: f64 = 1_500.0;
/// Cost of migrating one 2 MB region (ns): ~2 MB over ~1.6 GB/s effective
/// migration bandwidth, incl. unmap/copy/remap.
pub const MIGRATE_REGION_NS: f64 = 1_250_000.0;
/// 4 KB pages per 2 MB region (for vmstat-style counters).
pub const SMALL_PER_REGION: u64 = 512;

/// Packed-column "pinned" bit: set when the kernel may not migrate the
/// page (explicit interleave/membind policies).
const PIN: u32 = 1 << 31;
/// Packed-column node mask (low 31 bits).
const NODE_MASK: u32 = PIN - 1;

/// Page count below which the chunked-parallel epoch passes stay
/// sequential. At the paper's 65k pages a linear `u32` scan is a few
/// tens of microseconds — thread fan-out would cost more than it saves —
/// while at millions of pages the scan dominates the epoch. 2^18 pages
/// (= 512 GB of 2 MB regions) is comfortably past the break-even on
/// both counts.
pub const PAR_MIN_PAGES: usize = 1 << 18;

thread_local! {
    /// Test/bench override of [`PAR_MIN_PAGES`] (see
    /// [`with_par_min_pages`]).
    static PAR_MIN: std::cell::Cell<usize> = std::cell::Cell::new(PAR_MIN_PAGES);
}

/// Run `f` with the chunked-parallel page threshold lowered to `min` on
/// this thread (restored on exit, also on panic). Lets the parity tests
/// and benches exercise the chunked paths at small page counts.
pub fn with_par_min_pages<R>(min: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            PAR_MIN.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(PAR_MIN.with(|c| c.get()));
    PAR_MIN.with(|c| c.set(min));
    f()
}

/// `Some(jobs)` when an O(pages) epoch pass over `pages` pages should
/// run chunked: the caller raised `--jobs`, we are not in reference
/// mode (the reference is the sequential seed), and the page count is
/// past the fan-out break-even. `par_map` pins worker `jobs` to 1, so
/// grid cells running inside a sweep never nest.
fn par_chunks(pages: usize) -> Option<usize> {
    let jobs = crate::perf::current_jobs();
    if jobs > 1 && !crate::perf::reference_enabled() && pages >= PAR_MIN.with(|c| c.get()) {
        tiering_metrics().par_dispatches.inc();
        Some(jobs)
    } else {
        None
    }
}

/// Registry handles for tiering instrumentation, resolved once per
/// process. Recorded only off the reference path — the seed-semantics
/// baseline stays untouched (see the parity test in `tests/metrics.rs`).
struct TieringMetrics {
    epochs: &'static metrics::Counter,
    hint_faults: &'static metrics::Counter,
    migrated_regions: &'static metrics::Counter,
    par_dispatches: &'static metrics::Counter,
    epoch_ns: &'static metrics::Histogram,
}

fn tiering_metrics() -> &'static TieringMetrics {
    static M: std::sync::OnceLock<TieringMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| TieringMetrics {
        epochs: metrics::counter("tiering.epochs"),
        hint_faults: metrics::counter("tiering.hint_faults"),
        migrated_regions: metrics::counter("tiering.migrated_regions"),
        par_dispatches: metrics::counter("tiering.par_dispatches"),
        epoch_ns: metrics::histogram("tiering.epoch_ns"),
    })
}

/// Per-epoch ingested access histogram + per-(object, node) aggregates,
/// kept consistent across migrations so epoch app time is O(objects ×
/// nodes) instead of O(pages).
#[derive(Clone, Debug, Default)]
struct EpochAgg {
    /// Node count the aggregate was built for.
    nn: usize,
    /// This epoch's per-page access counts (owned copy; buffers reused).
    counts: Vec<u32>,
    /// Address of the slice that was ingested — a fast-path identity
    /// hint for the staleness check in [`epoch_app_time`].
    src_ptr: usize,
    /// Flattened [object][node] access totals. Integer-valued, so the
    /// incremental ± updates are exact and bit-identical to a rebuild.
    agg: Vec<u64>,
}

/// Page-granular placement state shared with the policies, held as
/// structure-of-arrays.
///
/// Columns (all `pages` long):
/// - `page` (private, packed `u32`): node id in the low bits, [`PIN`] set
///   for unmigratable pages. The victim scan (`page[p] == fast_node`,
///   one compare for "on the fast tier *and* migratable") and the
///   hint-fault candidate filter stream this single column.
/// - `object` (`u32`): object index per page (multi-object HPC runs).
/// - `last_counts` (`u32`, public): last epoch's access count per page —
///   the policies' LRU/recency signal ("heat").
///
/// Placement is inspected through [`PageState::node_of`] /
/// [`PageState::migratable`] / [`PageState::on_fast`]; *placement
/// changes must go through [`PageState::promote`] /
/// [`PageState::promote_batch`]* (and object remapping through
/// [`PageState::set_objects`]) so the incremental `fast_used` counter
/// and epoch aggregates stay consistent.
#[derive(Clone, Debug)]
pub struct PageState {
    /// Packed placement column: `node | PIN?` per page.
    page: Vec<u32>,
    /// Object index of each page.
    object: Vec<u32>,
    /// Fast tier node and its capacity in pages.
    pub fast_node: NodeId,
    pub fast_capacity: usize,
    /// Slow tier node (demotion target).
    pub slow_node: NodeId,
    /// Last-epoch access count per page (policy LRU/recency signal).
    pub last_counts: Vec<u32>,
    /// Incremental count of pages on `fast_node`.
    fast_used: usize,
    /// Number of distinct objects (`max(object) + 1`), fixed at
    /// construction / [`PageState::set_objects`] — the per-epoch
    /// O(pages) max scan the seed did is gone.
    n_obj: usize,
    /// Current epoch's histogram + aggregates (None between epochs).
    epoch: Option<EpochAgg>,
}

impl PageState {
    /// Build a state from explicit page maps; derives `fast_used` and the
    /// object count once, here, instead of per epoch.
    pub fn new(
        node: Vec<NodeId>,
        migratable: Vec<bool>,
        object: Vec<u32>,
        fast_node: NodeId,
        fast_capacity: usize,
        slow_node: NodeId,
    ) -> PageState {
        assert_eq!(node.len(), migratable.len());
        assert_eq!(node.len(), object.len());
        assert!(fast_node < PIN as usize && slow_node < PIN as usize);
        let page: Vec<u32> = node
            .iter()
            .zip(&migratable)
            .map(|(&n, &m)| {
                assert!(n < PIN as usize, "node id {n} overflows the packed column");
                if m {
                    n as u32
                } else {
                    n as u32 | PIN
                }
            })
            .collect();
        let fast_used = page
            .iter()
            .filter(|&&v| v & NODE_MASK == fast_node as u32)
            .count();
        let n_obj = object.iter().map(|&o| o as usize + 1).max().unwrap_or(1);
        let pages = page.len();
        PageState {
            page,
            object,
            fast_node,
            fast_capacity,
            slow_node,
            last_counts: vec![0; pages],
            fast_used,
            n_obj,
            epoch: None,
        }
    }

    /// Number of pages.
    pub fn len(&self) -> usize {
        self.page.len()
    }

    pub fn is_empty(&self) -> bool {
        self.page.is_empty()
    }

    /// Current node of `p`.
    #[inline]
    pub fn node_of(&self, p: usize) -> NodeId {
        (self.page[p] & NODE_MASK) as usize
    }

    /// Whether the kernel may migrate `p`.
    #[inline]
    pub fn migratable(&self, p: usize) -> bool {
        self.page[p] & PIN == 0
    }

    /// Whether `p` currently sits on the fast tier.
    #[inline]
    pub fn on_fast(&self, p: usize) -> bool {
        self.page[p] & NODE_MASK == self.fast_node as u32
    }

    /// Pages currently on the fast tier — O(1), maintained incrementally.
    pub fn fast_used(&self) -> usize {
        self.fast_used
    }

    /// Number of distinct objects (`max(object) + 1`).
    pub fn n_obj(&self) -> usize {
        self.n_obj
    }

    /// Replace the page→object map (multi-object HPC runs), recomputing
    /// the object count once.
    pub fn set_objects(&mut self, object: Vec<u32>) {
        assert_eq!(object.len(), self.page.len());
        self.n_obj = object.iter().map(|&o| o as usize + 1).max().unwrap_or(1);
        self.object = object;
        self.epoch = None;
    }

    /// Ingest this epoch's access histogram: one O(pages) pass over the
    /// narrow columns that makes every later placement change an O(1)
    /// aggregate update. Past [`PAR_MIN_PAGES`] with `--jobs > 1` the
    /// pass runs chunked, each chunk filling its own aggregate table
    /// summed at the end — u64 adds over a fixed partition, so the
    /// result is bit-identical to the sequential pass.
    pub(crate) fn set_epoch_counts(&mut self, counts: &[u32], nn: usize) {
        debug_assert_eq!(counts.len(), self.page.len());
        let n_obj = self.n_obj;
        let (page, object) = (&self.page, &self.object);
        let epoch = self.epoch.get_or_insert_with(EpochAgg::default);
        epoch.nn = nn;
        epoch.src_ptr = counts.as_ptr() as usize;
        epoch.counts.clear();
        epoch.counts.extend_from_slice(counts);
        epoch.agg.clear();
        epoch.agg.resize(n_obj * nn, 0);
        if let Some(jobs) = par_chunks(counts.len()) {
            let ranges = chunk_ranges(counts.len(), jobs);
            let parts = par_map(&ranges, jobs, |r| {
                let mut agg = vec![0u64; n_obj * nn];
                for p in r.clone() {
                    agg[object[p] as usize * nn + (page[p] & NODE_MASK) as usize] +=
                        counts[p] as u64;
                }
                agg
            });
            for part in parts {
                for (a, b) in epoch.agg.iter_mut().zip(part) {
                    *a += b;
                }
            }
        } else {
            for p in 0..counts.len() {
                epoch.agg[object[p] as usize * nn + (page[p] & NODE_MASK) as usize] +=
                    counts[p] as u64;
            }
        }
    }

    /// Move one page, maintaining `fast_used` and the epoch aggregates.
    /// The pinned bit travels with the page.
    fn move_page(&mut self, p: usize, to: NodeId) {
        let v = self.page[p];
        let from = (v & NODE_MASK) as usize;
        if from == to {
            return;
        }
        if from == self.fast_node {
            self.fast_used -= 1;
        }
        if to == self.fast_node {
            self.fast_used += 1;
        }
        if let Some(epoch) = self.epoch.as_mut() {
            let c = epoch.counts[p] as u64;
            if c > 0 {
                let row = self.object[p] as usize * epoch.nn;
                epoch.agg[row + from] -= c;
                epoch.agg[row + to] += c;
            }
        }
        self.page[p] = (v & PIN) | to as u32;
    }

    /// Promote `page` to the fast tier, demoting the coldest fast page if
    /// the tier is full. Returns number of regions moved (1 or 2).
    pub fn promote(&mut self, page: usize) -> u64 {
        let (p, d) = self.promote_batch(&[page]);
        p + d
    }

    /// Promote a batch of pages, demoting the coldest migratable
    /// fast-tier pages as needed. Returns (promoted_regions,
    /// demoted_regions).
    ///
    /// The victim scan is a linear pass over the packed column
    /// (`page[p] == fast_node` ⇔ fast-tier *and* migratable); selection
    /// is O(pages) via `select_nth_unstable` with the deterministic key
    /// `(last_counts, page)` — the same victims the seed's stable full
    /// sort picked, without the O(n log n). Past [`PAR_MIN_PAGES`] with
    /// `--jobs > 1` the scan runs chunked (see
    /// [`PageState::select_victims`]) with bit-identical results.
    pub fn promote_batch(&mut self, pages: &[usize]) -> (u64, u64) {
        if crate::perf::reference_enabled() {
            return self.promote_batch_reference(pages);
        }
        // Migratable fast-tier cells are exactly the value `fast` (pin
        // bit clear), so the victim scan below is a one-compare stream.
        let fast = self.fast_node as u32;
        let want: Vec<usize> = pages
            .iter()
            .copied()
            .filter(|&p| self.page[p] & NODE_MASK != fast)
            .collect();
        if want.is_empty() {
            return (0, 0);
        }
        let free = self.fast_capacity.saturating_sub(self.fast_used);
        let need_demote = want.len().saturating_sub(free);
        let mut demoted = 0u64;
        if need_demote > 0 {
            let victims = self.select_victims(need_demote, fast);
            demoted = victims.len() as u64;
            for &v in &victims {
                self.move_page(v, self.slow_node);
            }
        }
        // Promote as many as now fit.
        let capacity_now = self.fast_capacity.saturating_sub(self.fast_used);
        let mut promoted = 0u64;
        for &p in want.iter().take(capacity_now) {
            self.move_page(p, self.fast_node);
            promoted += 1;
        }
        (promoted, demoted)
    }

    /// The `need` coldest migratable fast-tier pages (all of them if
    /// fewer exist), under the strict total order `(last_counts, page)`.
    ///
    /// Chunked path: each chunk scans its range, keeps only its own
    /// `need`-smallest candidates (per-chunk `select_nth_unstable` — the
    /// global winners are necessarily among them), and a final select
    /// over the concatenated survivors picks the true k-smallest. The
    /// key is a strict total order, so the selected *set* is unique and
    /// the result is bit-identical to the sequential scan however the
    /// pages were chunked; the per-victim [`PageState::move_page`]
    /// bookkeeping (`fast_used` ±1, u64 aggregate ±) commutes, so the
    /// in-set order select leaves behind never matters.
    fn select_victims(&self, need: usize, fast: u32) -> Vec<usize> {
        debug_assert!(need > 0);
        let key = |p: usize| (self.last_counts[p], p);
        let mut victims: Vec<usize> = match par_chunks(self.page.len()) {
            Some(jobs) => {
                let ranges = chunk_ranges(self.page.len(), jobs);
                par_map(&ranges, jobs, |r| {
                    let mut part: Vec<usize> =
                        r.clone().filter(|&p| self.page[p] == fast).collect();
                    if need < part.len() {
                        part.select_nth_unstable_by_key(need - 1, |&p| key(p));
                        part.truncate(need);
                    }
                    part
                })
                .concat()
            }
            None => self
                .page
                .iter()
                .enumerate()
                .filter(|&(_, &v)| v == fast)
                .map(|(p, _)| p)
                .collect(),
        };
        if need < victims.len() {
            victims.select_nth_unstable_by_key(need - 1, |&p| key(p));
            victims.truncate(need);
        }
        victims
    }

    /// The seed's promotion batch, verbatim: O(pages) `fast_used`
    /// recounts and a full victim sort. Identical decisions to the
    /// optimized path; kept as the `cxlmem bench` baseline.
    fn promote_batch_reference(&mut self, pages: &[usize]) -> (u64, u64) {
        // Reference mode bypasses the incremental bookkeeping entirely.
        self.epoch = None;
        let fast = self.fast_node as u32;
        let recount =
            |page: &[u32], fast: u32| page.iter().filter(|&&v| v & NODE_MASK == fast).count();
        let want: Vec<usize> = pages
            .iter()
            .copied()
            .filter(|&p| self.page[p] & NODE_MASK != fast)
            .collect();
        if want.is_empty() {
            return (0, 0);
        }
        let free = self.fast_capacity.saturating_sub(recount(&self.page, fast));
        let need_demote = want.len().saturating_sub(free);
        let mut demoted = 0u64;
        if need_demote > 0 {
            let mut victims: Vec<usize> = (0..self.page.len())
                .filter(|&p| self.page[p] == fast)
                .collect();
            victims.sort_by_key(|&p| self.last_counts[p]);
            victims.truncate(need_demote);
            for &v in &victims {
                self.page[v] = (self.page[v] & PIN) | self.slow_node as u32;
            }
            demoted = victims.len() as u64;
        }
        let capacity_now = self.fast_capacity.saturating_sub(recount(&self.page, fast));
        let mut promoted = 0u64;
        for &p in want.iter().take(capacity_now) {
            self.page[p] = (self.page[p] & PIN) | fast;
            promoted += 1;
        }
        // Keep the incremental counter coherent for later optimized use.
        self.fast_used = recount(&self.page, fast);
        (promoted, demoted)
    }
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct TieringRun {
    pub policy: String,
    pub placement: String,
    pub total_s: f64,
    pub app_s: f64,
    pub overhead_s: f64,
    pub stats: VmStats,
}

/// Per-epoch workload view handed to the simulator.
pub struct EpochWorkload<'a> {
    /// Per-page access counts this epoch.
    pub counts: &'a [u32],
    /// Pattern and dependent fraction per object index.
    pub pattern: &'a dyn Fn(u32) -> (Pattern, f64),
}

/// Simulator configuration.
pub struct SimConfig {
    pub socket: usize,
    pub threads: usize,
    pub compute_ns_per_byte: f64,
    pub epochs: usize,
    pub seed: u64,
}

/// Hint-fault sampling: the policy asks for a scan fraction; faults fire
/// for scanned+accessed+migratable pages. Returns faulted page indices.
///
/// Sampling is geometric-skip: instead of one Bernoulli draw per
/// candidate page, one draw per *fault* yields the number of candidates
/// to skip — the two processes have identical distributions, but at
/// Tiering-0.8's 2% scan rate this is ~50× fewer RNG calls (and zero
/// calls at TPP's scan rate of 1.0). Both the optimized and reference
/// tiering paths share this sampler, so their decisions are identical.
/// The candidate filter reads the packed placement column: one `u32`
/// stream answers "migratable?" and "on the fast tier?" at once.
pub fn sample_hint_faults(
    state: &PageState,
    counts: &[u32],
    scan_frac: f64,
    slow_tier_only: bool,
    rng: &mut Rng,
) -> Vec<usize> {
    let mut faults = Vec::new();
    sample_hint_faults_into(state, counts, scan_frac, slow_tier_only, rng, &mut faults);
    faults
}

/// [`sample_hint_faults`] into a caller-owned buffer (cleared first).
/// [`epoch_step`] threads one scratch vector through the whole run, so
/// a simulation performs no per-epoch fault allocation.
///
/// Past [`PAR_MIN_PAGES`] with `--jobs > 1` the candidate filter runs
/// chunked: each chunk collects its candidate pages, and the geometric
/// skips then *jump* over the concatenated candidate list instead of
/// streaming it. The jump consumes the RNG exactly as the streaming
/// walk does — one draw up front, then one per emitted fault — so the
/// fault set (and every later draw in the epoch) is bit-identical to
/// the sequential path.
pub fn sample_hint_faults_into(
    state: &PageState,
    counts: &[u32],
    scan_frac: f64,
    slow_tier_only: bool,
    rng: &mut Rng,
    faults: &mut Vec<usize>,
) {
    faults.clear();
    if scan_frac <= 0.0 {
        return;
    }
    let full = scan_frac >= 1.0;
    let ln_q = if full { 0.0 } else { (1.0 - scan_frac).ln() };
    let fast_key = state.fast_node as u32;
    // `v == fast_key` ⇔ migratable (PIN clear) and on the fast node.
    let is_candidate = |p: usize| {
        let v = state.page[p];
        counts[p] != 0 && v & PIN == 0 && !(slow_tier_only && v == fast_key)
    };
    if let Some(jobs) = par_chunks(counts.len()) {
        let ranges = chunk_ranges(counts.len(), jobs);
        let parts = par_map(&ranges, jobs, |r| {
            r.clone().filter(|&p| is_candidate(p)).collect::<Vec<usize>>()
        });
        if full {
            for part in &parts {
                faults.extend_from_slice(part);
            }
            return;
        }
        // Jump selection: candidate i is the same page the streaming
        // walk would see i-th, so `i = skip0; emit; i += 1 + skip…`
        // replays the walk's draw sequence verbatim.
        let mut i = geometric_skip(rng, ln_q);
        let mut base = 0usize;
        for part in &parts {
            while i < base + part.len() {
                faults.push(part[i - base]);
                i += 1 + geometric_skip(rng, ln_q);
            }
            base += part.len();
        }
        return;
    }
    let mut skip = if full { 0 } else { geometric_skip(rng, ln_q) };
    for p in 0..counts.len() {
        if !is_candidate(p) {
            continue;
        }
        if full {
            faults.push(p);
        } else if skip == 0 {
            faults.push(p);
            skip = geometric_skip(rng, ln_q);
        } else {
            skip -= 1;
        }
    }
}

/// Failures before the next success of a Bernoulli(p) process, via
/// inversion: `floor(ln(1-U) / ln(1-p))`.
fn geometric_skip(rng: &mut Rng, ln_q: f64) -> usize {
    let u = rng.f64();
    let x = (1.0 - u).ln() / ln_q;
    if x.is_finite() {
        x as usize // saturating cast
    } else {
        usize::MAX / 2
    }
}

/// Execute one epoch's application time given current placement.
///
/// When the state carries this epoch's aggregates (set by [`simulate`] /
/// [`simulate_trace`]), this is O(objects × nodes); otherwise
/// (standalone calls, reference mode) it falls back to a full O(pages)
/// aggregation.
pub fn epoch_app_time(
    sys: &System,
    cfg: &SimConfig,
    state: &PageState,
    wl: &EpochWorkload,
) -> f64 {
    let nn = sys.nodes.len();
    let objects = if crate::perf::reference_enabled() {
        object_traffic_reference(sys, state, wl)
    } else {
        match &state.epoch {
            // The aggregates are only valid for the histogram they were
            // built from: accept on slice identity (the simulate() fast
            // path), else on content equality (a cheap memcmp); anything
            // else falls through to a fresh aggregation.
            Some(e)
                if e.nn == nn
                    && e.counts.len() == wl.counts.len()
                    && (e.src_ptr == wl.counts.as_ptr() as usize
                        || e.counts == wl.counts) =>
            {
                object_traffic_from_agg(&e.agg, state.n_obj, nn, wl)
            }
            _ => {
                let mut agg = vec![0u64; state.n_obj * nn];
                for p in 0..wl.counts.len() {
                    agg[state.object[p] as usize * nn + (state.page[p] & NODE_MASK) as usize] +=
                        wl.counts[p] as u64;
                }
                object_traffic_from_agg(&agg, state.n_obj, nn, wl)
            }
        }
    };
    let rcfg = RunConfig {
        socket: cfg.socket,
        threads: cfg.threads,
        compute_ns_per_byte: cfg.compute_ns_per_byte,
    };
    engine::run(sys, &rcfg, &objects).total_s
}

/// Build the engine's object traffic from flattened [object][node]
/// aggregates. Aggregates are integer totals, so this produces exactly
/// the values the seed's per-page f64 accumulation produced.
fn object_traffic_from_agg(
    agg: &[u64],
    n_obj: usize,
    nn: usize,
    wl: &EpochWorkload,
) -> Vec<ObjectTraffic> {
    let mut objects = Vec::new();
    for oi in 0..n_obj {
        let row = &agg[oi * nn..(oi + 1) * nn];
        let total: u64 = row.iter().sum();
        if total == 0 {
            continue;
        }
        let total_f = total as f64;
        let (pattern, dep) = (wl.pattern)(oi as u32);
        objects.push(ObjectTraffic {
            name: format!("obj{oi}"),
            traffic_bytes: total_f * crate::memsim::LINE,
            pattern,
            dep_frac: dep,
            node_weights: row
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0)
                .map(|(n, &c)| (n, c as f64 / total_f))
                .collect(),
        });
    }
    objects
}

/// The seed's per-epoch aggregation, verbatim: O(pages) object-count max
/// plus a full per-page pass. Baseline for `cxlmem bench`.
fn object_traffic_reference(
    sys: &System,
    state: &PageState,
    wl: &EpochWorkload,
) -> Vec<ObjectTraffic> {
    let n_obj = state.object.iter().map(|&o| o as usize + 1).max().unwrap_or(1);
    let nn = sys.nodes.len();
    let mut per = vec![vec![0.0f64; nn]; n_obj];
    for p in 0..wl.counts.len() {
        per[state.object[p] as usize][state.node_of(p)] += wl.counts[p] as f64;
    }
    let mut objects = Vec::new();
    for (oi, nodes) in per.iter().enumerate() {
        let total: f64 = nodes.iter().sum();
        if total <= 0.0 {
            continue;
        }
        let (pattern, dep) = (wl.pattern)(oi as u32);
        objects.push(ObjectTraffic {
            name: format!("obj{oi}"),
            traffic_bytes: total * crate::memsim::LINE,
            pattern,
            dep_frac: dep,
            node_weights: nodes
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0.0)
                .map(|(n, &c)| (n, c / total))
                .collect(),
        });
    }
    objects
}

/// One epoch of (faults → policy decision → migration → app time) —
/// the body both [`simulate`] and [`simulate_trace`] drive, so a trace
/// replay is bit-identical to the live producer by construction.
/// `faults` is a run-long scratch buffer (cleared and refilled here),
/// so no epoch allocates a fresh fault vector.
#[allow(clippy::too_many_arguments)]
fn epoch_step(
    sys: &System,
    cfg: &SimConfig,
    state: &mut PageState,
    policy: &mut dyn TieringPolicy,
    counts: &[u32],
    pattern: &dyn Fn(u32) -> (Pattern, f64),
    nn: usize,
    rng: &mut Rng,
    faults: &mut Vec<usize>,
    stats: &mut VmStats,
    app_s: &mut f64,
    overhead_s: &mut f64,
) {
    // Instrumentation stays off the parity-pinned reference path: no
    // clock read, no counter writes when the seed baseline runs.
    let t0 = if crate::perf::reference_enabled() {
        None
    } else {
        Some(std::time::Instant::now())
    };
    // 1. policy observes + migrates
    let scan = policy.scan_request(state, stats);
    sample_hint_faults_into(state, counts, scan.frac, scan.slow_tier_only, rng, faults);
    stats.hint_faults += faults.len() as u64;
    if !crate::perf::reference_enabled() {
        // Ingest the histogram once; migrations below keep the
        // (object, node) aggregates consistent in O(Δ).
        state.set_epoch_counts(counts, nn);
    }
    let moved_regions = policy.epoch(state, counts, faults, stats);
    stats.migrated_pages += moved_regions * SMALL_PER_REGION;
    // 2. overheads (parallelized across threads)
    *overhead_s += (faults.len() as f64 * HINT_FAULT_NS
        + moved_regions as f64 * MIGRATE_REGION_NS)
        / cfg.threads as f64
        / 1e9;
    // 3. application time under the (new) placement
    let wl = EpochWorkload { counts, pattern };
    *app_s += epoch_app_time(sys, cfg, state, &wl);
    // 4. recency state for next epoch
    state.last_counts.copy_from_slice(counts);
    if let Some(t0) = t0 {
        let m = tiering_metrics();
        m.epochs.inc();
        m.hint_faults.add(faults.len() as u64);
        m.migrated_regions.add(moved_regions);
        m.epoch_ns
            .record(t0.elapsed().as_nanos().min(u64::MAX as u128) as u64);
    }
}

/// Run the full tiering simulation: `epochs` epochs of (trace → faults →
/// policy decision → migration → app time).
///
/// `next_epoch` fills a buffer owned by the simulator with that epoch's
/// per-page access counts; the buffer is reused across epochs, so the
/// whole run performs no per-epoch histogram allocation
/// ([`crate::workloads::tiering_apps::TraceGen::epoch_counts_into`] is
/// the canonical producer). This is the bit-parity reference for
/// [`simulate_trace`], which replays a shared immutable snapshot
/// instead of producing each epoch.
pub fn simulate(
    sys: &System,
    cfg: &SimConfig,
    state: &mut PageState,
    policy: &mut dyn TieringPolicy,
    mut next_epoch: impl FnMut(usize, &mut Vec<u32>),
    pattern: impl Fn(u32) -> (Pattern, f64),
) -> TieringRun {
    let mut rng = Rng::seeded(cfg.seed);
    let mut stats = VmStats::default();
    let mut app_s = 0.0;
    let mut overhead_s = 0.0;
    let nn = sys.nodes.len();
    let mut counts: Vec<u32> = Vec::new();
    let mut faults: Vec<usize> = Vec::new();

    for e in 0..cfg.epochs {
        // Cooperative deadline checkpoint: a worker cancelled by the
        // supervision watchdog abandons the run at the next epoch
        // boundary (the partial result is discarded by the caller).
        // Free when no token is installed — nothing shared is read.
        if crate::util::cancel::cancelled() {
            break;
        }
        next_epoch(e, &mut counts);
        epoch_step(
            sys,
            cfg,
            state,
            policy,
            &counts,
            &pattern,
            nn,
            &mut rng,
            &mut faults,
            &mut stats,
            &mut app_s,
            &mut overhead_s,
        );
    }
    // Drop the last epoch's aggregates: they are only valid for the
    // histogram passed alongside them, and a later standalone
    // `epoch_app_time` call would otherwise silently reuse them.
    state.epoch = None;

    TieringRun {
        policy: policy.name().to_string(),
        placement: String::new(),
        total_s: app_s + overhead_s,
        app_s,
        overhead_s,
        stats,
    }
}

/// [`simulate`] over a shared immutable trace snapshot: each epoch
/// replays through a [`crate::workloads::trace::TraceCursor`] — dense
/// snapshots are read in place with no per-epoch histogram production
/// or copy at all; delta-encoded snapshots patch forward into the
/// cursor's single reusable buffer (O(drift) per epoch) — driving the
/// exact same epoch body as the producer path, so results are
/// bit-identical (pinned by test). This is the path every fig16/fig17
/// grid cell and fleet member takes; the snapshot usually comes from
/// [`crate::workloads::trace::global`].
pub fn simulate_trace(
    sys: &System,
    cfg: &SimConfig,
    state: &mut PageState,
    policy: &mut dyn TieringPolicy,
    trace: &EpochTrace,
    pattern: impl Fn(u32) -> (Pattern, f64),
) -> TieringRun {
    assert_eq!(trace.pages(), state.len(), "trace/page-state size mismatch");
    assert!(
        trace.epochs() >= cfg.epochs,
        "trace holds {} epochs, run wants {}",
        trace.epochs(),
        cfg.epochs
    );
    let mut rng = Rng::seeded(cfg.seed);
    let mut stats = VmStats::default();
    let mut app_s = 0.0;
    let mut overhead_s = 0.0;
    let nn = sys.nodes.len();
    let mut cursor = trace.cursor();
    let mut faults: Vec<usize> = Vec::new();

    for e in 0..cfg.epochs {
        // Same cooperative checkpoint as `simulate` (see above).
        if crate::util::cancel::cancelled() {
            break;
        }
        epoch_step(
            sys,
            cfg,
            state,
            policy,
            cursor.epoch(e),
            &pattern,
            nn,
            &mut rng,
            &mut faults,
            &mut stats,
            &mut app_s,
            &mut overhead_s,
        );
    }
    state.epoch = None;

    TieringRun {
        policy: policy.name().to_string(),
        placement: String::new(),
        total_s: app_s + overhead_s,
        app_s,
        overhead_s,
        stats,
    }
}

/// Build initial page state from a placement policy over one flat object.
/// `interleave`: if true, pages round-robin over {fast, slow}
/// (uniform interleave, unmigratable); if false, first touch fills fast
/// then spills (migratable).
pub fn initial_state(
    pages: usize,
    fast_node: NodeId,
    slow_node: NodeId,
    fast_capacity: usize,
    interleave: bool,
) -> PageState {
    let mut node = Vec::with_capacity(pages);
    let mut fast_used = 0usize;
    for p in 0..pages {
        let target = if interleave {
            if p % 2 == 0 && fast_used < fast_capacity {
                fast_node
            } else {
                slow_node
            }
        } else if fast_used < fast_capacity {
            fast_node
        } else {
            slow_node
        };
        if target == fast_node {
            fast_used += 1;
        }
        node.push(target);
    }
    PageState::new(
        node,
        vec![!interleave; pages],
        vec![0; pages],
        fast_node,
        fast_capacity,
        slow_node,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;
    use crate::memsim::MemKind;

    fn mini_state(interleave: bool) -> PageState {
        initial_state(100, 0, 2, 40, interleave)
    }

    #[test]
    fn first_touch_fills_fast_then_spills() {
        let s = mini_state(false);
        assert_eq!(s.fast_used(), 40);
        assert_eq!(s.node_of(0), 0);
        assert_eq!(s.node_of(99), 2);
        assert!((0..s.len()).all(|p| s.migratable(p)));
    }

    #[test]
    fn interleave_alternates_and_is_unmigratable() {
        let s = mini_state(true);
        assert_eq!(s.node_of(0), 0);
        assert_eq!(s.node_of(1), 2);
        assert!((0..s.len()).all(|p| !s.migratable(p)));
    }

    #[test]
    fn promote_respects_capacity_with_demotion() {
        let mut s = mini_state(false);
        s.last_counts[5] = 0; // cold fast page
        for p in 0..40 {
            s.last_counts[p] = 10;
        }
        s.last_counts[7] = 0; // coldest
        let moved = s.promote(80);
        assert_eq!(moved, 2); // one demotion + one promotion
        assert!(s.on_fast(80));
        assert_eq!(s.fast_used(), 40);
    }

    #[test]
    fn promote_noop_if_already_fast() {
        let mut s = mini_state(false);
        assert_eq!(s.promote(0), 0);
    }

    #[test]
    fn fast_used_counter_tracks_recount() {
        let mut s = mini_state(false);
        let faults: Vec<usize> = (40..70).collect();
        s.promote_batch(&faults);
        let recount = (0..s.len()).filter(|&p| s.node_of(p) == s.fast_node).count();
        assert_eq!(s.fast_used(), recount);
    }

    #[test]
    fn packed_column_keeps_pin_bit_across_moves() {
        // The pinned bit must travel with a page through promote/demote
        // cycles: an unmigratable page stays unmigratable wherever the
        // (never-firing) policies would leave it, and a migratable page
        // never becomes pinned.
        let mut s = mini_state(false);
        s.promote_batch(&(40..90).collect::<Vec<usize>>());
        assert!((0..s.len()).all(|p| s.migratable(p)));
        let i = mini_state(true);
        assert!((0..i.len()).all(|p| !i.migratable(p)));
    }

    #[test]
    fn promote_batch_matches_reference_decisions() {
        // Same inputs through the optimized and reference paths must
        // yield the same placement, counts, and fast_used.
        let build = || {
            let mut s = initial_state(500, 0, 2, 120, false);
            for p in 0..500 {
                s.last_counts[p] = ((p * 7) % 23) as u32;
            }
            s
        };
        let batch: Vec<usize> = (150..350).step_by(3).collect();
        let mut opt = build();
        let (p1, d1) = opt.promote_batch(&batch);
        let mut reference = build();
        let (p2, d2) = crate::perf::with_reference(|| reference.promote_batch(&batch));
        assert_eq!((p1, d1), (p2, d2));
        assert_eq!(opt.page, reference.page);
        assert_eq!(opt.fast_used(), reference.fast_used());
    }

    #[test]
    fn set_objects_updates_n_obj() {
        let mut s = mini_state(false);
        assert_eq!(s.n_obj(), 1);
        let objs: Vec<u32> = (0..100).map(|p| if p < 30 { 0 } else { 2 }).collect();
        s.set_objects(objs);
        assert_eq!(s.n_obj(), 3);
    }

    #[test]
    fn aggregates_survive_migrations_exactly() {
        // After ingest + migrations, incremental aggregates must equal a
        // from-scratch rebuild (integers: bit-exact).
        let mut s = initial_state(200, 0, 2, 50, false);
        let counts: Vec<u32> = (0..200).map(|p| (p % 17) as u32).collect();
        s.set_epoch_counts(&counts, 4);
        let batch: Vec<usize> = (60..160).collect();
        s.promote_batch(&batch);
        let e = s.epoch.as_ref().unwrap();
        let mut rebuilt = vec![0u64; s.n_obj() * 4];
        for p in 0..200 {
            rebuilt[s.object[p] as usize * 4 + s.node_of(p)] += counts[p] as u64;
        }
        assert_eq!(e.agg, rebuilt);
    }

    #[test]
    fn hint_faults_skip_unmigratable() {
        let s = mini_state(true);
        let counts = vec![5u32; 100];
        let mut rng = Rng::seeded(1);
        let faults = sample_hint_faults(&s, &counts, 1.0, false, &mut rng);
        assert!(faults.is_empty(), "PMO 3: interleaved pages never fault");
    }

    #[test]
    fn hint_faults_skip_unaccessed() {
        let s = mini_state(false);
        let mut counts = vec![0u32; 100];
        counts[3] = 1;
        let mut rng = Rng::seeded(1);
        let faults = sample_hint_faults(&s, &counts, 1.0, false, &mut rng);
        assert_eq!(faults, vec![3]);
    }

    #[test]
    fn geometric_sampling_hits_expected_rate() {
        // 2% scan of 50k candidates → ~1000 faults (±35%), and far fewer
        // RNG draws than candidates.
        let s = initial_state(50_000, 0, 2, 20_000, false);
        let counts = vec![1u32; 50_000];
        let mut rng = Rng::seeded(42);
        let faults = sample_hint_faults(&s, &counts, 0.02, false, &mut rng);
        let n = faults.len() as f64;
        assert!((650.0..=1350.0).contains(&n), "faults {n}");
        // All faults are valid candidate pages, strictly increasing.
        assert!(faults.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn zero_scan_never_faults() {
        let s = mini_state(false);
        let counts = vec![9u32; 100];
        let mut rng = Rng::seeded(3);
        assert!(sample_hint_faults(&s, &counts, 0.0, false, &mut rng).is_empty());
    }

    #[test]
    fn epoch_time_positive_and_fast_placement_faster() {
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.0,
            epochs: 1,
            seed: 1,
        };
        let counts = vec![1000u32; 1000];
        let pat = |_: u32| (Pattern::Random, 0.5);
        let all_fast = initial_state(1000, ld, cxl, 1000, false);
        let all_slow = initial_state(1000, ld, cxl, 0, false);
        let tf = epoch_app_time(&sys, &cfg, &all_fast, &EpochWorkload { counts: &counts, pattern: &pat });
        let ts = epoch_app_time(&sys, &cfg, &all_slow, &EpochWorkload { counts: &counts, pattern: &pat });
        assert!(tf > 0.0 && ts > tf, "fast {tf} slow {ts}");
    }

    #[test]
    fn epoch_app_time_agg_matches_full_pass() {
        // With aggregates ingested, epoch time must equal the fallback
        // full-pass computation bit-for-bit (integer aggregation).
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let cfg = SimConfig {
            socket: 0,
            threads: 32,
            compute_ns_per_byte: 0.2,
            epochs: 1,
            seed: 1,
        };
        let counts: Vec<u32> = (0..2000).map(|p| (p % 97) as u32).collect();
        let pat = |_: u32| (Pattern::Random, 0.4);
        let mut with_agg = initial_state(2000, ld, cxl, 700, false);
        with_agg.set_epoch_counts(&counts, sys.nodes.len());
        with_agg.promote_batch(&(900..1100).collect::<Vec<usize>>());
        let mut plain = initial_state(2000, ld, cxl, 700, false);
        plain.promote_batch(&(900..1100).collect::<Vec<usize>>());
        assert_eq!(with_agg.page, plain.page);
        let wl = EpochWorkload { counts: &counts, pattern: &pat };
        let ta = epoch_app_time(&sys, &cfg, &with_agg, &wl);
        let tp = epoch_app_time(&sys, &cfg, &plain, &wl);
        assert_eq!(ta.to_bits(), tp.to_bits());
    }

    #[test]
    fn simulate_reference_parity_full_run() {
        // End-to-end: a multi-epoch PageRank-style run must produce
        // identical results through the optimized and reference paths
        // (shared sampler → same RNG stream → same decisions; integer
        // aggregates → same app times).
        use crate::workloads::tiering_apps::{pagerank, TraceGen};
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mut app = pagerank();
        app.pages = 4000; // keep the test quick
        let run_once = |reference: bool| {
            let mut state = initial_state(4000, ld, cxl, 1500, false);
            let gen = TraceGen::new(app.clone(), 9);
            let mut pol = Tiering08::default();
            let cfg = SimConfig {
                socket: 0,
                threads: 64,
                compute_ns_per_byte: 0.5,
                epochs: 4,
                seed: 9,
            };
            let body = || {
                simulate(
                    &sys,
                    &cfg,
                    &mut state,
                    &mut pol,
                    |_, buf| gen.epoch_counts_into(buf),
                    |_| (Pattern::Random, 0.5),
                )
            };
            if reference {
                crate::perf::with_reference(body)
            } else {
                body()
            }
        };
        let opt = run_once(false);
        let reference = run_once(true);
        assert_eq!(opt.stats, reference.stats);
        assert_eq!(opt.overhead_s.to_bits(), reference.overhead_s.to_bits());
        let rel = (opt.app_s - reference.app_s).abs() / reference.app_s;
        assert!(rel < 1e-9, "app_s {} vs {}", opt.app_s, reference.app_s);
    }

    #[test]
    fn simulate_trace_bit_identical_to_producer() {
        // A shared-trace replay must be indistinguishable from driving
        // the generator live through the FnMut producer (same mode).
        use crate::workloads::tiering_apps::graph500;
        use crate::workloads::tiering_apps::TraceGen;
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mut app = graph500();
        app.pages = 3000;
        let cfg = || SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.5,
            epochs: 5,
            seed: 13,
        };
        let pat = |_: u32| (Pattern::Random, 0.5);
        let trace = EpochTrace::generate(&app, 5, 13);
        let mut state_t = initial_state(3000, ld, cxl, 1100, false);
        let mut pol_t = Tpp::default();
        let via_trace = simulate_trace(&sys, &cfg(), &mut state_t, &mut pol_t, &trace, pat);
        let mut state_p = initial_state(3000, ld, cxl, 1100, false);
        let mut pol_p = Tpp::default();
        let mut gen = TraceGen::new(app, 13);
        let via_producer = simulate(
            &sys,
            &cfg(),
            &mut state_p,
            &mut pol_p,
            |_, buf| {
                gen.epoch_counts_into(buf);
                gen.drift();
            },
            |_| (Pattern::Random, 0.5),
        );
        assert_eq!(via_trace.stats, via_producer.stats);
        assert_eq!(via_trace.app_s.to_bits(), via_producer.app_s.to_bits());
        assert_eq!(
            via_trace.overhead_s.to_bits(),
            via_producer.overhead_s.to_bits()
        );
        assert_eq!(state_t.page, state_p.page);
    }

    #[test]
    fn cancelled_simulate_stops_at_the_next_epoch_boundary() {
        // Satellite pin for cooperative deadlines: firing the cancel
        // token mid-run must end the simulation at the next epoch
        // boundary — the producer is called exactly once more (for the
        // epoch already in flight), never for the remaining 97.
        use crate::util::cancel;
        use crate::workloads::tiering_apps::{pagerank, TraceGen};
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mut app = pagerank();
        app.pages = 2000;
        let gen = TraceGen::new(app, 5);
        let mut pol = Tiering08::default();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.5,
            epochs: 100,
            seed: 5,
        };
        let mut state = initial_state(2000, ld, cxl, 700, false);
        let token = cancel::CancelToken::new();
        let mut produced = 0usize;
        let run = cancel::with_token(&token, || {
            simulate(
                &sys,
                &cfg,
                &mut state,
                &mut pol,
                |_, buf| {
                    produced += 1;
                    if produced == 3 {
                        token.cancel();
                    }
                    gen.epoch_counts_into(buf);
                },
                |_| (Pattern::Random, 0.5),
            )
        });
        assert_eq!(produced, 3, "must return within one epoch of the cancel");
        assert!(run.total_s > 0.0, "the completed epochs still accumulate");
    }

    #[test]
    fn pre_cancelled_simulate_trace_runs_no_epochs() {
        use crate::util::cancel;
        use crate::workloads::tiering_apps::graph500;
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mut app = graph500();
        app.pages = 1500;
        let trace = EpochTrace::generate(&app, 4, 3);
        let mut state = initial_state(1500, ld, cxl, 500, false);
        let mut pol = Tpp::default();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.5,
            epochs: 4,
            seed: 3,
        };
        let token = cancel::CancelToken::new();
        token.cancel();
        let run = cancel::with_token(&token, || {
            simulate_trace(&sys, &cfg, &mut state, &mut pol, &trace, |_| {
                (Pattern::Random, 0.5)
            })
        });
        assert_eq!(run.total_s, 0.0, "no epoch may run under a fired token");
        assert_eq!(run.stats, VmStats::default());
    }

    #[test]
    fn soa_parity_all_policies_and_drifts() {
        // The tentpole's bit-parity suite: the SoA state + trace replay
        // must reproduce the reference (AoS-era seed semantics) run for
        // every policy × drift {0, low, high} — same stats, same
        // overheads, app time to float round-off.
        use crate::workloads::tiering_apps::{graph500, TraceGen};
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        for drift in [0.0, 0.05, 0.5] {
            let mut app = graph500();
            app.pages = 3000;
            app.drift = drift;
            let cfg = || SimConfig {
                socket: 0,
                threads: 64,
                compute_ns_per_byte: 0.4,
                epochs: 4,
                seed: 23,
            };
            let pat = |_: u32| (Pattern::Random, 0.5);
            for pi in 0..policies::all_policies().len() {
                let trace = EpochTrace::generate(&app, 4, 23);
                let mut state = initial_state(3000, ld, cxl, 1100, false);
                let mut pol = policies::all_policies().remove(pi);
                let opt = simulate_trace(&sys, &cfg(), &mut state, pol.as_mut(), &trace, pat);
                let mut state_r = initial_state(3000, ld, cxl, 1100, false);
                let mut pol_r = policies::all_policies().remove(pi);
                let gen = TraceGen::new(app.clone(), 23);
                let reference = crate::perf::with_reference(|| {
                    let mut gen = gen;
                    simulate(
                        &sys,
                        &cfg(),
                        &mut state_r,
                        pol_r.as_mut(),
                        |_, buf| {
                            gen.epoch_counts_into(buf);
                            gen.drift();
                        },
                        |_| (Pattern::Random, 0.5),
                    )
                });
                let label = format!("{} drift={drift}", opt.policy);
                assert_eq!(opt.stats, reference.stats, "{label}");
                assert_eq!(
                    opt.overhead_s.to_bits(),
                    reference.overhead_s.to_bits(),
                    "{label}"
                );
                let rel = (opt.app_s - reference.app_s).abs() / reference.app_s.max(1e-12);
                assert!(rel < 1e-9, "{label}: app_s {} vs {}", opt.app_s, reference.app_s);
                assert_eq!(state.page, state_r.page, "{label}: final placement");
            }
        }
    }

    /// A state with tie-heavy synthetic heat (forces the `(count, page)`
    /// tie-break to matter) and a spread of fast/slow placement.
    fn chunk_state(pages: usize) -> PageState {
        let mut s = initial_state(pages, 0, 2, pages * 2 / 5, false);
        for p in 0..pages {
            s.last_counts[p] = ((p * 31) % 97) as u32;
        }
        s
    }

    #[test]
    fn promote_batch_chunked_matches_sequential() {
        // The chunked victim scan must be bit-identical to the
        // sequential one for every job count and page count — including
        // page counts that don't divide evenly by the chunk count.
        for pages in [1_000usize, 1_003, 65_000] {
            let batch: Vec<usize> = (pages * 2 / 5..pages).step_by(3).collect();
            let mut seq = chunk_state(pages);
            let seq_res = seq.promote_batch(&batch);
            for jobs in [1usize, 2, 8] {
                let mut par = chunk_state(pages);
                let par_res = with_par_min_pages(1, || {
                    crate::perf::with_jobs(jobs, || par.promote_batch(&batch))
                });
                assert_eq!(seq_res, par_res, "pages={pages} jobs={jobs}");
                assert_eq!(seq.page, par.page, "pages={pages} jobs={jobs}");
                assert_eq!(seq.fast_used(), par.fast_used(), "pages={pages} jobs={jobs}");
            }
        }
    }

    #[test]
    fn chunked_paths_stay_sequential_below_threshold() {
        // At the paper's 65k pages and default threshold, jobs > 1 must
        // not change anything either (the gate keeps it sequential) —
        // same results, pinned so a threshold regression can't slip by.
        let pages = 2_000;
        let batch: Vec<usize> = (800..pages).step_by(2).collect();
        let mut seq = chunk_state(pages);
        let mut par = chunk_state(pages);
        let a = seq.promote_batch(&batch);
        let b = crate::perf::with_jobs(8, || par.promote_batch(&batch));
        assert_eq!(a, b);
        assert_eq!(seq.page, par.page);
    }

    #[test]
    fn hint_faults_chunked_matches_sequential() {
        // Chunked candidate filtering + jump selection must reproduce
        // the streaming walk exactly: same fault set AND same RNG
        // position afterwards (the epoch body keeps drawing from the
        // same generator).
        let pages = 50_000;
        let s = chunk_state(pages);
        let counts: Vec<u32> = (0..pages).map(|p| ((p * 13) % 5) as u32).collect();
        for (frac, slow_only) in [(0.02, false), (0.02, true), (1.0, true), (0.6, false)] {
            let mut rng_seq = Rng::seeded(99);
            let seq = sample_hint_faults(&s, &counts, frac, slow_only, &mut rng_seq);
            for jobs in [2usize, 8] {
                let mut rng_par = Rng::seeded(99);
                let par = with_par_min_pages(1, || {
                    crate::perf::with_jobs(jobs, || {
                        sample_hint_faults(&s, &counts, frac, slow_only, &mut rng_par)
                    })
                });
                assert_eq!(seq, par, "frac={frac} slow={slow_only} jobs={jobs}");
                assert_eq!(
                    rng_seq.f64().to_bits(),
                    rng_par.f64().to_bits(),
                    "frac={frac} slow={slow_only} jobs={jobs}: RNG position diverged"
                );
            }
        }
    }

    #[test]
    fn set_epoch_counts_chunked_matches_sequential() {
        let pages = 30_000;
        let counts: Vec<u32> = (0..pages).map(|p| ((p * 7) % 41) as u32).collect();
        let objs: Vec<u32> = (0..pages as u32).map(|p| p % 3).collect();
        let mut seq = chunk_state(pages);
        seq.set_objects(objs.clone());
        seq.set_epoch_counts(&counts, 4);
        for jobs in [2usize, 8] {
            let mut par = chunk_state(pages);
            par.set_objects(objs.clone());
            with_par_min_pages(1, || {
                crate::perf::with_jobs(jobs, || par.set_epoch_counts(&counts, 4))
            });
            assert_eq!(
                seq.epoch.as_ref().unwrap().agg,
                par.epoch.as_ref().unwrap().agg,
                "jobs={jobs}"
            );
        }
    }

    #[test]
    fn full_run_chunked_matches_sequential_all_policies() {
        // End-to-end: an entire simulate_trace run with every chunked
        // path active (threshold lowered) must be bit-identical to the
        // sequential run, for all four policies.
        use crate::workloads::tiering_apps::graph500;
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mut app = graph500();
        app.pages = 3_000;
        let cfg = || SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.4,
            epochs: 4,
            seed: 31,
        };
        let pat = |_: u32| (Pattern::Random, 0.5);
        let trace = EpochTrace::generate(&app, 4, 31);
        for pi in 0..policies::all_policies().len() {
            let mut state_s = initial_state(3_000, ld, cxl, 1_100, false);
            let mut pol_s = policies::all_policies().remove(pi);
            let seq = simulate_trace(&sys, &cfg(), &mut state_s, pol_s.as_mut(), &trace, pat);
            let mut state_p = initial_state(3_000, ld, cxl, 1_100, false);
            let mut pol_p = policies::all_policies().remove(pi);
            let par = with_par_min_pages(1, || {
                crate::perf::with_jobs(8, || {
                    simulate_trace(&sys, &cfg(), &mut state_p, pol_p.as_mut(), &trace, pat)
                })
            });
            let label = &seq.policy;
            assert_eq!(seq.stats, par.stats, "{label}");
            assert_eq!(seq.app_s.to_bits(), par.app_s.to_bits(), "{label}");
            assert_eq!(seq.overhead_s.to_bits(), par.overhead_s.to_bits(), "{label}");
            assert_eq!(state_s.page, state_p.page, "{label}");
        }
    }
}
