//! Memory tiering based on page migration (§VI).
//!
//! An epoch-based page-granular simulator: each epoch the application
//! produces per-page access counts (from `workloads::tiering_apps` traces
//! or from HPC object traffic); the tiering policy samples accesses
//! through NUMA *hint faults* and promotes/demotes pages between the
//! fast tier (LDRAM) and the slow tier (CXL); epoch execution time comes
//! from the same engine cost model as §V plus fault/migration overheads.
//!
//! The paper's key mechanisms are modeled faithfully:
//! - hint faults only fire on *migratable* VMAs — pages under an explicit
//!   interleave policy never fault (PMO 3: interleaving + migration do
//!   not compose);
//! - Tiering-0.8 samples lazily and throttles promotion with an adaptive
//!   threshold (59× fewer faults than TPP, PMO 2);
//! - TPP scans the slow tier aggressively and promotes on LRU presence;
//! - AutoNUMA promotes any faulted slow page.

pub mod policies;
pub mod stats;

use crate::engine::{self, ObjectTraffic, RunConfig};
use crate::memsim::{NodeId, Pattern, System};
use crate::util::rng::Rng;

pub use policies::{AutoNuma, NoBalance, Tiering08, TieringPolicy, Tpp};
pub use stats::VmStats;

/// Cost of one hint fault (ns): trap + PTE walk + bookkeeping.
pub const HINT_FAULT_NS: f64 = 1_500.0;
/// Cost of migrating one 2 MB region (ns): ~2 MB over ~1.6 GB/s effective
/// migration bandwidth, incl. unmap/copy/remap.
pub const MIGRATE_REGION_NS: f64 = 1_250_000.0;
/// 4 KB pages per 2 MB region (for vmstat-style counters).
pub const SMALL_PER_REGION: u64 = 512;

/// Page-granular placement state shared with the policies.
#[derive(Clone, Debug)]
pub struct PageState {
    /// Current node of each page.
    pub node: Vec<NodeId>,
    /// Whether the kernel may migrate each page (false under explicit
    /// interleave/membind policies).
    pub migratable: Vec<bool>,
    /// Object index of each page (for multi-object HPC runs).
    pub object: Vec<u32>,
    /// Fast tier node and its capacity in pages.
    pub fast_node: NodeId,
    pub fast_capacity: usize,
    /// Slow tier node (demotion target).
    pub slow_node: NodeId,
    /// Last-epoch access count per page (policy LRU/recency signal).
    pub last_counts: Vec<u32>,
}

impl PageState {
    pub fn fast_used(&self) -> usize {
        self.node.iter().filter(|&&n| n == self.fast_node).count()
    }

    /// Promote `page` to the fast tier, demoting the coldest fast page if
    /// the tier is full. Returns number of regions moved (1 or 2).
    /// O(pages) per call — use [`PageState::promote_batch`] for epoch-sized
    /// promotion sets.
    pub fn promote(&mut self, page: usize) -> u64 {
        let (p, d) = self.promote_batch(&[page]);
        p + d
    }

    /// Promote a batch of pages, demoting the coldest migratable
    /// fast-tier pages as needed — one O(n log n) pass for the whole
    /// epoch instead of O(n) per promotion. Returns
    /// (promoted_regions, demoted_regions).
    pub fn promote_batch(&mut self, pages: &[usize]) -> (u64, u64) {
        let want: Vec<usize> = pages
            .iter()
            .copied()
            .filter(|&p| self.node[p] != self.fast_node)
            .collect();
        if want.is_empty() {
            return (0, 0);
        }
        let free = self.fast_capacity.saturating_sub(self.fast_used());
        let need_demote = want.len().saturating_sub(free);
        // Victim selection: coldest migratable fast pages.
        let mut demoted = 0u64;
        if need_demote > 0 {
            let mut victims: Vec<usize> = (0..self.node.len())
                .filter(|&p| self.node[p] == self.fast_node && self.migratable[p])
                .collect();
            victims.sort_by_key(|&p| self.last_counts[p]);
            victims.truncate(need_demote);
            for v in &victims {
                self.node[*v] = self.slow_node;
            }
            demoted = victims.len() as u64;
        }
        // Promote as many as now fit.
        let capacity_now = self.fast_capacity.saturating_sub(self.fast_used());
        let mut promoted = 0u64;
        for &p in want.iter().take(capacity_now) {
            self.node[p] = self.fast_node;
            promoted += 1;
        }
        (promoted, demoted)
    }
}

/// Result of one simulated run.
#[derive(Clone, Debug)]
pub struct TieringRun {
    pub policy: String,
    pub placement: String,
    pub total_s: f64,
    pub app_s: f64,
    pub overhead_s: f64,
    pub stats: VmStats,
}

/// Per-epoch workload view handed to the simulator.
pub struct EpochWorkload<'a> {
    /// Per-page access counts this epoch.
    pub counts: &'a [u32],
    /// Pattern and dependent fraction per object index.
    pub pattern: &'a dyn Fn(u32) -> (Pattern, f64),
}

/// Simulator configuration.
pub struct SimConfig {
    pub socket: usize,
    pub threads: usize,
    pub compute_ns_per_byte: f64,
    pub epochs: usize,
    pub seed: u64,
}

/// Hint-fault sampling: the policy asks for a scan fraction; faults fire
/// for scanned+accessed+migratable pages. Returns faulted page indices.
pub fn sample_hint_faults(
    state: &PageState,
    counts: &[u32],
    scan_frac: f64,
    slow_tier_only: bool,
    rng: &mut Rng,
) -> Vec<usize> {
    let mut faults = Vec::new();
    for p in 0..counts.len() {
        if counts[p] == 0 || !state.migratable[p] {
            continue;
        }
        if slow_tier_only && state.node[p] == state.fast_node {
            continue;
        }
        if rng.f64() < scan_frac {
            faults.push(p);
        }
    }
    faults
}

/// Execute one epoch's application time given current placement.
pub fn epoch_app_time(
    sys: &System,
    cfg: &SimConfig,
    state: &PageState,
    wl: &EpochWorkload,
) -> f64 {
    // Aggregate per (object, node) access counts.
    let n_obj = state.object.iter().map(|&o| o as usize + 1).max().unwrap_or(1);
    let nn = sys.nodes.len();
    let mut per = vec![vec![0.0f64; nn]; n_obj];
    for p in 0..wl.counts.len() {
        per[state.object[p] as usize][state.node[p]] += wl.counts[p] as f64;
    }
    let mut objects = Vec::new();
    for (oi, nodes) in per.iter().enumerate() {
        let total: f64 = nodes.iter().sum();
        if total <= 0.0 {
            continue;
        }
        let (pattern, dep) = (wl.pattern)(oi as u32);
        objects.push(ObjectTraffic {
            name: format!("obj{oi}"),
            traffic_bytes: total * crate::memsim::LINE,
            pattern,
            dep_frac: dep,
            node_weights: nodes
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0.0)
                .map(|(n, &c)| (n, c / total))
                .collect(),
        });
    }
    let rcfg = RunConfig {
        socket: cfg.socket,
        threads: cfg.threads,
        compute_ns_per_byte: cfg.compute_ns_per_byte,
    };
    engine::run(sys, &rcfg, &objects).total_s
}

/// Run the full tiering simulation: `epochs` epochs of (trace → faults →
/// policy decision → migration → app time).
pub fn simulate(
    sys: &System,
    cfg: &SimConfig,
    state: &mut PageState,
    policy: &mut dyn TieringPolicy,
    mut next_epoch: impl FnMut(usize) -> Vec<u32>,
    pattern: impl Fn(u32) -> (Pattern, f64),
) -> TieringRun {
    let mut rng = Rng::seeded(cfg.seed);
    let mut stats = VmStats::default();
    let mut app_s = 0.0;
    let mut overhead_s = 0.0;

    for e in 0..cfg.epochs {
        let counts = next_epoch(e);
        // 1. policy observes + migrates
        let scan = policy.scan_request(state, &stats);
        let faults = sample_hint_faults(state, &counts, scan.frac, scan.slow_tier_only, &mut rng);
        stats.hint_faults += faults.len() as u64;
        let moved_regions = policy.epoch(state, &counts, &faults, &mut stats);
        stats.migrated_pages += moved_regions * SMALL_PER_REGION;
        // 2. overheads (parallelized across threads)
        overhead_s += (faults.len() as f64 * HINT_FAULT_NS
            + moved_regions as f64 * MIGRATE_REGION_NS)
            / cfg.threads as f64
            / 1e9;
        // 3. application time under the (new) placement
        let wl = EpochWorkload {
            counts: &counts,
            pattern: &pattern,
        };
        app_s += epoch_app_time(sys, cfg, state, &wl);
        // 4. recency state for next epoch
        state.last_counts.copy_from_slice(&counts);
    }

    TieringRun {
        policy: policy.name().to_string(),
        placement: String::new(),
        total_s: app_s + overhead_s,
        app_s,
        overhead_s,
        stats,
    }
}

/// Build initial page state from a placement policy over one flat object.
/// `ldram_frac_interleave`: if `Some(k)`, pages are round-robined over
/// {fast, slow} every k-th to fast (uniform interleave, unmigratable);
/// if `None`, first touch fills fast then spills (migratable).
pub fn initial_state(
    pages: usize,
    fast_node: NodeId,
    slow_node: NodeId,
    fast_capacity: usize,
    interleave: bool,
) -> PageState {
    let mut node = Vec::with_capacity(pages);
    let mut fast_used = 0usize;
    for p in 0..pages {
        let target = if interleave {
            if p % 2 == 0 && fast_used < fast_capacity {
                fast_node
            } else {
                slow_node
            }
        } else if fast_used < fast_capacity {
            fast_node
        } else {
            slow_node
        };
        if target == fast_node {
            fast_used += 1;
        }
        node.push(target);
    }
    PageState {
        node,
        migratable: vec![!interleave; pages],
        object: vec![0; pages],
        fast_node,
        fast_capacity,
        slow_node,
        last_counts: vec![0; pages],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;
    use crate::memsim::MemKind;

    fn mini_state(interleave: bool) -> PageState {
        initial_state(100, 0, 2, 40, interleave)
    }

    #[test]
    fn first_touch_fills_fast_then_spills() {
        let s = mini_state(false);
        assert_eq!(s.fast_used(), 40);
        assert_eq!(s.node[0], 0);
        assert_eq!(s.node[99], 2);
        assert!(s.migratable.iter().all(|&m| m));
    }

    #[test]
    fn interleave_alternates_and_is_unmigratable() {
        let s = mini_state(true);
        assert_eq!(s.node[0], 0);
        assert_eq!(s.node[1], 2);
        assert!(s.migratable.iter().all(|&m| !m));
    }

    #[test]
    fn promote_respects_capacity_with_demotion() {
        let mut s = mini_state(false);
        s.last_counts[5] = 0; // cold fast page
        for p in 0..40 {
            s.last_counts[p] = 10;
        }
        s.last_counts[7] = 0; // coldest
        let moved = s.promote(80);
        assert_eq!(moved, 2); // one demotion + one promotion
        assert_eq!(s.node[80], s.fast_node);
        assert_eq!(s.fast_used(), 40);
    }

    #[test]
    fn promote_noop_if_already_fast() {
        let mut s = mini_state(false);
        assert_eq!(s.promote(0), 0);
    }

    #[test]
    fn hint_faults_skip_unmigratable(){
        let s = mini_state(true);
        let counts = vec![5u32; 100];
        let mut rng = Rng::seeded(1);
        let faults = sample_hint_faults(&s, &counts, 1.0, false, &mut rng);
        assert!(faults.is_empty(), "PMO 3: interleaved pages never fault");
    }

    #[test]
    fn hint_faults_skip_unaccessed() {
        let s = mini_state(false);
        let mut counts = vec![0u32; 100];
        counts[3] = 1;
        let mut rng = Rng::seeded(1);
        let faults = sample_hint_faults(&s, &counts, 1.0, false, &mut rng);
        assert_eq!(faults, vec![3]);
    }

    #[test]
    fn epoch_time_positive_and_fast_placement_faster() {
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.0,
            epochs: 1,
            seed: 1,
        };
        let counts = vec![1000u32; 1000];
        let pat = |_: u32| (Pattern::Random, 0.5);
        let all_fast = initial_state(1000, ld, cxl, 1000, false);
        let all_slow = initial_state(1000, ld, cxl, 0, false);
        let tf = epoch_app_time(&sys, &cfg, &all_fast, &EpochWorkload { counts: &counts, pattern: &pat });
        let ts = epoch_app_time(&sys, &cfg, &all_slow, &EpochWorkload { counts: &counts, pattern: &pat });
        assert!(tf > 0.0 && ts > tf, "fast {tf} slow {ts}");
    }
}
