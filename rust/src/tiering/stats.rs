//! `/proc/vmstat`-style counters the paper collects for PMO 1–3.

/// Migration statistics for one run (counts in 4 KB page units where the
/// paper reports page counts).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VmStats {
    /// NUMA hint faults taken.
    pub hint_faults: u64,
    /// Pages migrated (4 KB units, like `pgmigrate_success`).
    pub migrated_pages: u64,
    /// Promotions (2 MB regions moved to the fast tier).
    pub promoted_regions: u64,
    /// Demotions (2 MB regions moved to the slow tier).
    pub demoted_regions: u64,
    /// Promotions skipped by throttling / threshold (Tiering-0.8).
    pub throttled: u64,
}

impl VmStats {
    pub fn migrations_total(&self) -> u64 {
        self.promoted_regions + self.demoted_regions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let s = VmStats {
            promoted_regions: 3,
            demoted_regions: 2,
            ..Default::default()
        };
        assert_eq!(s.migrations_total(), 5);
    }
}
