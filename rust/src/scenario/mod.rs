//! Declarative scenario engine: describe *what* to evaluate — device
//! profiles, topology, workload mixes, policy grids — as data, and let
//! the engine expand, shard and evaluate it in batch.
//!
//! The subsystem turns the 19 hard-coded experiment drivers into one
//! parameterized surface:
//!
//! - [`spec`] — the `cxlmem-scenario-v1` JSON document model: systems
//!   built from base presets plus per-node device overrides (the paper's
//!   three vendor CXL cards ship as presets, see
//!   [`crate::memsim::topology::device_preset`]), one workload kind per
//!   experiment family, and a free-form `objects` kind for ad-hoc mixes.
//! - [`expand`] — deterministic generators: `sweep` cross products and
//!   seeded randomized `fleet`s (same seed ⇒ byte-identical JSONL).
//! - [`eval`] — one spec → one [`crate::report::Report`], dispatching to
//!   the parameterized `exp::*_with` drivers so bundled defaults
//!   reproduce `cxlmem exp` output exactly.
//! - [`batch`] — shard a scenario list over [`crate::util::par`] and
//!   stream per-scenario results as JSON lines; duplicate specs within a
//!   batch evaluate once (canonical-identity dedupe).
//! - [`supervise`] — per-spec fault isolation for fleet runs: panics
//!   and errors become `cxlmem-result-error-v1` documents instead of a
//!   fleet abort, transient IO failures retry with seeded jittered
//!   backoff, `--deadline-secs` marks overruns timed out, and
//!   `--fail-fast` restores the first-failure abort.
//! - [`cache`] — persistent, content-addressed result cache keyed on the
//!   canonical spec hash ([`ScenarioSpec::cache_key`]); `scenario run`
//!   consults it by default, so fleet re-runs and overlapping sweeps
//!   skip evaluation entirely while emitting byte-identical JSONL.
//! - [`store`] — the layered store under the cache: lock-free cascade
//!   lookups (mutable head → sealed immutable layers → compacted base),
//!   flushes sealed as uniquely-named `seg-*.jsonl` segments, and a
//!   compactor folding them back into `results.jsonl`; the advisory
//!   lock survives only for compaction and cross-process adoption.
//! - [`serve`] — the long-lived evaluation daemon (`scenario serve`):
//!   scenario specs as JSONL over a Unix domain socket, a bounded
//!   admission queue with queue-full backpressure, a worker pool over
//!   `StoreHandle` clones (warm hits are one atomic load), in-flight
//!   dedup, and live counters via a `stats` verb; `scenario submit` is
//!   the line client. Responses are byte-identical to a batch run.
//! - [`shard`] — deterministic cross-process splits (`--shard K/N`,
//!   input-index modulo): N processes run disjoint slices of one
//!   expanded fleet and rendezvous in a shared cache directory; a
//!   coordinator re-run is then pure hits.
//! - [`report`] — aggregate result JSONL (or a cache store) into fleet
//!   summaries: best policy per device profile, win matrices, run-time
//!   quantiles, OLI-vs-best-static gains.
//!
//! CLI surface (`cxlmem scenario …`):
//!
//! ```text
//! scenario validate <files…>                          parse + validate
//! scenario expand <file> [--seed S] [--count N]       spec JSONL to stdout/--out
//! scenario run <files…|-> [--jobs N] [--out F]        result JSONL (cached;
//!          [--shard K/N] [--no-cache] [--cache-dir D] default .cxlmem-cache/)
//! scenario bench [--count N] [--jobs N] [--cache]     fleet throughput probe
//! scenario report <results.jsonl|cache dir>           fleet summary tables
//! scenario serve <cache-dir> [--socket P] [--jobs N]  long-lived eval daemon
//!          [--queue N] [--retries N] [--deadline-secs S]
//! scenario submit <files…|-> --socket P [--out F]     send specs to a daemon
//!          [--stats] [--shutdown]
//! ```
//!
//! The bundled files under `examples/scenarios/` re-express every
//! experiment id as a scenario; `rust/tests/scenario.rs` pins the
//! equivalence.

pub mod batch;
pub mod cache;
pub mod eval;
pub mod expand;
pub mod report;
pub mod serve;
pub mod shard;
pub mod spec;
pub mod store;
pub mod supervise;

pub use batch::{
    docs_of, parse_docs, run_batch, run_batch_cached, run_batch_supervised, ScenarioResult,
};
pub use cache::ResultCache;
pub use eval::evaluate;
pub use expand::{expand, is_template};
pub use report::{summarize_docs, summarize_text};
pub use shard::Shard;
pub use spec::{ScenarioSpec, SystemSpec, WorkloadSpec, SCHEMA};
pub use supervise::{validate_error_doc, SuperviseOpts, ERROR_SCHEMA};
