//! Supervised per-spec evaluation: fault isolation, bounded retries,
//! deadlines, and structured error documents.
//!
//! The batch runner ([`super::batch`]) historically aborted a whole
//! fleet on the first failing spec. At fleet scale partial failure is
//! the norm, not the exception, so [`eval_supervised`] turns each
//! spec's evaluation into an isolated attempt loop:
//!
//! - **Isolation** — every attempt runs under `catch_unwind`, so one
//!   panicking spec (a bug, an injected fault) becomes a per-spec
//!   failure instead of tearing down its siblings mid-batch. The panic
//!   payload is captured into the failure message.
//! - **Retries** — *transient* failures (an [`std::io::Error`] anywhere
//!   in the cause chain: a flaky store, a lock timeout, a failed thread
//!   spawn) are retried up to `retries` times with jittered exponential
//!   backoff. Since the layered store ([`super::store`]) the cache's
//!   own write path is lock-free (seals, not locked appends), so the
//!   store IO this loop absorbs is a failed seal or compaction — both
//!   idempotent: sealed entries stay pending until a segment file is
//!   durably renamed into place. The jitter is seeded from the spec's cache key and the
//!   attempt number, so a re-run backs off identically — determinism
//!   survives supervision. Deterministic evaluation errors (a bad
//!   socket index) and panics are terminal on the first attempt:
//!   retrying them re-fails identically.
//! - **Deadlines** — with a deadline set, the attempt runs on a
//!   watchdog thread under a fresh [`crate::util::cancel`] token and is
//!   marked **timed out** when it overruns. The watchdog fires the
//!   token and **joins** the worker: the tiering epoch loops poll the
//!   token at epoch boundaries and bail out cooperatively, so the
//!   worker is reclaimed within one epoch instead of detached (its
//!   partial run is discarded). Timeouts are terminal.
//!
//! A spec that exhausts its attempts yields a [`Failure`], which the
//! batch runner renders as a schema [`ERROR_SCHEMA`]
//! (`cxlmem-result-error-v1`) document in the output JSONL: scenario
//! name, cache key, error kind (`panic`|`io`|`timeout`|`eval`),
//! message, and attempt count. Error documents are **never cached** —
//! a re-run retries exactly the failed slots. `--fail-fast` bypasses
//! all of this and restores the historical first-failure abort.
//!
//! Metrics (PR-7 registry): `scenario.errors` (specs that exhausted
//! supervision), `scenario.retries` (backoff round-trips),
//! `scenario.timeouts` (deadline overruns).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::batch::{eval_raw, ScenarioResult};
use super::spec::ScenarioSpec;
use crate::util::cancel;
use crate::util::json::Json;
use crate::util::metrics;

/// Error-document schema identifier.
pub const ERROR_SCHEMA: &str = "cxlmem-result-error-v1";

/// Longest single backoff sleep, whatever the attempt count.
const BACKOFF_CAP_MS: u64 = 5_000;

/// How a supervised evaluation failed — the `error` field of the
/// emitted document.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// The evaluation panicked; the payload is in the message.
    Panic,
    /// An `std::io::Error` in the cause chain (store, lock, spawn).
    /// The one *transient* kind: eligible for retry.
    Io,
    /// The evaluation overran the `--deadline-secs` watchdog.
    Timeout,
    /// A deterministic evaluation error (bad spec data at eval time).
    Eval,
}

impl ErrorKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::Panic => "panic",
            ErrorKind::Io => "io",
            ErrorKind::Timeout => "timeout",
            ErrorKind::Eval => "eval",
        }
    }

    /// Parse the `error` field of a document.
    pub fn parse(s: &str) -> Option<ErrorKind> {
        match s {
            "panic" => Some(ErrorKind::Panic),
            "io" => Some(ErrorKind::Io),
            "timeout" => Some(ErrorKind::Timeout),
            "eval" => Some(ErrorKind::Eval),
            _ => None,
        }
    }
}

/// A supervised evaluation that exhausted its attempts.
#[derive(Clone, Debug)]
pub struct Failure {
    pub kind: ErrorKind,
    /// The raw failure text (panic payload, error chain, deadline note)
    /// — *not* prefixed with the scenario name; callers add context.
    pub message: String,
    /// Attempts consumed, counting the failing one (≥ 1).
    pub attempts: u32,
}

/// Supervision policy for one batch run.
#[derive(Clone, Debug)]
pub struct SuperviseOpts {
    /// Abort the batch on the first failure (the historical behavior):
    /// no `catch_unwind`, no retries, no deadline — panics unwind
    /// through the executor and errors fail the batch.
    pub fail_fast: bool,
    /// Extra attempts granted to transient (IO) failures.
    pub retries: u32,
    /// Base backoff before the first retry; doubles per retry, with
    /// seeded jitter in [0.5, 1.5), capped at [`BACKOFF_CAP_MS`].
    pub backoff_ms: u64,
    /// Per-attempt wall-clock budget; overruns are marked timed out.
    pub deadline: Option<Duration>,
    /// `"K/N"` shard label stamped into error documents, so a fleet
    /// coordinator can attribute failures to the shard that ran them.
    pub shard: Option<String>,
}

impl Default for SuperviseOpts {
    /// The supervised defaults `scenario run` uses: isolate failures
    /// into error documents, grant transient failures two retries.
    fn default() -> Self {
        SuperviseOpts {
            fail_fast: false,
            retries: 2,
            backoff_ms: 25,
            deadline: None,
            shard: None,
        }
    }
}

impl SuperviseOpts {
    /// The historical first-failure-aborts policy (`--fail-fast`, and
    /// the library-level `run_batch`/`run_batch_cached` contract).
    pub fn fail_fast() -> Self {
        SuperviseOpts {
            fail_fast: true,
            retries: 0,
            ..SuperviseOpts::default()
        }
    }
}

/// Classify an evaluation error: an `std::io::Error` at the root of the
/// cause chain marks a transient environment failure (store IO, lock
/// acquisition, thread spawn); everything else is a deterministic
/// evaluation error.
pub fn classify(err: &anyhow::Error) -> ErrorKind {
    if err.root_cause().downcast_ref::<std::io::Error>().is_some() {
        ErrorKind::Io
    } else {
        ErrorKind::Eval
    }
}

/// Render a panic payload (`&str` and `String` payloads carry their
/// message; anything else is opaque).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".to_string())
}

/// Deterministic jittered exponential backoff: `base * 2^(attempt-1)`,
/// scaled by a jitter in [0.5, 1.5) seeded from the spec's cache key
/// and the attempt number — re-runs sleep identically, and a fleet of
/// specs retrying the same contended store spreads out instead of
/// thundering back in lockstep.
fn backoff(key: &str, attempt: u32, base_ms: u64) -> Duration {
    let mut h = crate::util::hash::Fnv64::new();
    h.write(key.as_bytes());
    h.write(&attempt.to_le_bytes());
    let mut rng = crate::util::rng::Rng::seeded(h.finish());
    let exp = base_ms.saturating_mul(1u64 << (attempt.saturating_sub(1)).min(6));
    let jittered = (exp as f64 * (0.5 + rng.f64())).round() as u64;
    Duration::from_millis(jittered.min(BACKOFF_CAP_MS))
}

/// One isolated attempt on the calling thread.
fn attempt_inline(spec: &ScenarioSpec) -> Result<ScenarioResult, (ErrorKind, String)> {
    match catch_unwind(AssertUnwindSafe(|| eval_raw(spec))) {
        Ok(Ok(r)) => Ok(r),
        Ok(Err(e)) => Err((classify(&e), format!("{e}"))),
        Err(payload) => Err((ErrorKind::Panic, panic_message(payload.as_ref()))),
    }
}

/// One isolated attempt under a watchdog: the evaluation runs on its
/// own thread (inheriting the caller's perf context) under a fresh
/// cancel token. On overrun the token is fired and the worker is
/// **joined** — the epoch loops in `tiering::simulate`/`simulate_trace`
/// observe the token at each epoch boundary and abandon the run, so the
/// worker comes back within one epoch instead of being detached.
fn attempt_with_deadline(
    spec: &ScenarioSpec,
    deadline: Duration,
) -> Result<ScenarioResult, (ErrorKind, String)> {
    let (tx, rx) = mpsc::channel();
    let spec = spec.clone();
    let token = cancel::CancelToken::new();
    let spawned = cancel::with_token(&token, || {
        crate::util::par::spawn_worker("cxlmem-eval", move || {
            let _ = tx.send(attempt_inline(&spec));
        })
    });
    let worker = match spawned {
        Ok(handle) => handle,
        // Spawn failure is environmental (an io::Error): transient.
        Err(e) => return Err((ErrorKind::Io, format!("spawning eval watchdog thread: {e}"))),
    };
    match rx.recv_timeout(deadline) {
        Ok(outcome) => {
            let _ = worker.join();
            outcome
        }
        Err(_) => {
            token.cancel();
            // Reclaim the worker: it bails at its next cooperative
            // checkpoint and its partial result is discarded.
            let _ = worker.join();
            Err((
                ErrorKind::Timeout,
                format!("evaluation exceeded the {deadline:?} deadline (worker cancelled and reclaimed)"),
            ))
        }
    }
}

/// Evaluate one spec under the supervision policy. `key` is the spec's
/// cache key — it seeds the backoff jitter and lands in error docs.
///
/// With `opts.fail_fast` this is exactly the historical path: one
/// uncaught attempt (panics unwind, errors return) wrapped in a
/// single-attempt [`Failure`] for the caller to abort on.
pub fn eval_supervised(
    spec: &ScenarioSpec,
    key: &str,
    opts: &SuperviseOpts,
) -> Result<ScenarioResult, Failure> {
    if opts.fail_fast {
        return eval_raw(spec).map_err(|e| Failure {
            kind: classify(&e),
            message: format!("{e}"),
            attempts: 1,
        });
    }
    let max_attempts = opts.retries.saturating_add(1);
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        let outcome = match opts.deadline {
            Some(d) => attempt_with_deadline(spec, d),
            None => attempt_inline(spec),
        };
        let (kind, message) = match outcome {
            Ok(r) => return Ok(r),
            Err(f) => f,
        };
        if kind == ErrorKind::Io && attempt < max_attempts {
            metrics::counter("scenario.retries").inc();
            std::thread::sleep(backoff(key, attempt, opts.backoff_ms));
            continue;
        }
        if kind == ErrorKind::Timeout {
            metrics::counter("scenario.timeouts").inc();
        }
        metrics::counter("scenario.errors").inc();
        return Err(Failure {
            kind,
            message,
            attempts: attempt,
        });
    }
}

/// Build the `cxlmem-result-error-v1` document for a failed slot.
pub fn error_doc(name: &str, key: &str, failure: &Failure, shard: Option<&str>) -> Json {
    let mut doc = Json::obj(vec![
        ("schema", ERROR_SCHEMA.into()),
        ("scenario", name.into()),
        ("key", key.into()),
        ("error", failure.kind.as_str().into()),
        ("message", failure.message.as_str().into()),
        ("attempts", u64::from(failure.attempts).into()),
    ]);
    if let Some(s) = shard {
        doc.set("shard", s.into());
    }
    doc
}

/// Whether a result-stream document is an error document (vs a result,
/// cache line, or metrics snapshot).
pub fn is_error_doc(doc: &Json) -> bool {
    doc.get("schema").and_then(Json::as_str) == Some(ERROR_SCHEMA)
}

/// Validate a parsed `cxlmem-result-error-v1` document — the gate the
/// `stats`/`bench` validators apply to error lines in mixed JSONL.
pub fn validate_error_doc(doc: &Json) -> Result<()> {
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == ERROR_SCHEMA => {}
        Some(s) => bail!("schema is '{s}', want '{ERROR_SCHEMA}'"),
        None => bail!("missing string field 'schema'"),
    }
    for field in ["scenario", "key", "error", "message"] {
        if doc.get(field).and_then(Json::as_str).is_none() {
            bail!("missing string field '{field}'");
        }
    }
    let kind = doc.get("error").and_then(Json::as_str).unwrap();
    if ErrorKind::parse(kind).is_none() {
        bail!("error kind '{kind}' is not one of panic|io|timeout|eval");
    }
    let attempts = doc
        .get("attempts")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing integer field 'attempts'"))?;
    if attempts < 1 {
        bail!("'attempts' must be >= 1 (got {attempts})");
    }
    if let Some(shard) = doc.get("shard") {
        if shard.as_str().is_none() {
            bail!("'shard', when present, must be a string");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fault;

    fn spec(text: &str) -> ScenarioSpec {
        ScenarioSpec::parse(&Json::parse(text).unwrap()).unwrap()
    }

    fn failure(kind: ErrorKind) -> Failure {
        Failure {
            kind,
            message: "boom".to_string(),
            attempts: 2,
        }
    }

    #[test]
    fn error_doc_roundtrips_and_validates() {
        let doc = error_doc("f-001", "00ab", &failure(ErrorKind::Panic), Some("2/4"));
        validate_error_doc(&doc).unwrap();
        assert!(is_error_doc(&doc));
        assert_eq!(doc.get("scenario").unwrap().as_str(), Some("f-001"));
        assert_eq!(doc.get("error").unwrap().as_str(), Some("panic"));
        assert_eq!(doc.get("attempts").unwrap().as_u64(), Some(2));
        assert_eq!(doc.get("shard").unwrap().as_str(), Some("2/4"));
        // Without a shard label the field is simply absent.
        let bare = error_doc("f", "k", &failure(ErrorKind::Io), None);
        validate_error_doc(&bare).unwrap();
        assert!(bare.get("shard").is_none());
        // The document survives a JSONL round-trip.
        let parsed = Json::parse(&doc.to_string()).unwrap();
        validate_error_doc(&parsed).unwrap();
    }

    #[test]
    fn validate_rejects_malformed_docs() {
        assert!(validate_error_doc(&Json::parse("{}").unwrap()).is_err());
        let mut wrong = error_doc("f", "k", &failure(ErrorKind::Eval), None);
        wrong.set("schema", "cxlmem-result-cache-v1".into());
        assert!(validate_error_doc(&wrong).is_err());
        let mut bad_kind = error_doc("f", "k", &failure(ErrorKind::Eval), None);
        bad_kind.set("error", "explosion".into());
        let err = validate_error_doc(&bad_kind).unwrap_err().to_string();
        assert!(err.contains("panic|io|timeout|eval"), "{err}");
        let mut no_attempts = error_doc("f", "k", &failure(ErrorKind::Eval), None);
        no_attempts.set("attempts", 0u64.into());
        assert!(validate_error_doc(&no_attempts).is_err());
        for field in ["scenario", "key", "error", "message"] {
            let text = error_doc("f", "k", &failure(ErrorKind::Io), None)
                .to_string()
                .replace(&format!("\"{field}\""), &format!("\"_{field}\""));
            assert!(
                validate_error_doc(&Json::parse(&text).unwrap()).is_err(),
                "missing '{field}' must be rejected"
            );
        }
    }

    #[test]
    fn classify_splits_io_from_eval() {
        let io_err = anyhow::Error::from(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            "lock marker held",
        ));
        assert_eq!(classify(&io_err), ErrorKind::Io);
        use anyhow::Context as _;
        let wrapped: anyhow::Error = Err::<(), _>(std::io::Error::new(
            std::io::ErrorKind::Other,
            "store unwritable",
        ))
        .context("flushing cache")
        .unwrap_err();
        assert_eq!(classify(&wrapped), ErrorKind::Io, "chain must be walked");
        assert_eq!(classify(&anyhow!("socket 7 out of range")), ErrorKind::Eval);
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let a = backoff("00ab", 1, 25);
        assert_eq!(a, backoff("00ab", 1, 25), "same key+attempt, same sleep");
        assert_ne!(backoff("00ab", 1, 25), backoff("00cd", 1, 25));
        // Jitter stays within [0.5, 1.5) of the exponential schedule.
        for attempt in 1..=10u32 {
            let exp = 25u64 << (attempt - 1).min(6);
            let d = backoff("k", attempt, 25).as_millis() as u64;
            assert!(d >= exp / 2 && d <= exp + exp / 2 + 1, "attempt {attempt}: {d}ms");
            assert!(d <= BACKOFF_CAP_MS);
        }
    }

    #[test]
    fn deterministic_eval_errors_are_terminal_not_retried() {
        // 'socket 7' fails deterministically at eval time: one attempt,
        // kind 'eval', message preserved for the error document.
        let s = spec(
            r#"{"name": "sup-eval-doomed", "workload": {"kind": "objects", "socket": 7,
                "objects": [{"name": "a", "gb": 1}], "oli_search": false}}"#,
        );
        let f = eval_supervised(&s, "k", &SuperviseOpts::default()).unwrap_err();
        assert_eq!(f.kind, ErrorKind::Eval);
        assert_eq!(f.attempts, 1);
        assert!(f.message.contains("socket 7"), "{}", f.message);
    }

    #[test]
    fn injected_panics_are_captured_with_payload() {
        let _g = fault::test_guard();
        fault::install(
            fault::FaultPlan::parse("scenario.eval/sup-panic-victim=panic").unwrap(),
        );
        let s = spec(r#"{"name": "sup-panic-victim", "workload": {"kind": "hpc-table"}}"#);
        let f = eval_supervised(&s, "k", &SuperviseOpts::default()).unwrap_err();
        fault::clear();
        assert_eq!(f.kind, ErrorKind::Panic);
        assert_eq!(f.attempts, 1, "panics are terminal");
        assert!(f.message.contains(fault::INJECTED), "{}", f.message);
    }

    #[test]
    fn transient_io_faults_retry_to_success() {
        let _g = fault::test_guard();
        fault::install(
            fault::FaultPlan::parse("scenario.eval.io/sup-flaky-io=io:2").unwrap(),
        );
        let before = metrics::counter("scenario.retries").get();
        let s = spec(r#"{"name": "sup-flaky-io", "workload": {"kind": "hpc-table"}}"#);
        let opts = SuperviseOpts {
            retries: 2,
            backoff_ms: 1,
            ..SuperviseOpts::default()
        };
        let r = eval_supervised(&s, "k", &opts).expect("third attempt must succeed");
        fault::clear();
        assert_eq!(r.name, "sup-flaky-io");
        if metrics::global().enabled() {
            assert_eq!(metrics::counter("scenario.retries").get() - before, 2);
        }
    }

    #[test]
    fn exhausted_io_retries_fail_with_attempt_count() {
        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("scenario.eval.io/sup-dead-io=io").unwrap());
        let s = spec(r#"{"name": "sup-dead-io", "workload": {"kind": "hpc-table"}}"#);
        let opts = SuperviseOpts {
            retries: 2,
            backoff_ms: 1,
            ..SuperviseOpts::default()
        };
        let f = eval_supervised(&s, "k", &opts).unwrap_err();
        fault::clear();
        assert_eq!(f.kind, ErrorKind::Io);
        assert_eq!(f.attempts, 3, "initial attempt + 2 retries");
        assert!(f.message.contains(fault::INJECTED), "{}", f.message);
    }

    #[test]
    fn deadline_marks_overruns_timed_out() {
        let _g = fault::test_guard();
        fault::install(
            fault::FaultPlan::parse("scenario.eval/sup-slowpoke=delay:400").unwrap(),
        );
        let before = metrics::counter("scenario.timeouts").get();
        let s = spec(r#"{"name": "sup-slowpoke", "workload": {"kind": "hpc-table"}}"#);
        let opts = SuperviseOpts {
            deadline: Some(Duration::from_millis(50)),
            ..SuperviseOpts::default()
        };
        let f = eval_supervised(&s, "k", &opts).unwrap_err();
        fault::clear();
        assert_eq!(f.kind, ErrorKind::Timeout);
        assert_eq!(f.attempts, 1, "timeouts are terminal");
        assert!(f.message.contains("deadline"), "{}", f.message);
        if metrics::global().enabled() {
            assert!(metrics::counter("scenario.timeouts").get() > before);
        }
    }

    #[test]
    fn deadline_joins_the_worker_instead_of_detaching() {
        // The injected 200ms delay has no cooperative checkpoint, so the
        // worker cannot bail early — the watchdog must still *join* it:
        // eval_supervised returns only once the worker finished, well
        // after the 50ms deadline. (The epoch-boundary early-exit is
        // pinned in tiering::tests.)
        let _g = fault::test_guard();
        fault::install(
            fault::FaultPlan::parse("scenario.eval/sup-reclaimed=delay:200").unwrap(),
        );
        let s = spec(r#"{"name": "sup-reclaimed", "workload": {"kind": "hpc-table"}}"#);
        let opts = SuperviseOpts {
            deadline: Some(Duration::from_millis(50)),
            ..SuperviseOpts::default()
        };
        let t0 = std::time::Instant::now();
        let f = eval_supervised(&s, "k", &opts).unwrap_err();
        let elapsed = t0.elapsed();
        fault::clear();
        assert_eq!(f.kind, ErrorKind::Timeout);
        assert!(f.message.contains("deadline"), "{}", f.message);
        assert!(
            elapsed >= Duration::from_millis(150),
            "worker must be joined, not detached (returned after {elapsed:?})"
        );
    }

    #[test]
    fn deadline_passes_fast_evaluations_through() {
        let s = spec(r#"{"name": "sup-quick", "workload": {"kind": "hpc-table"}}"#);
        let opts = SuperviseOpts {
            deadline: Some(Duration::from_secs(60)),
            ..SuperviseOpts::default()
        };
        let r = eval_supervised(&s, "k", &opts).unwrap();
        assert_eq!(r.name, "sup-quick");
        assert_eq!(r.doc.get("scenario").unwrap().as_str(), Some("sup-quick"));
    }
}
