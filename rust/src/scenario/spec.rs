//! Scenario spec: the declarative document (`cxlmem-scenario-v1`) that
//! describes one evaluation — system topology with per-node device
//! profiles, a workload, and its parameter/policy grid.
//!
//! Specs are plain JSON parsed with [`crate::util::json`]; every field a
//! workload kind accepts has a paper-calibrated default, so the bundled
//! files under `examples/scenarios/` stay small while still being fully
//! explicit data (see README "Scenario files" for the schema reference).
//! [`ScenarioSpec::to_json`] is the canonical serializer — parse ∘
//! to_json is the identity on the canonical form, which the round-trip
//! tests and the fleet generator both rely on.

use anyhow::{anyhow, bail, Result};

use crate::exp::llm::Hierarchy;
use crate::memsim::device::{IdleLatency, MemDevice};
use crate::memsim::{topology, MemKind, Pattern, System};
use crate::util::json::Json;

/// Spec schema identifier (the `"schema"` field, when present, must match).
pub const SCHEMA: &str = "cxlmem-scenario-v1";

/// The placement-policy grid names the `objects` kind understands.
pub const POLICY_NAMES: &[&str] = &[
    "ldram-preferred",
    "rdram-preferred",
    "cxl-preferred",
    "interleave-ldram-cxl",
    "interleave-rdram-cxl",
    "interleave-all",
];

/// One parsed, validated scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    /// Experiment id this scenario reproduces (golden-test hook).
    pub experiment: Option<String>,
    pub systems: Vec<SystemSpec>,
    pub workload: WorkloadSpec,
}

/// A system: a base preset (paper letter) plus per-node device overrides.
#[derive(Clone, Debug)]
pub struct SystemSpec {
    pub base: String,
    /// (node index, override), applied in order.
    pub devices: Vec<(usize, DeviceOverride)>,
}

#[derive(Clone, Debug)]
pub enum DeviceOverride {
    /// A shipped calibration (`topology::device_preset` name).
    Preset(String),
    /// A fully custom profile.
    Profile(MemDevice),
}

impl SystemSpec {
    pub fn preset(base: &str) -> Self {
        Self {
            base: base.to_string(),
            devices: Vec::new(),
        }
    }

    /// Canonical JSON form (a bare letter, or `{base, devices}`), as
    /// used in specs and echoed into result JSONL lines so results stay
    /// joinable to their device profiles without the spec file.
    pub fn to_json(&self) -> Json {
        system_json(self)
    }

    /// Materialize the system: base preset + device overrides.
    pub fn build(&self) -> Result<System> {
        let mut sys = topology::by_name(&self.base)
            .ok_or_else(|| anyhow!("unknown system preset '{}' (want A, B or C)", self.base))?;
        for (node, ov) in &self.devices {
            if *node >= sys.nodes.len() {
                bail!(
                    "device override node {node} out of range for system {} ({} nodes)",
                    self.base,
                    sys.nodes.len()
                );
            }
            sys.nodes[*node].device = match ov {
                DeviceOverride::Preset(p) => topology::device_preset(p)
                    .ok_or_else(|| anyhow!("unknown device preset '{p}'"))?,
                DeviceOverride::Profile(d) => d.clone(),
            };
        }
        Ok(sys)
    }
}

/// The workload + parameter grid of a scenario, one variant per
/// evaluator. Kind-specific fields default to the paper's calibration.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    /// Table I platform inventory.
    Table1,
    /// Fig 2 idle latency probes.
    IdleLatency { samples: usize, seed: u64 },
    /// Fig 3 bandwidth-vs-threads scaling.
    BwScaling { rows: Vec<usize> },
    /// Fig 4 loaded-latency delay sweep.
    LoadedLatency { threads: usize },
    /// §III bandwidth-aware thread assignment search.
    Assign { socket: usize },
    /// Fig 5 GPU↔CPU copy bandwidth grid.
    GpuCopy { blocks_log2: Vec<usize> },
    /// Fig 6 64 B GPU transfer latency.
    GpuLatency,
    /// Fig 8 ZeRO-Offload training throughput grid.
    ZeroTrain,
    /// Fig 9 step breakdown.
    ZeroBreakdown,
    /// Figs 11/12 + Table II FlexGen policy search over hierarchies.
    Flexgen {
        style: FlexgenStyle,
        models: Vec<String>,
        hierarchies: Vec<Hierarchy>,
    },
    /// Table III workload inventory.
    HpcTable,
    /// Fig 13 interleaving-policy family.
    HpcPolicies { socket: usize, threads: usize },
    /// Fig 14 thread scaling.
    HpcScaling {
        workloads: Vec<String>,
        threads: Vec<usize>,
        socket: usize,
    },
    /// Fig 15 OLI vs uniform interleave under an LDRAM cap.
    Oli {
        ldram_gb: u64,
        rdram_residue_gb: u64,
        socket: usize,
        threads: usize,
        title: String,
    },
    /// Fig 16 tiering policy × placement grid over the §VI apps.
    TieringApps {
        apps: Vec<String>,
        epochs: usize,
        seed: u64,
        threads: usize,
        fast_gb: u64,
        /// Working-set override in pages for every app (scale studies:
        /// a 1M+-page fleet cell instead of the paper's 65k). `None`
        /// keeps each app's own page count — and, being omitted from
        /// the canonical form, existing cache keys.
        pages: Option<usize>,
    },
    /// Fig 17 tiering × placement for the HPC workloads.
    TieringHpc {
        socket: usize,
        threads: usize,
        epochs: usize,
        seed: u64,
    },
    /// Free-form object mix evaluated over a placement-policy grid with
    /// best-policy selection and an optional OLI per-object search.
    Objects(ObjectsSpec),
}

/// Which FlexGen table to produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlexgenStyle {
    Fig11,
    Table2,
    Fig12,
}

impl FlexgenStyle {
    pub fn label(&self) -> &'static str {
        match self {
            FlexgenStyle::Fig11 => "fig11",
            FlexgenStyle::Table2 => "table2",
            FlexgenStyle::Fig12 => "fig12",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fig11" => FlexgenStyle::Fig11,
            "table2" => FlexgenStyle::Table2,
            "fig12" => FlexgenStyle::Fig12,
            other => bail!("unknown flexgen style '{other}' (want fig11|table2|fig12)"),
        })
    }
}

/// The `objects` workload: an explicit data-object mix plus its grid.
#[derive(Clone, Debug)]
pub struct ObjectsSpec {
    pub socket: usize,
    pub threads: usize,
    pub compute_ns_per_byte: f64,
    pub objects: Vec<ObjDecl>,
    pub policies: Vec<String>,
    /// Run the OLI per-object assignment search as an extra grid row.
    pub oli_search: bool,
}

/// One declared data object.
#[derive(Clone, Debug)]
pub struct ObjDecl {
    pub name: String,
    pub gbytes: f64,
    pub pattern: Pattern,
    /// Traffic per iteration as a multiple of the object size.
    pub scans: f64,
    pub dep_frac: f64,
}

// ---- parsing helpers -------------------------------------------------

fn get<'a>(obj: &'a Json, key: &str) -> Option<&'a Json> {
    obj.get(key)
}

fn str_or<'a>(obj: &'a Json, key: &str, default: &'a str) -> Result<&'a str> {
    match get(obj, key) {
        None => Ok(default),
        Some(v) => v
            .as_str()
            .ok_or_else(|| anyhow!("field '{key}' must be a string")),
    }
}

fn u64_or(obj: &Json, key: &str, default: u64) -> Result<u64> {
    match get(obj, key) {
        None => Ok(default),
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| anyhow!("field '{key}' must be a number"))?;
            if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
                bail!("field '{key}' must be a non-negative integer (got {f})");
            }
            Ok(f as u64)
        }
    }
}

fn usize_or(obj: &Json, key: &str, default: usize) -> Result<usize> {
    u64_or(obj, key, default as u64).map(|v| v as usize)
}

/// A `usize` field that must be ≥ 1 (thread/epoch/sample budgets).
fn positive_usize(obj: &Json, key: &str, default: usize) -> Result<usize> {
    let v = usize_or(obj, key, default)?;
    if v == 0 {
        bail!("field '{key}' must be >= 1");
    }
    Ok(v)
}

fn f64_or(obj: &Json, key: &str, default: f64) -> Result<f64> {
    match get(obj, key) {
        None => Ok(default),
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| anyhow!("field '{key}' must be a number"))?;
            if !f.is_finite() {
                bail!("field '{key}' must be finite");
            }
            Ok(f)
        }
    }
}

fn bool_or(obj: &Json, key: &str, default: bool) -> Result<bool> {
    match get(obj, key) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| anyhow!("field '{key}' must be a boolean")),
    }
}

fn req_f64(obj: &Json, key: &str) -> Result<f64> {
    get(obj, key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing numeric field '{key}'"))
}

fn str_list_or(obj: &Json, key: &str, default: &[&str]) -> Result<Vec<String>> {
    match get(obj, key) {
        None => Ok(default.iter().map(|s| s.to_string()).collect()),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow!("field '{key}' must be an array"))?
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("field '{key}' must hold strings"))
            })
            .collect(),
    }
}

fn usize_list_or(obj: &Json, key: &str, default: &[usize]) -> Result<Vec<usize>> {
    match get(obj, key) {
        None => Ok(default.to_vec()),
        Some(v) => v
            .as_arr()
            .ok_or_else(|| anyhow!("field '{key}' must be an array"))?
            .iter()
            .map(|x| {
                // Same strictness as the scalar path: integral, >= 0.
                let f = x
                    .as_f64()
                    .ok_or_else(|| anyhow!("field '{key}' must hold numbers"))?;
                if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
                    bail!("field '{key}' entries must be non-negative integers (got {f})");
                }
                Ok(f as usize)
            })
            .collect(),
    }
}

fn parse_pattern(s: &str) -> Result<Pattern> {
    Ok(match s {
        "sequential" => Pattern::Sequential,
        "random" => Pattern::Random,
        other => bail!("unknown pattern '{other}' (want sequential|random)"),
    })
}

fn pattern_label(p: Pattern) -> &'static str {
    match p {
        Pattern::Sequential => "sequential",
        Pattern::Random => "random",
    }
}

fn parse_mem_kind(s: &str) -> Result<MemKind> {
    Ok(match s {
        "ldram" => MemKind::Ldram,
        "rdram" => MemKind::Rdram,
        "cxl" => MemKind::Cxl,
        "nvme" => MemKind::Nvme,
        other => bail!("unknown memory kind '{other}' (want ldram|rdram|cxl|nvme)"),
    })
}

fn mem_kind_label(k: MemKind) -> &'static str {
    match k {
        MemKind::Ldram => "ldram",
        MemKind::Rdram => "rdram",
        MemKind::Cxl => "cxl",
        MemKind::Nvme => "nvme",
    }
}

fn parse_device_profile(obj: &Json) -> Result<MemDevice> {
    let kind = parse_mem_kind(str_or(obj, "kind", "cxl")?)?;
    Ok(MemDevice {
        kind,
        idle: IdleLatency {
            seq_ns: req_f64(obj, "idle_seq_ns")?,
            rand_ns: req_f64(obj, "idle_rand_ns")?,
        },
        peak_bw_gbs: req_f64(obj, "peak_bw_gbs")?,
        spec_bw_gbs: f64_or(obj, "spec_bw_gbs", req_f64(obj, "peak_bw_gbs")?)?,
        capacity: (f64_or(obj, "capacity_gb", 64.0)? * (1u64 << 30) as f64) as u64,
        queue_ns: f64_or(obj, "queue_ns", 6.0)?,
        queue_cap_ns: f64_or(obj, "queue_cap_ns", 230.0)?,
        stream_rate_gbs: req_f64(obj, "stream_rate_gbs")?,
        mlp_rand: f64_or(obj, "mlp_rand", 10.0)?,
        concentrated_rand_factor: f64_or(obj, "concentrated_rand_factor", 1.0)?,
    })
}

fn device_profile_json(d: &MemDevice) -> Json {
    Json::obj(vec![
        ("kind", mem_kind_label(d.kind).into()),
        ("idle_seq_ns", d.idle.seq_ns.into()),
        ("idle_rand_ns", d.idle.rand_ns.into()),
        ("peak_bw_gbs", d.peak_bw_gbs.into()),
        ("spec_bw_gbs", d.spec_bw_gbs.into()),
        (
            "capacity_gb",
            (d.capacity as f64 / (1u64 << 30) as f64).into(),
        ),
        ("queue_ns", d.queue_ns.into()),
        ("queue_cap_ns", d.queue_cap_ns.into()),
        ("stream_rate_gbs", d.stream_rate_gbs.into()),
        ("mlp_rand", d.mlp_rand.into()),
        ("concentrated_rand_factor", d.concentrated_rand_factor.into()),
    ])
}

fn parse_system(v: &Json) -> Result<SystemSpec> {
    if let Some(base) = v.as_str() {
        let spec = SystemSpec::preset(base);
        spec.build()?; // validate the preset exists
        return Ok(spec);
    }
    let base = str_or(v, "base", "")?;
    if base.is_empty() {
        bail!("system object needs a 'base' preset (A, B or C)");
    }
    let mut devices = Vec::new();
    if let Some(devs) = v.get("devices") {
        let map = devs
            .as_obj()
            .ok_or_else(|| anyhow!("'devices' must map node index -> preset|profile"))?;
        for (k, dv) in map {
            let node: usize = k
                .parse()
                .map_err(|_| anyhow!("device override key '{k}' is not a node index"))?;
            let ov = match dv {
                Json::Str(name) => DeviceOverride::Preset(name.clone()),
                Json::Obj(_) => DeviceOverride::Profile(parse_device_profile(dv)?),
                _ => bail!("device override for node {node} must be a preset name or profile"),
            };
            devices.push((node, ov));
        }
    }
    let spec = SystemSpec {
        base: base.to_string(),
        devices,
    };
    spec.build()?; // validate presets, node ranges
    Ok(spec)
}

fn system_json(s: &SystemSpec) -> Json {
    if s.devices.is_empty() {
        return Json::Str(s.base.clone());
    }
    let mut devices = std::collections::BTreeMap::new();
    for (node, ov) in &s.devices {
        let v = match ov {
            DeviceOverride::Preset(p) => Json::Str(p.clone()),
            DeviceOverride::Profile(d) => device_profile_json(d),
        };
        devices.insert(node.to_string(), v);
    }
    Json::obj(vec![
        ("base", s.base.as_str().into()),
        ("devices", Json::Obj(devices)),
    ])
}

fn parse_hierarchies(v: &Json) -> Result<Vec<Hierarchy>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("'hierarchies' must be an array"))?;
    let mut out = Vec::new();
    for h in arr {
        let name = h
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("hierarchy needs a 'name'"))?;
        let tiers = h
            .get("tiers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("hierarchy '{name}' needs 'tiers'"))?;
        let mut parsed = Vec::new();
        for t in tiers {
            let pair = t
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow!("hierarchy '{name}': tier must be [kind, gb]"))?;
            let kind = parse_mem_kind(
                pair[0]
                    .as_str()
                    .ok_or_else(|| anyhow!("hierarchy '{name}': tier kind must be a string"))?,
            )?;
            let gb = pair[1]
                .as_f64()
                .ok_or_else(|| anyhow!("hierarchy '{name}': tier capacity must be a number"))?;
            parsed.push((kind, gb * 1e9));
        }
        if parsed.is_empty() {
            bail!("hierarchy '{name}' has no tiers");
        }
        out.push(Hierarchy {
            name: name.to_string(),
            tiers: parsed,
        });
    }
    if out.is_empty() {
        bail!("'hierarchies' is empty");
    }
    Ok(out)
}

fn hierarchies_json(hs: &[Hierarchy]) -> Json {
    Json::arr(hs.iter().map(|h| {
        Json::obj(vec![
            ("name", h.name.as_str().into()),
            (
                "tiers",
                Json::arr(h.tiers.iter().map(|&(k, bytes)| {
                    Json::arr([Json::from(mem_kind_label(k)), Json::Num(bytes / 1e9)])
                })),
            ),
        ])
    }))
}

impl ScenarioSpec {
    /// Parse and validate one scenario document.
    pub fn parse(doc: &Json) -> Result<ScenarioSpec> {
        if doc.as_obj().is_none() {
            bail!("scenario must be a JSON object");
        }
        if let Some(schema) = doc.get("schema").and_then(Json::as_str) {
            if schema != SCHEMA {
                bail!("unsupported schema '{schema}' (this build reads {SCHEMA})");
            }
        }
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("scenario needs a 'name'"))?
            .to_string();
        let experiment = doc
            .get("experiment")
            .and_then(Json::as_str)
            .map(str::to_string);
        let systems = match doc.get("systems") {
            None => vec![SystemSpec::preset("A")],
            Some(v) => {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("'systems' must be an array"))?;
                if arr.is_empty() {
                    bail!("'systems' is empty");
                }
                arr.iter().map(parse_system).collect::<Result<Vec<_>>>()?
            }
        };
        let wl = doc
            .get("workload")
            .ok_or_else(|| anyhow!("scenario needs a 'workload'"))?;
        let kind = wl
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("workload needs a 'kind'"))?;
        let workload = Self::parse_workload(kind, wl)?;
        if systems.len() > 1 && !workload.uses_all_systems() {
            bail!(
                "workload kind '{kind}' evaluates a single system, but {} were given — \
                 use one scenario per system (or a sweep over 'systems')",
                systems.len()
            );
        }
        Ok(ScenarioSpec {
            name,
            experiment,
            systems,
            workload,
        })
    }

    fn parse_workload(kind: &str, wl: &Json) -> Result<WorkloadSpec> {
        use WorkloadSpec as W;
        Ok(match kind {
            "table1" => W::Table1,
            "idle-latency" => W::IdleLatency {
                samples: positive_usize(wl, "samples", 5000)?,
                seed: u64_or(wl, "seed", 42)?,
            },
            "bw-scaling" => W::BwScaling {
                rows: usize_list_or(wl, "threads", crate::exp::basic::FIG3_THREAD_ROWS)?,
            },
            "loaded-latency" => W::LoadedLatency {
                threads: positive_usize(wl, "threads", 32)?,
            },
            "assign" => W::Assign {
                socket: usize_or(wl, "socket", 0)?,
            },
            "gpu-copy" => {
                let blocks_log2 =
                    usize_list_or(wl, "blocks_log2", crate::exp::llm::FIG5_BLOCKS_LOG2)?;
                if blocks_log2.iter().any(|&b| b > 40) {
                    bail!("'blocks_log2' entries must be <= 40 (1 TB)");
                }
                W::GpuCopy { blocks_log2 }
            }
            "gpu-latency" => W::GpuLatency,
            "zero-train" => W::ZeroTrain,
            "zero-breakdown" => W::ZeroBreakdown,
            "flexgen" => {
                let style = FlexgenStyle::parse(str_or(wl, "style", "fig11")?)?;
                let models = str_list_or(wl, "models", &["llama-65b", "opt-66b"])?;
                for m in &models {
                    if crate::exp::llm::infer_model(m).is_none() {
                        bail!("unknown inference model '{m}'");
                    }
                }
                let hierarchies = match wl.get("hierarchies") {
                    Some(v) => parse_hierarchies(v)?,
                    None => match style {
                        FlexgenStyle::Fig11 => crate::exp::llm::hierarchies_324(),
                        _ => crate::exp::llm::hierarchies_ladder(),
                    },
                };
                W::Flexgen {
                    style,
                    models,
                    hierarchies,
                }
            }
            "hpc-table" => W::HpcTable,
            "hpc-policies" => W::HpcPolicies {
                socket: usize_or(wl, "socket", 0)?,
                threads: positive_usize(wl, "threads", 32)?,
            },
            "hpc-scaling" => {
                let workloads = str_list_or(wl, "workloads", &["CG", "MG"])?;
                for w in &workloads {
                    if crate::workloads::npb::by_name(w).is_none() {
                        bail!("unknown HPC workload '{w}'");
                    }
                }
                let threads = usize_list_or(wl, "threads", crate::exp::hpc::FIG14_THREADS)?;
                if threads.iter().any(|&t| t == 0) {
                    bail!("'threads' entries must be >= 1");
                }
                W::HpcScaling {
                    workloads,
                    threads,
                    socket: usize_or(wl, "socket", 1)?,
                }
            }
            "oli" => {
                let ldram_gb = u64_or(wl, "ldram_gb", 0)?;
                if ldram_gb == 0 {
                    bail!("'oli' workload needs 'ldram_gb'");
                }
                W::Oli {
                    ldram_gb,
                    rdram_residue_gb: u64_or(wl, "rdram_residue_gb", 32)?,
                    socket: usize_or(wl, "socket", 0)?,
                    threads: positive_usize(wl, "threads", 32)?,
                    title: str_or(
                        wl,
                        "title",
                        &format!("OLI speedup vs LDRAM preferred ({ldram_gb} GB LDRAM)"),
                    )?
                    .to_string(),
                }
            }
            "tiering" => {
                let apps = str_list_or(
                    wl,
                    "apps",
                    &["BTree", "PageRank", "Graph500", "Silo"],
                )?;
                for a in &apps {
                    // The evaluator's lookup is the single name authority.
                    super::eval::tiering_app(a)?;
                }
                let fast_gb = u64_or(wl, "fast_gb", 50)?;
                if fast_gb == 0 {
                    bail!("'fast_gb' must be >= 1");
                }
                let pages = match get(wl, "pages") {
                    None => None,
                    Some(_) => Some(positive_usize(wl, "pages", 1)?),
                };
                W::TieringApps {
                    apps,
                    epochs: positive_usize(wl, "epochs", 10)?,
                    seed: u64_or(wl, "seed", 7)?,
                    threads: positive_usize(wl, "threads", 64)?,
                    fast_gb,
                    pages,
                }
            }
            "tiering-hpc" => W::TieringHpc {
                socket: usize_or(wl, "socket", 1)?,
                threads: positive_usize(wl, "threads", 32)?,
                epochs: positive_usize(wl, "epochs", 10)?,
                seed: u64_or(wl, "seed", 11)?,
            },
            "objects" => {
                let objs = wl
                    .get("objects")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("'objects' workload needs an 'objects' array"))?;
                if objs.is_empty() {
                    bail!("'objects' array is empty");
                }
                let mut objects = Vec::new();
                for o in objs {
                    let name = o
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("object needs a 'name'"))?;
                    let gbytes = req_f64(o, "gb")?;
                    if gbytes <= 0.0 {
                        bail!("object '{name}': 'gb' must be positive");
                    }
                    let dep_frac = f64_or(o, "dep_frac", 0.0)?;
                    if !(0.0..=1.0).contains(&dep_frac) {
                        bail!("object '{name}': 'dep_frac' must be in [0, 1]");
                    }
                    objects.push(ObjDecl {
                        name: name.to_string(),
                        gbytes,
                        pattern: parse_pattern(str_or(o, "pattern", "sequential")?)?,
                        scans: f64_or(o, "scans", 1.0)?,
                        dep_frac,
                    });
                }
                let policies = str_list_or(wl, "policies", POLICY_NAMES)?;
                for p in &policies {
                    if !POLICY_NAMES.contains(&p.as_str()) {
                        bail!("unknown policy '{p}' (want one of {POLICY_NAMES:?})");
                    }
                }
                W::Objects(ObjectsSpec {
                    socket: usize_or(wl, "socket", 0)?,
                    threads: positive_usize(wl, "threads", 32)?,
                    compute_ns_per_byte: f64_or(wl, "compute_ns_per_byte", 0.0)?,
                    objects,
                    policies,
                    oli_search: bool_or(wl, "oli_search", true)?,
                })
            }
            other => bail!("unknown workload kind '{other}'"),
        })
    }

    /// Canonical serialization: parse(to_json(spec)) reproduces the spec.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj(vec![
            ("schema", SCHEMA.into()),
            ("name", self.name.as_str().into()),
            (
                "systems",
                Json::arr(self.systems.iter().map(system_json)),
            ),
            ("workload", self.workload_json()),
        ]);
        if let Some(e) = &self.experiment {
            doc.set("experiment", e.as_str().into());
        }
        doc
    }

    fn workload_json(&self) -> Json {
        use WorkloadSpec as W;
        match &self.workload {
            W::Table1 => Json::obj(vec![("kind", "table1".into())]),
            W::IdleLatency { samples, seed } => Json::obj(vec![
                ("kind", "idle-latency".into()),
                ("samples", (*samples).into()),
                ("seed", (*seed).into()),
            ]),
            W::BwScaling { rows } => Json::obj(vec![
                ("kind", "bw-scaling".into()),
                ("threads", Json::arr(rows.iter().map(|&t| Json::from(t)))),
            ]),
            W::LoadedLatency { threads } => Json::obj(vec![
                ("kind", "loaded-latency".into()),
                ("threads", (*threads).into()),
            ]),
            W::Assign { socket } => Json::obj(vec![
                ("kind", "assign".into()),
                ("socket", (*socket).into()),
            ]),
            W::GpuCopy { blocks_log2 } => Json::obj(vec![
                ("kind", "gpu-copy".into()),
                (
                    "blocks_log2",
                    Json::arr(blocks_log2.iter().map(|&b| Json::from(b))),
                ),
            ]),
            W::GpuLatency => Json::obj(vec![("kind", "gpu-latency".into())]),
            W::ZeroTrain => Json::obj(vec![("kind", "zero-train".into())]),
            W::ZeroBreakdown => Json::obj(vec![("kind", "zero-breakdown".into())]),
            W::Flexgen {
                style,
                models,
                hierarchies,
            } => Json::obj(vec![
                ("kind", "flexgen".into()),
                ("style", style.label().into()),
                (
                    "models",
                    Json::arr(models.iter().map(|m| Json::from(m.as_str()))),
                ),
                ("hierarchies", hierarchies_json(hierarchies)),
            ]),
            W::HpcTable => Json::obj(vec![("kind", "hpc-table".into())]),
            W::HpcPolicies { socket, threads } => Json::obj(vec![
                ("kind", "hpc-policies".into()),
                ("socket", (*socket).into()),
                ("threads", (*threads).into()),
            ]),
            W::HpcScaling {
                workloads,
                threads,
                socket,
            } => Json::obj(vec![
                ("kind", "hpc-scaling".into()),
                (
                    "workloads",
                    Json::arr(workloads.iter().map(|w| Json::from(w.as_str()))),
                ),
                ("threads", Json::arr(threads.iter().map(|&t| Json::from(t)))),
                ("socket", (*socket).into()),
            ]),
            W::Oli {
                ldram_gb,
                rdram_residue_gb,
                socket,
                threads,
                title,
            } => Json::obj(vec![
                ("kind", "oli".into()),
                ("ldram_gb", (*ldram_gb).into()),
                ("rdram_residue_gb", (*rdram_residue_gb).into()),
                ("socket", (*socket).into()),
                ("threads", (*threads).into()),
                ("title", title.as_str().into()),
            ]),
            W::TieringApps {
                apps,
                epochs,
                seed,
                threads,
                fast_gb,
                pages,
            } => {
                let mut fields = vec![
                    ("kind", Json::from("tiering")),
                    (
                        "apps",
                        Json::arr(apps.iter().map(|a| Json::from(a.as_str()))),
                    ),
                    ("epochs", (*epochs).into()),
                    ("seed", (*seed).into()),
                    ("threads", (*threads).into()),
                    ("fast_gb", (*fast_gb).into()),
                ];
                // Only an explicit override enters the canonical form:
                // specs written before the field existed keep their
                // canonical hash (and result-cache keys).
                if let Some(p) = pages {
                    fields.push(("pages", (*p).into()));
                }
                Json::obj(fields)
            }
            W::TieringHpc {
                socket,
                threads,
                epochs,
                seed,
            } => Json::obj(vec![
                ("kind", "tiering-hpc".into()),
                ("socket", (*socket).into()),
                ("threads", (*threads).into()),
                ("epochs", (*epochs).into()),
                ("seed", (*seed).into()),
            ]),
            W::Objects(o) => Json::obj(vec![
                ("kind", "objects".into()),
                ("socket", o.socket.into()),
                ("threads", o.threads.into()),
                ("compute_ns_per_byte", o.compute_ns_per_byte.into()),
                (
                    "objects",
                    Json::arr(o.objects.iter().map(|d| {
                        Json::obj(vec![
                            ("name", d.name.as_str().into()),
                            ("gb", d.gbytes.into()),
                            ("pattern", pattern_label(d.pattern).into()),
                            ("scans", d.scans.into()),
                            ("dep_frac", d.dep_frac.into()),
                        ])
                    })),
                ),
                (
                    "policies",
                    Json::arr(o.policies.iter().map(|p| Json::from(p.as_str()))),
                ),
                ("oli_search", o.oli_search.into()),
            ]),
        }
    }

    /// Short human label for `scenario validate` output.
    pub fn kind_label(&self) -> &'static str {
        self.workload.kind_label()
    }

    /// The canonical serialization as a compact JSON string — the
    /// byte identity the scenario-result cache stores and verifies.
    /// Formatting and field order never matter (parse ∘ to_json is the
    /// identity on canonical form) while any semantic change (a device
    /// override, a thread count, a policy list) changes the bytes.
    pub fn canonical_string(&self) -> String {
        self.to_json().to_string()
    }

    /// FNV-1a 64 content hash over [`ScenarioSpec::canonical_string`] —
    /// the scenario-result cache's index key. The hash is not
    /// collision-free, so cache hits additionally compare the stored
    /// canonical string ([`super::cache::ResultCache::lookup`]).
    pub fn canonical_hash(&self) -> u64 {
        crate::util::hash::hash_str(&self.canonical_string())
    }

    /// The cache identity pair `(key, canonical serialization)` — the
    /// single authority for the key scheme, serializing once. The key
    /// indexes the store; the canonical string is stored alongside and
    /// verified on every hit. The batch runner reuses the same pair to
    /// deduplicate identical specs within one batch (`scenario::batch`),
    /// so "same cache entry" and "same batch slot" can never disagree.
    pub fn cache_identity(&self) -> (String, String) {
        let canon = self.canonical_string();
        let key = crate::util::hash::hex16(crate::util::hash::hash_str(&canon));
        (key, canon)
    }

    /// Hex form of [`ScenarioSpec::canonical_hash`] (the on-disk cache key).
    pub fn cache_key(&self) -> String {
        self.cache_identity().0
    }
}

impl WorkloadSpec {
    /// Whether the evaluator consumes the whole `systems` list (the §III
    /// per-system probes) or exactly one system (everything else).
    /// Multi-system specs for single-system kinds are rejected at parse
    /// so no system is ever silently dropped.
    pub fn uses_all_systems(&self) -> bool {
        matches!(
            self,
            WorkloadSpec::Table1
                | WorkloadSpec::IdleLatency { .. }
                | WorkloadSpec::BwScaling { .. }
                | WorkloadSpec::LoadedLatency { .. }
        )
    }

    /// Short kind label (the spec's `workload.kind` value).
    pub fn kind_label(&self) -> &'static str {
        use WorkloadSpec as W;
        match self {
            W::Table1 => "table1",
            W::IdleLatency { .. } => "idle-latency",
            W::BwScaling { .. } => "bw-scaling",
            W::LoadedLatency { .. } => "loaded-latency",
            W::Assign { .. } => "assign",
            W::GpuCopy { .. } => "gpu-copy",
            W::GpuLatency => "gpu-latency",
            W::ZeroTrain => "zero-train",
            W::ZeroBreakdown => "zero-breakdown",
            W::Flexgen { .. } => "flexgen",
            W::HpcTable => "hpc-table",
            W::HpcPolicies { .. } => "hpc-policies",
            W::HpcScaling { .. } => "hpc-scaling",
            W::Oli { .. } => "oli",
            W::TieringApps { .. } => "tiering",
            W::TieringHpc { .. } => "tiering-hpc",
            W::Objects(_) => "objects",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_text(text: &str) -> Result<ScenarioSpec> {
        ScenarioSpec::parse(&Json::parse(text).unwrap())
    }

    #[test]
    fn minimal_spec_defaults() {
        let s = parse_text(r#"{"name": "t", "workload": {"kind": "table1"}}"#).unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.systems.len(), 1);
        assert_eq!(s.systems[0].base, "A");
        assert!(matches!(s.workload, WorkloadSpec::Table1));
    }

    #[test]
    fn schema_mismatch_rejected() {
        assert!(parse_text(
            r#"{"schema": "cxlmem-scenario-v0", "name": "t", "workload": {"kind": "table1"}}"#
        )
        .is_err());
    }

    #[test]
    fn unknown_kind_and_system_rejected() {
        assert!(parse_text(r#"{"name": "t", "workload": {"kind": "nope"}}"#).is_err());
        assert!(parse_text(
            r#"{"name": "t", "systems": ["Z"], "workload": {"kind": "table1"}}"#
        )
        .is_err());
    }

    #[test]
    fn device_override_applies() {
        let s = parse_text(
            r#"{"name": "t",
                "systems": [{"base": "A", "devices": {"2": "cxl-c"}}],
                "workload": {"kind": "table1"}}"#,
        )
        .unwrap();
        let sys = s.systems[0].build().unwrap();
        let preset = crate::memsim::topology::device_preset("cxl-c").unwrap();
        assert_eq!(sys.nodes[2].device.peak_bw_gbs, preset.peak_bw_gbs);
    }

    #[test]
    fn custom_profile_parses() {
        let s = parse_text(
            r#"{"name": "t",
                "systems": [{"base": "B", "devices": {"2": {
                    "kind": "cxl", "idle_seq_ns": 300, "idle_rand_ns": 320,
                    "peak_bw_gbs": 40, "stream_rate_gbs": 7.5, "capacity_gb": 96}}}],
                "workload": {"kind": "table1"}}"#,
        )
        .unwrap();
        let sys = s.systems[0].build().unwrap();
        assert_eq!(sys.nodes[2].device.peak_bw_gbs, 40.0);
        assert_eq!(sys.nodes[2].device.capacity, 96u64 << 30);
    }

    #[test]
    fn objects_spec_validates() {
        let ok = r#"{"name": "t", "workload": {"kind": "objects",
            "objects": [{"name": "a", "gb": 8, "pattern": "random", "dep_frac": 0.5}]}}"#;
        let s = parse_text(ok).unwrap();
        if let WorkloadSpec::Objects(o) = &s.workload {
            assert_eq!(o.objects.len(), 1);
            assert!(o.oli_search);
            assert_eq!(o.policies.len(), POLICY_NAMES.len());
        } else {
            panic!("wrong kind");
        }
        let bad = r#"{"name": "t", "workload": {"kind": "objects",
            "objects": [{"name": "a", "gb": -1}]}}"#;
        assert!(parse_text(bad).is_err());
        let bad_pol = r#"{"name": "t", "workload": {"kind": "objects",
            "objects": [{"name": "a", "gb": 1}], "policies": ["warp-drive"]}}"#;
        assert!(parse_text(bad_pol).is_err());
    }

    #[test]
    fn multi_system_single_kind_rejected() {
        // `assign` consumes one system; listing three must not silently
        // drop two of them.
        assert!(parse_text(
            r#"{"name": "t", "systems": ["A", "B", "C"], "workload": {"kind": "assign"}}"#
        )
        .is_err());
        // Multi-system kinds still take the full list.
        assert!(parse_text(
            r#"{"name": "t", "systems": ["A", "B", "C"], "workload": {"kind": "table1"}}"#
        )
        .is_ok());
    }

    #[test]
    fn bad_numeric_fields_rejected() {
        for bad in [
            r#"{"name": "t", "workload": {"kind": "tiering", "epochs": -1}}"#,
            r#"{"name": "t", "workload": {"kind": "tiering", "epochs": 0}}"#,
            r#"{"name": "t", "workload": {"kind": "tiering", "pages": 0}}"#,
            r#"{"name": "t", "workload": {"kind": "tiering", "pages": 1.5}}"#,
            r#"{"name": "t", "workload": {"kind": "idle-latency", "samples": 2.7}}"#,
            r#"{"name": "t", "workload": {"kind": "loaded-latency", "threads": 0}}"#,
            r#"{"name": "t", "workload": {"kind": "gpu-copy", "blocks_log2": [64]}}"#,
            r#"{"name": "t", "workload": {"kind": "hpc-scaling", "threads": [0, 8]}}"#,
        ] {
            assert!(parse_text(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn tiering_pages_override_round_trips() {
        // Explicit page override survives the canonical round trip and
        // changes the cache key; omitting it must canonicalize exactly
        // as pre-override specs did (stable cache keys).
        let plain = parse_text(r#"{"name": "t", "workload": {"kind": "tiering"}}"#).unwrap();
        if let WorkloadSpec::TieringApps { pages, .. } = &plain.workload {
            assert_eq!(*pages, None);
        } else {
            panic!("wrong kind");
        }
        assert!(!plain.to_json().to_string().contains("pages"));
        let scaled = parse_text(
            r#"{"name": "t", "workload": {"kind": "tiering", "pages": 1048576}}"#,
        )
        .unwrap();
        if let WorkloadSpec::TieringApps { pages, .. } = &scaled.workload {
            assert_eq!(*pages, Some(1 << 20));
        } else {
            panic!("wrong kind");
        }
        assert_ne!(plain.canonical_hash(), scaled.canonical_hash());
        // Round trip: re-parsing the canonical form preserves the field.
        let reparsed = ScenarioSpec::parse(&scaled.to_json()).unwrap();
        assert_eq!(scaled.canonical_hash(), reparsed.canonical_hash());
    }

    #[test]
    fn canonical_hash_ignores_formatting_but_not_content() {
        // Same spec, different field order + whitespace: same hash.
        let a = parse_text(
            r#"{"name": "h", "workload": {"kind": "loaded-latency", "threads": 16}}"#,
        )
        .unwrap();
        let b = parse_text(
            r#"{  "workload": {"threads": 16, "kind": "loaded-latency"},  "name": "h" }"#,
        )
        .unwrap();
        assert_eq!(a.canonical_hash(), b.canonical_hash());
        assert_eq!(a.cache_key(), b.cache_key());
        assert_eq!(a.cache_key().len(), 16);
        // A defaulted field made explicit is still the same canonical spec.
        let c = parse_text(
            r#"{"name": "h", "systems": ["A"],
                "workload": {"kind": "loaded-latency", "threads": 16}}"#,
        )
        .unwrap();
        assert_eq!(a.canonical_hash(), c.canonical_hash());
        // Any semantic change produces a new key.
        let d = parse_text(
            r#"{"name": "h", "workload": {"kind": "loaded-latency", "threads": 17}}"#,
        )
        .unwrap();
        assert_ne!(a.canonical_hash(), d.canonical_hash());
        let e = parse_text(
            r#"{"name": "h", "systems": [{"base": "A", "devices": {"2": "cxl-c"}}],
                "workload": {"kind": "loaded-latency", "threads": 16}}"#,
        )
        .unwrap();
        assert_ne!(a.canonical_hash(), e.canonical_hash());
    }

    #[test]
    fn roundtrip_is_stable() {
        let text = r#"{"name": "rt", "experiment": "fig3",
            "systems": ["A", {"base": "B", "devices": {"2": "cxl-a"}}],
            "workload": {"kind": "bw-scaling", "threads": [1, 2, 4]}}"#;
        let s1 = parse_text(text).unwrap();
        let j1 = s1.to_json();
        let s2 = ScenarioSpec::parse(&j1).unwrap();
        let j2 = s2.to_json();
        assert_eq!(j1, j2);
        assert_eq!(j1.to_string(), j2.to_string());
    }
}
