//! Cross-process fleet sharding: deterministic assignment of expanded
//! scenario specs to one of N cooperating runner processes.
//!
//! Scheme (pinned by the tests below and `rust/tests/scenario.rs`):
//! **input-index modulo**. Expansion is deterministic (same
//! file/seed/count ⇒ the same spec list in the same order), and shard
//! `k` of `n` — CLI `--shard k/n`, `k` 1-based — takes exactly the
//! specs whose 0-based position `i` in that list satisfies
//! `i % n == k - 1`.
//!
//! Index modulo was chosen over canonical-hash modulo deliberately:
//! shards stay balanced to within one spec no matter how similar the
//! specs are (hash modulo can skew small fleets badly), the mapping is
//! independent of the hash function (re-keying the cache can never
//! re-shard a fleet), and duplicates spread round-robin instead of
//! piling onto one shard. The cost is that assignment is positional —
//! every shard must be fed the *same* expanded list. That is the
//! intended workflow: `scenario expand` once, share the JSONL (or the
//! template file plus identical `--seed/--count`), and point every
//! process at the same `--cache-dir`; the shards rendezvous in the
//! shared store, and a coordinator re-run of the full list is then pure
//! cache hits, emitting the same bytes a single-process run would.
//!
//! Under the layered store ([`super::store`]) the rendezvous is
//! **segment adoption**: each shard's flushes seal uniquely-named
//! `seg-*.jsonl` segments (no store lock on the write path), the
//! coordinator's open adopts base + segments under the advisory lock,
//! and compaction folds everything into one `results.jsonl`. With
//! `--compact-every 0` the shards never lock at all — run
//! `cxlmem scenario compact <dir>` once afterwards (see `make
//! store-smoke`).

use std::fmt;

use anyhow::{anyhow, bail, Result};

/// One shard of an N-way split: `index` is 1-based, `1 <= index <= count`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub count: usize,
}

impl Shard {
    /// The trivial 1/1 shard (selects everything).
    pub fn whole() -> Self {
        Shard { index: 1, count: 1 }
    }

    /// Parse the CLI form `K/N` (e.g. `--shard 2/4`).
    pub fn parse(s: &str) -> Result<Shard> {
        let (k, n) = s
            .split_once('/')
            .ok_or_else(|| anyhow!("--shard wants K/N (e.g. 1/4), got '{s}'"))?;
        let index: usize = k
            .trim()
            .parse()
            .map_err(|_| anyhow!("--shard '{s}': K is not an integer"))?;
        let count: usize = n
            .trim()
            .parse()
            .map_err(|_| anyhow!("--shard '{s}': N is not an integer"))?;
        if count == 0 {
            bail!("--shard '{s}': N must be >= 1");
        }
        if index == 0 || index > count {
            bail!("--shard '{s}': K must be in 1..=N");
        }
        Ok(Shard { index, count })
    }

    /// Whether the item at 0-based input position `i` belongs to this
    /// shard: `i % count == index - 1`.
    pub fn selects(&self, i: usize) -> bool {
        i % self.count == self.index - 1
    }

    /// Filter a list down to this shard's slice, preserving input order.
    pub fn filter<T>(&self, items: Vec<T>) -> Vec<T> {
        items
            .into_iter()
            .enumerate()
            .filter(|(i, _)| self.selects(*i))
            .map(|(_, x)| x)
            .collect()
    }
}

impl fmt::Display for Shard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_k_of_n() {
        assert_eq!(Shard::parse("1/1").unwrap(), Shard::whole());
        assert_eq!(Shard::parse("2/4").unwrap(), Shard { index: 2, count: 4 });
        assert_eq!(Shard::parse(" 3 / 3 ").unwrap().to_string(), "3/3");
    }

    #[test]
    fn parse_rejects_bad_forms() {
        for bad in ["", "2", "a/b", "0/4", "5/4", "1/0", "-1/4", "1/-4"] {
            assert!(Shard::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    /// Pins the assignment scheme: 0-based index modulo N, shard K
    /// (1-based) takes `i % N == K - 1`. Every index lands in exactly
    /// one shard, shards are balanced to within one item, and the
    /// concatenation-in-index-order of all shards is the input.
    #[test]
    fn shards_partition_the_input_by_index_modulo() {
        let items: Vec<usize> = (0..23).collect();
        for count in 1..=5 {
            let mut seen = vec![0u32; items.len()];
            let mut sizes = Vec::new();
            for index in 1..=count {
                let sh = Shard { index, count };
                let part = sh.filter(items.clone());
                sizes.push(part.len());
                let mut prev = None;
                for &x in &part {
                    assert!(sh.selects(x), "item {x} not selected by {sh}");
                    assert_eq!(x % count, index - 1, "scheme drifted for {sh}");
                    seen[x] += 1;
                    // Order within a shard is input order.
                    assert!(prev.map_or(true, |p| p < x));
                    prev = Some(x);
                }
            }
            assert!(seen.iter().all(|&n| n == 1), "partition broken at N={count}");
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced split at N={count}: {sizes:?}");
        }
    }

    #[test]
    fn whole_shard_is_identity() {
        let items = vec!["a", "b", "c"];
        assert_eq!(Shard::whole().filter(items.clone()), items);
    }
}
