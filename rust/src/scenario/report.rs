//! Fleet summary reports: aggregate scenario-result JSONL into the
//! fleet-scale answers the raw lines only imply — which placement
//! policy wins on which device profile, how the policies' run times
//! distribute, and what the OLI per-object search buys over the best
//! static policy.
//!
//! Input is whatever `scenario run --out` wrote (one result document
//! per line) *or* a result-cache store (`<dir>/results.jsonl`, schema
//! `cxlmem-result-cache-v1` — each line's `result` field is the
//! document), so `cxlmem scenario report` can summarize a shared
//! `--cache-dir` that N `--shard` processes rendezvoused in without any
//! coordinator run. Damaged lines are skipped and counted, mirroring
//! the cache loader's tolerance.
//!
//! Output is an ordinary [`crate::report::Report`], so `--csv`/`--json`
//! and `--out` come for free from the shared renderer. Documents
//! without an `objects` policy grid (experiment reproductions, say) are
//! counted in the overview but excluded from the policy aggregation.
//! All aggregation is over `BTreeMap`/`BTreeSet`, so the report is
//! deterministic for a given input.
//!
//! Supervised runs interleave `cxlmem-result-error-v1` documents (see
//! [`crate::scenario::supervise`]) with genuine results; those route
//! into their own bucket and summarize as per-kind and per-shard error
//! tables. With `--expect FILE [--shards N]` the report also
//! *reconciles* coverage: every expected spec name is assigned to its
//! index-modulo shard (the `--shard K/N` scheme) and classified as
//! present, errored, or missing — the fleet-driver's answer to "which
//! shard lost work?".

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use super::cache::CACHE_SCHEMA;
use super::spec::POLICY_NAMES;
use super::supervise::ERROR_SCHEMA;
use crate::report::Report;
use crate::util::json::Json;
use crate::util::metrics::{self, METRICS_SCHEMA};
use crate::util::stats::{median, percentile};
use crate::util::table::{f3, Table};

/// The header row that identifies an `objects` policy-grid table (see
/// `scenario::eval::eval_objects`).
pub const GRID_HEADERS: [&str; 6] = ["policy", "total s", "stream s", "dep s", "compute s", "best"];

/// The policy-grid row the OLI per-object search reports under.
pub const OLI_ROW: &str = "OLI(search)";

/// One parsed policy grid: scenario name, device-profile label, and the
/// per-policy totals.
struct Grid {
    profile: String,
    /// `(policy, total seconds)`, in table order.
    rows: Vec<(String, f64)>,
    /// The starred (winning) policy and its total.
    best: (String, f64),
    /// Fastest non-OLI row — the best *static* placement.
    best_static: Option<(String, f64)>,
    /// The OLI(search) row's total, when the search ran.
    oli: Option<f64>,
}

/// Everything [`collect_docs`] pulled out of a results blob, routed by
/// schema so error documents and metrics sidecars never masquerade as
/// results.
#[derive(Default)]
pub struct Collected {
    /// Genuine result documents (direct lines or unwrapped cache lines).
    pub results: Vec<Json>,
    /// `cxlmem-metrics-v1` sidecar snapshots.
    pub metrics: Vec<Json>,
    /// `cxlmem-result-error-v1` documents from supervised runs.
    pub errors: Vec<Json>,
    /// Unparseable (damaged) lines, counted and skipped.
    pub skipped: usize,
}

/// Extract documents from a text blob: result JSONL as written by
/// `scenario run --out`, a result-cache store (each line's `result`
/// field), `cxlmem-metrics-v1` sidecar snapshots, and
/// `cxlmem-result-error-v1` documents — each routed into its own
/// [`Collected`] bucket, so `--metrics` sidecars and supervised-run
/// error lines can be concatenated straight onto the results.
pub fn collect_docs(text: &str) -> Collected {
    let mut out = Collected::default();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = match Json::parse(line) {
            Ok(d) => d,
            Err(_) => {
                out.skipped += 1;
                continue;
            }
        };
        match doc.get("schema").and_then(Json::as_str) {
            Some(s) if s == CACHE_SCHEMA => match doc.get("result") {
                Some(r) => out.results.push(r.clone()),
                None => out.skipped += 1,
            },
            Some(s) if s == METRICS_SCHEMA => out.metrics.push(doc),
            Some(s) if s == ERROR_SCHEMA => out.errors.push(doc),
            _ => out.results.push(doc),
        }
    }
    out
}

/// What a fleet run was *supposed* to produce: the expanded spec names
/// in input order, split over `shards` by the pinned index-modulo
/// scheme (`scenario::shard`). Built from an expanded spec JSONL file
/// via [`expectation_from_text`].
pub struct Expectation {
    /// Expected spec names, in expansion order.
    pub names: Vec<String>,
    /// How many `--shard K/N` processes the fleet was split over.
    pub shards: usize,
}

/// Parse `--expect FILE` input into an [`Expectation`]: expanded spec
/// JSONL (one `name`d document per line), or a sweep/fleet template,
/// which is expanded with its embedded seed/count first — so the same
/// file that fed `scenario expand | run --shard K/N` reconciles the
/// run.
pub fn expectation_from_text(text: &str, shards: usize) -> Result<Expectation> {
    if shards == 0 {
        bail!("--shards must be at least 1");
    }
    let mut names = Vec::new();
    for doc in super::batch::docs_of(text)? {
        let expanded = if super::expand::is_template(&doc) {
            super::expand::expand(&doc, None, None)?
        } else {
            vec![doc]
        };
        for spec in &expanded {
            match spec.get("name").and_then(Json::as_str) {
                Some(n) => names.push(n.to_string()),
                None => bail!("expected-spec document without a 'name' field"),
            }
        }
    }
    if names.is_empty() {
        bail!("no expected spec documents found (want expanded spec JSONL or a template)");
    }
    Ok(Expectation { names, shards })
}

/// Human label for a result document's device profile, from the
/// canonical `systems` echo: `"A"`, `"B+2:cxl-c"` (base + node:device
/// overrides), `"custom"` for a fully custom profile; multiple systems
/// join with `" & "`.
fn profile_label(doc: &Json) -> String {
    let Some(systems) = doc.get("systems").and_then(Json::as_arr) else {
        return "unknown".to_string();
    };
    let mut parts = Vec::new();
    for sys in systems {
        if let Some(s) = sys.as_str() {
            parts.push(s.to_string());
            continue;
        }
        let base = sys.get("base").and_then(Json::as_str).unwrap_or("?");
        let mut label = base.to_string();
        if let Some(devs) = sys.get("devices").and_then(Json::as_obj) {
            for (node, ov) in devs {
                let name = ov.as_str().unwrap_or("custom");
                label.push_str(&format!("+{node}:{name}"));
            }
        }
        parts.push(label);
    }
    if parts.is_empty() {
        "unknown".to_string()
    } else {
        parts.join(" & ")
    }
}

/// Parse a result document's `objects` policy grid, identified by its
/// exact header row. `None` when the document has no such table
/// (experiment reproductions) or the table is malformed.
fn grid_of(doc: &Json) -> Option<Grid> {
    let tables = doc.get("tables")?.as_arr()?;
    let table = tables.iter().find(|t| {
        t.get("headers").and_then(Json::as_arr).is_some_and(|hs| {
            hs.len() == GRID_HEADERS.len()
                && hs.iter().zip(GRID_HEADERS).all(|(h, w)| h.as_str() == Some(w))
        })
    })?;
    let mut rows = Vec::new();
    let mut best = None;
    for row in table.get("rows")?.as_arr()? {
        let cells = row.as_arr()?;
        if cells.len() != GRID_HEADERS.len() {
            return None;
        }
        let policy = cells[0].as_str()?.to_string();
        let total: f64 = cells[1].as_str()?.parse().ok()?;
        if !total.is_finite() {
            return None;
        }
        if best.is_none() && cells[5].as_str() == Some("*") {
            best = Some((policy.clone(), total));
        }
        rows.push((policy, total));
    }
    if rows.is_empty() {
        return None;
    }
    let best = best.or_else(|| min_row(rows.iter().map(|(p, t)| (p.as_str(), *t))))?;
    let best_static = min_row(
        rows.iter()
            .filter(|(p, _)| p != OLI_ROW)
            .map(|(p, t)| (p.as_str(), *t)),
    );
    let oli = rows.iter().find(|(p, _)| p == OLI_ROW).map(|(_, t)| *t);
    Some(Grid {
        profile: profile_label(doc),
        rows,
        best,
        best_static,
        oli,
    })
}

/// Row with the minimum total (first on ties — table order).
fn min_row<'a, I: Iterator<Item = (&'a str, f64)>>(rows: I) -> Option<(String, f64)> {
    let mut out: Option<(String, f64)> = None;
    for (p, t) in rows {
        if out.as_ref().map_or(true, |(_, b)| t < *b) {
            out = Some((p.to_string(), t));
        }
    }
    out
}

/// Canonical column/row order for policies: the declared grid order
/// ([`POLICY_NAMES`]) first, then anything unrecognized alphabetically,
/// then the OLI search row last.
fn policy_order(all: &BTreeSet<String>) -> Vec<String> {
    let mut out: Vec<String> = POLICY_NAMES
        .iter()
        .copied()
        .filter(|p| all.contains(*p))
        .map(str::to_string)
        .collect();
    for p in all {
        if p != OLI_ROW && !out.contains(p) {
            out.push(p.clone());
        }
    }
    if all.contains(OLI_ROW) {
        out.push(OLI_ROW.to_string());
    }
    out
}

/// Summarize result documents into a fleet report. `metrics_docs` are
/// `cxlmem-metrics-v1` sidecar snapshots (counters summed, gauge
/// high-water marks maxed, histograms bucket-merged across sidecars);
/// `skipped` is the damaged-line count from [`collect_docs`], surfaced
/// in the overview. Convenience wrapper over [`summarize_collected`]
/// for callers without error documents (`cxlmem stats`).
pub fn summarize_docs(docs: &[Json], metrics_docs: &[Json], skipped: usize) -> Report {
    let collected = Collected {
        results: docs.to_vec(),
        metrics: metrics_docs.to_vec(),
        errors: Vec::new(),
        skipped,
    };
    summarize_collected(&collected, None)
}

/// Summarize a routed [`Collected`] bundle into a fleet report,
/// optionally reconciling against an [`Expectation`] (the `--expect`
/// shard-coverage table).
pub fn summarize_collected(collected: &Collected, expected: Option<&Expectation>) -> Report {
    let docs = &collected.results;
    let (metrics_docs, skipped) = (&collected.metrics, collected.skipped);
    let grids: Vec<Grid> = docs.iter().filter_map(grid_of).collect();

    let mut policies = BTreeSet::new();
    // profile -> (grid count, wins per policy, best totals)
    let mut profiles: BTreeMap<String, (usize, BTreeMap<String, usize>, Vec<f64>)> =
        BTreeMap::new();
    // policy -> all observed totals
    let mut totals: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    // profile -> OLI-vs-best-static gains (fraction, positive = OLI faster)
    let mut gains: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for g in &grids {
        let entry = profiles.entry(g.profile.clone()).or_default();
        entry.0 += 1;
        *entry.1.entry(g.best.0.clone()).or_insert(0) += 1;
        entry.2.push(g.best.1);
        for (p, t) in &g.rows {
            policies.insert(p.clone());
            totals.entry(p.clone()).or_default().push(*t);
        }
        if let (Some(oli), Some((_, st))) = (g.oli, &g.best_static) {
            if *st > 0.0 {
                gains.entry(g.profile.clone()).or_default().push((*st - oli) / *st);
            }
        }
    }

    let mut report = Report::new();

    let mut overview = Table::new("Fleet summary — input", &["metric", "count"]);
    overview.row(vec!["result documents".into(), docs.len().to_string()]);
    overview.row(vec!["objects policy grids".into(), grids.len().to_string()]);
    let other = docs.len() - grids.len();
    overview.row(vec!["other result documents".into(), other.to_string()]);
    overview.row(vec!["error documents".into(), collected.errors.len().to_string()]);
    overview.row(vec!["unparseable lines skipped".into(), skipped.to_string()]);
    overview.row(vec!["device profiles".into(), profiles.len().to_string()]);
    overview.row(vec!["policies observed".into(), policies.len().to_string()]);
    report.add(overview);
    if let Some(exp) = expected {
        add_coverage_table(&mut report, exp, docs, &collected.errors);
    }
    add_error_tables(&mut report, &collected.errors);
    if grids.is_empty() {
        add_metrics_tables(&mut report, metrics_docs);
        return report;
    }

    let order = policy_order(&policies);

    let mut best_t = Table::new(
        "Fleet summary — best policy per device profile",
        &["profile", "results", "best policy", "wins", "win share", "median best s"],
    );
    for (profile, (n, wins, best_totals)) in &profiles {
        // Most wins; ties break to the canonical policy order (a plain
        // max_by_key would keep the *last* maximum).
        let mut top = ("", 0usize);
        for p in &order {
            if let Some(&w) = wins.get(p) {
                if w > top.1 {
                    top = (p.as_str(), w);
                }
            }
        }
        let (top, top_wins) = top;
        best_t.row(vec![
            profile.clone(),
            n.to_string(),
            top.to_string(),
            top_wins.to_string(),
            format!("{:.1}%", 100.0 * top_wins as f64 / *n as f64),
            f3(median(best_totals)),
        ]);
    }
    report.add(best_t);

    let mut headers: Vec<&str> = vec!["profile"];
    headers.extend(order.iter().map(String::as_str));
    let mut matrix = Table::new("Fleet summary — policy win matrix (wins per profile)", &headers);
    for (profile, (_, wins, _)) in &profiles {
        let mut row = vec![profile.clone()];
        for p in &order {
            row.push(wins.get(p).copied().unwrap_or(0).to_string());
        }
        matrix.row(row);
    }
    report.add(matrix);

    let mut quant = Table::new(
        "Fleet summary — total-time quantiles per policy (seconds)",
        &["policy", "n", "p10", "p50", "p90", "max"],
    );
    for p in &order {
        let ts = &totals[p];
        quant.row(vec![
            p.clone(),
            ts.len().to_string(),
            f3(percentile(ts, 10.0)),
            f3(percentile(ts, 50.0)),
            f3(percentile(ts, 90.0)),
            f3(ts.iter().cloned().fold(f64::NEG_INFINITY, f64::max)),
        ]);
    }
    report.add(quant);

    if !gains.is_empty() {
        let mut oli_t = Table::new(
            "Fleet summary — OLI(search) vs best static policy",
            &["profile", "n", "median gain %", "best gain %", "OLI wins"],
        );
        for (profile, gs) in &gains {
            let wins = gs.iter().filter(|&&g| g > 1e-9).count();
            oli_t.row(vec![
                profile.clone(),
                gs.len().to_string(),
                format!("{:.1}", 100.0 * median(gs)),
                format!("{:.1}", 100.0 * percentile(gs, 100.0)),
                wins.to_string(),
            ]);
        }
        report.add(oli_t);
    }
    add_metrics_tables(&mut report, metrics_docs);
    report
}

/// Reconcile expected-vs-present coverage per shard: every expected
/// spec name is assigned to its index-modulo shard (the same scheme
/// `--shard K/N` used to split the run) and classified as present (a
/// result document carries its name), errored (an error document
/// does), or missing (neither — the shard that lost it is the one to
/// re-run). A trailing `all` row totals the fleet.
fn add_coverage_table(report: &mut Report, exp: &Expectation, results: &[Json], errors: &[Json]) {
    let scenario_names = |docs: &[Json]| -> BTreeSet<String> {
        docs.iter()
            .filter_map(|d| d.get("scenario").and_then(Json::as_str))
            .map(str::to_string)
            .collect()
    };
    let present = scenario_names(results);
    let errored = scenario_names(errors);
    let n = exp.shards.max(1);
    let mut t = Table::new(
        "Fleet summary — shard coverage (expected vs present)",
        &["shard", "expected", "present", "errored", "missing", "missing names"],
    );
    let mut totals = [0usize; 4];
    for k in 1..=n {
        let mut counts = [0usize; 4];
        let mut missing: Vec<&str> = Vec::new();
        for (i, name) in exp.names.iter().enumerate() {
            if i % n != k - 1 {
                continue;
            }
            counts[0] += 1;
            if present.contains(name) {
                counts[1] += 1;
            } else if errored.contains(name) {
                counts[2] += 1;
            } else {
                counts[3] += 1;
                missing.push(name);
            }
        }
        for (tot, c) in totals.iter_mut().zip(counts) {
            *tot += c;
        }
        let sample = if missing.len() > 3 {
            format!("{}, … ({} total)", missing[..3].join(", "), missing.len())
        } else {
            missing.join(", ")
        };
        let mut row = vec![format!("{k}/{n}")];
        row.extend(counts.iter().map(usize::to_string));
        row.push(sample);
        t.row(row);
    }
    if n > 1 {
        let mut row = vec!["all".to_string()];
        row.extend(totals.iter().map(usize::to_string));
        row.push(String::new());
        t.row(row);
    }
    report.add(t);
}

/// Summarize `cxlmem-result-error-v1` documents: counts and worst
/// attempt depth per error kind, plus the per-shard error counts a
/// fleet driver pages on. No tables when the run was clean.
fn add_error_tables(report: &mut Report, errors: &[Json]) {
    if errors.is_empty() {
        return;
    }
    // kind -> (count, max attempts); shard -> count.
    let mut by_kind: BTreeMap<String, (usize, u64)> = BTreeMap::new();
    let mut by_shard: BTreeMap<String, usize> = BTreeMap::new();
    for doc in errors {
        let kind = doc.get("error").and_then(Json::as_str).unwrap_or("unknown");
        let attempts = doc.get("attempts").and_then(Json::as_u64).unwrap_or(1);
        let e = by_kind.entry(kind.to_string()).or_insert((0, 0));
        e.0 += 1;
        e.1 = e.1.max(attempts);
        let shard = doc.get("shard").and_then(Json::as_str).unwrap_or("-");
        *by_shard.entry(shard.to_string()).or_insert(0) += 1;
    }
    let mut kinds = Table::new(
        "Fleet summary — error documents by kind",
        &["error kind", "count", "max attempts"],
    );
    for (kind, (count, max_attempts)) in &by_kind {
        kinds.row(vec![kind.clone(), count.to_string(), max_attempts.to_string()]);
    }
    report.add(kinds);
    let mut shards = Table::new("Fleet summary — errors per shard", &["shard", "errors"]);
    for (shard, count) in &by_shard {
        shards.row(vec![shard.clone(), count.to_string()]);
    }
    report.add(shards);
}

/// Fold `cxlmem-metrics-v1` sidecars into fleet tables: counters sum
/// across sidecars (each shard counted its own work), gauge high-water
/// marks max (peak queue depth anywhere in the fleet), and histograms
/// merge by sparse bucket — exact, because every sidecar shares the
/// fixed `util::metrics` bucket edges.
fn add_metrics_tables(report: &mut Report, metrics_docs: &[Json]) {
    if metrics_docs.is_empty() {
        return;
    }
    let mut counters: BTreeMap<String, f64> = BTreeMap::new();
    let mut hwms: BTreeMap<String, f64> = BTreeMap::new();
    // name -> (merged sparse buckets, max observed value)
    let mut hists: BTreeMap<String, (BTreeMap<usize, u64>, f64)> = BTreeMap::new();
    for doc in metrics_docs {
        if let Some(cs) = doc.get("counters").and_then(Json::as_obj) {
            for (name, v) in cs {
                if let Some(x) = v.as_f64() {
                    *counters.entry(name.clone()).or_insert(0.0) += x;
                }
            }
        }
        if let Some(gs) = doc.get("gauges").and_then(Json::as_obj) {
            for (name, g) in gs {
                if let Some(x) = g.get("hwm").and_then(Json::as_f64) {
                    let e = hwms.entry(name.clone()).or_insert(f64::NEG_INFINITY);
                    if x > *e {
                        *e = x;
                    }
                }
            }
        }
        if let Some(hs) = doc.get("histograms").and_then(Json::as_obj) {
            for (name, h) in hs {
                let entry = hists.entry(name.clone()).or_default();
                if let Some(buckets) = h.get("buckets").and_then(Json::as_arr) {
                    for pair in buckets {
                        if let Some(p) = pair.as_arr().filter(|p| p.len() == 2) {
                            if let (Some(i), Some(c)) = (p[0].as_usize(), p[1].as_u64()) {
                                *entry.0.entry(i).or_insert(0) += c;
                            }
                        }
                    }
                }
                if let Some(mx) = h.get("max").and_then(Json::as_f64) {
                    if mx > entry.1 {
                        entry.1 = mx;
                    }
                }
            }
        }
    }

    let c = |name: &str| counters.get(name).copied().unwrap_or(0.0);
    let rate = |h: f64, m: f64| {
        if h + m > 0.0 {
            format!("{:.1}%", 100.0 * h / (h + m))
        } else {
            "-".to_string()
        }
    };
    let mut t = Table::new("Fleet summary — runtime metrics", &["metric", "value"]);
    t.row(vec!["metrics sidecars".into(), metrics_docs.len().to_string()]);
    let (ch, cm) = (c("scenario.cache.hits"), c("scenario.cache.misses"));
    t.row(vec!["result-cache hits".into(), (ch as u64).to_string()]);
    t.row(vec!["result-cache misses".into(), (cm as u64).to_string()]);
    t.row(vec!["result-cache hit rate".into(), rate(ch, cm)]);
    t.row(vec![
        "batch specs submitted".into(),
        (c("scenario.batch.specs") as u64).to_string(),
    ]);
    t.row(vec![
        "in-batch dedupe collapses".into(),
        (c("scenario.batch.dedup_collapsed") as u64).to_string(),
    ]);
    t.row(vec![
        "scenarios evaluated".into(),
        (c("scenario.batch.evaluated") as u64).to_string(),
    ]);
    let peak = hwms.get("scenario.batch.jobs_in_flight").copied().unwrap_or(0.0);
    t.row(vec!["peak jobs in flight".into(), (peak.max(0.0) as u64).to_string()]);
    t.row(vec![
        "trace generations".into(),
        (c("trace.generated") as u64).to_string(),
    ]);
    t.row(vec![
        "trace requests".into(),
        (c("trace.requests") as u64).to_string(),
    ]);
    let (sh, sm) = (c("solver.memo.hits"), c("solver.memo.misses"));
    t.row(vec!["solver memo hit rate".into(), rate(sh, sm)]);
    report.add(t);

    let mut quant = Table::new(
        "Fleet summary — eval-time quantiles per policy (ms)",
        &["policy", "n", "p50", "p90", "max"],
    );
    let ms = |ns: f64| format!("{:.3}", ns / 1e6);
    let mut any = false;
    for (name, (buckets, max_ns)) in &hists {
        let Some(policy) = name
            .strip_prefix("eval.policy.")
            .and_then(|s| s.strip_suffix(".ns"))
        else {
            continue;
        };
        let n: u64 = buckets.values().sum();
        if n == 0 {
            continue;
        }
        any = true;
        quant.row(vec![
            policy.to_string(),
            n.to_string(),
            ms(metrics::quantile_of_sparse(buckets, 50.0)),
            ms(metrics::quantile_of_sparse(buckets, 90.0)),
            ms(*max_ns),
        ]);
    }
    if any {
        report.add(quant);
    }
}

/// Summarize a results blob (see [`collect_docs`] for accepted forms)
/// into a fleet report. Errors when nothing parses at all — a wrong
/// file is a user error, not an empty fleet.
pub fn summarize_text(text: &str) -> Result<Report> {
    summarize_text_with(text, None)
}

/// [`summarize_text`] with an optional [`Expectation`] to reconcile
/// against (`scenario report --expect FILE [--shards N]`).
pub fn summarize_text_with(text: &str, expected: Option<&Expectation>) -> Result<Report> {
    let collected = collect_docs(text);
    let c = &collected;
    if c.results.is_empty() && c.metrics.is_empty() && c.errors.is_empty() {
        bail!(
            "no result documents found (want `scenario run` JSONL, a \
             result-cache store, metrics sidecars, or error documents){}",
            if collected.skipped > 0 {
                format!(" — {} unparseable line(s)", collected.skipped)
            } else {
                String::new()
            }
        );
    }
    Ok(summarize_collected(&collected, expected))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic result document with an `objects` policy grid.
    fn grid_doc(name: &str, system: Json, rows: &[(&str, f64, bool)]) -> Json {
        let table = Json::obj(vec![
            ("title", format!("Scenario {name} — policy grid").into()),
            (
                "headers",
                Json::arr(GRID_HEADERS.iter().map(|h| Json::from(*h))),
            ),
            (
                "rows",
                Json::arr(rows.iter().map(|(p, t, star)| {
                    Json::arr([
                        Json::from(*p),
                        Json::from(f3(*t)),
                        Json::from("0.000"),
                        Json::from("0.000"),
                        Json::from("0.000"),
                        Json::from(if *star { "*" } else { "" }),
                    ])
                })),
            ),
        ]);
        Json::obj(vec![
            ("scenario", name.into()),
            ("systems", Json::arr([system])),
            ("tables", Json::arr([table])),
        ])
    }

    fn sys_with_card(base: &str, node: usize, card: &str) -> Json {
        Json::obj(vec![
            ("base", base.into()),
            (
                "devices",
                Json::obj(vec![(&node.to_string()[..], card.into())]),
            ),
        ])
    }

    #[test]
    fn collect_docs_reads_results_cache_and_metrics_lines() {
        let result = r#"{"scenario": "s", "systems": ["A"], "tables": []}"#;
        let cached = format!(
            r#"{{"schema": "{CACHE_SCHEMA}", "key": "k", "scenario": "s", "spec": "x", "result": {result}}}"#
        );
        let sidecar = format!(
            r#"{{"schema": "{METRICS_SCHEMA}", "counters": {{"scenario.cache.hits": 3}}, "gauges": {{}}, "histograms": {{}}, "rates": {{}}}}"#
        );
        let error = format!(
            r#"{{"schema": "{ERROR_SCHEMA}", "scenario": "s9", "key": "k9", "error": "panic", "message": "boom", "attempts": 1}}"#
        );
        let text = format!("{result}\n{cached}\n{sidecar}\n{error}\n\nnot json\n");
        let c = collect_docs(&text);
        assert_eq!(c.results.len(), 2);
        assert_eq!(c.metrics.len(), 1, "metrics sidecar routed separately");
        assert_eq!(c.errors.len(), 1, "error document routed separately");
        assert_eq!(c.skipped, 1);
        assert_eq!(c.results[0], c.results[1], "cache line must unwrap to the result");
    }

    #[test]
    fn profile_labels_are_joinable() {
        let plain = grid_doc("p", Json::from("B"), &[("cxl-preferred", 1.0, true)]);
        assert_eq!(profile_label(&plain), "B");
        let carded = grid_doc("c", sys_with_card("A", 2, "cxl-b"), &[("x", 1.0, true)]);
        assert_eq!(profile_label(&carded), "A+2:cxl-b");
    }

    #[test]
    fn summarize_reports_best_policy_per_profile() {
        let a = sys_with_card("A", 2, "cxl-a");
        let c = sys_with_card("C", 2, "cxl-c");
        let docs = vec![
            grid_doc(
                "s0",
                a.clone(),
                &[("ldram-preferred", 1.0, true), ("cxl-preferred", 2.0, false)],
            ),
            grid_doc(
                "s1",
                a.clone(),
                &[("ldram-preferred", 1.5, true), ("cxl-preferred", 3.0, false)],
            ),
            grid_doc("s2", a, &[("ldram-preferred", 4.0, false), ("cxl-preferred", 3.0, true)]),
            grid_doc("s3", c, &[("ldram-preferred", 9.0, false), ("cxl-preferred", 5.0, true)]),
            // A non-grid document must be counted but not aggregated.
            Json::obj(vec![("scenario", "other".into()), ("tables", Json::arr([]))]),
        ];
        let report = summarize_docs(&docs, &[], 0);
        let best = report
            .tables
            .iter()
            .find(|t| t.title.contains("best policy per device profile"))
            .expect("best-policy table");
        assert_eq!(best.rows.len(), 2, "one row per device profile");
        let row_a = best.rows.iter().find(|r| r[0] == "A+2:cxl-a").unwrap();
        assert_eq!(row_a[1], "3");
        assert_eq!(row_a[2], "ldram-preferred");
        assert_eq!(row_a[3], "2");
        let row_c = best.rows.iter().find(|r| r[0] == "C+2:cxl-c").unwrap();
        assert_eq!(row_c[2], "cxl-preferred");
        assert_eq!(row_c[4], "100.0%");
        // Matrix: profile column + the two policies in canonical order.
        let matrix = report
            .tables
            .iter()
            .find(|t| t.title.contains("win matrix"))
            .unwrap();
        assert_eq!(matrix.headers, vec!["profile", "ldram-preferred", "cxl-preferred"]);
        // Overview counts the non-grid line.
        let overview = &report.tables[0];
        assert!(overview.rows.iter().any(|r| r[0] == "other result documents" && r[1] == "1"));
    }

    #[test]
    fn oli_gains_compare_to_best_static() {
        // OLI beats the best static (2.0) by 25% on one grid; the OLI
        // row must not count as "static" in the baseline.
        let docs = vec![grid_doc(
            "s",
            Json::from("A"),
            &[
                ("ldram-preferred", 2.0, false),
                ("interleave-ldram-cxl", 3.0, false),
                (OLI_ROW, 1.5, true),
            ],
        )];
        let report = summarize_docs(&docs, &[], 0);
        let oli = report
            .tables
            .iter()
            .find(|t| t.title.contains("OLI(search) vs best static"))
            .expect("OLI table");
        assert_eq!(oli.rows.len(), 1);
        assert_eq!(oli.rows[0][1], "1");
        assert_eq!(oli.rows[0][2], "25.0");
        assert_eq!(oli.rows[0][4], "1");
        // The OLI row sorts last in the quantile table.
        let quant = report
            .tables
            .iter()
            .find(|t| t.title.contains("quantiles per policy"))
            .unwrap();
        assert_eq!(quant.rows.last().unwrap()[0], OLI_ROW);
    }

    #[test]
    fn summarize_text_rejects_garbage() {
        assert!(summarize_text("").is_err());
        assert!(summarize_text("not json at all\n").is_err());
    }

    #[test]
    fn metrics_sidecars_fold_into_fleet_tables() {
        // Two "shard" sidecars, built from real registry snapshots:
        // counters sum, gauge high-water marks max, and the per-policy
        // histograms bucket-merge into the quantile table.
        let reg = metrics::Registry::new(true);
        reg.counter("scenario.cache.hits").add(3);
        reg.counter("scenario.cache.misses").add(1);
        reg.gauge("scenario.batch.jobs_in_flight").set(4);
        let h = reg.histogram("eval.policy.ldram-preferred.ns");
        for v in [1_000u64, 2_000, 4_000] {
            h.record(v);
        }
        let snap1 = reg.snapshot_at(1_000);
        let reg2 = metrics::Registry::new(true);
        reg2.counter("scenario.cache.hits").add(1);
        reg2.gauge("scenario.batch.jobs_in_flight").set(2);
        reg2.histogram("eval.policy.ldram-preferred.ns").record(8_000);
        let snap2 = reg2.snapshot_at(1_000);

        let report = summarize_docs(&[], &[snap1, snap2], 0);
        let t = report
            .tables
            .iter()
            .find(|t| t.title.contains("runtime metrics"))
            .expect("runtime metrics table");
        assert!(t.rows.iter().any(|r| r[0] == "result-cache hits" && r[1] == "4"));
        assert!(t.rows.iter().any(|r| r[0] == "result-cache misses" && r[1] == "1"));
        assert!(t.rows.iter().any(|r| r[0] == "result-cache hit rate" && r[1] == "80.0%"));
        assert!(t.rows.iter().any(|r| r[0] == "peak jobs in flight" && r[1] == "4"));
        let q = report
            .tables
            .iter()
            .find(|t| t.title.contains("eval-time quantiles per policy"))
            .expect("eval-time quantile table");
        assert_eq!(q.rows.len(), 1);
        assert_eq!(q.rows[0][0], "ldram-preferred");
        assert_eq!(q.rows[0][1], "4", "bucket merge must see all four samples");
    }

    #[test]
    fn error_docs_summarize_by_kind_and_shard() {
        use super::super::supervise::{error_doc, ErrorKind, Failure};
        let fail = |kind, attempts| Failure {
            kind,
            message: "injected fault at scenario.eval".into(),
            attempts,
        };
        let errors = vec![
            error_doc("f-0", "k0", &fail(ErrorKind::Panic, 1), Some("1/2")),
            error_doc("f-1", "k1", &fail(ErrorKind::Io, 3), Some("2/2")),
            error_doc("f-2", "k2", &fail(ErrorKind::Io, 3), Some("2/2")),
        ];
        let collected = Collected {
            results: vec![grid_doc("s0", Json::from("A"), &[("cxl-preferred", 1.0, true)])],
            metrics: vec![],
            errors,
            skipped: 0,
        };
        let report = summarize_collected(&collected, None);
        let overview = &report.tables[0];
        assert!(overview.rows.iter().any(|r| r[0] == "error documents" && r[1] == "3"));
        let kinds = report
            .tables
            .iter()
            .find(|t| t.title.contains("error documents by kind"))
            .expect("kind table");
        assert!(kinds.rows.iter().any(|r| r[0] == "io" && r[1] == "2" && r[2] == "3"));
        assert!(kinds.rows.iter().any(|r| r[0] == "panic" && r[1] == "1" && r[2] == "1"));
        let shards = report
            .tables
            .iter()
            .find(|t| t.title.contains("errors per shard"))
            .expect("shard table");
        assert!(shards.rows.iter().any(|r| r[0] == "1/2" && r[1] == "1"));
        assert!(shards.rows.iter().any(|r| r[0] == "2/2" && r[1] == "2"));
    }

    #[test]
    fn shard_coverage_reconciles_expected_vs_present() {
        use super::super::supervise::{error_doc, ErrorKind, Failure};
        fn counts(r: &[String]) -> Vec<&str> {
            r[1..5].iter().map(String::as_str).collect()
        }
        // Six expected specs over two shards (index modulo): shard 1/2
        // owns indices 0, 2, 4 and shard 2/2 owns 1, 3, 5. f-2 errored;
        // f-3 and f-5 never produced anything.
        let exp = Expectation {
            names: (0..6).map(|i| format!("f-{i}")).collect(),
            shards: 2,
        };
        let results: Vec<Json> = ["f-0", "f-1", "f-4"]
            .iter()
            .map(|n| grid_doc(n, Json::from("A"), &[("cxl-preferred", 1.0, true)]))
            .collect();
        let failure = Failure {
            kind: ErrorKind::Panic,
            message: "boom".into(),
            attempts: 1,
        };
        let errors = vec![error_doc("f-2", "k2", &failure, Some("1/2"))];
        let collected = Collected {
            results,
            metrics: vec![],
            errors,
            skipped: 0,
        };
        let report = summarize_collected(&collected, Some(&exp));
        let cov = report
            .tables
            .iter()
            .find(|t| t.title.contains("shard coverage"))
            .expect("coverage table");
        let s1 = cov.rows.iter().find(|r| r[0] == "1/2").unwrap();
        assert_eq!(counts(s1), ["3", "2", "1", "0"], "shard 1/2 fully accounted for");
        let s2 = cov.rows.iter().find(|r| r[0] == "2/2").unwrap();
        assert_eq!(counts(s2), ["3", "1", "0", "2"], "shard 2/2 lost two specs");
        assert_eq!(s2[5], "f-3, f-5");
        let all = cov.rows.iter().find(|r| r[0] == "all").unwrap();
        assert_eq!(counts(all), ["6", "3", "1", "2"]);
    }

    #[test]
    fn expectation_parses_jsonl_and_templates() {
        let jsonl = "{\"name\": \"a\"}\n{\"name\": \"b\"}\n";
        let e = expectation_from_text(jsonl, 2).unwrap();
        assert_eq!(e.names, vec!["a", "b"]);
        assert_eq!(e.shards, 2);
        assert!(expectation_from_text(jsonl, 0).is_err(), "zero shards is nonsense");
        assert!(expectation_from_text("", 1).is_err(), "empty expectation is a user error");
        assert!(expectation_from_text("{\"no_name\": 1}", 1).is_err());
        // A fleet template expands with its embedded count, so the same
        // file that fed `scenario expand` reconciles the run.
        let template = r#"{"name": "cov-fleet", "fleet": {"count": 3, "seed": 5}}"#;
        let e = expectation_from_text(template, 1).unwrap();
        assert_eq!(e.names.len(), 3);
    }
}
