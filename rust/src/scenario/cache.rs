//! Persistent scenario-result cache: content-addressed by the canonical
//! spec hash, disk-backed as append-only JSONL.
//!
//! Keying: [`crate::scenario::ScenarioSpec::cache_key`] — FNV-1a 64 over
//! the canonical serialization — indexes the store, and every entry also
//! carries the canonical spec string itself, which [`ResultCache::lookup`]
//! compares on hit: a 64-bit hash collision therefore degrades to a miss
//! (re-evaluation), never to another spec's results. Invalidation *is*
//! the content change: edit any field and the old entry is simply never
//! consulted again. The store never re-validates entries against the
//! evaluator, so after changing evaluator *code* the cache directory must
//! be deleted (or the run made with `--no-cache`); see README
//! "Result cache".
//!
//! On-disk format (`<dir>/results.jsonl`, schema
//! `cxlmem-result-cache-v1`): one line per entry, `{"schema": …,
//! "key": "<16-hex>", "scenario": "<name>", "spec": "<canonical JSON>",
//! "result": {…}}`, where `result` is the exact result document
//! `scenario run` would emit. Lines are only ever appended; unparseable
//! or foreign lines (a truncated tail write, an older schema) are
//! skipped on load, so a damaged cache degrades to re-evaluation rather
//! than an error. Within one store the first line for a key wins.
//!
//! Concurrency: the store is the rendezvous point for `--shard`ed fleet
//! processes, so all disk access is serialized under an advisory
//! exclusive lock on `<dir>/lock` ([`crate::util::lock::FileLock`] —
//! `flock(2)` on Unix). [`ResultCache::flush`] appends one line per
//! `write` call under the lock and re-reads the store's keys first, so
//! two shards that evaluated the same spec never tear a line *and* never
//! duplicate one; [`ResultCache::reload`] picks up entries other
//! processes flushed since open (first-insert-wins, so nothing a lookup
//! already returned ever changes). A lock that cannot be taken degrades
//! to the old unlocked behavior with a warning — the cache must never
//! block a run.
//!
//! Crash safety: a shard that dies mid-append leaves a torn tail line
//! (or, worse, interleaved garbage from a damaged filesystem). On load
//! the store **self-heals**: damaged lines — unparseable JSON, or our
//! schema missing required fields — are moved verbatim to the
//! `<dir>/quarantine.jsonl` sidecar (counted in the
//! `cache.quarantined_lines` metric) and the store is compacted to
//! exactly the surviving lines, byte-identical to a store that never
//! saw the damage. Valid foreign-schema lines are *kept* (they belong
//! to another tool or a future format, not to the damage). The
//! compaction writes a temp file and renames it into place, so a crash
//! mid-heal can at worst leave the original store. [`ResultCache::flush`]
//! additionally retries the whole locked append a bounded number of
//! times on IO errors (each attempt re-reads the on-disk keys, so
//! half-written attempts never duplicate lines) and starts appends on a
//! fresh line if a crashed writer left the tail without a newline —
//! the `cache.flush.io` fault point lets the chaos harness rehearse all
//! of this deterministically.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::batch::ScenarioResult;
use crate::util::json::Json;
use crate::util::lock::FileLock;
use crate::util::metrics;

/// Registry handles for the result-cache counters (`scenario.cache.*`
/// in `cxlmem stats` snapshots). Per-instance `hits`/`misses` fields
/// stay the CLI/test probes; these aggregate across every handle in the
/// process.
struct CacheMetrics {
    hits: &'static metrics::Counter,
    misses: &'static metrics::Counter,
    reloads: &'static metrics::Counter,
    flush_appends: &'static metrics::Counter,
    flush_retries: &'static metrics::Counter,
    quarantined_lines: &'static metrics::Counter,
    flush_lock_wait_ns: &'static metrics::Histogram,
}

fn cache_metrics() -> &'static CacheMetrics {
    static M: std::sync::OnceLock<CacheMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| CacheMetrics {
        hits: metrics::counter("scenario.cache.hits"),
        misses: metrics::counter("scenario.cache.misses"),
        reloads: metrics::counter("scenario.cache.reloads"),
        flush_appends: metrics::counter("scenario.cache.flush_appends"),
        flush_retries: metrics::counter("scenario.cache.flush_retries"),
        quarantined_lines: metrics::counter("cache.quarantined_lines"),
        flush_lock_wait_ns: metrics::histogram("scenario.cache.flush_lock_wait_ns"),
    })
}

/// Cache line schema identifier.
pub const CACHE_SCHEMA: &str = "cxlmem-result-cache-v1";
/// Default cache directory (relative to the working directory).
pub const DEFAULT_DIR: &str = ".cxlmem-cache";
/// Store file name inside the cache directory.
pub const STORE_FILE: &str = "results.jsonl";
/// Advisory lock file name inside the cache directory.
pub const LOCK_FILE: &str = "lock";
/// Sidecar file damaged store lines are quarantined to on load.
pub const QUARANTINE_FILE: &str = "quarantine.jsonl";
/// Whole-flush attempts before an IO error is surfaced to the caller.
const FLUSH_ATTEMPTS: u32 = 3;

/// One stored result: the canonical spec it was computed from (verified
/// on lookup) and the result document.
#[derive(Clone, Debug)]
struct Entry {
    spec: String,
    doc: Json,
}

/// A loaded cache: in-memory index over the JSONL store, with pending
/// inserts buffered until [`ResultCache::flush`].
#[derive(Debug)]
pub struct ResultCache {
    path: PathBuf,
    entries: BTreeMap<String, Entry>,
    /// Keys inserted this session, not yet appended to disk (the entry
    /// bodies live in `entries`): `(key, scenario name)`.
    pending: Vec<(String, String)>,
    hits: u64,
    misses: u64,
}

/// Parse one store line into `(key, entry)`; `None` for damage or
/// foreign schemas (the caller skips those).
fn parse_line(line: &str) -> Option<(String, Entry)> {
    if line.trim().is_empty() {
        return None;
    }
    let doc = Json::parse(line).ok()?;
    if doc.get("schema").and_then(Json::as_str) != Some(CACHE_SCHEMA) {
        return None;
    }
    let key = doc.get("key").and_then(Json::as_str)?;
    let spec = doc.get("spec").and_then(Json::as_str)?;
    let result = doc.get("result")?;
    Some((
        key.to_string(),
        Entry {
            spec: spec.to_string(),
            doc: result.clone(),
        },
    ))
}

/// Read the store text at `path`. An unreadable file degrades to `None`
/// with a warning: the cache must never block a run.
fn read_store(path: &Path) -> Option<String> {
    match fs::read_to_string(path) {
        Ok(t) => Some(t),
        Err(e) => {
            eprintln!(
                "warning: unreadable scenario result cache {} ({e}); treating as empty",
                path.display()
            );
            None
        }
    }
}

/// How a store line is treated on load.
enum LineClass {
    /// A well-formed entry of our schema.
    Entry(String, Entry),
    /// Valid JSON of another schema: not ours to judge — kept verbatim.
    Foreign,
    /// Unparseable, or our schema missing required fields: quarantined.
    Damaged,
    /// Whitespace only (an artifact, never written by us): dropped.
    Blank,
}

fn classify_line(line: &str) -> LineClass {
    if line.trim().is_empty() {
        return LineClass::Blank;
    }
    let Ok(doc) = Json::parse(line) else {
        return LineClass::Damaged;
    };
    if doc.get("schema").and_then(Json::as_str) != Some(CACHE_SCHEMA) {
        return LineClass::Foreign;
    }
    match parse_line(line) {
        Some((key, entry)) => LineClass::Entry(key, entry),
        None => LineClass::Damaged,
    }
}

/// Read the store at `path` into `entries`, keeping whatever is already
/// there (first-insert-wins — both across duplicate lines in the file
/// and against entries the caller holds in memory), and **self-heal**
/// any damage found: damaged lines are appended verbatim to the
/// quarantine sidecar and the store is compacted to the surviving lines
/// (original order, one trailing newline — byte-identical to a store
/// that never saw the damage). The caller holds the store lock. Healing
/// is best-effort: if the sidecar cannot be written the store is left
/// untouched (the damage stays tolerated in memory, nothing is lost).
/// Returns the number of keys added.
fn load_into(path: &Path, entries: &mut BTreeMap<String, Entry>) -> usize {
    let Some(text) = read_store(path) else {
        return 0;
    };
    let mut added = 0;
    let mut kept: Vec<&str> = Vec::new();
    let mut damaged: Vec<&str> = Vec::new();
    for line in text.lines() {
        match classify_line(line) {
            LineClass::Entry(key, entry) => {
                kept.push(line);
                if !entries.contains_key(&key) {
                    entries.insert(key, entry);
                    added += 1;
                }
            }
            LineClass::Foreign => kept.push(line),
            LineClass::Damaged => damaged.push(line),
            LineClass::Blank => {}
        }
    }
    let mut healed = String::with_capacity(text.len());
    for line in &kept {
        healed.push_str(line);
        healed.push('\n');
    }
    if healed != text {
        heal(path, &healed, &damaged);
    }
    added
}

/// Quarantine `damaged` lines and rewrite the store as `healed` (a temp
/// file renamed into place, so a crash mid-heal at worst leaves the
/// original). Failures degrade with a warning — never to data loss: the
/// store is only rewritten once the damaged lines are safely in the
/// sidecar.
fn heal(path: &Path, healed: &str, damaged: &[&str]) {
    if !damaged.is_empty() {
        let sidecar = match path.parent() {
            Some(dir) => dir.join(QUARANTINE_FILE),
            None => return,
        };
        let mut blob = String::new();
        for line in damaged {
            blob.push_str(line);
            blob.push('\n');
        }
        let appended = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&sidecar)
            .and_then(|mut f| f.write_all(blob.as_bytes()));
        if let Err(e) = appended {
            eprintln!(
                "warning: cannot quarantine {} damaged cache line(s) to {} ({e}); \
                 store left as-is",
                damaged.len(),
                sidecar.display()
            );
            return;
        }
        cache_metrics().quarantined_lines.add(damaged.len() as u64);
        eprintln!(
            "warning: quarantined {} damaged cache line(s) to {}",
            damaged.len(),
            sidecar.display()
        );
    }
    let tmp = path.with_extension("jsonl.tmp");
    let compacted = fs::write(&tmp, healed).and_then(|()| fs::rename(&tmp, path));
    if let Err(e) = compacted {
        let _ = fs::remove_file(&tmp);
        eprintln!(
            "warning: cache store {} not compacted ({e}); damage stays tolerated on load",
            path.display()
        );
    }
}

/// Take the store lock, degrading to unlocked access with a warning if
/// the lock file cannot be created/locked (read-only store, exotic FS).
fn lock_store(path: &Path) -> Option<FileLock> {
    let lock_path = path.parent()?.join(LOCK_FILE);
    match FileLock::acquire(&lock_path) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!(
                "warning: cache lock {} unavailable ({e}); proceeding unlocked",
                lock_path.display()
            );
            None
        }
    }
}

impl ResultCache {
    /// Open (or lazily create) the cache under `dir`. A missing
    /// directory/file is an empty cache, and so is an *unreadable* one
    /// (permissions, invalid UTF-8 from a torn write): the cache must
    /// degrade to re-evaluation, never block a run. Nothing is written
    /// until the first [`ResultCache::flush`] with pending entries.
    pub fn open(dir: &Path) -> Result<Self> {
        let path = dir.join(STORE_FILE);
        let mut entries = BTreeMap::new();
        if path.exists() {
            let _lock = lock_store(&path);
            load_into(&path, &mut entries);
        }
        Ok(Self {
            path,
            entries,
            pending: Vec::new(),
            hits: 0,
            misses: 0,
        })
    }

    /// Open the default store, [`DEFAULT_DIR`].
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new(DEFAULT_DIR))
    }

    /// Pick up entries other processes appended since open (or the last
    /// reload). Existing in-memory entries — loaded *or* inserted — are
    /// kept, so nothing a lookup already returned ever changes; pending
    /// inserts stay pending. Returns the number of new keys.
    pub fn reload(&mut self) -> Result<usize> {
        if !self.path.exists() {
            return Ok(0);
        }
        cache_metrics().reloads.inc();
        let _lock = lock_store(&self.path);
        Ok(load_into(&self.path, &mut self.entries))
    }

    /// Look a key up, verifying the entry was computed from the same
    /// canonical spec — a hash collision is served as a miss, never as
    /// another spec's results. Counts the hit/miss (the probe the cache
    /// tests use to prove a warm batch never evaluated anything).
    pub fn lookup(&mut self, key: &str, canonical_spec: &str) -> Option<&Json> {
        match self.entries.get(key) {
            Some(e) if e.spec == canonical_spec => {
                self.hits += 1;
                cache_metrics().hits.inc();
                Some(&e.doc)
            }
            _ => {
                self.misses += 1;
                cache_metrics().misses.inc();
                None
            }
        }
    }

    /// Record a freshly evaluated result under `key`. First insert wins
    /// (a colliding later spec stays uncached rather than overwriting);
    /// the entry reaches disk on the next [`ResultCache::flush`].
    pub fn insert(&mut self, key: String, canonical_spec: String, result: &ScenarioResult) {
        if self.entries.contains_key(&key) {
            return;
        }
        let entry = Entry {
            spec: canonical_spec,
            doc: result.doc.clone(),
        };
        self.entries.insert(key.clone(), entry);
        self.pending.push((key, result.name.clone()));
    }

    /// Append pending entries to the store, creating the directory/file
    /// on first use. The whole append runs under the store's advisory
    /// lock: the current on-disk keys are re-read first (a concurrent
    /// shard may have flushed the same spec already — those lines are
    /// not appended again), then each surviving entry is written as one
    /// whole line per `write` call, so a concurrent reader never sees a
    /// torn line and a crash mid-flush loses at most the unwritten tail.
    ///
    /// IO errors retry the whole locked section up to [`FLUSH_ATTEMPTS`]
    /// times — the re-read makes retries idempotent: lines a failed
    /// attempt did complete are seen on disk and skipped, and a torn
    /// tail fragment is healed by the next load. Only after the last
    /// attempt is the error surfaced, with pending entries retained so a
    /// later flush can still try.
    pub fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            fs::create_dir_all(dir)
                .with_context(|| format!("creating cache dir {}", dir.display()))?;
        }
        let mut attempt = 0;
        loop {
            attempt += 1;
            match self.flush_once() {
                Ok(()) => {
                    self.pending.clear();
                    return Ok(());
                }
                Err(e) if attempt < FLUSH_ATTEMPTS => {
                    cache_metrics().flush_retries.inc();
                    eprintln!(
                        "warning: cache flush attempt {attempt}/{FLUSH_ATTEMPTS} failed ({e}); \
                         retrying"
                    );
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One locked flush attempt (see [`ResultCache::flush`]).
    fn flush_once(&self) -> Result<()> {
        let m = cache_metrics();
        // The lock is the shard rendezvous point: time waiting for it is
        // the contention signal the serve-fleet roadmap item watches.
        let _lock = m.flush_lock_wait_ns.time(|| lock_store(&self.path));
        // Chaos hook: an `io` rule here fails the attempt after the lock
        // is held, exercising the retry loop end to end.
        crate::util::fault::io_point("cache.flush.io", &self.path.to_string_lossy())
            .with_context(|| format!("writing cache store {}", self.path.display()))?;
        let mut on_disk = BTreeMap::new();
        let mut needs_newline = false;
        if self.path.exists() {
            if let Some(text) = read_store(&self.path) {
                needs_newline = !text.is_empty() && !text.ends_with('\n');
                for line in text.lines() {
                    if let Some((key, entry)) = parse_line(line) {
                        on_disk.entry(key).or_insert(entry);
                    }
                }
            }
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening cache store {}", self.path.display()))?;
        if needs_newline {
            // A crashed writer left a torn tail: start on a fresh line so
            // this append cannot concatenate into the fragment (the
            // fragment itself is quarantined on the next load).
            f.write_all(b"\n")
                .with_context(|| format!("appending to cache store {}", self.path.display()))?;
        }
        for (key, name) in &self.pending {
            if on_disk.contains_key(key) {
                continue;
            }
            let entry = match self.entries.get(key) {
                Some(e) => e,
                None => continue,
            };
            let line = Json::obj(vec![
                ("schema", CACHE_SCHEMA.into()),
                ("key", key.as_str().into()),
                ("scenario", name.as_str().into()),
                ("spec", entry.spec.as_str().into()),
                ("result", entry.doc.clone()),
            ]);
            let mut text = line.to_string();
            text.push('\n');
            f.write_all(text.as_bytes())
                .with_context(|| format!("appending to cache store {}", self.path.display()))?;
            m.flush_appends.inc();
        }
        Ok(())
    }

    /// Lookups served from the cache since open.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to evaluation since open.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Number of distinct keys currently held (loaded + inserted).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Path of the backing store file.
    pub fn store_path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-cache-{tag}-{}", std::process::id()))
    }

    fn result(name: &str, v: u64) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            experiment: None,
            doc: Json::obj(vec![("scenario", name.into()), ("v", v.into())]),
        }
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        assert!(c.is_empty());
        assert!(c.lookup("00ab", "spec-a").is_none());
        c.insert("00ab".into(), "spec-a".into(), &result("one", 1));
        c.insert("00cd".into(), "spec-b".into(), &result("two", 2));
        c.flush().unwrap();
        // A fresh open sees both entries; hit/miss counters start clean.
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        let v = c2.lookup("00ab", "spec-a").unwrap().get("v").unwrap().as_u64();
        assert_eq!(v, Some(1));
        assert!(c2.lookup("zz", "spec-a").is_none());
        assert_eq!((c2.hits(), c2.misses()), (1, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn colliding_key_with_different_spec_misses() {
        // A 64-bit key collision must degrade to a miss (re-evaluation),
        // never serve another spec's results.
        let dir = tmp_dir("collision");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        assert!(c.lookup("k", "spec-b").is_none());
        assert_eq!((c.hits(), c.misses()), (0, 1));
        assert!(c.lookup("k", "spec-a").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn first_insert_wins_and_reinsert_is_noop() {
        let dir = tmp_dir("dup");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        c.insert("k".into(), "spec-b".into(), &result("b", 2));
        c.flush().unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        let doc = c2.lookup("k", "spec-a").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn damaged_lines_are_skipped_not_fatal() {
        let dir = tmp_dir("damaged");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("good".into(), "spec-g".into(), &result("ok", 7));
            c.flush().unwrap();
        }
        // A truncated tail write, a foreign-schema line, and a line of
        // our schema missing the 'spec' field (older format).
        let path = dir.join(STORE_FILE);
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("{\"schema\": \"other-v9\", \"key\": \"x\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"y\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"trunc");
        fs::write(&path, text).unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert!(c.lookup("good", "spec-g").is_some());
        assert!(c.lookup("x", "any").is_none());
        assert!(c.lookup("y", "any").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_without_pending_creates_nothing() {
        let dir = tmp_dir("empty");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.flush().unwrap();
        assert!(!dir.exists(), "an untouched cache must not litter the disk");
    }

    /// Two handles on one store, flushing interleaved entries: neither
    /// flush corrupts the other's lines, `reload()` surfaces the sibling's
    /// entries without touching ones already held, and a fresh open sees
    /// the union.
    #[test]
    fn interleaved_handles_share_the_store_via_reload() {
        let dir = tmp_dir("interleave");
        let _ = fs::remove_dir_all(&dir);
        let mut c1 = ResultCache::open(&dir).unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        c1.insert("ka".into(), "spec-a".into(), &result("a", 1));
        c1.flush().unwrap();
        c2.insert("kb".into(), "spec-b".into(), &result("b", 2));
        c2.flush().unwrap();

        // c1 has never seen kb; reload picks it up, and only it.
        assert!(c1.lookup("kb", "spec-b").is_none());
        assert_eq!(c1.reload().unwrap(), 1);
        assert_eq!(c1.len(), 2);
        let doc = c1.lookup("kb", "spec-b").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(2));
        // Nothing already held changed (first-insert-wins).
        let held = c1.lookup("ka", "spec-a").unwrap();
        assert_eq!(held.get("v").unwrap().as_u64(), Some(1));
        // A second reload finds nothing new.
        assert_eq!(c1.reload().unwrap(), 0);

        let c3 = ResultCache::open(&dir).unwrap();
        assert_eq!(c3.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Two handles that each evaluated the *same* spec (a shard overlap):
    /// the second flush must not append a duplicate line — the store ends
    /// up with one line for the key, and its content is the first
    /// flusher's (first-insert-wins at the store level too).
    #[test]
    fn overlapping_flushes_do_not_duplicate_lines() {
        let dir = tmp_dir("overlap");
        let _ = fs::remove_dir_all(&dir);
        let mut c1 = ResultCache::open(&dir).unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        c1.insert("k".into(), "spec".into(), &result("first", 1));
        c2.insert("k".into(), "spec".into(), &result("second", 2));
        c1.flush().unwrap();
        c2.flush().unwrap();

        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), 1, "duplicate key was re-appended");
        let mut c3 = ResultCache::open(&dir).unwrap();
        assert_eq!(c3.len(), 1);
        let doc = c3.lookup("k", "spec").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    /// A torn mid-line tail (crashed writer) is quarantined on load and
    /// the store compacts back to **byte-identical** with a store that
    /// never saw the damage — and stays stable across further reopens.
    #[test]
    fn torn_tail_quarantines_and_compacts_byte_identical() {
        let dir = tmp_dir("torn-tail");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("k1".into(), "spec-1".into(), &result("one", 1));
            c.insert("k2".into(), "spec-2".into(), &result("two", 2));
            c.flush().unwrap();
        }
        let path = dir.join(STORE_FILE);
        let pristine = fs::read_to_string(&path).unwrap();

        let torn = "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"t";
        fs::write(&path, format!("{pristine}{torn}")).unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.lookup("k1", "spec-1").is_some());
        assert_eq!(
            fs::read_to_string(&path).unwrap(),
            pristine,
            "healed store must be byte-identical to a never-damaged one"
        );
        let quarantined = fs::read_to_string(dir.join(QUARANTINE_FILE)).unwrap();
        assert_eq!(quarantined, format!("{torn}\n"), "fragment kept verbatim");

        // Reopening a healed store is a no-op: nothing new quarantined,
        // nothing rewritten.
        let c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(fs::read_to_string(&path).unwrap(), pristine);
        assert_eq!(fs::read_to_string(dir.join(QUARANTINE_FILE)).unwrap(), quarantined);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Garbage interleaved *between* valid lines: the valid lines (ours
    /// and foreign-schema alike) survive in order, the garbage moves to
    /// the sidecar in order.
    #[test]
    fn interleaved_garbage_is_quarantined_in_order() {
        let dir = tmp_dir("interleaved-garbage");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("k1".into(), "spec-1".into(), &result("one", 1));
            c.insert("k2".into(), "spec-2".into(), &result("two", 2));
            c.flush().unwrap();
        }
        let path = dir.join(STORE_FILE);
        let pristine = fs::read_to_string(&path).unwrap();
        let mut lines = pristine.lines();
        let (line1, line2) = (lines.next().unwrap(), lines.next().unwrap());
        let foreign = "{\"schema\": \"other-v9\", \"key\": \"f\"}";
        let missing = "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"m\"}";
        let damaged_text =
            format!("not json at all\n{line1}\n{missing}\n{foreign}\n\n{line2}garbage tail\n");
        fs::write(&path, &damaged_text).unwrap();

        let before = crate::util::metrics::counter("cache.quarantined_lines").get();
        let mut c = ResultCache::open(&dir).unwrap();
        // line2 was fused with "garbage tail" — unparseable, quarantined;
        // line1 and the foreign line survive.
        assert_eq!(c.len(), 1);
        assert!(c.lookup("k1", "spec-1").is_some());
        assert_eq!(
            fs::read_to_string(&path).unwrap(),
            format!("{line1}\n{foreign}\n")
        );
        let quarantined = fs::read_to_string(dir.join(QUARANTINE_FILE)).unwrap();
        assert_eq!(
            quarantined,
            format!("not json at all\n{missing}\n{line2}garbage tail\n"),
            "damaged lines keep file order, verbatim"
        );
        if crate::util::metrics::global().enabled() {
            assert!(
                crate::util::metrics::counter("cache.quarantined_lines").get() >= before + 3,
                "quarantined lines must be counted"
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// A zero-byte store (created then never written, or truncated to
    /// nothing) is an empty cache: no quarantine, no rewrite, and the
    /// next flush appends normally.
    #[test]
    fn zero_byte_store_is_an_empty_cache() {
        let dir = tmp_dir("zero-byte");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join(STORE_FILE);
        fs::write(&path, "").unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert!(c.is_empty());
        assert!(!dir.join(QUARANTINE_FILE).exists(), "nothing to quarantine");
        assert_eq!(fs::read_to_string(&path).unwrap(), "");
        c.insert("k".into(), "spec".into(), &result("a", 1));
        c.flush().unwrap();
        let c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Transient IO failures during flush burn retries, not results: an
    /// injected fault that fires twice is absorbed by the three-attempt
    /// loop and the store ends up complete.
    #[test]
    fn flush_retries_through_transient_io_faults() {
        use crate::util::fault;

        let dir = tmp_dir("flushfault");
        let _ = fs::remove_dir_all(&dir);
        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("cache.flush.io/flushfault=io:2").unwrap());
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec".into(), &result("a", 1));
        c.flush().expect("third attempt must succeed");
        assert_eq!(fault::fired("cache.flush.io"), 2);
        fault::clear();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        assert!(c2.lookup("k", "spec").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    /// An append onto a torn (newline-less) tail starts on a fresh line,
    /// so the new entry is never fused into the fragment; the next load
    /// quarantines the fragment and keeps the entry.
    #[test]
    fn flush_onto_torn_tail_never_fuses_lines() {
        let dir = tmp_dir("torn-append");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join(STORE_FILE);
        fs::write(&path, "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"t").unwrap();
        // Open tolerates (and heals) the fragment; then damage it again
        // to simulate a shard crashing *between* our open and flush.
        let mut c = ResultCache::open(&dir).unwrap();
        fs::write(&path, "{\"torn").unwrap();
        c.insert("k".into(), "spec".into(), &result("a", 1));
        c.flush().unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1, "appended entry must survive the fragment");
        assert!(c2.lookup("k", "spec").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    /// Many concurrent writers (threads here; the lock excludes separate
    /// processes the same way — each handle locks its own descriptor):
    /// every entry survives, every line parses, no lookup is corrupted.
    #[test]
    fn concurrent_writers_never_tear_lines() {
        let dir = tmp_dir("concurrent");
        let _ = fs::remove_dir_all(&dir);
        const WRITERS: usize = 4;
        const PER_WRITER: usize = 8;
        std::thread::scope(|s| {
            for w in 0..WRITERS {
                let dir = dir.clone();
                s.spawn(move || {
                    let mut c = ResultCache::open(&dir).unwrap();
                    for i in 0..PER_WRITER {
                        // A long filler pushes each line well past any
                        // small-write atomicity threshold.
                        let name = format!("w{w}-{i}-{}", "x".repeat(512));
                        c.insert(
                            format!("k-{w}-{i}"),
                            format!("spec-{w}-{i}"),
                            &result(&name, (w * PER_WRITER + i) as u64),
                        );
                        c.flush().unwrap();
                    }
                });
            }
        });
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), WRITERS * PER_WRITER, "entries were lost or torn");
        for w in 0..WRITERS {
            for i in 0..PER_WRITER {
                let doc = c
                    .lookup(&format!("k-{w}-{i}"), &format!("spec-{w}-{i}"))
                    .unwrap_or_else(|| panic!("k-{w}-{i} missing"));
                assert_eq!(doc.get("v").unwrap().as_u64(), Some((w * PER_WRITER + i) as u64));
            }
        }
        // Every stored line parses back as a well-formed entry.
        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), WRITERS * PER_WRITER);
        assert!(text.lines().all(|l| parse_line(l).is_some()));
        let _ = fs::remove_dir_all(&dir);
    }
}
