//! Persistent scenario-result cache: content-addressed by the canonical
//! spec hash, disk-backed as append-only JSONL.
//!
//! Keying: [`crate::scenario::ScenarioSpec::cache_key`] — FNV-1a 64 over
//! the canonical serialization — indexes the store, and every entry also
//! carries the canonical spec string itself, which [`ResultCache::lookup`]
//! compares on hit: a 64-bit hash collision therefore degrades to a miss
//! (re-evaluation), never to another spec's results. Invalidation *is*
//! the content change: edit any field and the old entry is simply never
//! consulted again. The store never re-validates entries against the
//! evaluator, so after changing evaluator *code* the cache directory must
//! be deleted (or the run made with `--no-cache`); see README
//! "Result cache".
//!
//! On-disk format (`<dir>/results.jsonl`, schema
//! `cxlmem-result-cache-v1`): one line per entry, `{"schema": …,
//! "key": "<16-hex>", "scenario": "<name>", "spec": "<canonical JSON>",
//! "result": {…}}`, where `result` is the exact result document
//! `scenario run` would emit. Lines are only ever appended; unparseable
//! or foreign lines (a truncated tail write, an older schema) are
//! skipped on load, so a damaged cache degrades to re-evaluation rather
//! than an error. Within one store the first line for a key wins —
//! re-inserting an existing key is a no-op, so concurrent writers can at
//! worst duplicate a line, never corrupt a lookup.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::batch::ScenarioResult;
use crate::util::json::Json;

/// Cache line schema identifier.
pub const CACHE_SCHEMA: &str = "cxlmem-result-cache-v1";
/// Default cache directory (relative to the working directory).
pub const DEFAULT_DIR: &str = ".cxlmem-cache";
/// Store file name inside the cache directory.
pub const STORE_FILE: &str = "results.jsonl";

/// One stored result: the canonical spec it was computed from (verified
/// on lookup) and the result document.
#[derive(Clone, Debug)]
struct Entry {
    spec: String,
    doc: Json,
}

/// A loaded cache: in-memory index over the JSONL store, with pending
/// inserts buffered until [`ResultCache::flush`].
#[derive(Debug)]
pub struct ResultCache {
    path: PathBuf,
    entries: BTreeMap<String, Entry>,
    /// Keys inserted this session, not yet appended to disk (the entry
    /// bodies live in `entries`): `(key, scenario name)`.
    pending: Vec<(String, String)>,
    hits: u64,
    misses: u64,
}

impl ResultCache {
    /// Open (or lazily create) the cache under `dir`. A missing
    /// directory/file is an empty cache, and so is an *unreadable* one
    /// (permissions, invalid UTF-8 from a torn write): the cache must
    /// degrade to re-evaluation, never block a run. Nothing is written
    /// until the first [`ResultCache::flush`] with pending entries.
    pub fn open(dir: &Path) -> Result<Self> {
        let path = dir.join(STORE_FILE);
        let mut entries = BTreeMap::new();
        if path.exists() {
            let text = match fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!(
                        "warning: unreadable scenario result cache {} ({e}); starting empty",
                        path.display()
                    );
                    String::new()
                }
            };
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                // Tolerate damage: skip anything that isn't a well-formed
                // entry of our schema instead of failing the whole run.
                let doc = match Json::parse(line) {
                    Ok(d) => d,
                    Err(_) => continue,
                };
                if doc.get("schema").and_then(Json::as_str) != Some(CACHE_SCHEMA) {
                    continue;
                }
                let key = doc.get("key").and_then(Json::as_str);
                let spec = doc.get("spec").and_then(Json::as_str);
                if let (Some(key), Some(spec), Some(result)) = (key, spec, doc.get("result")) {
                    entries.entry(key.to_string()).or_insert_with(|| Entry {
                        spec: spec.to_string(),
                        doc: result.clone(),
                    });
                }
            }
        }
        Ok(Self {
            path,
            entries,
            pending: Vec::new(),
            hits: 0,
            misses: 0,
        })
    }

    /// Open the default store, [`DEFAULT_DIR`].
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new(DEFAULT_DIR))
    }

    /// Look a key up, verifying the entry was computed from the same
    /// canonical spec — a hash collision is served as a miss, never as
    /// another spec's results. Counts the hit/miss (the probe the cache
    /// tests use to prove a warm batch never evaluated anything).
    pub fn lookup(&mut self, key: &str, canonical_spec: &str) -> Option<&Json> {
        match self.entries.get(key) {
            Some(e) if e.spec == canonical_spec => {
                self.hits += 1;
                Some(&e.doc)
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Record a freshly evaluated result under `key`. First insert wins
    /// (a colliding later spec stays uncached rather than overwriting);
    /// the entry reaches disk on the next [`ResultCache::flush`].
    pub fn insert(&mut self, key: String, canonical_spec: String, result: &ScenarioResult) {
        if self.entries.contains_key(&key) {
            return;
        }
        let entry = Entry {
            spec: canonical_spec,
            doc: result.doc.clone(),
        };
        self.entries.insert(key.clone(), entry);
        self.pending.push((key, result.name.clone()));
    }

    /// Append pending entries to the store, creating the directory/file
    /// on first use.
    pub fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            fs::create_dir_all(dir)
                .with_context(|| format!("creating cache dir {}", dir.display()))?;
        }
        let mut out = String::new();
        for (key, name) in self.pending.drain(..) {
            let entry = match self.entries.get(&key) {
                Some(e) => e,
                None => continue,
            };
            let line = Json::obj(vec![
                ("schema", CACHE_SCHEMA.into()),
                ("key", key.into()),
                ("scenario", name.into()),
                ("spec", entry.spec.as_str().into()),
                ("result", entry.doc.clone()),
            ]);
            out.push_str(&line.to_string());
            out.push('\n');
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening cache store {}", self.path.display()))?;
        f.write_all(out.as_bytes())
            .with_context(|| format!("appending to cache store {}", self.path.display()))?;
        Ok(())
    }

    /// Lookups served from the cache since open.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to evaluation since open.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Number of distinct keys currently held (loaded + inserted).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Path of the backing store file.
    pub fn store_path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-cache-{tag}-{}", std::process::id()))
    }

    fn result(name: &str, v: u64) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            experiment: None,
            doc: Json::obj(vec![("scenario", name.into()), ("v", v.into())]),
        }
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        assert!(c.is_empty());
        assert!(c.lookup("00ab", "spec-a").is_none());
        c.insert("00ab".into(), "spec-a".into(), &result("one", 1));
        c.insert("00cd".into(), "spec-b".into(), &result("two", 2));
        c.flush().unwrap();
        // A fresh open sees both entries; hit/miss counters start clean.
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        let v = c2.lookup("00ab", "spec-a").unwrap().get("v").unwrap().as_u64();
        assert_eq!(v, Some(1));
        assert!(c2.lookup("zz", "spec-a").is_none());
        assert_eq!((c2.hits(), c2.misses()), (1, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn colliding_key_with_different_spec_misses() {
        // A 64-bit key collision must degrade to a miss (re-evaluation),
        // never serve another spec's results.
        let dir = tmp_dir("collision");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        assert!(c.lookup("k", "spec-b").is_none());
        assert_eq!((c.hits(), c.misses()), (0, 1));
        assert!(c.lookup("k", "spec-a").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn first_insert_wins_and_reinsert_is_noop() {
        let dir = tmp_dir("dup");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        c.insert("k".into(), "spec-b".into(), &result("b", 2));
        c.flush().unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        let doc = c2.lookup("k", "spec-a").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn damaged_lines_are_skipped_not_fatal() {
        let dir = tmp_dir("damaged");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("good".into(), "spec-g".into(), &result("ok", 7));
            c.flush().unwrap();
        }
        // A truncated tail write, a foreign-schema line, and a line of
        // our schema missing the 'spec' field (older format).
        let path = dir.join(STORE_FILE);
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("{\"schema\": \"other-v9\", \"key\": \"x\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"y\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"trunc");
        fs::write(&path, text).unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert!(c.lookup("good", "spec-g").is_some());
        assert!(c.lookup("x", "any").is_none());
        assert!(c.lookup("y", "any").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_without_pending_creates_nothing() {
        let dir = tmp_dir("empty");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.flush().unwrap();
        assert!(!dir.exists(), "an untouched cache must not litter the disk");
    }
}
