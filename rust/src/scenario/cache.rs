//! Persistent scenario-result cache: content-addressed by the canonical
//! spec hash, disk-backed as append-only JSONL.
//!
//! Keying: [`crate::scenario::ScenarioSpec::cache_key`] — FNV-1a 64 over
//! the canonical serialization — indexes the store, and every entry also
//! carries the canonical spec string itself, which [`ResultCache::lookup`]
//! compares on hit: a 64-bit hash collision therefore degrades to a miss
//! (re-evaluation), never to another spec's results. Invalidation *is*
//! the content change: edit any field and the old entry is simply never
//! consulted again. The store never re-validates entries against the
//! evaluator, so after changing evaluator *code* the cache directory must
//! be deleted (or the run made with `--no-cache`); see README
//! "Result cache".
//!
//! On-disk format (`<dir>/results.jsonl`, schema
//! `cxlmem-result-cache-v1`): one line per entry, `{"schema": …,
//! "key": "<16-hex>", "scenario": "<name>", "spec": "<canonical JSON>",
//! "result": {…}}`, where `result` is the exact result document
//! `scenario run` would emit. Lines are only ever appended; unparseable
//! or foreign lines (a truncated tail write, an older schema) are
//! skipped on load, so a damaged cache degrades to re-evaluation rather
//! than an error. Within one store the first line for a key wins.
//!
//! Concurrency: the store is the rendezvous point for `--shard`ed fleet
//! processes, so all disk access is serialized under an advisory
//! exclusive lock on `<dir>/lock` ([`crate::util::lock::FileLock`] —
//! `flock(2)` on Unix). [`ResultCache::flush`] appends one line per
//! `write` call under the lock and re-reads the store's keys first, so
//! two shards that evaluated the same spec never tear a line *and* never
//! duplicate one; [`ResultCache::reload`] picks up entries other
//! processes flushed since open (first-insert-wins, so nothing a lookup
//! already returned ever changes). A lock that cannot be taken degrades
//! to the old unlocked behavior with a warning — the cache must never
//! block a run.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::batch::ScenarioResult;
use crate::util::json::Json;
use crate::util::lock::FileLock;
use crate::util::metrics;

/// Registry handles for the result-cache counters (`scenario.cache.*`
/// in `cxlmem stats` snapshots). Per-instance `hits`/`misses` fields
/// stay the CLI/test probes; these aggregate across every handle in the
/// process.
struct CacheMetrics {
    hits: &'static metrics::Counter,
    misses: &'static metrics::Counter,
    reloads: &'static metrics::Counter,
    flush_appends: &'static metrics::Counter,
    flush_lock_wait_ns: &'static metrics::Histogram,
}

fn cache_metrics() -> &'static CacheMetrics {
    static M: std::sync::OnceLock<CacheMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| CacheMetrics {
        hits: metrics::counter("scenario.cache.hits"),
        misses: metrics::counter("scenario.cache.misses"),
        reloads: metrics::counter("scenario.cache.reloads"),
        flush_appends: metrics::counter("scenario.cache.flush_appends"),
        flush_lock_wait_ns: metrics::histogram("scenario.cache.flush_lock_wait_ns"),
    })
}

/// Cache line schema identifier.
pub const CACHE_SCHEMA: &str = "cxlmem-result-cache-v1";
/// Default cache directory (relative to the working directory).
pub const DEFAULT_DIR: &str = ".cxlmem-cache";
/// Store file name inside the cache directory.
pub const STORE_FILE: &str = "results.jsonl";
/// Advisory lock file name inside the cache directory.
pub const LOCK_FILE: &str = "lock";

/// One stored result: the canonical spec it was computed from (verified
/// on lookup) and the result document.
#[derive(Clone, Debug)]
struct Entry {
    spec: String,
    doc: Json,
}

/// A loaded cache: in-memory index over the JSONL store, with pending
/// inserts buffered until [`ResultCache::flush`].
#[derive(Debug)]
pub struct ResultCache {
    path: PathBuf,
    entries: BTreeMap<String, Entry>,
    /// Keys inserted this session, not yet appended to disk (the entry
    /// bodies live in `entries`): `(key, scenario name)`.
    pending: Vec<(String, String)>,
    hits: u64,
    misses: u64,
}

/// Parse one store line into `(key, entry)`; `None` for damage or
/// foreign schemas (the caller skips those).
fn parse_line(line: &str) -> Option<(String, Entry)> {
    if line.trim().is_empty() {
        return None;
    }
    let doc = Json::parse(line).ok()?;
    if doc.get("schema").and_then(Json::as_str) != Some(CACHE_SCHEMA) {
        return None;
    }
    let key = doc.get("key").and_then(Json::as_str)?;
    let spec = doc.get("spec").and_then(Json::as_str)?;
    let result = doc.get("result")?;
    Some((
        key.to_string(),
        Entry {
            spec: spec.to_string(),
            doc: result.clone(),
        },
    ))
}

/// Read the store at `path` into `entries`, keeping whatever is already
/// there (first-insert-wins — both across duplicate lines in the file
/// and against entries the caller holds in memory). An unreadable file
/// degrades to "nothing new" with a warning: the cache must never block
/// a run. Returns the number of keys added.
fn load_into(path: &Path, entries: &mut BTreeMap<String, Entry>) -> usize {
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!(
                "warning: unreadable scenario result cache {} ({e}); treating as empty",
                path.display()
            );
            return 0;
        }
    };
    let mut added = 0;
    for line in text.lines() {
        if let Some((key, entry)) = parse_line(line) {
            if !entries.contains_key(&key) {
                entries.insert(key, entry);
                added += 1;
            }
        }
    }
    added
}

/// Take the store lock, degrading to unlocked access with a warning if
/// the lock file cannot be created/locked (read-only store, exotic FS).
fn lock_store(path: &Path) -> Option<FileLock> {
    let lock_path = path.parent()?.join(LOCK_FILE);
    match FileLock::acquire(&lock_path) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!(
                "warning: cache lock {} unavailable ({e}); proceeding unlocked",
                lock_path.display()
            );
            None
        }
    }
}

impl ResultCache {
    /// Open (or lazily create) the cache under `dir`. A missing
    /// directory/file is an empty cache, and so is an *unreadable* one
    /// (permissions, invalid UTF-8 from a torn write): the cache must
    /// degrade to re-evaluation, never block a run. Nothing is written
    /// until the first [`ResultCache::flush`] with pending entries.
    pub fn open(dir: &Path) -> Result<Self> {
        let path = dir.join(STORE_FILE);
        let mut entries = BTreeMap::new();
        if path.exists() {
            let _lock = lock_store(&path);
            load_into(&path, &mut entries);
        }
        Ok(Self {
            path,
            entries,
            pending: Vec::new(),
            hits: 0,
            misses: 0,
        })
    }

    /// Open the default store, [`DEFAULT_DIR`].
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new(DEFAULT_DIR))
    }

    /// Pick up entries other processes appended since open (or the last
    /// reload). Existing in-memory entries — loaded *or* inserted — are
    /// kept, so nothing a lookup already returned ever changes; pending
    /// inserts stay pending. Returns the number of new keys.
    pub fn reload(&mut self) -> Result<usize> {
        if !self.path.exists() {
            return Ok(0);
        }
        cache_metrics().reloads.inc();
        let _lock = lock_store(&self.path);
        Ok(load_into(&self.path, &mut self.entries))
    }

    /// Look a key up, verifying the entry was computed from the same
    /// canonical spec — a hash collision is served as a miss, never as
    /// another spec's results. Counts the hit/miss (the probe the cache
    /// tests use to prove a warm batch never evaluated anything).
    pub fn lookup(&mut self, key: &str, canonical_spec: &str) -> Option<&Json> {
        match self.entries.get(key) {
            Some(e) if e.spec == canonical_spec => {
                self.hits += 1;
                cache_metrics().hits.inc();
                Some(&e.doc)
            }
            _ => {
                self.misses += 1;
                cache_metrics().misses.inc();
                None
            }
        }
    }

    /// Record a freshly evaluated result under `key`. First insert wins
    /// (a colliding later spec stays uncached rather than overwriting);
    /// the entry reaches disk on the next [`ResultCache::flush`].
    pub fn insert(&mut self, key: String, canonical_spec: String, result: &ScenarioResult) {
        if self.entries.contains_key(&key) {
            return;
        }
        let entry = Entry {
            spec: canonical_spec,
            doc: result.doc.clone(),
        };
        self.entries.insert(key.clone(), entry);
        self.pending.push((key, result.name.clone()));
    }

    /// Append pending entries to the store, creating the directory/file
    /// on first use. The whole append runs under the store's advisory
    /// lock: the current on-disk keys are re-read first (a concurrent
    /// shard may have flushed the same spec already — those lines are
    /// not appended again), then each surviving entry is written as one
    /// whole line per `write` call, so a concurrent reader never sees a
    /// torn line and a crash mid-flush loses at most the unwritten tail.
    /// On failure, pending entries are retained for a retry.
    pub fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            fs::create_dir_all(dir)
                .with_context(|| format!("creating cache dir {}", dir.display()))?;
        }
        let m = cache_metrics();
        // The lock is the shard rendezvous point: time waiting for it is
        // the contention signal the serve-fleet roadmap item watches.
        let _lock = m.flush_lock_wait_ns.time(|| lock_store(&self.path));
        let mut on_disk = BTreeMap::new();
        if self.path.exists() {
            load_into(&self.path, &mut on_disk);
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening cache store {}", self.path.display()))?;
        for (key, name) in &self.pending {
            if on_disk.contains_key(key) {
                continue;
            }
            let entry = match self.entries.get(key) {
                Some(e) => e,
                None => continue,
            };
            let line = Json::obj(vec![
                ("schema", CACHE_SCHEMA.into()),
                ("key", key.as_str().into()),
                ("scenario", name.as_str().into()),
                ("spec", entry.spec.as_str().into()),
                ("result", entry.doc.clone()),
            ]);
            let mut text = line.to_string();
            text.push('\n');
            f.write_all(text.as_bytes())
                .with_context(|| format!("appending to cache store {}", self.path.display()))?;
            m.flush_appends.inc();
        }
        self.pending.clear();
        Ok(())
    }

    /// Lookups served from the cache since open.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that fell through to evaluation since open.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Number of distinct keys currently held (loaded + inserted).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Path of the backing store file.
    pub fn store_path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-cache-{tag}-{}", std::process::id()))
    }

    fn result(name: &str, v: u64) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            experiment: None,
            doc: Json::obj(vec![("scenario", name.into()), ("v", v.into())]),
        }
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        assert!(c.is_empty());
        assert!(c.lookup("00ab", "spec-a").is_none());
        c.insert("00ab".into(), "spec-a".into(), &result("one", 1));
        c.insert("00cd".into(), "spec-b".into(), &result("two", 2));
        c.flush().unwrap();
        // A fresh open sees both entries; hit/miss counters start clean.
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        let v = c2.lookup("00ab", "spec-a").unwrap().get("v").unwrap().as_u64();
        assert_eq!(v, Some(1));
        assert!(c2.lookup("zz", "spec-a").is_none());
        assert_eq!((c2.hits(), c2.misses()), (1, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn colliding_key_with_different_spec_misses() {
        // A 64-bit key collision must degrade to a miss (re-evaluation),
        // never serve another spec's results.
        let dir = tmp_dir("collision");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        assert!(c.lookup("k", "spec-b").is_none());
        assert_eq!((c.hits(), c.misses()), (0, 1));
        assert!(c.lookup("k", "spec-a").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn first_insert_wins_and_reinsert_is_noop() {
        let dir = tmp_dir("dup");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        c.insert("k".into(), "spec-b".into(), &result("b", 2));
        c.flush().unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        let doc = c2.lookup("k", "spec-a").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn damaged_lines_are_skipped_not_fatal() {
        let dir = tmp_dir("damaged");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("good".into(), "spec-g".into(), &result("ok", 7));
            c.flush().unwrap();
        }
        // A truncated tail write, a foreign-schema line, and a line of
        // our schema missing the 'spec' field (older format).
        let path = dir.join(STORE_FILE);
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("{\"schema\": \"other-v9\", \"key\": \"x\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"y\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"trunc");
        fs::write(&path, text).unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert!(c.lookup("good", "spec-g").is_some());
        assert!(c.lookup("x", "any").is_none());
        assert!(c.lookup("y", "any").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_without_pending_creates_nothing() {
        let dir = tmp_dir("empty");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.flush().unwrap();
        assert!(!dir.exists(), "an untouched cache must not litter the disk");
    }

    /// Two handles on one store, flushing interleaved entries: neither
    /// flush corrupts the other's lines, `reload()` surfaces the sibling's
    /// entries without touching ones already held, and a fresh open sees
    /// the union.
    #[test]
    fn interleaved_handles_share_the_store_via_reload() {
        let dir = tmp_dir("interleave");
        let _ = fs::remove_dir_all(&dir);
        let mut c1 = ResultCache::open(&dir).unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        c1.insert("ka".into(), "spec-a".into(), &result("a", 1));
        c1.flush().unwrap();
        c2.insert("kb".into(), "spec-b".into(), &result("b", 2));
        c2.flush().unwrap();

        // c1 has never seen kb; reload picks it up, and only it.
        assert!(c1.lookup("kb", "spec-b").is_none());
        assert_eq!(c1.reload().unwrap(), 1);
        assert_eq!(c1.len(), 2);
        let doc = c1.lookup("kb", "spec-b").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(2));
        // Nothing already held changed (first-insert-wins).
        let held = c1.lookup("ka", "spec-a").unwrap();
        assert_eq!(held.get("v").unwrap().as_u64(), Some(1));
        // A second reload finds nothing new.
        assert_eq!(c1.reload().unwrap(), 0);

        let c3 = ResultCache::open(&dir).unwrap();
        assert_eq!(c3.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Two handles that each evaluated the *same* spec (a shard overlap):
    /// the second flush must not append a duplicate line — the store ends
    /// up with one line for the key, and its content is the first
    /// flusher's (first-insert-wins at the store level too).
    #[test]
    fn overlapping_flushes_do_not_duplicate_lines() {
        let dir = tmp_dir("overlap");
        let _ = fs::remove_dir_all(&dir);
        let mut c1 = ResultCache::open(&dir).unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        c1.insert("k".into(), "spec".into(), &result("first", 1));
        c2.insert("k".into(), "spec".into(), &result("second", 2));
        c1.flush().unwrap();
        c2.flush().unwrap();

        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), 1, "duplicate key was re-appended");
        let mut c3 = ResultCache::open(&dir).unwrap();
        assert_eq!(c3.len(), 1);
        let doc = c3.lookup("k", "spec").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    /// Many concurrent writers (threads here; the lock excludes separate
    /// processes the same way — each handle locks its own descriptor):
    /// every entry survives, every line parses, no lookup is corrupted.
    #[test]
    fn concurrent_writers_never_tear_lines() {
        let dir = tmp_dir("concurrent");
        let _ = fs::remove_dir_all(&dir);
        const WRITERS: usize = 4;
        const PER_WRITER: usize = 8;
        std::thread::scope(|s| {
            for w in 0..WRITERS {
                let dir = dir.clone();
                s.spawn(move || {
                    let mut c = ResultCache::open(&dir).unwrap();
                    for i in 0..PER_WRITER {
                        // A long filler pushes each line well past any
                        // small-write atomicity threshold.
                        let name = format!("w{w}-{i}-{}", "x".repeat(512));
                        c.insert(
                            format!("k-{w}-{i}"),
                            format!("spec-{w}-{i}"),
                            &result(&name, (w * PER_WRITER + i) as u64),
                        );
                        c.flush().unwrap();
                    }
                });
            }
        });
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), WRITERS * PER_WRITER, "entries were lost or torn");
        for w in 0..WRITERS {
            for i in 0..PER_WRITER {
                let doc = c
                    .lookup(&format!("k-{w}-{i}"), &format!("spec-{w}-{i}"))
                    .unwrap_or_else(|| panic!("k-{w}-{i} missing"));
                assert_eq!(doc.get("v").unwrap().as_u64(), Some((w * PER_WRITER + i) as u64));
            }
        }
        // Every stored line parses back as a well-formed entry.
        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), WRITERS * PER_WRITER);
        assert!(text.lines().all(|l| parse_line(l).is_some()));
        let _ = fs::remove_dir_all(&dir);
    }
}
