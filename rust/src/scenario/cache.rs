//! Persistent scenario-result cache: content-addressed by the canonical
//! spec hash, disk-backed as append-only JSONL — now a thin facade over
//! the layered store in [`crate::scenario::store`].
//!
//! Keying: [`crate::scenario::ScenarioSpec::cache_key`] — FNV-1a 64 over
//! the canonical serialization — indexes the store, and every entry also
//! carries the canonical spec string itself, which [`ResultCache::lookup`]
//! compares on hit: a 64-bit hash collision therefore degrades to a miss
//! (re-evaluation), never to another spec's results. Invalidation *is*
//! the content change: edit any field and the old entry is simply never
//! consulted again. The store never re-validates entries against the
//! evaluator, so after changing evaluator *code* the cache directory must
//! be deleted (or the run made with `--no-cache`); see README
//! "Result cache".
//!
//! On-disk format (`<dir>/results.jsonl`, schema
//! `cxlmem-result-cache-v1`): one line per entry, `{"schema": …,
//! "key": "<16-hex>", "scenario": "<name>", "spec": "<canonical JSON>",
//! "result": {…}}`, where `result` is the exact result document
//! `scenario run` would emit. Unparseable or foreign lines (a truncated
//! tail write, an older schema) never poison a load — damage is
//! quarantined and self-healed exactly as before the layering (see the
//! [`store`] docs). Within one store the first line for a key wins.
//!
//! What changed under the facade: lookups are **lock-free** (one atomic
//! snapshot load and a cascade walk — no `flock(2)`, no disk access),
//! writers contend only on an in-process head shard, and
//! [`ResultCache::flush`] *seals* pending entries into a uniquely-named
//! immutable `seg-*.jsonl` segment instead of appending to the shared
//! base under the store lock. The advisory `<dir>/lock` survives, scoped
//! to the two true cross-process rendezvous: **compaction** (folding
//! segments back into `results.jsonl`, temp-file + rename, crash-safe)
//! and **adoption** ([`ResultCache::reload`], now segment discovery).
//! By default every flush compacts inline (`compact_every == 1`), so a
//! single-process run leaves exactly the flat store the flock-era cache
//! wrote — byte-compatible, first-insert-wins; `--compact-every N`
//! amortizes compaction over N flushes on a background thread, and
//! `--compact-every 0` defers it entirely to `cxlmem scenario compact`.
//!
//! [`ResultCache::flush`] retries the whole seal-and-compact a bounded
//! number of times on IO errors (idempotent: sealed entries leave
//! pending, failed seals restore it) — the `cache.flush.io` fault point
//! lets the chaos harness rehearse this deterministically, and
//! `store.seal.io` / `store.compact.io` target the layered stages.

use std::fs;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::batch::ScenarioResult;
use super::store::{self, CompactStats, Entry, LayeredStore};
use crate::util::json::Json;
use crate::util::metrics;

pub use super::store::{CACHE_SCHEMA, DEFAULT_DIR, LOCK_FILE, QUARANTINE_FILE, STORE_FILE};
pub(crate) use super::store::layer::parse_line;

/// Registry handles for the result-cache counters (`scenario.cache.*`
/// in `cxlmem stats` snapshots). Per-instance `hits`/`misses` fields
/// stay the CLI/test probes; these aggregate across every handle in the
/// process.
struct CacheMetrics {
    hits: &'static metrics::Counter,
    misses: &'static metrics::Counter,
    reloads: &'static metrics::Counter,
    flush_retries: &'static metrics::Counter,
}

fn cache_metrics() -> &'static CacheMetrics {
    static M: std::sync::OnceLock<CacheMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| CacheMetrics {
        hits: metrics::counter("scenario.cache.hits"),
        misses: metrics::counter("scenario.cache.misses"),
        reloads: metrics::counter("scenario.cache.reloads"),
        flush_retries: metrics::counter("scenario.cache.flush_retries"),
    })
}

/// Whole-flush attempts before an IO error is surfaced to the caller.
const FLUSH_ATTEMPTS: u32 = 3;

/// State shared between a [`ResultCache`], its [`StoreHandle`]s, and the
/// background compactor thread.
struct Shared {
    store: LayeredStore,
    /// Per-facade probe counters (the layered store itself is blind to
    /// spec verification, which is where hit/miss is decided).
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Shared {
    /// Spec-verified probe shared by facade and handles: counts the
    /// hit/miss on both the per-facade atomics and the process-wide
    /// registry, together, so the two stay in lock-step.
    fn probe(&self, key: &str, canonical_spec: &str) -> Option<Arc<Entry>> {
        match self.store.get(key) {
            Some(e) if e.spec == canonical_spec => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                cache_metrics().hits.inc();
                Some(e)
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                cache_metrics().misses.inc();
                None
            }
        }
    }
}

/// A loaded cache handle (see the module docs). Owns the flush/compact
/// policy; cheap read-side clones come from [`ResultCache::handle`].
pub struct ResultCache {
    shared: Arc<Shared>,
    /// Seals per compaction: 1 = compact inline after every flush (the
    /// flock-era disk layout, the default), 0 = never (segments
    /// accumulate for `scenario compact`), N > 1 = background-compact
    /// every Nth flush.
    compact_every: u64,
    seals_since_compact: u64,
    compactor: Option<std::thread::JoinHandle<()>>,
    /// Keeps the most recent hit alive so `lookup` can hand out a plain
    /// `&Json` borrow from the lock-free store.
    last_hit: Option<Arc<Entry>>,
}

impl std::fmt::Debug for ResultCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultCache")
            .field("path", &self.shared.store.store_path())
            .field("len", &self.shared.store.len())
            .field("compact_every", &self.compact_every)
            .finish()
    }
}

/// A cloneable, shareable read/write handle onto one cache session:
/// lock-free lookups and head inserts from any thread, sharing the
/// facade's hit/miss accounting. Sealing and compaction stay with the
/// owning [`ResultCache`] (or an explicit [`StoreHandle::seal`]).
#[derive(Clone)]
pub struct StoreHandle {
    shared: Arc<Shared>,
}

impl StoreHandle {
    /// Spec-verified lookup (see [`ResultCache::lookup`]); returns an
    /// owned document so the handle can be probed concurrently.
    pub fn lookup(&self, key: &str, canonical_spec: &str) -> Option<Json> {
        self.shared.probe(key, canonical_spec).map(|e| e.doc.clone())
    }

    /// First-insert-wins record (see [`ResultCache::insert`]).
    pub fn insert(&self, key: &str, canonical_spec: String, result: &ScenarioResult) {
        self.shared
            .store
            .insert(key, &result.name, canonical_spec, result.doc.clone());
    }

    /// Seal pending inserts into a segment (no compaction — the owning
    /// facade's policy decides when to fold). Returns lines sealed.
    pub fn seal(&self) -> Result<usize> {
        self.shared.store.seal()
    }
}

impl ResultCache {
    /// Open (or lazily create) the cache under `dir`. A missing
    /// directory/file is an empty cache, and so is an *unreadable* one
    /// (permissions, invalid UTF-8 from a torn write): the cache must
    /// degrade to re-evaluation, never block a run. Nothing is written
    /// until the first [`ResultCache::flush`] with pending entries.
    pub fn open(dir: &Path) -> Result<Self> {
        Ok(Self {
            shared: Arc::new(Shared {
                store: LayeredStore::open(dir)?,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
            }),
            compact_every: 1,
            seals_since_compact: 0,
            compactor: None,
            last_hit: None,
        })
    }

    /// Open the default store, [`DEFAULT_DIR`].
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new(DEFAULT_DIR))
    }

    /// Set the seals-per-compaction policy (the `--compact-every` flag);
    /// see the field docs on `compact_every`.
    pub fn set_compact_every(&mut self, n: u64) {
        self.compact_every = n;
    }

    /// A cloneable lock-free read/insert handle sharing this session.
    pub fn handle(&self) -> StoreHandle {
        StoreHandle {
            shared: Arc::clone(&self.shared),
        }
    }

    /// Pick up entries other processes published since open (or the last
    /// reload) — segments they sealed and base lines they compacted.
    /// Existing in-memory entries — loaded *or* inserted — are kept, so
    /// nothing a lookup already returned ever changes; pending inserts
    /// stay pending. Returns the number of new keys.
    pub fn reload(&mut self) -> Result<usize> {
        if !self.shared.store.has_disk() {
            return Ok(0);
        }
        cache_metrics().reloads.inc();
        self.shared.store.adopt()
    }

    /// Look a key up, verifying the entry was computed from the same
    /// canonical spec — a hash collision is served as a miss, never as
    /// another spec's results. Counts the hit/miss (the probe the cache
    /// tests use to prove a warm batch never evaluated anything).
    pub fn lookup(&mut self, key: &str, canonical_spec: &str) -> Option<&Json> {
        self.last_hit = self.shared.probe(key, canonical_spec);
        self.last_hit.as_ref().map(|e| &e.doc)
    }

    /// Record a freshly evaluated result under `key`. First insert wins
    /// (a colliding later spec stays uncached rather than overwriting);
    /// the entry reaches disk on the next [`ResultCache::flush`].
    pub fn insert(&mut self, key: String, canonical_spec: String, result: &ScenarioResult) {
        self.shared
            .store
            .insert(&key, &result.name, canonical_spec, result.doc.clone());
    }

    /// Persist pending entries: seal them into an immutable segment
    /// (lock-free — unique file name, temp + rename), then fold per the
    /// `compact_every` policy. IO errors retry the whole attempt up to
    /// [`FLUSH_ATTEMPTS`] times — idempotent, because a failed seal
    /// restores its batch to pending and a sealed batch leaves it. Only
    /// after the last attempt is the error surfaced, with pending
    /// entries retained so a later flush can still try.
    pub fn flush(&mut self) -> Result<()> {
        if !self.shared.store.has_pending() {
            return Ok(());
        }
        fs::create_dir_all(self.dir())
            .with_context(|| format!("creating cache dir {}", self.dir().display()))?;
        let mut attempt = 0;
        loop {
            attempt += 1;
            match self.flush_once() {
                Ok(()) => return Ok(()),
                Err(e) if attempt < FLUSH_ATTEMPTS => {
                    cache_metrics().flush_retries.inc();
                    eprintln!(
                        "warning: cache flush attempt {attempt}/{FLUSH_ATTEMPTS} failed ({e}); \
                         retrying"
                    );
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One flush attempt (see [`ResultCache::flush`]).
    fn flush_once(&mut self) -> Result<()> {
        // Chaos hook: an `io` rule here fails the attempt before
        // anything is sealed, exercising the retry loop end to end.
        crate::util::fault::io_point("cache.flush.io", &self.path().to_string_lossy())
            .with_context(|| format!("writing cache store {}", self.path().display()))?;
        if self.shared.store.seal()? > 0 {
            self.seals_since_compact += 1;
        }
        match self.compact_every {
            0 => {}
            1 => {
                // Inline: every flush leaves the flat flock-era layout.
                self.shared.store.compact(true)?;
                self.seals_since_compact = 0;
            }
            n => {
                if self.seals_since_compact >= n {
                    self.spawn_compactor();
                    self.seals_since_compact = 0;
                }
            }
        }
        Ok(())
    }

    /// Fold all sealed segments into the base store now, blocking on the
    /// store lock (the `scenario compact` verb, and the final fold of a
    /// `--compact-every N` run).
    pub fn compact(&mut self) -> Result<CompactStats> {
        self.join_compactor();
        self.seals_since_compact = 0;
        self.shared.store.compact(true)
    }

    /// Hand the fold to a background thread (non-blocking lock attempt:
    /// if a sibling process is compacting, theirs covers our segments).
    /// At most one in flight; errors degrade to a warning — compaction
    /// is maintenance, never correctness.
    fn spawn_compactor(&mut self) {
        self.join_compactor();
        let shared = Arc::clone(&self.shared);
        self.compactor = Some(std::thread::spawn(move || {
            if let Err(e) = shared.store.compact(false) {
                eprintln!("warning: background cache compaction failed ({e}); segments remain");
            }
        }));
    }

    fn join_compactor(&mut self) {
        if let Some(h) = self.compactor.take() {
            let _ = h.join();
        }
    }

    /// Lookups served from the cache since open (all handles included).
    pub fn hits(&self) -> u64 {
        self.shared.hits.load(Ordering::Relaxed)
    }

    /// Lookups that fell through to evaluation since open.
    pub fn misses(&self) -> u64 {
        self.shared.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct keys currently held (loaded + inserted).
    pub fn len(&self) -> usize {
        self.shared.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shared.store.is_empty()
    }

    /// Path of the backing base store file.
    pub fn store_path(&self) -> &Path {
        self.shared.store.store_path()
    }

    fn path(&self) -> &Path {
        self.shared.store.store_path()
    }

    fn dir(&self) -> &Path {
        self.shared.store.dir()
    }
}

impl Drop for ResultCache {
    fn drop(&mut self) {
        self.join_compactor();
    }
}

/// Read-only merged view of the store under `dir` (base + sealed
/// segments, first-line-wins) for interchange-format consumers; see
/// [`store::merged_store_text`].
pub fn merged_store_text(dir: &Path) -> Result<String> {
    store::merged_store_text(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-cache-{tag}-{}", std::process::id()))
    }

    fn result(name: &str, v: u64) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            experiment: None,
            doc: Json::obj(vec![("scenario", name.into()), ("v", v.into())]),
        }
    }

    #[test]
    fn roundtrips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        assert!(c.is_empty());
        assert!(c.lookup("00ab", "spec-a").is_none());
        c.insert("00ab".into(), "spec-a".into(), &result("one", 1));
        c.insert("00cd".into(), "spec-b".into(), &result("two", 2));
        c.flush().unwrap();
        // A fresh open sees both entries; hit/miss counters start clean.
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        let v = c2.lookup("00ab", "spec-a").unwrap().get("v").unwrap().as_u64();
        assert_eq!(v, Some(1));
        assert!(c2.lookup("zz", "spec-a").is_none());
        assert_eq!((c2.hits(), c2.misses()), (1, 1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn colliding_key_with_different_spec_misses() {
        // A 64-bit key collision must degrade to a miss (re-evaluation),
        // never serve another spec's results.
        let dir = tmp_dir("collision");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        assert!(c.lookup("k", "spec-b").is_none());
        assert_eq!((c.hits(), c.misses()), (0, 1));
        assert!(c.lookup("k", "spec-a").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn first_insert_wins_and_reinsert_is_noop() {
        let dir = tmp_dir("dup");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec-a".into(), &result("a", 1));
        c.insert("k".into(), "spec-b".into(), &result("b", 2));
        c.flush().unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        let doc = c2.lookup("k", "spec-a").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn damaged_lines_are_skipped_not_fatal() {
        let dir = tmp_dir("damaged");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("good".into(), "spec-g".into(), &result("ok", 7));
            c.flush().unwrap();
        }
        // A truncated tail write, a foreign-schema line, and a line of
        // our schema missing the 'spec' field (older format).
        let path = dir.join(STORE_FILE);
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("{\"schema\": \"other-v9\", \"key\": \"x\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"y\", \"result\": {}}\n");
        text.push_str("{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"trunc");
        fs::write(&path, text).unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), 1);
        assert!(c.lookup("good", "spec-g").is_some());
        assert!(c.lookup("x", "any").is_none());
        assert!(c.lookup("y", "any").is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_without_pending_creates_nothing() {
        let dir = tmp_dir("empty");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.flush().unwrap();
        assert!(!dir.exists(), "an untouched cache must not litter the disk");
    }

    /// Two handles on one store, flushing interleaved entries: neither
    /// flush corrupts the other's lines, `reload()` surfaces the sibling's
    /// entries without touching ones already held, and a fresh open sees
    /// the union.
    #[test]
    fn interleaved_handles_share_the_store_via_reload() {
        let dir = tmp_dir("interleave");
        let _ = fs::remove_dir_all(&dir);
        let mut c1 = ResultCache::open(&dir).unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        c1.insert("ka".into(), "spec-a".into(), &result("a", 1));
        c1.flush().unwrap();
        c2.insert("kb".into(), "spec-b".into(), &result("b", 2));
        c2.flush().unwrap();

        // c1 has never seen kb; reload picks it up, and only it.
        assert!(c1.lookup("kb", "spec-b").is_none());
        assert_eq!(c1.reload().unwrap(), 1);
        assert_eq!(c1.len(), 2);
        let doc = c1.lookup("kb", "spec-b").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(2));
        // Nothing already held changed (first-insert-wins).
        let held = c1.lookup("ka", "spec-a").unwrap();
        assert_eq!(held.get("v").unwrap().as_u64(), Some(1));
        // A second reload finds nothing new.
        assert_eq!(c1.reload().unwrap(), 0);

        let c3 = ResultCache::open(&dir).unwrap();
        assert_eq!(c3.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Two handles that each evaluated the *same* spec (a shard overlap):
    /// the second flush must not append a duplicate line — the store ends
    /// up with one line for the key, and its content is the first
    /// flusher's (first-insert-wins at the store level too).
    #[test]
    fn overlapping_flushes_do_not_duplicate_lines() {
        let dir = tmp_dir("overlap");
        let _ = fs::remove_dir_all(&dir);
        let mut c1 = ResultCache::open(&dir).unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        c1.insert("k".into(), "spec".into(), &result("first", 1));
        c2.insert("k".into(), "spec".into(), &result("second", 2));
        c1.flush().unwrap();
        c2.flush().unwrap();

        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), 1, "duplicate key was re-appended");
        let mut c3 = ResultCache::open(&dir).unwrap();
        assert_eq!(c3.len(), 1);
        let doc = c3.lookup("k", "spec").unwrap();
        assert_eq!(doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }

    /// A torn mid-line tail (crashed writer) is quarantined on load and
    /// the store compacts back to **byte-identical** with a store that
    /// never saw the damage — and stays stable across further reopens.
    #[test]
    fn torn_tail_quarantines_and_compacts_byte_identical() {
        let dir = tmp_dir("torn-tail");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("k1".into(), "spec-1".into(), &result("one", 1));
            c.insert("k2".into(), "spec-2".into(), &result("two", 2));
            c.flush().unwrap();
        }
        let path = dir.join(STORE_FILE);
        let pristine = fs::read_to_string(&path).unwrap();

        let torn = "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"t";
        fs::write(&path, format!("{pristine}{torn}")).unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.lookup("k1", "spec-1").is_some());
        assert_eq!(
            fs::read_to_string(&path).unwrap(),
            pristine,
            "healed store must be byte-identical to a never-damaged one"
        );
        let quarantined = fs::read_to_string(dir.join(QUARANTINE_FILE)).unwrap();
        assert_eq!(quarantined, format!("{torn}\n"), "fragment kept verbatim");

        // Reopening a healed store is a no-op: nothing new quarantined,
        // nothing rewritten.
        let c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(fs::read_to_string(&path).unwrap(), pristine);
        assert_eq!(fs::read_to_string(dir.join(QUARANTINE_FILE)).unwrap(), quarantined);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Garbage interleaved *between* valid lines: the valid lines (ours
    /// and foreign-schema alike) survive in order, the garbage moves to
    /// the sidecar in order.
    #[test]
    fn interleaved_garbage_is_quarantined_in_order() {
        let dir = tmp_dir("interleaved-garbage");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut c = ResultCache::open(&dir).unwrap();
            c.insert("k1".into(), "spec-1".into(), &result("one", 1));
            c.insert("k2".into(), "spec-2".into(), &result("two", 2));
            c.flush().unwrap();
        }
        let path = dir.join(STORE_FILE);
        let pristine = fs::read_to_string(&path).unwrap();
        let mut lines = pristine.lines();
        let (line1, line2) = (lines.next().unwrap(), lines.next().unwrap());
        let foreign = "{\"schema\": \"other-v9\", \"key\": \"f\"}";
        let missing = "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"m\"}";
        let damaged_text =
            format!("not json at all\n{line1}\n{missing}\n{foreign}\n\n{line2}garbage tail\n");
        fs::write(&path, &damaged_text).unwrap();

        let before = crate::util::metrics::counter("cache.quarantined_lines").get();
        let mut c = ResultCache::open(&dir).unwrap();
        // line2 was fused with "garbage tail" — unparseable, quarantined;
        // line1 and the foreign line survive.
        assert_eq!(c.len(), 1);
        assert!(c.lookup("k1", "spec-1").is_some());
        assert_eq!(
            fs::read_to_string(&path).unwrap(),
            format!("{line1}\n{foreign}\n")
        );
        let quarantined = fs::read_to_string(dir.join(QUARANTINE_FILE)).unwrap();
        assert_eq!(
            quarantined,
            format!("not json at all\n{missing}\n{line2}garbage tail\n"),
            "damaged lines keep file order, verbatim"
        );
        if crate::util::metrics::global().enabled() {
            assert!(
                crate::util::metrics::counter("cache.quarantined_lines").get() >= before + 3,
                "quarantined lines must be counted"
            );
        }
        let _ = fs::remove_dir_all(&dir);
    }

    /// A zero-byte store (created then never written, or truncated to
    /// nothing) is an empty cache: no quarantine, no rewrite, and the
    /// next flush appends normally.
    #[test]
    fn zero_byte_store_is_an_empty_cache() {
        let dir = tmp_dir("zero-byte");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join(STORE_FILE);
        fs::write(&path, "").unwrap();
        let mut c = ResultCache::open(&dir).unwrap();
        assert!(c.is_empty());
        assert!(!dir.join(QUARANTINE_FILE).exists(), "nothing to quarantine");
        assert_eq!(fs::read_to_string(&path).unwrap(), "");
        c.insert("k".into(), "spec".into(), &result("a", 1));
        c.flush().unwrap();
        let c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    /// Transient IO failures during flush burn retries, not results: an
    /// injected fault that fires twice is absorbed by the three-attempt
    /// loop and the store ends up complete.
    #[test]
    fn flush_retries_through_transient_io_faults() {
        use crate::util::fault;

        let dir = tmp_dir("flushfault");
        let _ = fs::remove_dir_all(&dir);
        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("cache.flush.io/flushfault=io:2").unwrap());
        let mut c = ResultCache::open(&dir).unwrap();
        c.insert("k".into(), "spec".into(), &result("a", 1));
        c.flush().expect("third attempt must succeed");
        assert_eq!(fault::fired("cache.flush.io"), 2);
        fault::clear();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1);
        assert!(c2.lookup("k", "spec").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    /// An append onto a torn (newline-less) tail starts on a fresh line,
    /// so the new entry is never fused into the fragment; the next load
    /// quarantines the fragment and keeps the entry.
    #[test]
    fn flush_onto_torn_tail_never_fuses_lines() {
        let dir = tmp_dir("torn-append");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join(STORE_FILE);
        fs::write(&path, "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"t").unwrap();
        // Open tolerates (and heals) the fragment; then damage it again
        // to simulate a shard crashing *between* our open and flush.
        let mut c = ResultCache::open(&dir).unwrap();
        fs::write(&path, "{\"torn").unwrap();
        c.insert("k".into(), "spec".into(), &result("a", 1));
        c.flush().unwrap();
        let mut c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 1, "appended entry must survive the fragment");
        assert!(c2.lookup("k", "spec").is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    /// Many concurrent writers (threads here; the lock excludes separate
    /// processes the same way — each handle locks its own descriptor):
    /// every entry survives, every line parses, no lookup is corrupted.
    #[test]
    fn concurrent_writers_never_tear_lines() {
        let dir = tmp_dir("concurrent");
        let _ = fs::remove_dir_all(&dir);
        const WRITERS: usize = 4;
        const PER_WRITER: usize = 8;
        std::thread::scope(|s| {
            for w in 0..WRITERS {
                let dir = dir.clone();
                s.spawn(move || {
                    let mut c = ResultCache::open(&dir).unwrap();
                    for i in 0..PER_WRITER {
                        // A long filler pushes each line well past any
                        // small-write atomicity threshold.
                        let name = format!("w{w}-{i}-{}", "x".repeat(512));
                        c.insert(
                            format!("k-{w}-{i}"),
                            format!("spec-{w}-{i}"),
                            &result(&name, (w * PER_WRITER + i) as u64),
                        );
                        c.flush().unwrap();
                    }
                });
            }
        });
        let mut c = ResultCache::open(&dir).unwrap();
        assert_eq!(c.len(), WRITERS * PER_WRITER, "entries were lost or torn");
        for w in 0..WRITERS {
            for i in 0..PER_WRITER {
                let doc = c
                    .lookup(&format!("k-{w}-{i}"), &format!("spec-{w}-{i}"))
                    .unwrap_or_else(|| panic!("k-{w}-{i} missing"));
                assert_eq!(doc.get("v").unwrap().as_u64(), Some((w * PER_WRITER + i) as u64));
            }
        }
        // Every stored line parses back as a well-formed entry.
        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), WRITERS * PER_WRITER);
        assert!(text.lines().all(|l| parse_line(l).is_some()));
        let _ = fs::remove_dir_all(&dir);
    }

    /// Layered-mode behaviors new in this refactor: seal-only flushes
    /// (`compact_every == 0`) leave segments the `scenario compact` verb
    /// folds; handles probe and insert lock-free, sharing counters.
    #[test]
    fn seal_only_flushes_then_explicit_compact() {
        let dir = tmp_dir("seal-only");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.set_compact_every(0);
        c.insert("k1".into(), "spec-1".into(), &result("one", 1));
        c.flush().unwrap();
        c.insert("k2".into(), "spec-2".into(), &result("two", 2));
        c.flush().unwrap();
        assert!(!dir.join(STORE_FILE).exists(), "seal-only must not write the base");

        // Handles share the session: lock-free probe, shared counters.
        let h = c.handle();
        assert!(h.lookup("k1", "spec-1").is_some());
        assert!(h.lookup("k1", "wrong-spec").is_none(), "spec mismatch is a miss");
        assert_eq!((c.hits(), c.misses()), (1, 1));
        h.insert("k3", "spec-3".into(), &result("three", 3));
        assert_eq!(c.len(), 3);

        // A sibling open adopts the segments without any base file…
        let c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2, "k3 is unsealed, invisible to siblings");

        // …and an explicit compact folds everything into one flat base.
        c.flush().unwrap();
        let stats = c.compact().unwrap();
        assert_eq!((stats.segments, stats.keys, stats.rewrote), (3, 3, true));
        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.lines().all(|l| parse_line(l).is_some()));
        let _ = fs::remove_dir_all(&dir);
    }

    /// `compact_every == N`: the background compactor folds after every
    /// Nth sealing flush, and the final state matches inline compaction.
    #[test]
    fn background_compaction_folds_every_nth_flush() {
        let dir = tmp_dir("bg-compact");
        let _ = fs::remove_dir_all(&dir);
        let mut c = ResultCache::open(&dir).unwrap();
        c.set_compact_every(2);
        for i in 0..4u64 {
            c.insert(format!("k{i}"), format!("spec-{i}"), &result("r", i));
            c.flush().unwrap();
        }
        let stats = c.compact().unwrap(); // joins the background fold
        assert_eq!(stats.keys, 4);
        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(super::super::store::layer::list_segments(&dir).is_empty());
        let c2 = ResultCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 4);
        let _ = fs::remove_dir_all(&dir);
    }
}
