//! Scenario evaluation: one spec in, one [`Report`] out.
//!
//! Every workload kind dispatches to the *parameterized* experiment
//! driver it generalizes (`exp::basic/llm/hpc/tiering_exp::*_with`), so a
//! bundled scenario whose parameters equal the paper defaults reproduces
//! the corresponding `cxlmem exp <id>` table byte-for-byte — the golden
//! suite in `rust/tests/scenario.rs` pins exactly that. The free-form
//! `objects` kind evaluates a declared object mix over a placement-policy
//! grid with best-policy selection and an OLI per-object search.

use anyhow::{anyhow, bail, Result};

use super::spec::{FlexgenStyle, ObjectsSpec, ScenarioSpec, WorkloadSpec};
use crate::engine::{self, ObjectTraffic, RunConfig, RunResult};
use crate::exp;
use crate::gpu::Gpu;
use crate::mem::{self, oli, AddressSpace, ObjectSpec as MemObjectSpec, PhysMem, Policy};
use crate::memsim::{MemKind, System};
use crate::report::Report;
use crate::util::table::{f2, f3, Table};
use crate::workloads::npb;
use crate::workloads::tiering_apps;

/// Evaluate one scenario.
pub fn evaluate(spec: &ScenarioSpec) -> Result<Report> {
    let systems: Vec<System> = spec
        .systems
        .iter()
        .map(|s| s.build())
        .collect::<Result<Vec<_>>>()?;
    let sys = systems
        .first()
        .ok_or_else(|| anyhow!("scenario '{}' has no systems", spec.name))?;
    use WorkloadSpec as W;
    // Socket indices are plain data at parse time (the workload is parsed
    // independently of the system list); validate them here, against the
    // system actually being evaluated, so a bad index is a clean error
    // with the scenario name attached instead of a panic deep in a driver.
    if let Some(socket) = workload_socket(&spec.workload) {
        if sys.node_of(socket, MemKind::Ldram).is_none() {
            bail!("socket {socket} out of range for system {}", sys.name);
        }
    }
    Ok(match &spec.workload {
        W::Table1 => exp::basic::table1_with(&systems),
        W::IdleLatency { samples, seed } => exp::basic::fig2_with(&systems, *samples, *seed),
        W::BwScaling { rows } => exp::basic::fig3_with(&systems, rows),
        W::LoadedLatency { threads } => exp::basic::fig4_with(&systems, *threads),
        W::Assign { socket } => exp::basic::assign_with(sys, *socket),
        W::GpuCopy { blocks_log2 } => exp::llm::fig5_with(sys, &Gpu::a10(), blocks_log2),
        W::GpuLatency => exp::llm::fig6_with(sys, &Gpu::a10()),
        W::ZeroTrain => exp::llm::fig8_with(sys, &Gpu::a10()),
        W::ZeroBreakdown => exp::llm::fig9_with(sys, &Gpu::a10()),
        W::Flexgen {
            style,
            models,
            hierarchies,
        } => {
            let models: Vec<_> = models
                .iter()
                .map(|m| {
                    exp::llm::infer_model(m).ok_or_else(|| anyhow!("unknown model '{m}'"))
                })
                .collect::<Result<Vec<_>>>()?;
            let gpu = Gpu::a10();
            match style {
                FlexgenStyle::Fig11 => exp::llm::fig11_with(sys, &gpu, &models, hierarchies),
                FlexgenStyle::Table2 => exp::llm::table2_with(sys, &gpu, &models, hierarchies),
                FlexgenStyle::Fig12 => exp::llm::fig12_with(sys, &gpu, &models, hierarchies),
            }
        }
        W::HpcTable => exp::hpc::table3_with(&npb::all_hpc_workloads()),
        W::HpcPolicies { socket, threads } => {
            exp::hpc::fig13_with(sys, *socket, *threads, &npb::all_hpc_workloads())
        }
        W::HpcScaling {
            workloads,
            threads,
            socket,
        } => {
            let names: Vec<&str> = workloads.iter().map(String::as_str).collect();
            exp::hpc::fig14_with(sys, *socket, &names, threads)
        }
        W::Oli {
            ldram_gb,
            rdram_residue_gb,
            socket,
            threads,
            title,
        } => exp::hpc::fig15_with(sys, *socket, *threads, *ldram_gb, *rdram_residue_gb, title),
        W::TieringApps {
            apps,
            epochs,
            seed,
            threads,
            fast_gb,
            pages,
        } => {
            let mut models: Vec<tiering_apps::AppModel> = apps
                .iter()
                .map(|a| tiering_app(a))
                .collect::<Result<Vec<_>>>()?;
            // Scale studies override every app's working set (a
            // different page count is a different trace key, so scaled
            // cells never collide with 65k-page snapshots in the store).
            if let Some(p) = pages {
                for m in &mut models {
                    m.pages = *p;
                }
            }
            // Trace sharing happens inside fig16_with: it fetches one
            // immutable snapshot per app from the process-global
            // `workloads::trace` store, so every policy×placement cell
            // of this grid — and any sibling fleet member in the same
            // batch with an equal (app, pages, epochs, drift, seed)
            // key — replays one Arc'd snapshot, generated at most once
            // per process.
            exp::tiering_exp::fig16_with(sys, &models, *epochs, *seed, *threads, *fast_gb)
        }
        W::TieringHpc {
            socket,
            threads,
            epochs,
            seed,
        } => exp::tiering_exp::fig17_with(sys, *socket, *threads, *epochs, *seed),
        W::Objects(o) => eval_objects(&spec.name, sys, o)?,
    })
}

/// The socket a single-system workload evaluates on, if it names one.
fn workload_socket(w: &WorkloadSpec) -> Option<usize> {
    use WorkloadSpec as W;
    match w {
        W::Assign { socket }
        | W::HpcPolicies { socket, .. }
        | W::HpcScaling { socket, .. }
        | W::Oli { socket, .. }
        | W::TieringHpc { socket, .. } => Some(*socket),
        W::Objects(o) => Some(o.socket),
        _ => None,
    }
}

/// Tiering-app lookup — the single authority for valid app names; spec
/// validation calls this too, so the two layers cannot drift.
pub fn tiering_app(name: &str) -> Result<tiering_apps::AppModel> {
    Ok(match name {
        "BTree" => tiering_apps::btree(),
        "PageRank" => tiering_apps::pagerank(),
        "Graph500" => tiering_apps::graph500(),
        "Silo" => tiering_apps::silo(),
        other => return Err(anyhow!("unknown tiering app '{other}'")),
    })
}

/// Resolve a named placement policy against a system/socket.
fn named_policy(sys: &System, socket: usize, name: &str) -> Result<Policy> {
    Ok(match name {
        "ldram-preferred" => mem::policy::ldram_preferred(sys, socket),
        "rdram-preferred" => Policy::Preferred(
            sys.node_of(socket, MemKind::Rdram)
                .ok_or_else(|| anyhow!("system {} has no RDRAM node", sys.name))?,
        ),
        "cxl-preferred" => mem::policy::cxl_preferred(sys, socket),
        "interleave-ldram-cxl" => {
            mem::policy::interleave_kinds(sys, socket, &[MemKind::Ldram, MemKind::Cxl])
        }
        "interleave-rdram-cxl" => {
            mem::policy::interleave_kinds(sys, socket, &[MemKind::Rdram, MemKind::Cxl])
        }
        "interleave-all" => mem::policy::interleave_all(sys, socket),
        other => return Err(anyhow!("unknown policy '{other}'")),
    })
}

/// Allocate the declared objects under per-object policies and run one
/// engine iteration (mirrors `HpcWorkload::run_with` for ad-hoc mixes).
fn run_objects(
    sys: &System,
    o: &ObjectsSpec,
    specs: &[MemObjectSpec],
    policy_for: &dyn Fn(usize) -> Policy,
) -> Result<RunResult> {
    let mut phys = PhysMem::of_system(sys);
    let mut asp = AddressSpace::new();
    let mut traffic = Vec::with_capacity(o.objects.len());
    for (i, decl) in o.objects.iter().enumerate() {
        let spec = &specs[i];
        let id = asp.alloc(sys, &mut phys, o.socket, &spec.name, spec.bytes, policy_for(i))?;
        traffic.push(ObjectTraffic {
            name: spec.name.clone(),
            traffic_bytes: spec.bytes as f64 * decl.scans,
            pattern: decl.pattern,
            dep_frac: spec.dep_frac,
            node_weights: asp.object(id).node_weights_in(sys.nodes.len()),
        });
    }
    let cfg = RunConfig {
        socket: o.socket,
        threads: o.threads,
        compute_ns_per_byte: o.compute_ns_per_byte,
    };
    Ok(engine::run(sys, &cfg, &traffic))
}

/// Evaluate an `objects` scenario: the named-policy grid, best-policy
/// selection, and (optionally) a greedy OLI per-object assignment search
/// seeded from the paper's two selection criteria.
fn eval_objects(name: &str, sys: &System, o: &ObjectsSpec) -> Result<Report> {
    let specs: Vec<MemObjectSpec> = o
        .objects
        .iter()
        .map(|d| {
            MemObjectSpec::new(
                &d.name,
                (d.gbytes * 1e9) as u64,
                d.gbytes * d.scans,
                d.dep_frac,
            )
        })
        .collect();

    // The header row doubles as the table's identity: `scenario report`
    // finds policy grids by exact header match (super::report), so both
    // sides share the one constant and cannot drift apart.
    let mut grid = Table::new(
        &format!("Scenario {name} — policy grid (seconds; lower is better)"),
        &super::report::GRID_HEADERS,
    );
    let mut results: Vec<(String, RunResult)> = Vec::new();
    for pname in &o.policies {
        let policy = named_policy(sys, o.socket, pname)?;
        // Per-policy eval-time histograms: `scenario report` merges
        // these across metrics sidecars into its quantile columns.
        let r = crate::util::metrics::histogram(&format!("eval.policy.{pname}.ns"))
            .time(|| run_objects(sys, o, &specs, &|_| policy.clone()))?;
        results.push((pname.clone(), r));
    }

    // OLI per-object search: start from the paper's footprint+intensity
    // selection, then greedily flip each object between interleave and
    // LDRAM-preferred while total time improves. Deterministic: fixed
    // object order, strict improvement threshold.
    let mut oli_assignment: Option<Vec<bool>> = None;
    if o.oli_search {
        let oli_ns = crate::util::metrics::histogram("eval.policy.OLI(search).ns");
        let t0 = std::time::Instant::now();
        let ld = sys
            .node_of(o.socket, MemKind::Ldram)
            .ok_or_else(|| anyhow!("system {} has no LDRAM node", sys.name))?;
        let inter = mem::policy::interleave_kinds(sys, o.socket, &[MemKind::Ldram, MemKind::Cxl]);
        let preferred = Policy::Preferred(ld);
        let eval_sel = |sel: &[bool]| -> Result<RunResult> {
            run_objects(sys, o, &specs, &|i| {
                if sel[i] {
                    inter.clone()
                } else {
                    preferred.clone()
                }
            })
        };
        let mut sel = oli::select_bw_hungry(&specs);
        let mut best = eval_sel(&sel)?;
        // Two greedy passes over the objects are enough for mixes this
        // size; each flip re-runs the whole mix (placements interact
        // through shared node bandwidth).
        for _ in 0..2 {
            let mut improved = false;
            for i in 0..sel.len() {
                sel[i] = !sel[i];
                let candidate = eval_sel(&sel)?;
                if candidate.total_s < best.total_s * (1.0 - 1e-9) {
                    best = candidate;
                    improved = true;
                } else {
                    sel[i] = !sel[i];
                }
            }
            if !improved {
                break;
            }
        }
        // The all-preferred assignment is always in the search space:
        // greedy descent must never report worse than that baseline.
        let all_preferred = vec![false; sel.len()];
        let baseline = eval_sel(&all_preferred)?;
        if baseline.total_s < best.total_s * (1.0 - 1e-9) {
            best = baseline;
            sel = all_preferred;
        }
        results.push((super::report::OLI_ROW.to_string(), best));
        oli_assignment = Some(sel);
        oli_ns.record(t0.elapsed().as_nanos().min(u64::MAX as u128) as u64);
    }

    let best_total = results
        .iter()
        .map(|(_, r)| r.total_s)
        .fold(f64::INFINITY, f64::min);
    for (pname, r) in &results {
        grid.row(vec![
            pname.clone(),
            f3(r.total_s),
            f3(r.stream_s),
            f3(r.dep_s),
            f3(r.compute_s),
            if r.total_s <= best_total { "*" } else { "" }.to_string(),
        ]);
    }

    let mut report = Report::new();
    report.add(grid);
    if let Some(sel) = oli_assignment {
        let mut t = Table::new(
            &format!("Scenario {name} — OLI per-object assignment"),
            &["object", "GB", "pattern", "placement"],
        );
        for (d, &s) in o.objects.iter().zip(&sel) {
            t.row(vec![
                d.name.clone(),
                f2(d.gbytes),
                match d.pattern {
                    crate::memsim::Pattern::Sequential => "sequential",
                    crate::memsim::Pattern::Random => "random",
                }
                .to_string(),
                if s {
                    "interleave ldram+cxl"
                } else {
                    "ldram-preferred"
                }
                .to_string(),
            ]);
        }
        report.add(t);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn spec(text: &str) -> ScenarioSpec {
        ScenarioSpec::parse(&Json::parse(text).unwrap()).unwrap()
    }

    #[test]
    fn every_declared_policy_name_resolves() {
        // spec::POLICY_NAMES is the validation list; named_policy() is
        // the dispatch — this pins them together so they cannot drift.
        for sys in crate::memsim::topology::all_systems() {
            for name in crate::scenario::spec::POLICY_NAMES {
                named_policy(&sys, 0, name).unwrap();
            }
        }
        assert!(named_policy(&crate::memsim::topology::system_a(), 0, "bogus").is_err());
    }

    #[test]
    fn out_of_range_socket_is_a_clean_eval_error() {
        for text in [
            r#"{"name": "s", "workload": {"kind": "objects", "socket": 7,
                "objects": [{"name": "a", "gb": 1}], "oli_search": false}}"#,
            r#"{"name": "s", "workload": {"kind": "assign", "socket": 9}}"#,
            r#"{"name": "s", "workload": {"kind": "oli", "ldram_gb": 16, "socket": 3}}"#,
        ] {
            let s = spec(text); // parses: sockets are data at parse time
            let err = evaluate(&s).unwrap_err().to_string();
            assert!(err.contains("out of range"), "{text}: {err}");
        }
        // In-range sockets on the same kinds still evaluate.
        let ok = spec(
            r#"{"name": "s", "workload": {"kind": "objects", "socket": 1,
                "objects": [{"name": "a", "gb": 1}],
                "policies": ["ldram-preferred"], "oli_search": false}}"#,
        );
        assert!(evaluate(&ok).is_ok());
    }

    #[test]
    fn table1_scenario_matches_exp() {
        let s = spec(r#"{"name": "t1", "workload": {"kind": "table1"},
                         "systems": ["A", "B", "C"]}"#);
        let via_scenario = evaluate(&s).unwrap();
        let via_exp = exp::run("table1").unwrap();
        assert_eq!(via_scenario.tables[0].rows, via_exp.tables[0].rows);
    }

    #[test]
    fn objects_grid_marks_best_and_searches_oli() {
        let s = spec(
            r#"{"name": "mix", "workload": {"kind": "objects",
                "threads": 32,
                "objects": [
                    {"name": "hot", "gb": 48, "pattern": "sequential", "scans": 4},
                    {"name": "cold", "gb": 16, "pattern": "random", "scans": 1, "dep_frac": 0.5}
                ]}}"#,
        );
        let r = evaluate(&s).unwrap();
        assert_eq!(r.tables.len(), 2, "grid + OLI assignment");
        let grid = &r.tables[0];
        // All named policies plus the OLI(search) row.
        assert_eq!(grid.rows.len(), 7);
        assert_eq!(grid.rows.iter().filter(|row| row[5] == "*").count(), 1);
        assert!(grid.rows.iter().any(|row| row[0] == "OLI(search)"));
        // The OLI search can never lose to plain LDRAM-preferred: the
        // all-false assignment is in its search space.
        let total = |name: &str| -> f64 {
            grid.rows
                .iter()
                .find(|row| row[0] == name)
                .unwrap()[1]
                .parse()
                .unwrap()
        };
        assert!(total("OLI(search)") <= total("ldram-preferred") + 1e-6);
    }

    #[test]
    fn device_override_changes_results() {
        let base = spec(
            r#"{"name": "b", "workload": {"kind": "objects",
                "objects": [{"name": "a", "gb": 32, "pattern": "sequential", "scans": 2}],
                "policies": ["cxl-preferred"], "oli_search": false}}"#,
        );
        let swapped = spec(
            r#"{"name": "s", "systems": [{"base": "A", "devices": {"2": "cxl-c"}}],
                "workload": {"kind": "objects",
                "objects": [{"name": "a", "gb": 32, "pattern": "sequential", "scans": 2}],
                "policies": ["cxl-preferred"], "oli_search": false}}"#,
        );
        let rb = evaluate(&base).unwrap();
        let rs = evaluate(&swapped).unwrap();
        let tb: f64 = rb.tables[0].rows[0][1].parse().unwrap();
        let ts: f64 = rs.tables[0].rows[0][1].parse().unwrap();
        // CXL C is ~3.5× the bandwidth of CXL A: the swap must show up.
        assert!(ts < tb * 0.6, "base {tb} vs swapped {ts}");
    }
}
