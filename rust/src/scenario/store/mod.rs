//! Layered scenario-result store: a mutable multi-writer **head**, a
//! stack of **sealed immutable layers**, and an **atomically-published
//! tail**, with a compactor folding everything back into the durable
//! `results.jsonl`.
//!
//! The flat flock-era cache serialized every flush under one advisory
//! lock and re-read the on-disk keys per append — fine for a handful of
//! shards, a bottleneck for a serve fleet (the paper's scale lesson:
//! shared-resource serialization, not raw latency, caps throughput).
//! This module restructures the store as a cascade:
//!
//! ```text
//!   lookup ──▶ head (sharded in-process map, this session's inserts)
//!                │ miss
//!                ▼
//!              tail  = atomically-published Vec<Arc<SealedLayer>>
//!                │      base layer (results.jsonl) + sealed segments
//!                ▼
//!              miss ⇒ evaluate, insert into head
//!
//!   flush  ──▶ seal: drain pending → write seg-<seq>-<pid>.jsonl
//!              (unique name — no lock) → publish as a sealed layer
//!   compact ─▶ under the store lock: fold base + segments →
//!              tmp + rename results.jsonl → delete folded segments
//! ```
//!
//! The lookup fast path is one atomic load plus a cascade walk — no
//! `flock(2)`, no disk re-read. Writers contend only on a head shard
//! mutex. The store-wide advisory lock survives, scoped down to the
//! two places that truly rendezvous across processes: compaction's
//! read-fold-rename cycle and layer adoption ([`LayeredStore::adopt`]
//! — the `--shard` rendezvous, which is now segment discovery instead
//! of a whole-store reload under lock).
//!
//! Compatibility is the hard constraint, pinned by the pre-refactor
//! test suites: `results.jsonl` stays the interchange format
//! (schema [`CACHE_SCHEMA`], first-line-wins, byte-compatible with
//! flock-era stores), damaged lines quarantine + self-heal exactly as
//! before, first-insert-wins holds at every level (handle, head shard,
//! store, cross-process), and compaction is crash-safe (segments are
//! deleted only after the merged base is renamed into place, so a kill
//! at any instant leaves a loadable store).
//!
//! Key disjointness invariant: a key is visible in **at most one**
//! place — the head or exactly one sealed layer. Seal moves keys from
//! head to a new layer; adopt and compact filter what they publish
//! against everything already visible. `len` is therefore a plain sum
//! and cascade order never changes which entry a key resolves to.
//!
//! Metrics: `store.layers` (published layer count),
//! `store.cascade_depth` (layers walked per lookup; 0 = head hit),
//! `store.compactions`, plus the flock-era families that keep their
//! names (`scenario.cache.flush_appends` now counts sealed lines,
//! `scenario.cache.flush_lock_wait_ns` times the compaction/adoption
//! lock — the contention signal the serve-fleet roadmap item watches).

use std::collections::{HashMap, HashSet};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::util::fault;
use crate::util::json::Json;
use crate::util::lock::FileLock;
use crate::util::metrics;

pub mod compact;
mod head;
pub mod layer;
pub mod legacy;
mod tail;

pub use compact::CompactStats;
pub use layer::Entry;

use head::Head;
use layer::SealedLayer;
use tail::Published;

/// Cache line schema identifier.
pub const CACHE_SCHEMA: &str = "cxlmem-result-cache-v1";
/// Default cache directory (relative to the working directory).
pub const DEFAULT_DIR: &str = ".cxlmem-cache";
/// Base store file name inside the cache directory.
pub const STORE_FILE: &str = "results.jsonl";
/// Advisory lock file name inside the cache directory.
pub const LOCK_FILE: &str = "lock";
/// Sidecar file damaged store lines are quarantined to on load.
pub const QUARANTINE_FILE: &str = "quarantine.jsonl";

/// Registry handles for the layered-store metric families.
struct StoreMetrics {
    layers: &'static metrics::Gauge,
    cascade_depth: &'static metrics::Histogram,
    compactions: &'static metrics::Counter,
    flush_appends: &'static metrics::Counter,
    flush_lock_wait_ns: &'static metrics::Histogram,
}

fn store_metrics() -> &'static StoreMetrics {
    static M: std::sync::OnceLock<StoreMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| StoreMetrics {
        layers: metrics::gauge("store.layers"),
        cascade_depth: metrics::histogram("store.cascade_depth"),
        compactions: metrics::counter("store.compactions"),
        flush_appends: metrics::counter("scenario.cache.flush_appends"),
        flush_lock_wait_ns: metrics::histogram("scenario.cache.flush_lock_wait_ns"),
    })
}

/// Take the store lock, degrading to unlocked access with a warning if
/// the lock file cannot be created/locked (read-only store, exotic FS).
pub(crate) fn lock_store(path: &Path) -> Option<FileLock> {
    let lock_path = path.parent()?.join(LOCK_FILE);
    match FileLock::acquire(&lock_path) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!(
                "warning: cache lock {} unavailable ({e}); proceeding unlocked",
                lock_path.display()
            );
            None
        }
    }
}

/// Non-blocking store-lock attempt for the background compactor:
/// `None` means another process holds it (their compaction covers us)
/// or the lock is unusable — either way, skip, never wait.
fn try_lock_store(path: &Path) -> Option<FileLock> {
    let lock_path = path.parent()?.join(LOCK_FILE);
    FileLock::try_acquire(&lock_path).ok().flatten()
}

/// The layered store (see the module docs). All methods take `&self`:
/// one instance is shared by every handle of a cache session.
pub struct LayeredStore {
    dir: PathBuf,
    path: PathBuf,
    head: Head,
    tail: Published<Vec<Arc<SealedLayer>>>,
    /// Serializes publishes (seal/adopt/compact read-modify-write the
    /// layer list); readers never touch it.
    publish_mu: Mutex<()>,
}

impl LayeredStore {
    /// Open the store under `dir`, adopting the base file and any
    /// sealed segments present (healing damage as the flat cache did).
    /// A missing or unreadable directory is an empty store; nothing is
    /// written until the first seal.
    pub fn open(dir: &Path) -> Result<Self> {
        let dir = dir.to_path_buf();
        let path = dir.join(STORE_FILE);
        let store = LayeredStore {
            dir,
            path,
            head: Head::new(),
            tail: Published::new(Arc::new(Vec::new())),
            publish_mu: Mutex::new(()),
        };
        if store.has_disk() {
            let _lock = lock_store(&store.path);
            let _ = store.adopt_locked();
        }
        Ok(store)
    }

    /// Whether anything durable exists for this store yet.
    pub fn has_disk(&self) -> bool {
        self.path.exists() || !layer::list_segments(&self.dir).is_empty()
    }

    /// Path of the base store file.
    pub fn store_path(&self) -> &Path {
        &self.path
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Lock-free cascade lookup: head first (this session's inserts
    /// win), then the published layers. One head-shard probe plus one
    /// atomic snapshot load — no file lock, no disk access.
    pub fn get(&self, key: &str) -> Option<Arc<Entry>> {
        let m = store_metrics();
        if let Some(e) = self.head.get(key) {
            m.cascade_depth.record(0);
            return Some(e);
        }
        let layers = self.tail.load();
        for (i, l) in layers.iter().enumerate() {
            if let Some(e) = l.get(key) {
                m.cascade_depth.record(i as u64 + 1);
                return Some(e.clone());
            }
        }
        m.cascade_depth.record(layers.len() as u64 + 1);
        None
    }

    /// Whether `key` is visible anywhere in the cascade.
    pub fn contains(&self, key: &str) -> bool {
        self.head.contains(key) || self.tail.load().iter().any(|l| l.contains(key))
    }

    /// Distinct keys visible (head + every layer; disjoint by the
    /// module-level invariant, so a plain sum).
    pub fn len(&self) -> usize {
        self.head.len() + self.tail.load().iter().map(|l| l.len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether any inserts await a seal.
    pub fn has_pending(&self) -> bool {
        self.head.has_pending()
    }

    /// Record a result under `key` unless the key is already visible
    /// (first insert wins at every level). Returns whether this insert
    /// won. Lock cost: one head-shard mutex.
    pub fn insert(&self, key: &str, scenario: &str, spec: String, doc: Json) -> bool {
        if self.contains(key) {
            return false;
        }
        self.head.insert_if_absent(key, scenario, Arc::new(Entry { spec, doc }))
    }

    /// Seal the pending head entries into a fresh immutable segment:
    /// write `seg-<seq>-<pid>.jsonl` (unique name, temp+rename — **no
    /// store lock**), publish it as a sealed layer, then drop the keys
    /// from the head (they stay the same `Arc`s, so nothing a lookup
    /// returned changes). Returns the number of lines sealed. On error
    /// the drained batch is restored, so a later seal retries it.
    pub fn seal(&self) -> Result<usize> {
        let pending = self.head.take_pending();
        if pending.is_empty() {
            return Ok(0);
        }
        match self.seal_batch(&pending) {
            Ok(n) => Ok(n),
            Err(e) => {
                self.head.restore_pending(pending);
                Err(e)
            }
        }
    }

    fn seal_batch(&self, pending: &[(String, String)]) -> Result<usize> {
        fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating cache dir {}", self.dir.display()))?;
        // Chaos hook: an `io` rule fails the seal before anything is
        // written; the batch goes back to pending for retry.
        fault::io_point("store.seal.io", &self.dir.to_string_lossy())
            .with_context(|| format!("sealing cache segment in {}", self.dir.display()))?;
        let _mu = self.publish_mu.lock().unwrap();
        let layers = self.tail.load();
        let mut lines = String::new();
        let mut sealed: HashMap<String, Arc<Entry>> = HashMap::new();
        let mut drained: Vec<String> = Vec::new();
        for (key, scenario) in pending {
            if layers.iter().any(|l| l.contains(key)) {
                // A sibling process's entry for this key was adopted
                // after our insert: first-on-disk wins, ours is dropped
                // (exactly what the flock path's append dedupe did).
                drained.push(key.clone());
                continue;
            }
            let Some(entry) = self.head.get(key) else {
                continue;
            };
            lines.push_str(&layer::entry_line(key, scenario, &entry.spec, &entry.doc));
            sealed.insert(key.clone(), entry);
            drained.push(key.clone());
        }
        let appended = sealed.len();
        if !sealed.is_empty() {
            let name = layer::next_segment_name();
            let seg = layer::segment_path(&self.dir, &name);
            let tmp = seg.with_extension("jsonl.tmp");
            let written = fs::write(&tmp, &lines).and_then(|()| fs::rename(&tmp, &seg));
            if let Err(e) = written {
                let _ = fs::remove_file(&tmp);
                return Err(e)
                    .with_context(|| format!("writing cache segment {}", seg.display()));
            }
            let mut new_layers = (*layers).clone();
            new_layers.push(Arc::new(SealedLayer::new(Some(name), sealed)));
            let m = store_metrics();
            m.layers.set(new_layers.len() as i64);
            m.flush_appends.add(appended as u64);
            self.tail.store(Arc::new(new_layers));
        }
        self.head.remove_keys(&drained);
        Ok(appended)
    }

    /// Adopt layers other processes published since open (the shard
    /// rendezvous): re-read the base file (a sibling's compaction may
    /// have folded new keys into it) and index segment files not seen
    /// yet, publishing only keys not already visible — nothing a lookup
    /// returned ever changes. Returns the number of new keys.
    pub fn adopt(&self) -> Result<usize> {
        if !self.has_disk() {
            return Ok(0);
        }
        let _lock = store_metrics().flush_lock_wait_ns.time(|| lock_store(&self.path));
        self.adopt_locked()
    }

    fn adopt_locked(&self) -> Result<usize> {
        let _mu = self.publish_mu.lock().unwrap();
        let layers = self.tail.load();
        let mut new_layers = (*layers).clone();
        let mut added = 0;
        if self.path.exists() {
            if let Some(loaded) = layer::load_file(&self.path) {
                layer::heal_in_place(&self.path, &loaded);
                added += Self::push_novel(&self.head, &mut new_layers, None, loaded.entries);
            }
        }
        let known: HashSet<&str> = layers.iter().filter_map(|l| l.segment.as_deref()).collect();
        for name in layer::list_segments(&self.dir) {
            if known.contains(name.as_str()) {
                continue;
            }
            let seg = layer::segment_path(&self.dir, &name);
            if let Some(loaded) = layer::load_file(&seg) {
                layer::heal_in_place(&seg, &loaded);
                added += Self::push_novel(&self.head, &mut new_layers, Some(name), loaded.entries);
            }
        }
        if new_layers.len() != layers.len() {
            store_metrics().layers.set(new_layers.len() as i64);
            self.tail.store(Arc::new(new_layers));
        }
        Ok(added)
    }

    /// Append a layer holding the subset of `entries` not already
    /// visible in the head or `layers`. Base-origin layers (`segment ==
    /// None`) are skipped when empty; segment layers are published even
    /// empty so their file counts as adopted. Returns the novel count.
    fn push_novel(
        head: &Head,
        layers: &mut Vec<Arc<SealedLayer>>,
        segment: Option<String>,
        entries: Vec<(String, Arc<Entry>, String)>,
    ) -> usize {
        let mut novel: HashMap<String, Arc<Entry>> = HashMap::new();
        for (key, entry, _) in entries {
            if head.contains(&key) || layers.iter().any(|l| l.contains(&key)) {
                continue;
            }
            novel.insert(key, entry);
        }
        let n = novel.len();
        if n > 0 || segment.is_some() {
            layers.push(Arc::new(SealedLayer::new(segment, novel)));
        }
        n
    }

    /// Fold every sealed segment into the base store file, under the
    /// store-wide advisory lock: quarantine any damage found, write the
    /// merged text to a temp file, rename it over `results.jsonl`, and
    /// only then delete the folded segments — a crash at any instant
    /// leaves a loadable store (at worst with segments still pending a
    /// later compaction, never with lost entries). Non-blocking mode
    /// (`blocking == false`, the background compactor) skips instead of
    /// waiting when another process holds the lock.
    pub fn compact(&self, blocking: bool) -> Result<CompactStats> {
        if !self.dir.exists() {
            return Ok(CompactStats::default());
        }
        let m = store_metrics();
        let _lock = if blocking {
            m.flush_lock_wait_ns.time(|| lock_store(&self.path))
        } else {
            match try_lock_store(&self.path) {
                Some(l) => Some(l),
                None => return Ok(CompactStats::default()),
            }
        };
        let fold = compact::fold_disk(&self.dir, &self.path);
        if fold.is_noop() {
            return Ok(CompactStats {
                segments: 0,
                keys: fold.entries.len(),
                rewrote: false,
            });
        }
        let mut text = fold.text.clone();
        if !layer::quarantine(&self.path, &fold.damaged) {
            // The sidecar could not be written: keep the damaged lines
            // tolerated (appended verbatim — they re-classify as damage
            // on the next load) rather than silently dropping them.
            for line in &fold.damaged {
                text.push_str(line);
                text.push('\n');
            }
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        fs::write(&tmp, &text)
            .with_context(|| format!("writing compacted cache store {}", tmp.display()))?;
        // Chaos hook: an `io` rule fails the compaction cleanly (temp
        // file removed, nothing merged); a `panic` rule kills the
        // process between temp write and rename — the
        // crash-mid-compaction drill the store tests rehearse.
        if let Err(e) = fault::io_point("store.compact.io", &self.dir.to_string_lossy()) {
            let _ = fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("compacting cache store {}", self.path.display()));
        }
        if let Err(e) = fs::rename(&tmp, &self.path) {
            let _ = fs::remove_file(&tmp);
            return Err(e)
                .with_context(|| format!("compacting cache store {}", self.path.display()));
        }
        for name in &fold.segments {
            // Best-effort: a segment that survives deletion holds only
            // keys the merged base now carries — the next fold drops
            // its lines again, nothing duplicates in memory.
            let _ = fs::remove_file(layer::segment_path(&self.dir, name));
        }
        m.compactions.inc();

        // Publish the consolidated view: one base layer with every
        // folded key (preferring already-published `Arc`s for pointer
        // stability), plus any layer whose segment was sealed after our
        // fold listed the directory.
        let _mu = self.publish_mu.lock().unwrap();
        let layers = self.tail.load();
        let folded: HashSet<&str> = fold.segments.iter().map(|s| s.as_str()).collect();
        let kept: Vec<Arc<SealedLayer>> = layers
            .iter()
            .filter(|l| l.segment.as_deref().is_some_and(|n| !folded.contains(n)))
            .cloned()
            .collect();
        let mut base: HashMap<String, Arc<Entry>> = HashMap::new();
        for (key, entry) in &fold.entries {
            if self.head.contains(key) || kept.iter().any(|l| l.contains(key)) {
                continue;
            }
            let existing = layers.iter().find_map(|l| l.get(key).cloned());
            base.insert(key.clone(), existing.unwrap_or_else(|| entry.clone()));
        }
        let mut new_layers = vec![Arc::new(SealedLayer::new(None, base))];
        new_layers.extend(kept);
        m.layers.set(new_layers.len() as i64);
        self.tail.store(Arc::new(new_layers));
        Ok(CompactStats {
            segments: fold.segments.len(),
            keys: fold.entries.len(),
            rewrote: true,
        })
    }
}

/// Read-only merged view of the store under `dir` — the base file plus
/// any sealed segments, first-line-wins, exactly what a compaction
/// would write — for consumers of the interchange format (`scenario
/// report`). Taken under the store lock so a mid-compaction rename is
/// never read half-done.
pub fn merged_store_text(dir: &Path) -> Result<String> {
    let path = dir.join(STORE_FILE);
    if !path.exists() && layer::list_segments(dir).is_empty() {
        return Err(anyhow::anyhow!(
            "no result store under {} (expected {} or sealed segments)",
            dir.display(),
            STORE_FILE
        ));
    }
    let _lock = lock_store(&path);
    Ok(compact::fold_disk(dir, &path).text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-store-{tag}-{}", std::process::id()))
    }

    fn doc(v: u64) -> Json {
        Json::obj(vec![("v", v.into())])
    }

    #[test]
    fn seal_publishes_and_compact_folds() {
        let dir = tmp_dir("seal-fold");
        let _ = fs::remove_dir_all(&dir);
        let s = LayeredStore::open(&dir).unwrap();
        assert!(s.insert("k1", "one", "spec-1".into(), doc(1)));
        assert!(!s.insert("k1", "dup", "spec-dup".into(), doc(9)), "first insert wins");
        assert!(s.insert("k2", "two", "spec-2".into(), doc(2)));
        let held = s.get("k1").unwrap();

        assert_eq!(s.seal().unwrap(), 2);
        assert!(!s.has_pending());
        assert_eq!(s.len(), 2);
        assert_eq!(layer::list_segments(&dir).len(), 1, "one sealed segment on disk");
        // Sealing moved the entries, same Arcs: held lookups unchanged.
        assert!(Arc::ptr_eq(&held, &s.get("k1").unwrap()));

        let stats = s.compact(true).unwrap();
        assert_eq!((stats.segments, stats.keys, stats.rewrote), (1, 2, true));
        assert!(layer::list_segments(&dir).is_empty(), "folded segments deleted");
        assert_eq!(s.len(), 2);
        assert!(Arc::ptr_eq(&held, &s.get("k1").unwrap()), "compaction keeps published Arcs");

        // A fresh open over the compacted base sees the same entries.
        let s2 = LayeredStore::open(&dir).unwrap();
        assert_eq!(s2.len(), 2);
        assert_eq!(s2.get("k2").unwrap().doc.get("v").unwrap().as_u64(), Some(2));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn seal_only_stores_rendezvous_via_adopt() {
        let dir = tmp_dir("adopt");
        let _ = fs::remove_dir_all(&dir);
        let a = LayeredStore::open(&dir).unwrap();
        let b = LayeredStore::open(&dir).unwrap();
        a.insert("ka", "a", "spec-a".into(), doc(1));
        a.seal().unwrap();
        b.insert("kb", "b", "spec-b".into(), doc(2));
        b.seal().unwrap();

        // Neither has compacted; rendezvous is pure segment adoption.
        assert!(a.get("kb").is_none());
        assert_eq!(a.adopt().unwrap(), 1);
        assert_eq!(a.get("kb").unwrap().doc.get("v").unwrap().as_u64(), Some(2));
        assert_eq!(a.adopt().unwrap(), 0, "second adopt finds nothing new");
        assert_eq!(a.len(), 2);

        // Compaction on either side folds both segments into the base.
        let stats = b.compact(true).unwrap();
        assert_eq!(stats.segments, 2);
        assert_eq!(b.adopt().unwrap(), 1, "b adopts ka from the merged base");
        let text = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn seal_io_fault_keeps_batch_pending() {
        use crate::util::fault;
        let dir = tmp_dir("sealfault");
        let _ = fs::remove_dir_all(&dir);
        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("store.seal.io/sealfault=io:1").unwrap());
        let s = LayeredStore::open(&dir).unwrap();
        s.insert("k", "one", "spec".into(), doc(1));
        assert!(s.seal().is_err(), "injected seal fault must surface");
        assert!(s.has_pending(), "failed seal restores the batch");
        assert_eq!(s.seal().unwrap(), 1, "retry seals the restored batch");
        fault::clear();
        assert_eq!(LayeredStore::open(&dir).unwrap().len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn merged_store_text_folds_base_and_segments() {
        let dir = tmp_dir("merged-text");
        let _ = fs::remove_dir_all(&dir);
        assert!(merged_store_text(&dir).is_err(), "no store yet");
        let s = LayeredStore::open(&dir).unwrap();
        s.insert("k1", "one", "spec-1".into(), doc(1));
        s.seal().unwrap();
        s.compact(true).unwrap();
        s.insert("k2", "two", "spec-2".into(), doc(2));
        s.seal().unwrap();
        // Base has k1, a live segment has k2: the merged view sees both
        // without rewriting anything.
        let text = merged_store_text(&dir).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert_eq!(layer::list_segments(&dir).len(), 1, "read path must not compact");
        let base = fs::read_to_string(dir.join(STORE_FILE)).unwrap();
        assert_eq!(base.lines().count(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
