//! On-disk pieces of the layered store: the JSONL line format, the
//! quarantine/self-heal loader, sealed immutable layers, and segment
//! file naming/discovery.
//!
//! Every durable file the store touches — the compacted base
//! `results.jsonl` and each sealed `seg-*.jsonl` segment — speaks the
//! same one-line-per-entry `cxlmem-result-cache-v1` format, so any of
//! them can be read (or concatenated) by older tooling, and the base
//! store stays byte-compatible with the pre-layered flock-era cache.
//!
//! Loading is where crash tolerance lives: damaged lines (torn tail
//! writes, interleaved garbage) are moved verbatim to the
//! `quarantine.jsonl` sidecar and the file is compacted to exactly the
//! surviving lines — byte-identical to a file that never saw the
//! damage — while valid foreign-schema lines are kept (they belong to
//! another tool, not to the damage).

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::json::Json;
use crate::util::metrics;

use super::{CACHE_SCHEMA, QUARANTINE_FILE};

/// Sealed segment files are `seg-<seq>-<pid>.jsonl`; fixed-width
/// decimal fields make lexicographic name order the seal order.
pub(crate) const SEGMENT_PREFIX: &str = "seg-";
pub(crate) const SEGMENT_SUFFIX: &str = ".jsonl";

/// One stored result: the canonical spec it was computed from (verified
/// on lookup) and the result document.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub spec: String,
    pub doc: Json,
}

/// Parse one store line into `(key, entry)`; `None` for damage or
/// foreign schemas (the caller skips those).
pub(crate) fn parse_line(line: &str) -> Option<(String, Entry)> {
    if line.trim().is_empty() {
        return None;
    }
    let doc = Json::parse(line).ok()?;
    if doc.get("schema").and_then(Json::as_str) != Some(CACHE_SCHEMA) {
        return None;
    }
    let key = doc.get("key").and_then(Json::as_str)?;
    let spec = doc.get("spec").and_then(Json::as_str)?;
    let result = doc.get("result")?;
    Some((
        key.to_string(),
        Entry {
            spec: spec.to_string(),
            doc: result.clone(),
        },
    ))
}

/// Serialize one entry as a store line (with trailing newline) — the
/// single writer-side counterpart of [`parse_line`], shared by seal and
/// the legacy reference path so both emit byte-identical lines.
pub(crate) fn entry_line(key: &str, scenario: &str, spec: &str, doc: &Json) -> String {
    let line = Json::obj(vec![
        ("schema", CACHE_SCHEMA.into()),
        ("key", key.into()),
        ("scenario", scenario.into()),
        ("spec", spec.into()),
        ("result", doc.clone()),
    ]);
    let mut text = line.to_string();
    text.push('\n');
    text
}

/// Read the store text at `path`. An unreadable file degrades to `None`
/// with a warning: the cache must never block a run.
pub(crate) fn read_store(path: &Path) -> Option<String> {
    match fs::read_to_string(path) {
        Ok(t) => Some(t),
        Err(e) => {
            eprintln!(
                "warning: unreadable scenario result cache {} ({e}); treating as empty",
                path.display()
            );
            None
        }
    }
}

/// How a store line is treated on load.
enum LineClass {
    /// A well-formed entry of our schema.
    Entry(String, Entry),
    /// Valid JSON of another schema: not ours to judge — kept verbatim.
    Foreign,
    /// Unparseable, or our schema missing required fields: quarantined.
    Damaged,
    /// Whitespace only (an artifact, never written by us): dropped.
    Blank,
}

fn classify_line(line: &str) -> LineClass {
    if line.trim().is_empty() {
        return LineClass::Blank;
    }
    let Ok(doc) = Json::parse(line) else {
        return LineClass::Damaged;
    };
    if doc.get("schema").and_then(Json::as_str) != Some(CACHE_SCHEMA) {
        return LineClass::Foreign;
    }
    match parse_line(line) {
        Some((key, entry)) => LineClass::Entry(key, entry),
        None => LineClass::Damaged,
    }
}

/// One loaded store file, classified line by line.
pub(crate) struct LoadedFile {
    /// The raw text as read (to decide whether healing must rewrite).
    pub text: String,
    /// Surviving lines, verbatim, in file order: our entries (duplicate
    /// keys included — disk keeps them, memory first-wins) + foreign.
    pub kept: Vec<String>,
    /// First occurrence per key, in file order: `(key, entry, line)`.
    pub entries: Vec<(String, Arc<Entry>, String)>,
    /// Damaged lines, verbatim, in file order.
    pub damaged: Vec<String>,
}

impl LoadedFile {
    fn healed_text(&self) -> String {
        let mut healed = String::with_capacity(self.text.len());
        for line in &self.kept {
            healed.push_str(line);
            healed.push('\n');
        }
        healed
    }
}

/// Load and classify the file at `path`. `None` if it is unreadable
/// (the caller treats that as empty). No disk writes happen here; pair
/// with [`heal_in_place`] to quarantine and compact the damage found.
pub(crate) fn load_file(path: &Path) -> Option<LoadedFile> {
    let text = read_store(path)?;
    let mut kept = Vec::new();
    let mut entries: Vec<(String, Arc<Entry>, String)> = Vec::new();
    let mut seen: std::collections::HashSet<String> = std::collections::HashSet::new();
    let mut damaged = Vec::new();
    for line in text.lines() {
        match classify_line(line) {
            LineClass::Entry(key, entry) => {
                kept.push(line.to_string());
                if seen.insert(key.clone()) {
                    entries.push((key, Arc::new(entry), line.to_string()));
                }
            }
            LineClass::Foreign => kept.push(line.to_string()),
            LineClass::Damaged => damaged.push(line.to_string()),
            LineClass::Blank => {}
        }
    }
    Some(LoadedFile {
        text,
        kept,
        entries,
        damaged,
    })
}

/// Append `damaged` lines verbatim to the quarantine sidecar next to
/// `path`, counting them in `cache.quarantined_lines`. Returns whether
/// the sidecar write succeeded (callers must not discard damage that
/// was never quarantined).
pub(crate) fn quarantine(path: &Path, damaged: &[String]) -> bool {
    if damaged.is_empty() {
        return true;
    }
    let Some(dir) = path.parent() else {
        return false;
    };
    let sidecar = dir.join(QUARANTINE_FILE);
    let mut blob = String::new();
    for line in damaged {
        blob.push_str(line);
        blob.push('\n');
    }
    let appended = fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&sidecar)
        .and_then(|mut f| f.write_all(blob.as_bytes()));
    if let Err(e) = appended {
        eprintln!(
            "warning: cannot quarantine {} damaged cache line(s) to {} ({e}); \
             store left as-is",
            damaged.len(),
            sidecar.display()
        );
        return false;
    }
    metrics::counter("cache.quarantined_lines").add(damaged.len() as u64);
    eprintln!(
        "warning: quarantined {} damaged cache line(s) to {}",
        damaged.len(),
        sidecar.display()
    );
    true
}

/// Self-heal the file at `path` from its classified load: quarantine
/// the damaged lines, then compact the file to exactly the surviving
/// lines (temp file + rename, so a crash mid-heal at worst leaves the
/// original). A clean file is untouched — reopening a healed store is
/// a byte-for-byte no-op. Failures degrade with a warning, never to
/// data loss: the file is only rewritten once the damaged lines are
/// safely in the sidecar.
pub(crate) fn heal_in_place(path: &Path, loaded: &LoadedFile) {
    let healed = loaded.healed_text();
    if healed == loaded.text {
        return;
    }
    if !quarantine(path, &loaded.damaged) {
        return;
    }
    let tmp = path.with_extension("jsonl.tmp");
    let compacted = fs::write(&tmp, &healed).and_then(|()| fs::rename(&tmp, path));
    if let Err(e) = compacted {
        let _ = fs::remove_file(&tmp);
        eprintln!(
            "warning: cache store {} not compacted ({e}); damage stays tolerated on load",
            path.display()
        );
    }
}

/// One sealed, immutable layer of the cascade: an `Arc`'d read-only
/// index over a flushed segment file (or over the compacted base store,
/// for which `segment` is `None`). Never mutated after publication —
/// lookups walk layers with no lock at all.
pub struct SealedLayer {
    /// Segment file name inside the store dir; `None` for layers whose
    /// entries came from (or were folded into) the base store file.
    pub(crate) segment: Option<String>,
    pub(crate) entries: HashMap<String, Arc<Entry>>,
}

impl SealedLayer {
    pub(crate) fn new(segment: Option<String>, entries: HashMap<String, Arc<Entry>>) -> Self {
        SealedLayer { segment, entries }
    }

    pub(crate) fn get(&self, key: &str) -> Option<&Arc<Entry>> {
        self.entries.get(key)
    }

    pub(crate) fn contains(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    pub(crate) fn len(&self) -> usize {
        self.entries.len()
    }
}

/// Per-process monotonic sequence base for segment names: wall-clock
/// nanoseconds, bumped past any previously issued value so two seals in
/// the same nanosecond (or a clock step backwards) still order.
fn next_segment_seq() -> u64 {
    static LAST: AtomicU64 = AtomicU64::new(0);
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    LAST.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |prev| {
        Some(now.max(prev + 1))
    })
    .map(|prev| now.max(prev + 1))
    .unwrap_or(now)
}

/// A fresh, globally unique segment file name. Uniqueness needs no
/// lock: the sequence is process-monotonic and the pid disambiguates
/// concurrent processes.
pub(crate) fn next_segment_name() -> String {
    format!(
        "{SEGMENT_PREFIX}{:020}-{:010}{SEGMENT_SUFFIX}",
        next_segment_seq(),
        std::process::id()
    )
}

/// Sealed segment files currently in `dir`, in name (= seal) order.
/// A missing or unreadable directory is an empty list — segment
/// discovery must never block a run.
pub(crate) fn list_segments(dir: &Path) -> Vec<String> {
    let Ok(rd) = fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut names: Vec<String> = rd
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with(SEGMENT_PREFIX) && n.ends_with(SEGMENT_SUFFIX))
        .collect();
    names.sort();
    names
}

/// Path of segment `name` inside `dir`.
pub(crate) fn segment_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_names_are_unique_and_ordered() {
        let a = next_segment_name();
        let b = next_segment_name();
        let c = next_segment_name();
        assert!(a < b && b < c, "{a} {b} {c}");
        for n in [&a, &b, &c] {
            assert!(n.starts_with(SEGMENT_PREFIX) && n.ends_with(SEGMENT_SUFFIX));
        }
    }

    #[test]
    fn load_file_classifies_and_first_key_wins() {
        let dir = std::env::temp_dir().join(format!("cxlmem-layer-load-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.jsonl");
        let l1 = entry_line("k1", "one", "spec-1", &Json::obj(vec![("v", 1u64.into())]));
        let dup = entry_line("k1", "one-again", "spec-1b", &Json::obj(vec![("v", 9u64.into())]));
        let foreign = "{\"schema\": \"other-v9\"}\n";
        let torn = "{\"schema\": \"cxlmem-result-cache-v1\", \"key\": \"t";
        fs::write(&path, format!("{l1}{dup}{foreign}{torn}")).unwrap();
        let loaded = load_file(&path).unwrap();
        // Disk keeps the duplicate + foreign lines; memory first-wins.
        assert_eq!(loaded.kept.len(), 3);
        assert_eq!(loaded.entries.len(), 1);
        assert_eq!(loaded.entries[0].0, "k1");
        assert_eq!(loaded.entries[0].1.spec, "spec-1");
        assert_eq!(loaded.damaged, vec![torn.to_string()]);
        let _ = fs::remove_dir_all(&dir);
    }
}
