//! The pre-layered flock-era write path, kept as a **reference
//! implementation** for the `scenario/cache(contended flush)` bench:
//! every flush takes the single store-wide advisory lock, re-reads all
//! on-disk keys, and appends one line per pending entry — correct, and
//! exactly the serialization bottleneck the layered store removes (the
//! paper's scale point: shared-resource serialization, not raw device
//! latency, is what caps fleet throughput).
//!
//! Emits byte-identical lines to the layered seal path (both go through
//! [`super::layer::entry_line`]), so a store written by either path
//! loads in either implementation.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::lock::FileLock;

use super::layer::{entry_line, parse_line, read_store};
use super::{LOCK_FILE, STORE_FILE};

/// A minimal legacy-path cache handle: in-memory key set plus pending
/// appends, flushed under the store-wide lock. Bench-only surface — the
/// production handle is [`crate::scenario::cache::ResultCache`].
pub struct LegacyCache {
    path: PathBuf,
    keys: BTreeMap<String, ()>,
    /// `(key, scenario, spec, doc)` awaiting flush.
    pending: Vec<(String, String, String, Json)>,
}

impl LegacyCache {
    pub fn open(dir: &Path) -> Result<Self> {
        let path = dir.join(STORE_FILE);
        let mut keys = BTreeMap::new();
        if path.exists() {
            if let Some(text) = read_store(&path) {
                for line in text.lines() {
                    if let Some((key, _)) = parse_line(line) {
                        keys.insert(key, ());
                    }
                }
            }
        }
        Ok(LegacyCache {
            path,
            keys,
            pending: Vec::new(),
        })
    }

    /// First insert wins, like the production handle.
    pub fn insert(&mut self, key: String, scenario: String, spec: String, doc: Json) {
        if self.keys.contains_key(&key) {
            return;
        }
        self.keys.insert(key.clone(), ());
        self.pending.push((key, scenario, spec, doc));
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// The legacy flush: one store-wide `flock`, a full re-read of
    /// on-disk keys (dedupe against concurrent flushers), then one
    /// whole-line append per surviving entry.
    pub fn flush(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            fs::create_dir_all(dir)
                .with_context(|| format!("creating cache dir {}", dir.display()))?;
        }
        let lock_path = self.path.parent().expect("store path has a dir").join(LOCK_FILE);
        let _lock = FileLock::acquire(&lock_path)
            .with_context(|| format!("locking cache store {}", self.path.display()))?;
        let mut on_disk = BTreeMap::new();
        let mut needs_newline = false;
        if self.path.exists() {
            if let Some(text) = read_store(&self.path) {
                needs_newline = !text.is_empty() && !text.ends_with('\n');
                for line in text.lines() {
                    if let Some((key, _)) = parse_line(line) {
                        on_disk.insert(key, ());
                    }
                }
            }
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening cache store {}", self.path.display()))?;
        if needs_newline {
            f.write_all(b"\n")
                .with_context(|| format!("appending to cache store {}", self.path.display()))?;
        }
        for (key, scenario, spec, doc) in self.pending.drain(..) {
            if on_disk.contains_key(&key) {
                continue;
            }
            let line = entry_line(&key, &scenario, &spec, &doc);
            f.write_all(line.as_bytes())
                .with_context(|| format!("appending to cache store {}", self.path.display()))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_lines_load_in_the_layered_store() {
        let dir = std::env::temp_dir().join(format!("cxlmem-legacy-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let mut c = LegacyCache::open(&dir).unwrap();
        c.insert(
            "k1".into(),
            "one".into(),
            "spec-1".into(),
            Json::obj(vec![("v", 1u64.into())]),
        );
        c.insert(
            "k1".into(),
            "dup".into(),
            "spec-dup".into(),
            Json::obj(vec![("v", 9u64.into())]),
        );
        c.insert(
            "k2".into(),
            "two".into(),
            "spec-2".into(),
            Json::obj(vec![("v", 2u64.into())]),
        );
        c.flush().unwrap();
        assert_eq!(c.len(), 2);

        let store = super::super::LayeredStore::open(&dir).unwrap();
        assert_eq!(store.len(), 2);
        let e = store.get("k1").unwrap();
        assert_eq!(e.spec, "spec-1");
        assert_eq!(e.doc.get("v").unwrap().as_u64(), Some(1));
        let _ = fs::remove_dir_all(&dir);
    }
}
