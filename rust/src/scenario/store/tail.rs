//! Atomically-published snapshot cell — the layered store's "tail".
//!
//! [`Published<T>`] holds an `Arc<T>` behind an atomic pointer so
//! readers take a consistent snapshot with **no lock**: one atomic
//! increment of a readers counter, one atomic pointer load, one
//! strong-count bump, one decrement. Writers swap in a new snapshot and
//! retire the old one; retired snapshots are reclaimed on a later
//! publish that observes zero in-flight readers (a deferred-reclamation
//! scheme in the hazard-era family — the niche `arc-swap` fills, built
//! here from `std` only because the vendor set is offline).
//!
//! This is the publication point of [`super::LayeredStore`]: the value
//! is the current `Vec<Arc<SealedLayer>>`, readers walk it on every
//! cache lookup, and writers (seal / adopt / compact) replace it a
//! handful of times per run. The design center is therefore
//! read-dominated: loads are wait-free with respect to writers (a
//! reader never blocks on a publish, and vice versa), while writers
//! additionally serialize among themselves in the store with a plain
//! mutex — reclamation only has to be safe here, not fast.
//!
//! # Safety argument
//!
//! Everything is `SeqCst`, so all the operations below sit in one total
//! order. A reader R does: `readers += 1` (R1), `p = ptr` (R2),
//! `strong_count(p) += 1` (R3), `readers -= 1` (R4). A writer W does:
//! `old = ptr.swap(new)` (W1), then frees retired pointers only if it
//! reads `readers == 0` (W2). For W to free a pointer R is still
//! dereferencing, R must have loaded it before the swap that retired it
//! (R2 before that W1 in the total order) while W2 saw no reader (W2
//! before R1, or after R4). `W2 < R1` contradicts `R1 < R2 < W1 < W2`;
//! and `R4 < W2` means R3 already ran, so the snapshot's strong count
//! carries R's claim and "freeing" it merely drops the cell's own
//! reference. Either way the dereference is of live memory.
//!
//! Retirement is bounded in practice: the store publishes rarely and
//! readers are short (a map probe), so the retire list drains on the
//! next publish; `Drop` frees whatever is left.

use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Mutex};

/// A lock-free-readable `Arc<T>` slot (see the module docs).
pub struct Published<T> {
    ptr: AtomicPtr<T>,
    /// Readers currently between their counter increment and decrement.
    readers: AtomicUsize,
    /// Swapped-out snapshots awaiting a quiescent publish to be freed.
    retired: Mutex<Vec<*mut T>>,
}

// The raw pointers are `Arc<T>` payloads managed per the module-level
// safety argument; they carry no thread affinity beyond T's own.
unsafe impl<T: Send + Sync> Send for Published<T> {}
unsafe impl<T: Send + Sync> Sync for Published<T> {}

impl<T> Published<T> {
    pub fn new(value: Arc<T>) -> Self {
        Published {
            ptr: AtomicPtr::new(Arc::into_raw(value) as *mut T),
            readers: AtomicUsize::new(0),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Take a snapshot of the current value. Wait-free with respect to
    /// [`Published::store`]: never blocks, never sees a torn value.
    pub fn load(&self) -> Arc<T> {
        self.readers.fetch_add(1, SeqCst);
        let p = self.ptr.load(SeqCst);
        // SAFETY: `p` came from `Arc::into_raw` and cannot have been
        // freed while `readers` is nonzero (module-level argument).
        let arc = unsafe {
            Arc::increment_strong_count(p);
            Arc::from_raw(p)
        };
        self.readers.fetch_sub(1, SeqCst);
        arc
    }

    /// Publish a new value. The old snapshot is retired and freed on
    /// the first publish that observes no in-flight readers.
    pub fn store(&self, value: Arc<T>) {
        let new = Arc::into_raw(value) as *mut T;
        let old = self.ptr.swap(new, SeqCst);
        let mut retired = self.retired.lock().unwrap();
        retired.push(old);
        if self.readers.load(SeqCst) == 0 {
            for p in retired.drain(..) {
                // SAFETY: no reader holds a pre-claim reference to any
                // retired pointer (module-level argument), so dropping
                // the cell's own count here is balanced.
                unsafe { drop(Arc::from_raw(p)) };
            }
        }
    }
}

impl<T> Drop for Published<T> {
    fn drop(&mut self) {
        // Exclusive access: no readers or writers remain.
        let current = *self.ptr.get_mut();
        // SAFETY: reclaiming the counts the cell itself holds.
        unsafe { drop(Arc::from_raw(current)) };
        for p in self.retired.get_mut().unwrap().drain(..) {
            unsafe { drop(Arc::from_raw(p)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_see_latest_store() {
        let cell = Published::new(Arc::new(1u64));
        assert_eq!(*cell.load(), 1);
        cell.store(Arc::new(2));
        assert_eq!(*cell.load(), 2);
        // A snapshot taken before a publish stays valid and unchanged.
        let old = cell.load();
        cell.store(Arc::new(3));
        assert_eq!(*old, 2);
        assert_eq!(*cell.load(), 3);
    }

    /// Hammer loads against stores across threads; every snapshot must
    /// be one of the published values (no torn or freed reads), and all
    /// retired snapshots must be reclaimed exactly once — `Arc`'s own
    /// count balancing aborts the test on a double free, and the drop
    /// counter below catches leaks.
    #[test]
    fn concurrent_load_store_reclaims_exactly_once() {
        use std::sync::atomic::AtomicU64;

        static DROPS: AtomicU64 = AtomicU64::new(0);
        struct Tracked(u64);
        impl Drop for Tracked {
            fn drop(&mut self) {
                DROPS.fetch_add(1, SeqCst);
            }
        }

        const PUBLISHES: u64 = 200;
        let before = DROPS.load(SeqCst);
        {
            let cell = Published::new(Arc::new(Tracked(0)));
            std::thread::scope(|s| {
                for _ in 0..4 {
                    s.spawn(|| {
                        for _ in 0..2_000 {
                            let snap = cell.load();
                            assert!(snap.0 <= PUBLISHES, "read a torn/garbage snapshot");
                        }
                    });
                }
                s.spawn(|| {
                    for v in 1..=PUBLISHES {
                        cell.store(Arc::new(Tracked(v)));
                    }
                });
            });
        }
        // PUBLISHES retired snapshots + the final one dropped with the cell.
        assert_eq!(DROPS.load(SeqCst) - before, PUBLISHES + 1, "snapshot leaked or double-freed");
    }
}
