//! Disk folding for the background compactor and the report reader.
//!
//! A compaction folds the base `results.jsonl` plus every sealed
//! `seg-*.jsonl` segment into one merged store text: the base's
//! surviving lines verbatim (byte-compatibility — a store that never
//! sealed a segment compacts to itself), then each segment's entry
//! lines in seal order, first-line-wins across the whole fold (a key
//! the base or an earlier segment already carries is dropped, which is
//! exactly how overlapping shards deduplicate). The caller owns
//! locking, quarantine, the temp-file+rename rewrite and segment
//! deletion — this module only reads and merges, so the same fold
//! backs the read-only `scenario report` path.

use std::collections::HashSet;
use std::path::Path;
use std::sync::Arc;

use super::layer::{list_segments, load_file, segment_path, Entry};

/// What one compaction did (the `scenario compact` verb prints this).
#[derive(Clone, Copy, Debug, Default)]
pub struct CompactStats {
    /// Sealed segment files folded (and deleted) by this compaction.
    pub segments: usize,
    /// Distinct keys in the merged store.
    pub keys: usize,
    /// Whether the base store file was rewritten (false = nothing to
    /// fold and no damage to heal: the store was already compact).
    pub rewrote: bool,
}

/// One folded view of everything durable under `dir`.
pub(crate) struct Fold {
    /// The merged store text (base surviving lines + novel segment
    /// entry lines, in order, one trailing newline per line).
    pub text: String,
    /// First-wins entries across the fold, in line order.
    pub entries: Vec<(String, Arc<Entry>)>,
    /// Damaged lines found anywhere in the fold, verbatim.
    pub damaged: Vec<String>,
    /// Raw text of the base file (`None` if missing/unreadable) — the
    /// no-op test: a fold with no segments and `text == base_text`
    /// changes nothing.
    pub base_text: Option<String>,
    /// Names of the segment files folded in, in seal order.
    pub segments: Vec<String>,
}

impl Fold {
    /// Whether rewriting the base with [`Fold::text`] would change
    /// anything on disk.
    pub fn is_noop(&self) -> bool {
        self.segments.is_empty() && self.base_text.as_deref() == Some(self.text.as_str())
            || self.segments.is_empty() && self.base_text.is_none() && self.text.is_empty()
    }
}

/// Read and merge the base store + all sealed segments under `dir`
/// (pure read — no disk writes, no locking; callers that intend to
/// rewrite hold the store lock around the whole fold+rewrite).
pub(crate) fn fold_disk(dir: &Path, base_path: &Path) -> Fold {
    let mut text = String::new();
    let mut entries: Vec<(String, Arc<Entry>)> = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();
    let mut damaged: Vec<String> = Vec::new();

    let base = if base_path.exists() {
        load_file(base_path)
    } else {
        None
    };
    let base_text = base.as_ref().map(|b| b.text.clone());
    if let Some(b) = base {
        for line in &b.kept {
            text.push_str(line);
            text.push('\n');
        }
        for (key, entry, _) in b.entries {
            if seen.insert(key.clone()) {
                entries.push((key, entry));
            }
        }
        damaged.extend(b.damaged);
    }

    let mut segments = Vec::new();
    for name in list_segments(dir) {
        let Some(loaded) = load_file(&segment_path(dir, &name)) else {
            // Unreadable segment: leave the file alone for a later
            // compaction (deleting what we could not fold would lose
            // data); it simply does not participate in this fold.
            continue;
        };
        for (key, entry, line) in loaded.entries {
            if seen.insert(key.clone()) {
                text.push_str(&line);
                text.push('\n');
                entries.push((key, entry));
            }
        }
        damaged.extend(loaded.damaged);
        segments.push(name);
    }

    Fold {
        text,
        entries,
        damaged,
        base_text,
        segments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::fs;

    fn line(key: &str, v: u64) -> String {
        super::super::layer::entry_line(
            key,
            &format!("s-{key}"),
            &format!("spec-{key}"),
            &Json::obj(vec![("v", v.into())]),
        )
    }

    #[test]
    fn fold_keeps_base_bytes_and_first_segment_wins() {
        let dir = std::env::temp_dir().join(format!("cxlmem-fold-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let base_path = dir.join("results.jsonl");
        let base = format!("{}{}", line("a", 1), "{\"schema\": \"other-v9\"}\n");
        fs::write(&base_path, &base).unwrap();
        // Two segments: the earlier one wins key "b"; key "a" is
        // shadowed by the base everywhere.
        fs::write(dir.join("seg-00000000000000000001-0000000001.jsonl"), line("b", 2)).unwrap();
        fs::write(
            dir.join("seg-00000000000000000002-0000000001.jsonl"),
            format!("{}{}", line("a", 9), line("b", 9)),
        )
        .unwrap();

        let fold = fold_disk(&dir, &base_path);
        assert_eq!(fold.segments.len(), 2);
        assert!(!fold.is_noop());
        assert_eq!(fold.text, format!("{base}{}", line("b", 2)));
        let keys: Vec<&str> = fold.entries.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a", "b"]);
        assert_eq!(fold.entries[1].1.doc.get("v").unwrap().as_u64(), Some(2));

        // Folding the rewritten text with no segments is a no-op.
        fs::write(&base_path, &fold.text).unwrap();
        for name in &fold.segments {
            fs::remove_file(dir.join(name)).unwrap();
        }
        assert!(fold_disk(&dir, &base_path).is_noop());
        let _ = fs::remove_dir_all(&dir);
    }
}
