//! The mutable multi-writer **head** of the layered store: a sharded
//! in-process concurrent map taking this session's inserts, plus the
//! ordered pending log that seals drain.
//!
//! Writers contend only on one of [`SHARDS`] small mutexes (picked by
//! the entry key's FNV hash), never on the store file or any global
//! lock; readers take the same shard mutex for a single map probe —
//! microseconds of critical section, no IO. Entries live here from
//! `insert` until a seal moves them (already `Arc`'d, so anything a
//! lookup returned stays valid) into a sealed immutable layer.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::hash::hash_str;

use super::layer::Entry;

/// Shard count: enough that 8–16 writer threads rarely collide, small
/// enough that draining every shard stays trivial.
const SHARDS: usize = 16;

/// The mutable head (see the module docs).
pub(crate) struct Head {
    shards: Vec<Mutex<HashMap<String, Arc<Entry>>>>,
    /// Insert-order log of keys awaiting a seal: `(key, scenario name)`.
    /// The scenario name rides along because the store line carries it
    /// but the entry body does not.
    pending: Mutex<Vec<(String, String)>>,
}

impl Head {
    pub fn new() -> Self {
        Head {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            pending: Mutex::new(Vec::new()),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<HashMap<String, Arc<Entry>>> {
        &self.shards[(hash_str(key) as usize) % SHARDS]
    }

    pub fn get(&self, key: &str) -> Option<Arc<Entry>> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.shard(key).lock().unwrap().contains_key(key)
    }

    /// Insert unless the key is already present (first insert wins —
    /// double-checked under the shard lock, so concurrent inserters of
    /// one key race to a single winner and a single pending record).
    /// Returns whether this call won.
    pub fn insert_if_absent(&self, key: &str, scenario: &str, entry: Arc<Entry>) -> bool {
        {
            let mut shard = self.shard(key).lock().unwrap();
            if shard.contains_key(key) {
                return false;
            }
            shard.insert(key.to_string(), entry);
        }
        self.pending
            .lock()
            .unwrap()
            .push((key.to_string(), scenario.to_string()));
        true
    }

    /// Drain the pending log (seal's input). Disjoint across concurrent
    /// seals: each pending record is handed out exactly once.
    pub fn take_pending(&self) -> Vec<(String, String)> {
        std::mem::take(&mut *self.pending.lock().unwrap())
    }

    /// Put a drained batch back at the front (a seal that failed before
    /// publishing durably must leave the entries pending for retry).
    pub fn restore_pending(&self, mut batch: Vec<(String, String)>) {
        let mut pending = self.pending.lock().unwrap();
        batch.append(&mut pending);
        *pending = batch;
    }

    pub fn has_pending(&self) -> bool {
        !self.pending.lock().unwrap().is_empty()
    }

    /// Remove sealed keys (they are now served by a published layer).
    pub fn remove_keys(&self, keys: &[String]) {
        for key in keys {
            self.shard(key).lock().unwrap().remove(key);
        }
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn entry(v: u64) -> Arc<Entry> {
        Arc::new(Entry {
            spec: format!("spec-{v}"),
            doc: Json::obj(vec![("v", v.into())]),
        })
    }

    #[test]
    fn first_insert_wins_and_pending_tracks_order() {
        let h = Head::new();
        assert!(h.insert_if_absent("a", "one", entry(1)));
        assert!(!h.insert_if_absent("a", "two", entry(2)), "second insert must lose");
        assert!(h.insert_if_absent("b", "three", entry(3)));
        assert_eq!(h.get("a").unwrap().spec, "spec-1");
        assert_eq!(h.len(), 2);
        let pending = h.take_pending();
        assert_eq!(
            pending,
            vec![("a".to_string(), "one".to_string()), ("b".to_string(), "three".to_string())]
        );
        assert!(!h.has_pending());
        // Restore prepends, preserving retry-before-new ordering.
        assert!(h.insert_if_absent("c", "four", entry(4)));
        h.restore_pending(pending);
        let replay: Vec<String> = h.take_pending().into_iter().map(|(k, _)| k).collect();
        assert_eq!(replay, vec!["a", "b", "c"]);
    }

    #[test]
    fn concurrent_inserters_of_one_key_race_to_one_winner() {
        let h = Head::new();
        let h = &h;
        let wins: usize = std::thread::scope(|s| {
            (0..8u64)
                .map(|v| s.spawn(move || h.insert_if_absent("hot", "x", entry(v)) as usize))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|j| j.join().unwrap())
                .sum()
        });
        assert_eq!(wins, 1, "exactly one insert may win");
        assert_eq!(h.len(), 1);
        assert_eq!(h.take_pending().len(), 1, "one winner, one pending record");
    }
}
