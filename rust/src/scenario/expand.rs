//! Scenario expansion: one base document in, a deterministic list of
//! concrete scenario documents out.
//!
//! Two generators, checked in this order:
//! - `"fleet"` — a seeded randomized fleet of `objects` scenarios:
//!   random base system, a random vendor CXL card spliced in, a random
//!   object mix. Same seed ⇒ byte-identical output (all sampled numbers
//!   are dyadic rationals, so their JSON rendering is exact), which the
//!   determinism tests pin.
//! - `"sweep"` — a cross product over dotted-path axes
//!   (`"workload.threads": [16, 32]`), axes in sorted key order.
//!
//! A document with neither field expands to itself.

use anyhow::{anyhow, bail, Result};

use super::spec::ScenarioSpec;
use crate::memsim::{topology, MemKind};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// True when a document is a generator template rather than a concrete
/// scenario — the single test `validate`, `run` and `expand` all share.
pub fn is_template(doc: &Json) -> bool {
    doc.get("fleet").is_some() || doc.get("sweep").is_some()
}

/// Expand a base document. `seed`/`count` override the fleet's own
/// fields (the CLI's `--seed` / `--count`); passing either for a
/// non-fleet document is an error rather than a silent no-op.
pub fn expand(doc: &Json, seed: Option<u64>, count: Option<usize>) -> Result<Vec<Json>> {
    if let Some(fleet) = doc.get("fleet") {
        return expand_fleet(doc, fleet, seed, count);
    }
    if seed.is_some() || count.is_some() {
        bail!("--seed/--count only apply to fleet templates (this document has no 'fleet')");
    }
    if let Some(sweep) = doc.get("sweep") {
        return expand_sweep(doc, sweep);
    }
    // Already concrete: validate and pass through.
    ScenarioSpec::parse(doc)?;
    Ok(vec![doc.clone()])
}

// ---- sweep -----------------------------------------------------------

fn expand_sweep(doc: &Json, sweep: &Json) -> Result<Vec<Json>> {
    let axes = sweep
        .as_obj()
        .ok_or_else(|| anyhow!("'sweep' must map dotted paths to value arrays"))?;
    let mut paths: Vec<&String> = axes.keys().collect();
    paths.sort(); // BTreeMap is already sorted; keep the intent explicit
    let mut values: Vec<&[Json]> = Vec::new();
    for p in &paths {
        let arr = axes[*p]
            .as_arr()
            .ok_or_else(|| anyhow!("sweep axis '{p}' must be an array"))?;
        if arr.is_empty() {
            bail!("sweep axis '{p}' is empty");
        }
        values.push(arr);
    }
    let base_name = doc
        .get("name")
        .and_then(Json::as_str)
        .unwrap_or("sweep")
        .to_string();
    let total: usize = values.iter().map(|v| v.len()).product();
    let mut out = Vec::with_capacity(total);
    let mut idx = vec![0usize; paths.len()];
    for i in 0..total {
        let mut variant = doc.clone();
        if let Json::Obj(m) = &mut variant {
            m.remove("sweep");
            // Swept parameters no longer match the base experiment, so
            // variants must not carry its golden-equivalence tag (an
            // axis that sets "experiment" explicitly re-adds it below).
            m.remove("experiment");
        }
        for (axis, &j) in idx.iter().enumerate() {
            set_path(&mut variant, paths[axis], values[axis][j].clone())?;
        }
        variant.set("name", format!("{base_name}#{i:04}").into());
        ScenarioSpec::parse(&variant)
            .map_err(|e| anyhow!("sweep variant {i} is invalid: {e}"))?;
        out.push(variant);
        // odometer increment
        for axis in (0..idx.len()).rev() {
            idx[axis] += 1;
            if idx[axis] < values[axis].len() {
                break;
            }
            idx[axis] = 0;
        }
    }
    Ok(out)
}

/// Set a dotted path (`workload.threads`) inside a document, creating
/// intermediate objects as needed.
fn set_path(doc: &mut Json, path: &str, value: Json) -> Result<()> {
    let mut cur = doc;
    let parts: Vec<&str> = path.split('.').collect();
    for (i, part) in parts.iter().enumerate() {
        if i + 1 == parts.len() {
            cur.set(part, value);
            return Ok(());
        }
        let m = match cur {
            Json::Obj(m) => m,
            _ => bail!("sweep path '{path}' crosses a non-object"),
        };
        cur = m
            .entry(part.to_string())
            .or_insert_with(|| Json::Obj(Default::default()));
    }
    bail!("empty sweep path")
}

// ---- fleet -----------------------------------------------------------

fn expand_fleet(
    doc: &Json,
    fleet: &Json,
    seed_override: Option<u64>,
    count_override: Option<usize>,
) -> Result<Vec<Json>> {
    let count = count_override
        .or_else(|| fleet.get("count").and_then(Json::as_usize))
        .unwrap_or(200);
    let seed = seed_override
        .or_else(|| fleet.get("seed").and_then(Json::as_u64))
        .unwrap_or(42);
    let base_name = doc
        .get("name")
        .and_then(Json::as_str)
        .unwrap_or("fleet")
        .to_string();

    let systems: Vec<String> = match fleet.get("systems").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("'fleet.systems' must hold system letters"))
            })
            .collect::<Result<Vec<_>>>()?,
        None => vec!["A".into(), "B".into(), "C".into()],
    };
    for s in &systems {
        if topology::by_name(s).is_none() {
            bail!("unknown system '{s}' in fleet pool");
        }
    }
    let cards: Vec<String> = match fleet.get("cxl_presets").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("'fleet.cxl_presets' must hold preset names"))
            })
            .collect::<Result<Vec<_>>>()?,
        None => vec!["cxl-a".into(), "cxl-b".into(), "cxl-c".into()],
    };
    for c in &cards {
        match topology::device_preset(c) {
            Some(d) if d.kind == MemKind::Cxl => {}
            Some(_) => bail!("fleet card '{c}' is not a CXL profile"),
            None => bail!("unknown device preset '{c}' in fleet pool"),
        }
    }
    let threads_pool: Vec<usize> = match fleet.get("threads").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .map(|t| {
                t.as_usize()
                    .ok_or_else(|| anyhow!("'fleet.threads' must hold numbers"))
            })
            .collect::<Result<Vec<_>>>()?,
        None => vec![8, 16, 32, 64],
    };

    // Object-count and size ranges (sizes snap to 0.25 GB).
    let objs_min = fleet
        .get("objects")
        .and_then(|o| o.get("min"))
        .and_then(Json::as_usize)
        .unwrap_or(2);
    let objs_max = fleet
        .get("objects")
        .and_then(|o| o.get("max"))
        .and_then(Json::as_usize)
        .unwrap_or(6);
    if objs_min == 0 || objs_max < objs_min {
        bail!("fleet object count range [{objs_min}, {objs_max}] is invalid");
    }
    let (gb_lo, gb_hi) = match fleet.get("objects").and_then(|o| o.get("gb")) {
        None => (2.0, 48.0),
        Some(v) => {
            let arr = v
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("'fleet.objects.gb' must be [lo, hi]"))?;
            let lo = arr[0].as_f64().unwrap_or(2.0);
            let hi = arr[1].as_f64().unwrap_or(48.0);
            if lo <= 0.0 || hi < lo {
                bail!("'fleet.objects.gb' range is invalid");
            }
            (lo, hi)
        }
    };

    const PATTERNS: [&str; 2] = ["sequential", "random"];
    const SCANS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
    const DEP_FRACS: [f64; 4] = [0.0, 0.25, 0.5, 0.75];
    const COMPUTE: [f64; 4] = [0.0, 0.25, 0.5, 1.0];

    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        // Independent per-scenario stream: order- and count-insensitive.
        let mut rng = Rng::seeded(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let base = rng.choose(&systems).clone();
        let card = rng.choose(&cards).clone();
        let sys = topology::by_name(&base).unwrap();
        let cxl_node = sys
            .node_of(0, MemKind::Cxl)
            .ok_or_else(|| anyhow!("system {base} has no CXL node"))?;
        let n_obj = objs_min + rng.index(objs_max - objs_min + 1);
        // Sizes snap to the 0.25 GB lattice (dyadic → byte-stable JSON
        // rendering) and clamp to the declared upper bound.
        let steps = ((gb_hi - gb_lo) / 0.25).floor() as u64;
        let objects: Vec<Json> = (0..n_obj)
            .map(|k| {
                let gb = (gb_lo + 0.25 * rng.below(steps + 1) as f64).min(gb_hi);
                Json::obj(vec![
                    ("name", format!("obj{k}").into()),
                    ("gb", gb.into()),
                    ("pattern", (*rng.choose(&PATTERNS)).into()),
                    ("scans", (*rng.choose(&SCANS)).into()),
                    ("dep_frac", (*rng.choose(&DEP_FRACS)).into()),
                ])
            })
            .collect();
        let workload = Json::obj(vec![
            ("kind", "objects".into()),
            ("socket", 0usize.into()),
            ("threads", (*rng.choose(&threads_pool)).into()),
            ("compute_ns_per_byte", (*rng.choose(&COMPUTE)).into()),
            ("objects", Json::Arr(objects)),
            ("oli_search", true.into()),
        ]);
        let system = Json::obj(vec![
            ("base", base.as_str().into()),
            (
                "devices",
                Json::obj(vec![(&cxl_node.to_string()[..], Json::Str(card))]),
            ),
        ]);
        let scenario = Json::obj(vec![
            ("schema", super::spec::SCHEMA.into()),
            ("name", format!("{base_name}-{i:03}").into()),
            ("systems", Json::Arr(vec![system])),
            ("workload", workload),
        ]);
        ScenarioSpec::parse(&scenario)
            .map_err(|e| anyhow!("generated fleet scenario {i} is invalid: {e}"))?;
        out.push(scenario);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::to_jsonl;

    #[test]
    fn concrete_doc_expands_to_itself() {
        let doc = Json::parse(r#"{"name": "x", "workload": {"kind": "table1"}}"#).unwrap();
        let out = expand(&doc, None, None).unwrap();
        assert_eq!(out, vec![doc]);
    }

    #[test]
    fn sweep_cross_product() {
        let doc = Json::parse(
            r#"{"name": "s", "workload": {"kind": "loaded-latency"},
                "sweep": {"workload.threads": [16, 32], "systems": [["A"], ["B"], ["C"]]}}"#,
        )
        .unwrap();
        let out = expand(&doc, None, None).unwrap();
        assert_eq!(out.len(), 6);
        // Every variant is concrete (no sweep), uniquely named, valid.
        let mut names = std::collections::BTreeSet::new();
        for v in &out {
            assert!(v.get("sweep").is_none());
            names.insert(v.get("name").unwrap().as_str().unwrap().to_string());
            ScenarioSpec::parse(v).unwrap();
        }
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn fleet_is_deterministic_and_seed_sensitive() {
        let doc = Json::parse(r#"{"name": "f", "fleet": {"count": 12, "seed": 42}}"#).unwrap();
        let a = to_jsonl(expand(&doc, None, None).unwrap());
        let b = to_jsonl(expand(&doc, None, None).unwrap());
        assert_eq!(a, b, "same seed must be byte-identical");
        let c = to_jsonl(expand(&doc, Some(43), None).unwrap());
        assert_ne!(a, c, "different seed must differ");
        assert_eq!(a.lines().count(), 12);
        // Count override wins, and the prefix is stable (per-index seeds).
        let d = to_jsonl(expand(&doc, None, Some(5)).unwrap());
        assert_eq!(d.lines().count(), 5);
        assert!(a.starts_with(&d));
    }

    #[test]
    fn fleet_rejects_bad_pools() {
        let doc =
            Json::parse(r#"{"name": "f", "fleet": {"count": 2, "systems": ["Z"]}}"#).unwrap();
        assert!(expand(&doc, None, None).is_err());
        let doc =
            Json::parse(r#"{"name": "f", "fleet": {"count": 2, "cxl_presets": ["ddr-a"]}}"#)
                .unwrap();
        assert!(expand(&doc, None, None).is_err());
    }
}
