//! Batched scenario evaluation: shard expanded scenarios across the
//! [`crate::util::par`] executor and stream per-scenario results as JSON
//! lines.
//!
//! Sharding notes: workers inherit the session's perf context with inner
//! `jobs` pinned to 1, so a batch never oversubscribes; each worker's
//! thread-local solver memo cache dedupes the repeated traffic solves a
//! fleet poses (same device profiles × near-identical stream descriptors
//! — see the quantized admission in `memsim::system`). Results come back
//! in input order, so a batch's JSONL output is deterministic at any
//! `--jobs`.

use anyhow::{anyhow, Result};

use super::eval::evaluate;
use super::spec::ScenarioSpec;
use crate::report::Report;
use crate::util::json::Json;
use crate::util::par::par_map;

/// One evaluated scenario, ready for JSONL emission.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub name: String,
    pub experiment: Option<String>,
    pub doc: Json,
}

/// Build the JSONL result document for one evaluated scenario.
pub fn result_doc(spec: &ScenarioSpec, report: &Report) -> ScenarioResult {
    let mut doc = Json::obj(vec![
        ("scenario", spec.name.as_str().into()),
        // Canonical system specs (incl. device overrides) so result
        // lines stay joinable to their device profiles on their own.
        (
            "systems",
            Json::arr(spec.systems.iter().map(|s| s.to_json())),
        ),
    ]);
    if let Some(e) = &spec.experiment {
        doc.set("experiment", e.as_str().into());
    }
    if let Some(tables) = report.to_json().get("tables") {
        doc.set("tables", tables.clone());
    }
    ScenarioResult {
        name: spec.name.clone(),
        experiment: spec.experiment.clone(),
        doc,
    }
}

/// Evaluate a batch over up to `jobs` worker threads, preserving input
/// order. A single-scenario batch runs inline with the whole `jobs`
/// budget handed to the scenario's *inner* sweeps instead (the fig16
/// grid path); larger batches shard scenarios across workers, whose
/// inner sweeps stay sequential. The first failing scenario aborts the
/// batch with its name attached.
pub fn run_batch(specs: &[ScenarioSpec], jobs: usize) -> Result<Vec<ScenarioResult>> {
    if specs.len() == 1 {
        let prev = crate::perf::current_jobs();
        crate::perf::set_jobs(jobs.max(1));
        let result = evaluate(&specs[0])
            .map(|report| result_doc(&specs[0], &report))
            .map_err(|e| anyhow!("scenario '{}' failed: {e}", specs[0].name));
        crate::perf::set_jobs(prev);
        return result.map(|r| vec![r]);
    }
    let results = par_map(specs, jobs, |spec| {
        evaluate(spec)
            .map(|report| result_doc(spec, &report))
            .map_err(|e| anyhow!("scenario '{}' failed: {e}", spec.name))
    });
    results.into_iter().collect()
}

/// Parse a text blob into raw documents: either one JSON document or
/// JSONL (one document per line, as `scenario expand` emits).
pub fn docs_of(text: &str) -> Result<Vec<Json>> {
    match Json::parse(text) {
        Ok(doc) => Ok(vec![doc]),
        Err(_) => crate::util::json::parse_jsonl(text)
            .map_err(|e| anyhow!("input is neither a JSON document nor JSONL: {e}")),
    }
}

/// Parse scenario documents out of a text blob (via [`docs_of`]).
/// Fleet/sweep templates are rejected with a pointer at `expand`.
pub fn parse_docs(text: &str) -> Result<Vec<ScenarioSpec>> {
    let docs = docs_of(text)?;
    for doc in &docs {
        if super::expand::is_template(doc) {
            return Err(anyhow!(
                "document is a fleet/sweep template — run `cxlmem scenario expand` first"
            ));
        }
    }
    docs.iter().map(ScenarioSpec::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::to_jsonl;

    fn specs(texts: &[&str]) -> Vec<ScenarioSpec> {
        texts
            .iter()
            .map(|t| ScenarioSpec::parse(&Json::parse(t).unwrap()).unwrap())
            .collect()
    }

    #[test]
    fn batch_preserves_order_and_is_jobs_invariant() {
        let s = specs(&[
            r#"{"name": "one", "experiment": "table1", "workload": {"kind": "table1"},
                "systems": ["A", "B", "C"]}"#,
            r#"{"name": "two", "workload": {"kind": "objects",
                "objects": [{"name": "a", "gb": 4, "pattern": "sequential", "scans": 2}],
                "policies": ["ldram-preferred", "cxl-preferred"], "oli_search": false}}"#,
        ]);
        let seq = run_batch(&s, 1).unwrap();
        let par = run_batch(&s, 4).unwrap();
        assert_eq!(seq.len(), 2);
        assert_eq!(seq[0].name, "one");
        assert_eq!(seq[0].experiment.as_deref(), Some("table1"));
        let a = to_jsonl(seq.iter().map(|r| r.doc.clone()));
        let b = to_jsonl(par.iter().map(|r| r.doc.clone()));
        assert_eq!(a, b, "results must not depend on --jobs");
        // Result lines parse back and carry the scenario name + tables.
        let docs = crate::util::json::parse_jsonl(&a).unwrap();
        assert_eq!(docs[1].get("scenario").unwrap().as_str(), Some("two"));
        assert!(docs[0].get("tables").unwrap().as_arr().unwrap().len() == 1);
    }

    #[test]
    fn parse_docs_accepts_json_and_jsonl() {
        let one = r#"{"name": "x", "workload": {"kind": "table1"}}"#;
        assert_eq!(parse_docs(one).unwrap().len(), 1);
        let two = format!("{one}\n{}\n", r#"{"name": "y", "workload": {"kind": "hpc-table"}}"#);
        let parsed = parse_docs(&two).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[1].name, "y");
        assert!(parse_docs("not json").is_err());
        // Templates must point the user at `expand`, not fail obscurely.
        let err = parse_docs(r#"{"name": "f", "fleet": {"count": 2}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expand"), "{err}");
    }

    #[test]
    fn batch_surfaces_failures_with_name() {
        // A spec that parses but cannot build: node override out of range
        // is caught at parse time, so use a model name gated at eval time
        // is not possible either — instead check empty batch is fine.
        assert!(run_batch(&[], 4).unwrap().is_empty());
    }
}
