//! Batched scenario evaluation: shard expanded scenarios across the
//! [`crate::util::par`] executor and stream per-scenario results as JSON
//! lines.
//!
//! Sharding notes: workers inherit the session's perf context with inner
//! `jobs` pinned to 1, so a batch never oversubscribes; each worker's
//! thread-local solver memo cache dedupes the repeated traffic solves a
//! fleet poses (same device profiles × near-identical stream descriptors
//! — see the quantized admission in `memsim::system`). Results come back
//! in input order, so a batch's JSONL output is deterministic at any
//! `--jobs`.
//!
//! [`run_batch_cached`] layers the persistent result cache
//! ([`super::cache`]) in front of evaluation: specs are keyed by their
//! canonical content hash, hits skip evaluation entirely, and only the
//! misses are scheduled — fleet re-runs and overlapping sweeps become
//! cache reads while the emitted JSONL stays byte-identical. The same
//! canonical identity dedupes *within* a batch, cache or no cache:
//! identical specs (overlapping sweeps, re-expanded fleets) evaluate
//! once, and every duplicate slot is filled from the representative.
//!
//! [`run_batch_supervised`] is the full engine: each miss evaluates
//! under the supervision policy of [`super::supervise`] — panics and
//! errors are isolated per spec and rendered as
//! `cxlmem-result-error-v1` documents in the output (never cached, so
//! a re-run retries exactly the failed slots), transient IO failures
//! get bounded retries, and a deadline marks overruns timed out. The
//! plain `run_batch`/`run_batch_cached` entry points keep the
//! historical fail-fast contract (first failure aborts the batch).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::cache::ResultCache;
use super::eval::evaluate;
use super::spec::ScenarioSpec;
use super::supervise::{self, SuperviseOpts};
use crate::report::Report;
use crate::util::fault;
use crate::util::json::Json;
use crate::util::metrics;
use crate::util::par::par_map;

/// Registry handles for the batch runner (`scenario.batch.*` plus the
/// whole-scenario eval-time histogram `scenario.eval_ns`).
struct BatchMetrics {
    specs: &'static metrics::Counter,
    dedup_collapsed: &'static metrics::Counter,
    evaluated: &'static metrics::Counter,
    jobs_in_flight: &'static metrics::Gauge,
    eval_ns: &'static metrics::Histogram,
}

fn batch_metrics() -> &'static BatchMetrics {
    static M: std::sync::OnceLock<BatchMetrics> = std::sync::OnceLock::new();
    M.get_or_init(|| BatchMetrics {
        specs: metrics::counter("scenario.batch.specs"),
        dedup_collapsed: metrics::counter("scenario.batch.dedup_collapsed"),
        evaluated: metrics::counter("scenario.batch.evaluated"),
        jobs_in_flight: metrics::gauge("scenario.batch.jobs_in_flight"),
        eval_ns: metrics::histogram("scenario.eval_ns"),
    })
}

/// One evaluated scenario, ready for JSONL emission.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub name: String,
    pub experiment: Option<String>,
    pub doc: Json,
}

/// Build the JSONL result document for one evaluated scenario.
pub fn result_doc(spec: &ScenarioSpec, report: &Report) -> ScenarioResult {
    let mut doc = Json::obj(vec![
        ("scenario", spec.name.as_str().into()),
        // Canonical system specs (incl. device overrides) so result
        // lines stay joinable to their device profiles on their own.
        (
            "systems",
            Json::arr(spec.systems.iter().map(|s| s.to_json())),
        ),
    ]);
    if let Some(e) = &spec.experiment {
        doc.set("experiment", e.as_str().into());
    }
    if let Some(tables) = report.to_json().get("tables") {
        doc.set("tables", tables.clone());
    }
    ScenarioResult {
        name: spec.name.clone(),
        experiment: spec.experiment.clone(),
        doc,
    }
}

/// Evaluate a batch over up to `jobs` worker threads, preserving input
/// order. A batch that reduces to a single distinct evaluation runs it
/// inline with the whole `jobs` budget handed to the scenario's *inner*
/// sweeps instead (the fig16 grid path); larger batches shard scenarios
/// across workers, whose inner sweeps stay sequential. The first failing
/// scenario aborts the batch with its name attached.
pub fn run_batch(specs: &[ScenarioSpec], jobs: usize) -> Result<Vec<ScenarioResult>> {
    run_batch_cached(specs, jobs, None)
}


/// [`run_batch`] with an optional content-addressed result cache: specs
/// whose canonical hash is already stored are served without evaluation,
/// only the misses are scheduled, and newly evaluated results are
/// appended to the store. Results keep input order whatever mix of hits
/// and misses a batch is, so the JSONL output stays byte-identical to an
/// uncached run at any `--jobs` — the cache changes cost, never results.
///
/// Duplicate specs within one batch (overlapping sweeps, re-expanded
/// fleets) are deduplicated by canonical identity before probing: the
/// first occurrence is the representative — it alone probes the cache
/// and, on a miss, evaluates — and every later identical slot is filled
/// from it. A batch that reduces to a single distinct miss keeps the
/// inline fast path (the whole `jobs` budget goes to that scenario's
/// inner sweeps, restored even if evaluation panics).
pub fn run_batch_cached(
    specs: &[ScenarioSpec],
    jobs: usize,
    cache: Option<&mut ResultCache>,
) -> Result<Vec<ScenarioResult>> {
    run_batch_supervised(specs, jobs, cache, &SuperviseOpts::fail_fast())
}

/// The full batch engine: [`run_batch_cached`] semantics plus the
/// supervision policy of [`super::supervise`].
///
/// With `opts.fail_fast` (the `run_batch`/`run_batch_cached` contract)
/// the first failing scenario aborts the batch with its name attached,
/// and panics unwind through the executor. Otherwise each failing spec
/// is isolated: its slot is filled with a `cxlmem-result-error-v1`
/// document ([`supervise::error_doc`]) carrying the spec name, cache
/// key, error kind and attempt count; transient IO failures retry with
/// seeded jittered backoff; `opts.deadline` marks overruns timed out.
/// Error documents are **never** inserted into the cache, so a re-run
/// over the same store retries exactly the failed slots while serving
/// every healthy sibling as a pure hit.
pub fn run_batch_supervised(
    specs: &[ScenarioSpec],
    jobs: usize,
    mut cache: Option<&mut ResultCache>,
    opts: &SuperviseOpts,
) -> Result<Vec<ScenarioResult>> {
    // One canonical serialization per slot: the cache key scheme doubles
    // as the in-batch dedupe key (identical canonical spec ⇒ identical
    // name, experiment and — evaluation being deterministic — result).
    let identities: Vec<(String, String)> = specs.iter().map(|s| s.cache_identity()).collect();

    let mut slots: Vec<Option<ScenarioResult>> = vec![None; specs.len()];
    // Duplicate slot -> representative slot (first occurrence).
    let mut rep_of: Vec<usize> = (0..specs.len()).collect();
    let mut first_seen: BTreeMap<&str, usize> = BTreeMap::new();
    let mut miss_idx: Vec<usize> = Vec::new();
    // Probe through a store handle: lookups walk the layered store's
    // lock-free cascade (no store lock, no disk), sharing the facade's
    // hit/miss accounting.
    let handle = cache.as_ref().map(|c| c.handle());
    for (i, spec) in specs.iter().enumerate() {
        let (key, canon) = &identities[i];
        if let Some(&rep) = first_seen.get(canon.as_str()) {
            rep_of[i] = rep;
            continue;
        }
        first_seen.insert(canon.as_str(), i);
        let hit = handle.as_ref().and_then(|h| {
            h.lookup(key, canon).map(|doc| ScenarioResult {
                name: spec.name.clone(),
                experiment: spec.experiment.clone(),
                doc,
            })
        });
        match hit {
            Some(r) => slots[i] = Some(r),
            None => miss_idx.push(i),
        }
    }
    let m = batch_metrics();
    m.specs.add(specs.len() as u64);
    m.dedup_collapsed.add((specs.len() - first_seen.len()) as u64);
    m.evaluated.add(miss_idx.len() as u64);

    let evaluated: Vec<Result<ScenarioResult, supervise::Failure>> = if miss_idx.len() == 1 {
        // Single distinct miss: run inline with the whole jobs budget
        // handed to the scenario's inner sweeps; the guard restores the
        // session's jobs even if evaluation panics.
        let i = miss_idx[0];
        vec![crate::perf::with_jobs(jobs, || {
            supervise::eval_supervised(&specs[i], &identities[i].0, opts)
        })]
    } else {
        let miss: Vec<(&ScenarioSpec, &str)> = miss_idx
            .iter()
            .map(|&i| (&specs[i], identities[i].0.as_str()))
            .collect();
        par_map(&miss, jobs, |&(spec, key)| {
            supervise::eval_supervised(spec, key, opts)
        })
    };

    // Fill the slots. Fail-fast keeps the first failure (input order)
    // but still flushes whatever completed before it — a failing fleet
    // member doesn't throw away its siblings' work on the next run.
    // Supervised mode fills failed slots with error documents instead,
    // which are deliberately never inserted into the cache.
    let mut first_err = None;
    for (&i, r) in miss_idx.iter().zip(evaluated) {
        match r {
            Ok(result) => {
                if let Some(c) = cache.as_mut() {
                    let (key, canon) = &identities[i];
                    c.insert(key.clone(), canon.clone(), &result);
                }
                slots[i] = Some(result);
            }
            Err(f) if opts.fail_fast => {
                if first_err.is_none() {
                    first_err = Some(anyhow!(
                        "scenario '{}' failed: {}",
                        specs[i].name,
                        f.message
                    ));
                }
            }
            Err(f) => {
                let doc = supervise::error_doc(
                    &specs[i].name,
                    &identities[i].0,
                    &f,
                    opts.shard.as_deref(),
                );
                slots[i] = Some(ScenarioResult {
                    name: specs[i].name.clone(),
                    experiment: specs[i].experiment.clone(),
                    doc,
                });
            }
        }
    }
    if let Some(c) = cache.as_mut() {
        // The cache changes cost, never results: a store that cannot be
        // written (read-only checkout, full disk) must not discard the
        // batch's computed results or mask a scenario failure — degrade
        // to uncached behavior with a warning.
        if let Err(e) = c.flush() {
            eprintln!("warning: scenario result cache not persisted: {e}");
        }
    }
    // Tiering fleet members sharing a trace key reused one immutable
    // snapshot from the process-global epoch-trace store during this
    // batch; with the batch done nobody holds those Arcs anymore, so
    // release idle snapshots down to the store's watermark (the hard
    // budget bound lives in `TraceStore::get`'s insert-time eviction).
    crate::workloads::trace::global().trim();
    if let Some(e) = first_err {
        return Err(e);
    }
    // Resolve duplicate slots from their representatives.
    for i in 0..slots.len() {
        if slots[i].is_none() {
            let resolved = slots[rep_of[i]].clone();
            slots[i] = resolved;
        }
    }
    Ok(slots
        .into_iter()
        .map(|s| s.expect("every non-hit slot was evaluated or resolved"))
        .collect())
}

/// One raw evaluation with the batch instrumentation attached. Errors
/// keep their cause chain intact (no stringification) so the
/// supervision layer can classify transient IO failures; callers that
/// surface the error attach the scenario name themselves. The two
/// fault points are where the chaos harness injects per-spec failures:
/// `scenario.eval` (panic/delay) and `scenario.eval.io` (synthetic IO
/// errors), both keyed by the spec name.
pub(crate) fn eval_raw(spec: &ScenarioSpec) -> Result<ScenarioResult> {
    let m = batch_metrics();
    let _in_flight = metrics::GaugeGuard::enter(m.jobs_in_flight);
    m.eval_ns.time(|| {
        fault::point("scenario.eval", &spec.name);
        fault::io_point("scenario.eval.io", &spec.name)?;
        evaluate(spec).map(|report| result_doc(spec, &report))
    })
}

/// Parse a text blob into raw documents: either one JSON document or
/// JSONL (one document per line, as `scenario expand` emits). The
/// whole-blob parse is strict ([`Json::parse`] rejects trailing
/// content), so a multi-line JSONL input can never be mistaken for —
/// and silently truncated to — its first document.
pub fn docs_of(text: &str) -> Result<Vec<Json>> {
    match Json::parse(text) {
        Ok(doc) => Ok(vec![doc]),
        Err(_) => crate::util::json::parse_jsonl(text)
            .map_err(|e| anyhow!("input is neither a JSON document nor JSONL: {e}")),
    }
}

/// Parse scenario documents out of a text blob (via [`docs_of`]).
/// Fleet/sweep templates are rejected with a pointer at `expand`.
pub fn parse_docs(text: &str) -> Result<Vec<ScenarioSpec>> {
    let docs = docs_of(text)?;
    for doc in &docs {
        if super::expand::is_template(doc) {
            return Err(anyhow!(
                "document is a fleet/sweep template — run `cxlmem scenario expand` first"
            ));
        }
    }
    docs.iter().map(ScenarioSpec::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::to_jsonl;

    fn specs(texts: &[&str]) -> Vec<ScenarioSpec> {
        texts
            .iter()
            .map(|t| ScenarioSpec::parse(&Json::parse(t).unwrap()).unwrap())
            .collect()
    }

    #[test]
    fn batch_preserves_order_and_is_jobs_invariant() {
        let s = specs(&[
            r#"{"name": "one", "experiment": "table1", "workload": {"kind": "table1"},
                "systems": ["A", "B", "C"]}"#,
            r#"{"name": "two", "workload": {"kind": "objects",
                "objects": [{"name": "a", "gb": 4, "pattern": "sequential", "scans": 2}],
                "policies": ["ldram-preferred", "cxl-preferred"], "oli_search": false}}"#,
        ]);
        let seq = run_batch(&s, 1).unwrap();
        let par = run_batch(&s, 4).unwrap();
        assert_eq!(seq.len(), 2);
        assert_eq!(seq[0].name, "one");
        assert_eq!(seq[0].experiment.as_deref(), Some("table1"));
        let a = to_jsonl(seq.iter().map(|r| r.doc.clone()));
        let b = to_jsonl(par.iter().map(|r| r.doc.clone()));
        assert_eq!(a, b, "results must not depend on --jobs");
        // Result lines parse back and carry the scenario name + tables.
        let docs = crate::util::json::parse_jsonl(&a).unwrap();
        assert_eq!(docs[1].get("scenario").unwrap().as_str(), Some("two"));
        assert!(docs[0].get("tables").unwrap().as_arr().unwrap().len() == 1);
    }

    #[test]
    fn parse_docs_accepts_json_and_jsonl() {
        let one = r#"{"name": "x", "workload": {"kind": "table1"}}"#;
        assert_eq!(parse_docs(one).unwrap().len(), 1);
        let two = format!("{one}\n{}\n", r#"{"name": "y", "workload": {"kind": "hpc-table"}}"#);
        let parsed = parse_docs(&two).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[1].name, "y");
        assert!(parse_docs("not json").is_err());
        // Templates must point the user at `expand`, not fail obscurely.
        let err = parse_docs(r#"{"name": "f", "fleet": {"count": 2}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expand"), "{err}");
    }

    /// Pins the strictness `docs_of` relies on: the whole-blob parse
    /// must reject a JSONL input (trailing content after the first
    /// document) rather than tolerate it — a tolerant parser would
    /// silently truncate a fleet to its first scenario.
    #[test]
    fn docs_of_never_truncates_jsonl_input() {
        let a = r#"{"name": "a", "workload": {"kind": "table1"}}"#;
        let b = r#"{"name": "b", "workload": {"kind": "hpc-table"}}"#;
        // The underlying parser rejects trailing content outright.
        assert!(Json::parse(&format!("{a}\n{b}")).is_err());
        // So docs_of must yield every document, never just the first.
        for text in [
            format!("{a}\n{b}"),
            format!("{a}\n{b}\n"),
            format!("{a}\n\n{b}\n"),
        ] {
            let docs = docs_of(&text).unwrap();
            assert_eq!(docs.len(), 2, "JSONL was truncated: {text:?}");
            assert_eq!(docs[1].get("name").unwrap().as_str(), Some("b"));
        }
        // A single document with surrounding whitespace stays one doc.
        assert_eq!(docs_of(&format!("  {a}\n")).unwrap().len(), 1);
    }

    #[test]
    fn batch_surfaces_failures_with_name() {
        // 'doomed' parses — a socket index is plain data at parse time —
        // but fails at eval: socket 7 does not exist on system A. The
        // batch must abort with the scenario's name attached.
        let s = specs(&[
            r#"{"name": "fine", "workload": {"kind": "hpc-table"}}"#,
            r#"{"name": "doomed", "workload": {"kind": "objects", "socket": 7,
                "objects": [{"name": "a", "gb": 1}], "oli_search": false}}"#,
        ]);
        let err = run_batch(&s, 2).unwrap_err().to_string();
        assert!(err.contains("scenario 'doomed' failed"), "{err}");
        assert!(err.contains("socket 7"), "{err}");
        // The empty batch stays a no-op.
        assert!(run_batch(&[], 4).unwrap().is_empty());
    }

    #[test]
    fn cache_serves_second_run_without_evaluation() {
        use crate::scenario::cache::ResultCache;

        let s = specs(&[
            r#"{"name": "one", "workload": {"kind": "table1"}, "systems": ["A", "B"]}"#,
            r#"{"name": "two", "workload": {"kind": "hpc-table"}}"#,
        ]);
        let dir = std::env::temp_dir().join(format!("cxlmem-batch-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut cold = ResultCache::open(&dir).unwrap();
        let r1 = run_batch_cached(&s, 2, Some(&mut cold)).unwrap();
        assert_eq!((cold.hits(), cold.misses()), (0, 2));

        // A fresh open reloads from disk; the warm batch must be pure
        // cache reads (miss probe == 0 ⇒ evaluate was never called) and
        // byte-identical JSONL at a different --jobs.
        let mut warm = ResultCache::open(&dir).unwrap();
        let r2 = run_batch_cached(&s, 4, Some(&mut warm)).unwrap();
        assert_eq!((warm.hits(), warm.misses()), (2, 0));
        let a = to_jsonl(r1.into_iter().map(|r| r.doc));
        let b = to_jsonl(r2.into_iter().map(|r| r.doc));
        assert_eq!(a, b, "cache hits must not change the output bytes");

        // A changed spec is a different key: only it re-evaluates.
        let s2 = specs(&[
            r#"{"name": "one", "workload": {"kind": "table1"}, "systems": ["A", "B", "C"]}"#,
            r#"{"name": "two", "workload": {"kind": "hpc-table"}}"#,
        ]);
        let mut mixed = ResultCache::open(&dir).unwrap();
        let r3 = run_batch_cached(&s2, 2, Some(&mut mixed)).unwrap();
        assert_eq!((mixed.hits(), mixed.misses()), (1, 1));
        assert_eq!(r3.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Duplicate specs in one batch evaluate once: the cold cache sees a
    /// single probe and stores a single entry, yet every input slot is
    /// filled, in input order, with the representative's document.
    #[test]
    fn duplicate_specs_in_a_batch_evaluate_once() {
        use crate::scenario::cache::ResultCache;

        let x = r#"{"name": "x", "workload": {"kind": "hpc-table"}}"#;
        let y = r#"{"name": "y", "workload": {"kind": "table1"}}"#;
        let s = specs(&[x, y, x, x]);
        let dir = std::env::temp_dir().join(format!("cxlmem-batch-dedupe-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut cold = ResultCache::open(&dir).unwrap();
        let r = run_batch_cached(&s, 2, Some(&mut cold)).unwrap();
        // Two *distinct* specs probed (and missed); only they evaluated
        // and only they were stored — the duplicates rode along.
        assert_eq!((cold.hits(), cold.misses()), (0, 2));
        assert_eq!(cold.len(), 2);
        assert_eq!(r.len(), 4, "every input slot must be filled");
        assert_eq!(r[0].name, "x");
        assert_eq!(r[1].name, "y");
        assert_eq!(r[2].name, "x");
        assert_eq!(r[0].doc, r[2].doc);
        assert_eq!(r[0].doc, r[3].doc);

        // Uncached batches dedupe the same way (order preserved).
        let plain = run_batch(&s, 2).unwrap();
        let a = to_jsonl(r.into_iter().map(|r| r.doc));
        let b = to_jsonl(plain.into_iter().map(|r| r.doc));
        assert_eq!(a, b, "dedupe must not change the output bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The tentpole behavior: under supervision a panicking spec fills
    /// its slot with a validated `cxlmem-result-error-v1` document while
    /// every sibling completes normally — and the error is never cached,
    /// so a fault-free re-run over the same store retries exactly the
    /// failed slot and comes back clean.
    #[test]
    fn supervised_batch_isolates_panics_into_error_docs() {
        use crate::scenario::cache::ResultCache;
        use crate::scenario::supervise::{validate_error_doc, ERROR_SCHEMA};
        use crate::util::fault;

        let s = specs(&[
            r#"{"name": "bat-sup-healthy-a", "workload": {"kind": "hpc-table"}}"#,
            r#"{"name": "bat-sup-victim", "workload": {"kind": "table1"}}"#,
            r#"{"name": "bat-sup-healthy-b", "workload": {"kind": "hpc-table"}}"#,
        ]);
        let dir = std::env::temp_dir().join(format!("cxlmem-batch-sup-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("scenario.eval/bat-sup-victim=panic").unwrap());
        let mut cold = ResultCache::open(&dir).unwrap();
        let opts = crate::scenario::supervise::SuperviseOpts {
            shard: Some("1/1".to_string()),
            ..Default::default()
        };
        let r = run_batch_supervised(&s, 2, Some(&mut cold), &opts)
            .expect("supervision must not abort the fleet");
        fault::clear();

        assert_eq!(r.len(), 3, "every slot filled, error or not");
        assert_eq!(r[0].doc.get("scenario").unwrap().as_str(), Some("bat-sup-healthy-a"));
        assert_eq!(r[2].doc.get("scenario").unwrap().as_str(), Some("bat-sup-healthy-b"));
        let err = &r[1].doc;
        assert_eq!(err.get("schema").unwrap().as_str(), Some(ERROR_SCHEMA));
        validate_error_doc(err).unwrap();
        assert_eq!(err.get("scenario").unwrap().as_str(), Some("bat-sup-victim"));
        assert_eq!(err.get("error").unwrap().as_str(), Some("panic"));
        assert_eq!(err.get("key").unwrap().as_str().map(str::len), Some(16));
        assert_eq!(err.get("shard").unwrap().as_str(), Some("1/1"));
        assert_eq!(cold.len(), 2, "the error document must never be cached");

        // Fault-free re-run over the same store: the two healthy specs
        // are pure hits, only the victim re-evaluates — and succeeds, so
        // the output carries no error documents at all.
        let mut warm = ResultCache::open(&dir).unwrap();
        let r2 = run_batch_supervised(&s, 2, Some(&mut warm), &opts).unwrap();
        assert_eq!((warm.hits(), warm.misses()), (2, 1));
        assert!(
            r2.iter().all(|x| x.doc.get("schema").is_none()),
            "clean re-run must emit no error docs"
        );
        assert_eq!(r2[1].doc.get("scenario").unwrap().as_str(), Some("bat-sup-victim"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Transient IO faults burn retries, not the batch: a rule limited
    /// to fewer fires than the retry budget ends in success with no
    /// error document in the output.
    #[test]
    fn supervised_batch_retries_transient_io_to_success() {
        use crate::util::fault;

        let s = specs(&[
            r#"{"name": "bat-flaky-io-spec", "workload": {"kind": "hpc-table"}}"#,
            r#"{"name": "bat-flaky-io-peer", "workload": {"kind": "table1"}}"#,
        ]);
        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("scenario.eval.io/bat-flaky-io-spec=io:2").unwrap());
        let opts = crate::scenario::supervise::SuperviseOpts {
            retries: 2,
            backoff_ms: 1,
            ..Default::default()
        };
        let r = run_batch_supervised(&s, 2, None, &opts).unwrap();
        assert_eq!(fault::fired("scenario.eval.io"), 2, "both injected fires consumed");
        fault::clear();
        assert!(r.iter().all(|x| x.doc.get("schema").is_none()), "no error docs");
        assert!(r.iter().all(|x| x.doc.get("tables").is_some()));
    }

    /// The single-distinct-miss inline fast path restores the session's
    /// jobs clamp even when evaluation fails (and, via the RAII guard in
    /// `perf::with_jobs`, even if it panics).
    #[test]
    fn inline_fast_path_restores_jobs_on_failure() {
        crate::perf::set_jobs(3);
        let s = specs(&[r#"{"name": "doomed", "workload": {"kind": "objects", "socket": 7,
            "objects": [{"name": "a", "gb": 1}], "oli_search": false}}"#]);
        assert!(run_batch(&s, 8).is_err());
        assert_eq!(crate::perf::current_jobs(), 3, "jobs left clamped");
        crate::perf::set_jobs(1);
    }
}
