//! Batched scenario evaluation: shard expanded scenarios across the
//! [`crate::util::par`] executor and stream per-scenario results as JSON
//! lines.
//!
//! Sharding notes: workers inherit the session's perf context with inner
//! `jobs` pinned to 1, so a batch never oversubscribes; each worker's
//! thread-local solver memo cache dedupes the repeated traffic solves a
//! fleet poses (same device profiles × near-identical stream descriptors
//! — see the quantized admission in `memsim::system`). Results come back
//! in input order, so a batch's JSONL output is deterministic at any
//! `--jobs`.
//!
//! [`run_batch_cached`] layers the persistent result cache
//! ([`super::cache`]) in front of evaluation: specs are keyed by their
//! canonical content hash, hits skip evaluation entirely, and only the
//! misses are scheduled — fleet re-runs and overlapping sweeps become
//! cache reads while the emitted JSONL stays byte-identical.

use anyhow::{anyhow, Result};

use super::cache::ResultCache;
use super::eval::evaluate;
use super::spec::ScenarioSpec;
use crate::report::Report;
use crate::util::json::Json;
use crate::util::par::par_map;

/// One evaluated scenario, ready for JSONL emission.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub name: String,
    pub experiment: Option<String>,
    pub doc: Json,
}

/// Build the JSONL result document for one evaluated scenario.
pub fn result_doc(spec: &ScenarioSpec, report: &Report) -> ScenarioResult {
    let mut doc = Json::obj(vec![
        ("scenario", spec.name.as_str().into()),
        // Canonical system specs (incl. device overrides) so result
        // lines stay joinable to their device profiles on their own.
        (
            "systems",
            Json::arr(spec.systems.iter().map(|s| s.to_json())),
        ),
    ]);
    if let Some(e) = &spec.experiment {
        doc.set("experiment", e.as_str().into());
    }
    if let Some(tables) = report.to_json().get("tables") {
        doc.set("tables", tables.clone());
    }
    ScenarioResult {
        name: spec.name.clone(),
        experiment: spec.experiment.clone(),
        doc,
    }
}

/// Evaluate a batch over up to `jobs` worker threads, preserving input
/// order. A single-scenario batch runs inline with the whole `jobs`
/// budget handed to the scenario's *inner* sweeps instead (the fig16
/// grid path); larger batches shard scenarios across workers, whose
/// inner sweeps stay sequential. The first failing scenario aborts the
/// batch with its name attached.
pub fn run_batch(specs: &[ScenarioSpec], jobs: usize) -> Result<Vec<ScenarioResult>> {
    run_batch_cached(specs, jobs, None)
}

/// [`run_batch`] with an optional content-addressed result cache: specs
/// whose canonical hash is already stored are served without evaluation,
/// only the misses are scheduled, and newly evaluated results are
/// appended to the store. Results keep input order whatever mix of hits
/// and misses a batch is, so the JSONL output stays byte-identical to an
/// uncached run at any `--jobs` — the cache changes cost, never results.
/// A batch that reduces to a single miss keeps the inline fast path (the
/// whole `jobs` budget goes to that scenario's inner sweeps).
pub fn run_batch_cached(
    specs: &[ScenarioSpec],
    jobs: usize,
    mut cache: Option<&mut ResultCache>,
) -> Result<Vec<ScenarioResult>> {
    // Probe the cache in input order; slots hold hits, keys carry the
    // (key, canonical spec) pair for the post-evaluation inserts.
    let mut slots: Vec<Option<ScenarioResult>> = Vec::with_capacity(specs.len());
    let mut keys: Vec<Option<(String, String)>> = Vec::with_capacity(specs.len());
    for spec in specs {
        match cache.as_mut() {
            Some(c) => {
                let (key, canon) = spec.cache_identity();
                let hit = c.lookup(&key, &canon).map(|doc| ScenarioResult {
                    name: spec.name.clone(),
                    experiment: spec.experiment.clone(),
                    doc: doc.clone(),
                });
                keys.push(Some((key, canon)));
                slots.push(hit);
            }
            None => {
                keys.push(None);
                slots.push(None);
            }
        }
    }
    let miss_idx: Vec<usize> = (0..specs.len()).filter(|&i| slots[i].is_none()).collect();

    let evaluated: Vec<Result<ScenarioResult>> = if miss_idx.len() == 1 {
        let prev = crate::perf::current_jobs();
        crate::perf::set_jobs(jobs.max(1));
        let r = eval_one(&specs[miss_idx[0]]);
        crate::perf::set_jobs(prev);
        vec![r]
    } else {
        let miss_specs: Vec<&ScenarioSpec> = miss_idx.iter().map(|&i| &specs[i]).collect();
        par_map(&miss_specs, jobs, |spec| eval_one(spec))
    };

    // Fill the slots, keeping the first failure (input order) but still
    // flushing whatever completed before it — a failing fleet member
    // doesn't throw away its siblings' work on the next run.
    let mut first_err = None;
    for (&i, r) in miss_idx.iter().zip(evaluated) {
        match r {
            Ok(result) => {
                if let (Some(c), Some((key, canon))) = (cache.as_mut(), &keys[i]) {
                    c.insert(key.clone(), canon.clone(), &result);
                }
                slots[i] = Some(result);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(c) = cache.as_mut() {
        // The cache changes cost, never results: a store that cannot be
        // written (read-only checkout, full disk) must not discard the
        // batch's computed results or mask a scenario failure — degrade
        // to uncached behavior with a warning.
        if let Err(e) = c.flush() {
            eprintln!("warning: scenario result cache not persisted: {e}");
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(slots
        .into_iter()
        .map(|s| s.expect("every non-hit slot was evaluated"))
        .collect())
}

fn eval_one(spec: &ScenarioSpec) -> Result<ScenarioResult> {
    evaluate(spec)
        .map(|report| result_doc(spec, &report))
        .map_err(|e| anyhow!("scenario '{}' failed: {e}", spec.name))
}

/// Parse a text blob into raw documents: either one JSON document or
/// JSONL (one document per line, as `scenario expand` emits).
pub fn docs_of(text: &str) -> Result<Vec<Json>> {
    match Json::parse(text) {
        Ok(doc) => Ok(vec![doc]),
        Err(_) => crate::util::json::parse_jsonl(text)
            .map_err(|e| anyhow!("input is neither a JSON document nor JSONL: {e}")),
    }
}

/// Parse scenario documents out of a text blob (via [`docs_of`]).
/// Fleet/sweep templates are rejected with a pointer at `expand`.
pub fn parse_docs(text: &str) -> Result<Vec<ScenarioSpec>> {
    let docs = docs_of(text)?;
    for doc in &docs {
        if super::expand::is_template(doc) {
            return Err(anyhow!(
                "document is a fleet/sweep template — run `cxlmem scenario expand` first"
            ));
        }
    }
    docs.iter().map(ScenarioSpec::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::to_jsonl;

    fn specs(texts: &[&str]) -> Vec<ScenarioSpec> {
        texts
            .iter()
            .map(|t| ScenarioSpec::parse(&Json::parse(t).unwrap()).unwrap())
            .collect()
    }

    #[test]
    fn batch_preserves_order_and_is_jobs_invariant() {
        let s = specs(&[
            r#"{"name": "one", "experiment": "table1", "workload": {"kind": "table1"},
                "systems": ["A", "B", "C"]}"#,
            r#"{"name": "two", "workload": {"kind": "objects",
                "objects": [{"name": "a", "gb": 4, "pattern": "sequential", "scans": 2}],
                "policies": ["ldram-preferred", "cxl-preferred"], "oli_search": false}}"#,
        ]);
        let seq = run_batch(&s, 1).unwrap();
        let par = run_batch(&s, 4).unwrap();
        assert_eq!(seq.len(), 2);
        assert_eq!(seq[0].name, "one");
        assert_eq!(seq[0].experiment.as_deref(), Some("table1"));
        let a = to_jsonl(seq.iter().map(|r| r.doc.clone()));
        let b = to_jsonl(par.iter().map(|r| r.doc.clone()));
        assert_eq!(a, b, "results must not depend on --jobs");
        // Result lines parse back and carry the scenario name + tables.
        let docs = crate::util::json::parse_jsonl(&a).unwrap();
        assert_eq!(docs[1].get("scenario").unwrap().as_str(), Some("two"));
        assert!(docs[0].get("tables").unwrap().as_arr().unwrap().len() == 1);
    }

    #[test]
    fn parse_docs_accepts_json_and_jsonl() {
        let one = r#"{"name": "x", "workload": {"kind": "table1"}}"#;
        assert_eq!(parse_docs(one).unwrap().len(), 1);
        let two = format!("{one}\n{}\n", r#"{"name": "y", "workload": {"kind": "hpc-table"}}"#);
        let parsed = parse_docs(&two).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[1].name, "y");
        assert!(parse_docs("not json").is_err());
        // Templates must point the user at `expand`, not fail obscurely.
        let err = parse_docs(r#"{"name": "f", "fleet": {"count": 2}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("expand"), "{err}");
    }

    #[test]
    fn batch_surfaces_failures_with_name() {
        // 'doomed' parses — a socket index is plain data at parse time —
        // but fails at eval: socket 7 does not exist on system A. The
        // batch must abort with the scenario's name attached.
        let s = specs(&[
            r#"{"name": "fine", "workload": {"kind": "hpc-table"}}"#,
            r#"{"name": "doomed", "workload": {"kind": "objects", "socket": 7,
                "objects": [{"name": "a", "gb": 1}], "oli_search": false}}"#,
        ]);
        let err = run_batch(&s, 2).unwrap_err().to_string();
        assert!(err.contains("scenario 'doomed' failed"), "{err}");
        assert!(err.contains("socket 7"), "{err}");
        // The empty batch stays a no-op.
        assert!(run_batch(&[], 4).unwrap().is_empty());
    }

    #[test]
    fn cache_serves_second_run_without_evaluation() {
        use crate::scenario::cache::ResultCache;

        let s = specs(&[
            r#"{"name": "one", "workload": {"kind": "table1"}, "systems": ["A", "B"]}"#,
            r#"{"name": "two", "workload": {"kind": "hpc-table"}}"#,
        ]);
        let dir = std::env::temp_dir().join(format!("cxlmem-batch-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut cold = ResultCache::open(&dir).unwrap();
        let r1 = run_batch_cached(&s, 2, Some(&mut cold)).unwrap();
        assert_eq!((cold.hits(), cold.misses()), (0, 2));

        // A fresh open reloads from disk; the warm batch must be pure
        // cache reads (miss probe == 0 ⇒ evaluate was never called) and
        // byte-identical JSONL at a different --jobs.
        let mut warm = ResultCache::open(&dir).unwrap();
        let r2 = run_batch_cached(&s, 4, Some(&mut warm)).unwrap();
        assert_eq!((warm.hits(), warm.misses()), (2, 0));
        let a = to_jsonl(r1.into_iter().map(|r| r.doc));
        let b = to_jsonl(r2.into_iter().map(|r| r.doc));
        assert_eq!(a, b, "cache hits must not change the output bytes");

        // A changed spec is a different key: only it re-evaluates.
        let s2 = specs(&[
            r#"{"name": "one", "workload": {"kind": "table1"}, "systems": ["A", "B", "C"]}"#,
            r#"{"name": "two", "workload": {"kind": "hpc-table"}}"#,
        ]);
        let mut mixed = ResultCache::open(&dir).unwrap();
        let r3 = run_batch_cached(&s2, 2, Some(&mut mixed)).unwrap();
        assert_eq!((mixed.hits(), mixed.misses()), (1, 1));
        assert_eq!(r3.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
