//! Worker pool: cache probe → in-flight dedup → supervised evaluation.
//!
//! Each pool worker owns a [`StoreHandle`] clone, so the warm path — a
//! request whose spec is already in the layered store — is one atomic
//! tail load plus a cascade walk, no flock, no mutable cache borrow.
//! Cold requests are deduplicated *in flight*: the first worker to
//! claim a canonical spec evaluates it under the supervision envelope
//! ([`supervise::eval_supervised`]: `catch_unwind`, bounded retries,
//! cancellable deadlines) while identical requests park on a waiter
//! list and are answered from the same result — N clients probing the
//! same fleet cost one evaluation, not N.
//!
//! The daemon keeps its own atomic [`Counters`] (mirrored into the
//! metrics registry) so the `stats` verb stays exact even when
//! `CXLMEM_METRICS=0` collapses registry handles into shared nulls.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::scenario::cache::StoreHandle;
use crate::scenario::spec::ScenarioSpec;
use crate::scenario::supervise::{self, SuperviseOpts};
use crate::util::json::Json;
use crate::util::metrics;

use super::protocol::STATS_SCHEMA;
use super::queue::AdmissionQueue;

/// Delivers one response line back to the client that sent request
/// `seq` on its connection (implementations re-order into request
/// order; `line` includes the trailing newline).
pub(crate) trait Respond: Send + Sync {
    fn deliver(&self, seq: u64, line: String);
}

/// One admitted request: a spec plus where to send the answer.
pub(crate) struct Job {
    pub seq: u64,
    pub spec: ScenarioSpec,
    pub key: String,
    pub canon: String,
    pub reply: Arc<dyn Respond>,
}

/// The daemon's own live counters (registry-independent; see module doc).
#[derive(Default)]
pub(crate) struct Counters {
    pub requests: AtomicU64,
    pub evaluated: AtomicU64,
    pub hits: AtomicU64,
    pub dedup_inflight: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    pub connections: AtomicU64,
}

/// Increment a daemon counter and its `serve.*` registry mirror.
pub(crate) fn bump(field: &AtomicU64, mirror: &str) {
    field.fetch_add(1, Ordering::Relaxed);
    metrics::counter(mirror).inc();
}

/// State shared by the listener, connection handlers, and pool workers.
pub(crate) struct Shared {
    pub queue: AdmissionQueue<Job>,
    /// canonical spec → waiters parked on the in-flight evaluation.
    pub inflight: Mutex<HashMap<String, Vec<Job>>>,
    pub store: StoreHandle,
    pub opts: SuperviseOpts,
    pub counters: Counters,
    pub shutdown: AtomicBool,
}

fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn line_of(doc: &Json) -> String {
    format!("{doc}\n")
}

/// Pool worker body: drain the admission queue until it is closed and
/// empty. Inner sweeps run sequentially (`jobs = 1`) — parallelism
/// comes from the pool itself, like the batch runner's `par_map`
/// workers.
pub(crate) fn worker_loop(shared: Arc<Shared>) {
    crate::perf::set_jobs(1);
    let store = shared.store.clone();
    while let Some(job) = shared.queue.pop() {
        process(&shared, &store, job);
    }
}

/// Serve one job: probe the store, dedup against in-flight identical
/// requests, evaluate on miss, deliver to the owner and any waiters.
pub(crate) fn process(shared: &Shared, store: &StoreHandle, job: Job) {
    // Warm path: one lock-free layered-store lookup.
    if let Some(doc) = store.lookup(&job.key, &job.canon) {
        bump(&shared.counters.hits, "serve.hits");
        job.reply.deliver(job.seq, line_of(&doc));
        return;
    }
    // In-flight dedup: park on an identical evaluation if one is
    // already running; otherwise claim the canonical spec.
    {
        let mut inflight = lock(&shared.inflight);
        if let Some(waiters) = inflight.get_mut(&job.canon) {
            bump(&shared.counters.dedup_inflight, "serve.dedup_inflight");
            waiters.push(job);
            return;
        }
        inflight.insert(job.canon.clone(), Vec::new());
    }
    bump(&shared.counters.evaluated, "serve.evaluated");
    let doc = match supervise::eval_supervised(&job.spec, &job.key, &shared.opts) {
        Ok(result) => {
            // Publish before releasing the claim: a duplicate that
            // misses the waiter list finds the store entry instead.
            store.insert(&job.key, job.canon.clone(), &result);
            result.doc
        }
        Err(failure) => {
            bump(&shared.counters.errors, "serve.errors");
            supervise::error_doc(
                &job.spec.name,
                &job.key,
                &failure,
                shared.opts.shard.as_deref(),
            )
        }
    };
    let waiters = lock(&shared.inflight).remove(&job.canon).unwrap_or_default();
    let line = line_of(&doc);
    for w in &waiters {
        w.reply.deliver(w.seq, line.clone());
    }
    job.reply.deliver(job.seq, line);
}

/// Build the `stats` verb's response: daemon counters, queue state, and
/// per-policy evaluation-latency quantiles from the metrics registry.
pub(crate) fn stats_doc(shared: &Shared) -> Json {
    let c = &shared.counters;
    let requests = c.requests.load(Ordering::Relaxed);
    let hits = c.hits.load(Ordering::Relaxed);
    let mut eval = std::collections::BTreeMap::new();
    let snap = metrics::snapshot();
    if let Some(hists) = snap.get("histograms").and_then(Json::as_obj) {
        for (name, h) in hists {
            let Some(policy) = name
                .strip_prefix("eval.policy.")
                .and_then(|p| p.strip_suffix(".ns"))
            else {
                continue;
            };
            eval.insert(
                policy.to_string(),
                Json::obj(vec![
                    ("count", h.get("count").cloned().unwrap_or_else(|| 0u64.into())),
                    ("p50_ns", h.get("p50").cloned().unwrap_or_else(|| 0u64.into())),
                    ("p90_ns", h.get("p90").cloned().unwrap_or_else(|| 0u64.into())),
                ]),
            );
        }
    }
    Json::obj(vec![
        ("schema", STATS_SCHEMA.into()),
        ("requests", requests.into()),
        ("evaluated", c.evaluated.load(Ordering::Relaxed).into()),
        ("hits", hits.into()),
        (
            "dedup_inflight",
            c.dedup_inflight.load(Ordering::Relaxed).into(),
        ),
        ("rejected", c.rejected.load(Ordering::Relaxed).into()),
        ("errors", c.errors.load(Ordering::Relaxed).into()),
        ("connections", c.connections.load(Ordering::Relaxed).into()),
        ("hit_rate", (hits as f64 / requests.max(1) as f64).into()),
        (
            "queue",
            Json::obj(vec![
                ("depth", (shared.queue.depth() as u64).into()),
                ("hwm", (shared.queue.high_water() as u64).into()),
                ("capacity", (shared.queue.capacity() as u64).into()),
            ]),
        ),
        ("eval_policy_ns", Json::Obj(eval)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::cache::ResultCache;

    struct MockReply(Mutex<Vec<(u64, String)>>);

    impl Respond for MockReply {
        fn deliver(&self, seq: u64, line: String) {
            lock(&self.0).push((seq, line));
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cxlmem-serve-worker-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn shared_for(dir: &std::path::Path) -> (Shared, ResultCache) {
        let cache = ResultCache::open(dir).unwrap();
        let shared = Shared {
            queue: AdmissionQueue::new(8),
            inflight: Mutex::new(HashMap::new()),
            store: cache.handle(),
            opts: SuperviseOpts::default(),
            counters: Counters::default(),
            shutdown: AtomicBool::new(false),
        };
        (shared, cache)
    }

    fn job_for(spec_text: &str, seq: u64, reply: &Arc<MockReply>) -> Job {
        let spec = ScenarioSpec::parse(&Json::parse(spec_text).unwrap()).unwrap();
        let (key, canon) = spec.cache_identity();
        Job {
            seq,
            spec,
            key,
            canon,
            reply: Arc::clone(reply) as Arc<dyn Respond>,
        }
    }

    #[test]
    fn miss_evaluates_then_hit_serves_from_store() {
        let dir = tmp_dir("hit");
        let (shared, _cache) = shared_for(&dir);
        let store = shared.store.clone();
        let reply = Arc::new(MockReply(Mutex::new(Vec::new())));
        let text = r#"{"name": "w-hit", "workload": {"kind": "hpc-table"}}"#;
        process(&shared, &store, job_for(text, 0, &reply));
        process(&shared, &store, job_for(text, 1, &reply));
        let delivered = lock(&reply.0).clone();
        assert_eq!(delivered.len(), 2);
        assert_eq!(delivered[0].0, 0);
        assert_eq!(delivered[1].0, 1);
        assert_eq!(
            delivered[0].1, delivered[1].1,
            "hit must be byte-identical to the evaluated line"
        );
        assert_eq!(shared.counters.evaluated.load(Ordering::Relaxed), 1);
        assert_eq!(shared.counters.hits.load(Ordering::Relaxed), 1);
        assert!(lock(&shared.inflight).is_empty(), "claims must be released");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_parks_on_the_inflight_claim() {
        let dir = tmp_dir("dedup");
        let (shared, _cache) = shared_for(&dir);
        let store = shared.store.clone();
        let reply = Arc::new(MockReply(Mutex::new(Vec::new())));
        let text = r#"{"name": "w-dup", "workload": {"kind": "hpc-table"}}"#;
        let dup = job_for(text, 1, &reply);
        let canon = dup.canon.clone();
        // Simulate an in-flight owner by claiming the canonical spec,
        // then route a duplicate through the worker: it must park on the
        // waiter list, unanswered and unevaluated.
        lock(&shared.inflight).insert(canon.clone(), Vec::new());
        process(&shared, &store, dup);
        assert!(
            lock(&reply.0).is_empty(),
            "a parked duplicate must not be answered yet"
        );
        assert_eq!(shared.counters.dedup_inflight.load(Ordering::Relaxed), 1);
        assert_eq!(shared.counters.evaluated.load(Ordering::Relaxed), 0);
        let waiters = lock(&shared.inflight).remove(&canon).unwrap();
        assert_eq!(waiters.len(), 1);
        assert_eq!(waiters[0].seq, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_identical_requests_cost_one_evaluation() {
        // End-to-end dedup: two workers race on the same spec; the
        // injected 300ms eval delay forces overlap. Exactly one
        // evaluation runs and both clients get byte-identical lines
        // (the second either parks in flight or hits the store).
        use crate::util::fault;
        let _g = fault::test_guard();
        fault::install(fault::FaultPlan::parse("scenario.eval/w-race=delay:300").unwrap());
        let dir = tmp_dir("race");
        let (shared, _cache) = shared_for(&dir);
        let store = shared.store.clone();
        let reply = Arc::new(MockReply(Mutex::new(Vec::new())));
        let text = r#"{"name": "w-race", "workload": {"kind": "hpc-table"}}"#;
        let a = job_for(text, 0, &reply);
        let b = job_for(text, 1, &reply);
        std::thread::scope(|s| {
            let shared = &shared;
            let store_a = shared.store.clone();
            s.spawn(move || process(shared, &store_a, a));
            std::thread::sleep(std::time::Duration::from_millis(60));
            process(shared, &store, b);
        });
        fault::clear();
        let delivered = lock(&reply.0).clone();
        assert_eq!(delivered.len(), 2, "both requests must be answered");
        assert_eq!(delivered[0].1, delivered[1].1, "identical answers");
        assert_eq!(shared.counters.evaluated.load(Ordering::Relaxed), 1);
        assert!(lock(&shared.inflight).is_empty(), "claims must be released");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failures_deliver_error_docs_and_are_not_cached() {
        let dir = tmp_dir("err");
        let (shared, _cache) = shared_for(&dir);
        let store = shared.store.clone();
        let reply = Arc::new(MockReply(Mutex::new(Vec::new())));
        // socket 7 fails deterministically at eval time.
        let text = r#"{"name": "w-doomed", "workload": {"kind": "objects", "socket": 7,
                       "objects": [{"name": "a", "gb": 1}], "oli_search": false}}"#;
        let job = job_for(text, 0, &reply);
        let (key, canon) = (job.key.clone(), job.canon.clone());
        process(&shared, &store, job);
        let delivered = lock(&reply.0).clone();
        assert_eq!(delivered.len(), 1);
        let doc = Json::parse(delivered[0].1.trim()).unwrap();
        supervise::validate_error_doc(&doc).unwrap();
        assert_eq!(shared.counters.errors.load(Ordering::Relaxed), 1);
        assert!(
            store.lookup(&key, &canon).is_none(),
            "error documents must never be cached"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_doc_validates_and_counts() {
        let dir = tmp_dir("stats");
        let (shared, _cache) = shared_for(&dir);
        let store = shared.store.clone();
        let reply = Arc::new(MockReply(Mutex::new(Vec::new())));
        let text = r#"{"name": "w-stats", "workload": {"kind": "hpc-table"}}"#;
        bump(&shared.counters.requests, "serve.requests");
        bump(&shared.counters.requests, "serve.requests");
        process(&shared, &store, job_for(text, 0, &reply));
        process(&shared, &store, job_for(text, 1, &reply));
        let doc = stats_doc(&shared);
        super::super::protocol::validate_stats_doc(&doc).unwrap();
        assert_eq!(doc.get("requests").and_then(Json::as_u64), Some(2));
        assert_eq!(doc.get("evaluated").and_then(Json::as_u64), Some(1));
        assert_eq!(doc.get("hits").and_then(Json::as_u64), Some(1));
        assert_eq!(doc.get("hit_rate").and_then(Json::as_f64), Some(0.5));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
