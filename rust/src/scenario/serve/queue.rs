//! Bounded admission queue: the daemon's backpressure point.
//!
//! Connection handlers admit work with a non-blocking [`AdmissionQueue::try_push`]
//! — a full queue hands the item straight back so the handler can answer
//! with a queue-full error document instead of stalling the socket.
//! Pool workers block in [`AdmissionQueue::pop`] until work arrives or
//! the queue is closed for shutdown (drain semantics: close stops
//! *admission*; already-queued items are still handed out until empty).
//!
//! Depth is mirrored into the `serve.queue_depth` registry gauge on
//! every transition; the queue also tracks its own high-water mark so
//! the `stats` verb stays exact when the registry is disabled
//! (`CXLMEM_METRICS=0` collapses registry handles into shared nulls).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

use crate::util::metrics;

/// Bounded MPMC queue with close-to-drain shutdown.
pub struct AdmissionQueue<T> {
    inner: Mutex<Inner<T>>,
    ready: Condvar,
    cap: usize,
    hwm: AtomicUsize,
    depth_gauge: &'static metrics::Gauge,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> AdmissionQueue<T> {
    /// A queue admitting at most `cap` (≥ 1) items at a time.
    pub fn new(cap: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
            cap: cap.max(1),
            hwm: AtomicUsize::new(0),
            depth_gauge: metrics::gauge("serve.queue_depth"),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Admit `item` without blocking. A full or closed queue returns the
    /// item back (`Err`) so the caller can reject it explicitly.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut q = self.lock();
        if q.closed || q.items.len() >= self.cap {
            return Err(item);
        }
        q.items.push_back(item);
        let depth = q.items.len();
        drop(q);
        self.hwm.fetch_max(depth, Ordering::Relaxed);
        self.depth_gauge.set(depth as i64);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until an item is available (`Some`) or the queue is closed
    /// *and* drained (`None`) — a closed queue still hands out whatever
    /// was admitted before the close.
    pub fn pop(&self) -> Option<T> {
        let mut q = self.lock();
        loop {
            if let Some(item) = q.items.pop_front() {
                self.depth_gauge.set(q.items.len() as i64);
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = self.ready.wait(q).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Stop admitting; wake every blocked `pop` so workers can drain
    /// the remainder and exit.
    pub fn close(&self) {
        self.lock().closed = true;
        self.ready.notify_all();
    }

    /// Items currently queued (admitted, not yet popped).
    pub fn depth(&self) -> usize {
        self.lock().items.len()
    }

    /// Deepest the queue has ever been.
    pub fn high_water(&self) -> usize {
        self.hwm.load(Ordering::Relaxed)
    }

    /// The admission bound this queue was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_push_pop_fifo() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(3), "full queue hands the item back");
        assert_eq!(q.depth(), 2);
        assert_eq!(q.high_water(), 2);
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.depth(), 0);
        assert_eq!(q.high_water(), 2, "high-water mark never shrinks");
    }

    #[test]
    fn close_drains_then_releases_blocked_pops() {
        use std::sync::Arc;
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(8));
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(9), Err(9), "closed queue admits nothing");
        assert_eq!(q.pop(), Some(7), "close still drains queued items");
        assert_eq!(q.pop(), None);
        // A pop blocked *before* the close must wake and observe it.
        let q2: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(8));
        let waiter = {
            let q2 = Arc::clone(&q2);
            std::thread::spawn(move || q2.pop())
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        q2.close();
        assert_eq!(waiter.join().unwrap(), None);
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.try_push(1).unwrap();
        assert_eq!(q.try_push(2), Err(2));
    }
}
