//! Wire protocol for the serve daemon.
//!
//! Requests and responses are newline-delimited JSON over one Unix
//! domain socket connection. A request line is one of:
//!
//! - a **scenario spec** document (schema [`crate::scenario::SCHEMA`])
//!   — answered with the evaluated `cxlmem-scenario-v1` result document
//!   or a `cxlmem-result-error-v1` error document, byte-identical to
//!   what the batch runner would emit for the same spec;
//! - `{"verb": "stats"}` — answered with a [`STATS_SCHEMA`] counters
//!   snapshot;
//! - `{"verb": "shutdown"}` — answered with [`shutdown_ack`], then the
//!   daemon stops accepting, drains its queue, and exits.
//!
//! Responses are delivered **in request order** per connection, one
//! line per request line, whatever order the worker pool finishes in.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Schema identifier of the `stats` verb's response document.
pub const STATS_SCHEMA: &str = "cxlmem-serve-stats-v1";

/// One parsed request line.
pub enum Request {
    /// A scenario spec document to evaluate.
    Spec(Json),
    /// Live-counters snapshot request.
    Stats,
    /// Graceful drain-and-exit request.
    Shutdown,
}

/// Parse one request line. Anything that is valid JSON without a
/// `verb` field is treated as a spec document (validated at admission).
pub fn parse_request(line: &str) -> Result<Request> {
    let doc = Json::parse(line).map_err(|e| anyhow!("unparseable request line: {e}"))?;
    if let Some(verb) = doc.get("verb").and_then(Json::as_str) {
        return match verb {
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown verb '{other}' (want stats|shutdown)"),
        };
    }
    Ok(Request::Spec(doc))
}

/// The response to a `shutdown` request, sent before the drain begins.
pub fn shutdown_ack() -> Json {
    Json::obj(vec![("ok", true.into()), ("verb", "shutdown".into())])
}

/// Validate a parsed [`STATS_SCHEMA`] document — the gate tests and
/// scripted clients apply to `stats` responses.
pub fn validate_stats_doc(doc: &Json) -> Result<()> {
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == STATS_SCHEMA => {}
        Some(s) => bail!("schema is '{s}', want '{STATS_SCHEMA}'"),
        None => bail!("missing string field 'schema'"),
    }
    for field in [
        "requests",
        "evaluated",
        "hits",
        "dedup_inflight",
        "rejected",
        "errors",
        "connections",
    ] {
        if doc.get(field).and_then(Json::as_u64).is_none() {
            bail!("missing integer field '{field}'");
        }
    }
    let rate = doc
        .get("hit_rate")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing numeric field 'hit_rate'"))?;
    if !(0.0..=1.0).contains(&rate) {
        bail!("'hit_rate' must be in [0, 1] (got {rate})");
    }
    let queue = doc
        .get("queue")
        .ok_or_else(|| anyhow!("missing object field 'queue'"))?;
    for field in ["depth", "hwm", "capacity"] {
        if queue.get(field).and_then(Json::as_u64).is_none() {
            bail!("missing integer field 'queue.{field}'");
        }
    }
    if doc
        .get("eval_policy_ns")
        .and_then(Json::as_obj)
        .is_none()
    {
        bail!("missing object field 'eval_policy_ns'");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_three_request_shapes() {
        assert!(matches!(
            parse_request(r#"{"verb": "stats"}"#).unwrap(),
            Request::Stats
        ));
        assert!(matches!(
            parse_request(r#"{"verb": "shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
        match parse_request(r#"{"name": "f-000", "workload": {"kind": "hpc-table"}}"#).unwrap() {
            Request::Spec(doc) => {
                assert_eq!(doc.get("name").and_then(Json::as_str), Some("f-000"));
            }
            _ => panic!("spec documents must parse as Request::Spec"),
        }
    }

    #[test]
    fn rejects_garbage_and_unknown_verbs() {
        assert!(parse_request("not json").is_err());
        let err = parse_request(r#"{"verb": "explode"}"#).unwrap_err().to_string();
        assert!(err.contains("unknown verb"), "{err}");
    }

    #[test]
    fn shutdown_ack_is_stable() {
        assert_eq!(shutdown_ack().to_string(), r#"{"ok":true,"verb":"shutdown"}"#);
    }

    #[test]
    fn validate_stats_doc_checks_shape() {
        let good = Json::parse(
            r#"{"schema": "cxlmem-serve-stats-v1", "requests": 4, "evaluated": 2,
                "hits": 1, "dedup_inflight": 1, "rejected": 0, "errors": 0,
                "connections": 2, "hit_rate": 0.25,
                "queue": {"depth": 0, "hwm": 2, "capacity": 64},
                "eval_policy_ns": {}}"#,
        )
        .unwrap();
        validate_stats_doc(&good).unwrap();
        let mut wrong = good.clone();
        wrong.set("schema", "cxlmem-metrics-v1".into());
        assert!(validate_stats_doc(&wrong).is_err());
        let mut bad_rate = good.clone();
        bad_rate.set("hit_rate", 1.5.into());
        assert!(validate_stats_doc(&bad_rate).is_err());
        let mut no_queue = good.clone();
        no_queue.set("queue", Json::obj(vec![("depth", 0u64.into())]));
        assert!(validate_stats_doc(&no_queue).is_err());
    }
}
