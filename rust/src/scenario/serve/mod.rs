//! `cxlmem scenario serve` — a long-lived fleet-evaluation daemon.
//!
//! One-shot `scenario run` invocations pay process startup plus a cold
//! [`crate::workloads::trace::TraceStore`] and
//! [`crate::scenario::ResultCache`] on every request. The daemon
//! amortizes all three across thousands of requests: it opens the cache
//! directory once, keeps the trace store resident, and answers requests
//! over a Unix domain socket ([`protocol`]: JSONL in, JSONL out — spec
//! documents plus the `stats` and `shutdown` verbs).
//!
//! Architecture (one module per concern):
//!
//! - **listener** (this file): a non-blocking accept loop; each
//!   connection gets a reader thread and an in-request-order delivery
//!   sink. Chaos point `serve.accept` (key `conn-N`) drops exactly one
//!   connection. Between accepts the loop flushes the cache (sealing
//!   pending results into segments; compaction per `--compact-every`
//!   runs on the store's background compactor) and trims the trace
//!   store to its watermark.
//! - **[`queue`]**: the bounded admission queue. A full queue answers
//!   that request with a `cxlmem-result-error-v1` document (kind `io`,
//!   "admission queue full") instead of stalling the socket; depth is
//!   mirrored into the `serve.queue_depth` gauge. Chaos point
//!   `serve.admit` (key = spec name) fails one admission the same way
//!   (kind `panic`) while the daemon keeps serving.
//! - **[`worker`]**: the evaluation pool over
//!   [`crate::util::par::spawn_worker`]. Each worker owns a
//!   [`crate::scenario::cache::StoreHandle`] clone (warm hits are one
//!   atomic load plus a cascade walk, no flock), dedups in-flight
//!   identical requests onto one evaluation, and evaluates under the
//!   supervision envelope (`catch_unwind`, retries, cancellable
//!   `--deadline-secs`).
//! - **[`protocol`]**: request parsing, the `stats` document
//!   ([`STATS_SCHEMA`]), and the shutdown ack.
//!
//! Responses are byte-identical to a batch run of the same specs
//! (pinned by `make serve-smoke` and `rust/tests/serve.rs`): results
//! and errors go through the same document builders, and the JSON
//! renderer is canonical (sorted keys, stable float formatting).
//!
//! Only Unix targets have `AF_UNIX` sockets in std; elsewhere
//! [`run_serve`] and the client helpers return an error.

mod protocol;
mod queue;
mod worker;

pub use protocol::{shutdown_ack, validate_stats_doc, STATS_SCHEMA};

use std::path::PathBuf;

use super::cache::ResultCache;
use super::supervise::SuperviseOpts;

/// Default admission-queue bound (`--queue`).
pub const DEFAULT_QUEUE_CAP: usize = 256;

/// Daemon configuration (`cxlmem scenario serve`).
pub struct ServeOpts {
    /// Unix-domain socket path to bind (`--socket`).
    pub socket: PathBuf,
    /// Evaluation pool size (`--jobs`).
    pub workers: usize,
    /// Admission-queue bound (`--queue`).
    pub queue_cap: usize,
    /// Supervision policy applied to every evaluation
    /// (`--retries`/`--deadline-secs`; `fail_fast` is ignored — a
    /// daemon always isolates failures into error documents).
    pub supervise: SuperviseOpts,
}

impl ServeOpts {
    /// Defaults for `socket`: machine-parallel workers, a
    /// [`DEFAULT_QUEUE_CAP`] queue, default supervision.
    pub fn new(socket: impl Into<PathBuf>) -> ServeOpts {
        ServeOpts {
            socket: socket.into(),
            workers: crate::perf::default_jobs(),
            queue_cap: DEFAULT_QUEUE_CAP,
            supervise: SuperviseOpts::default(),
        }
    }
}

#[cfg(unix)]
pub use unix::{request_lines, run_serve, wait_ready};

#[cfg(not(unix))]
mod stub {
    use super::{ResultCache, ServeOpts};
    use std::path::Path;

    /// Unsupported off-Unix: std has no `AF_UNIX` sockets here.
    pub fn run_serve(_cache: ResultCache, _opts: &ServeOpts) -> anyhow::Result<()> {
        anyhow::bail!("scenario serve requires Unix domain sockets (unix targets only)")
    }

    /// Unsupported off-Unix; see [`run_serve`].
    pub fn request_lines(_socket: &Path, _lines: &[String]) -> anyhow::Result<Vec<String>> {
        anyhow::bail!("scenario submit requires Unix domain sockets (unix targets only)")
    }

    /// Unsupported off-Unix; see [`run_serve`].
    pub fn wait_ready(_socket: &Path, _timeout: std::time::Duration) -> anyhow::Result<()> {
        anyhow::bail!("scenario serve requires Unix domain sockets (unix targets only)")
    }
}

#[cfg(not(unix))]
pub use stub::{request_lines, run_serve, wait_ready};

#[cfg(unix)]
mod unix {
    use std::collections::{BTreeMap, HashMap};
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::{UnixListener, UnixStream};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::path::Path;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    use anyhow::{bail, Context, Result};

    use super::worker::{bump, Job, Respond, Shared};
    use super::{protocol, queue::AdmissionQueue, worker, ResultCache, ServeOpts};
    use crate::scenario::spec::ScenarioSpec;
    use crate::scenario::supervise::{error_doc, panic_message, ErrorKind, Failure, SuperviseOpts};
    use crate::util::fault;
    use crate::util::json::Json;

    /// Accept-loop poll granularity when idle.
    const POLL_INTERVAL: Duration = Duration::from_millis(2);
    /// How often the idle loop seals pending results and trims traces.
    const FLUSH_INTERVAL: Duration = Duration::from_secs(1);

    /// Run the daemon until a `shutdown` request: bind `opts.socket`,
    /// accept connections, evaluate admitted specs on the worker pool,
    /// then drain the queue, seal the store head, and remove the
    /// socket file. Blocks the calling thread for the daemon's
    /// lifetime.
    pub fn run_serve(mut cache: ResultCache, opts: &ServeOpts) -> Result<()> {
        let shared = Arc::new(Shared {
            queue: AdmissionQueue::new(opts.queue_cap),
            inflight: Mutex::new(HashMap::new()),
            store: cache.handle(),
            opts: SuperviseOpts {
                fail_fast: false,
                ..opts.supervise.clone()
            },
            counters: worker::Counters::default(),
            shutdown: AtomicBool::new(false),
        });
        let workers: Vec<_> = (0..opts.workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                crate::util::par::spawn_worker(&format!("cxlmem-serve-{i}"), move || {
                    worker::worker_loop(shared)
                })
            })
            .collect::<std::io::Result<_>>()
            .context("spawning the serve worker pool")?;

        if opts.socket.exists() {
            // A stale socket from a dead daemon; a live one would fail
            // the bind below anyway.
            std::fs::remove_file(&opts.socket)
                .with_context(|| format!("removing stale socket {}", opts.socket.display()))?;
        }
        let listener = UnixListener::bind(&opts.socket)
            .with_context(|| format!("binding serve socket {}", opts.socket.display()))?;
        listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;

        let mut conn_n: u64 = 0;
        let mut last_flush = Instant::now();
        let served = loop {
            if shared.shutdown.load(Ordering::Acquire) {
                break Ok(());
            }
            match listener.accept() {
                Ok((stream, _addr)) => {
                    conn_n += 1;
                    let conn_key = format!("conn-{conn_n}");
                    // Chaos point: an injected accept panic drops exactly
                    // this connection (the client sees EOF); the daemon
                    // keeps serving.
                    if catch_unwind(AssertUnwindSafe(|| fault::point("serve.accept", &conn_key)))
                        .is_err()
                    {
                        continue;
                    }
                    bump(&shared.counters.connections, "serve.connections");
                    let _ = stream.set_nonblocking(false);
                    let shared = Arc::clone(&shared);
                    let spawned = std::thread::Builder::new()
                        .name(format!("cxlmem-serve-{conn_key}"))
                        .spawn(move || handle_conn(stream, &shared));
                    if let Err(e) = spawned {
                        eprintln!("warning: serve: dropping {conn_key}: spawn failed: {e}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if last_flush.elapsed() >= FLUSH_INTERVAL {
                        if let Err(e) = cache.flush() {
                            eprintln!("warning: serve: periodic flush failed: {e:#}");
                        }
                        crate::workloads::trace::global().trim();
                        last_flush = Instant::now();
                    }
                    std::thread::sleep(POLL_INTERVAL);
                }
                Err(e) => {
                    break Err(e).context("accepting on the serve socket");
                }
            }
        };

        // Drain: stop admitting, let the pool finish queued work (every
        // admitted request still gets its response), then seal the head.
        shared.queue.close();
        for w in workers {
            let _ = w.join();
        }
        let flushed = cache.flush().context("sealing the store at shutdown");
        let _ = std::fs::remove_file(&opts.socket);
        served.and(flushed)
    }

    /// Per-connection reader: parse request lines, answer verbs inline,
    /// admit specs to the queue. Responses flow through [`Delivery`] so
    /// they leave in request order whatever order workers finish in.
    fn handle_conn(stream: UnixStream, shared: &Arc<Shared>) {
        let reader = match stream.try_clone() {
            Ok(read_half) => BufReader::new(read_half),
            Err(_) => return,
        };
        let delivery = Arc::new(Delivery::new(stream));
        let mut seq: u64 = 0;
        for line in reader.lines() {
            let Ok(line) = line else { break };
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let this = seq;
            seq += 1;
            match protocol::parse_request(text) {
                Err(e) => {
                    bump(&shared.counters.errors, "serve.errors");
                    let failure = Failure {
                        kind: ErrorKind::Eval,
                        message: format!("{e:#}"),
                        attempts: 1,
                    };
                    delivery.deliver(this, doc_line(&error_doc("<request>", "-", &failure, None)));
                }
                Ok(protocol::Request::Stats) => {
                    delivery.deliver(this, doc_line(&worker::stats_doc(shared)));
                }
                Ok(protocol::Request::Shutdown) => {
                    // The ack takes its place in the per-connection
                    // order: it flushes to the client after every
                    // earlier request on this connection has answered,
                    // which the drain in `run_serve` guarantees happens.
                    delivery.deliver(this, doc_line(&protocol::shutdown_ack()));
                    shared.shutdown.store(true, Ordering::Release);
                    break;
                }
                Ok(protocol::Request::Spec(doc)) => admit(shared, &delivery, this, &doc),
            }
        }
    }

    /// Admit one spec request: validate, then try the bounded queue.
    /// Failure modes answer *this* request with an error document and
    /// leave the daemon serving: an invalid spec (kind `eval`), a full
    /// queue (kind `io`, the backpressure signal), an injected
    /// `serve.admit` panic (kind `panic`).
    fn admit(shared: &Arc<Shared>, delivery: &Arc<Delivery>, seq: u64, doc: &Json) {
        bump(&shared.counters.requests, "serve.requests");
        let reject = |kind: ErrorKind, name: &str, key: &str, message: String| {
            let failure = Failure {
                kind,
                message,
                attempts: 1,
            };
            delivery.deliver(seq, doc_line(&error_doc(name, key, &failure, None)));
        };
        if crate::scenario::expand::is_template(doc) {
            bump(&shared.counters.errors, "serve.errors");
            let name = doc.get("name").and_then(Json::as_str).unwrap_or("<template>");
            reject(
                ErrorKind::Eval,
                name,
                "-",
                "document is a sweep/fleet template — expand it first \
                 (`cxlmem scenario expand`)"
                    .to_string(),
            );
            return;
        }
        let spec = match ScenarioSpec::parse(doc) {
            Ok(spec) => spec,
            Err(e) => {
                bump(&shared.counters.errors, "serve.errors");
                let name = doc.get("name").and_then(Json::as_str).unwrap_or("<invalid>");
                reject(ErrorKind::Eval, name, "-", format!("{e:#}"));
                return;
            }
        };
        let (key, canon) = spec.cache_identity();
        let name = spec.name.clone();
        let job = Job {
            seq,
            spec,
            key: key.clone(),
            canon,
            reply: Arc::clone(delivery) as Arc<dyn Respond>,
        };
        match catch_unwind(AssertUnwindSafe(|| {
            fault::point("serve.admit", &name);
            shared.queue.try_push(job)
        })) {
            Ok(Ok(())) => {}
            Ok(Err(_rejected)) => {
                bump(&shared.counters.rejected, "serve.rejected");
                reject(
                    ErrorKind::Io,
                    &name,
                    &key,
                    format!(
                        "admission queue full ({} pending) — retry later",
                        shared.queue.capacity()
                    ),
                );
            }
            Err(payload) => {
                bump(&shared.counters.errors, "serve.errors");
                reject(ErrorKind::Panic, &name, &key, panic_message(payload.as_ref()));
            }
        }
    }

    fn doc_line(doc: &Json) -> String {
        format!("{doc}\n")
    }

    /// In-request-order response sink for one connection: workers
    /// deliver `(seq, line)` in completion order; lines buffer in a
    /// reorder map and flush to the socket as the contiguous prefix
    /// grows. This is what makes a connection's response stream
    /// byte-identical to a batch run over the same request order.
    struct Delivery {
        state: Mutex<DeliveryState>,
    }

    struct DeliveryState {
        out: UnixStream,
        next: u64,
        pending: BTreeMap<u64, String>,
    }

    impl Delivery {
        fn new(out: UnixStream) -> Delivery {
            Delivery {
                state: Mutex::new(DeliveryState {
                    out,
                    next: 0,
                    pending: BTreeMap::new(),
                }),
            }
        }
    }

    impl Respond for Delivery {
        fn deliver(&self, seq: u64, line: String) {
            let mut guard = self.state.lock().unwrap_or_else(|p| p.into_inner());
            let st = &mut *guard;
            st.pending.insert(seq, line);
            let mut wrote = false;
            while let Some(ready) = st.pending.remove(&st.next) {
                // A vanished client can't cancel its queued work; keep
                // draining so the reorder buffer stays bounded.
                let _ = st.out.write_all(ready.as_bytes());
                st.next += 1;
                wrote = true;
            }
            if wrote {
                let _ = st.out.flush();
            }
        }
    }

    /// Client side: send `lines` as one connection's requests and
    /// collect exactly one response line per request, in request order
    /// (trailing newlines stripped). Writes happen on a side thread so
    /// a batch larger than the socket buffer cannot deadlock against
    /// unread responses.
    pub fn request_lines(socket: &Path, lines: &[String]) -> Result<Vec<String>> {
        let stream = UnixStream::connect(socket)
            .with_context(|| format!("connecting to serve socket {}", socket.display()))?;
        let mut writer = stream.try_clone().context("cloning the socket stream")?;
        let reader = BufReader::new(stream);
        let mut body = String::new();
        for line in lines {
            body.push_str(line);
            body.push('\n');
        }
        let writer_thread = std::thread::spawn(move || -> std::io::Result<()> {
            writer.write_all(body.as_bytes())?;
            writer.flush()
        });
        let want = lines.len();
        let mut out = Vec::with_capacity(want);
        for line in reader.lines() {
            out.push(line.context("reading a daemon response")?);
            if out.len() == want {
                break;
            }
        }
        match writer_thread.join() {
            Ok(sent) => sent.context("sending requests to the daemon")?,
            Err(_) => bail!("request writer thread panicked"),
        }
        if out.len() < want {
            bail!(
                "daemon closed the connection after {} of {want} response(s)",
                out.len()
            );
        }
        Ok(out)
    }

    /// Block until the daemon's socket accepts connections, up to
    /// `timeout`. Note the successful probe counts as one accepted
    /// connection on the daemon side (`conn-1` when called first).
    pub fn wait_ready(socket: &Path, timeout: Duration) -> Result<()> {
        let t0 = Instant::now();
        loop {
            match UnixStream::connect(socket) {
                Ok(_probe) => return Ok(()),
                Err(_) if t0.elapsed() < timeout => std::thread::sleep(Duration::from_millis(5)),
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "serve socket {} not ready after {timeout:?}",
                            socket.display()
                        )
                    })
                }
            }
        }
    }
}
