//! GPU ↔ CPU-memory-hierarchy data-path model (§IV, Figs 5–6).
//!
//! Under CXL 1.1 there is no peer-to-peer access: the GPU reaches the CXL
//! memory via `GPU –PCIe– CPU –PCIe– CXL`, so
//! - bandwidth is clamped by the *GPU's own* PCIe link (Fig 5: < 3%
//!   difference across memory policies), and
//! - latency grows by the extra path (Fig 6: ~+500 ns to CXL vs CPU DRAM,
//!   larger than the CPU-side +120 ns difference).

use crate::memsim::{MemKind, NodeId, System};

/// Fixed software overhead of one `cudaMemcpy` call (driver + launch),
/// nanoseconds. Dominates small transfers.
pub const CUDAMEMCPY_OVERHEAD_NS: f64 = 1_800.0;

/// DMA streaming efficiency per memory kind: the DMA engine sustains
/// near-spec rates from DRAM, but CXL's longer round trip stalls the
/// pipeline slightly (the Fig 9 "data movement suffers from CXL latency"
/// effect).
pub fn dma_efficiency(kind: MemKind) -> f64 {
    match kind {
        MemKind::Ldram => 1.0,
        MemKind::Rdram => 0.95,
        MemKind::Cxl => 0.82,
        MemKind::Nvme => 1.0, // already bandwidth-limited far below PCIe
    }
}

/// A GPU attached to one socket via a PCIe link (system A's A10).
#[derive(Clone, Debug)]
pub struct Gpu {
    pub socket: usize,
    pub mem_bytes: u64,
    /// Peak dense fp16 throughput (FLOP/s) and achievable efficiency.
    pub peak_flops: f64,
    pub efficiency: f64,
}

impl Gpu {
    /// NVIDIA A10: 24 GB, PCIe 4.0 x16, ~125 TFLOP/s fp16 tensor peak.
    pub fn a10() -> Self {
        Self {
            socket: 1,
            mem_bytes: 24 << 30,
            peak_flops: 125e12,
            efficiency: 0.38,
        }
    }

    pub fn flops_effective(&self) -> f64 {
        self.peak_flops * self.efficiency
    }

    /// One-way transfer latency (ns) for a small (cache-block) copy
    /// between GPU memory and CPU-side memory on `node` (Fig 6).
    pub fn transfer_latency_ns(&self, sys: &System, node: NodeId) -> f64 {
        let gpu_link = sys.gpu_link.expect("system has no GPU");
        // GPU -PCIe-> CPU (root complex on gpu.socket), then the CPU side
        // walks to the memory node like a local access. CXL's own PCIe
        // traversal is inside the device's calibrated idle latency;
        // crossing sockets adds the fabric hop via `idle_latency`.
        let cpu_side = sys.idle_latency(self.socket, node, crate::memsim::Pattern::Random);
        CUDAMEMCPY_OVERHEAD_NS + gpu_link.hop_ns + cpu_side
    }

    /// Achievable large-transfer bandwidth (GB/s) for a copy whose CPU
    /// side is spread over `node_weights` (a membind/interleave choice).
    pub fn transfer_bw_gbs(&self, sys: &System, node_weights: &[(NodeId, f64)]) -> f64 {
        let gpu_link = sys.gpu_link.expect("system has no GPU");
        // Memory-side rate: weighted harmonic mean of per-node DMA rates
        // (the DMA engine walks pages in address order).
        let mut t_per_byte = 0.0;
        for &(node, w) in node_weights {
            let dev = &sys.nodes[node].device;
            let kind = dev.kind;
            // DMA sustains device spec bandwidth scaled by efficiency;
            // the fabric clamps cross-socket paths.
            let mut rate = dev.spec_bw_gbs * dma_efficiency(kind);
            if sys.nodes[node].socket != self.socket {
                rate = rate.min(sys.fabric.bw_gbs);
            }
            if kind == MemKind::Nvme {
                rate = dev.peak_bw_gbs;
            }
            t_per_byte += w / rate;
        }
        let mem_side = 1.0 / t_per_byte;
        gpu_link.bw_gbs.min(mem_side)
    }

    /// Time (seconds) to move `bytes` between GPU and the CPU hierarchy.
    pub fn transfer_time_s(&self, sys: &System, node_weights: &[(NodeId, f64)], bytes: f64) -> f64 {
        // Small-copy latency + streaming portion.
        let lat: f64 = node_weights
            .iter()
            .map(|&(n, w)| w * self.transfer_latency_ns(sys, n))
            .sum();
        let bw = self.transfer_bw_gbs(sys, node_weights);
        lat / 1e9 + bytes / (bw * 1e9)
    }

    /// Observed bandwidth (GB/s) for a block-size sweep point (Fig 5).
    pub fn observed_bw(&self, sys: &System, node_weights: &[(NodeId, f64)], bytes: f64) -> f64 {
        bytes / self.transfer_time_s(sys, node_weights, bytes) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;

    fn setup() -> (crate::memsim::System, Gpu) {
        (system_a(), Gpu::a10())
    }

    #[test]
    fn peak_bw_is_pcie_limited_for_all_policies() {
        // Fig 5: <3% spread across memory policies at large block sizes.
        let (sys, gpu) = setup();
        let ld = sys.node_of(1, MemKind::Ldram).unwrap();
        let rd = sys.node_of(1, MemKind::Rdram).unwrap();
        let cxl = sys.node_of(1, MemKind::Cxl).unwrap();
        let policies: Vec<Vec<(NodeId, f64)>> = vec![
            vec![(ld, 1.0)],
            vec![(ld, 0.5), (cxl, 0.5)],
            vec![(ld, 1.0 / 3.0), (rd, 1.0 / 3.0), (cxl, 1.0 / 3.0)],
        ];
        let bws: Vec<f64> = policies
            .iter()
            .map(|p| gpu.observed_bw(&sys, p, 4e9))
            .collect();
        let max = bws.iter().cloned().fold(0.0f64, f64::max);
        let min = bws.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((max - min) / max < 0.03, "spread {bws:?}");
        assert!(max <= sys.gpu_link.unwrap().bw_gbs * 1.001);
    }

    #[test]
    fn small_transfers_dominated_by_overhead() {
        let (sys, gpu) = setup();
        let ld = sys.node_of(1, MemKind::Ldram).unwrap();
        let bw_small = gpu.observed_bw(&sys, &[(ld, 1.0)], 128.0);
        let bw_big = gpu.observed_bw(&sys, &[(ld, 1.0)], 1e9);
        assert!(bw_small < 0.1);
        assert!(bw_big > 20.0);
    }

    #[test]
    fn gpu_to_cxl_latency_penalty_exceeds_cpu_side_penalty() {
        // Fig 6 vs Fig 2: the GPU-side CXL latency penalty (longer path)
        // is at least the CPU-side penalty, and substantial.
        let (sys, gpu) = setup();
        let ld = sys.node_of(1, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(1, MemKind::Cxl).unwrap();
        let gpu_pen = gpu.transfer_latency_ns(&sys, cxl) - gpu.transfer_latency_ns(&sys, ld);
        let cpu_pen = sys.idle_latency(1, cxl, crate::memsim::Pattern::Random)
            - sys.idle_latency(1, ld, crate::memsim::Pattern::Random);
        assert!(gpu_pen >= cpu_pen, "gpu {gpu_pen} vs cpu {cpu_pen}");
        assert!(gpu_pen > 100.0);
    }

    #[test]
    fn nvme_transfers_far_slower() {
        let (sys, gpu) = setup();
        let nv = sys.node_of(1, MemKind::Nvme).unwrap();
        let ld = sys.node_of(1, MemKind::Ldram).unwrap();
        let t_nv = gpu.transfer_time_s(&sys, &[(nv, 1.0)], 1e9);
        let t_ld = gpu.transfer_time_s(&sys, &[(ld, 1.0)], 1e9);
        assert!(t_nv > 4.0 * t_ld);
    }
}
