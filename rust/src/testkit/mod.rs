//! Mini property-testing harness (no `proptest` in the offline vendor
//! set): seeded random generators + a check loop that reports the
//! failing seed/case for reproduction.

use crate::util::rng::Rng;

/// Number of cases per property by default.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` against `cases` generated inputs. On failure, panics with
/// the case index, seed, and a debug rendering of the failing input.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = Rng::seeded(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input = {input:?}"
            );
        }
    }
}

/// Generator helpers.
pub fn vec_f64(rng: &mut Rng, len_max: usize, lo: f64, hi: f64) -> Vec<f64> {
    let n = rng.index(len_max.max(1)) + 1;
    (0..n).map(|_| rng.range_f64(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-nonneg",
            1,
            32,
            |r| vec_f64(r, 16, 0.0, 10.0),
            |v| v.iter().sum::<f64>() >= 0.0,
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_reports() {
        check("always-false", 2, 4, |r| r.below(10), |_| false);
    }
}
