//! NAS Parallel Benchmarks access-signature models (Table III).
//!
//! Each workload is modeled by its data objects (sizes from Table III's
//! "BW-hungry objects" column plus a residual), its dominant access
//! pattern, per-object traffic intensity, and a compute intensity.
//! Calibration targets are the paper's §V figures:
//! - Fig 13: CXL-involving interleaves are CXL-dominated (RDRAM+CXL ≈
//!   LDRAM+CXL within 9.2%).
//! - Fig 14: MG (bandwidth-hungry) favors "interleave all" by 10–85%;
//!   CG (latency-sensitive) favors CXL-preferred.
//! - Fig 15: OLI ≈ LDRAM-preferred with sufficient LDRAM, 65% over
//!   uniform interleave; 1.42× over LDRAM-preferred with 64 GB LDRAM.

use super::{HpcWorkload, WlObject};
use crate::memsim::Pattern::{Random, Sequential};

/// BT — dense linear algebra; unit-strided accesses; compute-rich
/// (tolerates CXL: <3.2% loss at moderate scale).
pub fn bt() -> HpcWorkload {
    HpcWorkload {
        name: "BT",
        dwarf: "Dense linear algebra",
        characterization: "Unit-strided memory accesses from dense matrices",
        input: "Class E",
        objects: vec![
            WlObject::new("u", 39.6, Sequential, 3.0, 0.02),
            WlObject::new("rsh", 39.6, Sequential, 3.0, 0.02),
            WlObject::new("forcing", 39.6, Sequential, 2.5, 0.02),
            WlObject::new("ws_rest", 47.2, Sequential, 0.4, 0.05),
        ],
        compute_ns_per_byte: 1.60,
    }
}

/// LU — sparse linear algebra; indexed loads/stores.
pub fn lu() -> HpcWorkload {
    HpcWorkload {
        name: "LU",
        dwarf: "Sparse linear algebra",
        characterization: "Indexed loads and stores from compressed matrices",
        input: "Class E",
        objects: vec![
            WlObject::new("u", 39.6, Sequential, 2.6, 0.05),
            WlObject::new("rsd", 39.6, Random, 2.6, 0.25),
            WlObject::new("ws_rest", 54.8, Sequential, 0.35, 0.05),
        ],
        compute_ns_per_byte: 0.95,
    }
}

/// CG — irregular, indirect-indexed accesses; latency-sensitive.
pub fn cg() -> HpcWorkload {
    HpcWorkload {
        name: "CG",
        dwarf: "Sparse linear algebra",
        characterization: "Irregular memory accesses based on indirect indexing",
        input: "Class E",
        objects: vec![
            // The sparse matrix is scanned (CSR walk) — bandwidth-hungry
            // and the object Table III lists for OLI...
            WlObject::new("a", 48.9, Sequential, 0.35, 0.05),
            // ...while the gather into x/p/q is the latency-critical
            // indirect part (small, hot, pointer-chasing).
            WlObject::new("vecs", 12.0, Random, 5.0, 0.85),
            WlObject::new("ws_rest", 73.1, Sequential, 0.1, 0.05),
        ],
        compute_ns_per_byte: 0.30,
    }
}

/// MG — structured grids; the paper's bandwidth-hungry exemplar.
pub fn mg() -> HpcWorkload {
    HpcWorkload {
        name: "MG",
        dwarf: "Structured grids",
        characterization: "Dynamic updates based on subdivided regular grids",
        input: "Class E",
        objects: vec![
            WlObject::new("v", 64.2, Sequential, 3.2, 0.02),
            WlObject::new("r", 73.4, Sequential, 3.2, 0.02),
            WlObject::new("ws_rest", 72.4, Sequential, 0.3, 0.05),
        ],
        compute_ns_per_byte: 0.80,
    }
}

/// SP — structured grids; floating-point intensive.
pub fn sp() -> HpcWorkload {
    HpcWorkload {
        name: "SP",
        dwarf: "Structured grids",
        characterization: "Intense floating-point computations for linear equations",
        input: "Class E",
        objects: vec![
            WlObject::new("u", 39.6, Sequential, 2.8, 0.02),
            WlObject::new("rsh", 39.6, Sequential, 2.8, 0.02),
            WlObject::new("forcing", 39.6, Sequential, 2.2, 0.02),
            WlObject::new("ws_rest", 55.2, Sequential, 0.35, 0.05),
        ],
        compute_ns_per_byte: 1.35,
    }
}

/// FT — spectral method; bandwidth-consuming transpose.
pub fn ft() -> HpcWorkload {
    HpcWorkload {
        name: "FT",
        dwarf: "Spectral method",
        characterization: "Bandwidth-consuming matrix transpose",
        input: "Class D",
        objects: vec![
            WlObject::new("u0", 32.0, Sequential, 4.5, 0.02),
            WlObject::new("u1", 32.0, Sequential, 4.5, 0.02),
            WlObject::new("ws_rest", 16.0, Sequential, 0.5, 0.05),
        ],
        compute_ns_per_byte: 0.55,
    }
}

/// All seven HPC workloads (NPB six + XSBench), Table III order.
pub fn all_hpc_workloads() -> Vec<HpcWorkload> {
    vec![
        bt(),
        lu(),
        cg(),
        mg(),
        sp(),
        ft(),
        super::xsbench::xsbench(),
    ]
}

/// Look up a workload by name (case-insensitive).
pub fn by_name(name: &str) -> Option<HpcWorkload> {
    all_hpc_workloads()
        .into_iter()
        .find(|w| w.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(by_name("cg").unwrap().name, "CG");
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn seven_workloads() {
        assert_eq!(all_hpc_workloads().len(), 7);
    }

    #[test]
    fn cg_is_latency_dominated() {
        let w = cg();
        let dep_traffic: f64 = w
            .objects
            .iter()
            .map(|o| o.traffic_bytes() * o.spec.dep_frac)
            .sum();
        let total: f64 = w.objects.iter().map(|o| o.traffic_bytes()).sum();
        assert!(dep_traffic / total > 0.4, "{}", dep_traffic / total);
    }

    #[test]
    fn mg_is_bandwidth_dominated() {
        let w = mg();
        let dep_traffic: f64 = w
            .objects
            .iter()
            .map(|o| o.traffic_bytes() * o.spec.dep_frac)
            .sum();
        let total: f64 = w.objects.iter().map(|o| o.traffic_bytes()).sum();
        assert!(dep_traffic / total < 0.05);
    }
}
