//! Shared immutable epoch-trace store for the tiering study.
//!
//! The fig16/fig17 policy×placement grids and the fleet scenarios
//! evaluate the *same* workload trace under many policy×placement
//! combinations. Before this module every grid cell and every fleet
//! member seeded its own [`TraceGen`] and regenerated the identical
//! epoch stream — at fleet scale, by far the dominant redundant work.
//!
//! [`EpochTrace`] is one immutable trace snapshot. Internally it is
//! either **dense** (every epoch's per-page histogram, flattened
//! `[epoch][page]`) or **delta-encoded**: consecutive epochs differ
//! only by a drift-sized set of pages, so the snapshot stores the
//! epoch-0 histogram plus one sparse `(page, wrapping Δcount)` list
//! per epoch boundary. [`EpochTrace::generate`] picks whichever
//! representation is smaller (falling back to dense mid-encode the
//! moment deltas stop paying for themselves, so pathological drifts
//! never hold both forms at once). A 16M-page × 10-epoch PageRank
//! trace is ~640 MB dense — over twice the default store budget — but
//! only base + near-empty deltas (~64 MB) delta-encoded.
//!
//! Replay goes through [`TraceCursor`]: a cursor owns one reusable
//! `pages`-sized buffer and materializes epochs into it by applying
//! boundary deltas in order, which is O(drift) per forward step and
//! zero-copy for dense traces (the cursor hands out the stored slice
//! directly). Delta application uses wrapping adds of wrapping
//! differences, so reconstruction is exact for every `u32` histogram —
//! bit-parity with the dense path is pinned by tests here and by the
//! end-to-end `simulate_trace` parity suite.
//!
//! [`TraceStore`] hands out `Arc<EpochTrace>` snapshots keyed by
//! [`TraceKey`] — `(app, pages, epochs, drift, seed)` plus the
//! remaining histogram-shaping model fields — generating each key **at
//! most once per process**: generation happens under the store lock, so
//! concurrent grid cells racing on a cold key still produce a single
//! generation, and every requester gets a pointer-equal `Arc` (pinned
//! by test).
//!
//! Lifetime and memory bound: the process-global store
//! ([`global`]) retains snapshots LRU-evicted to
//! [`DEFAULT_BUDGET_BYTES`] at insert time (a full-size fig16 app
//! trace — 65 000 pages × 10 epochs — is ~2.6 MB dense, far less
//! delta-encoded, so the default budget holds on the order of a
//! hundred distinct fleet keys). A single trace larger than the whole
//! budget is returned to the caller but **never cached** (counted in
//! `stats().oversized`) — retaining it would permanently blow the
//! byte budget for everyone else. Eviction only drops the store's own
//! handle; outstanding `Arc`s keep their snapshot alive until the
//! last cell finishes replaying it. The scenario batch runner
//! additionally calls [`TraceStore::trim`] after each batch, releasing
//! snapshots nobody holds anymore down to an idle watermark so
//! long-lived fleet processes don't pin a full budget of cold traces
//! between batches.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::tiering_apps::{AppModel, TraceGen};
use crate::util::metrics;

/// Default byte budget for the process-global store.
pub const DEFAULT_BUDGET_BYTES: usize = 256 << 20;

/// Identity of one generated trace. Two models that differ only in
/// fields that never enter the histogram (`compute_ns_per_access`)
/// share a key; everything that shapes the access stream — page count,
/// hot-set geometry, drift, skew, epoch budget, RNG seed — is part of
/// it. Float fields enter as their IEEE-754 bit patterns so the key is
/// totally ordered and exact.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TraceKey {
    app: String,
    pages: u64,
    epochs: u64,
    seed: u64,
    drift_bits: u64,
    shape_bits: [u64; 3],
    flags: u8,
}

impl TraceKey {
    pub fn of(model: &AppModel, epochs: usize, seed: u64) -> TraceKey {
        TraceKey {
            app: model.name.to_string(),
            pages: model.pages as u64,
            epochs: epochs as u64,
            seed,
            drift_bits: model.drift.to_bits(),
            shape_bits: [
                model.hot_frac.to_bits(),
                model.hot_share.to_bits(),
                model.accesses_per_epoch,
            ],
            flags: model.scattered as u8 | (model.hot_skewed as u8) << 1,
        }
    }
}

/// Physical representation of a trace. Dense keeps every epoch's
/// histogram flat; Delta keeps epoch 0 plus one sparse per-boundary
/// patch list. Both reproduce the exact same epoch histograms — the
/// representation is a pure storage decision and never part of
/// [`TraceKey`] identity.
#[derive(Clone, Debug)]
enum Repr {
    Dense {
        /// Distance between consecutive epochs in `counts`: `pages`
        /// for a generated trace, 0 for a constant trace (every epoch
        /// is the same shared slice — fig17's uniform-scan workloads).
        stride: usize,
        counts: Vec<u32>,
    },
    Delta {
        /// Epoch 0 histogram, `pages` long.
        base: Vec<u32>,
        /// Patched page indices, concatenated over all boundaries.
        idx: Vec<u32>,
        /// `new.wrapping_sub(old)` per patched page — wrapping deltas
        /// are exact mod 2^32 for *any* pair of `u32` counts, so no
        /// value-range fallback is ever needed.
        val: Vec<u32>,
        /// `ends[b]` = one-past-the-end offset into `idx`/`val` of the
        /// boundary taking epoch `b` to epoch `b+1` (`epochs - 1`
        /// entries; boundary `b` spans `ends[b-1]..ends[b]`).
        ends: Vec<usize>,
    },
}

/// One immutable epoch trace (dense or delta-encoded — see [`Repr`]).
///
/// Epochs are recorded in the order the fig16 producer emits them:
/// epoch `e`'s histogram, then one [`TraceGen::drift`] step — so a
/// replay is bit-identical to driving the generator live (pinned by the
/// parity test below).
#[derive(Clone, Debug)]
pub struct EpochTrace {
    pages: usize,
    epochs: usize,
    repr: Repr,
}

impl EpochTrace {
    /// Materialize `epochs` epochs of `model` under `seed`, driving the
    /// incremental generator exactly as the live fig16 producer does,
    /// and choosing the smaller of the dense and delta representations.
    /// The delta encoder only ever holds two epoch buffers plus the
    /// sparse patches; if mid-encode the patches grow past the dense
    /// footprint it abandons them and regenerates densely (the
    /// generator is deterministic, so the restart is exact).
    pub fn generate(model: &AppModel, epochs: usize, seed: u64) -> EpochTrace {
        let pages = model.pages;
        if epochs == 0 {
            return EpochTrace {
                pages,
                epochs,
                repr: Repr::Dense {
                    stride: pages,
                    counts: Vec::new(),
                },
            };
        }
        let dense_bytes = epochs * pages * std::mem::size_of::<u32>();
        let mut gen = TraceGen::new(model.clone(), seed);
        let mut base = Vec::new();
        gen.epoch_counts_into(&mut base);
        gen.drift();
        let mut prev = base.clone();
        let mut cur = Vec::new();
        let (mut idx, mut val, mut ends) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 1..epochs {
            gen.epoch_counts_into(&mut cur);
            for p in 0..pages {
                if cur[p] != prev[p] {
                    idx.push(p as u32);
                    val.push(cur[p].wrapping_sub(prev[p]));
                }
            }
            ends.push(idx.len());
            if delta_bytes(pages, idx.len(), ends.len()) >= dense_bytes {
                return Self::generate_dense(model, epochs, seed);
            }
            std::mem::swap(&mut prev, &mut cur);
            gen.drift();
        }
        EpochTrace {
            pages,
            epochs,
            repr: Repr::Delta {
                base,
                idx,
                val,
                ends,
            },
        }
    }

    /// Materialize every epoch flat (`[epoch][page]`), unconditionally.
    /// This is the pre-delta storage layout; [`EpochTrace::generate`]
    /// falls back to it when the sparse encoding would not be smaller,
    /// and the parity tests use it as the bit-exact reference.
    pub fn generate_dense(model: &AppModel, epochs: usize, seed: u64) -> EpochTrace {
        let mut gen = TraceGen::new(model.clone(), seed);
        let mut counts = Vec::with_capacity(epochs * model.pages);
        let mut buf = Vec::new();
        for _ in 0..epochs {
            gen.epoch_counts_into(&mut buf);
            counts.extend_from_slice(&buf);
            gen.drift();
        }
        EpochTrace {
            pages: model.pages,
            epochs,
            repr: Repr::Dense {
                stride: model.pages,
                counts,
            },
        }
    }

    /// A trace whose every epoch is the same histogram (fig17's
    /// constant uniform scans), stored once.
    pub fn constant(counts: Vec<u32>, epochs: usize) -> EpochTrace {
        EpochTrace {
            pages: counts.len(),
            epochs,
            repr: Repr::Dense { stride: 0, counts },
        }
    }

    /// A replay cursor with its own reusable materialization buffer.
    /// Cursors are cheap; each replaying cell holds one for the length
    /// of its run.
    pub fn cursor(&self) -> TraceCursor<'_> {
        TraceCursor {
            trace: self,
            buf: Vec::new(),
            at: usize::MAX,
        }
    }

    /// Epoch `e`'s histogram as an owned vector (convenience for tests
    /// and one-shot inspection; replay loops should use [`cursor`]).
    ///
    /// [`cursor`]: EpochTrace::cursor
    pub fn materialize(&self, e: usize) -> Vec<u32> {
        self.cursor().epoch(e).to_vec()
    }

    /// Whether this snapshot is delta-encoded (vs dense).
    pub fn is_delta(&self) -> bool {
        matches!(self.repr, Repr::Delta { .. })
    }

    pub fn pages(&self) -> usize {
        self.pages
    }

    pub fn epochs(&self) -> usize {
        self.epochs
    }

    /// Heap footprint (the store's budget currency).
    pub fn bytes(&self) -> usize {
        match &self.repr {
            Repr::Dense { counts, .. } => counts.len() * std::mem::size_of::<u32>(),
            Repr::Delta {
                base, idx, ends, ..
            } => delta_bytes(base.len(), idx.len(), ends.len()),
        }
    }
}

fn delta_bytes(pages: usize, patches: usize, boundaries: usize) -> usize {
    // base + idx + val (u32 each) + ends (usize each).
    (pages + 2 * patches) * std::mem::size_of::<u32>()
        + boundaries * std::mem::size_of::<usize>()
}

/// Sequential-friendly epoch accessor over one [`EpochTrace`].
///
/// For dense traces [`epoch`] returns the stored slice directly (zero
/// copies). For delta traces it keeps the last materialized epoch in a
/// reusable buffer: stepping forward applies only the boundary patches
/// in between (O(drift) per step — the `simulate_trace` replay pattern),
/// while a backward or cold request rebuilds from the epoch-0 base.
///
/// [`epoch`]: TraceCursor::epoch
pub struct TraceCursor<'a> {
    trace: &'a EpochTrace,
    buf: Vec<u32>,
    /// Epoch currently materialized in `buf`; `usize::MAX` = none.
    at: usize,
}

impl<'a> TraceCursor<'a> {
    /// Per-page access counts of epoch `e`.
    pub fn epoch(&mut self, e: usize) -> &[u32] {
        let t = self.trace;
        assert!(e < t.epochs, "epoch {e} out of range ({})", t.epochs);
        match &t.repr {
            Repr::Dense { stride, counts } => {
                let start = e * stride;
                &counts[start..start + t.pages]
            }
            Repr::Delta {
                base,
                idx,
                val,
                ends,
            } => {
                if self.at == usize::MAX || self.at > e {
                    self.buf.clear();
                    self.buf.extend_from_slice(base);
                    self.at = 0;
                }
                while self.at < e {
                    let b = self.at;
                    let start = if b == 0 { 0 } else { ends[b - 1] };
                    for i in start..ends[b] {
                        let p = idx[i] as usize;
                        self.buf[p] = self.buf[p].wrapping_add(val[i]);
                    }
                    self.at += 1;
                }
                &self.buf
            }
        }
    }
}

struct Entry {
    trace: Arc<EpochTrace>,
    last_use: u64,
}

#[derive(Default)]
struct Inner {
    map: BTreeMap<TraceKey, Entry>,
    bytes: usize,
    tick: u64,
}

/// Store counters (`cxlmem trace-smoke` gates on `generated`).
///
/// The counters live in `util::metrics` handles (the global store's
/// appear in `cxlmem stats` snapshots as `trace.*`); this struct is the
/// point-in-time view [`TraceStore::stats`] assembles from them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStoreStats {
    /// Total `get` calls.
    pub requests: u64,
    /// Traces generated (requests that missed).
    pub generated: u64,
    /// Entries dropped by the LRU budget.
    pub evicted: u64,
    /// Generated traces larger than the whole budget, returned to the
    /// caller but never cached (each repeat request regenerates).
    pub oversized: u64,
    /// Entries currently held.
    pub entries: usize,
    /// Bytes currently held.
    pub bytes: usize,
}

/// Metric handles backing one store's counters. The global store wires
/// these to the registry (`trace.*`); per-instance stores (tests) get
/// detached handles so they never pollute process snapshots.
struct StoreCounters {
    requests: &'static metrics::Counter,
    generated: &'static metrics::Counter,
    evicted: &'static metrics::Counter,
    oversized: &'static metrics::Counter,
    entries: &'static metrics::Gauge,
    bytes: &'static metrics::Gauge,
}

impl StoreCounters {
    fn detached() -> StoreCounters {
        StoreCounters {
            requests: metrics::detached_counter(),
            generated: metrics::detached_counter(),
            evicted: metrics::detached_counter(),
            oversized: metrics::detached_counter(),
            entries: metrics::detached_gauge(),
            bytes: metrics::detached_gauge(),
        }
    }

    fn registered(reg: &metrics::Registry) -> StoreCounters {
        StoreCounters {
            requests: reg.counter("trace.requests"),
            generated: reg.counter("trace.generated"),
            evicted: reg.counter("trace.evicted"),
            oversized: reg.counter("trace.oversized"),
            entries: reg.gauge("trace.entries"),
            bytes: reg.gauge("trace.bytes"),
        }
    }

    fn reset(&self) {
        self.requests.reset();
        self.generated.reset();
        self.evicted.reset();
        self.oversized.reset();
        self.entries.reset();
        self.bytes.reset();
    }
}

/// Keyed store of immutable trace snapshots; see the module docs for
/// keying, lifetime, and the memory bound.
pub struct TraceStore {
    budget: usize,
    counters: StoreCounters,
    inner: Mutex<Inner>,
}

impl TraceStore {
    pub fn with_budget(budget: usize) -> TraceStore {
        Self::with_counters(budget, StoreCounters::detached())
    }

    fn with_counters(budget: usize, counters: StoreCounters) -> TraceStore {
        TraceStore {
            budget,
            counters,
            inner: Mutex::new(Inner::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panicked holder leaves consistent data (all mutation is
        // counter/map bookkeeping) — recover instead of poisoning every
        // later grid cell.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The snapshot for `(model, epochs, seed)`, generated on first
    /// request and shared (pointer-equal) afterwards. Generation runs
    /// under the store lock: exactly one generation per key per
    /// process, however many cells race here. The deliberate trade-off
    /// is that cold *distinct* keys also serialize through the lock —
    /// acceptable because one generation is an O(epochs × pages) fill
    /// (milliseconds) while the evaluation that follows each fetch is
    /// orders of magnitude larger, and it keeps the single-generation
    /// counter exact without per-key once-cells.
    ///
    /// A trace larger than the whole budget is returned but not cached
    /// (`stats().oversized`): retaining it would exceed the byte budget
    /// permanently, since LRU eviction can never shrink below one entry.
    pub fn get(&self, model: &AppModel, epochs: usize, seed: u64) -> Arc<EpochTrace> {
        let key = TraceKey::of(model, epochs, seed);
        let mut inner = self.lock();
        self.counters.requests.inc();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            e.last_use = tick;
            return Arc::clone(&e.trace);
        }
        // Chaos hook (`trace.generate`, keyed by the app-model name):
        // a `panic` rule simulates generation dying mid-fill — the lock
        // recovery above keeps later cells usable; a `delay` rule
        // simulates a slow cold fill serializing its waiters.
        crate::util::fault::point("trace.generate", model.name);
        let trace = Arc::new(EpochTrace::generate(model, epochs, seed));
        self.counters.generated.inc();
        if trace.bytes() > self.budget {
            self.counters.oversized.inc();
            return trace;
        }
        inner.bytes += trace.bytes();
        let entry = Entry {
            trace: Arc::clone(&trace),
            last_use: tick,
        };
        inner.map.insert(key, entry);
        self.evict_over(&mut inner);
        self.sync_gauges(&inner);
        trace
    }

    /// Post-batch maintenance: drop snapshots nobody outside the store
    /// still holds (`Arc` strong count 1), oldest first, down to a
    /// quarter-budget idle watermark — so a long-lived fleet process
    /// does not pin a full budget of cold traces between batches. The
    /// *hard* bound is the insert-time LRU eviction in [`TraceStore::get`];
    /// this only reclaims idle memory earlier.
    pub fn trim(&self) {
        let mut inner = self.lock();
        let watermark = self.budget / 4;
        if inner.bytes <= watermark {
            return;
        }
        let mut idle: Vec<(u64, TraceKey)> = inner
            .map
            .iter()
            .filter(|(_, e)| Arc::strong_count(&e.trace) == 1)
            .map(|(k, e)| (e.last_use, k.clone()))
            .collect();
        idle.sort();
        for (_, key) in idle {
            if inner.bytes <= watermark {
                break;
            }
            if let Some(e) = inner.map.remove(&key) {
                inner.bytes -= e.trace.bytes();
                self.counters.evicted.inc();
            }
        }
        self.sync_gauges(&inner);
    }

    fn evict_over(&self, inner: &mut Inner) {
        // Oversized entries never enter the map (see `get`), so this
        // always terminates with `bytes <= budget`: the `len() > 1`
        // guard only stops it when the single remaining entry fits.
        while inner.bytes > self.budget && inner.map.len() > 1 {
            let key = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            if let Some(e) = inner.map.remove(&key) {
                inner.bytes -= e.trace.bytes();
                self.counters.evicted.inc();
            }
        }
    }

    /// Mirror the current retention level into the `entries`/`bytes`
    /// gauges (called with the lock held, after any mutation).
    fn sync_gauges(&self, inner: &Inner) {
        self.counters.entries.set(inner.map.len() as i64);
        self.counters.bytes.set(inner.bytes as i64);
    }

    /// Drop every entry and reset all counters (the trace-smoke gate
    /// starts from a clean store).
    pub fn clear(&self) {
        let mut inner = self.lock();
        *inner = Inner::default();
        self.counters.reset();
    }

    pub fn stats(&self) -> TraceStoreStats {
        let inner = self.lock();
        TraceStoreStats {
            requests: self.counters.requests.get(),
            generated: self.counters.generated.get(),
            evicted: self.counters.evicted.get(),
            oversized: self.counters.oversized.get(),
            entries: inner.map.len(),
            bytes: inner.bytes,
        }
    }
}

/// The process-global store every grid cell and fleet member shares.
/// Its counters are registered in the global metrics registry as
/// `trace.*`, so they appear in every `cxlmem stats` snapshot.
pub fn global() -> &'static TraceStore {
    static GLOBAL: OnceLock<TraceStore> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        TraceStore::with_counters(
            DEFAULT_BUDGET_BYTES,
            StoreCounters::registered(metrics::global()),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::par::par_map;
    use crate::workloads::tiering_apps::{all_apps, graph500, pagerank};

    fn small(mut app: AppModel, pages: usize) -> AppModel {
        app.pages = pages;
        app
    }

    #[test]
    fn generate_matches_live_producer_bit_exactly() {
        // A replayed snapshot must be indistinguishable from driving
        // the generator live, epoch by epoch (the fig16 producer
        // order: counts, then drift).
        let app = small(graph500(), 2_000);
        let trace = EpochTrace::generate(&app, 6, 17);
        let mut cursor = trace.cursor();
        let mut gen = TraceGen::new(app, 17);
        let mut buf = Vec::new();
        for e in 0..6 {
            gen.epoch_counts_into(&mut buf);
            assert_eq!(cursor.epoch(e), &buf[..], "epoch {e}");
            gen.drift();
        }
    }

    #[test]
    fn delta_matches_dense_for_all_apps_and_drifts() {
        // The representation is a pure storage decision: whatever
        // `generate` picks, every epoch must be bit-identical to the
        // unconditional dense layout — in replay order and under
        // random access (backward seeks rebuild from the base).
        for app in all_apps() {
            for drift in [0.0, 0.05, 0.5] {
                let mut app = small(app.clone(), 1_200);
                app.drift = drift;
                let auto = EpochTrace::generate(&app, 6, 9);
                let dense = EpochTrace::generate_dense(&app, 6, 9);
                assert_eq!(auto.bytes() <= dense.bytes(), true, "{} d={drift}", app.name);
                let mut c = auto.cursor();
                let mut d = dense.cursor();
                for e in 0..6 {
                    assert_eq!(c.epoch(e), d.epoch(e), "{} d={drift} e={e}", app.name);
                }
                for e in [3usize, 1, 4, 0, 5, 2] {
                    assert_eq!(c.epoch(e), d.epoch(e), "{} d={drift} seek e={e}", app.name);
                }
            }
        }
    }

    #[test]
    fn delta_encoding_shrinks_low_drift_traces() {
        // PageRank has drift 0: every boundary patch list is empty, so
        // the delta form is ~1/epochs of dense (the ISSUE memory-math
        // case scaled down). The ≥8× floor here mirrors the 16M bench
        // target.
        let app = small(pagerank(), 50_000);
        let tr = EpochTrace::generate(&app, 10, 7);
        assert!(tr.is_delta());
        let dense = EpochTrace::generate_dense(&app, 10, 7);
        assert!(!dense.is_delta());
        assert!(
            tr.bytes() * 8 <= dense.bytes(),
            "delta {} vs dense {}",
            tr.bytes(),
            dense.bytes()
        );
        // High-drift scattered traces may not shrink; generate must
        // then hand back the dense layout rather than a larger delta.
        let mut hot = small(graph500(), 1_000);
        hot.drift = 1.0;
        let t = EpochTrace::generate(&hot, 6, 3);
        assert!(t.bytes() <= EpochTrace::generate_dense(&hot, 6, 3).bytes());
    }

    #[test]
    fn store_returns_pointer_equal_snapshots() {
        let store = TraceStore::with_budget(DEFAULT_BUDGET_BYTES);
        let app = small(pagerank(), 1_000);
        let a = store.get(&app, 4, 7);
        let b = store.get(&app, 4, 7);
        assert!(Arc::ptr_eq(&a, &b));
        let s = store.stats();
        assert_eq!((s.requests, s.generated, s.entries), (2, 1, 1));
        // A different seed is a different key — and a different trace.
        let c = store.get(&app, 4, 8);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(store.stats().generated, 2);
    }

    #[test]
    fn grid_cells_share_one_snapshot_across_workers() {
        // Mirrors the fig16 fan-out: parallel cells requesting the same
        // key must observe pointer-equal Arcs from one generation.
        let store = TraceStore::with_budget(DEFAULT_BUDGET_BYTES);
        let app = small(graph500(), 1_500);
        let cells: Vec<usize> = (0..8).collect();
        let arcs = par_map(&cells, 4, |_| store.get(&app, 5, 3));
        for arc in &arcs[1..] {
            assert!(Arc::ptr_eq(&arcs[0], arc));
        }
        let s = store.stats();
        assert_eq!(s.generated, 1, "racing cells must not regenerate");
        assert_eq!(s.requests, 8);
    }

    #[test]
    fn key_separates_shape_not_compute() {
        let base = small(pagerank(), 800);
        let mut compute_only = base.clone();
        compute_only.compute_ns_per_access *= 2.0;
        assert_eq!(TraceKey::of(&base, 3, 1), TraceKey::of(&compute_only, 3, 1));
        let mut drifted = base.clone();
        drifted.drift = 0.25;
        assert_ne!(TraceKey::of(&base, 3, 1), TraceKey::of(&drifted, 3, 1));
        assert_ne!(TraceKey::of(&base, 3, 1), TraceKey::of(&base, 4, 1));
    }

    #[test]
    fn lru_budget_evicts_oldest_key() {
        let app = small(pagerank(), 1_000);
        let one = EpochTrace::generate(&app, 2, 1).bytes();
        // Room for one trace only: the second insert evicts the first.
        let store = TraceStore::with_budget(one);
        let a = store.get(&app, 2, 1);
        let _b = store.get(&app, 2, 2);
        let s = store.stats();
        assert_eq!((s.evicted, s.entries), (1, 1));
        assert!(s.bytes <= one);
        // The evicted snapshot stays alive through its Arc…
        assert_eq!(a.epochs(), 2);
        // …and a re-request regenerates it.
        let a2 = store.get(&app, 2, 1);
        assert!(!Arc::ptr_eq(&a, &a2));
        assert_eq!(
            a.materialize(1),
            a2.materialize(1),
            "regeneration is deterministic"
        );
    }

    #[test]
    fn oversized_trace_bypasses_retention() {
        // A trace bigger than the whole budget used to be inserted and
        // then retained forever by the `len() > 1` eviction guard,
        // permanently blowing the byte budget. It must now be returned
        // without being cached.
        let app = small(pagerank(), 1_000);
        let store = TraceStore::with_budget(64); // smaller than any trace
        let a = store.get(&app, 2, 1);
        assert!(a.bytes() > 64);
        let s = store.stats();
        assert_eq!((s.generated, s.oversized), (1, 1));
        assert_eq!((s.entries, s.bytes, s.evicted), (0, 0, 0));
        // Repeat requests regenerate (documented cost of not caching)…
        let a2 = store.get(&app, 2, 1);
        assert!(!Arc::ptr_eq(&a, &a2));
        assert_eq!(a.materialize(1), a2.materialize(1));
        assert_eq!(store.stats().oversized, 2);
        // …and trim/clear still behave with an empty map.
        store.trim();
        assert_eq!(store.stats().entries, 0);
    }

    #[test]
    fn delta_encoding_fits_budget_dense_cannot() {
        // The ISSUE scale case, shrunk 16×: a 1M-page × 10-epoch
        // PageRank trace is 40 MB dense — over a 32 MB store budget —
        // but ~4 MB delta-encoded, so the store can retain it.
        let app = small(pagerank(), 1 << 20);
        let dense_bytes = 10 * (1usize << 20) * 4;
        let budget = 32 << 20;
        assert!(dense_bytes > budget);
        let store = TraceStore::with_budget(budget);
        let t = store.get(&app, 10, 7);
        assert!(t.is_delta());
        assert!(t.bytes() <= budget, "delta bytes {}", t.bytes());
        let s = store.stats();
        assert_eq!((s.entries, s.oversized), (1, 0));
        assert!(Arc::ptr_eq(&t, &store.get(&app, 10, 7)));
    }

    #[test]
    fn trim_releases_idle_snapshots_to_the_watermark() {
        let app = small(pagerank(), 1_000);
        let one = EpochTrace::generate(&app, 2, 1).bytes();
        // All three entries fit the insert-time budget; the idle
        // watermark is budget/4 = one trace.
        let store = TraceStore::with_budget(4 * one);
        store.get(&app, 2, 1); // returned Arc dropped at once — idle
        let held = store.get(&app, 2, 2);
        store.get(&app, 2, 3); // idle
        store.trim();
        let s = store.stats();
        // Idle snapshots go oldest-first until the watermark is met;
        // the held one survives whatever its age.
        assert_eq!((s.evicted, s.entries), (2, 1));
        assert_eq!(s.bytes, one);
        assert!(Arc::ptr_eq(&held, &store.get(&app, 2, 2)));
    }

    #[test]
    fn constant_trace_shares_one_slice() {
        let t = EpochTrace::constant(vec![3, 1, 4, 1, 5], 10);
        assert_eq!(t.pages(), 5);
        assert_eq!(t.epochs(), 10);
        assert_eq!(t.bytes(), 5 * 4);
        assert!(!t.is_delta());
        let mut c = t.cursor();
        let p0 = c.epoch(0).as_ptr();
        assert_eq!(c.epoch(9), &[3, 1, 4, 1, 5]);
        let p9 = c.epoch(9).as_ptr();
        assert!(std::ptr::eq(p0, p9), "stride-0 epochs share storage");
    }

    #[test]
    fn clear_resets_entries_and_counters() {
        let store = TraceStore::with_budget(DEFAULT_BUDGET_BYTES);
        let app = small(pagerank(), 500);
        store.get(&app, 2, 1);
        store.clear();
        assert_eq!(store.stats(), TraceStoreStats::default());
    }

    #[test]
    fn registry_snapshot_agrees_with_stats() {
        // The global store's counters are registry-backed; a private
        // registry here keeps the test deterministic under the parallel
        // test harness. Snapshot and stats() must tell the same story.
        let reg = metrics::Registry::new(true);
        let store =
            TraceStore::with_counters(DEFAULT_BUDGET_BYTES, StoreCounters::registered(&reg));
        let app = small(pagerank(), 600);
        let a = store.get(&app, 2, 1);
        let b = store.get(&app, 2, 1);
        assert!(Arc::ptr_eq(&a, &b));
        let snap = reg.snapshot_at(1_000);
        let counter = |name: &str| {
            snap.get("counters")
                .unwrap()
                .get(name)
                .unwrap()
                .as_u64()
                .unwrap()
        };
        let s = store.stats();
        assert_eq!((s.requests, s.generated, s.entries), (2, 1, 1));
        assert_eq!(counter("trace.requests"), s.requests);
        assert_eq!(counter("trace.generated"), s.generated);
        assert_eq!(counter("trace.evicted"), s.evicted);
        let entries = snap
            .get("gauges")
            .unwrap()
            .get("trace.entries")
            .unwrap()
            .get("value")
            .unwrap()
            .as_u64()
            .unwrap();
        assert_eq!(entries as usize, s.entries);
        store.clear();
        assert_eq!(store.stats(), TraceStoreStats::default());
        assert_eq!(reg.counter("trace.requests").get(), 0, "clear resets registry");
    }
}
