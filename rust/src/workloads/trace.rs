//! Shared immutable epoch-trace store for the tiering study.
//!
//! The fig16/fig17 policy×placement grids and the fleet scenarios
//! evaluate the *same* workload trace under many policy×placement
//! combinations. Before this module every grid cell and every fleet
//! member seeded its own [`TraceGen`] and regenerated the identical
//! epoch stream — at fleet scale, by far the dominant redundant work.
//!
//! [`EpochTrace`] is one fully materialized trace: the per-page access
//! histogram of every epoch, flattened `[epoch][page]`, immutable once
//! built. [`TraceStore`] hands out `Arc<EpochTrace>` snapshots keyed by
//! [`TraceKey`] — `(app, pages, epochs, drift, seed)` plus the
//! remaining histogram-shaping model fields — generating each key **at
//! most once per process**: generation happens under the store lock, so
//! concurrent grid cells racing on a cold key still produce a single
//! generation, and every requester gets a pointer-equal `Arc` (pinned
//! by test).
//!
//! Lifetime and memory bound: the process-global store
//! ([`global`]) retains snapshots LRU-evicted to
//! [`DEFAULT_BUDGET_BYTES`] at insert time (a full-size fig16 app
//! trace — 65 000 pages × 10 epochs — is ~2.6 MB, so the default
//! budget holds on the order of a hundred distinct fleet keys).
//! Eviction only drops the store's own handle; outstanding `Arc`s keep
//! their snapshot alive until the last cell finishes replaying it. The
//! scenario batch runner additionally calls [`TraceStore::trim`] after
//! each batch, releasing snapshots nobody holds anymore down to an
//! idle watermark so long-lived fleet processes don't pin a full
//! budget of cold traces between batches.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::tiering_apps::{AppModel, TraceGen};

/// Default byte budget for the process-global store.
pub const DEFAULT_BUDGET_BYTES: usize = 256 << 20;

/// Identity of one generated trace. Two models that differ only in
/// fields that never enter the histogram (`compute_ns_per_access`)
/// share a key; everything that shapes the access stream — page count,
/// hot-set geometry, drift, skew, epoch budget, RNG seed — is part of
/// it. Float fields enter as their IEEE-754 bit patterns so the key is
/// totally ordered and exact.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TraceKey {
    app: String,
    pages: u64,
    epochs: u64,
    seed: u64,
    drift_bits: u64,
    shape_bits: [u64; 3],
    flags: u8,
}

impl TraceKey {
    pub fn of(model: &AppModel, epochs: usize, seed: u64) -> TraceKey {
        TraceKey {
            app: model.name.to_string(),
            pages: model.pages as u64,
            epochs: epochs as u64,
            seed,
            drift_bits: model.drift.to_bits(),
            shape_bits: [
                model.hot_frac.to_bits(),
                model.hot_share.to_bits(),
                model.accesses_per_epoch,
            ],
            flags: model.scattered as u8 | (model.hot_skewed as u8) << 1,
        }
    }
}

/// One immutable, fully materialized epoch trace.
///
/// Epochs are recorded in the order the fig16 producer emits them:
/// epoch `e`'s histogram, then one [`TraceGen::drift`] step — so a
/// replay is bit-identical to driving the generator live (pinned by the
/// parity test below).
#[derive(Clone, Debug)]
pub struct EpochTrace {
    pages: usize,
    epochs: usize,
    /// Distance between consecutive epochs in `counts`: `pages` for a
    /// generated trace, 0 for a constant trace (every epoch is the same
    /// shared slice — fig17's uniform-scan workloads).
    stride: usize,
    counts: Vec<u32>,
}

impl EpochTrace {
    /// Materialize `epochs` epochs of `model` under `seed`, driving the
    /// incremental generator exactly as the live fig16 producer does.
    pub fn generate(model: &AppModel, epochs: usize, seed: u64) -> EpochTrace {
        let mut gen = TraceGen::new(model.clone(), seed);
        let mut counts = Vec::with_capacity(epochs * model.pages);
        let mut buf = Vec::new();
        for _ in 0..epochs {
            gen.epoch_counts_into(&mut buf);
            counts.extend_from_slice(&buf);
            gen.drift();
        }
        EpochTrace {
            pages: model.pages,
            epochs,
            stride: model.pages,
            counts,
        }
    }

    /// A trace whose every epoch is the same histogram (fig17's
    /// constant uniform scans), stored once.
    pub fn constant(counts: Vec<u32>, epochs: usize) -> EpochTrace {
        EpochTrace {
            pages: counts.len(),
            epochs,
            stride: 0,
            counts,
        }
    }

    /// Per-page access counts of epoch `e`.
    pub fn epoch(&self, e: usize) -> &[u32] {
        assert!(e < self.epochs, "epoch {e} out of range ({})", self.epochs);
        let base = e * self.stride;
        &self.counts[base..base + self.pages]
    }

    pub fn pages(&self) -> usize {
        self.pages
    }

    pub fn epochs(&self) -> usize {
        self.epochs
    }

    /// Heap footprint (the store's budget currency).
    pub fn bytes(&self) -> usize {
        self.counts.len() * std::mem::size_of::<u32>()
    }
}

struct Entry {
    trace: Arc<EpochTrace>,
    last_use: u64,
}

#[derive(Default)]
struct Inner {
    map: BTreeMap<TraceKey, Entry>,
    bytes: usize,
    tick: u64,
    requests: u64,
    generated: u64,
    evicted: u64,
}

/// Store counters (`cxlmem trace-smoke` gates on `generated`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStoreStats {
    /// Total `get` calls.
    pub requests: u64,
    /// Traces generated (requests that missed).
    pub generated: u64,
    /// Entries dropped by the LRU budget.
    pub evicted: u64,
    /// Entries currently held.
    pub entries: usize,
    /// Bytes currently held.
    pub bytes: usize,
}

/// Keyed store of immutable trace snapshots; see the module docs for
/// keying, lifetime, and the memory bound.
pub struct TraceStore {
    budget: usize,
    inner: Mutex<Inner>,
}

impl TraceStore {
    pub fn with_budget(budget: usize) -> TraceStore {
        TraceStore {
            budget,
            inner: Mutex::new(Inner::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panicked holder leaves consistent data (all mutation is
        // counter/map bookkeeping) — recover instead of poisoning every
        // later grid cell.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The snapshot for `(model, epochs, seed)`, generated on first
    /// request and shared (pointer-equal) afterwards. Generation runs
    /// under the store lock: exactly one generation per key per
    /// process, however many cells race here. The deliberate trade-off
    /// is that cold *distinct* keys also serialize through the lock —
    /// acceptable because one generation is an O(epochs × pages) fill
    /// (milliseconds) while the evaluation that follows each fetch is
    /// orders of magnitude larger, and it keeps the single-generation
    /// counter exact without per-key once-cells.
    pub fn get(&self, model: &AppModel, epochs: usize, seed: u64) -> Arc<EpochTrace> {
        let key = TraceKey::of(model, epochs, seed);
        let mut inner = self.lock();
        inner.requests += 1;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            e.last_use = tick;
            return Arc::clone(&e.trace);
        }
        let trace = Arc::new(EpochTrace::generate(model, epochs, seed));
        inner.generated += 1;
        inner.bytes += trace.bytes();
        let entry = Entry {
            trace: Arc::clone(&trace),
            last_use: tick,
        };
        inner.map.insert(key, entry);
        Self::evict_over(&mut inner, self.budget);
        trace
    }

    /// Post-batch maintenance: drop snapshots nobody outside the store
    /// still holds (`Arc` strong count 1), oldest first, down to a
    /// quarter-budget idle watermark — so a long-lived fleet process
    /// does not pin a full budget of cold traces between batches. The
    /// *hard* bound is the insert-time LRU eviction in [`TraceStore::get`];
    /// this only reclaims idle memory earlier.
    pub fn trim(&self) {
        let mut inner = self.lock();
        let watermark = self.budget / 4;
        if inner.bytes <= watermark {
            return;
        }
        let mut idle: Vec<(u64, TraceKey)> = inner
            .map
            .iter()
            .filter(|(_, e)| Arc::strong_count(&e.trace) == 1)
            .map(|(k, e)| (e.last_use, k.clone()))
            .collect();
        idle.sort();
        for (_, key) in idle {
            if inner.bytes <= watermark {
                break;
            }
            if let Some(e) = inner.map.remove(&key) {
                inner.bytes -= e.trace.bytes();
                inner.evicted += 1;
            }
        }
    }

    fn evict_over(inner: &mut Inner, budget: usize) {
        while inner.bytes > budget && inner.map.len() > 1 {
            let key = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            if let Some(e) = inner.map.remove(&key) {
                inner.bytes -= e.trace.bytes();
                inner.evicted += 1;
            }
        }
    }

    /// Drop every entry and reset all counters (the trace-smoke gate
    /// starts from a clean store).
    pub fn clear(&self) {
        *self.lock() = Inner::default();
    }

    pub fn stats(&self) -> TraceStoreStats {
        let inner = self.lock();
        TraceStoreStats {
            requests: inner.requests,
            generated: inner.generated,
            evicted: inner.evicted,
            entries: inner.map.len(),
            bytes: inner.bytes,
        }
    }
}

/// The process-global store every grid cell and fleet member shares.
pub fn global() -> &'static TraceStore {
    static GLOBAL: OnceLock<TraceStore> = OnceLock::new();
    GLOBAL.get_or_init(|| TraceStore::with_budget(DEFAULT_BUDGET_BYTES))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::par::par_map;
    use crate::workloads::tiering_apps::{graph500, pagerank};

    fn small(mut app: AppModel, pages: usize) -> AppModel {
        app.pages = pages;
        app
    }

    #[test]
    fn generate_matches_live_producer_bit_exactly() {
        // A replayed snapshot must be indistinguishable from driving
        // the generator live, epoch by epoch (the fig16 producer
        // order: counts, then drift).
        let app = small(graph500(), 2_000);
        let trace = EpochTrace::generate(&app, 6, 17);
        let mut gen = TraceGen::new(app, 17);
        let mut buf = Vec::new();
        for e in 0..6 {
            gen.epoch_counts_into(&mut buf);
            assert_eq!(trace.epoch(e), &buf[..], "epoch {e}");
            gen.drift();
        }
    }

    #[test]
    fn store_returns_pointer_equal_snapshots() {
        let store = TraceStore::with_budget(DEFAULT_BUDGET_BYTES);
        let app = small(pagerank(), 1_000);
        let a = store.get(&app, 4, 7);
        let b = store.get(&app, 4, 7);
        assert!(Arc::ptr_eq(&a, &b));
        let s = store.stats();
        assert_eq!((s.requests, s.generated, s.entries), (2, 1, 1));
        // A different seed is a different key — and a different trace.
        let c = store.get(&app, 4, 8);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(store.stats().generated, 2);
    }

    #[test]
    fn grid_cells_share_one_snapshot_across_workers() {
        // Mirrors the fig16 fan-out: parallel cells requesting the same
        // key must observe pointer-equal Arcs from one generation.
        let store = TraceStore::with_budget(DEFAULT_BUDGET_BYTES);
        let app = small(graph500(), 1_500);
        let cells: Vec<usize> = (0..8).collect();
        let arcs = par_map(&cells, 4, |_| store.get(&app, 5, 3));
        for arc in &arcs[1..] {
            assert!(Arc::ptr_eq(&arcs[0], arc));
        }
        let s = store.stats();
        assert_eq!(s.generated, 1, "racing cells must not regenerate");
        assert_eq!(s.requests, 8);
    }

    #[test]
    fn key_separates_shape_not_compute() {
        let base = small(pagerank(), 800);
        let mut compute_only = base.clone();
        compute_only.compute_ns_per_access *= 2.0;
        assert_eq!(TraceKey::of(&base, 3, 1), TraceKey::of(&compute_only, 3, 1));
        let mut drifted = base.clone();
        drifted.drift = 0.25;
        assert_ne!(TraceKey::of(&base, 3, 1), TraceKey::of(&drifted, 3, 1));
        assert_ne!(TraceKey::of(&base, 3, 1), TraceKey::of(&base, 4, 1));
    }

    #[test]
    fn lru_budget_evicts_oldest_key() {
        let app = small(pagerank(), 1_000);
        let one = EpochTrace::generate(&app, 2, 1).bytes();
        // Room for one trace only: the second insert evicts the first.
        let store = TraceStore::with_budget(one);
        let a = store.get(&app, 2, 1);
        let _b = store.get(&app, 2, 2);
        let s = store.stats();
        assert_eq!((s.evicted, s.entries), (1, 1));
        assert!(s.bytes <= one);
        // The evicted snapshot stays alive through its Arc…
        assert_eq!(a.epochs(), 2);
        // …and a re-request regenerates it.
        let a2 = store.get(&app, 2, 1);
        assert!(!Arc::ptr_eq(&a, &a2));
        assert_eq!(a.epoch(1), a2.epoch(1), "regeneration is deterministic");
    }

    #[test]
    fn trim_releases_idle_snapshots_to_the_watermark() {
        let app = small(pagerank(), 1_000);
        let one = EpochTrace::generate(&app, 2, 1).bytes();
        // All three entries fit the insert-time budget; the idle
        // watermark is budget/4 = one trace.
        let store = TraceStore::with_budget(4 * one);
        store.get(&app, 2, 1); // returned Arc dropped at once — idle
        let held = store.get(&app, 2, 2);
        store.get(&app, 2, 3); // idle
        store.trim();
        let s = store.stats();
        // Idle snapshots go oldest-first until the watermark is met;
        // the held one survives whatever its age.
        assert_eq!((s.evicted, s.entries), (2, 1));
        assert_eq!(s.bytes, one);
        assert!(Arc::ptr_eq(&held, &store.get(&app, 2, 2)));
    }

    #[test]
    fn constant_trace_shares_one_slice() {
        let t = EpochTrace::constant(vec![3, 1, 4, 1, 5], 10);
        assert_eq!(t.pages(), 5);
        assert_eq!(t.epochs(), 10);
        assert_eq!(t.bytes(), 5 * 4);
        assert_eq!(t.epoch(0), t.epoch(9));
        assert!(std::ptr::eq(t.epoch(0).as_ptr(), t.epoch(9).as_ptr()));
    }

    #[test]
    fn clear_resets_entries_and_counters() {
        let store = TraceStore::with_budget(DEFAULT_BUDGET_BYTES);
        let app = small(pagerank(), 500);
        store.get(&app, 2, 1);
        store.clear();
        assert_eq!(store.stats(), TraceStoreStats::default());
    }
}
