//! XSBench — Monte Carlo neutron-transport macroscopic cross-section
//! lookup kernel (Table III row 7).
//!
//! Signature: repeated random trials; most accesses concentrate in a
//! small, latency-sensitive index structure, while the large nuclide
//! grids receive scattered random reads. This is why the paper finds
//! LDRAM-preferred beats both uniform and object-level interleaving for
//! XSBench (§V-B, OLI observation 2 discussion).

use super::{HpcWorkload, WlObject};
use crate::memsim::Pattern::{Random, Sequential};

pub fn xsbench() -> HpcWorkload {
    HpcWorkload {
        name: "XSBench",
        dwarf: "Monte Carlo",
        characterization: "Computation based on repeated random trials",
        input: "Extra large",
        objects: vec![
            // The big grids: large + most total accesses → OLI selects
            // them (Table III's "nuclide grids")...
            WlObject::new("nuclide_grids", 60.0, Random, 2.0, 0.45),
            // ...but the hot set is a small latency-critical index
            // (< 10% footprint, so OLI correctly does NOT interleave it —
            // yet interleaving the grids still hurts the lookups).
            WlObject::new("unionized_index", 9.0, Random, 6.0, 0.85),
            WlObject::new("ws_rest", 47.0, Sequential, 0.2, 0.05),
        ],
        compute_ns_per_byte: 0.55,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::oli::select_bw_hungry;

    #[test]
    fn oli_selects_only_the_grids() {
        let w = xsbench();
        let specs: Vec<_> = w.objects.iter().map(|o| o.spec.clone()).collect();
        let sel = select_bw_hungry(&specs);
        assert_eq!(sel, vec![true, false, false]);
    }

    #[test]
    fn hot_index_is_latency_critical() {
        let w = xsbench();
        let idx = &w.objects[1];
        assert!(idx.spec.dep_frac > 0.8);
        assert!((idx.spec.bytes as f64) < 0.1 * w.footprint_bytes() as f64);
    }
}
