//! Workload models.
//!
//! [`npb`]/[`xsbench`] carry the seven HPC workloads of Table III as
//! access-signature models; [`tiering_apps`] carries the four
//! memory-intensive applications of §VI (BTree, PageRank, Graph500,
//! Silo) as page-granular trace generators for the tiering study;
//! [`trace`] is the shared immutable epoch-trace store that lets one
//! generated trace serve an entire policy×placement grid or fleet.

pub mod npb;
pub mod tiering_apps;
pub mod trace;
pub mod xsbench;

use anyhow::Result;

use crate::engine::{self, ObjectTraffic, RunConfig, RunResult};
use crate::mem::{oli::ObjectSpec, AddressSpace, PhysMem, Policy};
use crate::memsim::{Pattern, System};

/// One modeled data object of an HPC workload.
#[derive(Clone, Debug)]
pub struct WlObject {
    pub spec: ObjectSpec,
    pub pattern: Pattern,
    /// Object traffic per timed iteration, as a multiple of its size
    /// (how many times the object is effectively scanned).
    pub scans: f64,
}

impl WlObject {
    pub fn new(
        name: &str,
        gbytes: f64,
        pattern: Pattern,
        scans: f64,
        dep_frac: f64,
    ) -> Self {
        let bytes = (gbytes * 1e9) as u64;
        Self {
            // `accesses` drives OLI's intensity criterion: total traffic.
            spec: ObjectSpec::new(name, bytes, gbytes * scans, dep_frac),
            pattern,
            scans,
        }
    }

    pub fn traffic_bytes(&self) -> f64 {
        self.spec.bytes as f64 * self.scans
    }
}

/// An HPC workload model (one row of Table III).
#[derive(Clone, Debug)]
pub struct HpcWorkload {
    pub name: &'static str,
    pub dwarf: &'static str,
    pub characterization: &'static str,
    pub input: &'static str,
    pub objects: Vec<WlObject>,
    pub compute_ns_per_byte: f64,
}

impl HpcWorkload {
    pub fn footprint_bytes(&self) -> u64 {
        self.objects.iter().map(|o| o.spec.bytes).sum()
    }

    pub fn specs(&self) -> Vec<ObjectSpec> {
        self.objects.iter().map(|o| o.spec.clone()).collect()
    }

    /// Allocate all objects with per-object policies and run one timed
    /// iteration. `policy_for(i, spec)` supplies each object's policy.
    pub fn run_with(
        &self,
        sys: &System,
        socket: usize,
        threads: usize,
        phys: &mut PhysMem,
        policy_for: &dyn Fn(usize, &ObjectSpec) -> Policy,
    ) -> Result<RunResult> {
        let mut asp = AddressSpace::new();
        let mut traffic = Vec::with_capacity(self.objects.len());
        for (i, o) in self.objects.iter().enumerate() {
            let policy = policy_for(i, &o.spec);
            let id = asp.alloc(sys, phys, socket, &o.spec.name, o.spec.bytes, policy)?;
            traffic.push(ObjectTraffic {
                name: o.spec.name.clone(),
                traffic_bytes: o.traffic_bytes(),
                pattern: o.pattern,
                dep_frac: o.spec.dep_frac,
                node_weights: asp.object(id).node_weights_in(sys.nodes.len()),
            });
        }
        let cfg = RunConfig {
            socket,
            threads,
            compute_ns_per_byte: self.compute_ns_per_byte,
        };
        let result = engine::run(sys, &cfg, &traffic);
        // Release pages so the caller can reuse `phys` for the next policy.
        for id in 0..asp.objects.len() {
            asp.free(phys, id);
        }
        Ok(result)
    }

    /// Run with a single uniform policy for every object.
    pub fn run_uniform(
        &self,
        sys: &System,
        socket: usize,
        threads: usize,
        phys: &mut PhysMem,
        policy: &Policy,
    ) -> Result<RunResult> {
        self.run_with(sys, socket, threads, phys, &|_, _| policy.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::npb::all_hpc_workloads;
    use super::*;
    use crate::mem::policy;
    use crate::memsim::topology::system_a;

    #[test]
    fn footprints_match_table3() {
        // Table III memory footprints (GB): BT 166, LU 134, CG 134,
        // MG 210, SP 174, FT 80, XSBench 116.
        let expect = [
            ("BT", 166.0),
            ("LU", 134.0),
            ("CG", 134.0),
            ("MG", 210.0),
            ("SP", 174.0),
            ("FT", 80.0),
            ("XSBench", 116.0),
        ];
        for (wl, (name, gb)) in all_hpc_workloads().iter().zip(expect) {
            assert_eq!(wl.name, name);
            let fp = wl.footprint_bytes() as f64 / 1e9;
            assert!((fp - gb).abs() < 2.0, "{name}: {fp} vs {gb}");
        }
    }

    #[test]
    fn run_uniform_produces_time() {
        let sys = system_a();
        let mut phys = PhysMem::of_system(&sys);
        let wl = &all_hpc_workloads()[0];
        let r = wl
            .run_uniform(&sys, 0, 32, &mut phys, &policy::ldram_preferred(&sys, 0))
            .unwrap();
        assert!(r.total_s > 0.0);
        // pages were freed
        assert_eq!(phys.total_used(), 0);
    }

    #[test]
    fn bw_hungry_objects_match_table3() {
        // Table III last column: the objects OLI selects.
        use crate::mem::oli::select_bw_hungry;
        let expect: &[(&str, &[&str])] = &[
            ("BT", &["u", "rsh", "forcing"]),
            ("LU", &["u", "rsd"]),
            ("CG", &["a"]),
            ("MG", &["v", "r"]),
            ("SP", &["u", "rsh", "forcing"]),
            ("FT", &["u0", "u1"]),
            ("XSBench", &["nuclide_grids"]),
        ];
        for (wl, (name, objs)) in all_hpc_workloads().iter().zip(expect) {
            assert_eq!(&wl.name, name);
            let sel = select_bw_hungry(&wl.specs());
            let picked: Vec<&str> = wl
                .objects
                .iter()
                .zip(&sel)
                .filter(|&(_, &s)| s)
                .map(|(o, _)| o.spec.name.as_str())
                .collect();
            assert_eq!(&picked, objs, "{name}");
        }
    }
}
