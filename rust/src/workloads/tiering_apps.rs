//! Page-access trace generators for the memory-tiering study (§VI-A):
//! BTree, PageRank, Graph500, Silo.
//!
//! Each application is modeled by the *shape* of its page-hotness
//! distribution — the property the paper identifies as deciding which
//! tiering solution wins:
//! - BTree: irregular accesses, effectively uniform over the working set
//!   (no solution helps; variance < 3%).
//! - PageRank: small and *stable* hot page set → first-touch without
//!   migration wins (hot pages land in LDRAM early and stay hot).
//! - Graph500: hot pages scattered and drifting across the working set →
//!   hotness tracking must adapt; interleaving helps.
//! - Silo: B-tree-like index gathers hot records into few pages →
//!   small concentrated hot set, mild drift; first touch effective.
//!
//! Hot-path structure: a generator's histogram is *incremental*. The
//! per-rank hot access counts and the cold-uniform base are fixed for the
//! lifetime of the generator (they depend only on the model), so the full
//! histogram is built once at construction and [`TraceGen::drift`] applies
//! only the ± delta of each replaced hot page — producing an epoch is an
//! O(pages) copy with zero recomputation (and O(drifted) maintenance on
//! drift; drift = 0 apps like PageRank pay nothing between epochs).
//! Under [`crate::perf::with_reference`] every epoch instead regenerates
//! the histogram from scratch, seed-style (weight table recomputed per
//! call); the two paths are bit-identical — integer counts, same
//! deterministic rank assignment — which the parity tests pin.
//!
//! Access counts conserve exactly: the per-rank hot counts are assigned
//! by cumulative rounding (largest share to the lowest ranks, remainder
//! absorbed deterministically) and the cold base distributes its integer
//! remainder to the lowest page indices, so every epoch's histogram sums
//! to precisely `accesses_per_epoch`.

use crate::util::rng::Rng;

/// A tiering-study application model.
#[derive(Clone, Debug)]
pub struct AppModel {
    pub name: &'static str,
    /// Working-set size in pages (2 MB regions).
    pub pages: usize,
    /// Fraction of pages forming the hot set.
    pub hot_frac: f64,
    /// Share of accesses that hit the hot set.
    pub hot_share: f64,
    /// Fraction of the hot set replaced each epoch (0 = perfectly stable).
    pub drift: f64,
    /// Whether hot pages are scattered across the address space (true)
    /// or clustered at low addresses / allocation order (false).
    pub scattered: bool,
    /// Whether accesses within the hot set are skewed (zipf-like) or
    /// flat (BTree's irregular lookups).
    pub hot_skewed: bool,
    /// Page accesses per epoch (drives absolute epoch time).
    pub accesses_per_epoch: u64,
    /// CPU ns per access (compute between memory touches).
    pub compute_ns_per_access: f64,
}

/// 130 GB working set in 2 MB pages (the paper's §VI configuration).
pub const WSS_PAGES: usize = 65_000;

pub fn btree() -> AppModel {
    AppModel {
        name: "BTree",
        pages: WSS_PAGES,
        hot_frac: 0.85, // effectively the whole set is lukewarm
        hot_share: 0.90,
        drift: 0.30,
        scattered: true,
        hot_skewed: false,
        accesses_per_epoch: 220_000_000,
        compute_ns_per_access: 55.0,
    }
}

pub fn pagerank() -> AppModel {
    AppModel {
        name: "PageRank",
        pages: WSS_PAGES,
        hot_frac: 0.10, // small...
        hot_share: 0.85,
        drift: 0.0, // ...and perfectly stable hot set
        scattered: false,
        hot_skewed: true,
        accesses_per_epoch: 260_000_000,
        compute_ns_per_access: 30.0,
    }
}

pub fn graph500() -> AppModel {
    AppModel {
        name: "Graph500",
        pages: WSS_PAGES,
        hot_frac: 0.25,
        hot_share: 0.75,
        drift: 0.35, // hot pages wander (BFS frontier)
        scattered: true,
        hot_skewed: true,
        accesses_per_epoch: 240_000_000,
        compute_ns_per_access: 35.0,
    }
}

pub fn silo() -> AppModel {
    AppModel {
        name: "Silo",
        pages: WSS_PAGES,
        hot_frac: 0.06, // index gathers hot records into few pages
        hot_share: 0.80,
        drift: 0.08,
        scattered: false,
        hot_skewed: true,
        accesses_per_epoch: 200_000_000,
        compute_ns_per_access: 70.0,
    }
}

pub fn all_apps() -> Vec<AppModel> {
    vec![btree(), pagerank(), graph500(), silo()]
}

/// Split one epoch's accesses: `(hot_total, per_cold, cold_rem)`.
/// The cold share is `per_cold` on every page plus one extra access on
/// the first `cold_rem` pages, so hot + cold always sums exactly to
/// `accesses_per_epoch`. An empty hot set folds its share into cold.
fn access_split(model: &AppModel, hot_n: usize) -> (u64, u32, usize) {
    let mut hot_total = (model.accesses_per_epoch as f64 * model.hot_share) as u64;
    if hot_n == 0 {
        hot_total = 0;
    }
    let cold_total = model.accesses_per_epoch - hot_total;
    if model.pages == 0 {
        return (hot_total, 0, 0);
    }
    let pages = model.pages as u64;
    (
        hot_total,
        (cold_total / pages) as u32,
        (cold_total % pages) as usize,
    )
}

/// Per-rank hot access counts, summing to exactly `hot_total`.
///
/// Skewed models keep the seed's zipf-ish `1/sqrt(rank)` weights but
/// assign them by cumulative rounding: rank r receives
/// `round(hot_total * W(r)) - round(hot_total * W(r-1))` (cumulative
/// normalized weight `W`), with the final rank pinned to `hot_total` so
/// the truncation the seed silently dropped (up to ~5% of accesses) is
/// redistributed deterministically. Flat models split integrally, with
/// the remainder going to the lowest ranks.
///
/// Deterministic in `(model, hot_n)`: the reference path recomputes this
/// table every epoch (seed semantics) and gets bit-identical counts to
/// the table the optimized path builds once at construction.
fn build_rank_counts(model: &AppModel, hot_n: usize) -> Vec<u32> {
    let (hot_total, _, _) = access_split(model, hot_n);
    if hot_n == 0 {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(hot_n);
    if model.hot_skewed {
        let norm: f64 = (1..=hot_n).map(|r| 1.0 / (r as f64).sqrt()).sum();
        let mut cum = 0.0f64;
        let mut assigned = 0u64;
        for rank in 0..hot_n {
            cum += 1.0 / ((rank + 1) as f64).sqrt();
            // Cumulative targets are monotone (round of a non-decreasing
            // product); the last one is exact by construction.
            let target = if rank + 1 == hot_n {
                hot_total
            } else {
                (((hot_total as f64) * (cum / norm)).round() as u64).min(hot_total)
            };
            let c = target.saturating_sub(assigned);
            assigned += c;
            out.push(c as u32);
        }
    } else {
        let per = (hot_total / hot_n as u64) as u32;
        let rem = (hot_total % hot_n as u64) as usize;
        for rank in 0..hot_n {
            out.push(per + u32::from(rank < rem));
        }
    }
    out
}

/// Full histogram regeneration into `buf`: branch-free fills for the
/// cold-uniform base (two `fill` runs the autovectorizer turns into wide
/// stores), then the per-rank hot scatter. Shared by construction and
/// the reference path, so the incrementally-maintained histogram always
/// has a bit-identical from-scratch oracle.
fn fill_counts(
    buf: &mut Vec<u32>,
    pages: usize,
    per_cold: u32,
    cold_rem: usize,
    hot_set: &[u32],
    rank_counts: &[u32],
) {
    buf.clear();
    buf.resize(pages, per_cold);
    buf[..cold_rem.min(pages)].fill(per_cold + 1);
    for (&p, &c) in hot_set.iter().zip(rank_counts) {
        buf[p as usize] += c;
    }
}

/// Evolving hot-set state + per-epoch access histogram generation.
pub struct TraceGen {
    pub model: AppModel,
    hot_set: Vec<u32>,
    rng: Rng,
    /// Per-rank hot access counts (fixed: ranks keep their share as the
    /// pages under them drift).
    rank_counts: Vec<u32>,
    /// Cold-uniform base per page + pages receiving one extra access.
    per_cold: u32,
    cold_rem: usize,
    /// The current hot set's histogram, maintained incrementally by
    /// [`TraceGen::drift`].
    counts: Vec<u32>,
}

impl TraceGen {
    pub fn new(model: AppModel, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let hot_n = ((model.pages as f64) * model.hot_frac).round() as usize;
        let hot_set = if model.scattered {
            // Hot pages uniformly scattered over the address space.
            let mut all: Vec<u32> = (0..model.pages as u32).collect();
            rng.shuffle(&mut all);
            all.truncate(hot_n);
            all
        } else {
            // Allocation-order clustering: the first-allocated pages are
            // the hot ones (graph/index structures built first).
            (0..hot_n as u32).collect()
        };
        let rank_counts = build_rank_counts(&model, hot_set.len());
        let (_, per_cold, cold_rem) = access_split(&model, hot_set.len());
        let mut counts = Vec::new();
        fill_counts(
            &mut counts,
            model.pages,
            per_cold,
            cold_rem,
            &hot_set,
            &rank_counts,
        );
        Self {
            model,
            hot_set,
            rng,
            rank_counts,
            per_cold,
            cold_rem,
            counts,
        }
    }

    pub fn hot_set(&self) -> &[u32] {
        &self.hot_set
    }

    /// Advance the hot set by one epoch of drift, applying only the
    /// ± delta of each replaced page to the maintained histogram —
    /// O(drifted) total, O(1) for drift-free apps (PageRank).
    pub fn drift(&mut self) {
        let n_replace = (self.hot_set.len() as f64 * self.model.drift).round() as usize;
        for _ in 0..n_replace {
            let idx = self.rng.index(self.hot_set.len());
            let new = self.rng.below(self.model.pages as u64) as u32;
            let old = self.hot_set[idx];
            self.hot_set[idx] = new;
            // The rank keeps its count; only the page under it moves.
            let c = self.rank_counts[idx];
            self.counts[old as usize] -= c;
            self.counts[new as usize] += c;
        }
    }

    /// Fill `buf` with this epoch's per-page access counts. Hot pages
    /// share `hot_share` of accesses (zipf-skewed within the hot set);
    /// the rest spread uniformly; totals are exact.
    ///
    /// Optimized path: one O(pages) copy of the incrementally-maintained
    /// histogram, zero recomputation. Under
    /// [`crate::perf::with_reference`]: full seed-style regeneration,
    /// weight table recomputed every call.
    pub fn epoch_counts_into(&self, buf: &mut Vec<u32>) {
        if crate::perf::reference_enabled() {
            let rank_counts = build_rank_counts(&self.model, self.hot_set.len());
            let (_, per_cold, cold_rem) = access_split(&self.model, self.hot_set.len());
            fill_counts(
                buf,
                self.model.pages,
                per_cold,
                cold_rem,
                &self.hot_set,
                &rank_counts,
            );
            return;
        }
        debug_assert_eq!(per_cold_check(self), (self.per_cold, self.cold_rem));
        buf.clear();
        buf.extend_from_slice(&self.counts);
    }

    /// Allocating convenience wrapper around
    /// [`TraceGen::epoch_counts_into`].
    pub fn epoch_counts(&self) -> Vec<u32> {
        let mut buf = Vec::new();
        self.epoch_counts_into(&mut buf);
        buf
    }
}

/// Debug-build invariant: the cached cold split never drifts from a
/// recomputation (the hot-set *size* is fixed for a generator's life).
fn per_cold_check(g: &TraceGen) -> (u32, usize) {
    let (_, per_cold, cold_rem) = access_split(&g.model, g.hot_set.len());
    (per_cold, cold_rem)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_apps() {
        let names: Vec<&str> = all_apps().iter().map(|a| a.name).collect();
        assert_eq!(names, vec!["BTree", "PageRank", "Graph500", "Silo"]);
    }

    #[test]
    fn pagerank_hot_set_is_stable() {
        let mut g = TraceGen::new(pagerank(), 1);
        let before = g.hot_set().to_vec();
        g.drift();
        assert_eq!(g.hot_set(), &before[..]);
    }

    #[test]
    fn graph500_hot_set_drifts() {
        let mut g = TraceGen::new(graph500(), 1);
        let before = g.hot_set().to_vec();
        g.drift();
        let moved = g
            .hot_set()
            .iter()
            .zip(&before)
            .filter(|(a, b)| a != b)
            .count();
        assert!(moved > before.len() / 10);
    }

    #[test]
    fn clustered_apps_have_low_hot_pages() {
        let g = TraceGen::new(silo(), 2);
        let max = *g.hot_set().iter().max().unwrap() as usize;
        assert!(max < WSS_PAGES / 10); // clustered at allocation order
    }

    #[test]
    fn epoch_counts_conserve_accesses_exactly() {
        // The seed tolerated ~5% truncation loss; the cumulative-rounding
        // assignment conserves exactly — for every app, and across drift.
        for app in all_apps() {
            let mut g = TraceGen::new(app, 3);
            for epoch in 0..4 {
                let counts = g.epoch_counts();
                let total: u64 = counts.iter().map(|&c| c as u64).sum();
                assert_eq!(total, g.model.accesses_per_epoch, "{} epoch {epoch}", g.model.name);
                g.drift();
            }
        }
    }

    #[test]
    fn rank_counts_sum_and_skew() {
        let app = graph500();
        let hot_n = ((app.pages as f64) * app.hot_frac).round() as usize;
        let rc = build_rank_counts(&app, hot_n);
        let (hot_total, _, _) = access_split(&app, hot_n);
        assert_eq!(rc.iter().map(|&c| c as u64).sum::<u64>(), hot_total);
        // zipf-ish: rank 0 far hotter than the median rank.
        assert!(rc[0] > 10 * rc[hot_n / 2].max(1));
    }

    #[test]
    fn incremental_matches_full_regeneration() {
        // The tentpole's parity oracle: across 50 epochs, the maintained
        // histogram must be bit-identical to a from-scratch regeneration
        // for every app at drift 0 / low / high.
        for base in all_apps() {
            for drift in [0.0, 0.05, 0.5] {
                let mut app = base.clone();
                app.drift = drift;
                app.pages = 6_000; // keep 12 generators × 50 epochs quick
                let mut g = TraceGen::new(app, 21);
                let mut opt = Vec::new();
                let mut full = Vec::new();
                for epoch in 0..50 {
                    g.epoch_counts_into(&mut opt);
                    crate::perf::with_reference(|| g.epoch_counts_into(&mut full));
                    assert_eq!(opt, full, "{} drift={drift} epoch={epoch}", g.model.name);
                    g.drift();
                }
            }
        }
    }

    #[test]
    fn epoch_counts_into_reuses_capacity() {
        let mut g = TraceGen::new(silo(), 8);
        let mut buf = Vec::new();
        g.epoch_counts_into(&mut buf);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        g.drift();
        g.epoch_counts_into(&mut buf);
        assert_eq!(buf.len(), g.model.pages);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf.as_ptr(), ptr, "refill must not reallocate");
    }

    #[test]
    fn hot_pages_hotter_than_cold() {
        let g = TraceGen::new(pagerank(), 4);
        let counts = g.epoch_counts();
        let hot0 = g.hot_set()[0] as usize;
        let cold = WSS_PAGES - 1; // clustered model: last page is cold
        assert!(counts[hot0] > 20 * counts[cold].max(1));
    }

    #[test]
    fn btree_is_near_uniform() {
        let g = TraceGen::new(btree(), 5);
        let counts = g.epoch_counts();
        let mean = counts.iter().map(|&c| c as f64).sum::<f64>() / counts.len() as f64;
        let hottest = *counts.iter().max().unwrap() as f64;
        assert!(hottest < 40.0 * mean, "hottest={hottest} mean={mean}");
    }
}
