//! Page-access trace generators for the memory-tiering study (§VI-A):
//! BTree, PageRank, Graph500, Silo.
//!
//! Each application is modeled by the *shape* of its page-hotness
//! distribution — the property the paper identifies as deciding which
//! tiering solution wins:
//! - BTree: irregular accesses, effectively uniform over the working set
//!   (no solution helps; variance < 3%).
//! - PageRank: small and *stable* hot page set → first-touch without
//!   migration wins (hot pages land in LDRAM early and stay hot).
//! - Graph500: hot pages scattered and drifting across the working set →
//!   hotness tracking must adapt; interleaving helps.
//! - Silo: B-tree-like index gathers hot records into few pages →
//!   small concentrated hot set, mild drift; first touch effective.

use crate::util::rng::Rng;

/// A tiering-study application model.
#[derive(Clone, Debug)]
pub struct AppModel {
    pub name: &'static str,
    /// Working-set size in pages (2 MB regions).
    pub pages: usize,
    /// Fraction of pages forming the hot set.
    pub hot_frac: f64,
    /// Share of accesses that hit the hot set.
    pub hot_share: f64,
    /// Fraction of the hot set replaced each epoch (0 = perfectly stable).
    pub drift: f64,
    /// Whether hot pages are scattered across the address space (true)
    /// or clustered at low addresses / allocation order (false).
    pub scattered: bool,
    /// Whether accesses within the hot set are skewed (zipf-like) or
    /// flat (BTree's irregular lookups).
    pub hot_skewed: bool,
    /// Page accesses per epoch (drives absolute epoch time).
    pub accesses_per_epoch: u64,
    /// CPU ns per access (compute between memory touches).
    pub compute_ns_per_access: f64,
}

/// 130 GB working set in 2 MB pages (the paper's §VI configuration).
pub const WSS_PAGES: usize = 65_000;

pub fn btree() -> AppModel {
    AppModel {
        name: "BTree",
        pages: WSS_PAGES,
        hot_frac: 0.85, // effectively the whole set is lukewarm
        hot_share: 0.90,
        drift: 0.30,
        scattered: true,
        hot_skewed: false,
        accesses_per_epoch: 220_000_000,
        compute_ns_per_access: 55.0,
    }
}

pub fn pagerank() -> AppModel {
    AppModel {
        name: "PageRank",
        pages: WSS_PAGES,
        hot_frac: 0.10, // small...
        hot_share: 0.85,
        drift: 0.0, // ...and perfectly stable hot set
        scattered: false,
        hot_skewed: true,
        accesses_per_epoch: 260_000_000,
        compute_ns_per_access: 30.0,
    }
}

pub fn graph500() -> AppModel {
    AppModel {
        name: "Graph500",
        pages: WSS_PAGES,
        hot_frac: 0.25,
        hot_share: 0.75,
        drift: 0.35, // hot pages wander (BFS frontier)
        scattered: true,
        hot_skewed: true,
        accesses_per_epoch: 240_000_000,
        compute_ns_per_access: 35.0,
    }
}

pub fn silo() -> AppModel {
    AppModel {
        name: "Silo",
        pages: WSS_PAGES,
        hot_frac: 0.06, // index gathers hot records into few pages
        hot_share: 0.80,
        drift: 0.08,
        scattered: false,
        hot_skewed: true,
        accesses_per_epoch: 200_000_000,
        compute_ns_per_access: 70.0,
    }
}

pub fn all_apps() -> Vec<AppModel> {
    vec![btree(), pagerank(), graph500(), silo()]
}

/// Evolving hot-set state + per-epoch access histogram generation.
pub struct TraceGen {
    pub model: AppModel,
    hot_set: Vec<u32>,
    rng: Rng,
}

impl TraceGen {
    pub fn new(model: AppModel, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let hot_n = ((model.pages as f64) * model.hot_frac).round() as usize;
        let hot_set = if model.scattered {
            // Hot pages uniformly scattered over the address space.
            let mut all: Vec<u32> = (0..model.pages as u32).collect();
            rng.shuffle(&mut all);
            all.truncate(hot_n);
            all
        } else {
            // Allocation-order clustering: the first-allocated pages are
            // the hot ones (graph/index structures built first).
            (0..hot_n as u32).collect()
        };
        Self {
            model,
            hot_set,
            rng,
        }
    }

    pub fn hot_set(&self) -> &[u32] {
        &self.hot_set
    }

    /// Advance the hot set by one epoch of drift.
    pub fn drift(&mut self) {
        let n_replace = (self.hot_set.len() as f64 * self.model.drift).round() as usize;
        for _ in 0..n_replace {
            let idx = self.rng.index(self.hot_set.len());
            self.hot_set[idx] = self.rng.below(self.model.pages as u64) as u32;
        }
    }

    /// Per-page access counts for one epoch. Hot pages share
    /// `hot_share` of accesses (zipf-skewed within the hot set); the
    /// rest spread uniformly.
    pub fn epoch_counts(&mut self) -> Vec<u32> {
        let m = &self.model;
        let mut counts = vec![0u32; m.pages];
        // Use expected-value assignment rather than per-access sampling:
        // deterministic and fast at 10^8 accesses per epoch.
        let hot_total = (m.accesses_per_epoch as f64 * m.hot_share) as u64;
        let cold_total = m.accesses_per_epoch - hot_total;
        // zipf-ish weights within the hot set
        let hn = self.hot_set.len();
        if hn > 0 {
            if m.hot_skewed {
                let norm: f64 = (1..=hn).map(|r| 1.0 / (r as f64).sqrt()).sum();
                for (rank, &p) in self.hot_set.iter().enumerate() {
                    let w = (1.0 / ((rank + 1) as f64).sqrt()) / norm;
                    counts[p as usize] += (hot_total as f64 * w) as u32;
                }
            } else {
                let per = (hot_total as f64 / hn as f64) as u32;
                for &p in &self.hot_set {
                    counts[p as usize] += per;
                }
            }
        }
        let per_cold = (cold_total as f64 / m.pages as f64).round() as u32;
        for c in counts.iter_mut() {
            *c += per_cold;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_apps() {
        let names: Vec<&str> = all_apps().iter().map(|a| a.name).collect();
        assert_eq!(names, vec!["BTree", "PageRank", "Graph500", "Silo"]);
    }

    #[test]
    fn pagerank_hot_set_is_stable() {
        let mut g = TraceGen::new(pagerank(), 1);
        let before = g.hot_set().to_vec();
        g.drift();
        assert_eq!(g.hot_set(), &before[..]);
    }

    #[test]
    fn graph500_hot_set_drifts() {
        let mut g = TraceGen::new(graph500(), 1);
        let before = g.hot_set().to_vec();
        g.drift();
        let moved = g
            .hot_set()
            .iter()
            .zip(&before)
            .filter(|(a, b)| a != b)
            .count();
        assert!(moved > before.len() / 10);
    }

    #[test]
    fn clustered_apps_have_low_hot_pages() {
        let g = TraceGen::new(silo(), 2);
        let max = *g.hot_set().iter().max().unwrap() as usize;
        assert!(max < WSS_PAGES / 10); // clustered at allocation order
    }

    #[test]
    fn epoch_counts_conserve_accesses_roughly() {
        let mut g = TraceGen::new(silo(), 3);
        let counts = g.epoch_counts();
        let total: u64 = counts.iter().map(|&c| c as u64).sum();
        let expect = g.model.accesses_per_epoch as f64;
        assert!((total as f64 - expect).abs() / expect < 0.05);
    }

    #[test]
    fn hot_pages_hotter_than_cold() {
        let mut g = TraceGen::new(pagerank(), 4);
        let counts = g.epoch_counts();
        let hot0 = g.hot_set()[0] as usize;
        let cold = WSS_PAGES - 1; // clustered model: last page is cold
        assert!(counts[hot0] > 20 * counts[cold].max(1));
    }

    #[test]
    fn btree_is_near_uniform() {
        let mut g = TraceGen::new(btree(), 5);
        let counts = g.epoch_counts();
        let mean = counts.iter().map(|&c| c as f64).sum::<f64>() / counts.len() as f64;
        let hottest = *counts.iter().max().unwrap() as f64;
        assert!(hottest < 40.0 * mean, "hottest={hottest} mean={mean}");
    }
}
