//! LLM size calculators for the models the paper evaluates:
//! BERT (110 M / 340 M / 4 B), GPT-2 (4 B / 6 B / 8 B),
//! LLaMA-65B and OPT-66B.
//!
//! The paper's figures depend on tensor *sizes* (transfer volume, memory
//! footprint, KV-cache growth), which these derive exactly from the
//! architecture parameters.

/// Transformer architecture description.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub vocab: usize,
    pub ffn_mult: usize,
}

impl ModelCfg {
    pub fn new(name: &str, layers: usize, d_model: usize, heads: usize, vocab: usize) -> Self {
        Self {
            name: name.to_string(),
            layers,
            d_model,
            heads,
            vocab,
            ffn_mult: 4,
        }
    }

    /// Parameter count: embeddings + per-layer (attention 4·d² +
    /// FFN 2·4·d²) + LN weights (negligible but included).
    pub fn params(&self) -> u64 {
        let d = self.d_model as u64;
        let per_layer = 4 * d * d + 2 * self.ffn_mult as u64 * d * d + 9 * d;
        self.vocab as u64 * d + self.layers as u64 * per_layer
    }

    /// Bytes of fp16 weights (the GPU/transfer representation).
    pub fn weight_bytes_fp16(&self) -> u64 {
        2 * self.params()
    }

    /// CPU-side bytes under ZeRO-Offload: fp32 master params + fp32
    /// momentum + fp32 variance + fp16 gradient staging
    /// (4+4+4+2 = 14 bytes/param) + fp16 param staging (2) = 16 B/param.
    pub fn zero_offload_cpu_bytes(&self) -> u64 {
        16 * self.params()
    }

    /// KV-cache bytes per sequence position per batch element (fp16):
    /// 2 (K and V) · layers · d_model · 2 bytes.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.layers as u64 * self.d_model as u64 * 2
    }

    /// Activation bytes per token held during decode (fp16, one layer's
    /// worth kept resident per FlexGen's schedule).
    pub fn act_bytes_per_token(&self) -> u64 {
        2 * self.d_model as u64 * 8
    }

    /// Forward+backward FLOPs per token (the standard 6·P estimate).
    pub fn train_flops_per_token(&self) -> f64 {
        6.0 * self.params() as f64
    }

    /// Forward FLOPs per token (2·P).
    pub fn infer_flops_per_token(&self) -> f64 {
        2.0 * self.params() as f64
    }
}

/// BERT variants (the paper's 110 M "base", 340 M "medium", 4 B "large").
pub fn bert(params_label: &str) -> ModelCfg {
    match params_label {
        "110M" => ModelCfg::new("BERT-110M", 12, 768, 12, 30522),
        "340M" => ModelCfg::new("BERT-340M", 24, 1024, 16, 30522),
        "4B" => ModelCfg::new("BERT-4B", 48, 2560, 32, 30522),
        other => panic!("unknown BERT size {other}"),
    }
}

/// GPT-2 scaled variants (4 B / 6 B / 8 B as evaluated in Fig 8).
pub fn gpt2(params_label: &str) -> ModelCfg {
    match params_label {
        "4B" => ModelCfg::new("GPT2-4B", 48, 2560, 32, 50257),
        "6B" => ModelCfg::new("GPT2-6B", 40, 3584, 28, 50257),
        "8B" => ModelCfg::new("GPT2-8B", 48, 3712, 32, 50257),
        other => panic!("unknown GPT2 size {other}"),
    }
}

/// LLaMA-65B (Fig 11–12, Table II).
pub fn llama_65b() -> ModelCfg {
    // SwiGLU FFN: 3 matrices of d x 2.6875d ≈ 8d² ≡ ffn_mult 4 here.
    ModelCfg::new("LLaMA-65B", 80, 8192, 64, 32000)
}

/// OPT-66B.
pub fn opt_66b() -> ModelCfg {
    ModelCfg::new("OPT-66B", 64, 9216, 72, 50272)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_is_about_110m() {
        let p = bert("110M").params() as f64;
        assert!((p - 110e6).abs() / 110e6 < 0.15, "params {p}");
    }

    #[test]
    fn bert_medium_is_about_340m() {
        let p = bert("340M").params() as f64;
        assert!((p - 340e6).abs() / 340e6 < 0.15, "params {p}");
    }

    #[test]
    fn gpt2_sizes_scale() {
        let p4 = gpt2("4B").params() as f64;
        let p6 = gpt2("6B").params() as f64;
        let p8 = gpt2("8B").params() as f64;
        assert!((p4 - 4e9).abs() / 4e9 < 0.15, "4B: {p4}");
        assert!((p6 - 6e9).abs() / 6e9 < 0.15, "6B: {p6}");
        assert!((p8 - 8e9).abs() / 8e9 < 0.15, "8B: {p8}");
    }

    #[test]
    fn llama_and_opt_in_range() {
        let l = llama_65b().params() as f64;
        let o = opt_66b().params() as f64;
        assert!((l - 65e9).abs() / 65e9 < 0.12, "llama {l}");
        assert!((o - 66e9).abs() / 66e9 < 0.12, "opt {o}");
    }

    #[test]
    fn zero_offload_cpu_footprint() {
        // 8B params → 128 GB CPU-side state.
        let m = gpt2("8B");
        let gb = m.zero_offload_cpu_bytes() as f64 / 1e9;
        assert!((gb - 16.0 * m.params() as f64 / 1e9).abs() < 1.0);
    }

    #[test]
    fn kv_cache_growth_llama() {
        // LLaMA-65B: 2·80·8192·2 = 2.62 MB per token position.
        let m = llama_65b();
        let kb = m.kv_bytes_per_token() as f64 / 1e6;
        assert!((kb - 2.62).abs() < 0.05, "kv {kb} MB");
    }
}
