//! Request batcher for the serving example: groups incoming inference
//! requests into FlexGen-sized batches and tracks latency/throughput.
//!
//! This is the L3 "coordinator" face of the inference stack: requests
//! arrive on a queue, the batcher forms batches up to the offload
//! policy's batch size, and each batch is charged prefill+decode time
//! from the FlexGen model (with the real decode-attention kernel running
//! through the PJRT runtime in the examples).

use std::collections::VecDeque;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    pub prompt_len: usize,
    pub gen_len: usize,
}

/// A completed request with timing.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub arrival_s: f64,
    pub finish_s: f64,
    pub tokens: usize,
}

impl Completion {
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// FIFO batcher with a maximum batch size.
#[derive(Debug)]
pub struct Batcher {
    pub max_batch: usize,
    queue: VecDeque<Request>,
    pub completions: Vec<Completion>,
    /// Simulated wall clock (seconds).
    pub now_s: f64,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch > 0);
        Self {
            max_batch,
            queue: VecDeque::new(),
            completions: Vec::new(),
            now_s: 0.0,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Form the next batch (up to `max_batch` requests whose arrival time
    /// is ≤ now). Returns an empty vec if nothing is ready.
    pub fn next_batch(&mut self) -> Vec<Request> {
        let mut batch = Vec::new();
        while batch.len() < self.max_batch {
            match self.queue.front() {
                Some(r) if r.arrival_s <= self.now_s => {
                    batch.push(self.queue.pop_front().unwrap())
                }
                _ => break,
            }
        }
        if batch.is_empty() {
            // Advance the clock to the next arrival, if any.
            if let Some(r) = self.queue.front() {
                self.now_s = self.now_s.max(r.arrival_s);
            }
        }
        batch
    }

    /// Record a processed batch that took `batch_time_s`.
    pub fn complete(&mut self, batch: Vec<Request>, batch_time_s: f64) {
        self.now_s += batch_time_s;
        for r in batch {
            self.completions.push(Completion {
                id: r.id,
                arrival_s: r.arrival_s,
                finish_s: self.now_s,
                tokens: r.gen_len,
            });
        }
    }

    /// Serving metrics over all completions.
    pub fn metrics(&self) -> (f64, f64, f64) {
        if self.completions.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let lats: Vec<f64> = self.completions.iter().map(|c| c.latency_s()).collect();
        let mean_lat = crate::util::stats::mean(&lats);
        let p95 = crate::util::stats::percentile(&lats, 95.0);
        let tokens: usize = self.completions.iter().map(|c| c.tokens).sum();
        let span = self
            .completions
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max);
        let tput = tokens as f64 / span.max(1e-9);
        (mean_lat, p95, tput)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: f64) -> Request {
        Request {
            id,
            arrival_s: t,
            prompt_len: 2048,
            gen_len: 256,
        }
    }

    #[test]
    fn batches_respect_max_size() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.submit(req(i, 0.0));
        }
        let batch = b.next_batch();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 3);
    }

    #[test]
    fn only_arrived_requests_batch() {
        let mut b = Batcher::new(8);
        b.submit(req(0, 0.0));
        b.submit(req(1, 100.0)); // far future
        let batch = b.next_batch();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn clock_advances_to_next_arrival_when_idle() {
        let mut b = Batcher::new(8);
        b.submit(req(0, 5.0));
        let batch = b.next_batch();
        assert!(batch.is_empty());
        assert_eq!(b.now_s, 5.0);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn metrics_track_latency_and_throughput() {
        let mut b = Batcher::new(4);
        for i in 0..4 {
            b.submit(req(i, 0.0));
        }
        let batch = b.next_batch();
        b.complete(batch, 10.0);
        let (mean, p95, tput) = b.metrics();
        assert_eq!(mean, 10.0);
        assert_eq!(p95, 10.0);
        assert!((tput - 4.0 * 256.0 / 10.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(1);
        b.submit(req(7, 0.0));
        b.submit(req(8, 0.0));
        assert_eq!(b.next_batch()[0].id, 7);
        assert_eq!(b.next_batch()[0].id, 8);
    }
}
