//! ZeRO-Offload training-step coordinator (§IV-A, Figs 7–9).
//!
//! Workflow per training step (Fig 7):
//! 1–2. forward + backward on the GPU;
//! 3.   gradients offloaded to CPU memory (overlapped with backward);
//! 4.   ADAM optimizer runs **on the CPU** over fp32 states — this is the
//!      latency/bandwidth-sensitive phase the paper dissects;
//! 5.   updated fp16 parameters uploaded to the GPU (partially
//!      overlapped with the next forward).
//!
//! In this reproduction the ADAM step is *real*: the runtime executes the
//! AOT-compiled Pallas `adam` kernel (see `runtime::artifacts`); the
//! simulator charges the memory-system time for the tensor traffic.

use crate::gpu::Gpu;
use crate::llm::model_cfg::ModelCfg;
use crate::memsim::{MemKind, NodeId, Pattern, System};

/// Bytes of CPU memory traffic per parameter for one ADAM step:
/// read p32+m+v+g16 (14), write p32+m+v+p16 (14) ≈ 28, minus cache reuse.
pub const ADAM_TRAFFIC_PER_PARAM: f64 = 20.0;
/// Per-thread ADAM streaming rate against LDRAM (GB/s): SIMD ADAM is
/// memory-bound at roughly this per-core rate.
pub const ADAM_RATE_GBS: f64 = 1.66;
/// Latency sensitivity exponent: the effective per-thread rate scales as
/// `(lat_ldram / lat_node)^ALPHA` (software pipelining hides part of the
/// extra latency; the rest shows — the paper's "optimizer is sensitive to
/// memory latency").
pub const ADAM_LAT_ALPHA: f64 = 0.15;

/// Fractions of the gradient-offload / parameter-upload transfers exposed
/// on the critical path (the rest overlaps with backward / next forward).
pub const GRAD_EXPOSED: f64 = 0.15;
pub const PARAM_EXPOSED: f64 = 0.25;

/// Training-step configuration.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub model: ModelCfg,
    pub batch: usize,
    pub seq: usize,
    /// CPU threads running the ADAM kernel.
    pub threads: usize,
}

/// Where the CPU-side tensors live: (node, fraction) — the membind /
/// interleave choice of Fig 8.
pub type CpuPlacement = Vec<(NodeId, f64)>;

/// Step-time breakdown (seconds), Fig 9's decomposition.
#[derive(Clone, Debug)]
pub struct StepBreakdown {
    pub gpu_s: f64,
    pub optimizer_s: f64,
    pub data_move_exposed_s: f64,
    pub total_s: f64,
}

impl StepBreakdown {
    pub fn optimizer_share(&self) -> f64 {
        self.optimizer_s / self.total_s
    }

    pub fn data_move_share(&self) -> f64 {
        self.data_move_exposed_s / self.total_s
    }
}

/// Maximum batch size that fits the GPU for training (the paper picks
/// the max batch without OOM per model size).
pub fn max_batch(gpu: &Gpu, model: &ModelCfg, seq: usize) -> usize {
    let budget = gpu.mem_bytes as f64 * 0.92
        - model.weight_bytes_fp16() as f64
        - 1e9; // workspace
    // Activation bytes per sequence with checkpointing every layer.
    let per_seq = (seq * model.d_model * model.layers) as f64 * 2.0 * 4.5;
    (budget / per_seq).floor().max(1.0) as usize
}

/// ADAM optimizer time on the CPU for the given tensor placement.
pub fn optimizer_time_s(
    sys: &System,
    cfg: &TrainCfg,
    placement: &CpuPlacement,
) -> f64 {
    let traffic = ADAM_TRAFFIC_PER_PARAM * cfg.model.params() as f64;
    let ld = sys
        .node_of(0, MemKind::Ldram)
        .expect("no LDRAM node");
    let lat_ld = sys.idle_latency(0, ld, Pattern::Sequential);
    let mut t = 0.0f64;
    for &(node, w) in placement {
        if w <= 0.0 {
            continue;
        }
        let lat = sys.idle_latency(0, node, Pattern::Sequential);
        let rate = ADAM_RATE_GBS * (lat_ld / lat).powf(ADAM_LAT_ALPHA);
        let cap = sys.eff_peak_bw(0, node);
        let bw = (cfg.threads as f64 * rate * w).min(cap);
        // Decoupled scan: slowest tier bounds the step.
        t = t.max(traffic * w / (bw * 1e9));
    }
    t
}

/// One full training step under `placement` for the CPU-side tensors.
pub fn step(sys: &System, gpu: &Gpu, cfg: &TrainCfg, placement: &CpuPlacement) -> StepBreakdown {
    let tokens = (cfg.batch * cfg.seq) as f64;
    let gpu_s = cfg.model.train_flops_per_token() * tokens / gpu.flops_effective();

    let optimizer_s = optimizer_time_s(sys, cfg, placement);

    let grad_bytes = 2.0 * cfg.model.params() as f64;
    let param_bytes = 2.0 * cfg.model.params() as f64;
    let grad_s = gpu.transfer_time_s(sys, placement, grad_bytes);
    let param_s = gpu.transfer_time_s(sys, placement, param_bytes);
    let data_move_exposed_s = GRAD_EXPOSED * grad_s + PARAM_EXPOSED * param_s;

    StepBreakdown {
        gpu_s,
        optimizer_s,
        data_move_exposed_s,
        total_s: gpu_s + optimizer_s + data_move_exposed_s,
    }
}

/// Training throughput (samples/s).
pub fn throughput(sys: &System, gpu: &Gpu, cfg: &TrainCfg, placement: &CpuPlacement) -> f64 {
    cfg.batch as f64 / step(sys, gpu, cfg, placement).total_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::model_cfg::gpt2;
    use crate::memsim::topology::system_a;

    fn fixture() -> (System, Gpu, TrainCfg) {
        let sys = system_a();
        let gpu = Gpu::a10();
        let cfg = TrainCfg {
            model: gpt2("8B"),
            batch: 3,
            seq: 1024,
            threads: 32,
        };
        (sys, gpu, cfg)
    }

    fn placement(sys: &System, kinds: &[MemKind]) -> CpuPlacement {
        let w = 1.0 / kinds.len() as f64;
        kinds
            .iter()
            .map(|&k| (sys.node_of(0, k).unwrap(), w))
            .collect()
    }

    #[test]
    fn max_batch_matches_paper_bs3_at_8b() {
        let gpu = Gpu::a10();
        let bs = max_batch(&gpu, &gpt2("8B"), 1024);
        assert!((2..=4).contains(&bs), "bs={bs}");
        // Smaller models fit bigger batches.
        assert!(max_batch(&gpu, &gpt2("4B"), 1024) > bs);
    }

    #[test]
    fn optimizer_slower_on_cxl_but_bounded() {
        // Fig 9: interleaving CXL slows the optimizer by 2–18%.
        let (sys, _gpu, cfg) = fixture();
        let t_ld = optimizer_time_s(&sys, &cfg, &placement(&sys, &[MemKind::Ldram]));
        let t_ldcxl = optimizer_time_s(
            &sys,
            &cfg,
            &placement(&sys, &[MemKind::Ldram, MemKind::Cxl]),
        );
        let pen = t_ldcxl / t_ld - 1.0;
        assert!(pen > 0.01, "penalty {pen}");
        assert!(pen < 0.45, "penalty {pen}");
    }

    #[test]
    fn data_movement_under_ten_percent() {
        // Fig 9: data movement is a small share of step time (<5% for
        // GPT2 in the paper; we accept <10%).
        let (sys, gpu, cfg) = fixture();
        let b = step(&sys, &gpu, &cfg, &placement(&sys, &[MemKind::Ldram]));
        assert!(b.data_move_share() < 0.10, "{}", b.data_move_share());
    }

    #[test]
    fn optimizer_share_grows_as_batch_shrinks() {
        // §IV-A: with small batch the optimizer dominates (≈31% at bs=3).
        let (sys, gpu, mut cfg) = fixture();
        let p = placement(&sys, &[MemKind::Ldram]);
        let small = step(&sys, &gpu, &cfg, &p).optimizer_share();
        cfg.batch = 16;
        let big = step(&sys, &gpu, &cfg, &p).optimizer_share();
        assert!(small > big);
        assert!((0.2..=0.55).contains(&small), "share {small}");
    }

    #[test]
    fn cxl_brings_no_throughput_win() {
        // LLM training observation 1: adding CXL does not help.
        let (sys, gpu, cfg) = fixture();
        let ld = throughput(&sys, &gpu, &cfg, &placement(&sys, &[MemKind::Ldram]));
        let ldcxl = throughput(
            &sys,
            &gpu,
            &cfg,
            &placement(&sys, &[MemKind::Ldram, MemKind::Cxl]),
        );
        let all = throughput(
            &sys,
            &gpu,
            &cfg,
            &placement(&sys, &[MemKind::Ldram, MemKind::Rdram, MemKind::Cxl]),
        );
        assert!(ldcxl <= ld * 1.001);
        assert!(all <= ld * 1.001);
    }

    #[test]
    fn ldram_rdram_beats_ldram_cxl() {
        // Fig 8 (8B): LDRAM+RDRAM outperforms LDRAM+CXL (paper: 16%).
        let (sys, gpu, cfg) = fixture();
        let ldrd = throughput(
            &sys,
            &gpu,
            &cfg,
            &placement(&sys, &[MemKind::Ldram, MemKind::Rdram]),
        );
        let ldcxl = throughput(
            &sys,
            &gpu,
            &cfg,
            &placement(&sys, &[MemKind::Ldram, MemKind::Cxl]),
        );
        let adv = ldrd / ldcxl - 1.0;
        assert!((0.02..=0.35).contains(&adv), "advantage {adv}");
    }
}
