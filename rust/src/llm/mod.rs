//! LLM tensor-offloading stack (§IV): model size calculators, the
//! ZeRO-Offload training coordinator, the FlexGen inference coordinator,
//! and a request batcher for serving.

pub mod batcher;
pub mod flexgen;
pub mod model_cfg;
pub mod zero_offload;

pub use batcher::{Batcher, Completion, Request};
pub use model_cfg::ModelCfg;
