//! FlexGen-style LLM inference coordinator (§IV-B, Figs 10–12, Table II).
//!
//! Workflow (Fig 10): prefill loads weights layer-by-layer to the GPU and
//! writes the generated KV cache back to the CPU hierarchy; decode runs
//! the attention **on the CPU** (to avoid moving the KV cache) and the
//! MLP on the GPU (weights streamed over PCIe each step).
//!
//! The offload policy (Table II) is a capacity-driven search: the batch
//! size grows with the CPU hierarchy capacity; weights are pinned to the
//! fastest tiers, the KV cache spills to the slower ones. Decode
//! throughput is bandwidth-sensitive (CPU attention scans the KV cache);
//! prefill is latency/load-path sensitive — exactly LIO 1–3.

use crate::gpu::Gpu;
use crate::llm::model_cfg::ModelCfg;
use crate::memsim::{MemKind, NodeId, System};

/// KV compression factor (1.0 = fp16, matching the paper's Table II
/// footprints; FlexGen's optional 4-bit compression is not enabled).
pub const KV_COMPRESS: f64 = 1.0;
/// Fraction of CPU capacity usable for model state (rest: OS, buffers).
pub const USABLE_FRAC: f64 = 0.92;
/// CPU threads running decode attention.
pub const CPU_THREADS: usize = 32;
/// Per-thread CPU attention streaming rate over LDRAM (GB/s). Decode
/// attention does softmax/reduction work per element, so aggregate
/// demand (~21 GB/s at 32 threads) sits *below* the CXL plateau — the
/// mechanism behind LIO 1's "CXL ≈ RDRAM for decode".
pub const ATTN_RATE_GBS: f64 = 0.94;
/// Page-cache hit fraction for NVMe-backed mmap KV reads (the hot slice
/// of the cache stays resident in DRAM).
pub const NVME_PAGE_CACHE_HIT: f64 = 0.75;

/// One tier of the CPU hierarchy available to the policy.
#[derive(Clone, Debug)]
pub struct Tier {
    pub node: NodeId,
    pub kind: MemKind,
    pub capacity: f64, // bytes
}

/// Offload policy: where weights and KV cache live (Table II's columns).
#[derive(Clone, Debug)]
pub struct OffloadPolicy {
    pub batch: usize,
    /// Fraction of the KV cache held on the GPU.
    pub kv_gpu_frac: f64,
    /// (node, bytes) placement of CPU-side weights.
    pub weights: Vec<(NodeId, f64)>,
    /// (node, bytes) placement of the CPU-side KV cache.
    pub kv: Vec<(NodeId, f64)>,
    /// Total CPU-side bytes (the Table II "memory footprint").
    pub footprint: f64,
}

/// Inference configuration (prompt 2048 / output 256, the paper's setup).
#[derive(Clone, Debug)]
pub struct InferCfg {
    pub model: ModelCfg,
    pub prompt: usize,
    pub gen: usize,
}

impl InferCfg {
    pub fn paper(model: ModelCfg) -> Self {
        Self {
            model,
            prompt: 2048,
            gen: 256,
        }
    }

    /// Compressed KV bytes per token-position per sequence.
    pub fn kv_bytes_per_pos(&self) -> f64 {
        self.model.kv_bytes_per_token() as f64 / KV_COMPRESS
    }

    /// Total KV bytes for a batch at full context.
    pub fn kv_total(&self, batch: usize) -> f64 {
        self.kv_bytes_per_pos() * (self.prompt + self.gen) as f64 * batch as f64
    }
}

/// Largest batch the scan considers (the paper's policies top out far
/// below this).
const MAX_BATCH: usize = 512;

/// Largest `b` in `1..=max` with `feasible(b)`, or 1 when none is.
///
/// The CPU footprint is non-decreasing in the batch (KV, its CPU spill
/// and the activations all grow with `b`), so feasibility is monotone:
/// an exponential probe brackets the boundary and a binary search pins
/// it — O(log max) feasibility evaluations instead of the former linear
/// `1..=512` scan, with the identical result (pinned by test against
/// the scan, which [`crate::perf::with_reference`] keeps as the
/// reference path).
fn max_feasible_batch(feasible: &dyn Fn(usize) -> bool, max: usize) -> usize {
    if !feasible(1) {
        return 1;
    }
    let mut lo = 1usize; // invariant: feasible(lo)
    let mut hi = 2usize;
    while hi <= max && feasible(hi) {
        lo = hi;
        hi <<= 1;
    }
    // invariant: hi > max, or !feasible(hi)
    let mut hi = hi.min(max + 1);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if feasible(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Capacity-driven policy search: grow the batch until the CPU footprint
/// hits the tier capacities; pin weights to the fastest tiers, spill KV
/// downward; give the GPU's leftover memory to the hottest KV slice.
pub fn search_policy(gpu: &Gpu, cfg: &InferCfg, tiers: &[Tier]) -> OffloadPolicy {
    let weights = cfg.model.weight_bytes_fp16() as f64;
    let cpu_cap: f64 = tiers.iter().map(|t| t.capacity * USABLE_FRAC).sum();
    // GPU leftover for KV after the working layer set + activations.
    let layer_w = weights / cfg.model.layers as f64;
    let gpu_free = (gpu.mem_bytes as f64 * 0.9 - 2.5 * layer_w - 2e9).max(0.0);

    // Max batch: weights + (1-kv_gpu_frac)·KV + activations ≤ cpu_cap
    // (kv_gpu_frac depends on batch). The footprint is monotone in the
    // batch, so the boundary comes from exponential probe + binary
    // search; reference mode keeps the seed's linear scan.
    let feasible = |b: usize| {
        let kv = cfg.kv_total(b);
        let kv_gpu = gpu_free.min(kv);
        let act = cfg.model.act_bytes_per_token() as f64 * b as f64 * 64.0;
        weights + (kv - kv_gpu) + act <= cpu_cap
    };
    let batch = if crate::perf::reference_enabled() {
        let mut best_batch = 1usize;
        for b in 1..=MAX_BATCH {
            if feasible(b) {
                best_batch = b;
            } else {
                break;
            }
        }
        best_batch
    } else {
        max_feasible_batch(&feasible, MAX_BATCH)
    };
    let kv = cfg.kv_total(batch);
    let kv_gpu = gpu_free.min(kv);
    let kv_cpu = kv - kv_gpu;
    let act = cfg.model.act_bytes_per_token() as f64 * batch as f64 * 64.0;

    // Greedy placement fastest-first: weights, then KV, then activations.
    let mut free: Vec<f64> = tiers.iter().map(|t| t.capacity * USABLE_FRAC).collect();
    let mut place = |bytes: f64, free: &mut Vec<f64>| -> Vec<(NodeId, f64)> {
        let mut left = bytes;
        let mut out = Vec::new();
        for (i, t) in tiers.iter().enumerate() {
            if left <= 0.0 {
                break;
            }
            let take = left.min(free[i]);
            if take > 0.0 {
                out.push((t.node, take));
                free[i] -= take;
                left -= take;
            }
        }
        out
    };
    let w_place = place(weights, &mut free);
    let _a_place = place(act, &mut free);
    let kv_place = place(kv_cpu, &mut free);

    OffloadPolicy {
        batch,
        kv_gpu_frac: kv_gpu / kv,
        weights: w_place,
        kv: kv_place,
        footprint: weights + kv_cpu + act,
    }
}

/// Throughput result (tokens/s), decomposed as in Fig 11.
#[derive(Clone, Debug)]
pub struct Throughput {
    pub prefill_tok_s: f64,
    pub decode_tok_s: f64,
    pub total_tok_s: f64,
    pub batch: usize,
}

fn norm_weights(p: &[(NodeId, f64)]) -> Vec<(NodeId, f64)> {
    let total: f64 = p.iter().map(|&(_, b)| b).sum();
    if total <= 0.0 {
        return Vec::new();
    }
    p.iter().map(|&(n, b)| (n, b / total)).collect()
}

/// End-to-end inference throughput under a policy.
pub fn throughput(sys: &System, gpu: &Gpu, cfg: &InferCfg, pol: &OffloadPolicy) -> Throughput {
    let b = pol.batch as f64;
    let weights = cfg.model.weight_bytes_fp16() as f64;
    let w_nw = norm_weights(&pol.weights);
    let kv_nw = norm_weights(&pol.kv);

    // ---- prefill: one pass over all layers for batch·prompt tokens ----
    let prefill_tokens = b * cfg.prompt as f64;
    let gpu_compute = cfg.model.infer_flops_per_token() * prefill_tokens / gpu.flops_effective();
    let weight_load = gpu.transfer_time_s(sys, &w_nw, weights);
    // KV write-back of the prompt's cache to the CPU tiers.
    let kv_cpu_bytes = cfg.kv_bytes_per_pos() * cfg.prompt as f64 * b * (1.0 - pol.kv_gpu_frac);
    let kv_write = if kv_nw.is_empty() {
        0.0
    } else {
        // GPU→CXL/NVMe writes bounce through a DRAM buffer under CXL 1.1
        // (no peer-to-peer): extra copy halves the effective write rate.
        let mut t = 0.0;
        for &(node, w) in &kv_nw {
            let kind = sys.nodes[node].device.kind;
            let bounce = match kind {
                MemKind::Cxl => 0.62,
                MemKind::Nvme => 0.80,
                _ => 1.0,
            };
            let base = gpu.transfer_bw_gbs(sys, &[(node, 1.0)]);
            let bw = match kind {
                MemKind::Cxl => (sys.nodes[node].device.peak_bw_gbs * bounce).min(base),
                _ => base * bounce,
            };
            t += kv_cpu_bytes * w / (bw * 1e9);
        }
        t
    };
    // Layer-pipelined compute/loads; KV write-back is exposed at layer
    // boundaries (synchronous offload in FlexGen's schedule).
    let prefill_s = gpu_compute.max(weight_load) + kv_write;
    let prefill_tok_s = prefill_tokens / prefill_s;

    // ---- decode: per generated token ----
    // CPU attention scans the CPU-resident KV at tier bandwidth.
    let ctx = (cfg.prompt + cfg.gen / 2) as f64; // average context length
    let kv_read_bytes = cfg.kv_bytes_per_pos() * ctx * b * (1.0 - pol.kv_gpu_frac);
    let mut attn_s = 0.0f64;
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let lat_ld = sys.idle_latency(0, ld, crate::memsim::Pattern::Sequential);
    for &(node, w) in &kv_nw {
        let lat = sys.idle_latency(0, node, crate::memsim::Pattern::Sequential);
        let mut rate = ATTN_RATE_GBS * (lat_ld / lat).powf(0.10);
        let mut cap = sys.eff_peak_bw(0, node);
        if sys.nodes[node].device.kind == MemKind::Nvme {
            rate = ATTN_RATE_GBS; // streaming readahead hides NVMe latency
            // mmap'd KV: hot fraction served from the page cache.
            let ld_bw = sys.eff_peak_bw(0, ld);
            cap = 1.0 / (NVME_PAGE_CACHE_HIT / ld_bw + (1.0 - NVME_PAGE_CACHE_HIT) / cap);
        }
        let bw = (CPU_THREADS as f64 * rate * w).min(cap);
        attn_s = attn_s.max(kv_read_bytes * w / (bw * 1e9));
    }
    // MLP weights streamed to the GPU each step (layer-pipelined).
    let mlp_frac = 2.0 * cfg.model.ffn_mult as f64 / (4.0 + 2.0 * cfg.model.ffn_mult as f64);
    let mlp_load = gpu.transfer_time_s(sys, &w_nw, weights * mlp_frac);
    let gpu_mlp = cfg.model.infer_flops_per_token() * mlp_frac * b / gpu.flops_effective();
    // Activation hops GPU↔CPU per layer, small but latency-bearing.
    let act_bytes = cfg.model.act_bytes_per_token() as f64 * b * cfg.model.layers as f64;
    let act_xfer = gpu.transfer_time_s(sys, &w_nw, act_bytes);
    let decode_step_s = attn_s.max(mlp_load) + gpu_mlp + act_xfer;
    let decode_tok_s = b / decode_step_s;

    // ---- end-to-end ----
    let total_tokens = b * cfg.gen as f64;
    let total_s = prefill_s + cfg.gen as f64 * decode_step_s;
    Throughput {
        prefill_tok_s,
        decode_tok_s,
        total_tok_s: total_tokens / total_s,
        batch: pol.batch,
    }
}

/// Build the tier list for a named memory configuration on `sys`
/// (socket-0 view), with per-tier capacity caps in bytes.
pub fn tiers_of(sys: &System, kinds_caps: &[(MemKind, f64)]) -> Vec<Tier> {
    kinds_caps
        .iter()
        .map(|&(k, cap)| Tier {
            node: sys.node_of(0, k).expect("missing tier"),
            kind: k,
            capacity: cap,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::model_cfg::{llama_65b, opt_66b};
    use crate::memsim::topology::system_a;

    const GB: f64 = 1e9;

    fn fixture() -> (System, Gpu, InferCfg) {
        (system_a(), Gpu::a10(), InferCfg::paper(llama_65b()))
    }

    #[test]
    fn batch_scales_with_capacity() {
        // Table II / LIO 3: batch grows with memory capacity.
        let (sys, gpu, cfg) = fixture();
        let small = search_policy(&gpu, &cfg, &tiers_of(&sys, &[(MemKind::Ldram, 196.0 * GB)]));
        let med = search_policy(
            &gpu,
            &cfg,
            &tiers_of(
                &sys,
                &[(MemKind::Ldram, 196.0 * GB), (MemKind::Rdram, 196.0 * GB)],
            ),
        );
        let big = search_policy(
            &gpu,
            &cfg,
            &tiers_of(
                &sys,
                &[
                    (MemKind::Ldram, 196.0 * GB),
                    (MemKind::Rdram, 196.0 * GB),
                    (MemKind::Cxl, 128.0 * GB),
                ],
            ),
        );
        assert!(small.batch < med.batch && med.batch < big.batch);
        // Paper Table II: LLaMA batches 14 / 40 / 56 for these configs.
        assert!((8..=18).contains(&small.batch), "batch {}", small.batch);
        assert!((30..=50).contains(&med.batch), "batch {}", med.batch);
        assert!((45..=70).contains(&big.batch), "batch {}", big.batch);
    }

    #[test]
    fn batch_search_matches_linear_scan() {
        // The exponential-probe + binary-search batch must equal the
        // seed's linear scan for every model × capacity shape: below
        // batch-1 feasibility, mid-range boundaries, and the MAX_BATCH
        // cap (everything feasible).
        let sys = system_a();
        let gpu = Gpu::a10();
        for model in [llama_65b(), opt_66b()] {
            let cfg = InferCfg::paper(model);
            let shapes: Vec<Vec<(MemKind, f64)>> = vec![
                vec![(MemKind::Ldram, 8.0 * GB)], // weights alone overflow
                vec![(MemKind::Ldram, 64.0 * GB)],
                vec![(MemKind::Ldram, 150.0 * GB)],
                vec![(MemKind::Ldram, 196.0 * GB)],
                vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)],
                vec![(MemKind::Ldram, 196.0 * GB), (MemKind::Nvme, 512.0 * GB)],
                vec![
                    (MemKind::Ldram, 196.0 * GB),
                    (MemKind::Rdram, 196.0 * GB),
                    (MemKind::Cxl, 128.0 * GB),
                ],
                vec![(MemKind::Ldram, 100_000.0 * GB)], // all 512 feasible
            ];
            for caps in shapes {
                let tiers = tiers_of(&sys, &caps);
                let opt = search_policy(&gpu, &cfg, &tiers);
                let reference =
                    crate::perf::with_reference(|| search_policy(&gpu, &cfg, &tiers));
                assert_eq!(opt.batch, reference.batch, "{caps:?}");
                assert_eq!(
                    opt.footprint.to_bits(),
                    reference.footprint.to_bits(),
                    "{caps:?}"
                );
                assert_eq!(opt.weights, reference.weights, "{caps:?}");
            }
        }
    }

    #[test]
    fn policy_respects_capacity() {
        let (sys, gpu, cfg) = fixture();
        let tiers = tiers_of(
            &sys,
            &[(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)],
        );
        let pol = search_policy(&gpu, &cfg, &tiers);
        let cap: f64 = tiers.iter().map(|t| t.capacity * USABLE_FRAC).sum();
        assert!(pol.footprint <= cap * 1.001);
        // weights land on the fastest tier first
        assert_eq!(pol.weights[0].0, tiers[0].node);
    }

    #[test]
    fn most_kv_stays_on_cpu() {
        // Paper: only ~8–20% of the KV cache fits the GPU.
        let (sys, gpu, cfg) = fixture();
        let pol = search_policy(
            &gpu,
            &cfg,
            &tiers_of(
                &sys,
                &[(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)],
            ),
        );
        assert!(pol.kv_gpu_frac < 0.35, "kv gpu frac {}", pol.kv_gpu_frac);
    }

    #[test]
    fn cxl_close_to_rdram_and_beats_nvme() {
        // Fig 11 / LIO 1: LDRAM+CXL ≈ LDRAM+RDRAM (≲5%), both beat
        // LDRAM+NVMe substantially.
        let (sys, gpu, cfg) = fixture();
        let run = |kinds: &[(MemKind, f64)]| {
            let t = tiers_of(&sys, kinds);
            let p = search_policy(&gpu, &cfg, &t);
            // equal-capacity configs ⇒ equal batch; compare throughput
            throughput(&sys, &gpu, &cfg, &p)
        };
        let cxl = run(&[(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)]);
        let rdram = run(&[(MemKind::Ldram, 196.0 * GB), (MemKind::Rdram, 128.0 * GB)]);
        let nvme = run(&[(MemKind::Ldram, 196.0 * GB), (MemKind::Nvme, 128.0 * GB)]);
        let gap = (rdram.total_tok_s - cxl.total_tok_s).abs() / rdram.total_tok_s;
        assert!(gap < 0.08, "CXL vs RDRAM gap {gap}");
        let win = cxl.total_tok_s / nvme.total_tok_s - 1.0;
        assert!(win > 0.10, "CXL vs NVMe win {win}");
    }

    #[test]
    fn decode_bandwidth_sensitive_nvme_suffers() {
        // LIO 2: decode responds to bandwidth (CXL ≫ NVMe there).
        let (sys, gpu, cfg) = fixture();
        let run = |kinds: &[(MemKind, f64)]| {
            let t = tiers_of(&sys, kinds);
            let p = search_policy(&gpu, &cfg, &t);
            throughput(&sys, &gpu, &cfg, &p)
        };
        let cxl = run(&[(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)]);
        let nvme = run(&[(MemKind::Ldram, 196.0 * GB), (MemKind::Nvme, 128.0 * GB)]);
        assert!(cxl.decode_tok_s > nvme.decode_tok_s * 1.1);
    }

    #[test]
    fn bigger_capacity_bigger_total_throughput() {
        // Fig 12: total throughput grows with capacity via batch size.
        let (sys, gpu, cfg) = fixture();
        let run = |kinds: &[(MemKind, f64)]| {
            let t = tiers_of(&sys, kinds);
            let p = search_policy(&gpu, &cfg, &t);
            throughput(&sys, &gpu, &cfg, &p)
        };
        let ld = run(&[(MemKind::Ldram, 196.0 * GB)]);
        let ldrd = run(&[(MemKind::Ldram, 196.0 * GB), (MemKind::Rdram, 196.0 * GB)]);
        let all = run(&[
            (MemKind::Ldram, 196.0 * GB),
            (MemKind::Rdram, 196.0 * GB),
            (MemKind::Cxl, 128.0 * GB),
        ]);
        assert!(ldrd.total_tok_s > ld.total_tok_s * 1.2);
        // interleave-all lands within ~10% of LDRAM+RDRAM (paper: +3%,
        // ours: -7% — the CXL KV slice pays a small latency penalty; see
        // EXPERIMENTS.md F12 notes).
        assert!(all.total_tok_s >= ldrd.total_tok_s * 0.90);
        assert!(all.total_tok_s > ld.total_tok_s * 1.2);
    }

    #[test]
    fn opt_66b_also_works() {
        let (sys, gpu, _) = fixture();
        let cfg = InferCfg::paper(opt_66b());
        let pol = search_policy(
            &gpu,
            &cfg,
            &tiers_of(
                &sys,
                &[(MemKind::Ldram, 196.0 * GB), (MemKind::Cxl, 128.0 * GB)],
            ),
        );
        let t = throughput(&sys, &gpu, &cfg, &pol);
        assert!(t.total_tok_s > 0.0 && t.prefill_tok_s > t.decode_tok_s);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::llm::model_cfg::llama_65b;
    use crate::memsim::topology::system_a;

    #[test]
    #[ignore]
    fn dump_components() {
        let sys = system_a();
        let gpu = crate::gpu::Gpu::a10();
        let cfg = InferCfg::paper(llama_65b());
        for (name, kinds) in [
            ("LDRAM", vec![(MemKind::Ldram, 196e9)]),
            ("LD+CXL", vec![(MemKind::Ldram, 196e9), (MemKind::Cxl, 128e9)]),
            ("LD+RD", vec![(MemKind::Ldram, 196e9), (MemKind::Rdram, 128e9)]),
            ("LD+NVMe", vec![(MemKind::Ldram, 196e9), (MemKind::Nvme, 128e9)]),
            ("LD+RD392", vec![(MemKind::Ldram, 196e9), (MemKind::Rdram, 196e9)]),
            ("ALL", vec![(MemKind::Ldram, 196e9), (MemKind::Rdram, 196e9), (MemKind::Cxl, 128e9)]),
        ] {
            let t = tiers_of(&sys, &kinds);
            let p = search_policy(&gpu, &cfg, &t);
            let th = throughput(&sys, &gpu, &cfg, &p);
            println!("{name}: batch={} kv_gpu={:.2} fp={:.0}GB pre={:.1} dec={:.2} tot={:.2}",
                p.batch, p.kv_gpu_frac, p.footprint/1e9, th.prefill_tok_s, th.decode_tok_s, th.total_tok_s);
            println!("   weights on: {:?}", p.weights.iter().map(|&(n,b)| (n, (b/1e9) as u64)).collect::<Vec<_>>());
            println!("   kv on: {:?}", p.kv.iter().map(|&(n,b)| (n, (b/1e9) as u64)).collect::<Vec<_>>());
        }
    }
}
