//! Workload execution engine: turns (workload model × page placement ×
//! system) into execution time with a per-component breakdown.
//!
//! Model. A workload iteration scans its data objects with `threads`
//! worker threads. Placement gives each object a per-node page
//! distribution. Traffic decomposes into:
//!
//! - **streaming** (sequential) traffic: decoupled per node — node `i`
//!   serves its share at `min(cap_i, threads·rate_i·share_i)`; the scan
//!   finishes when the slowest node finishes (`max_i bytes_i / bw_i`).
//!   This is the additive-bandwidth behaviour behind HPC observation 2
//!   ("interleave all" achieves the highest bandwidth for MG).
//! - **random throughput** traffic: like streaming but with the
//!   MSHR-bound random per-thread bandwidth.
//! - **dependent** accesses (`dep_frac` of an object's random traffic):
//!   serialized pointer-chase-style; time `count · latency / (threads ·
//!   DEP_MLP)`, where latency reflects load and the paper's
//!   concentrated-access bonus (HPC observation 3: CG on CXL).
//! - **compute**: `compute_ns_per_byte · total_bytes / threads`,
//!   overlapped with memory traffic (`max(compute, memory)`).

use crate::memsim::{NodeId, Pattern, System};

/// Overlap factor for dependent access chains (a thread keeps a few
/// dependent loads in flight via speculation).
pub const DEP_MLP: f64 = 3.0;

/// One object's traffic description, placement-resolved.
#[derive(Clone, Debug)]
pub struct ObjectTraffic {
    pub name: String,
    /// Bytes of traffic this object receives per iteration.
    pub traffic_bytes: f64,
    /// Access pattern for the bulk of the traffic.
    pub pattern: Pattern,
    /// Fraction of traffic that is dependent (serialized) accesses.
    pub dep_frac: f64,
    /// Page distribution over nodes: (node, fraction), summing to 1.
    pub node_weights: Vec<(NodeId, f64)>,
}

/// Execution-time breakdown for one iteration (seconds).
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub total_s: f64,
    pub compute_s: f64,
    pub stream_s: f64,
    pub dep_s: f64,
    /// Per-node utilization during the memory phase.
    pub node_rho: Vec<f64>,
}

/// Engine configuration for one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub socket: usize,
    pub threads: usize,
    /// ns of CPU work per byte of traffic (workload compute intensity).
    pub compute_ns_per_byte: f64,
}

/// Execute one iteration of the workload model.
pub fn run(sys: &System, cfg: &RunConfig, objects: &[ObjectTraffic]) -> RunResult {
    let nn = sys.nodes.len();
    let threads = cfg.threads as f64;

    // ---- aggregate per-node traffic ----
    let mut seq_bytes = vec![0.0f64; nn];
    let mut rnd_bytes = vec![0.0f64; nn];
    let mut total_bytes = 0.0f64;
    for o in objects {
        total_bytes += o.traffic_bytes;
        for &(node, w) in &o.node_weights {
            match o.pattern {
                Pattern::Sequential => seq_bytes[node] += o.traffic_bytes * w,
                Pattern::Random => {
                    rnd_bytes[node] += o.traffic_bytes * w * (1.0 - o.dep_frac)
                }
            }
        }
    }
    if total_bytes <= 0.0 {
        return RunResult::default();
    }

    // ---- per-node bandwidths ----
    // Threads divide their issue capacity in proportion to traffic share;
    // each node also caps at its effective peak.
    let mut node_bw = vec![0.0f64; nn];
    let mut rho = vec![0.0f64; nn];
    for i in 0..nn {
        let bytes_i = seq_bytes[i] + rnd_bytes[i];
        if bytes_i <= 0.0 {
            continue;
        }
        let share = bytes_i / total_bytes;
        let dev = &sys.nodes[i].device;
        let hop = sys.path(cfg.socket, i).latency_ns();
        // Blend the streaming and random per-thread rates by traffic mix.
        let seq_rate = dev.stream_rate_gbs * dev.idle.seq_ns / (dev.idle.seq_ns + hop);
        let rnd_rate = dev.mlp_rand * crate::memsim::LINE / (dev.idle.rand_ns + hop);
        let mix = seq_bytes[i] / bytes_i;
        let per_thread = mix * seq_rate + (1.0 - mix) * rnd_rate;
        let cap = sys.eff_peak_bw(cfg.socket, i);
        let bw = (threads * per_thread * share).min(cap);
        node_bw[i] = bw;
        rho[i] = (bw / cap).min(1.0);
    }

    // ---- phase times ----
    // Streaming + random-throughput traffic finishes when the slowest
    // node finishes (decoupled scan).
    let mut mem_s = 0.0f64;
    for i in 0..nn {
        let bytes_i = seq_bytes[i] + rnd_bytes[i];
        if bytes_i > 0.0 && node_bw[i] > 0.0 {
            mem_s = mem_s.max(bytes_i / node_bw[i] / 1e9);
        }
    }

    // Dependent accesses: serialized chains at loaded latency.
    let mut dep_s = 0.0f64;
    for o in objects {
        if o.dep_frac <= 0.0 || o.pattern != Pattern::Random {
            continue;
        }
        let concentrated = o.node_weights.iter().filter(|&&(_, w)| w > 1e-9).count() <= 1;
        let mut lat = 0.0;
        for &(node, w) in &o.node_weights {
            let dev = &sys.nodes[node].device;
            let mut l = dev.latency_at(Pattern::Random, rho[node]);
            if concentrated {
                l *= dev.concentrated_rand_factor;
            }
            lat += w * (l + sys.path(cfg.socket, node).latency_ns());
        }
        let count = o.traffic_bytes * o.dep_frac / crate::memsim::LINE;
        dep_s += count * lat / (threads * DEP_MLP) / 1e9;
    }

    let compute_s = cfg.compute_ns_per_byte * total_bytes / threads / 1e9;
    let stream_s = mem_s;
    let total_s = compute_s.max(stream_s + dep_s);

    RunResult {
        total_s,
        compute_s,
        stream_s,
        dep_s,
        node_rho: rho,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;
    use crate::memsim::MemKind;

    fn one_obj(node_weights: Vec<(NodeId, f64)>, pattern: Pattern, dep: f64) -> ObjectTraffic {
        ObjectTraffic {
            name: "o".into(),
            traffic_bytes: 100e9,
            pattern,
            dep_frac: dep,
            node_weights,
        }
    }

    fn cfg(threads: usize) -> RunConfig {
        RunConfig {
            socket: 0,
            threads,
            compute_ns_per_byte: 0.0,
        }
    }

    #[test]
    fn ldram_faster_than_cxl_for_streams() {
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let t_ld = run(&sys, &cfg(32), &[one_obj(vec![(ld, 1.0)], Pattern::Sequential, 0.0)]);
        let t_cxl = run(&sys, &cfg(32), &[one_obj(vec![(cxl, 1.0)], Pattern::Sequential, 0.0)]);
        assert!(t_cxl.total_s > 3.0 * t_ld.total_s);
    }

    #[test]
    fn interleave_bottleneck_is_cxl_share() {
        // 1:1 LDRAM+CXL: time ≈ (bytes/2) / cxl_bw — not the mean.
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let r = run(
            &sys,
            &cfg(32),
            &[one_obj(vec![(ld, 0.5), (cxl, 0.5)], Pattern::Sequential, 0.0)],
        );
        let expected = 50e9 / (sys.nodes[cxl].device.peak_bw_gbs * 1e9);
        assert!((r.total_s - expected).abs() / expected < 0.1, "{}", r.total_s);
    }

    #[test]
    fn interleave_all_beats_cxl_only_at_high_threads() {
        // HPC observation 2 (MG-style): more nodes = more bandwidth.
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let rd = sys.node_of(0, MemKind::Rdram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let third = 1.0 / 3.0;
        let all = run(
            &sys,
            &cfg(32),
            &[one_obj(
                vec![(ld, third), (rd, third), (cxl, third)],
                Pattern::Sequential,
                0.0,
            )],
        );
        let cxl_only =
            run(&sys, &cfg(32), &[one_obj(vec![(cxl, 1.0)], Pattern::Sequential, 0.0)]);
        assert!(cxl_only.total_s > 2.0 * all.total_s);
    }

    #[test]
    fn concentrated_random_beats_spread_for_dep_chains() {
        // HPC observation 3 (CG-style): concentrating dependent random
        // accesses on CXL is competitive with spreading them.
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let rd = sys.node_of(0, MemKind::Rdram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let t = 8; // low thread count: latency-dominated
        let conc = run(&sys, &cfg(t), &[one_obj(vec![(cxl, 1.0)], Pattern::Random, 0.9)]);
        let spread = run(
            &sys,
            &cfg(t),
            &[one_obj(
                vec![(ld, 1.0 / 3.0), (rd, 1.0 / 3.0), (cxl, 1.0 / 3.0)],
                Pattern::Random,
                0.9,
            )],
        );
        assert!(
            conc.dep_s < spread.dep_s * 1.15,
            "conc={} spread={}",
            conc.dep_s,
            spread.dep_s
        );
    }

    #[test]
    fn compute_bound_workload_insensitive_to_placement() {
        // BT-style tolerance: with high compute intensity, CXL placement
        // costs little.
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let mut c = cfg(32);
        c.compute_ns_per_byte = 3.0; // strongly compute-bound
        let t_ld = run(&sys, &c, &[one_obj(vec![(ld, 1.0)], Pattern::Sequential, 0.0)]);
        let t_cxl = run(&sys, &c, &[one_obj(vec![(cxl, 1.0)], Pattern::Sequential, 0.0)]);
        let loss = t_cxl.total_s / t_ld.total_s - 1.0;
        assert!(loss < 0.60, "loss {loss}");
        assert_eq!(t_ld.total_s, t_ld.compute_s);
    }

    #[test]
    fn more_threads_never_slower() {
        let sys = system_a();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let obj = one_obj(vec![(cxl, 1.0)], Pattern::Sequential, 0.0);
        let mut prev = f64::INFINITY;
        for t in [1, 2, 4, 8, 16, 32] {
            let r = run(&sys, &cfg(t), &[obj.clone()]);
            assert!(r.total_s <= prev * 1.0001, "t={t}");
            prev = r.total_s;
        }
    }

    #[test]
    fn empty_workload_is_zero() {
        let sys = system_a();
        let r = run(&sys, &cfg(32), &[]);
        assert_eq!(r.total_s, 0.0);
    }
}
