//! Hot-path benchmark suite behind `cxlmem bench` and
//! `cargo bench --bench hotpath`.
//!
//! Each hot path is measured twice in the same process — once through
//! the seed-semantics reference implementations
//! ([`crate::perf::with_reference`]) and once through the optimized
//! production paths — so every run records its own before/after
//! trajectory. Results land in `BENCH_hotpath.json`:
//!
//! ```json
//! {
//!   "schema": "cxlmem-bench-v1",
//!   "jobs": 8,
//!   "smoke": false,
//!   "hotpaths": [
//!     {"name": "memsim/solve_traffic(2 streams)", "mode": "reference",
//!      "median_ns": 0.0, "mean_ns": 0.0, "p50_ns": 0.0, "p90_ns": 0.0,
//!      "p95_ns": 0.0, "iters": 0}
//!   ],
//!   "wall": {"exp_all_reference_s": 0.0, "exp_all_optimized_s": 0.0},
//!   "speedup": {"exp/all": 0.0, "tiering/epoch(PageRank, t08, 65k pages)": 0.0}
//! }
//! ```
//!
//! `hotpaths[*].mode` is `reference` (seed semantics, sequential),
//! `optimized` (production path, memo cache off for the raw solver), or
//! `memoized` (production path with the solve cache warm — the sweep
//! case). `speedup` maps each hot path to reference/optimized median
//! ratio; four wall-clock ratios ride along: `exp/all` (full
//! 19-experiment suite, sequential reference vs `--jobs`-parallel
//! optimized), `exp/fig16(shared trace)` (the fig16 grid at jobs=1,
//! per-cell seed-style trace regeneration vs one shared immutable
//! snapshot per app replayed by every cell on the SoA page state),
//! `exp/fig16(policy x placement grid)` (the optimized grid at jobs=1
//! vs `--jobs`), `scenario/cache(fleet re-run)` (one seeded fleet
//! evaluated cold vs served warm from the persistent result cache,
//! measured against the same on-disk store), and
//! `scenario/cache(contended flush)` (8 writers × 1k entries flushing
//! into one store: the flock-era append path, kept as
//! [`crate::scenario::store::legacy`], vs layered seal-only writes plus
//! one final compaction — the store refactor's headline ratio), and
//! `scenario/serve(warm vs cold)` (one fleet submitted to a warm
//! long-lived daemon over its Unix socket vs the same specs as cold
//! one-shot `scenario run` processes — the serve daemon's headline
//! ratio; needs the `cxlmem` binary, so it records only under
//! `cxlmem bench`, not the cargo-bench harness).
//! `tiering/epoch_counts(Graph500)` times per-epoch histogram
//! *production* — seed-style full regeneration vs the incremental copy —
//! with the (mode-shared) hot-set drift untimed between epochs.
//! `tiering/promote_batch(SoA)` times a full-pressure promotion batch
//! through the packed-column state vs the seed's recount-and-sort path.
//! `tiering/promote_batch(16M pages)` times the same full-pressure
//! batch at production scale: the sequential single-thread scan (the
//! parity reference, recorded as mode `reference`) vs the chunked
//! `--jobs`-parallel scan (`optimized`), state clones untimed, results
//! asserted bit-identical each iteration. `workloads/trace(delta encode
//! 16M)` is a *memory* entry: its `speedup` value is the dense/delta
//! byte ratio of a 16M-page × 10-epoch PageRank trace (the dense form
//! cannot fit the trace-store budget; the delta form must).
//!
//! [`validate_report_doc`] checks a written `BENCH_hotpath.json` against
//! this schema (`cxlmem bench --validate FILE`, `make bench-check`).
//!
//! One caveat on the tiering baseline: both modes share the
//! geometric-skip fault sampler (required for decision parity), so the
//! reference measurement *understates* the seed's true cost — the seed
//! drew one RNG value per candidate page. Reported tiering speedups are
//! therefore conservative.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::engine::{self, ObjectTraffic, RunConfig};
use crate::exp;
use crate::memsim::{topology, MemKind, Pattern, Stream, System};
use crate::perf;
use crate::tiering::{self, initial_state, SimConfig, Tiering08};
use crate::util::json::Json;
use crate::util::stats;
use crate::util::timer::{BenchResult, Bencher};
use crate::workloads::npb;
use crate::workloads::tiering_apps::{graph500, pagerank, TraceGen};

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Short budgets for CI (`--smoke`).
    pub smoke: bool,
    /// Worker threads for the optimized `exp all` wall measurement.
    pub jobs: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            smoke: false,
            jobs: perf::default_jobs(),
        }
    }
}

/// One measured hot path.
#[derive(Clone, Debug)]
pub struct HotpathResult {
    pub result: BenchResult,
    /// "reference" | "optimized" | "memoized"
    pub mode: &'static str,
}

/// Everything one suite run measured.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub hotpaths: Vec<HotpathResult>,
    pub exp_all_reference_s: f64,
    pub exp_all_optimized_s: f64,
    pub speedups: Vec<(String, f64)>,
    pub jobs: usize,
    pub smoke: bool,
}

fn bencher(opts: &BenchOpts) -> Bencher {
    if opts.smoke {
        Bencher::quick()
    } else {
        Bencher::default()
    }
}

const SOLVER_NAME: &str = "memsim/solve_traffic(2 streams)";
const ENGINE_NAME: &str = "engine/run(MG, 2-tier)";
const TIERING_NAME: &str = "tiering/epoch(PageRank, t08, 65k pages)";
const PROMOTE_NAME: &str = "tiering/promote_batch(SoA)";
const PROMOTE16_NAME: &str = "tiering/promote_batch(16M pages)";
const TRACE_DELTA_NAME: &str = "workloads/trace(delta encode 16M)";
const EPOCH_COUNTS_NAME: &str = "tiering/epoch_counts(Graph500)";
const FLEXGEN_NAME: &str = "flexgen/search+throughput";
const SHARED_TRACE_NAME: &str = "exp/fig16(shared trace)";
const GRID_NAME: &str = "exp/fig16(policy x placement grid)";
const SCENARIO_CACHE_NAME: &str = "scenario/cache(fleet re-run)";
const CACHE_FLUSH_NAME: &str = "scenario/cache(contended flush)";
#[cfg(unix)]
const SERVE_NAME: &str = "scenario/serve(warm vs cold)";
const EXP_ALL_NAME: &str = "exp/all";

/// Run the full suite. Prints one line per measurement as it completes.
pub fn run_suite(opts: &BenchOpts) -> BenchReport {
    let prev_jobs = perf::current_jobs();
    perf::set_jobs(1); // measurements themselves are single-threaded
    let mut hotpaths = Vec::new();
    let mut speedups = Vec::new();

    let sys = topology::system_a();
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(0, MemKind::Cxl).unwrap();

    // --- memsim solver ---
    let streams = vec![
        Stream {
            socket: 0,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            pattern: Pattern::Sequential,
            threads: 32.0,
            delay_ns: 0.0,
        },
        Stream {
            socket: 0,
            node_weights: vec![(ld, 1.0)],
            pattern: Pattern::Random,
            threads: 16.0,
            delay_ns: 0.0,
        },
    ];
    {
        let mut b = bencher(opts);
        perf::with_reference(|| {
            b.bench(&format!("{SOLVER_NAME} [reference]"), || {
                std::hint::black_box(sys.solve_traffic(std::hint::black_box(&streams)));
            });
        });
        perf::without_memo(|| {
            b.bench(&format!("{SOLVER_NAME} [optimized]"), || {
                std::hint::black_box(sys.solve_traffic(std::hint::black_box(&streams)));
            });
        });
        System::clear_solver_cache();
        b.bench(&format!("{SOLVER_NAME} [memoized]"), || {
            std::hint::black_box(sys.solve_traffic(std::hint::black_box(&streams)));
        });
        let rs = b.results();
        speedups.push((SOLVER_NAME.to_string(), ratio(&rs[0], &rs[1])));
        push_modes(&mut hotpaths, rs, &["reference", "optimized", "memoized"]);
    }

    // --- engine (no reference variant: the engine was already closed-form) ---
    {
        let wl = npb::by_name("MG").unwrap();
        let objects: Vec<ObjectTraffic> = wl
            .objects
            .iter()
            .map(|o| ObjectTraffic {
                name: o.spec.name.clone(),
                traffic_bytes: o.traffic_bytes(),
                pattern: o.pattern,
                dep_frac: o.spec.dep_frac,
                node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            })
            .collect();
        let cfg = RunConfig {
            socket: 0,
            threads: 32,
            compute_ns_per_byte: wl.compute_ns_per_byte,
        };
        let mut b = bencher(opts);
        b.bench(&format!("{ENGINE_NAME} [optimized]"), || {
            std::hint::black_box(engine::run(&sys, &cfg, std::hint::black_box(&objects)));
        });
        push_modes(&mut hotpaths, b.results(), &["optimized"]);
    }

    // --- tiering epoch ---
    {
        // Pre-generate the trace so the measurement is the epoch cost
        // given the histogram, not the histogram generator.
        let pages = if opts.smoke { 16_000 } else { 65_000 };
        let fast_cap = if opts.smoke { 6_000 } else { 25_000 };
        let mut app = pagerank();
        app.pages = pages;
        let mut gen = TraceGen::new(app, 3);
        let epochs: Vec<Vec<u32>> = (0..4)
            .map(|_| {
                let c = gen.epoch_counts();
                gen.drift();
                c
            })
            .collect();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.5,
            epochs: 1,
            seed: 3,
        };
        let mut b = bencher(opts);
        let mut measure = |b: &mut Bencher, label: String| {
            // Fresh state + policy per iteration (as the seed bench did):
            // every timed epoch exercises the migration-heavy first-epoch
            // path — budget-limited promotion with victim selection over
            // the full fast tier — not a settled steady state.
            let mut e = 0usize;
            b.bench(&label, || {
                let mut state = initial_state(pages, ld, cxl, fast_cap, false);
                let mut pol = Tiering08::default();
                let c = &epochs[e % epochs.len()];
                e += 1;
                let run = tiering::simulate(
                    &sys,
                    &cfg,
                    &mut state,
                    &mut pol,
                    |_, buf| {
                        buf.clear();
                        buf.extend_from_slice(c);
                    },
                    |_| (Pattern::Random, 0.5),
                );
                std::hint::black_box(run.total_s);
            });
        };
        let name = if opts.smoke {
            "tiering/epoch(PageRank, t08, 16k pages)".to_string()
        } else {
            TIERING_NAME.to_string()
        };
        perf::with_reference(|| measure(&mut b, format!("{name} [reference]")));
        measure(&mut b, format!("{name} [optimized]"));
        let rs = b.results();
        speedups.push((name, ratio(&rs[0], &rs[1])));
        push_modes(&mut hotpaths, rs, &["reference", "optimized"]);
    }

    // --- promotion batch on the SoA page state ---
    // A full-pressure batch (promote slow pages into a full fast tier,
    // forcing mass demotion) through the packed-column SoA path —
    // single-stream victim scan + `select_nth_unstable` — vs the seed's
    // O(pages) recounts + full victim sort. Each iteration clones a
    // prebuilt template so both modes pay the identical setup cost.
    {
        let pages = if opts.smoke { 16_000 } else { 65_000 };
        let fast_cap = pages * 2 / 5;
        let mut template = initial_state(pages, ld, cxl, fast_cap, false);
        for p in 0..pages {
            template.last_counts[p] = ((p * 31) % 97) as u32;
        }
        // Every second slow page: larger than the (zero) free headroom,
        // smaller than the victim pool, so select/sort both run.
        let batch: Vec<usize> = (fast_cap..pages).step_by(2).collect();
        let mut b = bencher(opts);
        let mut measure = |b: &mut Bencher, label: String| {
            b.bench(&label, || {
                let mut s = template.clone();
                std::hint::black_box(s.promote_batch(std::hint::black_box(&batch)));
            });
        };
        perf::with_reference(|| measure(&mut b, format!("{PROMOTE_NAME} [reference]")));
        measure(&mut b, format!("{PROMOTE_NAME} [optimized]"));
        let rs = b.results();
        speedups.push((PROMOTE_NAME.to_string(), ratio(&rs[0], &rs[1])));
        push_modes(&mut hotpaths, rs, &["reference", "optimized"]);
    }

    // --- promotion batch at production scale: sequential vs chunked ---
    // The million-page regime: 16M pages (32 TB of 2 MB regions), full
    // promotion pressure. Both sides run the *optimized* SoA scan; the
    // pair isolates the intra-epoch chunking — sequential single-thread
    // (the parity reference the chunked path is pinned against) vs the
    // chunked `--jobs` scan with per-chunk top-k + rank merge. A custom
    // paired loop keeps the ~190 MB state clone untimed (a `Bencher`
    // closure would let the memcpy swamp the scan), and every iteration
    // asserts the two paths moved identical page counts; the first also
    // verifies full placement equality.
    {
        let pages: usize = 16 << 20;
        let fast_cap = pages * 2 / 5;
        let mut template = initial_state(pages, ld, cxl, fast_cap, false);
        for p in 0..pages {
            template.last_counts[p] = ((p * 31) % 97) as u32;
        }
        // Sparse batch of slow pages: far larger than the (zero) free
        // headroom, far smaller than the ~6.7M-page victim pool, so the
        // per-chunk top-k prunes hard.
        let batch: Vec<usize> = (fast_cap..pages).step_by(24).collect();
        let iters = if opts.smoke { 3 } else { 8 };
        let jobs = opts.jobs.max(2);
        let mut seq_ns: Vec<f64> = Vec::with_capacity(iters);
        let mut par_ns: Vec<f64> = Vec::with_capacity(iters);
        for it in 0..iters {
            let mut seq = template.clone();
            let t0 = Instant::now();
            let seq_res = perf::with_jobs(1, || seq.promote_batch(&batch));
            seq_ns.push(t0.elapsed().as_nanos() as f64);
            let mut par = template.clone();
            let t0 = Instant::now();
            let par_res = perf::with_jobs(jobs, || par.promote_batch(&batch));
            par_ns.push(t0.elapsed().as_nanos() as f64);
            assert_eq!(seq_res, par_res, "chunked promote_batch parity (counts)");
            if it == 0 {
                assert_eq!(seq.fast_used(), par.fast_used());
                assert!(
                    (0..pages).all(|q| seq.node_of(q) == par.node_of(q)),
                    "chunked promote_batch parity (placement)"
                );
            }
        }
        let r_seq = sampled_result(format!("{PROMOTE16_NAME} [reference]"), &seq_ns);
        let r_par = sampled_result(
            format!("{PROMOTE16_NAME} [optimized, jobs={jobs}]"),
            &par_ns,
        );
        println!("{}", r_seq.report());
        println!("{}", r_par.report());
        speedups.push((PROMOTE16_NAME.to_string(), ratio(&r_seq, &r_par)));
        hotpaths.push(HotpathResult {
            result: r_seq,
            mode: "reference",
        });
        hotpaths.push(HotpathResult {
            result: r_par,
            mode: "optimized",
        });
    }

    // --- delta trace encoding at production scale ---
    // A memory entry, not a time entry: its `speedup` value is the
    // dense/delta byte ratio of the 16M-page × 10-epoch PageRank trace.
    // Dense would be ~640 MB — it cannot fit the 256 MB trace-store
    // budget at all — so the dense side is arithmetic, never allocated;
    // the encode wall time is printed for the record.
    {
        let pages: usize = 16 << 20;
        let epochs = 10;
        let mut app = pagerank();
        app.pages = pages;
        let dense_bytes = epochs * pages * std::mem::size_of::<u32>();
        let t0 = Instant::now();
        let tr = crate::workloads::trace::EpochTrace::generate(&app, epochs, 5);
        let encode_s = t0.elapsed().as_secs_f64();
        assert!(tr.is_delta(), "16M-page PageRank trace must delta-encode");
        assert!(
            tr.bytes() <= crate::workloads::trace::DEFAULT_BUDGET_BYTES,
            "delta trace ({} B) must fit the store budget",
            tr.bytes()
        );
        assert!(
            dense_bytes > crate::workloads::trace::DEFAULT_BUDGET_BYTES,
            "scale check: the dense form must NOT fit the budget"
        );
        let mem_ratio = dense_bytes as f64 / tr.bytes().max(1) as f64;
        println!(
            "{TRACE_DELTA_NAME}: encoded in {encode_s:.2} s; {} MB delta vs {} MB dense \
             ({mem_ratio:.1}x smaller)",
            tr.bytes() >> 20,
            dense_bytes >> 20
        );
        speedups.push((TRACE_DELTA_NAME.to_string(), mem_ratio));
    }

    // --- incremental epoch-trace generation ---
    // A custom paired loop rather than `Bencher`: the hot-set drift
    // between epochs must run *untimed* — it is the application's own
    // behavior, identical RNG stream in both modes — so each epoch times
    // only histogram production: full seed-style regeneration (weight
    // table recomputed per epoch) vs the incremental copy. Both run on
    // the same generator state each epoch and are checked bit-identical.
    {
        let pages = if opts.smoke { 16_000 } else { 65_000 };
        let mut app = graph500();
        app.pages = pages;
        let mut gen = TraceGen::new(app, 11);
        let mut opt_buf = Vec::new();
        let mut ref_buf = Vec::new();
        let epochs = if opts.smoke { 16 } else { 48 };
        let mut opt_ns: Vec<f64> = Vec::with_capacity(epochs);
        let mut ref_ns: Vec<f64> = Vec::with_capacity(epochs);
        // Warm both paths (and size the reusable buffers) untimed.
        gen.epoch_counts_into(&mut opt_buf);
        perf::with_reference(|| gen.epoch_counts_into(&mut ref_buf));
        for _ in 0..epochs {
            gen.drift();
            let t0 = Instant::now();
            gen.epoch_counts_into(&mut opt_buf);
            opt_ns.push(t0.elapsed().as_nanos() as f64);
            let t0 = Instant::now();
            perf::with_reference(|| gen.epoch_counts_into(&mut ref_buf));
            ref_ns.push(t0.elapsed().as_nanos() as f64);
            assert_eq!(opt_buf, ref_buf, "incremental vs regeneration parity");
        }
        let r_ref = sampled_result(format!("{EPOCH_COUNTS_NAME} [reference]"), &ref_ns);
        let r_opt = sampled_result(format!("{EPOCH_COUNTS_NAME} [optimized]"), &opt_ns);
        println!("{}", r_ref.report());
        println!("{}", r_opt.report());
        speedups.push((EPOCH_COUNTS_NAME.to_string(), ratio(&r_ref, &r_opt)));
        hotpaths.push(HotpathResult {
            result: r_ref,
            mode: "reference",
        });
        hotpaths.push(HotpathResult {
            result: r_opt,
            mode: "optimized",
        });
    }

    // --- FlexGen control plane (policy search over the solver) ---
    {
        let gpu = crate::gpu::Gpu::a10();
        let icfg = crate::llm::flexgen::InferCfg::paper(crate::llm::model_cfg::llama_65b());
        let mut b = bencher(opts);
        let mut measure = |b: &mut Bencher, label: &str| {
            b.bench(label, || {
                let tiers = crate::llm::flexgen::tiers_of(
                    &sys,
                    &[(MemKind::Ldram, 196e9), (MemKind::Cxl, 128e9)],
                );
                let pol = crate::llm::flexgen::search_policy(&gpu, &icfg, &tiers);
                std::hint::black_box(crate::llm::flexgen::throughput(&sys, &gpu, &icfg, &pol));
            });
        };
        perf::with_reference(|| measure(&mut b, &format!("{FLEXGEN_NAME} [reference]")));
        // Memo off: "optimized" means the raw production path, matching
        // the schema doc — repeated identical searches would otherwise
        // reduce to cache lookups.
        perf::without_memo(|| measure(&mut b, &format!("{FLEXGEN_NAME} [optimized]")));
        let rs = b.results();
        speedups.push((FLEXGEN_NAME.to_string(), ratio(&rs[0], &rs[1])));
        push_modes(&mut hotpaths, rs, &["reference", "optimized"]);
    }

    // --- fig16 grid: shared-trace replay, then sequential vs parallel ---
    {
        let (apps, epochs, fast_gb) = if opts.smoke {
            // Shrunken working set for CI: same grid shape, ~10× cheaper.
            let mut apps = crate::workloads::tiering_apps::all_apps();
            for a in &mut apps {
                a.pages = 8_000;
            }
            (apps, 3usize, 6u64)
        } else {
            (crate::workloads::tiering_apps::all_apps(), 10, 50)
        };
        let sys16 = topology::system_a();

        // Shared-trace pair: the whole grid at jobs=1, seed semantics
        // (every cell regenerates its own epoch stream, seed promote
        // path, reference solver) vs the optimized stack (one immutable
        // snapshot per app replayed by all 8 of its cells, SoA state).
        // Same parallelism both sides — this isolates the algorithmic
        // trajectory; the fan-out ratio is the GRID entry below.
        perf::set_jobs(1);
        let t0 = Instant::now();
        perf::with_reference(|| {
            std::hint::black_box(exp::tiering_exp::fig16_with(
                &sys16, &apps, epochs, 7, 64, fast_gb,
            ));
        });
        let ref_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        std::hint::black_box(exp::tiering_exp::fig16_with(&sys16, &apps, epochs, 7, 64, fast_gb));
        let shared_s = t0.elapsed().as_secs_f64();
        println!(
            "{SHARED_TRACE_NAME} [reference]: {ref_s:.2} s, [optimized]: {shared_s:.2} s \
             (jobs=1)"
        );
        speedups.push((SHARED_TRACE_NAME.to_string(), ref_s / shared_s.max(1e-12)));

        // Wall-clock pair (the grid is one experiment, not a
        // microbenchmark): same optimized cell code both times, only
        // the inner fan-out differs.
        perf::set_jobs(1);
        let t0 = Instant::now();
        std::hint::black_box(exp::tiering_exp::fig16_with(&sys16, &apps, epochs, 7, 64, fast_gb));
        let seq_s = t0.elapsed().as_secs_f64();
        perf::set_jobs(opts.jobs);
        let t0 = Instant::now();
        std::hint::black_box(exp::tiering_exp::fig16_with(&sys16, &apps, epochs, 7, 64, fast_gb));
        let par_s = t0.elapsed().as_secs_f64();
        perf::set_jobs(1);
        println!(
            "{GRID_NAME} [jobs=1]: {seq_s:.2} s, [jobs={}]: {par_s:.2} s",
            opts.jobs
        );
        speedups.push((GRID_NAME.to_string(), seq_s / par_s.max(1e-12)));
    }

    // --- scenario result cache: fleet re-run, cold vs warm ---
    // Wall-clock pair over one seeded fleet and one on-disk store: the
    // cold pass evaluates every scenario and appends to the cache; the
    // warm pass reloads the store from disk and must be pure cache reads
    // — asserted via the miss probe and byte-identical JSONL.
    {
        let count = if opts.smoke { 6 } else { 16 };
        let template = Json::parse(&format!(
            r#"{{"name": "bench-fleet", "fleet": {{"count": {count}, "seed": 7}}}}"#
        ))
        .expect("internal fleet template");
        let specs: Vec<crate::scenario::ScenarioSpec> =
            crate::scenario::expand(&template, None, None)
                .expect("fleet expansion")
                .iter()
                .map(|d| crate::scenario::ScenarioSpec::parse(d).expect("fleet spec"))
                .collect();
        let dir = std::env::temp_dir().join(format!("cxlmem-bench-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cache = crate::scenario::ResultCache::open(&dir).expect("cache open");
        let t0 = Instant::now();
        let cold = crate::scenario::run_batch_cached(&specs, opts.jobs, Some(&mut cache))
            .expect("cold fleet run");
        let cold_s = t0.elapsed().as_secs_f64();
        // The warm pass is timed end-to-end including the store load: a
        // real re-run pays the disk read too.
        let t0 = Instant::now();
        let mut cache = crate::scenario::ResultCache::open(&dir).expect("cache reopen");
        let warm = crate::scenario::run_batch_cached(&specs, opts.jobs, Some(&mut cache))
            .expect("warm fleet run");
        let warm_s = t0.elapsed().as_secs_f64();
        assert_eq!(cache.misses(), 0, "warm fleet run must not evaluate");
        let cold_jsonl = crate::util::json::to_jsonl(cold.into_iter().map(|r| r.doc));
        let warm_jsonl = crate::util::json::to_jsonl(warm.into_iter().map(|r| r.doc));
        assert_eq!(cold_jsonl, warm_jsonl, "cache hits must not change output");
        let _ = std::fs::remove_dir_all(&dir);
        println!(
            "{SCENARIO_CACHE_NAME} [cold]: {cold_s:.3} s, [warm]: {warm_s:.4} s \
             ({count} scenarios, jobs={})",
            opts.jobs
        );
        speedups.push((SCENARIO_CACHE_NAME.to_string(), cold_s / warm_s.max(1e-12)));
    }

    // --- scenario result cache: contended flush, legacy flock vs layered ---
    // 8 writers hammer one store with disjoint key ranges, flushing
    // every 64 inserts. The legacy path (each flush: store-wide flock +
    // full re-read + append) serializes on the lock; the layered path
    // seals lock-free segments and pays the lock once, in the single
    // final compaction — both timed end-to-end and asserted to leave
    // identical key counts. This is the store refactor's headline ratio.
    {
        use crate::scenario::store::legacy::LegacyCache;
        let writers = 8usize;
        let per = if opts.smoke { 128usize } else { 1000 };
        let flush_every = 64usize;
        let entry_doc = |w: usize, i: usize| {
            Json::obj(vec![("w", (w as u64).into()), ("i", (i as u64).into())])
        };

        let dir_legacy =
            std::env::temp_dir().join(format!("cxlmem-bench-flush-legacy-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir_legacy);
        std::fs::create_dir_all(&dir_legacy).expect("bench dir");
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for w in 0..writers {
                let dir = &dir_legacy;
                s.spawn(move || {
                    let mut cache = LegacyCache::open(dir).expect("legacy open");
                    for i in 0..per {
                        cache.insert(
                            format!("w{w}-{i:05}"),
                            format!("bench-w{w}-{i}"),
                            format!("spec-w{w}-{i}"),
                            entry_doc(w, i),
                        );
                        if (i + 1) % flush_every == 0 {
                            cache.flush().expect("legacy flush");
                        }
                    }
                    cache.flush().expect("legacy flush");
                });
            }
        });
        let legacy_s = t0.elapsed().as_secs_f64();

        let dir_layered =
            std::env::temp_dir().join(format!("cxlmem-bench-flush-layered-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir_layered);
        let mut cache = crate::scenario::ResultCache::open(&dir_layered).expect("cache open");
        cache.set_compact_every(0);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for w in 0..writers {
                let handle = cache.handle();
                s.spawn(move || {
                    for i in 0..per {
                        let result = crate::scenario::ScenarioResult {
                            name: format!("bench-w{w}-{i}"),
                            experiment: None,
                            doc: entry_doc(w, i),
                        };
                        handle.insert(&format!("w{w}-{i:05}"), format!("spec-w{w}-{i}"), &result);
                        if (i + 1) % flush_every == 0 {
                            handle.seal().expect("seal");
                        }
                    }
                    handle.seal().expect("seal");
                });
            }
        });
        // The one lock-taking pass the layered path owes the directory.
        cache.compact().expect("final compaction");
        let layered_s = t0.elapsed().as_secs_f64();

        let want = writers * per;
        for dir in [&dir_legacy, &dir_layered] {
            let text = crate::scenario::cache::merged_store_text(dir).expect("store text");
            assert_eq!(
                text.lines().count(),
                want,
                "{} must hold every key exactly once",
                dir.display()
            );
        }
        let _ = std::fs::remove_dir_all(&dir_legacy);
        let _ = std::fs::remove_dir_all(&dir_layered);
        println!(
            "{CACHE_FLUSH_NAME} [legacy flock]: {legacy_s:.3} s, [layered]: {layered_s:.3} s \
             ({writers} writers x {per} entries, flush every {flush_every})"
        );
        speedups.push((CACHE_FLUSH_NAME.to_string(), legacy_s / layered_s.max(1e-12)));
    }

    // --- scenario serve: warm daemon vs cold one-shot processes ---
    // The serve daemon's headline ratio: one fleet of N specs submitted
    // over the daemon's Unix socket with caches warm (an untimed first
    // pass populates the resident store) vs the same N specs as N
    // concurrent cold `scenario run` processes, each paying process
    // startup, a cold trace store, and a full evaluation. The cold side
    // needs the real `cxlmem` binary, so the entry records only under
    // `cxlmem bench` (the `make bench-check` path), not the cargo-bench
    // harness.
    #[cfg(unix)]
    {
        use crate::scenario::serve::{self, ServeOpts};
        let count = if opts.smoke { 6 } else { 16 };
        let template = Json::parse(&format!(
            r#"{{"name": "bench-serve", "fleet": {{"count": {count}, "seed": 7}}}}"#
        ))
        .expect("internal fleet template");
        let docs = crate::scenario::expand(&template, None, None).expect("fleet expansion");
        let lines: Vec<String> = docs.iter().map(|d| d.to_string()).collect();
        let exe = std::env::current_exe().ok().filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("cxlmem"))
        });
        match exe {
            None => println!(
                "{SERVE_NAME}: skipped — the cold side needs the cxlmem binary \
                 (run `cxlmem bench`, e.g. via `make bench-check`)"
            ),
            Some(exe) => {
                let dir =
                    std::env::temp_dir().join(format!("cxlmem-bench-serve-{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&dir);
                let cache = crate::scenario::ResultCache::open(&dir).expect("serve cache open");
                let socket = dir.join("serve.sock");
                let mut sopts = ServeOpts::new(&socket);
                sopts.workers = opts.jobs.max(2);
                sopts.queue_cap = 1024;
                let daemon = std::thread::spawn(move || serve::run_serve(cache, &sopts));
                serve::wait_ready(&socket, std::time::Duration::from_secs(10))
                    .expect("serve daemon ready");
                // Untimed warm-up: the cold evaluations that fill the
                // resident store and the trace store.
                let first = serve::request_lines(&socket, &lines).expect("serve warm-up pass");
                let t0 = Instant::now();
                let warm = serve::request_lines(&socket, &lines).expect("serve warm pass");
                let warm_s = t0.elapsed().as_secs_f64();
                assert_eq!(first, warm, "warm responses must match the evaluating pass");
                serve::request_lines(&socket, &[r#"{"verb": "shutdown"}"#.to_string()])
                    .expect("serve shutdown");
                daemon
                    .join()
                    .expect("serve daemon thread")
                    .expect("serve daemon exit");

                // Cold side: one process per spec, all launched at once —
                // the kernel gives the one-shots at least the daemon's
                // parallelism, so the ratio isolates amortization, not
                // scheduling.
                let cold_dir = dir.join("cold");
                std::fs::create_dir_all(&cold_dir).expect("cold dir");
                let mut outs = Vec::with_capacity(lines.len());
                let t0 = Instant::now();
                let children: Vec<_> = lines
                    .iter()
                    .enumerate()
                    .map(|(i, line)| {
                        let spec = cold_dir.join(format!("spec-{i}.json"));
                        let out = cold_dir.join(format!("out-{i}.jsonl"));
                        std::fs::write(&spec, format!("{line}\n")).expect("cold spec write");
                        let child = std::process::Command::new(&exe)
                            .arg("scenario")
                            .arg("run")
                            .arg(&spec)
                            .arg("--no-cache")
                            .arg("--jobs")
                            .arg("1")
                            .arg("--out")
                            .arg(&out)
                            .stdout(std::process::Stdio::null())
                            .stderr(std::process::Stdio::null())
                            .spawn()
                            .expect("cold scenario run spawn");
                        outs.push(out);
                        child
                    })
                    .collect();
                for mut child in children {
                    let status = child.wait().expect("cold scenario run wait");
                    assert!(status.success(), "cold scenario run failed: {status}");
                }
                let cold_s = t0.elapsed().as_secs_f64();
                let mut cold_cat = String::new();
                for out in &outs {
                    cold_cat.push_str(&std::fs::read_to_string(out).expect("cold output read"));
                }
                let mut warm_cat = warm.join("\n");
                warm_cat.push('\n');
                assert_eq!(
                    cold_cat, warm_cat,
                    "daemon responses must be byte-identical to cold one-shot runs"
                );
                let _ = std::fs::remove_dir_all(&dir);
                println!(
                    "{SERVE_NAME} [cold one-shots]: {cold_s:.3} s, [warm daemon]: {warm_s:.4} s \
                     ({count} requests, {} worker(s))",
                    opts.jobs.max(2)
                );
                speedups.push((SERVE_NAME.to_string(), cold_s / warm_s.max(1e-12)));
            }
        }
    }

    // --- exp all wall clock: sequential reference vs parallel optimized ---
    let t0 = Instant::now();
    perf::with_reference(|| {
        exp::run_all(exp::ALL, 1).expect("exp all (reference) failed");
    });
    let exp_all_reference_s = t0.elapsed().as_secs_f64();
    println!("exp/all [reference, jobs=1]: {exp_all_reference_s:.2} s");

    System::clear_solver_cache();
    // Same methodology for the trace store: the fig16 block above warmed
    // the exact keys exp/all's fig16 uses, and a standalone `cxlmem exp
    // all` process would pay those generations.
    crate::workloads::trace::global().clear();
    let t0 = Instant::now();
    exp::run_all(exp::ALL, opts.jobs).expect("exp all (optimized) failed");
    let exp_all_optimized_s = t0.elapsed().as_secs_f64();
    println!(
        "exp/all [optimized, jobs={}]: {exp_all_optimized_s:.2} s",
        opts.jobs
    );
    speedups.push((
        EXP_ALL_NAME.to_string(),
        exp_all_reference_s / exp_all_optimized_s.max(1e-12),
    ));

    perf::set_jobs(prev_jobs);
    BenchReport {
        hotpaths,
        exp_all_reference_s,
        exp_all_optimized_s,
        speedups,
        jobs: opts.jobs,
        smoke: opts.smoke,
    }
}

fn ratio(reference: &BenchResult, optimized: &BenchResult) -> f64 {
    reference.median_ns / optimized.median_ns.max(1e-9)
}

/// Summarize hand-timed samples (custom paired loops that must keep
/// setup untimed) into the same shape `Bencher` produces.
fn sampled_result(label: String, ns: &[f64]) -> BenchResult {
    BenchResult {
        name: label,
        iters: ns.len() as u64,
        mean_ns: stats::mean(ns),
        median_ns: stats::median(ns),
        p50_ns: crate::util::timer::bucketed_percentile(ns, 50.0),
        p90_ns: crate::util::timer::bucketed_percentile(ns, 90.0),
        p95_ns: stats::percentile(ns, 95.0),
        stddev_ns: stats::stddev(ns),
    }
}

fn push_modes(out: &mut Vec<HotpathResult>, results: &[BenchResult], modes: &[&'static str]) {
    let start = results.len() - modes.len();
    for (r, &mode) in results[start..].iter().zip(modes) {
        out.push(HotpathResult {
            result: r.clone(),
            mode,
        });
    }
}

impl BenchReport {
    /// Render as the `BENCH_hotpath.json` document.
    pub fn to_json(&self) -> Json {
        let hotpaths = Json::arr(self.hotpaths.iter().map(|h| {
            Json::obj(vec![
                ("name", strip_mode_suffix(&h.result.name).into()),
                ("mode", h.mode.into()),
                ("median_ns", h.result.median_ns.into()),
                ("mean_ns", h.result.mean_ns.into()),
                // Bucketed through util::metrics edges — comparable
                // 1:1 with metrics-sidecar histogram quantiles.
                ("p50_ns", h.result.p50_ns.into()),
                ("p90_ns", h.result.p90_ns.into()),
                ("p95_ns", h.result.p95_ns.into()),
                ("iters", h.result.iters.into()),
            ])
        }));
        let speedup = Json::Obj(
            self.speedups
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        Json::obj(vec![
            ("schema", "cxlmem-bench-v1".into()),
            ("jobs", self.jobs.into()),
            ("smoke", self.smoke.into()),
            ("hotpaths", hotpaths),
            (
                "wall",
                Json::obj(vec![
                    ("exp_all_reference_s", self.exp_all_reference_s.into()),
                    ("exp_all_optimized_s", self.exp_all_optimized_s.into()),
                ]),
            ),
            ("speedup", speedup),
        ])
    }

    /// Write `BENCH_hotpath.json`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Human summary of the speedup column.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (name, s) in &self.speedups {
            out.push_str(&format!("{name:<44} speedup {s:>7.2}x\n"));
        }
        out
    }
}

fn strip_mode_suffix(name: &str) -> String {
    match name.rfind(" [") {
        Some(i) => name[..i].to_string(),
        None => name.to_string(),
    }
}

/// Validate a parsed `BENCH_hotpath.json` document against schema
/// `cxlmem-bench-v1` — the gate behind `cxlmem bench --validate FILE`
/// and `make bench-check`. Checks the schema tag, the top-level shape,
/// and that every measurement carries finite, non-negative numbers.
pub fn validate_report_doc(doc: &Json) -> Result<()> {
    match doc.get("schema").and_then(Json::as_str) {
        Some("cxlmem-bench-v1") => {}
        Some(other) => bail!("schema is '{other}', want 'cxlmem-bench-v1'"),
        None => bail!("missing string field 'schema'"),
    }
    doc.get("jobs")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("missing numeric field 'jobs'"))?;
    doc.get("smoke")
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow!("missing boolean field 'smoke'"))?;
    let hotpaths = doc
        .get("hotpaths")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing array field 'hotpaths'"))?;
    if hotpaths.is_empty() {
        bail!("'hotpaths' is empty");
    }
    for (i, h) in hotpaths.iter().enumerate() {
        let name = h
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("hotpaths[{i}]: missing string 'name'"))?;
        let mode = h
            .get("mode")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("hotpaths[{i}] ('{name}'): missing string 'mode'"))?;
        if !matches!(mode, "reference" | "optimized" | "memoized") {
            bail!(
                "hotpaths[{i}] ('{name}'): mode '{mode}' not one of \
                 reference|optimized|memoized"
            );
        }
        for field in ["median_ns", "mean_ns", "p95_ns", "iters"] {
            let v = h.get(field).and_then(Json::as_f64).ok_or_else(|| {
                anyhow!("hotpaths[{i}] ('{name}'): missing numeric '{field}'")
            })?;
            if !v.is_finite() || v < 0.0 {
                bail!("hotpaths[{i}] ('{name}'): '{field}' must be finite and >= 0");
            }
        }
    }
    let wall = doc
        .get("wall")
        .ok_or_else(|| anyhow!("missing object field 'wall'"))?;
    for field in ["exp_all_reference_s", "exp_all_optimized_s"] {
        let v = wall
            .get(field)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("wall: missing numeric '{field}'"))?;
        if !v.is_finite() || v < 0.0 {
            bail!("wall.{field} must be finite and >= 0");
        }
    }
    let speedup = doc
        .get("speedup")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("missing object field 'speedup'"))?;
    if speedup.is_empty() {
        bail!("'speedup' is empty");
    }
    for (k, v) in speedup {
        let v = v
            .as_f64()
            .ok_or_else(|| anyhow!("speedup['{k}'] must be a number"))?;
        if !v.is_finite() || v < 0.0 {
            bail!("speedup['{k}'] must be finite and >= 0");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_schema_shape() {
        let report = BenchReport {
            hotpaths: vec![HotpathResult {
                result: BenchResult {
                    name: format!("{SOLVER_NAME} [optimized]"),
                    iters: 10,
                    mean_ns: 2.0,
                    median_ns: 1.5,
                    p50_ns: 1.5,
                    p90_ns: 2.5,
                    p95_ns: 3.0,
                    stddev_ns: 0.1,
                },
                mode: "optimized",
            }],
            exp_all_reference_s: 4.0,
            exp_all_optimized_s: 1.0,
            speedups: vec![(EXP_ALL_NAME.to_string(), 4.0)],
            jobs: 2,
            smoke: true,
        };
        let j = report.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("cxlmem-bench-v1"));
        assert_eq!(j.get("jobs").unwrap().as_u64(), Some(2));
        let hp = j.get("hotpaths").unwrap().as_arr().unwrap();
        assert_eq!(hp[0].get("name").unwrap().as_str(), Some(SOLVER_NAME));
        assert_eq!(hp[0].get("mode").unwrap().as_str(), Some("optimized"));
        assert_eq!(hp[0].get("p50_ns").unwrap().as_f64(), Some(1.5));
        assert_eq!(hp[0].get("p90_ns").unwrap().as_f64(), Some(2.5));
        let wall = j.get("wall").unwrap();
        assert_eq!(wall.get("exp_all_reference_s").unwrap().as_f64(), Some(4.0));
        assert_eq!(
            j.get("speedup").unwrap().get(EXP_ALL_NAME).unwrap().as_f64(),
            Some(4.0)
        );
        // Round-trips through the parser.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // And the emitted document is schema-valid.
        validate_report_doc(&j).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        let good = BenchReport {
            hotpaths: vec![HotpathResult {
                result: BenchResult {
                    name: format!("{EPOCH_COUNTS_NAME} [reference]"),
                    iters: 4,
                    mean_ns: 2.0,
                    median_ns: 1.5,
                    p50_ns: 1.5,
                    p90_ns: 2.5,
                    p95_ns: 3.0,
                    stddev_ns: 0.1,
                },
                mode: "reference",
            }],
            exp_all_reference_s: 4.0,
            exp_all_optimized_s: 1.0,
            speedups: vec![(SCENARIO_CACHE_NAME.to_string(), 40.0)],
            jobs: 2,
            smoke: true,
        }
        .to_json();
        validate_report_doc(&good).unwrap();
        // Each mutation below must fail with a pointed message.
        let mutate = |f: &dyn Fn(&mut Json)| {
            let mut doc = good.clone();
            f(&mut doc);
            doc
        };
        let bad_schema = mutate(&|d| d.set("schema", "cxlmem-bench-v0".into()));
        assert!(validate_report_doc(&bad_schema).is_err());
        let no_wall = mutate(&|d| d.set("wall", Json::Null));
        assert!(validate_report_doc(&no_wall).is_err());
        let empty_hot = mutate(&|d| d.set("hotpaths", Json::Arr(Vec::new())));
        assert!(validate_report_doc(&empty_hot).is_err());
        let bad_mode = mutate(&|d| {
            if let Json::Obj(m) = d {
                if let Some(Json::Arr(hp)) = m.get_mut("hotpaths") {
                    hp[0].set("mode", "turbo".into());
                }
            }
        });
        assert!(validate_report_doc(&bad_mode).is_err());
        let nan_speedup = mutate(&|d| {
            d.set("speedup", Json::obj(vec![("x", Json::Num(f64::NAN))]));
        });
        assert!(validate_report_doc(&nan_speedup).is_err());
        assert!(validate_report_doc(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn strip_suffix() {
        assert_eq!(strip_mode_suffix("a/b [reference]"), "a/b");
        assert_eq!(strip_mode_suffix("plain"), "plain");
    }
}
