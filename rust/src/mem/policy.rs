//! NUMA memory placement policies, with `numa(3)`/`numactl` semantics.
//!
//! These are the static placement policies the paper evaluates:
//! first touch (Linux default), preferred, membind, uniform interleave,
//! and subset interleave (`numa_alloc_interleaved_subset`, the primitive
//! under the paper's object-level interleaving).

use crate::memsim::{MemKind, NodeId, System};

/// A page placement policy for a VMA / data object.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Allocate on the faulting thread's local node; fall back by NUMA
    /// distance when full (Linux default behaviour).
    FirstTouch,
    /// Prefer `0`-th entry; when full, fall back to the next-closest
    /// node (the paper's "preferred" policy).
    Preferred(NodeId),
    /// Strict bind to the node set: round-robin inside the set; OOM when
    /// all are full (numactl --membind).
    Membind(Vec<NodeId>),
    /// Round-robin page interleave across the node set
    /// (numactl --interleave / numa_alloc_interleaved_subset).
    Interleave(Vec<NodeId>),
    /// Weighted interleave (Linux weighted interleave, e.g. 2:1 ratios).
    WeightedInterleave(Vec<(NodeId, u32)>),
}

impl Policy {
    /// Human-readable label matching the paper's figure legends.
    pub fn label(&self, sys: &System, socket: usize) -> String {
        let name = |&n: &NodeId| sys.kind_from(socket, n).label().to_string();
        match self {
            Policy::FirstTouch => "first-touch".into(),
            Policy::Preferred(n) => format!("{} preferred", name(n)),
            Policy::Membind(ns) => format!(
                "bind({})",
                ns.iter().map(|n| name(n)).collect::<Vec<_>>().join("+")
            ),
            Policy::Interleave(ns) => {
                let labels: Vec<String> = ns.iter().map(|n| name(n)).collect();
                if ns.len() == sys.nodes.iter().filter(|n| n.device.kind.is_dram_like()).count()
                {
                    "interleave all".into()
                } else {
                    format!("interleave {}", labels.join("+"))
                }
            }
            Policy::WeightedInterleave(ws) => format!(
                "winterleave({})",
                ws.iter()
                    .map(|(n, w)| format!("{}:{}", name(n), w))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

/// Fallback order for a socket: nodes sorted by idle latency (NUMA
/// distance), nearest first. NVMe never appears (not a page target).
pub fn fallback_order(sys: &System, socket: usize) -> Vec<NodeId> {
    let mut order: Vec<NodeId> = (0..sys.nodes.len())
        .filter(|&n| sys.nodes[n].device.kind.is_dram_like())
        .collect();
    order.sort_by(|&a, &b| {
        let la = sys.idle_latency(socket, a, crate::memsim::Pattern::Sequential);
        let lb = sys.idle_latency(socket, b, crate::memsim::Pattern::Sequential);
        la.partial_cmp(&lb).unwrap()
    });
    order
}

/// Convenience constructors for the paper's standard policy set.
pub fn ldram_preferred(sys: &System, socket: usize) -> Policy {
    Policy::Preferred(sys.node_of(socket, MemKind::Ldram).unwrap())
}

pub fn cxl_preferred(sys: &System, socket: usize) -> Policy {
    Policy::Preferred(sys.node_of(socket, MemKind::Cxl).unwrap())
}

pub fn interleave_kinds(sys: &System, socket: usize, kinds: &[MemKind]) -> Policy {
    Policy::Interleave(
        kinds
            .iter()
            .map(|&k| sys.node_of(socket, k).expect("node kind missing"))
            .collect(),
    )
}

/// "interleave all": LDRAM + RDRAM + CXL.
pub fn interleave_all(sys: &System, socket: usize) -> Policy {
    interleave_kinds(sys, socket, &[MemKind::Ldram, MemKind::Rdram, MemKind::Cxl])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;

    #[test]
    fn fallback_is_ldram_rdram_cxl() {
        let sys = system_a();
        let order = fallback_order(&sys, 0);
        let kinds: Vec<MemKind> = order.iter().map(|&n| sys.kind_from(0, n)).collect();
        assert_eq!(kinds, vec![MemKind::Ldram, MemKind::Rdram, MemKind::Cxl]);
    }

    #[test]
    fn fallback_excludes_nvme() {
        let sys = system_a();
        for &n in &fallback_order(&sys, 0) {
            assert!(sys.nodes[n].device.kind.is_dram_like());
        }
    }

    #[test]
    fn labels_match_paper_legends() {
        let sys = system_a();
        assert_eq!(ldram_preferred(&sys, 0).label(&sys, 0), "LDRAM preferred");
        assert_eq!(
            interleave_kinds(&sys, 0, &[MemKind::Ldram, MemKind::Cxl]).label(&sys, 0),
            "interleave LDRAM+CXL"
        );
        assert_eq!(interleave_all(&sys, 0).label(&sys, 0), "interleave all");
    }
}
