//! Virtual memory: data objects (VMAs), fault-driven page placement, and
//! placement queries used by the execution engine and tiering layers.

use anyhow::{bail, Result};

use super::page::{pages_of, PhysMem};
use super::policy::{fallback_order, Policy};
use crate::memsim::{NodeId, System};

/// Handle to an allocated data object.
pub type ObjectId = usize;

/// A data object: one VMA-like region with a placement policy and a
/// per-page node map.
#[derive(Clone, Debug)]
pub struct DataObject {
    pub name: String,
    pub bytes: u64,
    pub policy: Policy,
    /// Page → node placement, in fault order.
    pub placement: Vec<NodeId>,
    /// Whether the kernel may migrate these pages. Linux AutoNUMA skips
    /// VMAs carrying an explicit mempolicy — the mechanism behind the
    /// paper's PMO 3 ("interleaving places pages in unmigratable
    /// regions").
    pub migratable: bool,
}

impl DataObject {
    pub fn pages(&self) -> u64 {
        self.placement.len() as u64
    }

    /// Fraction of this object's pages on each node (access weights for a
    /// uniform scan of the object). `n_nodes` sizes the count buffer — pass
    /// the system's node count; placements beyond it still work (the
    /// buffer grows on demand), so no separate max() pass is needed.
    pub fn node_weights_in(&self, n_nodes: usize) -> Vec<(NodeId, f64)> {
        if self.placement.is_empty() {
            return Vec::new();
        }
        let mut counts = vec![0u64; n_nodes];
        for &n in &self.placement {
            if n >= counts.len() {
                counts.resize(n + 1, 0);
            }
            counts[n] += 1;
        }
        let total = self.placement.len() as f64;
        counts
            .into_iter()
            .enumerate()
            .filter(|&(_, c)| c > 0)
            .map(|(n, c)| (n, c as f64 / total))
            .collect()
    }

    /// [`DataObject::node_weights_in`] without a known node count (sizes
    /// the buffer on demand in the same single pass).
    pub fn node_weights(&self) -> Vec<(NodeId, f64)> {
        self.node_weights_in(0)
    }

    pub fn pages_on(&self, node: NodeId) -> u64 {
        self.placement.iter().filter(|&&n| n == node).count() as u64
    }
}

/// An application's address space: the set of its data objects.
#[derive(Clone, Debug, Default)]
pub struct AddressSpace {
    pub objects: Vec<DataObject>,
}

impl AddressSpace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate (fault in) an object of `bytes` under `policy`, with the
    /// faulting threads on `socket`. Pages are placed one by one exactly
    /// as Linux would: policy target first, then distance-ordered
    /// fallback; strict membind OOMs instead of falling back.
    pub fn alloc(
        &mut self,
        sys: &System,
        phys: &mut PhysMem,
        socket: usize,
        name: &str,
        bytes: u64,
        policy: Policy,
    ) -> Result<ObjectId> {
        let npages = pages_of(bytes);
        let order = fallback_order(sys, socket);
        let mut placement = Vec::with_capacity(npages as usize);
        let mut rr = 0usize; // round-robin cursor for interleaves

        for page_idx in 0..npages {
            let node = match &policy {
                Policy::FirstTouch => alloc_with_fallback(phys, &order, order[0]),
                Policy::Preferred(n) => alloc_with_fallback(phys, &order, *n),
                Policy::Membind(set) => {
                    // Strict: only nodes in the set, round-robin, skip
                    // full ones; OOM when the whole set is full.
                    let mut placed = None;
                    for k in 0..set.len() {
                        let cand = set[(rr + k) % set.len()];
                        if phys.try_alloc(cand) {
                            placed = Some(cand);
                            rr = (rr + k + 1) % set.len();
                            break;
                        }
                    }
                    match placed {
                        Some(n) => Some(n),
                        None => {
                            bail!(
                                "membind OOM for object '{name}' at page {page_idx}/{npages}"
                            )
                        }
                    }
                }
                Policy::Interleave(set) => {
                    // Round-robin; a full node is skipped (Linux falls
                    // through to the next interleave target). If the
                    // whole set is full, fall back by distance.
                    let mut placed = None;
                    for k in 0..set.len() {
                        let cand = set[(rr + k) % set.len()];
                        if phys.try_alloc(cand) {
                            placed = Some(cand);
                            rr = (rr + k + 1) % set.len();
                            break;
                        }
                    }
                    placed.or_else(|| alloc_with_fallback(phys, &order, order[0]))
                }
                Policy::WeightedInterleave(weights) => {
                    // Expand weights into a repeating schedule.
                    let total: u32 = weights.iter().map(|&(_, w)| w).sum();
                    let mut placed = None;
                    for k in 0..total {
                        let slot = (rr as u32 + k) % total;
                        let mut acc = 0u32;
                        let mut cand = weights[0].0;
                        for &(n, w) in weights {
                            acc += w;
                            if slot < acc {
                                cand = n;
                                break;
                            }
                        }
                        if phys.try_alloc(cand) {
                            placed = Some(cand);
                            rr = ((rr as u32 + k + 1) % total) as usize;
                            break;
                        }
                    }
                    placed.or_else(|| alloc_with_fallback(phys, &order, order[0]))
                }
            };
            match node {
                Some(n) => placement.push(n),
                None => bail!("OOM: no node can hold page {page_idx} of '{name}'"),
            }
        }

        let migratable = matches!(policy, Policy::FirstTouch | Policy::Preferred(_));
        self.objects.push(DataObject {
            name: name.to_string(),
            bytes,
            policy,
            placement,
            migratable,
        });
        Ok(self.objects.len() - 1)
    }

    /// Free an object's pages back to the zones. Also zeroes the object's
    /// `bytes` so accounting queries ([`AddressSpace::total_bytes`]) no
    /// longer count freed objects.
    pub fn free(&mut self, phys: &mut PhysMem, id: ObjectId) {
        for &n in &self.objects[id].placement {
            phys.free(n);
        }
        self.objects[id].placement.clear();
        self.objects[id].bytes = 0;
    }

    pub fn object(&self, id: ObjectId) -> &DataObject {
        &self.objects[id]
    }

    pub fn total_pages_on(&self, node: NodeId) -> u64 {
        self.objects.iter().map(|o| o.pages_on(node)).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.objects.iter().map(|o| o.bytes).sum()
    }
}

/// Try `preferred` first, then the distance-ordered fallback chain.
fn alloc_with_fallback(phys: &mut PhysMem, order: &[NodeId], preferred: NodeId) -> Option<NodeId> {
    if phys.try_alloc(preferred) {
        return Some(preferred);
    }
    for &n in order {
        if n != preferred && phys.try_alloc(n) {
            return Some(n);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::page::PAGE_BYTES;
    use crate::mem::policy;
    use crate::memsim::topology::system_a;
    use crate::memsim::MemKind;

    fn setup() -> (crate::memsim::System, PhysMem, AddressSpace) {
        let sys = system_a();
        let phys = PhysMem::of_system(&sys);
        (sys, phys, AddressSpace::new())
    }

    #[test]
    fn preferred_lands_on_target_until_full() {
        let (sys, mut phys, mut asp) = setup();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        phys.limit_node(ld, 10 * PAGE_BYTES);
        let id = asp
            .alloc(
                &sys,
                &mut phys,
                0,
                "u",
                20 * PAGE_BYTES,
                Policy::Preferred(ld),
            )
            .unwrap();
        let obj = asp.object(id);
        assert_eq!(obj.pages_on(ld), 10);
        // Overflow goes to the next-closest node (RDRAM).
        let rd = sys.node_of(0, MemKind::Rdram).unwrap();
        assert_eq!(obj.pages_on(rd), 10);
    }

    #[test]
    fn interleave_round_robins_evenly() {
        let (sys, mut phys, mut asp) = setup();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let id = asp
            .alloc(
                &sys,
                &mut phys,
                0,
                "v",
                100 * PAGE_BYTES,
                Policy::Interleave(vec![ld, cxl]),
            )
            .unwrap();
        let obj = asp.object(id);
        assert_eq!(obj.pages_on(ld), 50);
        assert_eq!(obj.pages_on(cxl), 50);
        assert!(!obj.migratable, "interleaved VMA must be unmigratable (PMO 3)");
    }

    #[test]
    fn interleave_skips_full_node() {
        let (sys, mut phys, mut asp) = setup();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        phys.limit_node(cxl, 5 * PAGE_BYTES);
        let id = asp
            .alloc(
                &sys,
                &mut phys,
                0,
                "w",
                40 * PAGE_BYTES,
                Policy::Interleave(vec![ld, cxl]),
            )
            .unwrap();
        let obj = asp.object(id);
        assert_eq!(obj.pages_on(cxl), 5);
        assert_eq!(obj.pages_on(ld), 35);
    }

    #[test]
    fn membind_ooms_when_set_full() {
        let (sys, mut phys, mut asp) = setup();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        phys.limit_node(cxl, 2 * PAGE_BYTES);
        let err = asp.alloc(
            &sys,
            &mut phys,
            0,
            "x",
            4 * PAGE_BYTES,
            Policy::Membind(vec![cxl]),
        );
        assert!(err.is_err());
    }

    #[test]
    fn weighted_interleave_ratio() {
        let (sys, mut phys, mut asp) = setup();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let id = asp
            .alloc(
                &sys,
                &mut phys,
                0,
                "y",
                90 * PAGE_BYTES,
                Policy::WeightedInterleave(vec![(ld, 2), (cxl, 1)]),
            )
            .unwrap();
        let obj = asp.object(id);
        assert_eq!(obj.pages_on(ld), 60);
        assert_eq!(obj.pages_on(cxl), 30);
    }

    #[test]
    fn node_weights_sum_to_one() {
        let (sys, mut phys, mut asp) = setup();
        let id = asp
            .alloc(
                &sys,
                &mut phys,
                0,
                "z",
                64 * PAGE_BYTES,
                policy::interleave_all(&sys, 0),
            )
            .unwrap();
        let w: f64 = asp.object(id).node_weights().iter().map(|&(_, w)| w).sum();
        assert!((w - 1.0).abs() < 1e-12);
    }

    #[test]
    fn first_touch_local_then_spill() {
        let (sys, mut phys, mut asp) = setup();
        let ld = sys.node_of(1, MemKind::Ldram).unwrap();
        phys.limit_node(ld, 3 * PAGE_BYTES);
        let id = asp
            .alloc(&sys, &mut phys, 1, "ft", 5 * PAGE_BYTES, Policy::FirstTouch)
            .unwrap();
        let obj = asp.object(id);
        assert_eq!(obj.pages_on(ld), 3);
        assert!(obj.migratable);
    }

    #[test]
    fn free_returns_pages() {
        let (sys, mut phys, mut asp) = setup();
        let before = phys.total_used();
        let id = asp
            .alloc(&sys, &mut phys, 0, "f", 8 * PAGE_BYTES, Policy::FirstTouch)
            .unwrap();
        assert_eq!(phys.total_used(), before + 8);
        asp.free(&mut phys, id);
        assert_eq!(phys.total_used(), before);
    }

    #[test]
    fn free_zeroes_accounting() {
        // Regression: freeing cleared `placement` but left `bytes`, so
        // total_bytes() kept counting freed objects.
        let (sys, mut phys, mut asp) = setup();
        let a = asp
            .alloc(&sys, &mut phys, 0, "a", 8 * PAGE_BYTES, Policy::FirstTouch)
            .unwrap();
        let _b = asp
            .alloc(&sys, &mut phys, 0, "b", 4 * PAGE_BYTES, Policy::FirstTouch)
            .unwrap();
        assert_eq!(asp.total_bytes(), 12 * PAGE_BYTES);
        asp.free(&mut phys, a);
        assert_eq!(asp.total_bytes(), 4 * PAGE_BYTES);
        assert_eq!(asp.object(a).pages(), 0);
    }

    #[test]
    fn node_weights_in_matches_unsized_and_handles_small_hint() {
        let (sys, mut phys, mut asp) = setup();
        let id = asp
            .alloc(
                &sys,
                &mut phys,
                0,
                "nw",
                64 * PAGE_BYTES,
                policy::interleave_all(&sys, 0),
            )
            .unwrap();
        let obj = asp.object(id);
        let sized = obj.node_weights_in(sys.nodes.len());
        assert_eq!(sized, obj.node_weights());
        // An undersized hint must still be correct (buffer grows).
        assert_eq!(obj.node_weights_in(1), sized);
        let w: f64 = sized.iter().map(|&(_, w)| w).sum();
        assert!((w - 1.0).abs() < 1e-12);
    }
}
