//! Physical page frames and per-node zones.
//!
//! Pages are tracked at 2 MB granularity (huge-page-sized regions): the
//! paper's workloads touch 80–450 GB, and 2 MB frames keep the page-level
//! structures (placement maps, tiering hotness counters) tractable while
//! preserving every placement/migration behaviour the paper studies.
//! Zone capacities model the paper's GRUB `mmap`/`memmap` fast-memory
//! limiting (e.g. "LDRAM limited to 64 GB").

use crate::memsim::{NodeId, System};

/// Page size in bytes (2 MB regions).
pub const PAGE_BYTES: u64 = 2 << 20;

/// Convert a byte size to pages, rounding up.
pub fn pages_of(bytes: u64) -> u64 {
    bytes.div_ceil(PAGE_BYTES)
}

/// One node's physical memory zone.
#[derive(Clone, Debug)]
pub struct Zone {
    pub node: NodeId,
    pub capacity_pages: u64,
    pub used_pages: u64,
}

impl Zone {
    pub fn free_pages(&self) -> u64 {
        self.capacity_pages - self.used_pages
    }
}

/// Physical memory across all NUMA nodes, with optional capacity limits.
#[derive(Clone, Debug)]
pub struct PhysMem {
    pub zones: Vec<Zone>,
}

impl PhysMem {
    /// Build from a system, using full device capacities.
    pub fn of_system(sys: &System) -> Self {
        Self {
            zones: sys
                .nodes
                .iter()
                .enumerate()
                .map(|(i, n)| Zone {
                    node: i,
                    capacity_pages: n.device.capacity / PAGE_BYTES,
                    used_pages: 0,
                })
                .collect(),
        }
    }

    /// Limit one node's capacity (GRUB mmap emulation). `bytes` becomes
    /// the new capacity; usage must not already exceed it.
    pub fn limit_node(&mut self, node: NodeId, bytes: u64) {
        let z = &mut self.zones[node];
        let pages = pages_of(bytes);
        assert!(
            z.used_pages <= pages,
            "cannot shrink node {node} below its current usage"
        );
        z.capacity_pages = pages;
    }

    pub fn free_on(&self, node: NodeId) -> u64 {
        self.zones[node].free_pages()
    }

    /// Try to allocate one page on `node`. Returns false if full.
    pub fn try_alloc(&mut self, node: NodeId) -> bool {
        let z = &mut self.zones[node];
        if z.used_pages < z.capacity_pages {
            z.used_pages += 1;
            true
        } else {
            false
        }
    }

    /// Free one page on `node`.
    pub fn free(&mut self, node: NodeId) {
        let z = &mut self.zones[node];
        assert!(z.used_pages > 0, "double free on node {node}");
        z.used_pages -= 1;
    }

    /// Move one page `from` → `to`. Returns false (and changes nothing)
    /// if `to` is full.
    pub fn migrate(&mut self, from: NodeId, to: NodeId) -> bool {
        if from == to {
            return true;
        }
        if self.zones[to].used_pages >= self.zones[to].capacity_pages {
            return false;
        }
        self.free(from);
        assert!(self.try_alloc(to));
        true
    }

    pub fn total_used(&self) -> u64 {
        self.zones.iter().map(|z| z.used_pages).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;

    #[test]
    fn pages_round_up() {
        assert_eq!(pages_of(1), 1);
        assert_eq!(pages_of(PAGE_BYTES), 1);
        assert_eq!(pages_of(PAGE_BYTES + 1), 2);
        assert_eq!(pages_of(0), 0);
    }

    #[test]
    fn capacities_from_system() {
        let pm = PhysMem::of_system(&system_a());
        assert_eq!(pm.zones[0].capacity_pages, (768 << 30) / PAGE_BYTES);
        assert_eq!(pm.total_used(), 0);
    }

    #[test]
    fn alloc_until_full_then_fail() {
        let mut pm = PhysMem::of_system(&system_a());
        pm.limit_node(0, 4 * PAGE_BYTES);
        for _ in 0..4 {
            assert!(pm.try_alloc(0));
        }
        assert!(!pm.try_alloc(0));
        assert_eq!(pm.free_on(0), 0);
        pm.free(0);
        assert!(pm.try_alloc(0));
    }

    #[test]
    fn migrate_respects_target_capacity() {
        let mut pm = PhysMem::of_system(&system_a());
        pm.limit_node(1, PAGE_BYTES);
        assert!(pm.try_alloc(0));
        assert!(pm.try_alloc(1));
        // node 1 full: migration 0→1 must fail and leave state intact.
        let used0 = pm.zones[0].used_pages;
        assert!(!pm.migrate(0, 1));
        assert_eq!(pm.zones[0].used_pages, used0);
        // but 1→0 works
        assert!(pm.migrate(1, 0));
        assert_eq!(pm.zones[1].used_pages, 0);
        assert_eq!(pm.zones[0].used_pages, 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pm = PhysMem::of_system(&system_a());
        pm.free(0);
    }
}
