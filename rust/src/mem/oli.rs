//! Object-level interleaving (OLI) — the paper's §V-B contribution.
//!
//! Instead of interleaving every page of the application uniformly, OLI
//! decides *per data object* whether to interleave its pages across
//! DRAM+CXL (bandwidth-hungry objects) or allocate them "LDRAM preferred"
//! (latency-sensitive objects). Selection criteria from the paper:
//!
//! 1. footprint: the object takes ≥ 10% of total memory consumption;
//! 2. intensity: among those, the objects with the largest number of
//!    memory accesses (several may qualify).
//!
//! Selected objects get `numa_alloc_interleaved_subset`-style placement;
//! everything else is LDRAM-preferred.

use super::policy::Policy;
use crate::memsim::{MemKind, NodeId, System};

/// Workload-provided description of one data object, before placement.
#[derive(Clone, Debug)]
pub struct ObjectSpec {
    pub name: String,
    pub bytes: u64,
    /// Relative number of memory accesses this object receives
    /// (arbitrary units; only ratios matter).
    pub accesses: f64,
    /// Fraction of this object's accesses that are dependent /
    /// latency-bound rather than streaming.
    pub dep_frac: f64,
}

impl ObjectSpec {
    pub fn new(name: &str, bytes: u64, accesses: f64, dep_frac: f64) -> Self {
        Self {
            name: name.to_string(),
            bytes,
            accesses,
            dep_frac,
        }
    }
}

/// Footprint threshold: ≥ 10% of total memory consumption.
pub const FOOTPRINT_FRAC: f64 = 0.10;
/// Intensity threshold: within this factor of the most-accessed
/// qualifying object ("objects with the largest number of accesses").
pub const INTENSITY_FRAC: f64 = 0.5;

/// Apply the paper's two criteria; returns selection flags per object.
pub fn select_bw_hungry(objects: &[ObjectSpec]) -> Vec<bool> {
    let total: u64 = objects.iter().map(|o| o.bytes).sum();
    if total == 0 {
        return vec![false; objects.len()];
    }
    // Criterion 1: large footprint.
    let big: Vec<bool> = objects
        .iter()
        .map(|o| o.bytes as f64 >= FOOTPRINT_FRAC * total as f64)
        .collect();
    // Criterion 2: most-accessed among the big ones.
    let max_acc = objects
        .iter()
        .zip(&big)
        .filter(|&(_, &b)| b)
        .map(|(o, _)| o.accesses)
        .fold(0.0f64, f64::max);
    objects
        .iter()
        .zip(&big)
        .map(|(o, &b)| b && max_acc > 0.0 && o.accesses >= INTENSITY_FRAC * max_acc)
        .collect()
}

/// The per-object policy assignment OLI produces.
#[derive(Clone, Debug)]
pub struct OliPlan {
    /// (object index, policy, selected-for-interleave?)
    pub assignments: Vec<(usize, Policy, bool)>,
    pub interleave_nodes: Vec<NodeId>,
    pub preferred_node: NodeId,
}

/// Build the OLI placement plan: bandwidth-hungry objects interleave over
/// `interleave_kinds` (paper: LDRAM+CXL); the rest are LDRAM-preferred.
pub fn plan(
    sys: &System,
    socket: usize,
    objects: &[ObjectSpec],
    interleave_kinds: &[MemKind],
) -> OliPlan {
    let selected = select_bw_hungry(objects);
    let inter_nodes: Vec<NodeId> = interleave_kinds
        .iter()
        .map(|&k| sys.node_of(socket, k).expect("missing node kind"))
        .collect();
    let preferred = sys.node_of(socket, MemKind::Ldram).unwrap();
    let assignments = objects
        .iter()
        .enumerate()
        .map(|(i, _)| {
            if selected[i] {
                (i, Policy::Interleave(inter_nodes.clone()), true)
            } else {
                (i, Policy::Preferred(preferred), false)
            }
        })
        .collect();
    OliPlan {
        assignments,
        interleave_nodes: inter_nodes,
        preferred_node: preferred,
    }
}

/// Fast-memory (LDRAM) bytes OLI needs vs. an LDRAM-preferred baseline:
/// interleaved objects only keep `1/len(interleave_set)` of their pages
/// in LDRAM. Returns (oli_ldram_bytes, baseline_ldram_bytes).
pub fn ldram_demand(objects: &[ObjectSpec], plan: &OliPlan) -> (u64, u64) {
    let baseline: u64 = objects.iter().map(|o| o.bytes).sum();
    let mut oli = 0u64;
    let has_ldram = plan.interleave_nodes.contains(&plan.preferred_node);
    let share = if plan.interleave_nodes.is_empty() {
        0.0
    } else if has_ldram {
        1.0 / plan.interleave_nodes.len() as f64
    } else {
        0.0
    };
    for &(i, _, selected) in &plan.assignments {
        if selected {
            oli += (objects[i].bytes as f64 * share) as u64;
        } else {
            oli += objects[i].bytes;
        }
    }
    (oli, baseline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_a;

    fn gb(x: u64) -> u64 {
        x << 30
    }

    #[test]
    fn small_objects_never_selected() {
        let objs = vec![
            ObjectSpec::new("big", gb(90), 100.0, 0.1),
            ObjectSpec::new("tiny", gb(1), 1e9, 0.1), // hot but tiny
        ];
        let sel = select_bw_hungry(&objs);
        assert_eq!(sel, vec![true, false]);
    }

    #[test]
    fn cold_big_objects_not_selected() {
        let objs = vec![
            ObjectSpec::new("hot", gb(50), 100.0, 0.1),
            ObjectSpec::new("coldbig", gb(50), 1.0, 0.1),
        ];
        let sel = select_bw_hungry(&objs);
        assert_eq!(sel, vec![true, false]);
    }

    #[test]
    fn multiple_objects_can_qualify() {
        // BT-style: u, rsh, forcing all large and similarly hot.
        let objs = vec![
            ObjectSpec::new("u", gb(40), 90.0, 0.1),
            ObjectSpec::new("rsh", gb(40), 100.0, 0.1),
            ObjectSpec::new("forcing", gb(40), 80.0, 0.1),
            ObjectSpec::new("rest", gb(46), 5.0, 0.3),
        ];
        let sel = select_bw_hungry(&objs);
        assert_eq!(sel, vec![true, true, true, false]);
    }

    #[test]
    fn empty_input() {
        assert!(select_bw_hungry(&[]).is_empty());
    }

    #[test]
    fn plan_assigns_policies() {
        let sys = system_a();
        let objs = vec![
            ObjectSpec::new("a", gb(60), 100.0, 0.05),
            ObjectSpec::new("b", gb(40), 2.0, 0.6),
        ];
        let p = plan(&sys, 0, &objs, &[MemKind::Ldram, MemKind::Cxl]);
        assert!(matches!(p.assignments[0].1, Policy::Interleave(_)));
        assert!(matches!(p.assignments[1].1, Policy::Preferred(_)));
        assert!(p.assignments[0].2 && !p.assignments[1].2);
    }

    #[test]
    fn ldram_savings_computed() {
        let sys = system_a();
        // One 100 GB bandwidth-hungry object + 20 GB of everything else:
        // OLI keeps 50 GB + 20 GB in LDRAM vs 120 GB baseline → 42% saved.
        let objs = vec![
            ObjectSpec::new("a", gb(100), 100.0, 0.05),
            ObjectSpec::new("b", gb(20), 2.0, 0.6),
        ];
        let p = plan(&sys, 0, &objs, &[MemKind::Ldram, MemKind::Cxl]);
        let (oli, base) = ldram_demand(&objs, &p);
        assert_eq!(base, gb(120));
        assert_eq!(oli, gb(70));
        let saved = 1.0 - oli as f64 / base as f64;
        assert!((saved - 0.4167).abs() < 0.01);
    }
}
