//! Virtual-memory substrate: page frames + zones, placement policies
//! (first-touch / preferred / membind / interleave), and the paper's
//! object-level interleaving (OLI) planner.

pub mod oli;
pub mod page;
pub mod policy;
pub mod vmm;

pub use oli::{plan as oli_plan, ObjectSpec, OliPlan};
pub use page::{pages_of, PhysMem, Zone, PAGE_BYTES};
pub use policy::Policy;
pub use vmm::{AddressSpace, DataObject, ObjectId};
