//! Bandwidth-aware thread assignment (§III, Fig 3(d)).
//!
//! The paper observes that each tier has a distinct saturation point, so
//! to maximize total bandwidth one should cap the threads assigned to each
//! tier at its saturation count (system B: 6 CXL + 23 LDRAM + 23 RDRAM
//! threads ⇒ ~420 GB/s). This module searches that assignment.

use super::mlc::{bw_scaling_sweep, combined_bw, saturation_threads};
use crate::memsim::{MemKind, NodeId, Pattern, System};

/// A thread→tier assignment and the bandwidth it achieves.
#[derive(Clone, Debug)]
pub struct Assignment {
    /// (node, #threads), in the order the search considered them.
    pub split: Vec<(NodeId, usize)>,
    pub total_bw_gbs: f64,
}

impl Assignment {
    pub fn threads_for(&self, node: NodeId) -> usize {
        self.split
            .iter()
            .find(|&&(n, _)| n == node)
            .map(|&(_, t)| t)
            .unwrap_or(0)
    }
}

/// Greedy saturation-guided search with local refinement.
///
/// 1. Seed each tier with its single-tier saturation thread count,
///    scaled down proportionally if the seed exceeds the core budget.
/// 2. Hill-climb: repeatedly move one thread between tiers while total
///    bandwidth improves.
pub fn best_assignment(sys: &System, socket: usize, total_threads: usize) -> Assignment {
    let nodes: Vec<NodeId> = [MemKind::Ldram, MemKind::Rdram, MemKind::Cxl]
        .iter()
        .filter_map(|&k| sys.node_of(socket, k))
        .collect();
    assert!(!nodes.is_empty());

    // Seed from saturation points.
    let mut alloc: Vec<usize> = nodes
        .iter()
        .map(|&n| {
            let sweep = bw_scaling_sweep(sys, socket, n, Pattern::Sequential, total_threads);
            saturation_threads(&sweep, 0.97)
        })
        .collect();
    let seed_total: usize = alloc.iter().sum();
    if seed_total > total_threads {
        // Scale down, preserving at least 1 thread per tier.
        let scale = total_threads as f64 / seed_total as f64;
        for a in alloc.iter_mut() {
            *a = ((*a as f64 * scale).round() as usize).max(1);
        }
        while alloc.iter().sum::<usize>() > total_threads {
            let i = alloc
                .iter()
                .enumerate()
                .max_by_key(|&(_, &a)| a)
                .map(|(i, _)| i)
                .unwrap();
            alloc[i] -= 1;
        }
    }

    let score = |alloc: &[usize]| -> f64 {
        let split: Vec<(NodeId, usize)> =
            nodes.iter().copied().zip(alloc.iter().copied()).collect();
        combined_bw(sys, socket, &split)
    };

    let mut best = score(&alloc);
    // Hill climbing: move one thread i→j if it helps.
    let mut improved = true;
    while improved {
        improved = false;
        for i in 0..alloc.len() {
            for j in 0..alloc.len() {
                if i == j || alloc[i] == 0 {
                    continue;
                }
                let mut cand = alloc.clone();
                cand[i] -= 1;
                cand[j] += 1;
                let s = score(&cand);
                if s > best * 1.0005 {
                    best = s;
                    alloc = cand;
                    improved = true;
                }
            }
        }
        // Also try adding an unused thread if under budget (re-check the
        // budget before every add — each accepted add consumes one).
        for j in 0..alloc.len() {
            if alloc.iter().sum::<usize>() >= total_threads {
                break;
            }
            let mut cand = alloc.clone();
            cand[j] += 1;
            let s = score(&cand);
            if s > best * 1.0005 {
                best = s;
                alloc = cand;
                improved = true;
            }
        }
    }

    Assignment {
        split: nodes.into_iter().zip(alloc).collect(),
        total_bw_gbs: best,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::system_b;
    use crate::probes::mlc::combined_bw;

    #[test]
    fn beats_uniform_assignment_on_system_b() {
        let sys = system_b();
        let total = 52;
        let best = best_assignment(&sys, 0, total);
        // Uniform split across the three tiers.
        let nodes: Vec<NodeId> = best.split.iter().map(|&(n, _)| n).collect();
        let uniform: Vec<(NodeId, usize)> =
            nodes.iter().map(|&n| (n, total / nodes.len())).collect();
        let uni_bw = combined_bw(&sys, 0, &uniform);
        assert!(
            best.total_bw_gbs > uni_bw,
            "best {} <= uniform {}",
            best.total_bw_gbs,
            uni_bw
        );
    }

    #[test]
    fn cxl_gets_few_threads() {
        // Fig 3(d): only ~6 threads should go to CXL on system B.
        let sys = system_b();
        let best = best_assignment(&sys, 0, 52);
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let t = best.threads_for(cxl);
        assert!(t <= 12, "CXL threads {t}");
        assert!(t >= 1);
    }

    #[test]
    fn total_bw_in_420_gbs_ballpark() {
        // §III: the tuned assignment reaches ~420 GB/s on system B.
        let sys = system_b();
        let best = best_assignment(&sys, 0, 52);
        assert!(
            (300.0..=470.0).contains(&best.total_bw_gbs),
            "bw {}",
            best.total_bw_gbs
        );
    }

    #[test]
    fn respects_thread_budget() {
        let sys = system_b();
        let best = best_assignment(&sys, 0, 16);
        let used: usize = best.split.iter().map(|&(_, t)| t).sum();
        assert!(used <= 16);
    }
}
