//! Intel-MLC-style measurement probes over the simulator.
//!
//! These implement the paper's §III methodology: pointer-chase idle
//! latency (5,000 reps, outlier-excluded mean), multi-threaded
//! sequential/random bandwidth sweeps (2,000 reps), the loaded-latency
//! delay sweep (Fig 4), and the bandwidth-aware thread-assignment search
//! the paper derives from Fig 3(d).

pub mod assign;
pub mod mlc;

pub use assign::{best_assignment, Assignment};
pub use mlc::{bw_scaling_sweep, idle_latency, loaded_latency_sweep, BwPoint, LoadPoint};
