//! MLC-equivalent probes: idle latency, bandwidth scaling, loaded latency.

use crate::memsim::{NodeId, Pattern, Stream, System};
use crate::util::par::par_map_auto;
use crate::util::rng::Rng;
use crate::util::stats;

/// One point of a bandwidth-vs-threads sweep (Fig 3).
#[derive(Clone, Debug)]
pub struct BwPoint {
    pub threads: usize,
    pub bw_gbs: f64,
    pub latency_ns: f64,
}

/// One point of a loaded-latency sweep (Fig 4).
#[derive(Clone, Debug)]
pub struct LoadPoint {
    pub delay_ns: f64,
    pub bw_gbs: f64,
    pub latency_ns: f64,
}

/// Idle latency via pointer chasing: repeat the probe `reps` times with
/// small measurement noise (OS jitter, TLB misses) and report the
/// outlier-excluded mean — the paper's §III methodology. Deterministic
/// for a given seed.
pub fn idle_latency(
    sys: &System,
    socket: usize,
    node: NodeId,
    pattern: Pattern,
    reps: usize,
    seed: u64,
) -> f64 {
    let base = sys.idle_latency(socket, node, pattern);
    let mut rng = Rng::seeded(seed ^ (node as u64) << 8 ^ socket as u64);
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        // 2% gaussian measurement noise + occasional outlier spikes from
        // "operating system services and random TLB misses".
        let mut v = base * (1.0 + 0.02 * rng.normal());
        if rng.chance(0.01) {
            v += base * rng.range_f64(1.0, 8.0);
        }
        samples.push(v);
    }
    stats::mean_excluding_outliers(&samples, 3.0)
}

/// Bandwidth scaling: drive `node` with 1..=max_threads (Fig 3).
/// The per-thread-count solves are independent; they fan out over
/// [`crate::perf::current_jobs`] threads when the CLI raised `--jobs`
/// (sequential by default).
pub fn bw_scaling_sweep(
    sys: &System,
    socket: usize,
    node: NodeId,
    pattern: Pattern,
    max_threads: usize,
) -> Vec<BwPoint> {
    let threads: Vec<usize> = (1..=max_threads).collect();
    par_map_auto(&threads, |&t| {
        let (bw, lat) = sys.drive(socket, node, pattern, t as f64, 0.0);
        BwPoint {
            threads: t,
            bw_gbs: bw,
            latency_ns: lat,
        }
    })
}

/// Loaded latency: fixed thread count, sweep the inter-access injection
/// delay from high (idle) to zero (saturated) — Fig 4. Returns points in
/// descending-delay order, matching the figure's left-to-right axis.
pub fn loaded_latency_sweep(
    sys: &System,
    socket: usize,
    node: NodeId,
    pattern: Pattern,
    threads: usize,
    delays_ns: &[f64],
) -> Vec<LoadPoint> {
    let mut pts: Vec<LoadPoint> = delays_ns
        .iter()
        .map(|&d| {
            let (bw, lat) = sys.drive(socket, node, pattern, threads as f64, d);
            LoadPoint {
                delay_ns: d,
                bw_gbs: bw,
                latency_ns: lat,
            }
        })
        .collect();
    pts.sort_by(|a, b| b.delay_ns.partial_cmp(&a.delay_ns).unwrap());
    pts
}

/// The delay grid used by the paper (0 → 80 µs).
pub fn mlc_delay_grid() -> Vec<f64> {
    vec![
        80_000.0, 40_000.0, 20_000.0, 10_000.0, 5_000.0, 2_500.0, 1_250.0, 600.0, 300.0, 150.0,
        80.0, 40.0, 20.0, 10.0, 5.0, 2.0, 1.0, 0.0,
    ]
}

/// Saturation point: smallest thread count achieving `frac` of the
/// sweep's plateau bandwidth.
pub fn saturation_threads(points: &[BwPoint], frac: f64) -> usize {
    let peak = points.iter().map(|p| p.bw_gbs).fold(0.0f64, f64::max);
    points
        .iter()
        .find(|p| p.bw_gbs >= frac * peak)
        .map(|p| p.threads)
        .unwrap_or(points.len())
}

/// Peak bandwidth of a sweep.
pub fn peak_bw(points: &[BwPoint]) -> f64 {
    points.iter().map(|p| p.bw_gbs).fold(0.0f64, f64::max)
}

/// Drive several node groups simultaneously with a given thread split and
/// report the combined bandwidth (the §III thread-assignment experiment).
pub fn combined_bw(sys: &System, socket: usize, split: &[(NodeId, usize)]) -> f64 {
    let streams: Vec<Stream> = split
        .iter()
        .filter(|&&(_, t)| t > 0)
        .map(|&(node, t)| Stream {
            socket,
            node_weights: vec![(node, 1.0)],
            pattern: Pattern::Sequential,
            threads: t as f64,
            delay_ns: 0.0,
        })
        .collect();
    if streams.is_empty() {
        return 0.0;
    }
    sys.solve_traffic(&streams)
        .streams
        .iter()
        .map(|s| s.bw_gbs)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::topology::{system_a, system_b};
    use crate::memsim::MemKind;

    #[test]
    fn idle_latency_close_to_model_and_deterministic() {
        let sys = system_a();
        let node = sys.node_of(0, MemKind::Cxl).unwrap();
        let a = idle_latency(&sys, 0, node, Pattern::Random, 5000, 1);
        let b = idle_latency(&sys, 0, node, Pattern::Random, 5000, 1);
        assert_eq!(a, b);
        let base = sys.idle_latency(0, node, Pattern::Random);
        assert!((a - base).abs() / base < 0.05, "a={a} base={base}");
    }

    #[test]
    fn sweep_monotone_until_plateau() {
        let sys = system_b();
        let node = sys.node_of(0, MemKind::Ldram).unwrap();
        let pts = bw_scaling_sweep(&sys, 0, node, Pattern::Sequential, 52);
        for w in pts.windows(2) {
            assert!(w[1].bw_gbs >= w[0].bw_gbs * 0.999);
        }
        assert!(peak_bw(&pts) <= sys.nodes[node].device.peak_bw_gbs * 1.01);
    }

    #[test]
    fn cxl_saturates_before_dram_system_b() {
        let sys = system_b();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let rd = sys.node_of(0, MemKind::Rdram).unwrap();
        let s_cxl = saturation_threads(&bw_scaling_sweep(&sys, 0, cxl, Pattern::Sequential, 52), 0.95);
        let s_ld = saturation_threads(&bw_scaling_sweep(&sys, 0, ld, Pattern::Sequential, 52), 0.95);
        let s_rd = saturation_threads(&bw_scaling_sweep(&sys, 0, rd, Pattern::Sequential, 52), 0.95);
        assert!(s_cxl <= 10, "cxl sat {s_cxl}");
        assert!(s_ld > 2 * s_cxl, "ldram sat {s_ld}");
        assert!(s_rd > s_cxl, "rdram sat {s_rd}");
    }

    #[test]
    fn loaded_latency_knee() {
        let sys = system_a();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let pts = loaded_latency_sweep(&sys, 0, ld, Pattern::Sequential, 32, &mlc_delay_grid());
        // Left of the figure (high delay): near idle latency. Right
        // (delay 0): latency skyrockets, bandwidth near peak.
        let first = pts.first().unwrap();
        let last = pts.last().unwrap();
        assert!(first.latency_ns < 1.3 * sys.idle_latency(0, ld, Pattern::Sequential));
        assert!(last.latency_ns > 2.0 * first.latency_ns);
        assert!(last.bw_gbs > 0.9 * sys.nodes[ld].device.peak_bw_gbs);
    }

    #[test]
    fn combined_bw_adds_tiers() {
        let sys = system_b();
        let ld = sys.node_of(0, MemKind::Ldram).unwrap();
        let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
        let only_ld = combined_bw(&sys, 0, &[(ld, 26)]);
        let both = combined_bw(&sys, 0, &[(ld, 26), (cxl, 6)]);
        assert!(both > only_ld * 1.05, "both={both} only={only_ld}");
    }
}
