//! Report sink: experiment drivers print paper-format tables and can
//! also emit CSV / JSON for downstream plotting.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::table::Table;

/// Output format selection for the experiment CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    Text,
    Csv,
    Json,
}

/// Collects the tables of one experiment run.
#[derive(Default)]
pub struct Report {
    pub tables: Vec<Table>,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, t: Table) -> &mut Self {
        self.tables.push(t);
        self
    }

    /// The report as a JSON document: `{"tables": [...]}`. This is the
    /// one JSON shape every emitter shares — `--json` report output, the
    /// `exp all --json` array, and the scenario JSONL result lines all
    /// serialize through this value and [`Json`]'s writer.
    pub fn to_json(&self) -> Json {
        let tables: Vec<Json> = self
            .tables
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("title", t.title.as_str().into()),
                    (
                        "headers",
                        Json::arr(t.headers.iter().map(|h| Json::from(h.as_str()))),
                    ),
                    (
                        "rows",
                        Json::arr(
                            t.rows
                                .iter()
                                .map(|r| Json::arr(r.iter().map(|c| Json::from(c.as_str())))),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![("tables", Json::Arr(tables))])
    }

    pub fn render(&self, fmt: Format) -> String {
        match fmt {
            Format::Text => self
                .tables
                .iter()
                .map(|t| t.render())
                .collect::<Vec<_>>()
                .join("\n"),
            Format::Csv => self
                .tables
                .iter()
                .map(|t| format!("# {}\n{}", t.title, t.to_csv()))
                .collect::<Vec<_>>()
                .join("\n"),
            Format::Json => self.to_json().to_string(),
        }
    }

    pub fn print(&self, fmt: Format) {
        println!("{}", self.render(fmt));
    }

    pub fn save(&self, path: &Path, fmt: Format) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render(fmt).as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new();
        let mut t = Table::new("fig", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        r.add(t);
        r
    }

    #[test]
    fn renders_all_formats() {
        let r = sample();
        assert!(r.render(Format::Text).contains("== fig =="));
        assert!(r.render(Format::Csv).contains("a,b"));
        let j = Json::parse(&r.render(Format::Json)).unwrap();
        assert!(j.get("tables").is_some());
    }

    #[test]
    fn saves_to_file() {
        let r = sample();
        let path = std::env::temp_dir().join("cxlmem_report_test.csv");
        r.save(&path, Format::Csv).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("1,2"));
        let _ = std::fs::remove_file(path);
    }
}
