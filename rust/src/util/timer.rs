//! Micro-benchmark timing harness (no `criterion` in the offline vendor
//! set). Used by `rust/benches/*` (built with `harness = false`).

use std::time::{Duration, Instant};

use super::metrics;
use super::stats;

/// Result of benchmarking one target.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p50_ns: f64,
    pub p90_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
}

/// Quantile through the shared [`metrics`] histogram buckets: a
/// `BenchResult` p50/p90 and a `cxlmem-metrics-v1` histogram quantile
/// over the same samples agree exactly (same bucket edges, same rank
/// interpolation) — the point is that BENCH_hotpath.json and a metrics
/// sidecar are directly comparable.
pub fn bucketed_percentile(samples_ns: &[f64], p: f64) -> f64 {
    let mut buckets = std::collections::BTreeMap::new();
    for &s in samples_ns {
        *buckets.entry(metrics::bucket_index(s.max(0.0) as u64)).or_insert(0u64) += 1;
    }
    metrics::quantile_of_sparse(&buckets, p)
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// criterion-style one-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{:>12} median {:>12} p95 {:>12}]  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench harness: warms up, then samples `f` until `budget` is consumed
/// (at least `min_samples` samples). `f` should perform ONE unit of work;
/// use `std::hint::black_box` inside to defeat DCE.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_samples: 5,
            results: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + estimate per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        // Aim for ~100 samples in the budget; batch iterations if fast.
        let target_sample_s = (self.budget.as_secs_f64() / 100.0).max(1e-6);
        let batch = ((target_sample_s / per_iter.max(1e-9)) as u64).max(1);

        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0u64;
        while start.elapsed() < self.budget || samples_ns.len() < self.min_samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples_ns.len() > 10_000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&samples_ns),
            median_ns: stats::median(&samples_ns),
            p50_ns: bucketed_percentile(&samples_ns, 50.0),
            p90_ns: bucketed_percentile(&samples_ns, 90.0),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            stddev_ns: stats::stddev(&samples_ns),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleepless_work() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(30),
            min_samples: 3,
            results: Vec::new(),
        };
        let r = b
            .bench("sum", || {
                let s: u64 = std::hint::black_box((0..1000u64).sum());
                std::hint::black_box(s);
            })
            .clone();
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.p50_ns > 0.0 && r.p90_ns >= r.p50_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn bucketed_percentiles_match_plain_percentiles_on_representatives() {
        // 0..16 are exact histogram buckets (identity region), so the
        // bucketed quantile reproduces stats::percentile bit-for-bit —
        // pins that timer and util::metrics share one bucket scheme.
        let samples: Vec<f64> = (0..16).map(|i| i as f64).collect();
        for p in [0.0, 50.0, 90.0, 100.0] {
            assert_eq!(bucketed_percentile(&samples, p), stats::percentile(&samples, p));
        }
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
