//! Scoped-thread parallel map for experiment sweeps (no `rayon` in the
//! offline vendor set).
//!
//! Work is distributed by an atomic index counter (dynamic load balance —
//! experiment costs vary by two orders of magnitude), results are
//! reassembled in input order, and the caller's [`crate::perf`] context
//! and [`crate::util::cancel`] token are propagated into each worker
//! (with inner `jobs` pinned to 1 so nested sweeps don't oversubscribe
//! the machine). [`spawn_worker`] gives long-lived threads — the serve
//! daemon's pool, the supervision watchdog — the same propagation.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::perf;
use crate::util::cancel;

/// Map `f` over `items` using up to `jobs` OS threads, preserving input
/// order in the output. `jobs <= 1` (or a single item) runs inline on the
/// calling thread; a worker panic propagates to the caller — with its
/// original payload, and only after **every** worker has been joined, so
/// a panicking chunk never aborts the process or leaves detached workers
/// racing the caller's next step (the supervised batch runner relies on
/// this to turn per-spec panics into structured error documents).
pub fn par_map<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs <= 1 || items.len() <= 1 {
        return items.iter().map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let ctx = perf::snapshot();
    let token = cancel::current();
    let f = &f;
    let mut indexed: Vec<(usize, R)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..jobs)
            .map(|_| {
                let next = &next;
                let token = &token;
                s.spawn(move || {
                    perf::apply(ctx);
                    perf::set_jobs(1);
                    let _cancel = token.as_ref().map(cancel::enter);
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        out.push((i, f(&items[i])));
                    }
                    out
                })
            })
            .collect();
        // Join ALL workers before deciding the outcome: the surviving
        // workers keep draining the shared index counter, and their
        // completed results are simply discarded if anyone panicked.
        let mut results = Vec::new();
        let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            match h.join() {
                Ok(part) => results.extend(part),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = first_panic {
            std::panic::resume_unwind(payload);
        }
        results
    });
    indexed.sort_unstable_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Spawn a named long-lived worker thread that inherits the caller's
/// [`crate::perf`] context and [`crate::util::cancel`] token. The worker
/// starts with the fresh-thread default of `jobs = 1` (like `par_map`
/// workers); owners that want inner parallelism raise it themselves.
/// Used by the supervision deadline watchdog and the serve daemon's
/// worker pool.
pub fn spawn_worker<F, R>(name: &str, f: F) -> std::io::Result<std::thread::JoinHandle<R>>
where
    F: FnOnce() -> R + Send + 'static,
    R: Send + 'static,
{
    let ctx = perf::snapshot();
    let token = cancel::current();
    std::thread::Builder::new().name(name.to_string()).spawn(move || {
        perf::apply(ctx);
        let _cancel = token.as_ref().map(cancel::enter);
        f()
    })
}

/// Split `0..len` into at most `chunks` contiguous, near-equal ranges
/// (the first `len % chunks` ranges are one element longer). Used by the
/// chunked tiering hot paths: each range is scanned independently and the
/// partial results are rank-merged, so the split geometry never affects
/// the final answer — only how the work is distributed.
pub fn chunk_ranges(len: usize, chunks: usize) -> Vec<std::ops::Range<usize>> {
    let chunks = chunks.max(1).min(len.max(1));
    let base = len / chunks;
    let extra = len % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

/// `par_map` with the current thread's configured job count
/// ([`perf::current_jobs`]); the default of 1 keeps library calls
/// sequential unless the CLI raised it.
pub fn par_map_auto<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map(items, perf::current_jobs(), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..100).collect();
        let out = par_map(&xs, 8, |&x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<u64>>());
    }

    #[test]
    fn inline_when_single_job() {
        let xs = vec![1, 2, 3];
        assert_eq!(par_map(&xs, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = Vec::new();
        let out: Vec<u32> = par_map(&xs, 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn propagates_reference_mode_into_workers() {
        let xs: Vec<u32> = (0..16).collect();
        let flags = crate::perf::with_reference(|| {
            par_map(&xs, 4, |_| crate::perf::reference_enabled())
        });
        assert!(flags.iter().all(|&r| r));
        let flags = par_map(&xs, 4, |_| crate::perf::reference_enabled());
        assert!(flags.iter().all(|&r| !r));
    }

    #[test]
    fn chunk_ranges_cover_exactly_in_order() {
        for len in [0usize, 1, 2, 7, 8, 9, 100, 65_000] {
            for chunks in [1usize, 2, 3, 8, 64] {
                let rs = chunk_ranges(len, chunks);
                assert!(!rs.is_empty());
                assert!(rs.len() <= chunks.max(1));
                assert_eq!(rs[0].start, 0);
                assert_eq!(rs.last().unwrap().end, len);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                // Near-equal: sizes differ by at most one.
                let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1);
            }
        }
    }

    #[test]
    fn workers_run_inner_jobs_sequentially() {
        let xs: Vec<u32> = (0..8).collect();
        let inner = par_map(&xs, 4, |_| crate::perf::current_jobs());
        assert!(inner.iter().all(|&j| j == 1));
    }

    #[test]
    fn propagates_cancel_token_into_workers() {
        let xs: Vec<u32> = (0..16).collect();
        let token = cancel::CancelToken::new();
        token.cancel();
        let seen = cancel::with_token(&token, || par_map(&xs, 4, |_| cancel::cancelled()));
        assert!(seen.iter().all(|&c| c));
        let seen = par_map(&xs, 4, |_| cancel::cancelled());
        assert!(seen.iter().all(|&c| !c));
    }

    #[test]
    fn spawn_worker_inherits_context_and_token() {
        let token = cancel::CancelToken::new();
        token.cancel();
        let handle = crate::perf::with_reference(|| {
            cancel::with_token(&token, || {
                spawn_worker("cxlmem-test-worker", || {
                    (
                        crate::perf::reference_enabled(),
                        crate::perf::current_jobs(),
                        cancel::cancelled(),
                    )
                })
                .expect("spawn")
            })
        });
        let (reference, jobs, cancelled) = handle.join().expect("join");
        assert!(reference, "perf context must be inherited");
        assert_eq!(jobs, 1, "workers start with the fresh-thread default");
        assert!(cancelled, "cancel token must be inherited");
    }

    /// One panicking chunk of many: the panic must reach the caller as an
    /// unwind carrying the *original* payload (not an `.expect` abort of
    /// a secondary panic), and only after every worker was joined — all
    /// other items keep getting processed off the shared counter.
    #[test]
    fn panicking_chunk_unwinds_with_payload_after_joining_all() {
        use std::sync::atomic::AtomicUsize;

        let xs: Vec<u32> = (0..64).collect();
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            par_map(&xs, 4, |&x| {
                if x == 13 {
                    panic!("chunk 13 exploded");
                }
                done.fetch_add(1, Ordering::SeqCst);
                x
            })
        }));
        let payload = result.expect_err("the panic must propagate");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_string)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("chunk 13 exploded"), "payload lost: {msg:?}");
        // Every worker was joined, and the survivors drained the counter:
        // all items except the panicking one completed.
        assert_eq!(done.load(Ordering::SeqCst), xs.len() - 1);
        // The executor stays usable after a panicked batch.
        let out = par_map(&xs, 4, |&x| x + 1);
        assert_eq!(out.len(), xs.len());
    }
}
